//! E1 — the paper's §II properties table:
//!
//! |                         | Gumbel-Sinkhorn | Kissing | SoftSort | Ours |
//! | Number of parameters K  | N²              | 2NM     | N        | N    |
//! | Non-iterative norm.     | no              | yes     | yes      | yes  |
//! | Quality                 | ++              | +       | -        | ++   |
//! | Stability               | +               | o       | ++       | ++   |
//!
//! Parameters and normalization are structural (read from the manifest /
//! method definitions); quality and stability are *measured*: short runs
//! over several seeds, stability = fraction of runs yielding a valid
//! permutation without repair.

mod common;

use shufflesort::bench::{banner, quick_mode, Table};
use shufflesort::data::random_colors;

fn grade_quality(dpq: f64) -> &'static str {
    match dpq {
        q if q >= 0.75 => "++",
        q if q >= 0.55 => "+",
        q if q >= 0.35 => "o",
        _ => "-",
    }
}

fn grade_stability(valid_rate: f64) -> &'static str {
    match valid_rate {
        v if v >= 0.99 => "++",
        v if v >= 0.8 => "+",
        v if v >= 0.5 => "o",
        _ => "-",
    }
}

fn main() {
    let side = 16usize; // stability statistics want repeats; keep N=256
    let n = side * side;
    banner("E1/properties", "structural + measured properties per method");
    let engine = common::engine();
    let seeds: &[u64] = if quick_mode() { &[1, 2, 3] } else { &[1, 2, 3, 4, 5, 6, 7, 8] };

    let methods: &[(&str, &str, &str, &str)] = &[
        // label, key, params formula, non-iterative normalization?
        ("Gumbel-Sinkhorn", "gs", "N^2", "no"),
        ("Kissing", "kiss", "2NM", "yes"),
        ("SoftSort", "softsort", "N", "yes"),
        ("ShuffleSoftSort", "sss", "N", "yes"),
    ];

    let mut table = Table::new(&[
        "Property", "Gumbel-Sinkhorn", "Kissing", "SoftSort", "Ours",
    ]);

    let mut params_row = vec!["Parameters K".to_string()];
    let mut norm_row = vec!["Non-iterative normalization".to_string()];
    let mut quality_row = vec!["Quality (measured)".to_string()];
    let mut stability_row = vec!["Stability (measured)".to_string()];

    for (_, key, formula, noniter) in methods {
        let mut dpq_best = 0.0f64;
        let mut valid = 0usize;
        let mut params = 0usize;
        for &seed in seeds {
            let ds = random_colors(n, seed);
            let out = common::run_method(&engine, key, &ds, side);
            dpq_best = dpq_best.max(out.report.final_dpq);
            if out.report.valid_without_repair {
                valid += 1;
            }
            params = out.report.param_count;
        }
        let rate = valid as f64 / seeds.len() as f64;
        params_row.push(format!("{formula} = {params}"));
        norm_row.push(noniter.to_string());
        quality_row.push(format!("{} ({dpq_best:.2})", grade_quality(dpq_best)));
        stability_row.push(format!("{} ({:.0}%)", grade_stability(rate), rate * 100.0));
    }
    table.row(&params_row);
    table.row(&norm_row);
    table.row(&quality_row);
    table.row(&stability_row);
    table.print();
    println!(
        "\npaper expectations: K row exact; GS 'no' normalization; quality ++/+/-/++;\n\
         stability +/o/++/++ (Kissing the least stable)."
    );
}
