//! E2 — the paper's §III evaluation table on random RGB colors:
//!
//! | Method          | Memory ↓ | Runtime [s] ↓ | Quality (DPQ16) ↑ |
//! | Gumbel-Sinkhorn | 1048576  | 226.8         | 0.913             |
//! | Kissing         | 26624    | 114.4         | -* (invalid)      |
//! | SoftSort        | 1024     | 110.7         | 0.698             |
//! | ShuffleSoftSort | 1024     | 98.0          | 0.892             |
//!
//! Absolute runtimes are testbed-relative (the paper used an M1 Max; this
//! runs single-core CPU PJRT). What must reproduce (DESIGN.md §4): the
//! memory column exactly; ShuffleSoftSort ≈ Gumbel-Sinkhorn quality with
//! both well above SoftSort; Kissing unstable; ShuffleSoftSort cheapest
//! per unit of quality.

mod common;

use shufflesort::bench::{banner, Table};
use shufflesort::data::random_colors;

fn main() {
    let side = common::headline_side();
    let n = side * side;
    banner("E2/main-table", &format!("{n} random RGB colors on {side}x{side}"));
    let engine = common::engine();
    let ds = random_colors(n, 42);

    let paper: &[(&str, &str, f64, &str)] = &[
        ("Gumbel-Sinkhorn", "gs", 226.8, "0.913"),
        ("Kissing", "kiss", 114.4, "-* invalid"),
        ("SoftSort", "softsort", 110.7, "0.698"),
        ("ShuffleSoftSort", "sss", 98.0, "0.892"),
    ];

    let mut table = Table::new(&[
        "Method", "Memory", "Runtime[s]", "DPQ16", "Valid", "Paper-DPQ16", "Paper-Rt[s]",
    ]);
    for (label, key, paper_rt, paper_q) in paper {
        let out = common::run_method(&engine, key, &ds, side);
        table.row(&[
            label.to_string(),
            out.report.param_count.to_string(),
            format!("{:.1}", out.report.wall_secs),
            format!("{:.3}", out.report.final_dpq),
            if out.report.valid_without_repair {
                "yes".into()
            } else {
                format!("repaired {}", out.report.repaired)
            },
            paper_q.to_string(),
            format!("{paper_rt}"),
        ]);
    }
    table.print();
    println!(
        "\nexpected shape: memory column exact; ShuffleSoftSort & GS ≫ SoftSort quality;\n\
         Kissing invalid/repaired; ShuffleSoftSort lowest runtime per quality."
    );
}
