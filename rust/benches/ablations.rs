//! E8 — ablations over the design choices DESIGN.md §4 calls out:
//! shuffle strategy (Algorithm 1's randperm vs scan vs mixed vs none),
//! inner iteration count I (paper: 4), the inner τ ramp, and the greedy
//! phase-acceptance guard. All on the same color workload and budget.
//!
//! Each variant is the default registry config plus one `k=v` override —
//! exactly what a user would pass on the `sssort` command line.

mod common;

use shufflesort::bench::{banner, Table};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;

fn main() {
    let side = 16usize; // ablations need repeats; N=256 keeps each run ~10 s
    let n = side * side;
    banner("E8/ablations", &format!("{n} colors, one factor varied at a time"));
    let engine = common::engine();
    let ds = random_colors(n, 42);
    let g = GridShape::new(side, side);
    let base = common::method_overrides("sss", side);

    let mut table = Table::new(&["Variant", "DPQ16", "loss", "rejected", "secs"]);
    let mut run = |label: &str, extra: &[(&str, &str)]| {
        let mut ov = base.clone();
        ov.extend(extra.iter().map(|(k, v)| (k.to_string(), v.to_string())));
        let out = engine.sort("shuffle-softsort", &ds, g, &ov).unwrap();
        table.row(&[
            label.to_string(),
            format!("{:.3}", out.report.final_dpq),
            format!("{:.3}", out.report.final_loss),
            out.report.rejected_phases.to_string(),
            format!("{:.1}", out.report.wall_secs),
        ]);
    };

    run("default (random, I=4, accept, flat tau_i)", &[]);

    for s in ["scan", "mixed", "identity"] {
        run(&format!("shuffle={s}"), &[("shuffle", s)]);
    }
    for i in ["2", "8"] {
        run(&format!("I={i}"), &[("inner_iters", i)]);
    }
    run("no greedy accept", &[("greedy_accept", "false")]);
    run("paper inner ramp (0.2)", &[("inner_frac", "0.2")]);
    run("no annealing (tau=0.1)", &[("tau_start", "0.1")]);
    table.print();
    println!(
        "\nexpected shape: identity shuffle (= plain SoftSort policy) clearly worst —\n\
         the paper's core claim; I=2 starves phases; disabling the ramp or annealing hurts."
    );
}
