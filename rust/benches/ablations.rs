//! E8 — ablations over the design choices DESIGN.md §4 calls out:
//! shuffle strategy (Algorithm 1's randperm vs scan vs mixed vs none),
//! inner iteration count I (paper: 4), the inner τ ramp, and the greedy
//! phase-acceptance guard. All on the same color workload and budget.

mod common;

use shufflesort::bench::{banner, Table};
use shufflesort::coordinator::shuffle::ShuffleStrategy;
use shufflesort::coordinator::ShuffleSoftSort;
use shufflesort::data::random_colors;

fn main() {
    let side = 16usize; // ablations need repeats; N=256 keeps each run ~10 s
    let n = side * side;
    banner("E8/ablations", &format!("{n} colors, one factor varied at a time"));
    let rt = common::runtime();
    let ds = random_colors(n, 42);
    let base = common::sss_config(side);

    let mut table = Table::new(&["Variant", "DPQ16", "loss", "rejected", "secs"]);
    let mut run = |label: &str, cfg: shufflesort::config::ShuffleSoftSortConfig| {
        let out = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&ds).unwrap();
        table.row(&[
            label.to_string(),
            format!("{:.3}", out.report.final_dpq),
            format!("{:.3}", out.report.final_loss),
            out.report.rejected_phases.to_string(),
            format!("{:.1}", out.report.wall_secs),
        ]);
    };

    run("default (random, I=4, accept, flat tau_i)", base.clone());

    for s in [ShuffleStrategy::AlternatingScan, ShuffleStrategy::Mixed, ShuffleStrategy::Identity] {
        let mut cfg = base.clone();
        cfg.shuffle = s;
        run(&format!("shuffle={}", s.name()), cfg);
    }
    for i in [2usize, 8] {
        let mut cfg = base.clone();
        cfg.inner_iters = i;
        run(&format!("I={i}"), cfg);
    }
    {
        let mut cfg = base.clone();
        cfg.greedy_accept = false;
        run("no greedy accept", cfg);
    }
    {
        let mut cfg = base.clone();
        cfg.tau.inner_frac = 0.2; // Algorithm 1's 0.2τ→τ inner ramp
        run("paper inner ramp (0.2)", cfg);
    }
    {
        let mut cfg = base.clone();
        cfg.tau.tau_start = 0.1; // no annealing
        run("no annealing (tau=0.1)", cfg);
    }
    table.print();
    println!(
        "\nexpected shape: identity shuffle (= plain SoftSort policy) clearly worst —\n\
         the paper's core claim; I=2 starves phases; disabling the ramp or annealing hurts."
    );
}
