//! E5 — Fig. 5: grid-based image sorting on 50-d "low-level visual
//! feature" vectors (the e-commerce application). Synthetic clustered
//! features substitute the proprietary catalogue (DESIGN.md §3); measured:
//! DPQ16 + cluster spatial coherence for FLAS (production heuristic) vs
//! ShuffleSoftSort — both dispatched through the registry.

mod common;

use shufflesort::api::overrides;
use shufflesort::bench::{banner, Table};
use shufflesort::data::clustered_features;
use shufflesort::grid::GridShape;
use shufflesort::metrics::dpq16;
use shufflesort::perm::Permutation;

fn coherence(perm: &Permutation, labels: &[u32], g: GridShape) -> f64 {
    let pairs = g.neighbor_pairs();
    pairs
        .iter()
        .filter(|&&(a, b)| {
            labels[perm.as_slice()[a as usize] as usize]
                == labels[perm.as_slice()[b as usize] as usize]
        })
        .count() as f64
        / pairs.len() as f64
}

fn main() {
    let side = common::headline_side();
    let n = side * side;
    banner("E5/fig5", &format!("{n} x 50-d clustered features (e-commerce stand-in)"));
    let engine = common::engine();
    let ds = clustered_features(n, 50, 12, 0.06, 7);
    let labels = ds.labels.clone().unwrap();
    let g = GridShape::new(side, side);

    let mut table = Table::new(&["Layout", "DPQ16", "Cluster coherence", "secs"]);
    table.row(&[
        "unsorted".into(),
        format!("{:.3}", dpq16(&ds.rows, ds.d, g)),
        format!("{:.3}", coherence(&Permutation::identity(n), &labels, g)),
        "-".into(),
    ]);

    let flas = engine
        .sort("flas", &ds, g, &overrides(&[("seed", "3")]))
        .unwrap();
    table.row(&[
        "FLAS".into(),
        format!("{:.3}", flas.report.final_dpq),
        format!("{:.3}", coherence(&flas.perm, &labels, g)),
        format!("{:.1}", flas.report.wall_secs),
    ]);

    // 50-d needs the full phase budget even in quick mode (the gradient
    // signal per phase is weaker than on RGB; EXPERIMENTS.md §Tuning).
    let out = engine
        .sort(
            "shuffle-softsort",
            &ds,
            g,
            &overrides(&[("record_curve", "false")]),
        )
        .unwrap();
    table.row(&[
        "ShuffleSoftSort".into(),
        format!("{:.3}", out.report.final_dpq),
        format!("{:.3}", coherence(&out.perm, &labels, g)),
        format!("{:.1}", out.report.wall_secs),
    ]);
    table.print();
    println!(
        "\nexpected shape (Fig. 5): both sorted layouts group same-cluster items\n\
         (coherence ≫ unsorted); browsing-quality layout from N parameters only."
    );
}
