//! E6 — Fig. 6 / §IV-B: Self-Organizing Gaussians. Sorting a 3DGS scene's
//! attributes into 2-D grids raises spatial correlation, which the
//! image-style codec converts into storage savings at identical rendering
//! quality (PSNR is quantization-only; the point order is ambiguous).

mod common;

use shufflesort::api::overrides;
use shufflesort::bench::{banner, quick_mode, Table};
use shufflesort::grid::GridShape;
use shufflesort::sog::codec::CodecConfig;
use shufflesort::sog::scene::{GaussianScene, SceneConfig};
use shufflesort::sog::{pipeline::random_baseline, run_pipeline, SorterKind};

fn main() {
    let n: usize = if quick_mode() { 1024 } else { 4096 };
    let side = (n as f64).sqrt() as usize;
    banner("E6/fig6", &format!("SOG: {n} synthetic splats, {side}x{side} attribute grids"));
    let engine = common::engine();
    let scene = GaussianScene::generate(&SceneConfig { n_splats: n, seed: 7, ..Default::default() });
    let g = GridShape::new(side, side);

    let mut table = Table::new(&["Order", "Compressed", "Ratio", "lag-1 corr", "PSNR dB", "sort s"]);
    let mut rows = Vec::new();
    rows.push(random_baseline(&scene, g, &CodecConfig::default(), 3).unwrap());
    {
        let flas = engine.sorter("flas", &overrides(&[("seed", "11")])).unwrap();
        rows.push(
            run_pipeline(&scene, g, SorterKind::Sorter(flas.as_ref()), &CodecConfig::default())
                .unwrap(),
        );
    }
    {
        let sss = engine
            .sorter("shuffle-softsort", &common::method_overrides("sss", side))
            .unwrap();
        rows.push(
            run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &CodecConfig::default())
                .unwrap(),
        );
    }
    for r in &rows {
        table.row(&[
            r.label.clone(),
            format!("{}B", r.compressed_bytes),
            format!("{:.2}x", r.ratio),
            format!("{:.3}", r.spatial_corr),
            format!("{:.1}", r.mean_psnr_db),
            format!("{:.1}", r.sort_secs),
        ]);
    }
    table.print();

    let shuffled = &rows[0];
    let learned = rows.last().unwrap();
    println!(
        "\nlearned-sorted storage = {:.1}% of shuffled ({:.2}x densification), PSNR unchanged\n\
         (order ambiguity: reshuffling splats renders identically — §IV-B).",
        100.0 * learned.compressed_bytes as f64 / shuffled.compressed_bytes as f64,
        shuffled.compressed_bytes as f64 / learned.compressed_bytes as f64,
    );
    println!(
        "permutation memory at this N: ours {} params vs Gumbel-Sinkhorn {} — the\n\
         paper's enabling-scalability claim.",
        n,
        (n as u64) * (n as u64)
    );
    println!("\nexpected shape (Fig. 6): corr random≈0 < FLAS ≈ learned; ratio gap ≫ 1.");
}
