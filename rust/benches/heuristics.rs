//! E9 — §I-B context: the heuristic grid-layout family (SOM, SSM,
//! LAS/FLAS, DR+LAP) vs the learned methods, same workload and metric.
//! The paper's [2]-line claim: gradient-based layouts reach (and can pass)
//! heuristic quality; ShuffleSoftSort does it with N parameters.
//!
//! Every method — heuristic and learned — dispatches through the `api`
//! registry, so the sweep is simply "every `MethodKind::Heuristic` spec".

mod common;

use shufflesort::api::{overrides, MethodKind};
use shufflesort::bench::{banner, write_table_report, Table};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::dpq16;

fn main() {
    let side = common::headline_side();
    let n = side * side;
    banner("E9/heuristics", &format!("{n} colors: heuristics vs learned"));
    let engine = common::engine();
    let ds = random_colors(n, 42);
    let g = GridShape::new(side, side);

    let mut table = Table::new(&["Method", "Kind", "DPQ16", "secs"]);
    table.row(&["unsorted".into(), "-".into(), format!("{:.3}", dpq16(&ds.rows, 3, g)), "-".into()]);

    for spec in engine.registry().specs().iter().filter(|s| s.kind == MethodKind::Heuristic) {
        let out = engine
            .sort(spec.name, &ds, g, &overrides(&[("seed", "7")]))
            .unwrap();
        table.row(&[
            spec.name.into(),
            "heuristic".into(),
            format!("{:.3}", out.report.final_dpq),
            format!("{:.1}", out.report.wall_secs),
        ]);
    }

    for (key, label) in [("sss", "ShuffleSoftSort"), ("softsort", "SoftSort")] {
        let out = common::run_method(&engine, key, &ds, side);
        table.row(&[
            label.into(),
            "learned (N params)".into(),
            format!("{:.3}", out.report.final_dpq),
            format!("{:.1}", out.report.wall_secs),
        ]);
    }
    table.print();
    const REPORT_PATH: &str = "target/bench_reports/heuristics.json";
    match write_table_report(REPORT_PATH, "heuristics", &table) {
        Ok(()) => println!("\nwrote {REPORT_PATH}"),
        Err(e) => eprintln!("\ncould not write {REPORT_PATH}: {e}"),
    }
    println!(
        "\nexpected shape: LAS/FLAS/SOM strong; SSM/DR+LAP weaker; ShuffleSoftSort in the\n\
         strong band and far above plain SoftSort."
    );
}
