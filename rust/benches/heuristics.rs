//! E9 — §I-B context: the heuristic grid-layout family (SOM, SSM,
//! LAS/FLAS, DR+LAP) vs the learned methods, same workload and metric.
//! The paper's [2]-line claim: gradient-based layouts reach (and can pass)
//! heuristic quality; ShuffleSoftSort does it with N parameters.

mod common;

use shufflesort::bench::{banner, Table};
use shufflesort::data::random_colors;
use shufflesort::dimred::DrLap;
use shufflesort::grid::GridShape;
use shufflesort::heuristics::{flas::Flas, som::Som, ssm::Ssm, GridSorter};
use shufflesort::metrics::dpq16;
use shufflesort::util::timer::Stopwatch;

fn main() {
    let side = common::headline_side();
    let n = side * side;
    banner("E9/heuristics", &format!("{n} colors: heuristics vs learned"));
    let rt = common::runtime();
    let ds = random_colors(n, 42);
    let g = GridShape::new(side, side);

    let mut table = Table::new(&["Method", "Kind", "DPQ16", "secs"]);
    table.row(&["unsorted".into(), "-".into(), format!("{:.3}", dpq16(&ds.rows, 3, g)), "-".into()]);

    let sorters: Vec<Box<dyn GridSorter>> = vec![
        Box::new(Som::default()),
        Box::new(Ssm::default()),
        Box::new(Flas::default()),
        Box::new(Flas::las(24)),
        Box::new(DrLap { use_tsne: false }),
        Box::new(DrLap { use_tsne: true }),
    ];
    for s in sorters {
        let t = Stopwatch::start();
        let p = s.sort(&ds.rows, 3, g, 7);
        let secs = t.secs();
        table.row(&[
            s.name().into(),
            "heuristic".into(),
            format!("{:.3}", dpq16(&p.apply_rows(&ds.rows, 3), 3, g)),
            format!("{secs:.1}"),
        ]);
    }

    for (key, label) in [("sss", "ShuffleSoftSort"), ("softsort", "SoftSort")] {
        let out = common::run_method(&rt, key, &ds, side);
        table.row(&[
            label.into(),
            "learned (N params)".into(),
            format!("{:.3}", out.report.final_dpq),
            format!("{:.1}", out.report.wall_secs),
        ]);
    }
    table.print();
    println!(
        "\nexpected shape: LAS/FLAS/SOM strong; SSM/DR+LAP weaker; ShuffleSoftSort in the\n\
         strong band and far above plain SoftSort."
    );
}
