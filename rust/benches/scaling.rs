//! E7 — the scalability claim (§I, §V): parameters and per-step cost vs N.
//! Gumbel-Sinkhorn's O(N²) memory is the paper's motivating bottleneck;
//! ShuffleSoftSort stays O(N). Per-step wall time is measured on the live
//! artifacts (a few steps each; no full optimization runs).

mod common;

use shufflesort::bench::{banner, bench, quick_mode, Table};
use shufflesort::data::random_colors;
use shufflesort::runtime::Arg;
use shufflesort::util::rng::Pcg32;

fn main() {
    banner("E7/scaling", "params + per-step time vs N (O(N) vs O(N^2))");
    let rt = common::runtime();
    let mut table = Table::new(&[
        "N", "sss params", "gs params", "kiss params", "sss ms/step", "gs ms/step",
    ]);
    let reps = if quick_mode() { 5 } else { 20 };

    for (n, side) in [(64usize, 8usize), (256, 16), (1024, 32), (4096, 64)] {
        let ds = random_colors(n, 1);
        let mut rng = Pcg32::new(2);

        // ShuffleSoftSort step.
        let exe = rt.sss_step(n, 3, side).unwrap();
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let s = bench(&format!("sss n{n}"), 2, reps, || {
            exe.run(&[
                Arg::F32(&w),
                Arg::F32(&ds.rows),
                Arg::I32(&inv),
                Arg::ScalarF32(0.3),
                Arg::ScalarF32(0.5),
            ])
            .unwrap()
        });

        // Gumbel-Sinkhorn step (artifact exists only for N ≤ 1024).
        let gs_ms = if n <= 1024 {
            let gexe = rt.gs_step(n, 3, side).unwrap();
            let logits: Vec<f32> = (0..n * n).map(|_| rng.gaussian() * 0.01).collect();
            let gumbel = vec![0.0f32; n * n];
            let gs = bench(&format!("gs n{n}"), 1, reps.min(5), || {
                gexe.run(&[
                    Arg::F32(&logits),
                    Arg::F32(&ds.rows),
                    Arg::F32(&gumbel),
                    Arg::ScalarF32(0.3),
                    Arg::ScalarF32(0.5),
                ])
                .unwrap()
            });
            format!("{:.2}", gs.mean_s * 1e3)
        } else {
            "OOM-scale (not shipped)".to_string()
        };

        let kiss_params = rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.method == "kiss" && a.n == n)
            .map(|a| a.param_count.to_string())
            .unwrap_or_else(|| "-".into());

        table.row(&[
            n.to_string(),
            n.to_string(),
            if n <= 1024 { (n * n).to_string() } else { format!("{} (4 GiB f32 grads)", n * n) },
            kiss_params,
            format!("{:.2}", s.mean_s * 1e3),
            gs_ms,
        ]);
    }
    table.print();
    println!(
        "\nexpected shape: sss params linear, gs quadratic (1024² = 1048576 matches the\n\
         paper's Table 2 memory entry); gs per-step cost grows ~N² while sss stays near-linear."
    );
}
