//! E7 — the scalability claim (§I, §V): parameters and per-step cost vs N.
//! Gumbel-Sinkhorn's O(N²) memory is the paper's motivating bottleneck;
//! ShuffleSoftSort stays O(N).
//!
//! Runs on a bare checkout: the native backend measures every size through
//! the session hot path (one `StepSession` reused across steps) and, for
//! contrast, the fresh-session-per-step cost — the per-step overhead of
//! the pre-session scoped-thread path. PJRT rows are appended when the
//! AOT artifacts are present. All samples land in the machine-readable
//! report `target/bench_reports/scaling.json` next to `runtime_micro`'s.

mod common;

use shufflesort::backend::{
    GsStep, NativeBackend, SessionOpts, SssStep, StepBackend, StepSession, StepShape,
};
use shufflesort::bench::{banner, bench, quick_mode, write_json_report, Sample, Table};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::util::rng::Pcg32;

const REPORT_PATH: &str = "target/bench_reports/scaling.json";

fn main() {
    banner("E7/scaling", "params + per-step time vs N (O(N) vs O(N^2))");
    let reps = if quick_mode() { 5 } else { 20 };
    // GS is O(N²) memory *and* compute; cap the measured sizes so quick
    // mode stays quick (the table still reports its parameter scaling).
    let gs_max_n = if quick_mode() { 256 } else { 1024 };
    let native = NativeBackend::default();
    let mut samples: Vec<Sample> = Vec::new();
    let mut table = Table::new(&[
        "N",
        "sss params",
        "gs params",
        "kiss params",
        "sss ms/step (session)",
        "sss ms/step (fresh)",
        "gs ms/step (session)",
    ]);

    for (n, side) in [(64usize, 8usize), (256, 16), (1024, 32), (4096, 64)] {
        let ds = random_colors(n, 1);
        let mut rng = Pcg32::new(2);
        let shape = StepShape::new(GridShape::new(side, n / side), 3);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let r = if n >= 4096 { reps.min(3) } else { reps };

        // ShuffleSoftSort step: steady-state session path vs fresh session
        // per step (≈ the legacy scoped-thread per-step overhead).
        let mut session = native.session(shape, SessionOpts::default()).unwrap();
        let mut step = SssStep::new_for(shape);
        let sess = bench(&format!("native sss n{n} (session reuse)"), 1, r, || {
            session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
            step.loss
        });
        println!("{}", sess.line());
        let fresh = bench(&format!("native sss n{n} (fresh session)"), 1, r, || {
            native.sss_step(shape, &w, &ds.rows, &inv, 0.3, 0.5).unwrap().loss
        });
        println!("{}", fresh.line());

        // Gumbel-Sinkhorn step (bounded: O(N²) params and compute).
        let gs_ms = if n <= gs_max_n {
            let logits: Vec<f32> = (0..n * n).map(|_| rng.gaussian() * 0.01).collect();
            let gumbel = vec![0.0f32; n * n];
            let mut gout = GsStep::new_for(n);
            let gs = bench(&format!("native gs n{n} (session reuse)"), 1, r.min(5), || {
                session.gs_step(&logits, &ds.rows, &gumbel, 0.3, 0.5, &mut gout).unwrap();
                gout.loss
            });
            println!("{}", gs.line());
            let ms = format!("{:.2}", gs.mean_s * 1e3);
            samples.push(gs);
            ms
        } else {
            "O(N^2)-scale (skipped)".to_string()
        };

        let kiss_params = native
            .kiss_rank(n, 3)
            .map(|m| (2 * n * m).to_string())
            .unwrap_or_else(|_| "-".into());

        table.row(&[
            n.to_string(),
            n.to_string(),
            if n <= 1024 {
                (n * n).to_string()
            } else {
                format!("{} (4 GiB f32 grads)", n * n)
            },
            kiss_params,
            format!("{:.2}", sess.mean_s * 1e3),
            format!("{:.2}", fresh.mean_s * 1e3),
            gs_ms,
        ]);
        samples.push(sess);
        samples.push(fresh);
    }
    table.print();

    // Tiled-vs-full per-phase cost: a `Tiled { tile_n }` phase runs B
    // independent tile steps of O(tile_n²) work instead of one O(N²) full
    // step, so the phase-equivalent cost is B × the tile step. Full-shape
    // rows stop at 4096 in quick mode (the O(N²) sweep is what tiling
    // exists to avoid); tiled rows run at every size.
    println!();
    let tile_n = 512usize;
    let mut tiled_table = Table::new(&[
        "N",
        "tile_n",
        "tiles",
        "full ms/step",
        "tiled ms/phase-equiv",
    ]);
    for (n, side) in [(4096usize, 64usize), (16384, 128)] {
        let ds = random_colors(n, 3);
        let full_ms = if n <= 4096 || !quick_mode() {
            let shape = StepShape::new(GridShape::new(side, n / side), 3);
            let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
            let inv: Vec<i32> = (0..n as i32).collect();
            let mut session = native.session(shape, SessionOpts::default()).unwrap();
            let mut step = SssStep::new_for(shape);
            let s = bench(&format!("native sss n{n} full (per step)"), 1, reps.min(3), || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            println!("{}", s.line());
            let ms = format!("{:.2}", s.mean_s * 1e3);
            samples.push(s);
            ms
        } else {
            "O(N^2)-scale (skipped)".to_string()
        };

        // One tile: `tile_n` items as a (tile_n/w)×w band of the grid —
        // exactly the sub-problem shape the tiled executor opens.
        let w_grid = n / side;
        let rows = (tile_n / w_grid).max(1);
        let nb = rows * w_grid;
        let tiles = n.div_ceil(nb);
        let tshape = StepShape::new(GridShape::new(rows, w_grid), 3);
        let tw: Vec<f32> = (0..nb).map(|i| (nb - i) as f32).collect();
        let tinv: Vec<i32> = (0..nb as i32).collect();
        let mut tsession = native.session(tshape, SessionOpts::default()).unwrap();
        let mut tstep = SssStep::new_for(tshape);
        let ts = bench(&format!("native sss n{n} tiled{nb} (per tile step)"), 1, reps, || {
            tsession.sss_step(&tw, &ds.rows[..nb * 3], &tinv, 0.3, 0.5, &mut tstep).unwrap();
            tstep.loss
        });
        println!("{}", ts.line());
        tiled_table.row(&[
            n.to_string(),
            nb.to_string(),
            tiles.to_string(),
            full_ms,
            format!("{:.2}", ts.mean_s * 1e3 * tiles as f64),
        ]);
        samples.push(ts);
    }
    tiled_table.print();

    // ---- pyramid vs tiled at N=65536: end-to-end wall time and DPQ -------
    // The block-diagonal `banded` plan never moves an item across a tile
    // seam, so its layout quality saturates no matter how many phases run;
    // the `overlapped` plan alternates seam positions and the pyramid
    // relocates whole tiles on a coarse grid first. Each config lands two
    // rows in scaling.json: the end-to-end wall time, and a "(dpq)" twin
    // whose mean_s field carries the final DPQ16 — the CI quality guard
    // reads those rows and requires the exchange plans to beat banded.
    {
        use shufflesort::api::{BackendChoice, Engine};
        let n = 65536usize;
        let g = GridShape::new(256, 256);
        let ds = random_colors(n, 9);
        let phases = if quick_mode() { 16 } else { 64 };
        let engine = Engine::builder("artifacts").backend(BackendChoice::Native).build();
        let mut pvt_table =
            Table::new(&["config", "tiles", "plan", "wall s", "final DPQ16"]);
        let configs: [(&str, &[(&str, &str)]); 3] = [
            ("banded tile512", &[("tile_n", "512"), ("tile_plan", "banded")]),
            ("overlapped tile512", &[("tile_n", "512"), ("tile_plan", "overlapped")]),
            ("pyramid tile512", &[("tile_n", "512"), ("pyramid", "true")]),
        ];
        for (label, extra) in configs {
            let mut overrides: Vec<(String, String)> = vec![
                ("seed".into(), "9".into()),
                ("phases".into(), phases.to_string()),
                ("record_curve".into(), "false".into()),
            ];
            overrides
                .extend(extra.iter().map(|(k, v)| (k.to_string(), v.to_string())));
            match engine.sort("shuffle-softsort", &ds, g, &overrides) {
                Ok(out) => {
                    let wall = out.report.wall_secs;
                    let dpq = out.report.final_dpq;
                    for (suffix, v) in [("", wall), (" (dpq)", dpq)] {
                        samples.push(Sample {
                            name: format!("e2e sss n{n} {label}{suffix}"),
                            reps: 1,
                            mean_s: v,
                            std_s: 0.0,
                            min_s: v,
                        });
                    }
                    pvt_table.row(&[
                        label.to_string(),
                        out.report.tiles.to_string(),
                        out.report.tile_plan.clone(),
                        format!("{wall:.2}"),
                        format!("{dpq:.4}"),
                    ]);
                }
                Err(e) => println!("e2e sss n{n} {label}: {e:#}"),
            }
        }
        println!();
        pvt_table.print();
    }

    // ---- where a tiled phase's wall time goes (folded self-time) ---------
    // Fold one short traced tiled run into collapsed stacks and print the
    // heaviest paths — the same view `/v1/profile` serves, here as a quick
    // check that tile compute (not dispatch) dominates the phase.
    {
        use shufflesort::trace;
        let engine = shufflesort::api::Engine::builder("artifacts")
            .backend(shufflesort::api::BackendChoice::Native)
            .build();
        let ds = random_colors(1024, 5);
        let g = GridShape::new(32, 32);
        let overrides: Vec<(String, String)> = [
            ("seed", "5"),
            ("phases", "4"),
            ("tile_n", "256"),
            ("record_curve", "false"),
        ]
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
        trace::set_enabled(true);
        let root = trace::Span::root("sort");
        let trace_id = root.ctx().map(|c| c.trace_id).unwrap_or(0);
        let outcome = {
            let _cur = root.make_current();
            engine.sort("shuffle-softsort", &ds, g, &overrides)
        };
        root.end();
        let finished = trace::finish(trace_id);
        trace::set_enabled(false);
        if let (Ok(_), Some(t)) = (outcome, finished) {
            let p = trace::profile::Profile::new();
            p.observe(&t);
            println!("\nfolded self-time, tiled sss n=1024 tile_n=256 (top 5 paths):");
            for (path, stat) in p.snapshot().into_iter().take(5) {
                println!(
                    "  {path} self={}us total={}us x{}",
                    stat.self_us, stat.total_us, stat.count
                );
            }
        }
    }

    // PJRT comparison rows when the AOT artifacts are around.
    #[cfg(feature = "pjrt")]
    if let Some(backend) = common::try_pjrt() {
        for (n, side) in [(64usize, 8usize), (256, 16), (1024, 32), (4096, 64)] {
            let ds = random_colors(n, 1);
            let shape = StepShape::new(GridShape::new(side, n / side), 3);
            let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
            let inv: Vec<i32> = (0..n as i32).collect();
            let mut session = match backend.session(shape, SessionOpts::default()) {
                Ok(s) => s,
                Err(e) => {
                    println!("pjrt n{n}: {e:#}");
                    continue;
                }
            };
            let mut step = SssStep::new_for(shape);
            if session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).is_err() {
                println!("pjrt n{n}: no sss artifact, skipped");
                continue;
            }
            let s = bench(&format!("pjrt sss n{n} (session reuse)"), 1, reps, || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            println!("{}", s.line());
            samples.push(s);
        }
    }

    match write_json_report(REPORT_PATH, "scaling", &samples) {
        Ok(()) => println!("\nwrote {REPORT_PATH}"),
        Err(e) => eprintln!("\ncould not write {REPORT_PATH}: {e}"),
    }
    println!(
        "\nexpected shape: sss params linear, gs quadratic (1024² = 1048576 matches the\n\
         paper's Table 2 memory entry); gs per-step cost grows ~N² while sss stays\n\
         near-linear, and session reuse beats fresh-session-per-step at every N."
    );
}
