//! E10 — infrastructure micro-benchmarks: where does a coordinator step's
//! time go? Native-vs-PJRT per-step cost on the same (n, d, h) grid,
//! compile cost (once), execute dispatch, JV extraction, DPQ evaluation.
//! Feeds EXPERIMENTS.md §Perf; the per-step numbers are also written to a
//! machine-readable JSON report (`target/bench_reports/runtime_micro.json`).
//!
//! Runs without artifacts: the PJRT cases skip themselves (with a note)
//! when `artifacts/manifest.json` is absent, so the native numbers are
//! always measurable on a bare checkout.

mod common;

use shufflesort::assignment::jv;
use shufflesort::backend::{
    simd, NativeBackend, SessionOpts, SimdChoice, SssStep, StepBackend, StepSession, StepShape,
};
use shufflesort::bench::{banner, bench, quick_mode, write_json_report, Sample};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::dpq16;
use shufflesort::util::rng::Pcg32;

const REPORT_PATH: &str = "target/bench_reports/runtime_micro.json";

fn main() {
    banner("E10/runtime-micro", "backend + substrate hot-path costs");
    let reps = if quick_mode() { 10 } else { 50 };
    let mut samples: Vec<Sample> = Vec::new();

    // ---- native vs pjrt: one full sss step on the same (n, d, h) grid ----
    // Two native rows per size: "session reuse" is the driver hot path
    // (one session per run: warm scratch + persistent pool, zero per-step
    // allocations); "fresh session" pays buffer allocation and pool spawn
    // on every step — the per-step overhead of the pre-session
    // scoped-thread code path.
    let native = NativeBackend::default();
    #[cfg(feature = "pjrt")]
    let pjrt = common::try_pjrt();

    for (n, d, h) in [(64usize, 3usize, 8usize), (256, 3, 16), (1024, 3, 32)] {
        let ds = random_colors(n, 1);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let shape = StepShape::new(GridShape::new(h, n / h), d);

        let mut session = native.session(shape, SessionOpts::default()).unwrap();
        let mut step = SssStep::new_for(shape);
        let reuse = bench(
            &format!("native sss_step n={n} d={d} h={h} (session reuse)"),
            2,
            reps,
            || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            },
        );
        println!("{}", reuse.line());

        let fresh = bench(
            &format!("native sss_step n={n} d={d} h={h} (fresh session)"),
            2,
            reps,
            || native.sss_step(shape, &w, &ds.rows, &inv, 0.3, 0.5).unwrap().loss,
        );
        println!("{}", fresh.line());
        println!(
            "    session speedup at n={n}: {:.2}x (fresh {:.3} ms vs reuse {:.3} ms per step)",
            fresh.mean_s / reuse.mean_s.max(1e-12),
            fresh.mean_s * 1e3,
            reuse.mean_s * 1e3
        );
        samples.push(reuse);
        samples.push(fresh);

        #[cfg(feature = "pjrt")]
        if let Some(backend) = pjrt.as_ref() {
            let s = bench(&format!("pjrt sss_step n={n} d={d} h={h}"), 2, reps, || {
                backend.sss_step(shape, &w, &ds.rows, &inv, 0.3, 0.5).unwrap()
            });
            println!("{}", s.line());
            samples.push(s);
        }
    }

    // ---- scalar vs SIMD step kernels (session reuse, d=3 and d=64) -------
    // Row pairs differing only in the session's `simd` knob: `auto` is the
    // best instruction set detected at runtime, `off` the scalar oracle.
    // The pair delta is the ISSUE-8 tentpole win, tracked per commit in
    // BENCH_runtime.json (CI's regression guard keys on the d=3 auto row).
    {
        println!("    simd detected: {}", simd::detected().name());
        let n = 1024usize;
        let h = 32usize;
        for d in [3usize, 64] {
            let mut rng = Pcg32::new(7 + d as u64);
            let x: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
            let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
            let inv: Vec<i32> = (0..n as i32).collect();
            let shape = StepShape::new(GridShape::new(h, n / h), d);
            let mut pair = Vec::with_capacity(2);
            for choice in [SimdChoice::Off, SimdChoice::Auto] {
                let opts = SessionOpts { threads: None, simd: choice };
                let mut session = native.session(shape, opts).unwrap();
                let mut step = SssStep::new_for(shape);
                let s = bench(
                    &format!("native sss_step n={n} d={d} h={h} simd={choice} (session reuse)"),
                    2,
                    reps,
                    || {
                        session.sss_step(&w, &x, &inv, 0.3, 0.5, &mut step).unwrap();
                        step.loss
                    },
                );
                println!("{}", s.line());
                pair.push(s);
            }
            println!(
                "    simd speedup at n={n} d={d}: {:.2}x (off {:.3} ms vs auto {:.3} ms per step)",
                pair[0].mean_s / pair[1].mean_s.max(1e-12),
                pair[0].mean_s * 1e3,
                pair[1].mean_s * 1e3
            );
            samples.extend(pair);
        }
    }

    // ---- Engine (n, d, h) session memoization (native, artifact-free) ----
    {
        let engine = shufflesort::api::Engine::builder("artifacts")
            .backend(shufflesort::api::BackendChoice::Native)
            .build();
        let n = 1024usize;
        let ds = random_colors(n, 1);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let shape = StepShape::new(GridShape::new(32, 32), 3);
        let mut sess = engine.step_session(n, 3, 32).unwrap();
        let mut step = SssStep::new_for(shape);
        let s = bench("engine.step_session sss n=1024 (memoized)", 2, reps, || {
            sess.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
            step.loss
        });
        println!("{}", s.line());
        samples.push(s);
    }

    // ---- PJRT infrastructure costs (artifact compile, caches) -----------
    #[cfg(feature = "pjrt")]
    if pjrt.is_some() {
        use shufflesort::runtime::{Arg, Runtime};

        // Artifact compile cost (fresh runtime → first load pays
        // compilation).
        let s = bench("compile sss_step_n1024 (cold cache)", 0, 3, || {
            let rt2 = Runtime::from_manifest("artifacts").unwrap();
            rt2.sss_step(1024, 3, 32).unwrap()
        });
        println!("{}", s.line());
        samples.push(s);

        let rt = common::runtime();
        let n = 1024usize;
        let ds = random_colors(n, 1);
        let exe = rt.sss_step(n, 3, 32).unwrap();
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();

        let s = bench("load sss_step_n1024 (warm cache)", 1, reps, || {
            rt.sss_step(1024, 3, 32).unwrap()
        });
        println!("{}", s.line());
        samples.push(s);

        // Engine front cache: (n, d, h)-keyed, skips the name formatting +
        // string hashing of the runtime's own cache.
        let engine = common::engine();
        engine.sss_step(1024, 3, 32).unwrap();
        let s = bench("engine.sss_step (memoized (n,d,h))", 1, reps, || {
            engine.sss_step(1024, 3, 32).unwrap()
        });
        println!("{}", s.line());
        samples.push(s);

        let s = bench("execute sss_step n=1024 (raw artifact)", 2, reps, || {
            exe.run(&[
                Arg::F32(&w),
                Arg::F32(&ds.rows),
                Arg::I32(&inv),
                Arg::ScalarF32(0.3),
                Arg::ScalarF32(0.5),
            ])
            .unwrap()
        });
        println!("{}", s.line());
        samples.push(s);
    }

    // ---- tracing overhead: disabled vs enabled around the step kernel ----
    // The PR-3 invariant says a disabled tracing spine costs one relaxed
    // atomic load per gate; the enabled cost (two clock reads + a ring
    // write per span) must stay small against a real step. Measured here
    // on the n=1024 session-reuse hot path, same shape as above. The
    // bench owns this process, so toggling the global flag is safe.
    {
        let n = 1024usize;
        let ds = random_colors(n, 1);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let shape = StepShape::new(GridShape::new(32, n / 32), 3);
        let mut session = native.session(shape, SessionOpts::default()).unwrap();
        let mut step = SssStep::new_for(shape);

        shufflesort::trace::set_enabled(false);
        let off = bench("sss_step n=1024 tracing disabled", 2, reps, || {
            let mut clock = shufflesort::trace::StepClock::start(shufflesort::trace::current());
            let loss = clock.time(shufflesort::trace::FAM_SSS, || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            clock.emit();
            loss
        });
        println!("{}", off.line());

        shufflesort::trace::set_enabled(true);
        let root = shufflesort::trace::Span::root("bench");
        let _cur = root.make_current();
        let on = bench("sss_step n=1024 tracing enabled", 2, reps, || {
            let mut clock = shufflesort::trace::StepClock::start(shufflesort::trace::current());
            let loss = clock.time(shufflesort::trace::FAM_SSS, || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            clock.emit();
            loss
        });
        drop(_cur);
        root.end();
        shufflesort::trace::set_enabled(false);
        println!("{}", on.line());
        println!(
            "    tracing overhead at n=1024: {:+.2}% (enabled {:.3} ms vs disabled {:.3} ms per step)",
            100.0 * (on.mean_s / off.mean_s.max(1e-12) - 1.0),
            on.mean_s * 1e3,
            off.mean_s * 1e3
        );
        samples.push(off);
        samples.push(on);
    }

    // ---- trace-derived per-kernel time (StepClock units) -----------------
    // The serve plane reports kernel time as the step_family_seconds_total
    // counter: StepClock totals folded out of finished traces. Deriving a
    // bench row from the same spans puts the SIMD win in the units
    // `/metrics` reports, not just wall-clock around the call.
    {
        let n = 1024usize;
        let ds = random_colors(n, 1);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let shape = StepShape::new(GridShape::new(32, n / 32), 3);
        let mut session = native.session(shape, SessionOpts::default()).unwrap();
        let mut step = SssStep::new_for(shape);

        shufflesort::trace::set_enabled(true);
        let root = shufflesort::trace::Span::root("bench");
        let trace_id = root.ctx().map(|c| c.trace_id).unwrap_or(0);
        {
            let _cur = root.make_current();
            let mut clock = shufflesort::trace::StepClock::start(shufflesort::trace::current());
            for _ in 0..reps {
                clock.time(shufflesort::trace::FAM_SSS, || {
                    session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                });
            }
            clock.emit();
        }
        root.end();
        let finished = shufflesort::trace::finish(trace_id);
        shufflesort::trace::set_enabled(false);
        if let Some(t) = finished {
            let fam = shufflesort::trace::FAMILY_NAMES[shufflesort::trace::FAM_SSS];
            if let Some(span) = t.spans.iter().find(|s| s.name == fam) {
                let steps = span
                    .attrs
                    .iter()
                    .flatten()
                    .find_map(|(k, v)| match v {
                        shufflesort::trace::AttrValue::U64(c) if *k == "steps" => Some(*c),
                        _ => None,
                    })
                    .unwrap_or(1)
                    .max(1);
                let total_s = span.dur_us as f64 / 1e6;
                let s = Sample {
                    name: format!("step_family_seconds_total {fam} n={n} d=3 (per step)"),
                    reps: steps as usize,
                    mean_s: total_s / steps as f64,
                    std_s: 0.0,
                    min_s: total_s / steps as f64,
                };
                println!("{}", s.line());
                samples.push(s);
            }
        }
    }

    // ---- request sampling overhead: off vs 1-in-8 ------------------------
    // The serve plane's head sampling (`--trace-sample K`) makes one
    // counter-based decision per request; untraced requests must keep the
    // inert-span path. This pair mirrors that decision around the same
    // step kernel: "off" is trace_sample=0 (gate load + inert spans),
    // "1-in-8" opens a real root on every 8th iteration and folds the
    // finished trace away, amortizing the full sampled-request cost.
    {
        let n = 1024usize;
        let ds = random_colors(n, 1);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let shape = StepShape::new(GridShape::new(32, n / 32), 3);
        let mut session = native.session(shape, SessionOpts::default()).unwrap();
        let mut step = SssStep::new_for(shape);

        shufflesort::trace::set_enabled(false);
        let off = bench("sss_step n=1024 request sampling off", 2, reps, || {
            let root = shufflesort::trace::Span::off();
            let _cur = root.make_current();
            let mut clock = shufflesort::trace::StepClock::start(shufflesort::trace::current());
            let loss = clock.time(shufflesort::trace::FAM_SSS, || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            clock.emit();
            drop(_cur);
            root.end();
            loss
        });
        println!("{}", off.line());

        shufflesort::trace::set_enabled(true);
        let mut req = 0u64;
        let sampled = bench("sss_step n=1024 request sampled 1-in-8", 2, reps, || {
            let traced = req % 8 == 0;
            req += 1;
            let root = if traced {
                shufflesort::trace::Span::root("request")
            } else {
                shufflesort::trace::Span::off()
            };
            let _cur = root.make_current();
            let mut clock = shufflesort::trace::StepClock::start(shufflesort::trace::current());
            let loss = clock.time(shufflesort::trace::FAM_SSS, || {
                session.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut step).unwrap();
                step.loss
            });
            clock.emit();
            drop(_cur);
            let id = root.ctx().map(|c| c.trace_id);
            root.end();
            if let Some(id) = id {
                let _ = shufflesort::trace::finish(id);
            }
            loss
        });
        shufflesort::trace::set_enabled(false);
        println!("{}", sampled.line());
        println!(
            "    sampling overhead at n=1024: {:+.2}% (1-in-8 {:.3} ms vs off {:.3} ms per step)",
            100.0 * (sampled.mean_s / off.mean_s.max(1e-12) - 1.0),
            sampled.mean_s * 1e3,
            off.mean_s * 1e3
        );
        samples.push(off);
        samples.push(sampled);
    }

    // ---- flamegraph artifact: fold one traced tiled sort -----------------
    // The CI bench job publishes this next to sample_trace.json: a small
    // traced shuffle-softsort run collapsed into Brendan Gregg folded
    // stacks, paste-ready for flamegraph.pl / speedscope.
    {
        use shufflesort::trace;
        let engine = shufflesort::api::Engine::builder("artifacts")
            .backend(shufflesort::api::BackendChoice::Native)
            .build();
        let ds = random_colors(256, 9);
        let g = GridShape::new(16, 16);
        let overrides: Vec<(String, String)> = [
            ("seed", "9"),
            ("phases", "8"),
            ("tile_n", "64"),
            ("record_curve", "false"),
        ]
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
        trace::set_enabled(true);
        let mut root = trace::Span::root("sort");
        let trace_id = root.ctx().map(|c| c.trace_id).unwrap_or(0);
        let outcome = {
            let _cur = root.make_current();
            engine.sort("shuffle-softsort", &ds, g, &overrides)
        };
        if let Ok(out) = &outcome {
            out.report.trace_attrs(&mut root);
        }
        root.end();
        let finished = trace::finish(trace_id);
        trace::set_enabled(false);
        match (outcome, finished) {
            (Ok(_), Some(t)) => {
                let p = trace::profile::Profile::new();
                p.observe(&t);
                let path = "target/bench_reports/profile.folded";
                let _ = std::fs::create_dir_all("target/bench_reports");
                match std::fs::write(path, p.folded()) {
                    Ok(()) => println!("wrote {path} ({} stacks)", p.len()),
                    Err(e) => eprintln!("could not write {path}: {e}"),
                }
            }
            _ => eprintln!("flamegraph artifact skipped (sort failed or trace empty)"),
        }
    }

    // ---- pure-Rust substrate costs on the same scale ---------------------
    let mut rng = Pcg32::new(3);
    let cost: Vec<f64> = (0..256 * 256).map(|_| rng.f64()).collect();
    let s = bench("JV solve 256x256", 1, reps, || jv::solve(&cost, 256));
    println!("{}", s.line());
    samples.push(s);

    let ds = random_colors(1024, 1);
    let g = GridShape::new(32, 32);
    let s = bench("DPQ16 n=1024", 1, reps.min(10), || dpq16(&ds.rows, 3, g));
    println!("{}", s.line());
    samples.push(s);

    let mut rng2 = Pcg32::new(4);
    let s = bench("rng permutation n=4096", 1, reps, || rng2.permutation(4096));
    println!("{}", s.line());
    samples.push(s);

    match write_json_report(REPORT_PATH, "runtime_micro", &samples) {
        Ok(()) => println!("\nwrote {REPORT_PATH}"),
        Err(e) => eprintln!("\ncould not write {REPORT_PATH}: {e}"),
    }
    println!("use: the per-step cost sets the coordinator step floor; everything else must stay ≪ it.");
}
