//! E10 — infrastructure micro-benchmarks: where does a coordinator step's
//! time go? Compile cost (once), host→device literal creation, execute
//! dispatch, JV extraction, DPQ evaluation. Feeds EXPERIMENTS.md §Perf.

mod common;

use shufflesort::bench::{banner, bench, quick_mode};
use shufflesort::assignment::jv;
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::dpq16;
use shufflesort::runtime::{Arg, Runtime};
use shufflesort::util::rng::Pcg32;

fn main() {
    banner("E10/runtime-micro", "PJRT + substrate hot-path costs");
    let reps = if quick_mode() { 10 } else { 50 };

    // Artifact compile cost (fresh runtime → first load pays compilation).
    let s = bench("compile sss_step_n1024 (cold cache)", 0, 3, || {
        let rt2 = Runtime::from_manifest("artifacts").unwrap();
        rt2.sss_step(1024, 3, 32).unwrap()
    });
    println!("{}", s.line());

    let rt = common::runtime();
    let n = 1024usize;
    let ds = random_colors(n, 1);
    let exe = rt.sss_step(n, 3, 32).unwrap();
    let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
    let inv: Vec<i32> = (0..n as i32).collect();

    let s = bench("load sss_step_n1024 (warm cache)", 1, reps, || {
        rt.sss_step(1024, 3, 32).unwrap()
    });
    println!("{}", s.line());

    // Engine front cache: (n, d, h)-keyed, skips the name formatting +
    // string hashing of the runtime's own cache.
    let engine = common::engine();
    engine.sss_step(1024, 3, 32).unwrap();
    let s = bench("engine.sss_step (memoized (n,d,h))", 1, reps, || {
        engine.sss_step(1024, 3, 32).unwrap()
    });
    println!("{}", s.line());

    let s = bench("execute sss_step n=1024 (full step)", 2, reps, || {
        exe.run(&[
            Arg::F32(&w),
            Arg::F32(&ds.rows),
            Arg::I32(&inv),
            Arg::ScalarF32(0.3),
            Arg::ScalarF32(0.5),
        ])
        .unwrap()
    });
    println!("{}", s.line());

    // Pure-Rust substrate costs on the same scale.
    let mut rng = Pcg32::new(3);
    let cost: Vec<f64> = (0..256 * 256).map(|_| rng.f64()).collect();
    let s = bench("JV solve 256x256", 1, reps, || jv::solve(&cost, 256));
    println!("{}", s.line());

    let g = GridShape::new(32, 32);
    let s = bench("DPQ16 n=1024", 1, reps.min(10), || dpq16(&ds.rows, 3, g));
    println!("{}", s.line());

    let mut rng2 = Pcg32::new(4);
    let s = bench("rng permutation n=4096", 1, reps, || rng2.permutation(4096));
    println!("{}", s.line());

    println!("\nuse: execute cost sets the coordinator step floor; everything else must stay ≪ it.");
}
