//! E3 — Fig. 1: 1024 random RGB colors sorted by SoftSort (left) vs
//! ShuffleSoftSort (right). Regenerates the two grid images as PPM files
//! and reports the quantitative gap the figure illustrates.

mod common;

use shufflesort::bench::banner;
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::mean_neighbor_distance;
use shufflesort::util::ppm;

fn main() {
    let side = common::headline_side();
    let n = side * side;
    banner("E3/fig1", &format!("{n} RGB colors: SoftSort vs ShuffleSoftSort grids"));
    let engine = common::engine();
    let ds = random_colors(n, 42);
    let g = GridShape::new(side, side);
    std::fs::create_dir_all("out").unwrap();

    ppm::write_ppm_upscaled(
        std::path::Path::new("out/fig1_unsorted.ppm"),
        &ds.rows,
        side,
        side,
        8,
    )
    .unwrap();

    for (key, label, file) in [
        ("softsort", "SoftSort", "out/fig1_softsort.ppm"),
        ("sss", "ShuffleSoftSort", "out/fig1_shufflesoftsort.ppm"),
    ] {
        let out = common::run_method(&engine, key, &ds, side);
        ppm::write_ppm_upscaled(std::path::Path::new(file), &out.arranged, side, side, 8)
            .unwrap();
        println!(
            "{label:<16} dpq16={:.3} nbr={:.4} -> {file}",
            out.report.final_dpq,
            mean_neighbor_distance(&out.arranged, 3, g)
        );
    }
    println!(
        "\nexpected shape (Fig. 1): ShuffleSoftSort image shows coherent color patches;\n\
         SoftSort only a rough 1-D-ish gradient; dpq gap ≳ 0.2."
    );
}
