//! Shared helpers for the paper-reproduction benches. All method dispatch
//! flows through the `api` registry/Engine — no bench constructs a driver
//! directly.
#![allow(dead_code)] // each bench target uses a subset of these helpers

use shufflesort::api::Engine;
use shufflesort::coordinator::SortOutcome;
use shufflesort::data::Dataset;
use shufflesort::grid::GridShape;
#[cfg(feature = "pjrt")]
use shufflesort::runtime::Runtime;

/// Headline grid: 16×16 in quick mode, the paper's 32×32 with `--full`.
pub fn headline_side() -> usize {
    if shufflesort::bench::quick_mode() {
        16
    } else {
        32
    }
}

/// The session every bench dispatches through (eager artifact load: the
/// learned methods are the point of these benches).
#[cfg(feature = "pjrt")]
pub fn engine() -> Engine {
    Engine::from_artifacts("artifacts").expect("run `make artifacts` first")
}

/// Raw runtime for the micro-benches that measure PJRT itself.
#[cfg(feature = "pjrt")]
pub fn runtime() -> Runtime {
    Runtime::from_manifest("artifacts").expect("run `make artifacts` first")
}

/// PJRT backend if the artifacts are present, else `None` (benches print a
/// note and measure the native backend only).
#[cfg(feature = "pjrt")]
pub fn try_pjrt() -> Option<shufflesort::backend::PjrtBackend> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("note: artifacts missing — PJRT cases skipped (run `make artifacts`)");
        return None;
    }
    Some(
        shufflesort::backend::PjrtBackend::from_artifacts("artifacts")
            .expect("artifacts present but failed to load"),
    )
}

fn kv(k: &str, v: impl ToString) -> (String, String) {
    (k.to_string(), v.to_string())
}

/// ShuffleSoftSort phase budget at the bench scale (quick mode shrinks the
/// grid-scaled default 4x, floored at 512).
pub fn sss_phases(side: usize) -> usize {
    let phases = shufflesort::config::ShuffleSoftSortConfig::for_grid(side, side).phases;
    if shufflesort::bench::quick_mode() {
        (phases / 4).max(512)
    } else {
        phases
    }
}

/// Registry overrides giving each method a comparable optimization effort
/// at the bench's scale (quick mode shrinks budgets 4x / caps steps).
pub fn method_overrides(method: &str, side: usize) -> Vec<(String, String)> {
    match method {
        "sss" | "shuffle-softsort" | "shufflesoftsort" => {
            vec![kv("phases", sss_phases(side)), kv("record_curve", false)]
        }
        // Step budget matched to ShuffleSoftSort's phases × inner_iters.
        "softsort" => {
            let inner =
                shufflesort::config::ShuffleSoftSortConfig::for_grid(side, side).inner_iters;
            vec![kv("steps", sss_phases(side) * inner)]
        }
        "gs" | "gumbel-sinkhorn" | "kiss" | "kissing" => {
            let steps = if shufflesort::bench::quick_mode() { 1024 } else { 3072 };
            vec![kv("steps", steps)]
        }
        _ => Vec::new(),
    }
}

/// Run a method by registry name with the bench-scale budgets.
pub fn run_method(engine: &Engine, name: &str, ds: &Dataset, side: usize) -> SortOutcome {
    engine
        .sort(name, ds, GridShape::new(side, side), &method_overrides(name, side))
        .unwrap_or_else(|e| panic!("method {name} failed: {e:#}"))
}
