//! Shared helpers for the paper-reproduction benches.

use shufflesort::config::{BaselineConfig, ShuffleSoftSortConfig};
use shufflesort::coordinator::baselines::{
    GumbelSinkhornDriver, KissingDriver, SoftSortDriver,
};
use shufflesort::coordinator::{ShuffleSoftSort, SortOutcome};
use shufflesort::data::Dataset;
use shufflesort::runtime::Runtime;

/// Headline grid: 16×16 in quick mode, the paper's 32×32 with `--full`.
pub fn headline_side() -> usize {
    if shufflesort::bench::quick_mode() {
        16
    } else {
        32
    }
}

pub fn runtime() -> Runtime {
    Runtime::from_manifest("artifacts").expect("run `make artifacts` first")
}

/// Budgets chosen so each method gets a comparable optimization effort at
/// the bench's scale (quick mode shrinks them 4x).
pub fn sss_config(side: usize) -> ShuffleSoftSortConfig {
    let mut cfg = ShuffleSoftSortConfig::for_grid(side, side);
    if shufflesort::bench::quick_mode() {
        cfg.phases = (cfg.phases / 4).max(512);
    }
    cfg.record_curve = false;
    cfg
}

pub fn softsort_config(side: usize) -> BaselineConfig {
    let mut cfg = BaselineConfig::for_grid(side, side);
    cfg.steps = sss_config(side).phases * sss_config(side).inner_iters;
    cfg
}

pub fn gs_config(side: usize) -> BaselineConfig {
    let mut cfg = BaselineConfig::for_gs(side, side);
    cfg.steps = if shufflesort::bench::quick_mode() { 1024 } else { 3072 };
    cfg
}

pub fn kiss_config(side: usize) -> BaselineConfig {
    let mut cfg = BaselineConfig::for_grid(side, side);
    cfg.steps = if shufflesort::bench::quick_mode() { 1024 } else { 3072 };
    cfg
}

pub fn run_method(rt: &Runtime, name: &str, ds: &Dataset, side: usize) -> SortOutcome {
    match name {
        "sss" => ShuffleSoftSort::new(rt, sss_config(side)).unwrap().sort(ds).unwrap(),
        "softsort" => SoftSortDriver::new(rt, softsort_config(side)).sort(ds).unwrap(),
        "gs" => GumbelSinkhornDriver::new(rt, gs_config(side)).sort(ds).unwrap(),
        "kiss" => KissingDriver::new(rt, kiss_config(side)).sort(ds).unwrap(),
        _ => panic!("unknown method {name}"),
    }
}
