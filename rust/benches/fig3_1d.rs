//! E4 — Fig. 3: the 1-D toy that motivates shuffling. The start arrangement
//! has two hues swapped relative to the smooth circular order; fixing it
//! requires moving elements *through* dissimilar intermediates, so plain
//! SoftSort's gradient path is blocked (quality would first degrade), while
//! ShuffleSoftSort's re-shuffled paths escape.

mod common;

use shufflesort::api::overrides;
use shufflesort::bench::banner;
use shufflesort::data::fig3_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::mean_neighbor_distance;

fn main() {
    banner("E4/fig3", "1-D chain with a blocked swap: SoftSort stuck, ShuffleSoftSort not");
    let engine = common::engine();
    let ds = fig3_colors(); // N=16, engineered local optimum
    let g = GridShape::new(1, 16);
    let start = mean_neighbor_distance(&ds.rows, 3, g);
    println!("start arrangement: nbr={start:.4}");

    // Plain SoftSort, generous budget.
    let ss = engine
        .sort("softsort", &ds, g, &overrides(&[("steps", "4096")]))
        .unwrap();
    let ss_nbr = mean_neighbor_distance(&ss.arranged, 3, g);

    // ShuffleSoftSort, same step budget.
    let sss = engine
        .sort(
            "shuffle-softsort",
            &ds,
            g,
            &overrides(&[("phases", "1024"), ("inner_iters", "4")]),
        )
        .unwrap();
    let sss_nbr = mean_neighbor_distance(&sss.arranged, 3, g);

    // Brute reference: best circular order = sorted hues.
    println!("SoftSort        final nbr={ss_nbr:.4}  (improvement {:.1}%)", 100.0 * (1.0 - ss_nbr / start));
    println!("ShuffleSoftSort final nbr={sss_nbr:.4}  (improvement {:.1}%)", 100.0 * (1.0 - sss_nbr / start));
    println!(
        "\nexpected shape (Fig. 3): SoftSort cannot realize the distant swap, its final\n\
         neighbor distance stays near the start; ShuffleSoftSort lands well below it."
    );
    assert!(sss_nbr <= ss_nbr + 1e-9, "ShuffleSoftSort must not lose to SoftSort here");
}
