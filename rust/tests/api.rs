//! Integration tests for the unified `api` layer: registry coverage,
//! override semantics, backend selection, and `Engine::sort_batch`
//! determinism.
//!
//! Heuristic methods and the native backend are pure Rust and run
//! unconditionally — including learned-method end-to-end coverage, which
//! no longer silently skips without artifacts. PJRT-specific tests need
//! the AOT artifacts (`make artifacts`) and skip gracefully when the
//! manifest is absent.

use shufflesort::api::{overrides, BackendChoice, Engine, MethodKind, MethodRegistry};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::perm::Permutation;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

/// Permutation validity beyond the type invariant: explicit duplicate scan
/// over the raw indices (what the satellite task asks to verify).
fn assert_valid_perm(perm: &Permutation, n: usize, who: &str) {
    assert_eq!(perm.len(), n, "{who}: wrong length");
    assert_eq!(
        Permutation::count_duplicates(perm.as_slice()),
        0,
        "{who}: duplicate grid targets"
    );
}

#[test]
fn every_heuristic_method_sorts_a_tiny_4x4_dataset() {
    let engine = Engine::builder(ARTIFACTS).build();
    let g = GridShape::new(4, 4);
    let ds = random_colors(16, 3);
    let mut tested = 0;
    for spec in engine.registry().specs().iter().filter(|s| s.kind == MethodKind::Heuristic) {
        let out = engine.sort(spec.name, &ds, g, &[]).unwrap();
        assert_valid_perm(&out.perm, 16, spec.name);
        assert!(out.report.final_dpq.is_finite(), "{}: dpq", spec.name);
        assert_eq!(out.report.method, spec.name);
        assert!(out.report.sections.count("sort") > 0, "{}: timing", spec.name);
        tested += 1;
    }
    assert!(tested >= 3, "expected at least FLAS/SOM/SSM, got {tested}");
}

#[test]
fn every_learned_method_sorts_a_small_dataset_on_the_native_backend() {
    // No artifacts required: an engine pointed at a nonexistent directory
    // with backend=auto falls back to native and still runs every learned
    // method end-to-end.
    let engine = Engine::builder("/definitely/not/artifacts").build();
    let g = GridShape::new(4, 4);
    let ds = random_colors(16, 3);
    let budget: &[(&str, &[(&str, &str)])] = &[
        ("shuffle-softsort", &[("phases", "64"), ("record_curve", "false")]),
        ("softsort", &[("steps", "64")]),
        ("gumbel-sinkhorn", &[("steps", "64")]),
        ("kissing", &[("steps", "64")]),
    ];
    for &(name, ov) in budget {
        let out = engine.sort(name, &ds, g, &overrides(ov)).unwrap();
        assert_valid_perm(&out.perm, 16, name);
        assert!(out.report.final_dpq.is_finite(), "{name}: dpq");
        assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged, "{name}: arranged");
    }
}

#[test]
fn backend_override_pair_and_builder_choice_select_the_backend() {
    // Explicit native choice on a bogus artifacts dir: must work.
    let engine = Engine::builder("/definitely/not/artifacts")
        .backend(BackendChoice::Native)
        .build();
    assert_eq!(engine.backend_choice(), BackendChoice::Native);
    let desc = engine.backend_desc(&[]).unwrap();
    assert!(desc.contains("native"), "{desc}");
    let ds = random_colors(16, 4);
    let out = engine
        .sort("softsort", &ds, GridShape::new(4, 4), &overrides(&[("steps", "32")]))
        .unwrap();
    assert_valid_perm(&out.perm, 16, "softsort/native");

    // The `backend=...` override pair wins over the session default and is
    // peeled before config validation (it is not a config key).
    let auto_engine = Engine::builder("/definitely/not/artifacts").build();
    let out = auto_engine
        .sort(
            "softsort",
            &ds,
            GridShape::new(4, 4),
            &overrides(&[("backend", "native"), ("steps", "32")]),
        )
        .unwrap();
    assert_valid_perm(&out.perm, 16, "softsort/backend=native");

    // Bad backend names error helpfully.
    let err = auto_engine
        .sort("softsort", &ds, GridShape::new(4, 4), &overrides(&[("backend", "gpu")]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("unknown backend"), "{err:#}");
}

#[test]
fn underscore_method_spelling_resolves() {
    let engine = Engine::builder("/definitely/not/artifacts").build();
    let ds = random_colors(16, 5);
    let out = engine
        .sort(
            "shuffle_softsort",
            &ds,
            GridShape::new(4, 4),
            &overrides(&[("phases", "32"), ("record_curve", "false")]),
        )
        .unwrap();
    assert_eq!(out.report.method, "ShuffleSoftSort");
}

#[test]
fn unknown_method_through_engine_lists_names() {
    let engine = Engine::builder(ARTIFACTS).build();
    let ds = random_colors(16, 1);
    let err = engine.sort("definitely-not-a-method", &ds, GridShape::new(4, 4), &[]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("definitely-not-a-method"), "{msg}");
    for name in MethodRegistry::new().names() {
        assert!(msg.contains(name), "error does not list {name}: {msg}");
    }
}

#[test]
fn registry_overrides_are_last_wins_like_the_cli() {
    let reg = MethodRegistry::new();
    let g = GridShape::new(4, 4);
    let ds = random_colors(16, 5);
    // flas epochs=2 then epochs=24: the later pair must win, i.e. equal a
    // run with epochs=24 alone and (generically) differ from epochs=2.
    let last_wins = reg
        .build("flas", None, &overrides(&[("epochs", "2"), ("epochs", "24")]))
        .unwrap()
        .sort(&ds, g)
        .unwrap();
    let direct = reg
        .build("flas", None, &overrides(&[("epochs", "24")]))
        .unwrap()
        .sort(&ds, g)
        .unwrap();
    assert_eq!(last_wins.perm, direct.perm);
    assert_eq!(
        last_wins.report.final_dpq.to_bits(),
        direct.report.final_dpq.to_bits()
    );
}

#[test]
fn sort_batch_heuristic_is_bit_identical_to_sequential() {
    let engine = Engine::builder(ARTIFACTS).workers(4).build();
    let g = GridShape::new(8, 8);
    let datasets: Vec<_> = (0..4).map(|s| random_colors(64, 100 + s)).collect();

    let batched = engine.sort_batch("flas", &datasets, g, &[]);
    assert_eq!(batched.len(), 4);
    for (i, result) in batched.into_iter().enumerate() {
        let batched = result.unwrap();
        let sequential = engine.sort("flas", &datasets[i], g, &[]).unwrap();
        assert_eq!(batched.perm, sequential.perm, "item {i}");
        assert_eq!(
            batched.report.final_dpq.to_bits(),
            sequential.report.final_dpq.to_bits(),
            "item {i}: final_dpq must be bit-identical under batching"
        );
        assert_eq!(batched.arranged, sequential.arranged, "item {i}");
    }
}

#[test]
fn sort_batch_native_shares_one_backend_and_is_bit_identical_to_sequential() {
    // The acceptance criterion: 4 workers on the native backend (one
    // shared Send+Sync instance) must be bit-identical to sequential runs.
    // Runs without any artifacts.
    let engine = Engine::builder("/definitely/not/artifacts").workers(4).build();
    let g = GridShape::new(4, 4);
    let datasets: Vec<_> = (0..6).map(|s| random_colors(16, 300 + s)).collect();
    let ov = overrides(&[("phases", "48"), ("record_curve", "false")]);

    let batched = engine.sort_batch("shuffle-softsort", &datasets, g, &ov);
    assert_eq!(batched.len(), 6);
    for (i, result) in batched.into_iter().enumerate() {
        let batched = result.unwrap();
        let sequential = engine.sort("shuffle-softsort", &datasets[i], g, &ov).unwrap();
        assert_eq!(batched.perm, sequential.perm, "item {i}");
        assert_eq!(
            batched.report.final_dpq.to_bits(),
            sequential.report.final_dpq.to_bits(),
            "item {i}: final_dpq must be bit-identical under batching"
        );
        assert_eq!(batched.arranged, sequential.arranged, "item {i}");
    }
}

#[test]
fn threads_override_and_engine_default_are_accepted_and_invariant() {
    use shufflesort::backend::NativeBackend;

    let engine = Engine::builder("/definitely/not/artifacts").build();
    let g = GridShape::new(4, 4);
    let ds = random_colors(16, 12);
    let ov_base = overrides(&[("phases", "32"), ("record_curve", "false")]);
    let base = engine.sort("shuffle-softsort", &ds, g, &ov_base).unwrap();

    // `threads=` flows through the registry like any config key and never
    // changes results (the native reduction is pool-size-invariant).
    let ov = overrides(&[("phases", "32"), ("record_curve", "false"), ("threads", "3")]);
    let out = engine.sort("shuffle-softsort", &ds, g, &ov).unwrap();
    assert_eq!(out.perm, base.perm);
    assert_eq!(out.arranged, base.arranged);

    // The engine-level default (the --threads flag) composes the same way
    // and loses to an explicit per-call pair (last-wins).
    let engine_t = Engine::builder("/definitely/not/artifacts").threads(2).build();
    let out = engine_t.sort("shuffle-softsort", &ds, g, &ov_base).unwrap();
    assert_eq!(out.perm, base.perm);
    let out = engine_t.sort("shuffle-softsort", &ds, g, &ov).unwrap();
    assert_eq!(out.perm, base.perm);

    // Baselines take the key too, and bad values error helpfully.
    let out = engine
        .sort("softsort", &ds, g, &overrides(&[("steps", "32"), ("threads", "2")]))
        .unwrap();
    assert_valid_perm(&out.perm, 16, "softsort threads=2");
    let err = engine
        .sort("shuffle-softsort", &ds, g, &overrides(&[("threads", "lots")]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("threads"), "{err:#}");

    // The backend default is what sessions inherit when unset.
    assert_eq!(NativeBackend::new(3).threads(), 3);
}

#[test]
fn tile_overrides_flow_through_engine_and_registry() {
    let engine = Engine::builder("/definitely/not/artifacts").build();
    let g = GridShape::new(8, 8);
    let ds = random_colors(64, 21);
    let base_ov = overrides(&[("phases", "48"), ("record_curve", "false")]);
    let base = engine.sort("shuffle-softsort", &ds, g, &base_ov).unwrap();
    assert_eq!(base.report.tiles, 1);

    // Engine-level degeneracy: one tile (tile_n >= n) is bit-identical to
    // the full executor.
    let one_tile =
        overrides(&[("phases", "48"), ("record_curve", "false"), ("tile_n", "64")]);
    let out = engine.sort("shuffle-softsort", &ds, g, &one_tile).unwrap();
    assert_eq!(out.report.tiles, 1);
    assert_eq!(out.perm, base.perm);
    for (a, b) in out.arranged.iter().zip(&base.arranged) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(out.report.final_dpq.to_bits(), base.report.final_dpq.to_bits());

    // A real split sorts validly and reports its tile count; `tiles=B`
    // spells the same knob as a count.
    let split = overrides(&[("phases", "48"), ("record_curve", "false"), ("tile_n", "16")]);
    let out = engine.sort("shuffle-softsort", &ds, g, &split).unwrap();
    assert_valid_perm(&out.perm, 64, "tiled sss");
    assert_eq!(out.report.tiles, 4);
    let by_count = overrides(&[("phases", "48"), ("record_curve", "false"), ("tiles", "4")]);
    let out2 = engine.sort("shuffle-softsort", &ds, g, &by_count).unwrap();
    assert_eq!(out2.perm, out.perm, "tiles=4 must equal tile_n=16 on 8x8");

    // Validation is eager and names the key, at the registry layer too.
    let err = engine
        .sort("shuffle-softsort", &ds, g, &overrides(&[("tile_n", "lots")]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("tile_n"), "{err:#}");
    let err = MethodRegistry::new()
        .build("shuffle-softsort", None, &overrides(&[("tiles", "x")]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("tiles"), "{err:#}");
    // Baselines do not take the key (it is a ShuffleSoftSort knob).
    let err = engine
        .sort("softsort", &ds, g, &overrides(&[("tile_n", "16")]))
        .unwrap_err();
    assert!(format!("{err:#}").contains("tile_n"), "{err:#}");
}

#[test]
fn engine_step_session_covers_tile_shapes() {
    use shufflesort::backend::{SssStep, StepSession};

    // The memoized (n, d, h) session cache must serve the sub-grid shapes
    // the tiled executor opens — e.g. a 4-row band of a 128-wide grid.
    let engine = Engine::builder("/definitely/not/artifacts").build();
    let mut sess = engine.step_session(512, 3, 4).unwrap();
    assert_eq!((sess.shape().n, sess.shape().h, sess.shape().w), (512, 4, 128));
    let ds = random_colors(512, 2);
    let w: Vec<f32> = (0..512).map(|i| (512 - i) as f32).collect();
    let inv: Vec<i32> = (0..512).collect();
    let mut out = SssStep::new_for(sess.shape());
    sess.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut out).unwrap();
    assert!(out.loss.is_finite());
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn engine_is_send_on_pure_rust_builds() {
    // The session cache must not cost the pure-Rust build the ability to
    // move an Engine into a worker thread (native sessions are Send).
    fn assert_send<T: Send>() {}
    assert_send::<Engine>();
}

#[test]
fn engine_step_session_is_memoized_and_runs_native_steps() {
    use shufflesort::backend::{NativeBackend, SssStep, StepBackend, StepSession};

    // auto + bogus artifacts dir → native; step sessions need no drivers.
    let engine = Engine::builder("/definitely/not/artifacts").build();
    let ds = random_colors(64, 9);
    let w: Vec<f32> = (0..64).map(|i| (64 - i) as f32).collect();
    let inv: Vec<i32> = (0..64).collect();
    {
        let mut sess = engine.step_session(64, 3, 8).unwrap();
        assert_eq!(sess.backend_name(), "native");
        assert_eq!((sess.shape().n, sess.shape().d, sess.shape().h), (64, 3, 8));
        let mut out = SssStep::new_for(sess.shape());
        sess.sss_step(&w, &ds.rows, &inv, 0.3, 0.5, &mut out).unwrap();
        let direct = NativeBackend::default()
            .sss_step(sess.shape(), &w, &ds.rows, &inv, 0.3, 0.5)
            .unwrap();
        assert_eq!(out.loss.to_bits(), direct.loss.to_bits());
        assert_eq!(out.sort_idx, direct.sort_idx);
    }
    // Second lookup of the same key reuses the memoized session.
    let sess = engine.step_session(64, 3, 8).unwrap();
    assert_eq!(sess.shape().n, 64);
    drop(sess);
    // Ill-formed grids are rejected up front.
    assert!(engine.step_session(63, 3, 8).is_err());
}

#[test]
fn sort_batch_reports_per_item_errors_for_pjrt_without_artifacts() {
    // A learned method pinned to the pjrt backend with a bogus artifacts
    // dir must fail per item (not panic), keeping positional alignment —
    // and without the pjrt feature it must error that pjrt is unavailable.
    let engine = Engine::builder("/definitely/not/artifacts")
        .backend(BackendChoice::Pjrt)
        .workers(2)
        .build();
    let g = GridShape::new(4, 4);
    let datasets: Vec<_> = (0..3).map(|s| random_colors(16, s)).collect();
    let results = engine.sort_batch("shuffle-softsort", &datasets, g, &[]);
    assert_eq!(results.len(), 3);
    for r in &results {
        assert!(r.is_err());
    }
    // ... while heuristics on the same engine still succeed.
    let results = engine.sort_batch("som", &datasets, g, &[]);
    assert!(results.iter().all(|r| r.is_ok()));
    // ... and a per-call backend=native override rescues the learned path.
    let results = engine.sort_batch(
        "shuffle-softsort",
        &datasets,
        g,
        &overrides(&[("backend", "native"), ("phases", "16"), ("record_curve", "false")]),
    );
    assert!(results.iter().all(|r| r.is_ok()));
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;

    fn artifacts_present() -> bool {
        std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
    }

    #[test]
    fn every_learned_method_sorts_a_small_dataset() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let engine = Engine::from_artifacts(ARTIFACTS).unwrap();
        // 8x8 is the smallest grid with artifacts for all four methods.
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 3);
        let budget: &[(&str, &[(&str, &str)])] = &[
            ("shuffle-softsort", &[("phases", "64"), ("record_curve", "false")]),
            ("softsort", &[("steps", "64")]),
            ("gumbel-sinkhorn", &[("steps", "64")]),
            ("kissing", &[("steps", "64")]),
        ];
        for &(name, ov) in budget {
            let out = engine.sort(name, &ds, g, &overrides(ov)).unwrap();
            assert_valid_perm(&out.perm, 64, name);
            assert!(out.report.final_dpq.is_finite(), "{name}: dpq");
            assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged, "{name}: arranged");
        }
    }

    #[test]
    fn auto_choice_prefers_artifacts_when_present() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let engine = Engine::builder(ARTIFACTS).build();
        let desc = engine.backend_desc(&[]).unwrap();
        assert!(desc.contains("pjrt"), "auto with artifacts must pick pjrt: {desc}");
        // An explicit override still forces native.
        let desc = engine.backend_desc(&overrides(&[("backend", "native")])).unwrap();
        assert!(desc.contains("native"), "{desc}");
    }

    #[test]
    fn sort_batch_learned_is_bit_identical_to_sequential() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let engine = Engine::builder(ARTIFACTS).workers(4).build();
        let g = GridShape::new(8, 8);
        let datasets: Vec<_> = (0..4).map(|s| random_colors(64, 200 + s)).collect();
        let ov = overrides(&[("phases", "96"), ("record_curve", "false")]);

        let batched = engine.sort_batch("shuffle-softsort", &datasets, g, &ov);
        assert_eq!(batched.len(), 4);
        for (i, result) in batched.into_iter().enumerate() {
            let batched = result.unwrap();
            let sequential = engine.sort("shuffle-softsort", &datasets[i], g, &ov).unwrap();
            assert_eq!(batched.perm, sequential.perm, "item {i}");
            assert_eq!(
                batched.report.final_dpq.to_bits(),
                sequential.report.final_dpq.to_bits(),
                "item {i}: final_dpq must be bit-identical under batching"
            );
        }
    }

    #[test]
    fn engine_step_cache_memoizes_per_shape() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let engine = Engine::from_artifacts(ARTIFACTS).unwrap();
        let a = engine.sss_step(64, 3, 8).unwrap();
        let b = engine.sss_step(64, 3, 8).unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b), "second lookup must hit the (n,d,h) cache");
        assert!(engine.sss_step(9999, 3, 8).is_err());
    }
}
