//! Trace neutrality and spine coverage, at the `Engine` level: the
//! tracing subsystem is pure observability, so enabling it must never
//! change a single result bit, and a traced sort must actually produce
//! the span tree the serve/CLI exposures rely on.
//!
//! Collector internals (ring wraparound, multi-thread parent/child
//! integrity) are unit-tested inside `src/trace/mod.rs`; this file covers
//! the driver-facing contract on the native backend.

use shufflesort::api::{BackendChoice, Engine};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::serve::json::Json;
use shufflesort::trace;

fn engine() -> Engine {
    Engine::builder("artifacts").backend(BackendChoice::Native).threads(1).build()
}

/// Sort once with tracing in the given state; returns the outcome and
/// (when traced) the finished trace.
fn sort_with_tracing(
    traced: bool,
    method: &str,
    overrides: &[(String, String)],
) -> (shufflesort::coordinator::SortOutcome, Option<std::sync::Arc<trace::FinishedTrace>>) {
    let ds = random_colors(64, 9);
    let g = GridShape::new(8, 8);
    trace::set_enabled(traced);
    let root = if traced { trace::Span::root("test_sort") } else { trace::Span::off() };
    let id = root.ctx().map(|c| c.trace_id);
    let out = {
        let _cur = root.make_current();
        engine().sort(method, &ds, g, overrides).expect("sort succeeds")
    };
    root.end();
    let finished = id.and_then(trace::finish);
    trace::set_enabled(false);
    (out, finished)
}

fn ov(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

#[test]
fn engine_sort_is_bit_identical_with_tracing_on_and_off() {
    let _x = trace::exclusive_test_lock();
    // Tiled shuffle-softsort covers phases, tiles and both step families.
    for (method, overrides) in [
        ("shuffle-softsort", ov(&[("phases", "12"), ("tile_n", "16"), ("record_curve", "false")])),
        ("softsort", ov(&[("steps", "24")])),
    ] {
        let (off, none) = sort_with_tracing(false, method, &overrides);
        assert!(none.is_none(), "untraced sorts record nothing");
        let (on, finished) = sort_with_tracing(true, method, &overrides);

        assert_eq!(
            off.perm.as_slice(),
            on.perm.as_slice(),
            "{method}: permutation must not depend on tracing"
        );
        let off_bits: Vec<u32> = off.arranged.iter().map(|v| v.to_bits()).collect();
        let on_bits: Vec<u32> = on.arranged.iter().map(|v| v.to_bits()).collect();
        assert_eq!(off_bits, on_bits, "{method}: arranged rows must be bit-identical");
        assert_eq!(off.report.final_loss.to_bits(), on.report.final_loss.to_bits(), "{method}");
        assert_eq!(off.report.final_dpq.to_bits(), on.report.final_dpq.to_bits(), "{method}");
        assert_eq!(off.report.steps, on.report.steps, "{method}");
        assert_eq!(off.report.rejected_phases, on.report.rejected_phases, "{method}");

        let t = finished.expect("traced sort produced a finished trace");
        assert!(t.spans.len() > 1, "{method}: trace has spans beyond the root");
    }
}

#[test]
fn traced_tiled_sort_produces_phase_tile_and_step_spans() {
    let _x = trace::exclusive_test_lock();
    // record_curve stays on (the default): the per-phase `loss` attr is
    // read off the curve, so the telemetry assertions below need it.
    let (_, finished) = sort_with_tracing(
        true,
        "shuffle-softsort",
        &ov(&[("phases", "8"), ("tile_n", "16")]),
    );
    let t = finished.expect("finished trace");
    let names: Vec<&str> = t.spans.iter().map(|s| s.name).collect();
    for want in ["test_sort", "phase", "tile", "sss_step", "adam_step", "session_build"] {
        assert!(names.contains(&want), "missing '{want}' span: {names:?}");
    }
    // Every phase span carries the convergence attrs the telemetry uses.
    let phases: Vec<_> = t.spans.iter().filter(|s| s.name == "phase").collect();
    assert_eq!(phases.len(), 8, "stride 1 at 8 phases samples all of them");
    for p in phases {
        let keys: Vec<&str> = p.attrs.iter().flatten().map(|(k, _)| *k).collect();
        for want in ["phase", "tau", "loss", "accepted"] {
            assert!(keys.contains(&want), "phase span misses attr '{want}': {keys:?}");
        }
    }
    // Parent links all resolve within the trace, with one root.
    let ids: Vec<u64> = t.spans.iter().map(|s| s.span_id).collect();
    let mut roots = 0usize;
    for s in &t.spans {
        assert_eq!(s.trace_id, t.trace_id);
        if s.parent_id == 0 {
            roots += 1;
        } else {
            assert!(ids.contains(&s.parent_id), "dangling parent for '{}'", s.name);
        }
    }
    assert_eq!(roots, 1);
    // 4 tiles per phase × 8 phases, each timing both step families.
    assert_eq!(t.spans.iter().filter(|s| s.name == "tile").count(), 32);
    let sss_steps: u64 = t
        .spans
        .iter()
        .filter(|s| s.name == "sss_step")
        .filter_map(|s| {
            s.attrs.iter().flatten().find(|(k, _)| *k == "steps").and_then(|(_, v)| match v {
                trace::AttrValue::U64(n) => Some(*n),
                _ => None,
            })
        })
        .sum();
    assert!(sss_steps > 0, "sss_step spans count their steps");
}

#[test]
fn chrome_export_nests_events_with_monotonic_timestamps_and_stable_ids() {
    let _x = trace::exclusive_test_lock();
    let (_, finished) = sort_with_tracing(
        true,
        "shuffle-softsort",
        &ov(&[("phases", "6"), ("tile_n", "16"), ("record_curve", "false")]),
    );
    let t = finished.expect("finished trace");
    let parsed = Json::parse(&trace::chrome_trace_json(&t).to_string_compact()).unwrap();
    let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert_eq!(events.len(), t.spans.len(), "one complete-event per span");

    // First pass: per-event invariants + an id -> (ts, end, tid) index.
    let mut by_id: std::collections::HashMap<u64, (f64, f64, f64, &str)> =
        std::collections::HashMap::new();
    let mut last_ts = f64::MIN;
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"), "complete events only");
        assert_eq!(e.get("pid").and_then(Json::as_f64), Some(1.0), "single stable pid");
        let ts = e.get("ts").and_then(Json::as_f64).unwrap();
        let dur = e.get("dur").and_then(Json::as_f64).unwrap();
        let tid = e.get("tid").and_then(Json::as_f64).unwrap();
        assert!(tid > 0.0, "tids are nonzero thread slots");
        assert!(ts >= last_ts, "events sorted by start time");
        last_ts = ts;
        let name = e.get("name").and_then(Json::as_str).unwrap();
        let args = e.get("args").unwrap();
        let id = args.get("span_id").and_then(Json::as_f64).unwrap();
        by_id.insert(id.to_bits(), (ts, ts + dur, tid, name));
    }

    // Second pass: every child interval nests inside its parent's, with
    // ≤2µs slack for the µs truncation of start and duration.
    for e in events {
        let args = e.get("args").unwrap();
        let parent = args.get("parent_id").and_then(Json::as_f64).unwrap();
        if parent == 0.0 {
            continue;
        }
        let id = args.get("span_id").and_then(Json::as_f64).unwrap();
        let (ts, end, _, name) = by_id[&id.to_bits()];
        let (pts, pend, ptid, pname) = by_id[&parent.to_bits()];
        assert!(ts >= pts, "'{name}' starts before its parent '{pname}'");
        assert!(end <= pend + 2.0, "'{name}' outlives its parent '{pname}'");
        // The driver runs phases on the root's thread: tid is stable
        // along that edge of the tree.
        if name == "phase" && pname == "test_sort" {
            let (_, _, tid, _) = by_id[&id.to_bits()];
            assert_eq!(tid.to_bits(), ptid.to_bits(), "phase rides the driver thread");
        }
    }
}

#[test]
fn folded_profile_from_a_traced_sort_matches_span_paths() {
    let _x = trace::exclusive_test_lock();
    let (_, finished) = sort_with_tracing(
        true,
        "shuffle-softsort",
        &ov(&[("phases", "8"), ("tile_n", "16"), ("record_curve", "false")]),
    );
    let t = finished.expect("finished trace");
    let p = trace::profile::Profile::new();
    p.observe(&t);
    assert_eq!(p.traces(), 1);
    let folded = p.folded();
    assert!(
        folded.lines().any(|l| l.starts_with("test_sort ")),
        "root path present:\n{folded}"
    );
    assert!(
        folded.lines().any(|l| l.contains("phase;tile;sss_step ")),
        "phase->tile->sss_step chain missing:\n{folded}"
    );
    // Folded weights are self time: their sum can never exceed the sum of
    // raw span durations, and every line is `path weight`.
    let mut total_self = 0u64;
    for line in folded.lines() {
        let (path, weight) = line.rsplit_once(' ').expect("`path weight` lines");
        assert!(!path.is_empty());
        total_self += weight.parse::<u64>().expect("integer weight");
    }
    let total_span: u64 = t.spans.iter().map(|s| s.dur_us).sum();
    assert!(total_self <= total_span, "self time folded past total span time");
}
