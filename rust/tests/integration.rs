//! Integration tests across backends + coordinator + substrates.
//!
//! Two tiers:
//!
//! * **Native tier — always runs.** The pure-Rust `NativeBackend` needs no
//!   artifacts, so the learned drivers are exercised end-to-end on every
//!   `cargo test`, including `--no-default-features` builds.
//! * **PJRT tier — `pjrt` feature + artifacts.** Exercises the AOT
//!   artifacts from `make artifacts`; each test skips itself (with a note)
//!   when the artifacts are absent, so `cargo test` stays green on a fresh
//!   checkout while still running the full suite locally. This tier also
//!   holds the native-vs-PJRT numerical parity tests.

use shufflesort::backend::{NativeBackend, StepBackend};
use shufflesort::config::{BaselineConfig, ShuffleSoftSortConfig, TilePlanKind};
use shufflesort::coordinator::baselines::{
    GumbelSinkhornDriver, KissingDriver, SoftSortDriver,
};
use shufflesort::coordinator::ShuffleSoftSort;
use shufflesort::data::{fig3_colors, random_colors};
use shufflesort::grid::GridShape;
use shufflesort::metrics::{dpq16, mean_neighbor_distance};

fn small_cfg() -> ShuffleSoftSortConfig {
    let mut cfg = ShuffleSoftSortConfig::for_grid(8, 8);
    cfg.phases = 768;
    cfg
}

// ==========================================================================
// Native tier: always runs, no artifacts required.
// ==========================================================================

#[test]
fn native_shuffle_softsort_improves_dpq_end_to_end() {
    // The satellite acceptance check: ShuffleSoftSort through the native
    // backend on (n=64, d=3) must clearly improve DPQ over the identity
    // arrangement.
    let ds = random_colors(64, 42);
    let g = GridShape::new(8, 8);
    let before = dpq16(&ds.rows, 3, g);
    let backend = NativeBackend::default();
    let out = ShuffleSoftSort::new(&backend, small_cfg()).unwrap().sort(&ds).unwrap();
    assert!(
        out.report.final_dpq > before + 0.2,
        "native sss {} vs unsorted {before}",
        out.report.final_dpq
    );
    // The returned permutation really produces the returned arrangement.
    assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged);
    assert_eq!(out.perm.len(), 64);
}

#[test]
fn native_shuffle_softsort_is_deterministic_per_seed() {
    let ds = random_colors(64, 7);
    let backend = NativeBackend::default();
    let mut cfg = small_cfg();
    cfg.phases = 256;
    let a = ShuffleSoftSort::new(&backend, cfg.clone()).unwrap().sort(&ds).unwrap();
    let b = ShuffleSoftSort::new(&backend, cfg.clone()).unwrap().sort(&ds).unwrap();
    assert_eq!(a.perm, b.perm);
    cfg.seed = 8;
    let c = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
    assert_ne!(a.perm, c.perm);
}

#[test]
fn native_session_pool_sizes_do_not_change_results_end_to_end() {
    // N = 640 ≥ PAR_MIN_N, so multi-thread sessions really engage the
    // worker pool. The chunk-ordered reductions must make every pool size
    // — via `cfg.threads` or the backend default — bit-identical through a
    // full ShuffleSoftSort run (perm, arrangement, DPQ).
    let ds = random_colors(640, 5);
    let base_cfg = {
        let mut cfg = ShuffleSoftSortConfig::for_grid(20, 32);
        cfg.phases = 3;
        cfg.record_curve = false;
        cfg
    };
    let run = |threads: Option<usize>, backend_threads: usize| {
        let backend = NativeBackend::new(backend_threads);
        let mut cfg = base_cfg.clone();
        cfg.threads = threads;
        ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap()
    };
    let base = run(None, 1);
    for (threads, bt) in [(Some(2), 1), (Some(8), 1), (None, 4)] {
        let out = run(threads, bt);
        assert_eq!(out.perm, base.perm, "threads={threads:?} backend_threads={bt}");
        for (a, b) in out.arranged.iter().zip(&base.arranged) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads:?} backend_threads={bt}");
        }
        assert_eq!(
            out.report.final_dpq.to_bits(),
            base.report.final_dpq.to_bits(),
            "threads={threads:?} backend_threads={bt}"
        );
    }
}

#[test]
fn native_baseline_drivers_run_end_to_end() {
    let ds = random_colors(64, 42);
    let g = GridShape::new(8, 8);
    let backend = NativeBackend::default();
    let before = dpq16(&ds.rows, 3, g);

    let mut ss_cfg = BaselineConfig::for_grid(8, 8);
    ss_cfg.steps = 512;
    let ss = SoftSortDriver::new(&backend, ss_cfg).sort(&ds).unwrap();
    assert_eq!(ss.perm.len(), 64);
    assert!(ss.report.final_dpq.is_finite());

    let mut gs_cfg = BaselineConfig::for_gs(8, 8);
    gs_cfg.steps = 512;
    let gs = GumbelSinkhornDriver::new(&backend, gs_cfg).sort(&ds).unwrap();
    assert_eq!(gs.perm.len(), 64); // JV extraction always valid
    assert!(gs.report.final_dpq > before, "gs {} vs {before}", gs.report.final_dpq);

    let mut kiss_cfg = BaselineConfig::for_grid(8, 8);
    kiss_cfg.steps = 192;
    let kiss = KissingDriver::new(&backend, kiss_cfg).sort(&ds).unwrap();
    assert_eq!(kiss.perm.len(), 64);
    assert_eq!(kiss.report.repaired == 0, kiss.report.valid_without_repair);
    assert_eq!(kiss.report.param_count, 2 * 64 * 8); // M(64) = 8
}

#[test]
fn native_fig3_toy_shuffle_softsort_beats_softsort() {
    let ds = fig3_colors();
    let g = GridShape::new(1, 16);
    let backend = NativeBackend::default();
    let mut cfg = ShuffleSoftSortConfig::for_grid(1, 16);
    cfg.phases = 512;
    let sss = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
    let mut ss_cfg = BaselineConfig::for_grid(1, 16);
    ss_cfg.steps = 2048;
    let ss = SoftSortDriver::new(&backend, ss_cfg).sort(&ds).unwrap();
    let n_sss = mean_neighbor_distance(&sss.arranged, 3, g);
    let n_ss = mean_neighbor_distance(&ss.arranged, 3, g);
    assert!(n_sss < n_ss + 1e-9, "sss {n_sss} vs softsort {n_ss}");
}

#[test]
fn native_loss_curve_is_recorded_and_roughly_decreasing() {
    let ds = random_colors(64, 3);
    let backend = NativeBackend::default();
    let mut cfg = small_cfg();
    cfg.phases = 512;
    cfg.record_curve = true;
    let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
    assert_eq!(out.report.curve.len(), out.report.steps);
    let k = out.report.curve.len() / 8;
    let head: f64 =
        out.report.curve[..k].iter().map(|p| p.loss).sum::<f64>() / k as f64;
    let tail: f64 =
        out.report.curve[out.report.curve.len() - k..].iter().map(|p| p.loss).sum::<f64>() / k as f64;
    assert!(tail < head, "loss head {head} tail {tail}");
}

#[test]
fn native_sog_pipeline_beats_shuffled_compression() {
    use shufflesort::api::{overrides, MethodRegistry};
    use shufflesort::sog::codec::CodecConfig;
    use shufflesort::sog::scene::{GaussianScene, SceneConfig};
    use shufflesort::sog::{run_pipeline, SorterKind};

    let scene = GaussianScene::generate(&SceneConfig {
        n_splats: 256,
        seed: 5,
        ..Default::default()
    });
    let g = GridShape::new(16, 16);
    let codec = CodecConfig::default();
    let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec).unwrap();
    let backend = NativeBackend::default();
    let sss = MethodRegistry::new()
        .build(
            "shuffle-softsort",
            Some(&backend as &dyn StepBackend),
            // Small budget: tests run in the dev profile; directional only.
            &overrides(&[("phases", "512"), ("record_curve", "false")]),
        )
        .unwrap();
    let learned = run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &codec).unwrap();
    // Directional at this small budget; paper-scale numbers live in the
    // fig6_sog bench.
    assert!(
        learned.compressed_bytes < shuffled.compressed_bytes,
        "learned {} vs shuffled {}",
        learned.compressed_bytes,
        shuffled.compressed_bytes
    );
    assert!(learned.spatial_corr > shuffled.spatial_corr + 0.05);
    assert!((learned.mean_psnr_db - shuffled.mean_psnr_db).abs() < 3.0);
}

// --------------------------------------------------------------------------
// Tiled phase execution (native tier).
// --------------------------------------------------------------------------

#[test]
fn tiled_with_one_tile_is_bit_identical_to_full() {
    // The degeneracy contract: `tile_n >= n` puts the whole grid in one
    // tile, the tile-local gather is the identity, and the tiled executor
    // must reproduce the full executor bit for bit — permutation,
    // arrangement, DPQ, loss trace, everything.
    let ds = random_colors(64, 31);
    let backend = NativeBackend::default();
    let mut full_cfg = ShuffleSoftSortConfig::for_grid(8, 8);
    full_cfg.phases = 96;
    let mut tiled_cfg = full_cfg.clone();
    for tile_n in [64usize, 65, 100_000] {
        tiled_cfg.tile_n = Some(tile_n);
        let full = ShuffleSoftSort::new(&backend, full_cfg.clone()).unwrap().sort(&ds).unwrap();
        let tiled =
            ShuffleSoftSort::new(&backend, tiled_cfg.clone()).unwrap().sort(&ds).unwrap();
        assert_eq!(tiled.report.tiles, 1, "tile_n={tile_n}");
        assert_eq!(full.report.tiles, 1);
        assert_eq!(tiled.perm, full.perm, "tile_n={tile_n}");
        for (a, b) in tiled.arranged.iter().zip(&full.arranged) {
            assert_eq!(a.to_bits(), b.to_bits(), "tile_n={tile_n}");
        }
        assert_eq!(
            tiled.report.final_dpq.to_bits(),
            full.report.final_dpq.to_bits(),
            "tile_n={tile_n}"
        );
        assert_eq!(tiled.report.steps, full.report.steps);
        assert_eq!(tiled.report.extensions, full.report.extensions);
        for (a, b) in tiled.report.curve.iter().zip(&full.report.curve) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "tile_n={tile_n}");
        }
    }
}

#[test]
fn tiled_block_diagonal_composition_is_valid_for_ragged_splits() {
    // Ragged grids and tile sizes that do not divide N: the per-tile
    // permutations must still compose into a valid bijection on every
    // phase, and the driver invariant perm→arranged must hold.
    let backend = NativeBackend::default();
    for (h, w, tile_n) in [(8usize, 8usize, 24usize), (5, 7, 10), (1, 40, 7), (9, 4, 13)] {
        let n = h * w;
        let ds = random_colors(n, 7 + (h * 31 + w) as u64);
        let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
        cfg.phases = 24;
        cfg.record_curve = false;
        cfg.tile_n = Some(tile_n);
        let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        assert_eq!(out.perm.len(), n, "{h}x{w} tile_n={tile_n}");
        assert!(out.report.tiles > 1, "{h}x{w} tile_n={tile_n}: expected a real split");
        assert!(out.report.final_dpq.is_finite());
        assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged, "{h}x{w} tile_n={tile_n}");
    }
}

#[test]
fn tiled_results_are_dispatch_order_invariant() {
    // N = 640 with 4-row tiles → 5 tiles; threads=1 forces the sequential
    // tile loop, larger budgets dispatch tiles over the worker pool. The
    // tile-index-ordered fold must make every configuration bit-identical.
    let ds = random_colors(640, 17);
    let backend = NativeBackend::default();
    let base_cfg = {
        let mut cfg = ShuffleSoftSortConfig::for_grid(20, 32);
        cfg.phases = 6;
        cfg.record_curve = false;
        cfg.tile_n = Some(128);
        cfg
    };
    let run = |threads: Option<usize>| {
        let mut cfg = base_cfg.clone();
        cfg.threads = threads;
        ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap()
    };
    let base = run(Some(1));
    assert_eq!(base.report.tiles, 5);
    for threads in [Some(2), Some(4), Some(8), None] {
        let out = run(threads);
        assert_eq!(out.perm, base.perm, "threads={threads:?}");
        for (a, b) in out.arranged.iter().zip(&base.arranged) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads:?}");
        }
        assert_eq!(
            out.report.final_dpq.to_bits(),
            base.report.final_dpq.to_bits(),
            "threads={threads:?}"
        );
    }
}

#[test]
fn zero_inner_iters_still_yields_valid_permutations() {
    // Degenerate `inner_iters=0` (accepted by the config) must reach the
    // extension/repair path — not return an empty permutation — on both
    // executors (regression: the executor refactor must keep the old
    // zero-seeded hard draft).
    let ds = random_colors(64, 3);
    let backend = NativeBackend::default();
    for tile_n in [None, Some(16usize)] {
        let mut cfg = ShuffleSoftSortConfig::for_grid(8, 8);
        cfg.phases = 4;
        cfg.inner_iters = 0;
        cfg.record_curve = false;
        cfg.tile_n = tile_n;
        let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        assert_eq!(out.perm.len(), 64, "tile_n={tile_n:?}");
        assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged, "tile_n={tile_n:?}");
    }
}

#[test]
fn tiled_shuffle_softsort_improves_dpq_end_to_end() {
    // Tiling is a performance knob, not a quality escape hatch: with the
    // standard shuffles + greedy acceptance a tiled run must still clearly
    // improve DPQ over the identity arrangement.
    let ds = random_colors(256, 42);
    let g = GridShape::new(16, 16);
    let before = dpq16(&ds.rows, 3, g);
    let backend = NativeBackend::default();
    let mut cfg = ShuffleSoftSortConfig::for_grid(16, 16);
    cfg.phases = 1024;
    cfg.record_curve = false;
    cfg.tile_n = Some(64);
    let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
    assert_eq!(out.report.tiles, 4);
    assert!(
        out.report.final_dpq > before + 0.15,
        "tiled sss {} vs unsorted {before}",
        out.report.final_dpq
    );
    assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged);
}

#[test]
fn snake_and_overlapped_plans_compose_valid_permutations() {
    // Boundary-aware plans: boustrophedon chains and phase-alternating
    // half-offset bands must keep every phase a bijection on ragged
    // shapes, including 1-D and w=1 grids, and the driver invariant
    // perm→arranged must hold.
    let backend = NativeBackend::default();
    for kind in [TilePlanKind::Snake, TilePlanKind::Overlapped] {
        for (h, w, tile_n) in [(8usize, 8usize, 24usize), (5, 7, 10), (1, 40, 7), (9, 4, 13)] {
            let n = h * w;
            let ds = random_colors(n, 7 + (h * 31 + w) as u64);
            let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
            cfg.phases = 24;
            cfg.record_curve = false;
            cfg.tile_n = Some(tile_n);
            cfg.tile_plan = kind;
            let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
            assert_eq!(out.perm.len(), n, "{kind:?} {h}x{w} tile_n={tile_n}");
            assert!(out.report.tiles > 1, "{kind:?} {h}x{w}: expected a real split");
            assert_eq!(out.report.tile_plan, kind.name(), "{kind:?} {h}x{w}");
            assert!(out.report.final_dpq.is_finite());
            assert_eq!(
                out.perm.apply_rows(&ds.rows, 3),
                out.arranged,
                "{kind:?} {h}x{w} tile_n={tile_n}"
            );
        }
    }
}

#[test]
fn alternating_plans_are_dispatch_order_invariant() {
    // The overlapped plan alternates two cuts between phases; the
    // tile-index-ordered fold must still make every thread budget
    // bit-identical (threads 1–8 plus the backend default).
    let ds = random_colors(640, 17);
    let backend = NativeBackend::default();
    let base_cfg = {
        let mut cfg = ShuffleSoftSortConfig::for_grid(20, 32);
        cfg.phases = 6;
        cfg.record_curve = false;
        cfg.tile_n = Some(128);
        cfg.tile_plan = TilePlanKind::Overlapped;
        cfg
    };
    let run = |threads: Option<usize>| {
        let mut cfg = base_cfg.clone();
        cfg.threads = threads;
        ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap()
    };
    let base = run(Some(1));
    for threads in [Some(2), Some(3), Some(4), Some(5), Some(6), Some(7), Some(8), None] {
        let out = run(threads);
        assert_eq!(out.perm, base.perm, "threads={threads:?}");
        for (a, b) in out.arranged.iter().zip(&base.arranged) {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={threads:?}");
        }
        assert_eq!(
            out.report.final_dpq.to_bits(),
            base.report.final_dpq.to_bits(),
            "threads={threads:?}"
        );
    }
}

#[test]
fn pyramid_with_single_coarse_tile_is_bit_identical_to_full_and_tiled() {
    // Degeneracy contract, pyramid edition: a budget covering the whole
    // grid collapses the schedule to one leaf solve, whose gather is the
    // identity — bit-identical to the full executor (and hence to the
    // one-tile tiled executor, which shares the contract).
    let ds = random_colors(64, 31);
    let backend = NativeBackend::default();
    let mut full_cfg = ShuffleSoftSortConfig::for_grid(8, 8);
    full_cfg.phases = 96;
    let full = ShuffleSoftSort::new(&backend, full_cfg.clone()).unwrap().sort(&ds).unwrap();
    for tile_n in [None, Some(64usize), Some(100_000)] {
        let mut cfg = full_cfg.clone();
        cfg.pyramid = true;
        cfg.tile_n = tile_n;
        let pyr = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        assert_eq!(pyr.report.tiles, 1, "tile_n={tile_n:?}");
        assert_eq!(pyr.report.tile_plan, "pyramid");
        assert_eq!(pyr.perm, full.perm, "tile_n={tile_n:?}");
        for (a, b) in pyr.arranged.iter().zip(&full.arranged) {
            assert_eq!(a.to_bits(), b.to_bits(), "tile_n={tile_n:?}");
        }
        assert_eq!(
            pyr.report.final_dpq.to_bits(),
            full.report.final_dpq.to_bits(),
            "tile_n={tile_n:?}"
        );
        assert_eq!(pyr.report.steps, full.report.steps);
        for (a, b) in pyr.report.curve.iter().zip(&full.report.curve) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "tile_n={tile_n:?}");
        }
    }
}

#[test]
fn pyramid_composes_valid_permutations_and_improves_dpq() {
    // A real multi-level pyramid (32x32 with a 64-item budget → a 4x4
    // coarse grid over 8x8 subtiles): every phase must compose a valid
    // bijection, the coarse relocation must not break the perm→arranged
    // invariant, and the run must clearly improve DPQ.
    let ds = random_colors(1024, 42);
    let g = GridShape::new(32, 32);
    let before = dpq16(&ds.rows, 3, g);
    let backend = NativeBackend::default();
    let mut cfg = ShuffleSoftSortConfig::for_grid(32, 32);
    cfg.phases = 192;
    cfg.record_curve = false;
    cfg.tile_n = Some(64);
    cfg.pyramid = true;
    let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
    assert_eq!(out.report.tiles, 16, "4x4 coarse split over 8x8 leaves");
    assert_eq!(out.report.tile_plan, "pyramid");
    assert!(
        out.report.final_dpq > before + 0.1,
        "pyramid sss {} vs unsorted {before}",
        out.report.final_dpq
    );
    assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged);
}

// ==========================================================================
// PJRT tier: needs the `pjrt` feature and the AOT artifacts.
// ==========================================================================

#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use shufflesort::backend::{PjrtBackend, StepShape};
    use shufflesort::runtime::{Arg, Runtime};

    /// Load the artifacts, or `None` (→ skip) when `make artifacts` hasn't
    /// run.
    fn try_rt() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts missing — run `make artifacts`");
            return None;
        }
        Some(Runtime::from_manifest(dir).expect("manifest present but runtime failed to load"))
    }

    macro_rules! require_backend {
        () => {
            match try_rt() {
                Some(rt) => PjrtBackend::new(rt),
                None => return,
            }
        };
    }

    #[test]
    fn manifest_covers_every_runtime_lookup_used_by_benches() {
        let backend = require_backend!();
        let rt = backend.runtime();
        rt.sss_step(64, 3, 8).unwrap();
        rt.sss_step(16, 3, 1).unwrap();
        rt.gs_step(64, 3, 8).unwrap();
        rt.gs_probe(64).unwrap();
        rt.kiss_step(64, 8, 3).unwrap();
        assert!(rt.load("no_such_artifact").is_err());
    }

    #[test]
    fn step_artifact_outputs_match_manifest_shapes() {
        let backend = require_backend!();
        let exe = backend.runtime().sss_step(64, 3, 8).unwrap();
        let w: Vec<f32> = (0..64).map(|i| (64 - i) as f32).collect();
        let x: Vec<f32> = (0..64 * 3).map(|i| (i as f32 * 0.37).fract()).collect();
        let inv: Vec<i32> = (0..64).collect();
        let out = exe
            .run(&[Arg::F32(&w), Arg::F32(&x), Arg::I32(&inv), Arg::ScalarF32(0.3), Arg::ScalarF32(0.5)])
            .unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(out[0].as_f32().unwrap().len(), 1); // loss scalar
        assert_eq!(out[1].as_f32().unwrap().len(), 64); // grad
        assert_eq!(out[2].as_i32().unwrap().len(), 64); // sort_idx
        assert_eq!(out[3].as_f32().unwrap().len(), 64); // colsum
        assert_eq!(out[4].as_f32().unwrap().len(), 64 * 3); // y
        assert!(out[0].scalar_f32().unwrap().is_finite());
        // Typed accessor errors name the artifact (OutValue satellite).
        let err = out[2].as_f32().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("sss_step_n64_d3_h8"), "{msg}");
        assert!(out[1].scalar_f32().is_err()); // shape error: 64 != 1
        // Order-preserving init at sharp tau ⇒ identity sort_idx.
        let idx = out[2].as_i32().unwrap();
        assert!(idx.iter().enumerate().all(|(i, &v)| v as usize == i));
        // colsum of a near-permutation ≈ 1.
        for &c in out[3].as_f32().unwrap() {
            assert!((c - 1.0).abs() < 0.2, "colsum {c}");
        }
    }

    #[test]
    fn artifact_rejects_wrong_arity_and_shapes() {
        let backend = require_backend!();
        let exe = backend.runtime().sss_step(64, 3, 8).unwrap();
        let w = vec![0.0f32; 64];
        assert!(exe.run(&[Arg::F32(&w)]).is_err());
        let bad_x = vec![0.0f32; 10];
        let inv: Vec<i32> = (0..64).collect();
        assert!(exe
            .run(&[Arg::F32(&w), Arg::F32(&bad_x), Arg::I32(&inv), Arg::ScalarF32(0.3), Arg::ScalarF32(0.5)])
            .is_err());
    }

    #[test]
    fn shuffle_softsort_improves_over_random_and_softsort() {
        let backend = require_backend!();
        let ds = random_colors(64, 42);
        let g = GridShape::new(8, 8);
        let before = dpq16(&ds.rows, 3, g);

        let out = ShuffleSoftSort::new(&backend, small_cfg()).unwrap().sort(&ds).unwrap();
        assert!(out.report.final_dpq > before + 0.3, "sss {} vs unsorted {before}", out.report.final_dpq);

        let mut ss_cfg = BaselineConfig::for_grid(8, 8);
        ss_cfg.steps = 768 * 4;
        let ss = SoftSortDriver::new(&backend, ss_cfg).sort(&ds).unwrap();
        assert!(
            out.report.final_dpq > ss.report.final_dpq,
            "sss {} must beat plain softsort {}",
            out.report.final_dpq,
            ss.report.final_dpq
        );
        // The returned permutation really produces the returned arrangement.
        assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged);
    }

    #[test]
    fn shuffle_softsort_is_deterministic_per_seed() {
        let backend = require_backend!();
        let ds = random_colors(64, 7);
        let mut cfg = small_cfg();
        cfg.phases = 256;
        let a = ShuffleSoftSort::new(&backend, cfg.clone()).unwrap().sort(&ds).unwrap();
        let b = ShuffleSoftSort::new(&backend, cfg.clone()).unwrap().sort(&ds).unwrap();
        assert_eq!(a.perm, b.perm);
        cfg.seed = 8;
        let c = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        assert_ne!(a.perm, c.perm);
    }

    #[test]
    fn gumbel_sinkhorn_driver_runs_and_improves() {
        let backend = require_backend!();
        let ds = random_colors(64, 42);
        let g = GridShape::new(8, 8);
        let mut cfg = BaselineConfig::for_gs(8, 8);
        cfg.steps = 512;
        let out = GumbelSinkhornDriver::new(&backend, cfg).sort(&ds).unwrap();
        assert!(out.report.final_dpq > dpq16(&ds.rows, 3, g));
        assert_eq!(out.perm.len(), 64); // JV extraction always valid
    }

    #[test]
    fn kissing_driver_runs_and_reports_validity() {
        let backend = require_backend!();
        let ds = random_colors(64, 42);
        let mut cfg = BaselineConfig::for_grid(8, 8);
        cfg.steps = 256;
        let out = KissingDriver::new(&backend, cfg).sort(&ds).unwrap();
        // Whether valid or repaired, the final permutation must be a
        // bijection and the stability stat must be consistent.
        assert_eq!(out.perm.len(), 64);
        assert_eq!(out.report.repaired == 0, out.report.valid_without_repair);
    }

    #[test]
    fn fig3_toy_shuffle_softsort_beats_softsort() {
        let backend = require_backend!();
        let ds = fig3_colors();
        let g = GridShape::new(1, 16);
        let mut cfg = ShuffleSoftSortConfig::for_grid(1, 16);
        cfg.phases = 512;
        let sss = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        let mut ss_cfg = BaselineConfig::for_grid(1, 16);
        ss_cfg.steps = 2048;
        let ss = SoftSortDriver::new(&backend, ss_cfg).sort(&ds).unwrap();
        let n_sss = mean_neighbor_distance(&sss.arranged, 3, g);
        let n_ss = mean_neighbor_distance(&ss.arranged, 3, g);
        assert!(n_sss < n_ss + 1e-9, "sss {n_sss} vs softsort {n_ss}");
    }

    #[test]
    fn loss_curve_is_recorded_and_roughly_decreasing() {
        let backend = require_backend!();
        let ds = random_colors(64, 3);
        let mut cfg = small_cfg();
        cfg.phases = 512;
        cfg.record_curve = true;
        let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&ds).unwrap();
        assert_eq!(out.report.curve.len(), out.report.steps);
        let k = out.report.curve.len() / 8;
        let head: f64 =
            out.report.curve[..k].iter().map(|p| p.loss).sum::<f64>() / k as f64;
        let tail: f64 = out.report.curve[out.report.curve.len() - k..]
            .iter()
            .map(|p| p.loss)
            .sum::<f64>()
            / k as f64;
        assert!(tail < head, "loss head {head} tail {tail}");
    }

    #[test]
    fn sog_learned_pipeline_beats_shuffled() {
        use shufflesort::api::{overrides, MethodRegistry};
        use shufflesort::sog::codec::CodecConfig;
        use shufflesort::sog::scene::{GaussianScene, SceneConfig};
        use shufflesort::sog::{run_pipeline, SorterKind};

        let backend = require_backend!();
        let scene = GaussianScene::generate(&SceneConfig {
            n_splats: 1024,
            seed: 5,
            ..Default::default()
        });
        let g = GridShape::new(32, 32);
        let codec = CodecConfig::default();
        let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec).unwrap();
        let sss = MethodRegistry::new()
            .build(
                "shuffle-softsort",
                Some(&backend as &dyn StepBackend),
                &overrides(&[("phases", "2048"), ("record_curve", "false")]),
            )
            .unwrap();
        let learned = run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &codec).unwrap();
        // The integration budget (2048 phases) is deliberately small — the
        // assertion is directional; the full-quality numbers live in the
        // fig6_sog bench (EXPERIMENTS.md §E6).
        assert!(
            (learned.compressed_bytes as f64) < 0.95 * shuffled.compressed_bytes as f64,
            "learned {} vs shuffled {}",
            learned.compressed_bytes,
            shuffled.compressed_bytes
        );
        assert!(learned.spatial_corr > shuffled.spatial_corr + 0.15);
        assert!((learned.mean_psnr_db - shuffled.mean_psnr_db).abs() < 3.0);
    }

    // ----------------------------------------------------------------------
    // Numerical parity: NativeBackend vs the AOT artifacts on identical
    // inputs (the satellite's 1e-4 tolerance; GS/Kissing allow 1e-3 — the
    // 40 iterated Sinkhorn normalizations / the scale-30 softmax amplify
    // f32 reduction-order drift).
    // ----------------------------------------------------------------------

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = 1.0 + x.abs().max(y.abs());
            assert!(
                (x - y).abs() <= tol * scale,
                "{what}[{i}]: native {y} vs pjrt {x} (tol {tol})"
            );
        }
    }

    #[test]
    fn native_backend_matches_pjrt_sss_step() {
        let pjrt = require_backend!();
        let native = NativeBackend::default();
        let shape = StepShape::new(GridShape::new(8, 8), 3);
        let ds = random_colors(64, 9);
        let w: Vec<f32> = (0..64).map(|i| (64 - i) as f32 + 0.2 * (i as f32).sin()).collect();
        let inv: Vec<i32> = (0..64).map(|k| (k * 5) % 64).collect();
        for tau in [0.6f32, 0.3, 0.12] {
            let a = pjrt.sss_step(shape, &w, &ds.rows, &inv, tau, 0.5).unwrap();
            let b = native.sss_step(shape, &w, &ds.rows, &inv, tau, 0.5).unwrap();
            assert_close(&[a.loss], &[b.loss], 1e-4, "loss");
            assert_close(&a.grad, &b.grad, 1e-4, "grad");
            assert_close(&a.colsum, &b.colsum, 1e-4, "colsum");
            assert_close(&a.y, &b.y, 1e-4, "y");
            assert_eq!(a.sort_idx, b.sort_idx, "sort_idx at tau={tau}");
        }
    }

    #[test]
    fn native_backend_matches_pjrt_gs_step_and_probe() {
        let pjrt = require_backend!();
        let native = NativeBackend::default();
        let shape = StepShape::new(GridShape::new(8, 8), 3);
        let ds = random_colors(64, 10);
        let logits: Vec<f32> = (0..64 * 64)
            .map(|i| (((i * 2654435761usize) % 1000) as f32 / 1000.0 - 0.5) * 0.2)
            .collect();
        let gumbel = vec![0.0f32; 64 * 64];
        let a = pjrt.gs_step(shape, &logits, &ds.rows, &gumbel, 0.5, 0.5).unwrap();
        let b = native.gs_step(shape, &logits, &ds.rows, &gumbel, 0.5, 0.5).unwrap();
        assert_close(&[a.loss], &[b.loss], 1e-3, "gs loss");
        assert_close(&a.grad, &b.grad, 1e-3, "gs grad");
        let pa = pjrt.gs_probe(64, &logits, 0.1).unwrap();
        let pb = native.gs_probe(64, &logits, 0.1).unwrap();
        assert_close(&pa, &pb, 1e-3, "gs probe");
    }

    #[test]
    fn native_backend_matches_pjrt_kiss_step() {
        let pjrt = require_backend!();
        let native = NativeBackend::default();
        let shape = StepShape::new(GridShape::new(8, 8), 3);
        let ds = random_colors(64, 11);
        let m = pjrt.kiss_rank(64, 3).unwrap();
        assert_eq!(m, native.kiss_rank(64, 3).unwrap(), "rank rule vs manifest");
        let v: Vec<f32> = (0..64 * m)
            .map(|i| (((i * 1103515245usize) % 1000) as f32 / 1000.0 - 0.5))
            .collect();
        let wf: Vec<f32> = (0..64 * m)
            .map(|i| (((i * 69069usize + 7) % 1000) as f32 / 1000.0 - 0.5))
            .collect();
        let a = pjrt.kiss_step(shape, m, &v, &wf, &ds.rows, 1.0, 0.5).unwrap();
        let b = native.kiss_step(shape, m, &v, &wf, &ds.rows, 1.0, 0.5).unwrap();
        assert_close(&[a.loss], &[b.loss], 1e-3, "kiss loss");
        assert_close(&a.grad_v, &b.grad_v, 1e-3, "kiss grad_v");
        assert_close(&a.grad_w, &b.grad_w, 1e-3, "kiss grad_w");
        assert_eq!(a.sort_idx, b.sort_idx);
    }
}
