//! Integration tests across runtime + coordinator + substrates.
//!
//! Exercise the AOT artifacts from `make artifacts`; each test skips
//! itself (with a note) when the artifacts are absent, so `cargo test`
//! stays green on a fresh checkout / artifact-less CI while still running
//! the full suite locally. Small-N shapes keep the whole suite under a
//! couple of minutes on one core.

use shufflesort::config::{BaselineConfig, ShuffleSoftSortConfig};
use shufflesort::coordinator::baselines::{
    GumbelSinkhornDriver, KissingDriver, SoftSortDriver,
};
use shufflesort::coordinator::ShuffleSoftSort;
use shufflesort::data::{fig3_colors, random_colors};
use shufflesort::grid::GridShape;
use shufflesort::metrics::{dpq16, mean_neighbor_distance};
use shufflesort::runtime::{Arg, Runtime};

/// Load the artifacts, or `None` (→ skip) when `make artifacts` hasn't run.
fn try_rt() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::from_manifest(dir).expect("manifest present but runtime failed to load"))
}

macro_rules! require_rt {
    () => {
        match try_rt() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn small_cfg() -> ShuffleSoftSortConfig {
    let mut cfg = ShuffleSoftSortConfig::for_grid(8, 8);
    cfg.phases = 768;
    cfg
}

#[test]
fn manifest_covers_every_runtime_lookup_used_by_benches() {
    let rt = require_rt!();
    rt.sss_step(64, 3, 8).unwrap();
    rt.sss_step(16, 3, 1).unwrap();
    rt.gs_step(64, 3, 8).unwrap();
    rt.gs_probe(64).unwrap();
    rt.kiss_step(64, 8, 3).unwrap();
    assert!(rt.load("no_such_artifact").is_err());
}

#[test]
fn step_artifact_outputs_match_manifest_shapes() {
    let rt = require_rt!();
    let exe = rt.sss_step(64, 3, 8).unwrap();
    let w: Vec<f32> = (0..64).map(|i| (64 - i) as f32).collect();
    let x: Vec<f32> = (0..64 * 3).map(|i| (i as f32 * 0.37).fract()).collect();
    let inv: Vec<i32> = (0..64).collect();
    let out = exe
        .run(&[Arg::F32(&w), Arg::F32(&x), Arg::I32(&inv), Arg::ScalarF32(0.3), Arg::ScalarF32(0.5)])
        .unwrap();
    assert_eq!(out.len(), 5);
    assert_eq!(out[0].as_f32().len(), 1); // loss scalar
    assert_eq!(out[1].as_f32().len(), 64); // grad
    assert_eq!(out[2].as_i32().len(), 64); // sort_idx
    assert_eq!(out[3].as_f32().len(), 64); // colsum
    assert_eq!(out[4].as_f32().len(), 64 * 3); // y
    assert!(out[0].scalar_f32().is_finite());
    // Order-preserving init at sharp tau ⇒ identity sort_idx.
    let idx = out[2].as_i32();
    assert!(idx.iter().enumerate().all(|(i, &v)| v as usize == i));
    // colsum of a near-permutation ≈ 1.
    for &c in out[3].as_f32() {
        assert!((c - 1.0).abs() < 0.2, "colsum {c}");
    }
}

#[test]
fn artifact_rejects_wrong_arity_and_shapes() {
    let rt = require_rt!();
    let exe = rt.sss_step(64, 3, 8).unwrap();
    let w = vec![0.0f32; 64];
    assert!(exe.run(&[Arg::F32(&w)]).is_err());
    let bad_x = vec![0.0f32; 10];
    let inv: Vec<i32> = (0..64).collect();
    assert!(exe
        .run(&[Arg::F32(&w), Arg::F32(&bad_x), Arg::I32(&inv), Arg::ScalarF32(0.3), Arg::ScalarF32(0.5)])
        .is_err());
}

#[test]
fn shuffle_softsort_improves_over_random_and_softsort() {
    let rt = require_rt!();
    let ds = random_colors(64, 42);
    let g = GridShape::new(8, 8);
    let before = dpq16(&ds.rows, 3, g);

    let out = ShuffleSoftSort::new(&rt, small_cfg()).unwrap().sort(&ds).unwrap();
    assert!(out.report.final_dpq > before + 0.3, "sss {} vs unsorted {before}", out.report.final_dpq);

    let mut ss_cfg = BaselineConfig::for_grid(8, 8);
    ss_cfg.steps = 768 * 4;
    let ss = SoftSortDriver::new(&rt, ss_cfg).sort(&ds).unwrap();
    assert!(
        out.report.final_dpq > ss.report.final_dpq,
        "sss {} must beat plain softsort {}",
        out.report.final_dpq,
        ss.report.final_dpq
    );
    // The returned permutation really produces the returned arrangement.
    assert_eq!(out.perm.apply_rows(&ds.rows, 3), out.arranged);
}

#[test]
fn shuffle_softsort_is_deterministic_per_seed() {
    let rt = require_rt!();
    let ds = random_colors(64, 7);
    let mut cfg = small_cfg();
    cfg.phases = 256;
    let a = ShuffleSoftSort::new(&rt, cfg.clone()).unwrap().sort(&ds).unwrap();
    let b = ShuffleSoftSort::new(&rt, cfg.clone()).unwrap().sort(&ds).unwrap();
    assert_eq!(a.perm, b.perm);
    cfg.seed = 8;
    let c = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&ds).unwrap();
    assert_ne!(a.perm, c.perm);
}

#[test]
fn gumbel_sinkhorn_driver_runs_and_improves() {
    let rt = require_rt!();
    let ds = random_colors(64, 42);
    let g = GridShape::new(8, 8);
    let mut cfg = BaselineConfig::for_gs(8, 8);
    cfg.steps = 512;
    let out = GumbelSinkhornDriver::new(&rt, cfg).sort(&ds).unwrap();
    assert!(out.report.final_dpq > dpq16(&ds.rows, 3, g));
    assert_eq!(out.perm.len(), 64); // JV extraction always valid
}

#[test]
fn kissing_driver_runs_and_reports_validity() {
    let rt = require_rt!();
    let ds = random_colors(64, 42);
    let mut cfg = BaselineConfig::for_grid(8, 8);
    cfg.steps = 256;
    let out = KissingDriver::new(&rt, cfg).sort(&ds).unwrap();
    // Whether valid or repaired, the final permutation must be a bijection
    // and the stability stat must be consistent.
    assert_eq!(out.perm.len(), 64);
    assert_eq!(out.report.repaired == 0, out.report.valid_without_repair);
}

#[test]
fn fig3_toy_shuffle_softsort_beats_softsort() {
    let rt = require_rt!();
    let ds = fig3_colors();
    let g = GridShape::new(1, 16);
    let mut cfg = ShuffleSoftSortConfig::for_grid(1, 16);
    cfg.phases = 512;
    let sss = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&ds).unwrap();
    let mut ss_cfg = BaselineConfig::for_grid(1, 16);
    ss_cfg.steps = 2048;
    let ss = SoftSortDriver::new(&rt, ss_cfg).sort(&ds).unwrap();
    let n_sss = mean_neighbor_distance(&sss.arranged, 3, g);
    let n_ss = mean_neighbor_distance(&ss.arranged, 3, g);
    assert!(n_sss < n_ss + 1e-9, "sss {n_sss} vs softsort {n_ss}");
}

#[test]
fn loss_curve_is_recorded_and_roughly_decreasing() {
    let rt = require_rt!();
    let ds = random_colors(64, 3);
    let mut cfg = small_cfg();
    cfg.phases = 512;
    cfg.record_curve = true;
    let out = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&ds).unwrap();
    assert_eq!(out.report.curve.len(), out.report.steps);
    let k = out.report.curve.len() / 8;
    let head: f64 =
        out.report.curve[..k].iter().map(|p| p.loss).sum::<f64>() / k as f64;
    let tail: f64 =
        out.report.curve[out.report.curve.len() - k..].iter().map(|p| p.loss).sum::<f64>() / k as f64;
    assert!(tail < head, "loss head {head} tail {tail}");
}

#[test]
fn sog_learned_pipeline_beats_shuffled() {
    use shufflesort::api::{overrides, MethodRegistry};
    use shufflesort::sog::codec::CodecConfig;
    use shufflesort::sog::scene::{GaussianScene, SceneConfig};
    use shufflesort::sog::{run_pipeline, SorterKind};

    let rt = require_rt!();
    let scene = GaussianScene::generate(&SceneConfig {
        n_splats: 1024,
        seed: 5,
        ..Default::default()
    });
    let g = GridShape::new(32, 32);
    let codec = CodecConfig::default();
    let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec).unwrap();
    let sss = MethodRegistry::new()
        .build(
            "shuffle-softsort",
            &rt,
            &overrides(&[("phases", "2048"), ("record_curve", "false")]),
        )
        .unwrap();
    let learned = run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &codec).unwrap();
    // The integration budget (2048 phases) is deliberately small — the
    // assertion is directional; the full-quality numbers live in the
    // fig6_sog bench (EXPERIMENTS.md §E6).
    assert!(
        (learned.compressed_bytes as f64) < 0.95 * shuffled.compressed_bytes as f64,
        "learned {} vs shuffled {}",
        learned.compressed_bytes,
        shuffled.compressed_bytes
    );
    assert!(learned.spatial_corr > shuffled.spatial_corr + 0.15);
    assert!((learned.mean_psnr_db - shuffled.mean_psnr_db).abs() < 3.0);
}
