//! End-to-end tests for the serve layer, over real loopback sockets: boot
//! a `Server` on port 0, speak raw HTTP/1.1 from client threads, and check
//! the contract the ISSUE pins down — JSON 4xx bodies for malformed
//! input, bit-identical cache replays with zero extra Engine work, and
//! concurrent-client results identical to sequential `Engine::sort`.
//!
//! Everything runs on the native backend: no artifacts, no `pjrt` feature
//! needed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use shufflesort::api::{BackendChoice, Engine};
use shufflesort::config::ServeConfig;
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::serve::{self, json::Json, EngineSpec, Server};

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        cache_mb: 8,
        queue_depth: 64,
        max_body_bytes: 1 << 20,
        keep_alive_secs: 2,
        ..Default::default()
    }
}

fn start_server_with(cfg: ServeConfig) -> Server {
    let spec = EngineSpec {
        artifacts_dir: "artifacts".to_string(),
        backend: BackendChoice::Native,
        threads: Some(1),
        batch_workers: Some(2),
        ..Default::default()
    };
    serve::start(cfg, spec).expect("server boots on a free port")
}

fn start_server() -> Server {
    start_server_with(serve_cfg())
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body)
            .unwrap_or_else(|e| panic!("body is not JSON ({e}): {}", self.body))
    }
}

/// Tiny raw-HTTP client; keeps the connection (and its read buffer) so
/// keep-alive tests can pipeline requests.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).expect("connect to serve");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        Client { writer: s.try_clone().unwrap(), reader: BufReader::new(s) }
    }

    fn request(&mut self, method: &str, path: &str, body: &str, close: bool) -> Resp {
        self.request_with_headers(method, path, body, close, &[])
    }

    fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
        close: bool,
        extra: &[(&str, &str)],
    ) -> Resp {
        let conn = if close { "close" } else { "keep-alive" };
        let mut raw = format!("{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: {conn}\r\n");
        for (k, v) in extra {
            raw.push_str(&format!("{k}: {v}\r\n"));
        }
        raw.push_str(&format!("Content-Length: {}\r\n\r\n{body}", body.len()));
        self.writer.write_all(raw.as_bytes()).unwrap();
        self.read_response()
    }

    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line: {line:?}"))
            .parse()
            .unwrap();
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h.split_once(':').unwrap();
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        let chunked = headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
        let body = if chunked {
            // Dechunk: hex size line, payload, CRLF — until the 0 chunk.
            let mut out = Vec::new();
            loop {
                let mut size_line = String::new();
                self.reader.read_line(&mut size_line).unwrap();
                let size = usize::from_str_radix(size_line.trim(), 16)
                    .unwrap_or_else(|_| panic!("bad chunk size line: {size_line:?}"));
                if size == 0 {
                    let mut crlf = [0u8; 2];
                    self.reader.read_exact(&mut crlf).unwrap();
                    assert_eq!(&crlf, b"\r\n", "terminator chunk ends with CRLF");
                    break;
                }
                let mut chunk = vec![0u8; size];
                self.reader.read_exact(&mut chunk).unwrap();
                out.extend_from_slice(&chunk);
                let mut crlf = [0u8; 2];
                self.reader.read_exact(&mut crlf).unwrap();
                assert_eq!(&crlf, b"\r\n", "chunk payload ends with CRLF");
            }
            out
        } else {
            let len: usize = headers
                .iter()
                .find(|(k, _)| k == "content-length")
                .map(|(_, v)| v.parse().unwrap())
                .unwrap_or(0);
            let mut body = vec![0u8; len];
            self.reader.read_exact(&mut body).unwrap();
            body
        };
        Resp { status, headers, body: String::from_utf8(body).unwrap() }
    }
}

fn get(addr: SocketAddr, path: &str) -> Resp {
    Client::connect(addr).request("GET", path, "", true)
}

fn post(addr: SocketAddr, path: &str, body: &str) -> Resp {
    Client::connect(addr).request("POST", path, body, true)
}

fn perm_of(body: &Json) -> Vec<u32> {
    body.get("perm")
        .expect("response has perm")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect()
}

/// A local engine configured exactly like the server's engine host.
fn local_engine() -> Engine {
    Engine::builder("artifacts").backend(BackendChoice::Native).threads(1).build()
}

fn sort_body(seed: u64, steps: usize) -> String {
    format!(
        r#"{{"method":"softsort","grid":"4x4","dataset":{{"kind":"colors","n":16,"seed":{seed}}},"overrides":{{"seed":{seed},"steps":{steps}}}}}"#
    )
}

/// Overrides in the server's canonical (sorted-key) order.
fn sort_overrides(seed: u64, steps: usize) -> Vec<(String, String)> {
    vec![("seed".into(), seed.to_string()), ("steps".into(), steps.to_string())]
}

#[test]
fn healthz_methods_and_metrics_render() {
    let server = start_server();
    let addr = server.addr();

    let r = get(addr, "/healthz");
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("status").unwrap().as_str(), Some("ok"));

    let r = get(addr, "/v1/methods");
    assert_eq!(r.status, 200);
    let j = r.json();
    let names: Vec<&str> = j
        .get("methods")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(names.contains(&"shuffle-softsort"), "{names:?}");
    assert!(names.contains(&"flas"), "{names:?}");
    assert_eq!(j.get("default_backend").unwrap().as_str(), Some("native"));

    let r = get(addr, "/metrics");
    assert_eq!(r.status, 200);
    assert!(r.json().get("requests_total").is_some());
    let r = get(addr, "/metrics?format=prometheus");
    assert_eq!(r.status, 200);
    assert!(r.body.contains("sssort_requests_total"), "{}", r.body);

    server.shutdown();
}

#[test]
fn sort_roundtrip_is_bit_identical_to_engine_sort() {
    let server = start_server();
    let addr = server.addr();

    let r = post(addr, "/v1/sort", &sort_body(5, 24));
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("x-cache"), Some("miss"));
    let j = r.json();

    let expected = local_engine()
        .sort("softsort", &random_colors(16, 5), GridShape::new(4, 4), &sort_overrides(5, 24))
        .unwrap();
    assert_eq!(perm_of(&j), expected.perm.as_slice().to_vec());
    // f64s survive the JSON round-trip exactly (shortest-roundtrip repr).
    assert_eq!(j.get("dpq16").unwrap().as_f64(), Some(expected.report.final_dpq));
    assert_eq!(j.get("steps").unwrap().as_usize(), Some(expected.report.steps));
    assert_eq!(j.get("n").unwrap().as_usize(), Some(16));

    // Inline data sorts too, and matches the generated-dataset request
    // when the bytes are the same dataset.
    let ds = random_colors(16, 5);
    let rows: Vec<String> = ds.rows.iter().map(|v| format!("{v}")).collect();
    let body = format!(
        r#"{{"method":"softsort","grid":"4x4","data":{{"rows":[{}],"d":3}},"overrides":{{"seed":5,"steps":24}}}}"#,
        rows.join(",")
    );
    let r2 = post(addr, "/v1/sort", &body);
    assert_eq!(r2.status, 200, "{}", r2.body);
    assert_eq!(perm_of(&r2.json()), expected.perm.as_slice().to_vec());

    server.shutdown();
}

#[test]
fn bad_requests_get_json_4xx_bodies() {
    let server = start_server();
    let addr = server.addr();

    // Malformed JSON → 400 with a JSON error body.
    let r = post(addr, "/v1/sort", "{nope");
    assert_eq!(r.status, 400, "{}", r.body);
    let msg = r.json().get("error").unwrap().get("message").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("malformed JSON"), "{msg}");

    // Unknown method → 404 listing what exists.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"bogus","grid":"4x4","dataset":{"kind":"colors","n":16}}"#,
    );
    assert_eq!(r.status, 404, "{}", r.body);
    assert!(r.body.contains("shuffle-softsort"), "{}", r.body);

    // Grid/dataset mismatch → 400.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":64}}"#,
    );
    assert_eq!(r.status, 400, "{}", r.body);

    // Bad override value → 400 naming the key.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16},"overrides":{"steps":"nope"}}"#,
    );
    assert_eq!(r.status, 400, "{}", r.body);
    assert!(r.body.contains("steps"), "{}", r.body);

    // Unknown route → 404; wrong verb on a real route → 405.
    assert_eq!(get(addr, "/nope").status, 404);
    assert_eq!(get(addr, "/v1/sort").status, 405);

    // Oversized declared body → 413 before the body is read.
    let mut c = Client::connect(addr);
    c.writer
        .write_all(b"POST /v1/sort HTTP/1.1\r\nHost: t\r\nContent-Length: 99999999\r\n\r\n")
        .unwrap();
    let r = c.read_response();
    assert_eq!(r.status, 413, "{}", r.body);
    assert!(r.json().get("error").is_some());

    server.shutdown();
}

#[test]
fn cache_hit_replays_identical_bytes_with_zero_extra_engine_jobs() {
    let server = start_server();
    let addr = server.addr();

    let first = post(addr, "/v1/sort", &sort_body(9, 24));
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(first.header("x-cache"), Some("miss"));

    let jobs_after_first = get(addr, "/metrics")
        .json()
        .get("engine")
        .unwrap()
        .get("jobs")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(jobs_after_first, 1);

    // Same request, different JSON key order and whitespace: still a hit.
    let reordered = r#"{ "overrides": {"steps": 24, "seed": 9}, "grid": "4x4", "dataset": {"seed": 9, "n": 16, "kind": "colors"}, "method": "softsort" }"#;
    let second = post(addr, "/v1/sort", reordered);
    assert_eq!(second.status, 200, "{}", second.body);
    assert_eq!(second.header("x-cache"), Some("hit"));
    assert_eq!(second.body, first.body, "cache replay must be byte-identical");

    let metrics = get(addr, "/metrics").json();
    assert_eq!(metrics.get("cache").unwrap().get("hits").unwrap().as_usize(), Some(1));
    assert_eq!(
        metrics.get("engine").unwrap().get("jobs").unwrap().as_usize(),
        Some(jobs_after_first),
        "a cache hit must not reach the engine"
    );

    server.shutdown();
}

#[test]
fn eight_concurrent_clients_match_sequential_engine_sort() {
    let server = start_server();
    let addr = server.addr();

    let handles: Vec<_> = (0..8u64)
        .map(|seed| {
            std::thread::spawn(move || {
                let r = post(addr, "/v1/sort", &sort_body(seed, 16));
                assert_eq!(r.status, 200, "{}", r.body);
                (seed, perm_of(&r.json()))
            })
        })
        .collect();
    let results: Vec<(u64, Vec<u32>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for (seed, perm) in results {
        let expected = engine
            .sort("softsort", &random_colors(16, seed), g, &sort_overrides(seed, 16))
            .unwrap();
        assert_eq!(
            perm,
            expected.perm.as_slice().to_vec(),
            "seed {seed}: concurrent serve result must equal sequential Engine::sort"
        );
    }

    server.shutdown();
}

#[test]
fn arranged_payload_is_opt_in_with_a_size_threshold() {
    let server = start_server();
    let addr = server.addr();

    // Below the default threshold (4096) the arranged rows ship by default
    // and equal perm-applied input rows.
    let r = post(addr, "/v1/sort", &sort_body(40, 16));
    assert_eq!(r.status, 200, "{}", r.body);
    let j = r.json();
    let arranged = j.get("arranged").expect("default includes arranged").as_arr().unwrap();
    assert_eq!(arranged.len(), 16 * 3);
    let expected = local_engine()
        .sort("softsort", &random_colors(16, 40), GridShape::new(4, 4), &sort_overrides(40, 16))
        .unwrap();
    for (v, want) in arranged.iter().zip(&expected.arranged) {
        assert_eq!(v.as_f64().unwrap() as f32, *want);
    }

    // Explicit false strips it — and caches separately from the default
    // body (the response shape is part of the cache key).
    let body = r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":40},"overrides":{"seed":40,"steps":16},"include_arranged":false}"#;
    let slim = post(addr, "/v1/sort", body);
    assert_eq!(slim.status, 200, "{}", slim.body);
    assert_eq!(slim.header("x-cache"), Some("miss"), "different response shape, new entry");
    assert!(slim.json().get("arranged").is_none(), "{}", slim.body);
    assert!(slim.body.len() < r.body.len());
    // Repeat of each shape replays its own bytes.
    let again = post(addr, "/v1/sort", body);
    assert_eq!(again.header("x-cache"), Some("hit"));
    assert_eq!(again.body, slim.body);

    // A non-boolean flag is a 400 naming the field.
    let bad = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16},"include_arranged":"yes"}"#,
    );
    assert_eq!(bad.status, 400, "{}", bad.body);
    assert!(bad.body.contains("include_arranged"), "{}", bad.body);
    server.shutdown();

    // A server configured with a tiny threshold defaults the payload off
    // (the large-N posture), while an explicit true still opts in.
    let mut cfg = serve_cfg();
    cfg.arranged_max_n = 4;
    let server = start_server_with(cfg);
    let addr = server.addr();
    let r = post(addr, "/v1/sort", &sort_body(41, 16));
    assert_eq!(r.status, 200, "{}", r.body);
    assert!(r.json().get("arranged").is_none(), "{}", r.body);
    let body = r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":41},"overrides":{"seed":41,"steps":16},"include_arranged":true}"#;
    let fat = post(addr, "/v1/sort", body);
    assert_eq!(fat.status, 200, "{}", fat.body);
    assert_eq!(fat.json().get("arranged").unwrap().as_arr().unwrap().len(), 16 * 3);
    server.shutdown();
}

#[test]
fn tile_n_override_sorts_tiled_and_caches_separately_from_full() {
    let server = start_server();
    let addr = server.addr();

    // 8x8 shuffle-softsort with 2-row tiles → 4 tiles per phase.
    let tiled_body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":3},"overrides":{"phases":16,"record_curve":false,"tile_n":16},"include_arranged":false}"#;
    let r = post(addr, "/v1/sort", tiled_body);
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("x-cache"), Some("miss"));
    let j = r.json();
    assert_eq!(j.get("tiles").unwrap().as_usize(), Some(4));
    let perm = perm_of(&j);
    assert_eq!(perm.len(), 64);

    // The same request without the tile override is a distinct cache entry
    // (the canonical overrides differ), served by the full executor.
    let full_body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":3},"overrides":{"phases":16,"record_curve":false},"include_arranged":false}"#;
    let full = post(addr, "/v1/sort", full_body);
    assert_eq!(full.status, 200, "{}", full.body);
    assert_eq!(full.header("x-cache"), Some("miss"));
    assert_eq!(full.json().get("tiles").unwrap().as_usize(), Some(1));

    // Replaying the tiled request is a pure cache hit.
    let again = post(addr, "/v1/sort", tiled_body);
    assert_eq!(again.header("x-cache"), Some("hit"));
    assert_eq!(again.body, r.body);

    // metrics: 2 engine jobs (hit never reached it), 4 + 1 phase tiles.
    let metrics = get(addr, "/metrics").json();
    let engine = metrics.get("engine").unwrap();
    assert_eq!(engine.get("jobs").unwrap().as_usize(), Some(2));
    assert_eq!(engine.get("phase_tiles").unwrap().as_usize(), Some(5));

    server.shutdown();
}

#[test]
fn traced_sort_exposes_the_full_span_tree_and_chrome_export() {
    let server = start_server();
    let addr = server.addr();

    // A tiled shuffle-softsort exercises every layer of the spine:
    // routing, queue, engine job, phases, tiles, step families.
    let body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":11},"overrides":{"phases":8,"record_curve":false,"tile_n":16},"include_arranged":false}"#;
    let r = Client::connect(addr).request_with_headers(
        "POST", "/v1/sort", body, true, &[("X-Trace-Id", "00000000deadbeef")],
    );
    assert_eq!(r.status, 200, "{}", r.body);
    // The trace id is minted server-side: the echoed header is canonical
    // 16-hex but never the client's value, which rides along as a
    // correlation attribute on the request span instead.
    let tid = r.header("x-trace-id").expect("traced server echoes a minted id").to_string();
    assert_eq!(tid.len(), 16, "canonical id form: {tid}");
    assert_ne!(tid, "00000000deadbeef", "client ids never name the trace");
    assert_eq!(
        get(addr, "/v1/trace/00000000deadbeef").status,
        404,
        "the raw client id addresses no trace"
    );

    let t = get(addr, &format!("/v1/trace/{tid}"));
    assert_eq!(t.status, 200, "{}", t.body);
    let j = t.json();
    assert_eq!(j.get("trace_id").unwrap().as_str(), Some(tid.as_str()));
    let spans = j.get("spans").unwrap().as_arr().unwrap();
    let names: Vec<&str> =
        spans.iter().map(|s| s.get("name").unwrap().as_str().unwrap()).collect();
    for want in
        ["request", "shard_route", "queue_wait", "engine_job", "phase", "tile", "sss_step", "adam_step"]
    {
        assert!(names.contains(&want), "span tree misses '{want}': {names:?}");
    }
    // The client's X-Trace-Id landed as the correlation attribute.
    let request_span = spans
        .iter()
        .find(|s| s.get("name").unwrap().as_str() == Some("request"))
        .unwrap();
    assert_eq!(
        request_span.get("attrs").unwrap().get("client_trace_id").unwrap().as_f64(),
        Some(0x00000000deadbeefu64 as f64),
        "client id recorded as an attribute"
    );
    // Parent links are internally consistent: exactly one root, and every
    // child's parent id is a span of this same trace.
    let ids: Vec<f64> =
        spans.iter().map(|s| s.get("id").unwrap().as_f64().unwrap()).collect();
    let mut roots = 0usize;
    for s in spans {
        let parent = s.get("parent").unwrap().as_f64().unwrap();
        if parent == 0.0 {
            roots += 1;
        } else {
            assert!(
                ids.contains(&parent),
                "span {:?} has a dangling parent {parent}",
                s.get("name")
            );
        }
    }
    assert_eq!(roots, 1, "exactly one root (the request span)");

    // The same trace renders as Chrome trace-event JSON for
    // chrome://tracing / Perfetto.
    let c = get(addr, &format!("/v1/trace/{tid}?format=chrome"));
    assert_eq!(c.status, 200, "{}", c.body);
    let events = c.json().get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), spans.len());
    assert!(events.iter().all(|e| e.get("ph").unwrap().as_str() == Some("X")));

    // Convergence telemetry landed on /metrics: span histograms observed
    // phases and tiles, and the step-family totals counted sss steps.
    let m = get(addr, "/metrics").json();
    let span_hists = m.get("spans").expect("metrics carry span histograms");
    assert!(
        span_hists.get("phase_exec").unwrap().get("count").unwrap().as_usize().unwrap() >= 1
    );
    assert!(
        span_hists.get("tile_exec").unwrap().get("count").unwrap().as_usize().unwrap() >= 1
    );
    assert!(
        span_hists.get("queue_wait").unwrap().get("count").unwrap().as_usize().unwrap() >= 1
    );
    let fams = m.get("step_families").unwrap();
    assert!(fams.get("sss_step").unwrap().get("steps").unwrap().as_usize().unwrap() >= 1);

    // Endpoint error contract: bad hex → 400, unknown id → 404, wrong
    // verb → 405.
    assert_eq!(get(addr, "/v1/trace/zzzz").status, 400);
    assert_eq!(get(addr, "/v1/trace/123abc").status, 404);
    assert_eq!(post(addr, "/v1/trace/123abc", "").status, 405);

    server.shutdown();
}

#[test]
fn trace_off_server_matches_traced_bodies_and_hides_the_endpoint() {
    // Same request on a traced and an untraced server: the bodies must be
    // byte-identical (tracing is observability, never behavior).
    let server_on = start_server();
    let traced = Client::connect(server_on.addr()).request_with_headers(
        "POST", "/v1/sort", &sort_body(21, 24), true, &[("X-Trace-Id", "feedc0de")],
    );
    assert_eq!(traced.status, 200, "{}", traced.body);
    // The echo is a server-minted canonical 16-hex id, never the client's.
    let minted = traced.header("x-trace-id").expect("traced servers echo an id");
    assert_eq!(minted.len(), 16, "canonical id form: {minted}");
    assert_ne!(minted, "00000000feedc0de");
    server_on.shutdown();

    let mut cfg = serve_cfg();
    cfg.trace = false;
    let server_off = start_server_with(cfg);
    let addr = server_off.addr();
    let plain = Client::connect(addr).request_with_headers(
        "POST", "/v1/sort", &sort_body(21, 24), true, &[("X-Trace-Id", "feedc0de")],
    );
    assert_eq!(plain.status, 200, "{}", plain.body);
    assert_eq!(plain.header("x-trace-id"), None, "untraced servers do not echo the id");
    assert_eq!(plain.body, traced.body, "tracing never changes response bytes");
    assert_eq!(get(addr, "/v1/trace/feedc0de").status, 404, "endpoint is off with trace=off");
    server_off.shutdown();
}

#[test]
fn reused_client_trace_ids_get_distinct_traces() {
    // Two requests sending the SAME X-Trace-Id must land in two distinct
    // traces: the server mints per-request ids, so one request can never
    // merge into (or overwrite) another's span tree.
    let server = start_server();
    let addr = server.addr();
    let headers = &[("X-Trace-Id", "cafe")];
    let a = Client::connect(addr).request_with_headers(
        "POST", "/v1/sort", &sort_body(31, 16), true, headers,
    );
    let b = Client::connect(addr).request_with_headers(
        "POST", "/v1/sort", &sort_body(32, 16), true, headers,
    );
    assert_eq!(a.status, 200, "{}", a.body);
    assert_eq!(b.status, 200, "{}", b.body);
    let ta = a.header("x-trace-id").unwrap().to_string();
    let tb = b.header("x-trace-id").unwrap().to_string();
    assert_ne!(ta, tb, "each request gets its own trace id");
    for tid in [&ta, &tb] {
        let t = get(addr, &format!("/v1/trace/{tid}"));
        assert_eq!(t.status, 200, "{}", t.body);
        let j = t.json();
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        let roots = spans
            .iter()
            .filter(|s| s.get("parent").unwrap().as_f64() == Some(0.0))
            .count();
        assert_eq!(roots, 1, "one request span per trace, never merged");
    }
    assert_eq!(get(addr, "/v1/trace/cafe").status, 404);
    server.shutdown();
}

#[test]
fn include_report_adds_run_telemetry_and_caches_separately() {
    let server = start_server();
    let addr = server.addr();

    let plain_body = r#"{"method":"shuffle-softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":6},"overrides":{"phases":8,"record_curve":false},"include_arranged":false}"#;
    let with_report = r#"{"method":"shuffle-softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":6},"overrides":{"phases":8,"record_curve":false},"include_arranged":false,"include_report":true}"#;

    let plain = post(addr, "/v1/sort", plain_body);
    assert_eq!(plain.status, 200, "{}", plain.body);
    assert!(plain.json().get("report").is_none(), "report is opt-in: {}", plain.body);

    // Same sort with the report: a distinct cache entry (response shape is
    // part of the key) carrying the convergence counters.
    let r = post(addr, "/v1/sort", with_report);
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("x-cache"), Some("miss"));
    let j = r.json();
    let report = j.get("report").expect("include_report adds the report object");
    assert!(report.get("wall_secs").unwrap().as_f64().unwrap() >= 0.0);
    assert!(report.get("rejected_phases").unwrap().as_usize().is_some());
    assert!(report.get("extensions").unwrap().as_usize().is_some());
    assert_eq!(report.get("tiles").unwrap().as_usize(), Some(1));
    assert_eq!(report.get("tile_plan").unwrap().as_str(), Some("full"));
    assert_eq!(
        report.get("notes").unwrap().as_arr().map(|a| a.len()),
        Some(0),
        "no config adjustments on a plain full-executor sort: {}",
        r.body
    );
    // The rest of the body is unchanged by the rider.
    assert_eq!(perm_of(&j), perm_of(&plain.json()));

    // Replay is a byte-identical cache hit.
    let again = post(addr, "/v1/sort", with_report);
    assert_eq!(again.header("x-cache"), Some("hit"));
    assert_eq!(again.body, r.body);

    // Non-boolean flag → 400 naming the field.
    let bad = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16},"include_report":"yes"}"#,
    );
    assert_eq!(bad.status, 400, "{}", bad.body);
    assert!(bad.body.contains("include_report"), "{}", bad.body);

    server.shutdown();
}

#[test]
fn keep_alive_serves_multiple_requests_on_one_connection() {
    let server = start_server();
    let addr = server.addr();

    let mut c = Client::connect(addr);
    let r1 = c.request("GET", "/healthz", "", false);
    assert_eq!(r1.status, 200);
    assert_eq!(r1.header("connection"), Some("keep-alive"));
    let r2 = c.request("POST", "/v1/sort", &sort_body(3, 16), false);
    assert_eq!(r2.status, 200, "{}", r2.body);
    let r3 = c.request("GET", "/metrics", "", true);
    assert_eq!(r3.status, 200);
    assert_eq!(r3.header("connection"), Some("close"));

    server.shutdown();
}

#[test]
fn sort_batch_fans_out_and_shares_the_cache_with_single_sorts() {
    let server = start_server();
    let addr = server.addr();

    // Warm one of the two items through the single-sort path.
    let warm = post(addr, "/v1/sort", &sort_body(100, 16));
    assert_eq!(warm.status, 200, "{}", warm.body);

    let batch_body = r#"{"method":"softsort","grid":"4x4","overrides":{"seed":100,"steps":16},"datasets":[{"dataset":{"kind":"colors","n":16,"seed":100}},{"dataset":{"kind":"colors","n":16,"seed":101}}]}"#;
    // Item 0 is the warmed request — but its overrides there included
    // seed=100 too, so the canonical config matches and it must hit.
    let first = post(addr, "/v1/sort_batch", batch_body);
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(first.header("x-cache"), Some("hits=1 misses=1"));
    let j = first.json();
    assert_eq!(j.get("count").unwrap().as_usize(), Some(2));
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);

    // Batch results equal sequential engine sorts, item by item.
    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for (i, seed) in [100u64, 101].iter().enumerate() {
        let expected = engine
            .sort("softsort", &random_colors(16, *seed), g, &sort_overrides(100, 16))
            .unwrap();
        assert_eq!(
            perm_of(&results[i]),
            expected.perm.as_slice().to_vec(),
            "batch item {i}"
        );
    }

    // Re-running the whole batch is now pure cache replay.
    let second = post(addr, "/v1/sort_batch", batch_body);
    assert_eq!(second.header("x-cache"), Some("hits=2 misses=0"));
    assert_eq!(second.body, first.body);

    server.shutdown();
}

// ---------------------------------------------------------------------------
// Sharded serve plane: affinity routing, panic isolation, persistence,
// streaming, rate limiting, auth.
// ---------------------------------------------------------------------------

/// A unique temp path per test invocation (std-only; no tempfile crate).
fn temp_path(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "sssort-e2e-{tag}-{}-{}.spill",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

#[test]
fn two_shards_split_concurrent_clients_and_stay_bit_identical() {
    let mut cfg = serve_cfg();
    cfg.shards = 2;
    let server = start_server_with(cfg);
    let addr = server.addr();
    assert_eq!(server.shard_count(), 2);

    let handles: Vec<_> = (0..8u64)
        .map(|seed| {
            std::thread::spawn(move || {
                let r = post(addr, "/v1/sort", &sort_body(seed, 16));
                assert_eq!(r.status, 200, "{}", r.body);
                (seed, perm_of(&r.json()))
            })
        })
        .collect();
    let results: Vec<(u64, Vec<u32>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Sharding never changes bytes: every result equals sequential
    // Engine::sort.
    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for (seed, perm) in results {
        let expected = engine
            .sort("softsort", &random_colors(16, seed), g, &sort_overrides(seed, 16))
            .unwrap();
        assert_eq!(perm, expected.perm.as_slice().to_vec(), "seed {seed}");
    }

    // The affinity hash spreads these 8 request shapes 4/4 across the two
    // shards (deterministic: hash of method + canonical config + grid),
    // and each shard warmed at least one step session.
    let metrics = get(addr, "/metrics").json();
    assert_eq!(
        metrics.get("engine").unwrap().get("jobs").unwrap().as_usize(),
        Some(8),
        "all 8 sorts were engine-executed"
    );
    let shards = metrics.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 2);
    for s in shards {
        assert_eq!(s.get("alive").unwrap().as_bool(), Some(true));
        assert_eq!(
            s.get("jobs").unwrap().as_usize(),
            Some(4),
            "affinity hash splits seeds 0..8 evenly on 2 shards"
        );
        assert!(
            s.get("session_memo_entries").unwrap().as_usize().unwrap() >= 1,
            "each shard keeps a warm step session"
        );
    }
    // Uncontended sub-queues: nothing needed to steal.
    assert_eq!(
        metrics.get("engine").unwrap().get("shard_steals").unwrap().as_usize(),
        Some(0)
    );

    server.shutdown();
}

#[test]
fn killing_a_shard_degrades_capacity_but_not_availability() {
    let mut cfg = serve_cfg();
    cfg.shards = 2;
    let server = start_server_with(cfg);
    let addr = server.addr();

    // Warm both shards (seed 1 homes to shard 0, seed 0 to shard 1).
    assert_eq!(post(addr, "/v1/sort", &sort_body(1, 16)).status, 200);
    assert_eq!(post(addr, "/v1/sort", &sort_body(0, 16)).status, 200);

    server.kill_shard(0);

    // Seed 3 homes to the dead shard 0 → steals to shard 1; seed 2 homes
    // to shard 1 directly. Both still answer, bit-identical to the engine.
    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for seed in [3u64, 2] {
        let r = post(addr, "/v1/sort", &sort_body(seed, 16));
        assert_eq!(r.status, 200, "seed {seed} after shard kill: {}", r.body);
        let expected = engine
            .sort("softsort", &random_colors(16, seed), g, &sort_overrides(seed, 16))
            .unwrap();
        assert_eq!(perm_of(&r.json()), expected.perm.as_slice().to_vec(), "seed {seed}");
    }

    let health = get(addr, "/healthz").json();
    assert_eq!(health.get("status").unwrap().as_str(), Some("degraded"));
    assert_eq!(health.get("shards").unwrap().as_usize(), Some(2));
    assert_eq!(health.get("shards_alive").unwrap().as_usize(), Some(1));

    let metrics = get(addr, "/metrics").json();
    let shards = metrics.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards[0].get("alive").unwrap().as_bool(), Some(false));
    assert_eq!(shards[1].get("alive").unwrap().as_bool(), Some(true));
    assert!(
        metrics.get("engine").unwrap().get("shard_steals").unwrap().as_usize().unwrap() >= 1,
        "the dead shard's traffic was stolen"
    );

    server.shutdown();
}

#[test]
fn cache_file_survives_a_restart_and_replays_identical_bytes() {
    let spill = temp_path("restart");
    let mut cfg = serve_cfg();
    cfg.cache_file = Some(spill.to_string_lossy().into_owned());

    // First server: a miss computes and spills.
    let server = start_server_with(cfg.clone());
    let addr = server.addr();
    let first = post(addr, "/v1/sort", &sort_body(9, 24));
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(first.header("x-cache"), Some("miss"));
    let persisted = get(addr, "/metrics").json();
    assert!(
        persisted.get("cache_persist").unwrap().get("appends").unwrap().as_usize().unwrap() >= 1,
        "the miss was appended to the spill file"
    );
    server.shutdown();

    // Second server, same spill file: the very first request is a hit with
    // byte-identical body and zero engine work.
    let server = start_server_with(cfg);
    let addr = server.addr();
    let replayed = post(addr, "/v1/sort", &sort_body(9, 24));
    assert_eq!(replayed.status, 200, "{}", replayed.body);
    assert_eq!(replayed.header("x-cache"), Some("hit"), "first post-restart request hits");
    assert_eq!(replayed.body, first.body, "replayed body is byte-identical");

    let metrics = get(addr, "/metrics").json();
    assert_eq!(
        metrics.get("engine").unwrap().get("jobs").unwrap().as_usize(),
        Some(0),
        "the restarted server never touched its engine"
    );
    assert!(
        metrics.get("cache_persist").unwrap().get("replayed").unwrap().as_usize().unwrap() >= 1,
        "boot replayed the spill file"
    );
    server.shutdown();
    let _ = std::fs::remove_file(&spill);
}

#[test]
fn large_arranged_responses_stream_chunked_and_match_buffered_bytes() {
    // stream_min_n below this grid's N=16 → the arranged response streams.
    let mut cfg = serve_cfg();
    cfg.stream_min_n = 8;
    let streaming = start_server_with(cfg);
    let r = post(streaming.addr(), "/v1/sort", &sort_body(7, 16));
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("transfer-encoding"), Some("chunked"));
    assert_eq!(r.header("content-length"), None, "streamed responses have no length");
    assert_eq!(r.header("x-cache"), Some("bypass"), "streamed bodies skip the cache");
    streaming.shutdown();

    // A default server buffers the same request; the bytes must match.
    let buffered_server = start_server();
    let buffered = post(buffered_server.addr(), "/v1/sort", &sort_body(7, 16));
    assert_eq!(buffered.status, 200, "{}", buffered.body);
    assert_eq!(buffered.header("transfer-encoding"), None);
    assert_eq!(
        r.body, buffered.body,
        "chunked and buffered paths must produce identical JSON bytes"
    );
    assert!(r.json().get("arranged").is_some());
    buffered_server.shutdown();
}

#[test]
fn rate_limit_answers_429_but_spares_healthz() {
    let mut cfg = serve_cfg();
    cfg.rate_limit = 1; // burst 2
    let server = start_server_with(cfg);
    let addr = server.addr();

    let mut ok = 0usize;
    let mut throttled = 0usize;
    for _ in 0..5 {
        let r = get(addr, "/v1/methods");
        match r.status {
            200 => ok += 1,
            429 => {
                throttled += 1;
                let msg = r.json().get("error").unwrap().get("message").unwrap()
                    .as_str().unwrap().to_string();
                assert!(msg.contains("rate limit"), "{msg}");
            }
            other => panic!("unexpected status {other}: {}", r.body),
        }
    }
    assert!(ok >= 1, "the burst admits the first requests");
    assert!(throttled >= 1, "5 rapid requests at 1/s must trip the limiter");

    // /healthz is exempt — probes keep working mid-throttle.
    assert_eq!(get(addr, "/healthz").status, 200);

    // After a refill interval the same client is admitted again, so the
    // metrics scrape itself is not throttled.
    std::thread::sleep(Duration::from_millis(2600));
    let r = get(addr, "/metrics");
    assert_eq!(r.status, 200, "{}", r.body);
    assert!(
        r.json().get("listener").unwrap().get("rate_limited").unwrap().as_usize().unwrap()
            >= throttled,
        "throttles are counted"
    );

    server.shutdown();
}

#[test]
fn bearer_auth_guards_everything_but_healthz() {
    let mut cfg = serve_cfg();
    cfg.auth_token = Some("secret-tok".to_string());
    let server = start_server_with(cfg);
    let addr = server.addr();

    // Probes stay open.
    assert_eq!(get(addr, "/healthz").status, 200);

    // No header → 401 with the expected scheme advertised.
    let r = get(addr, "/v1/methods");
    assert_eq!(r.status, 401, "{}", r.body);
    assert_eq!(r.header("www-authenticate"), Some("Bearer"));
    assert!(r.json().get("error").is_some());

    // Wrong token → 401; right token → 200.
    let r = Client::connect(addr).request_with_headers(
        "GET", "/v1/methods", "", true, &[("Authorization", "Bearer wrong")],
    );
    assert_eq!(r.status, 401, "{}", r.body);
    let r = Client::connect(addr).request_with_headers(
        "GET", "/v1/methods", "", true, &[("Authorization", "Bearer secret-tok")],
    );
    assert_eq!(r.status, 200, "{}", r.body);

    // Sorts work with credentials too, and the failures were counted.
    let r = Client::connect(addr).request_with_headers(
        "POST", "/v1/sort", &sort_body(2, 16), true,
        &[("Authorization", "Bearer secret-tok")],
    );
    assert_eq!(r.status, 200, "{}", r.body);
    let m = Client::connect(addr).request_with_headers(
        "GET", "/metrics", "", true, &[("Authorization", "Bearer secret-tok")],
    );
    assert_eq!(
        m.json().get("listener").unwrap().get("auth_failures").unwrap().as_usize(),
        Some(2)
    );

    server.shutdown();
}

#[test]
fn tail_sampling_keeps_slow_requests_the_head_sampler_would_drop() {
    // A sparse head rate with a tail threshold: fast requests past the
    // head window leave no trace at all, while a slow sort is kept even
    // though the head counter skipped it.
    let mut cfg = serve_cfg();
    cfg.trace_sample = 1_000_000; // head-samples only request 0
    cfg.trace_tail_ms = 15;
    let server = start_server_with(cfg);
    let addr = server.addr();

    // Request 0 is the head sampler's; burn it on a trivial GET.
    let r = get(addr, "/v1/methods");
    assert!(r.header("x-trace-id").is_some(), "request 0 is head-sampled");

    // A fast request past the head window: traced speculatively, then
    // discarded below the threshold — no id minted for the client.
    let fast = get(addr, "/v1/methods");
    assert_eq!(fast.header("x-trace-id"), None, "fast request is tail-dropped");

    // A heavy sort runs well past 15 ms: the tail sampler keeps it, the
    // trace is retrievable and complete, and the keep is counted.
    let body = r#"{"method":"shuffle-softsort","grid":"16x16","dataset":{"kind":"colors","n":256,"seed":3},"overrides":{"phases":512,"record_curve":false},"include_arranged":false}"#;
    let slow = post(addr, "/v1/sort", body);
    assert_eq!(slow.status, 200, "{}", slow.body);
    let tid = slow
        .header("x-trace-id")
        .expect("slow request kept by tail sampling")
        .to_string();
    let t = get(addr, &format!("/v1/trace/{tid}"));
    assert_eq!(t.status, 200, "{}", t.body);
    assert!(t.body.contains("engine_job"), "tail-kept trace is complete: {}", t.body);

    let m = get(addr, "/metrics").json();
    assert_eq!(
        m.get("trace").unwrap().get("tail_kept").unwrap().as_usize(),
        Some(1),
        "{m:?}"
    );

    // Boot config is visible on /healthz.
    let h = get(addr, "/healthz").json();
    assert_eq!(h.get("trace_tail_ms").unwrap().as_usize(), Some(15));

    server.shutdown();
}

#[test]
fn head_sampling_traces_exactly_one_in_k_requests() {
    let mut cfg = serve_cfg();
    cfg.trace_sample = 3;
    let server = start_server_with(cfg);
    let addr = server.addr();

    // Six sequential sorts with distinct seeds (all engine jobs). The
    // deterministic counter samples requests 0 and 3 — exactly ceil(6/3).
    let mut trace_ids: Vec<Option<String>> = Vec::new();
    for i in 0..6u64 {
        let r = post(addr, "/v1/sort", &sort_body(60 + i, 16));
        assert_eq!(r.status, 200, "{}", r.body);
        trace_ids.push(r.header("x-trace-id").map(str::to_string));
    }
    let minted: Vec<(usize, String)> = trace_ids
        .iter()
        .enumerate()
        .filter_map(|(i, t)| t.clone().map(|t| (i, t)))
        .collect();
    assert_eq!(
        minted.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
        vec![0, 3],
        "1-in-3 sampling traces requests 0 and 3 of 6: {trace_ids:?}"
    );

    // Each sampled request's trace is retrievable and complete.
    for (_, tid) in &minted {
        let t = get(addr, &format!("/v1/trace/{tid}"));
        assert_eq!(t.status, 200, "{}", t.body);
        let tj = t.json();
        let names: Vec<String> = tj
            .get("spans")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        for want in ["request", "engine_job"] {
            assert!(names.iter().any(|n| n == want), "missing {want}: {names:?}");
        }
    }

    // The profile accumulated exactly the two sampled sorts: sampled GETs
    // fold bare `request` paths, only sorts reach `request;engine_job`.
    let p = get(addr, "/v1/profile");
    assert_eq!(p.status, 200, "{}", p.body);
    let pj = p.json();
    let stacks = pj.get("stacks").unwrap().as_arr().unwrap();
    let engine_stack = stacks
        .iter()
        .find(|s| s.get("stack").and_then(Json::as_str) == Some("request;engine_job"))
        .expect("sampled sorts folded into the profile");
    assert_eq!(engine_stack.get("count").unwrap().as_usize(), Some(2));

    server.shutdown();
}

#[test]
fn sampling_is_results_neutral_and_gates_the_observability_routes() {
    // The same sort body across sample rates 0 (off), 1 (always) and 3
    // must produce byte-identical response bodies: sampling is pure
    // observability.
    let mut bodies: Vec<String> = Vec::new();
    for k in [0u64, 1, 3] {
        let mut cfg = serve_cfg();
        cfg.trace_sample = k;
        let server = start_server_with(cfg);
        let addr = server.addr();
        let r = post(addr, "/v1/sort", &sort_body(99, 16));
        assert_eq!(r.status, 200, "{}", r.body);
        if k == 0 {
            assert_eq!(r.header("x-trace-id"), None, "sample=0 never traces");
            assert_eq!(get(addr, "/v1/trace/123abc").status, 404, "trace route gated");
            assert_eq!(get(addr, "/v1/profile").status, 404, "profile route gated");
        } else {
            assert!(r.header("x-trace-id").is_some(), "request 0 is always sampled");
        }
        bodies.push(r.body);
        server.shutdown();
    }
    assert_eq!(bodies[0], bodies[1], "sample=0 vs sample=1");
    assert_eq!(bodies[1], bodies[2], "sample=1 vs sample=3");

    // trace=false gates the same routes regardless of the sample rate.
    let mut cfg = serve_cfg();
    cfg.trace = false;
    let server = start_server_with(cfg);
    assert_eq!(get(server.addr(), "/v1/profile").status, 404);
    server.shutdown();
}

#[test]
fn profile_endpoint_serves_folded_stacks_and_resets_on_demand() {
    let server = start_server(); // trace_sample = 1: every request folds
    let addr = server.addr();

    // A tiled run exercises the full span chain down to the step kernels.
    let body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":13},"overrides":{"phases":8,"record_curve":false,"tile_n":16},"include_arranged":false}"#;
    let r = post(addr, "/v1/sort", body);
    assert_eq!(r.status, 200, "{}", r.body);

    // Folded text: full path chain present, every line is `path weight`.
    let folded = get(addr, "/v1/profile?format=folded");
    assert_eq!(folded.status, 200);
    assert!(
        folded.body.lines().any(|l| l.starts_with("request;engine_job;phase;tile;sss_step ")),
        "folded stacks miss the sampled span chain:\n{}",
        folded.body
    );
    for line in folded.body.lines() {
        let (path, weight) = line.rsplit_once(' ').expect("`path weight` lines");
        assert!(!path.is_empty());
        weight.parse::<u64>().expect("integer self-time weight");
    }

    // JSON projection: the sort plus the folded scrape have been folded.
    let pj = get(addr, "/v1/profile?format=json").json();
    assert!(pj.get("traces").unwrap().as_usize().unwrap() >= 2, "{pj:?}");
    assert!(!pj.get("stacks").unwrap().as_arr().unwrap().is_empty());

    // Unknown format → structured 400.
    let bad = get(addr, "/v1/profile?format=svg");
    assert_eq!(bad.status, 400, "{}", bad.body);
    assert!(bad.body.contains("unknown profile format"), "{}", bad.body);

    // reset=1 renders *before* clearing, so the wiping scrape still shows
    // the stacks; afterwards only freshly-sampled bare GET paths remain.
    let wiped = get(addr, "/v1/profile?format=folded&reset=1");
    assert_eq!(wiped.status, 200);
    assert!(wiped.body.contains("engine_job"), "reset renders before clearing");
    let after = get(addr, "/v1/profile?format=folded");
    assert!(
        !after.body.contains("engine_job"),
        "reset dropped the accumulated stacks:\n{}",
        after.body
    );

    server.shutdown();
}

#[test]
fn healthz_reports_uptime_and_build_info() {
    let server = start_server();
    let j = get(server.addr(), "/healthz").json();
    assert!(j.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(j.get("version").unwrap().as_str(), Some(env!("CARGO_PKG_VERSION")));
    let simd = j.get("simd").unwrap().as_str().unwrap();
    assert!(["scalar", "sse2", "avx2"].contains(&simd), "unknown simd level {simd}");
    assert_eq!(j.get("trace_sample").unwrap().as_usize(), Some(1), "default samples all");
    assert_eq!(j.get("shards_alive").unwrap().as_usize(), Some(1));
    server.shutdown();
}

#[test]
fn metrics_expose_latency_percentiles_and_convergence_windows() {
    let server = start_server();
    let addr = server.addr();
    for seed in [70u64, 71, 72] {
        assert_eq!(post(addr, "/v1/sort", &sort_body(seed, 16)).status, 200);
    }

    let m = get(addr, "/metrics").json();
    // Sliding-window percentiles: queue wait is observed per engine job.
    let qw = m.get("spans").unwrap().get("queue_wait").unwrap();
    for key in ["p50_ms", "p95_ms", "p99_ms"] {
        assert!(qw.get(key).unwrap().as_f64().unwrap() >= 0.0, "{key} missing");
    }
    let lat = m.get("latency").unwrap().get("softsort").unwrap();
    assert_eq!(lat.get("count").unwrap().as_usize(), Some(3));
    assert!(lat.get("p95_ms").unwrap().as_f64().unwrap() > 0.0);
    // Convergence window: the engine hosts fed all three runs.
    let conv = m.get("convergence").unwrap().get("softsort").unwrap();
    assert_eq!(conv.get("runs").unwrap().as_usize(), Some(3));
    assert!(conv.get("mean_loss").unwrap().as_f64().unwrap().is_finite());
    let rej = conv.get("rejected_phase_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rej), "rejected rate {rej} out of range");

    let prom = get(addr, "/metrics?format=prometheus").body;
    assert!(prom.contains("sssort_queue_wait_seconds_window{quantile=\"0.99\"}"), "{prom}");
    assert!(prom.contains("sssort_sort_duration_seconds_window{method=\"softsort\""), "{prom}");
    assert!(prom.contains("sssort_convergence_mean_loss{method=\"softsort\"}"), "{prom}");
    assert!(prom.contains("sssort_convergence_rejected_phase_rate{method=\"softsort\"}"), "{prom}");

    server.shutdown();
}

#[test]
fn trace_keep_knob_exports_capacity_and_eviction_counters() {
    let mut cfg = serve_cfg();
    // Enlarging the shared LRU is safe alongside concurrently-running
    // servers; shrinking it could evict their still-awaited traces.
    cfg.trace_keep = 200;
    let server = start_server_with(cfg);
    let addr = server.addr();

    let m = get(addr, "/metrics").json();
    let tr = m.get("trace").expect("metrics carry the trace LRU block");
    assert_eq!(tr.get("keep").unwrap().as_usize(), Some(200));
    assert!(tr.get("finished_evictions").unwrap().as_usize().is_some());

    let prom = get(addr, "/metrics?format=prometheus").body;
    assert!(prom.contains("sssort_trace_keep 200"), "{prom}");
    assert!(prom.contains("sssort_trace_finished_evictions_total"), "{prom}");

    server.shutdown();
}
