//! End-to-end tests for the serve layer, over real loopback sockets: boot
//! a `Server` on port 0, speak raw HTTP/1.1 from client threads, and check
//! the contract the ISSUE pins down — JSON 4xx bodies for malformed
//! input, bit-identical cache replays with zero extra Engine work, and
//! concurrent-client results identical to sequential `Engine::sort`.
//!
//! Everything runs on the native backend: no artifacts, no `pjrt` feature
//! needed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use shufflesort::api::{BackendChoice, Engine, MethodRegistry};
use shufflesort::config::ServeConfig;
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::serve::{self, json::Json, EngineSpec, Server};

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 8,
        cache_mb: 8,
        queue_depth: 64,
        max_body_bytes: 1 << 20,
        keep_alive_secs: 2,
        ..Default::default()
    }
}

fn start_server_with(cfg: ServeConfig) -> Server {
    let spec = EngineSpec {
        artifacts_dir: "artifacts".to_string(),
        backend: BackendChoice::Native,
        threads: Some(1),
        batch_workers: Some(2),
        registry: MethodRegistry::new(),
    };
    serve::start(cfg, spec).expect("server boots on a free port")
}

fn start_server() -> Server {
    start_server_with(serve_cfg())
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body)
            .unwrap_or_else(|e| panic!("body is not JSON ({e}): {}", self.body))
    }
}

/// Tiny raw-HTTP client; keeps the connection (and its read buffer) so
/// keep-alive tests can pipeline requests.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).expect("connect to serve");
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        Client { writer: s.try_clone().unwrap(), reader: BufReader::new(s) }
    }

    fn request(&mut self, method: &str, path: &str, body: &str, close: bool) -> Resp {
        let conn = if close { "close" } else { "keep-alive" };
        let raw = format!(
            "{method} {path} HTTP/1.1\r\nHost: test\r\nConnection: {conn}\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.writer.write_all(raw.as_bytes()).unwrap();
        self.read_response()
    }

    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line: {line:?}"))
            .parse()
            .unwrap();
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let (k, v) = h.split_once(':').unwrap();
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse().unwrap())
            .unwrap_or(0);
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).unwrap();
        Resp { status, headers, body: String::from_utf8(body).unwrap() }
    }
}

fn get(addr: SocketAddr, path: &str) -> Resp {
    Client::connect(addr).request("GET", path, "", true)
}

fn post(addr: SocketAddr, path: &str, body: &str) -> Resp {
    Client::connect(addr).request("POST", path, body, true)
}

fn perm_of(body: &Json) -> Vec<u32> {
    body.get("perm")
        .expect("response has perm")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect()
}

/// A local engine configured exactly like the server's engine host.
fn local_engine() -> Engine {
    Engine::builder("artifacts").backend(BackendChoice::Native).threads(1).build()
}

fn sort_body(seed: u64, steps: usize) -> String {
    format!(
        r#"{{"method":"softsort","grid":"4x4","dataset":{{"kind":"colors","n":16,"seed":{seed}}},"overrides":{{"seed":{seed},"steps":{steps}}}}}"#
    )
}

/// Overrides in the server's canonical (sorted-key) order.
fn sort_overrides(seed: u64, steps: usize) -> Vec<(String, String)> {
    vec![("seed".into(), seed.to_string()), ("steps".into(), steps.to_string())]
}

#[test]
fn healthz_methods_and_metrics_render() {
    let server = start_server();
    let addr = server.addr();

    let r = get(addr, "/healthz");
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("status").unwrap().as_str(), Some("ok"));

    let r = get(addr, "/v1/methods");
    assert_eq!(r.status, 200);
    let j = r.json();
    let names: Vec<&str> = j
        .get("methods")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(names.contains(&"shuffle-softsort"), "{names:?}");
    assert!(names.contains(&"flas"), "{names:?}");
    assert_eq!(j.get("default_backend").unwrap().as_str(), Some("native"));

    let r = get(addr, "/metrics");
    assert_eq!(r.status, 200);
    assert!(r.json().get("requests_total").is_some());
    let r = get(addr, "/metrics?format=prometheus");
    assert_eq!(r.status, 200);
    assert!(r.body.contains("sssort_requests_total"), "{}", r.body);

    server.shutdown();
}

#[test]
fn sort_roundtrip_is_bit_identical_to_engine_sort() {
    let server = start_server();
    let addr = server.addr();

    let r = post(addr, "/v1/sort", &sort_body(5, 24));
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("x-cache"), Some("miss"));
    let j = r.json();

    let expected = local_engine()
        .sort("softsort", &random_colors(16, 5), GridShape::new(4, 4), &sort_overrides(5, 24))
        .unwrap();
    assert_eq!(perm_of(&j), expected.perm.as_slice().to_vec());
    // f64s survive the JSON round-trip exactly (shortest-roundtrip repr).
    assert_eq!(j.get("dpq16").unwrap().as_f64(), Some(expected.report.final_dpq));
    assert_eq!(j.get("steps").unwrap().as_usize(), Some(expected.report.steps));
    assert_eq!(j.get("n").unwrap().as_usize(), Some(16));

    // Inline data sorts too, and matches the generated-dataset request
    // when the bytes are the same dataset.
    let ds = random_colors(16, 5);
    let rows: Vec<String> = ds.rows.iter().map(|v| format!("{v}")).collect();
    let body = format!(
        r#"{{"method":"softsort","grid":"4x4","data":{{"rows":[{}],"d":3}},"overrides":{{"seed":5,"steps":24}}}}"#,
        rows.join(",")
    );
    let r2 = post(addr, "/v1/sort", &body);
    assert_eq!(r2.status, 200, "{}", r2.body);
    assert_eq!(perm_of(&r2.json()), expected.perm.as_slice().to_vec());

    server.shutdown();
}

#[test]
fn bad_requests_get_json_4xx_bodies() {
    let server = start_server();
    let addr = server.addr();

    // Malformed JSON → 400 with a JSON error body.
    let r = post(addr, "/v1/sort", "{nope");
    assert_eq!(r.status, 400, "{}", r.body);
    let msg = r.json().get("error").unwrap().get("message").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("malformed JSON"), "{msg}");

    // Unknown method → 404 listing what exists.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"bogus","grid":"4x4","dataset":{"kind":"colors","n":16}}"#,
    );
    assert_eq!(r.status, 404, "{}", r.body);
    assert!(r.body.contains("shuffle-softsort"), "{}", r.body);

    // Grid/dataset mismatch → 400.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":64}}"#,
    );
    assert_eq!(r.status, 400, "{}", r.body);

    // Bad override value → 400 naming the key.
    let r = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16},"overrides":{"steps":"nope"}}"#,
    );
    assert_eq!(r.status, 400, "{}", r.body);
    assert!(r.body.contains("steps"), "{}", r.body);

    // Unknown route → 404; wrong verb on a real route → 405.
    assert_eq!(get(addr, "/nope").status, 404);
    assert_eq!(get(addr, "/v1/sort").status, 405);

    // Oversized declared body → 413 before the body is read.
    let mut c = Client::connect(addr);
    c.writer
        .write_all(b"POST /v1/sort HTTP/1.1\r\nHost: t\r\nContent-Length: 99999999\r\n\r\n")
        .unwrap();
    let r = c.read_response();
    assert_eq!(r.status, 413, "{}", r.body);
    assert!(r.json().get("error").is_some());

    server.shutdown();
}

#[test]
fn cache_hit_replays_identical_bytes_with_zero_extra_engine_jobs() {
    let server = start_server();
    let addr = server.addr();

    let first = post(addr, "/v1/sort", &sort_body(9, 24));
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(first.header("x-cache"), Some("miss"));

    let jobs_after_first = get(addr, "/metrics")
        .json()
        .get("engine")
        .unwrap()
        .get("jobs")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(jobs_after_first, 1);

    // Same request, different JSON key order and whitespace: still a hit.
    let reordered = r#"{ "overrides": {"steps": 24, "seed": 9}, "grid": "4x4", "dataset": {"seed": 9, "n": 16, "kind": "colors"}, "method": "softsort" }"#;
    let second = post(addr, "/v1/sort", reordered);
    assert_eq!(second.status, 200, "{}", second.body);
    assert_eq!(second.header("x-cache"), Some("hit"));
    assert_eq!(second.body, first.body, "cache replay must be byte-identical");

    let metrics = get(addr, "/metrics").json();
    assert_eq!(metrics.get("cache").unwrap().get("hits").unwrap().as_usize(), Some(1));
    assert_eq!(
        metrics.get("engine").unwrap().get("jobs").unwrap().as_usize(),
        Some(jobs_after_first),
        "a cache hit must not reach the engine"
    );

    server.shutdown();
}

#[test]
fn eight_concurrent_clients_match_sequential_engine_sort() {
    let server = start_server();
    let addr = server.addr();

    let handles: Vec<_> = (0..8u64)
        .map(|seed| {
            std::thread::spawn(move || {
                let r = post(addr, "/v1/sort", &sort_body(seed, 16));
                assert_eq!(r.status, 200, "{}", r.body);
                (seed, perm_of(&r.json()))
            })
        })
        .collect();
    let results: Vec<(u64, Vec<u32>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for (seed, perm) in results {
        let expected = engine
            .sort("softsort", &random_colors(16, seed), g, &sort_overrides(seed, 16))
            .unwrap();
        assert_eq!(
            perm,
            expected.perm.as_slice().to_vec(),
            "seed {seed}: concurrent serve result must equal sequential Engine::sort"
        );
    }

    server.shutdown();
}

#[test]
fn arranged_payload_is_opt_in_with_a_size_threshold() {
    let server = start_server();
    let addr = server.addr();

    // Below the default threshold (4096) the arranged rows ship by default
    // and equal perm-applied input rows.
    let r = post(addr, "/v1/sort", &sort_body(40, 16));
    assert_eq!(r.status, 200, "{}", r.body);
    let j = r.json();
    let arranged = j.get("arranged").expect("default includes arranged").as_arr().unwrap();
    assert_eq!(arranged.len(), 16 * 3);
    let expected = local_engine()
        .sort("softsort", &random_colors(16, 40), GridShape::new(4, 4), &sort_overrides(40, 16))
        .unwrap();
    for (v, want) in arranged.iter().zip(&expected.arranged) {
        assert_eq!(v.as_f64().unwrap() as f32, *want);
    }

    // Explicit false strips it — and caches separately from the default
    // body (the response shape is part of the cache key).
    let body = r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":40},"overrides":{"seed":40,"steps":16},"include_arranged":false}"#;
    let slim = post(addr, "/v1/sort", body);
    assert_eq!(slim.status, 200, "{}", slim.body);
    assert_eq!(slim.header("x-cache"), Some("miss"), "different response shape, new entry");
    assert!(slim.json().get("arranged").is_none(), "{}", slim.body);
    assert!(slim.body.len() < r.body.len());
    // Repeat of each shape replays its own bytes.
    let again = post(addr, "/v1/sort", body);
    assert_eq!(again.header("x-cache"), Some("hit"));
    assert_eq!(again.body, slim.body);

    // A non-boolean flag is a 400 naming the field.
    let bad = post(
        addr,
        "/v1/sort",
        r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16},"include_arranged":"yes"}"#,
    );
    assert_eq!(bad.status, 400, "{}", bad.body);
    assert!(bad.body.contains("include_arranged"), "{}", bad.body);
    server.shutdown();

    // A server configured with a tiny threshold defaults the payload off
    // (the large-N posture), while an explicit true still opts in.
    let mut cfg = serve_cfg();
    cfg.arranged_max_n = 4;
    let server = start_server_with(cfg);
    let addr = server.addr();
    let r = post(addr, "/v1/sort", &sort_body(41, 16));
    assert_eq!(r.status, 200, "{}", r.body);
    assert!(r.json().get("arranged").is_none(), "{}", r.body);
    let body = r#"{"method":"softsort","grid":"4x4","dataset":{"kind":"colors","n":16,"seed":41},"overrides":{"seed":41,"steps":16},"include_arranged":true}"#;
    let fat = post(addr, "/v1/sort", body);
    assert_eq!(fat.status, 200, "{}", fat.body);
    assert_eq!(fat.json().get("arranged").unwrap().as_arr().unwrap().len(), 16 * 3);
    server.shutdown();
}

#[test]
fn tile_n_override_sorts_tiled_and_caches_separately_from_full() {
    let server = start_server();
    let addr = server.addr();

    // 8x8 shuffle-softsort with 2-row tiles → 4 tiles per phase.
    let tiled_body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":3},"overrides":{"phases":16,"record_curve":false,"tile_n":16},"include_arranged":false}"#;
    let r = post(addr, "/v1/sort", tiled_body);
    assert_eq!(r.status, 200, "{}", r.body);
    assert_eq!(r.header("x-cache"), Some("miss"));
    let j = r.json();
    assert_eq!(j.get("tiles").unwrap().as_usize(), Some(4));
    let perm = perm_of(&j);
    assert_eq!(perm.len(), 64);

    // The same request without the tile override is a distinct cache entry
    // (the canonical overrides differ), served by the full executor.
    let full_body = r#"{"method":"shuffle-softsort","grid":"8x8","dataset":{"kind":"colors","n":64,"seed":3},"overrides":{"phases":16,"record_curve":false},"include_arranged":false}"#;
    let full = post(addr, "/v1/sort", full_body);
    assert_eq!(full.status, 200, "{}", full.body);
    assert_eq!(full.header("x-cache"), Some("miss"));
    assert_eq!(full.json().get("tiles").unwrap().as_usize(), Some(1));

    // Replaying the tiled request is a pure cache hit.
    let again = post(addr, "/v1/sort", tiled_body);
    assert_eq!(again.header("x-cache"), Some("hit"));
    assert_eq!(again.body, r.body);

    // metrics: 2 engine jobs (hit never reached it), 4 + 1 phase tiles.
    let metrics = get(addr, "/metrics").json();
    let engine = metrics.get("engine").unwrap();
    assert_eq!(engine.get("jobs").unwrap().as_usize(), Some(2));
    assert_eq!(engine.get("phase_tiles").unwrap().as_usize(), Some(5));

    server.shutdown();
}

#[test]
fn keep_alive_serves_multiple_requests_on_one_connection() {
    let server = start_server();
    let addr = server.addr();

    let mut c = Client::connect(addr);
    let r1 = c.request("GET", "/healthz", "", false);
    assert_eq!(r1.status, 200);
    assert_eq!(r1.header("connection"), Some("keep-alive"));
    let r2 = c.request("POST", "/v1/sort", &sort_body(3, 16), false);
    assert_eq!(r2.status, 200, "{}", r2.body);
    let r3 = c.request("GET", "/metrics", "", true);
    assert_eq!(r3.status, 200);
    assert_eq!(r3.header("connection"), Some("close"));

    server.shutdown();
}

#[test]
fn sort_batch_fans_out_and_shares_the_cache_with_single_sorts() {
    let server = start_server();
    let addr = server.addr();

    // Warm one of the two items through the single-sort path.
    let warm = post(addr, "/v1/sort", &sort_body(100, 16));
    assert_eq!(warm.status, 200, "{}", warm.body);

    let batch_body = r#"{"method":"softsort","grid":"4x4","overrides":{"seed":100,"steps":16},"datasets":[{"dataset":{"kind":"colors","n":16,"seed":100}},{"dataset":{"kind":"colors","n":16,"seed":101}}]}"#;
    // Item 0 is the warmed request — but its overrides there included
    // seed=100 too, so the canonical config matches and it must hit.
    let first = post(addr, "/v1/sort_batch", batch_body);
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(first.header("x-cache"), Some("hits=1 misses=1"));
    let j = first.json();
    assert_eq!(j.get("count").unwrap().as_usize(), Some(2));
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);

    // Batch results equal sequential engine sorts, item by item.
    let engine = local_engine();
    let g = GridShape::new(4, 4);
    for (i, seed) in [100u64, 101].iter().enumerate() {
        let expected = engine
            .sort("softsort", &random_colors(16, *seed), g, &sort_overrides(100, 16))
            .unwrap();
        assert_eq!(
            perm_of(&results[i]),
            expected.perm.as_slice().to_vec(),
            "batch item {i}"
        );
    }

    // Re-running the whole batch is now pure cache replay.
    let second = post(addr, "/v1/sort_batch", batch_body);
    assert_eq!(second.header("x-cache"), Some("hits=2 misses=0"));
    assert_eq!(second.body, first.body);

    server.shutdown();
}
