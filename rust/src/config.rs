//! Typed configuration for every driver, loadable from JSON files and
//! overridable from `key=value` CLI pairs (no serde/clap offline — see
//! DESIGN.md §6).

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{SessionOpts, SimdChoice};
use crate::coordinator::shuffle::ShuffleStrategy;
use crate::coordinator::{optimizer::AdamConfig, schedule::TauSchedule};
use crate::grid::GridShape;
use crate::util::json::Json;

/// The shared `threads` sentinel rule: 0 means "backend default" (`None`),
/// anything else is an explicit session pool size. One definition for the
/// CLI flag, the `threads=` override and both config builders.
pub fn normalize_threads(threads: usize) -> Option<usize> {
    (threads > 0).then_some(threads)
}

/// Convert a requested tile *count* into the per-tile cell count the
/// executor's plan will honor: the plan splits 2-D grids into whole-row
/// bands, so `tiles=B` maps to ⌈h/B⌉ rows per tile (≈B bands; never more),
/// and 1-D grids to ⌈N/B⌉ cells. A request the grid cannot satisfy (more
/// bands than rows, or bands that would drop below 2 cells) is clamped and
/// the clamp reported in the returned note, so `tiles=B` never silently
/// produces fewer bands than asked. Shared by `tiles=` and the builder.
fn tiles_to_tile_n(grid: GridShape, tiles: usize) -> (usize, Option<String>) {
    let max_b = if grid.h == 1 {
        (grid.n() / 2).max(1)
    } else if grid.w == 1 {
        (grid.h / 2).max(1)
    } else {
        grid.h
    };
    let b = tiles.min(max_b).max(1);
    let note = (b != tiles).then(|| {
        format!(
            "tiles={tiles} clamped to {b}: a {}x{} grid splits into at most {max_b} \
             bands of >=2 cells",
            grid.h, grid.w
        )
    });
    let tile_n =
        if grid.h == 1 { grid.n().div_ceil(b) } else { grid.h.div_ceil(b) * grid.w };
    (tile_n, note)
}

/// Tile-plan family for the tiled phase executor (`tile_plan=` override /
/// `--tile-plan` flag): how each phase's ≈`tile_n`-cell bands are laid
/// out. Inert without `tile_n`.
///
/// * `banded` — the block-diagonal baseline: fixed whole-row bands
///   (column segments on wide grids), identical every phase.
/// * `snake` — 1-D chains along a boustrophedon path over the grid, with
///   a phase-alternating half-tile offset: successive phases shift chain
///   seams, and chains cross row boundaries, so items migrate across the
///   whole grid over the run (the FLAS/SOM seam-escape trick).
/// * `overlapped` — whole-row bands whose seams alternate between phases
///   by half a band height, so every seam of one phase is interior to a
///   tile of the next.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TilePlanKind {
    #[default]
    Banded,
    Snake,
    Overlapped,
}

impl TilePlanKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "banded" => Some(TilePlanKind::Banded),
            "snake" => Some(TilePlanKind::Snake),
            "overlapped" | "overlap" => Some(TilePlanKind::Overlapped),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TilePlanKind::Banded => "banded",
            TilePlanKind::Snake => "snake",
            TilePlanKind::Overlapped => "overlapped",
        }
    }
}

/// Configuration of the ShuffleSoftSort driver (Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub struct ShuffleSoftSortConfig {
    pub grid: GridShape,
    /// Outer phases R.
    pub phases: usize,
    /// SoftSort iterations per phase I (paper: 4).
    pub inner_iters: usize,
    pub tau: TauSchedule,
    pub adam: AdamConfig,
    pub shuffle: ShuffleStrategy,
    /// Extra inner iterations allowed to reach a valid permutation
    /// (paper §II: "iterations are extended until a valid permutation is
    /// achieved") before greedy repair kicks in.
    pub max_extensions: usize,
    pub seed: u64,
    /// Record the loss curve (small overhead; on by default).
    pub record_curve: bool,
    /// Greedy phase acceptance: adopt a phase's hard permutation only if it
    /// does not worsen the hard neighbor metric. Guards the stochastic
    /// phases against regressions (ablated in benches/ablations.rs).
    pub greedy_accept: bool,
    /// Scale the Adam lr with feature dimension: lr · (d/3)^0.25
    /// (EXPERIMENTS.md §Tuning: 50-d wants ≈2× the 3-d step). Disabled
    /// automatically when `lr` is overridden explicitly.
    pub lr_auto_scale: bool,
    /// Worker-pool size for the backend step session (`None` = the
    /// backend's default; `threads=0` resets to the default). Never
    /// changes results — the native reduction is pool-size-invariant.
    pub threads: Option<usize>,
    /// Step-kernel implementation for the native backend (`simd=` override:
    /// `auto` picks the best detected at runtime, `off` forces the scalar
    /// bit-exactness oracle). Results agree within the documented
    /// scalar-vs-SIMD tolerance; ignored by pjrt.
    pub simd: SimdChoice,
    /// Tiled phase execution: `Some(t)` splits every phase into contiguous
    /// grid bands of ≈`t` cells and runs an independent SoftSort inner
    /// loop per tile — O(Σ n_b²) per step instead of O(N²), the knob that
    /// makes native sorts practical far beyond N≈4k. `None` (or
    /// `tile_n=0`) is the classic full-problem executor; `t >= N` yields
    /// one tile and is bit-identical to it. The `tiles=B` override is the
    /// same knob phrased as a tile count.
    pub tile_n: Option<usize>,
    /// Tile layout for the tiled executor (see [`TilePlanKind`]); inert
    /// without `tile_n`.
    pub tile_plan: TilePlanKind,
    /// Coarse-to-fine pyramid execution (`pyramid=true` / `--pyramid`):
    /// instead of independent block-diagonal tiles, each phase sorts tile
    /// *centroids* on a coarse grid with the full path, relocates whole
    /// tiles by the coarse permutation, then refines within tiles
    /// recursively until a region fits the O(tile_n²) budget (`tile_n`,
    /// default 512 when unset). Items exchange across the whole grid every
    /// phase — the knob that makes N=1,000,000 sorts converge. Takes
    /// precedence over `tile_plan`.
    pub pyramid: bool,
    /// Clamp note from `tiles=` parsing (surfaced in `RunReport.notes`);
    /// `None` when the requested tile count was honored exactly.
    pub tile_note: Option<String>,
}

impl ShuffleSoftSortConfig {
    /// Builder-style construction: `.grid(h, w)` is required (it seeds the
    /// grid-scaled defaults), typed setters tweak individual fields, and
    /// string `k=v` overrides (CLI semantics, last-wins) apply on top.
    pub fn builder() -> ShuffleSoftSortConfigBuilder {
        ShuffleSoftSortConfigBuilder::default()
    }

    /// Defaults from the EXPERIMENTS.md §Tuning sweep: random shuffles
    /// (Algorithm 1), τ 0.6→0.1, flat inner temperature (inner_frac = 1.0 —
    /// the paper's 0.2τ→τ ramp measurably hurts under greedy acceptance,
    /// see benches/ablations.rs), Adam lr 0.35·(d/3)^0.25, greedy phase
    /// acceptance, and R ≈ 16·N phases (capped — each phase is I=4 cheap
    /// steps).
    pub fn for_grid(h: usize, w: usize) -> Self {
        let n = h * w;
        let phases = (16 * n).clamp(512, 16384);
        ShuffleSoftSortConfig {
            grid: GridShape::new(h, w),
            phases,
            inner_iters: 4,
            tau: TauSchedule { tau_start: 0.6, tau_end: 0.1, inner_frac: 1.0 },
            adam: AdamConfig { lr: 0.35, ..Default::default() },
            shuffle: ShuffleStrategy::Random,
            max_extensions: 8,
            seed: 42,
            record_curve: true,
            greedy_accept: true,
            lr_auto_scale: true,
            threads: None,
            simd: SimdChoice::Auto,
            tile_n: None,
            tile_plan: TilePlanKind::Banded,
            pyramid: false,
            tile_note: None,
        }
    }

    /// The backend session knobs this config carries (pool width + SIMD
    /// level), in the shape [`StepBackend::session`] wants.
    ///
    /// [`StepBackend::session`]: crate::backend::StepBackend::session
    pub fn session_opts(&self) -> SessionOpts {
        SessionOpts { threads: self.threads, simd: self.simd }
    }

    /// Effective Adam lr for a d-dimensional dataset.
    pub fn effective_lr(&self, d: usize) -> f32 {
        if self.lr_auto_scale {
            self.adam.lr * (d as f32 / 3.0).powf(0.25)
        } else {
            self.adam.lr
        }
    }

    /// Apply a `key=value` override (CLI syntax).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "phases" | "r" => self.phases = value.parse()?,
            "inner_iters" | "i" => self.inner_iters = value.parse()?,
            "tau_start" => self.tau.tau_start = value.parse()?,
            "tau_end" => self.tau.tau_end = value.parse()?,
            "inner_frac" => self.tau.inner_frac = value.parse()?,
            "lr" => {
                self.adam.lr = value.parse()?;
                self.lr_auto_scale = false; // explicit lr wins
            }
            "seed" => self.seed = value.parse()?,
            "max_extensions" => self.max_extensions = value.parse()?,
            "shuffle" => {
                self.shuffle = ShuffleStrategy::parse(value)
                    .ok_or_else(|| anyhow!("unknown shuffle strategy '{value}'"))?
            }
            "record_curve" => self.record_curve = value.parse()?,
            "greedy_accept" | "accept" => self.greedy_accept = value.parse()?,
            "threads" => self.threads = normalize_threads(value.parse()?),
            "simd" => self.simd = SimdChoice::parse(value)?,
            "tile_n" => {
                let t: usize = value.parse()?;
                self.tile_n = (t > 0).then_some(t);
                self.tile_note = None;
            }
            "tiles" => {
                // A tile count is tile_n phrased per-grid, quantized the
                // way the executor's plan quantizes (whole grid rows on
                // 2-D grids) so B tiles really come out as B bands — an
                // unsatisfiable count is clamped with a note instead of
                // silently producing fewer bands. 0 resets to the full
                // executor.
                let b: usize = value.parse()?;
                if b == 0 {
                    self.tile_n = None;
                    self.tile_note = None;
                } else {
                    let (t, note) = tiles_to_tile_n(self.grid, b);
                    self.tile_n = Some(t);
                    self.tile_note = note;
                }
            }
            "tile_plan" => {
                self.tile_plan = TilePlanKind::parse(value).ok_or_else(|| {
                    anyhow!("unknown tile plan '{value}' (banded, snake, overlapped)")
                })?
            }
            "pyramid" => self.pyramid = value.parse()?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file: {"phases": 300, ...}.
    pub fn apply_json(&mut self, text: &str) -> Result<()> {
        let j = Json::parse(text)?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => bail!("config file must be a JSON object"),
        };
        for (k, v) in obj {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => format!("{n}"),
                Json::Bool(b) => format!("{b}"),
                _ => bail!("config value for '{k}' must be scalar"),
            };
            self.set(k, &s)?;
        }
        Ok(())
    }
}

/// Builder for [`ShuffleSoftSortConfig`]. Field order is irrelevant:
/// `build()` starts from the `for_grid` defaults, applies the typed
/// setters, then the string overrides (so `k=v` pairs win, matching the
/// CLI's last-wins semantics).
#[derive(Clone, Debug, Default)]
pub struct ShuffleSoftSortConfigBuilder {
    grid: Option<(usize, usize)>,
    phases: Option<usize>,
    inner_iters: Option<usize>,
    tau_start: Option<f32>,
    tau_end: Option<f32>,
    inner_frac: Option<f32>,
    lr: Option<f32>,
    seed: Option<u64>,
    shuffle: Option<ShuffleStrategy>,
    max_extensions: Option<usize>,
    record_curve: Option<bool>,
    greedy_accept: Option<bool>,
    threads: Option<usize>,
    simd: Option<SimdChoice>,
    tile_n: Option<usize>,
    tiles: Option<usize>,
    tile_plan: Option<TilePlanKind>,
    pyramid: Option<bool>,
    overrides: Vec<(String, String)>,
}

impl ShuffleSoftSortConfigBuilder {
    /// Target grid (required; all other defaults scale from it).
    pub fn grid(mut self, h: usize, w: usize) -> Self {
        self.grid = Some((h, w));
        self
    }

    /// Outer phase count R.
    pub fn phases(mut self, phases: usize) -> Self {
        self.phases = Some(phases);
        self
    }

    /// Inner SoftSort iterations per phase I.
    pub fn inner_iters(mut self, inner_iters: usize) -> Self {
        self.inner_iters = Some(inner_iters);
        self
    }

    /// Outer temperature schedule endpoints.
    pub fn tau(mut self, tau_start: f32, tau_end: f32) -> Self {
        self.tau_start = Some(tau_start);
        self.tau_end = Some(tau_end);
        self
    }

    /// Inner ramp start as a fraction of the phase temperature.
    pub fn inner_frac(mut self, inner_frac: f32) -> Self {
        self.inner_frac = Some(inner_frac);
        self
    }

    /// Explicit Adam lr (disables the d-dependent auto-scale, like the
    /// `lr=` CLI override).
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn shuffle(mut self, shuffle: ShuffleStrategy) -> Self {
        self.shuffle = Some(shuffle);
        self
    }

    pub fn max_extensions(mut self, max_extensions: usize) -> Self {
        self.max_extensions = Some(max_extensions);
        self
    }

    pub fn record_curve(mut self, record_curve: bool) -> Self {
        self.record_curve = Some(record_curve);
        self
    }

    pub fn greedy_accept(mut self, greedy_accept: bool) -> Self {
        self.greedy_accept = Some(greedy_accept);
        self
    }

    /// Session worker-pool size (like the `threads=` override; 0 keeps
    /// the backend default).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Step-kernel implementation (like the `simd=` override / the
    /// `--simd` CLI flag).
    pub fn simd(mut self, simd: SimdChoice) -> Self {
        self.simd = Some(simd);
        self
    }

    /// Tiled phase execution with ≈`tile_n` cells per tile (like the
    /// `tile_n=` override / the `--tile-n` CLI flag; 0 keeps the full
    /// executor).
    pub fn tile_n(mut self, tile_n: usize) -> Self {
        self.tile_n = Some(tile_n);
        self
    }

    /// Tiled phase execution phrased as a tile count (like the `tiles=`
    /// override; 0 keeps the full executor). Wins over [`Self::tile_n`]
    /// when both typed setters are used.
    pub fn tiles(mut self, tiles: usize) -> Self {
        self.tiles = Some(tiles);
        self
    }

    /// Tile layout for the tiled executor (like the `tile_plan=` override
    /// / the `--tile-plan` CLI flag).
    pub fn tile_plan(mut self, tile_plan: TilePlanKind) -> Self {
        self.tile_plan = Some(tile_plan);
        self
    }

    /// Coarse-to-fine pyramid execution (like the `pyramid=` override /
    /// the `--pyramid` CLI flag).
    pub fn pyramid(mut self, pyramid: bool) -> Self {
        self.pyramid = Some(pyramid);
        self
    }

    /// Queue one `k=v` override (applied last, CLI semantics).
    pub fn set(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.overrides.push((key.into(), value.into()));
        self
    }

    /// Queue many `k=v` overrides (applied last, in order, last-wins).
    pub fn overrides(mut self, pairs: impl IntoIterator<Item = (String, String)>) -> Self {
        self.overrides.extend(pairs);
        self
    }

    pub fn build(self) -> Result<ShuffleSoftSortConfig> {
        let (h, w) = self
            .grid
            .ok_or_else(|| anyhow!("ShuffleSoftSortConfig builder: grid(h, w) is required"))?;
        let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
        if let Some(v) = self.phases {
            cfg.phases = v;
        }
        if let Some(v) = self.inner_iters {
            cfg.inner_iters = v;
        }
        if let Some(v) = self.tau_start {
            cfg.tau.tau_start = v;
        }
        if let Some(v) = self.tau_end {
            cfg.tau.tau_end = v;
        }
        if let Some(v) = self.inner_frac {
            cfg.tau.inner_frac = v;
        }
        if let Some(v) = self.lr {
            cfg.adam.lr = v;
            cfg.lr_auto_scale = false;
        }
        if let Some(v) = self.seed {
            cfg.seed = v;
        }
        if let Some(v) = self.shuffle {
            cfg.shuffle = v;
        }
        if let Some(v) = self.max_extensions {
            cfg.max_extensions = v;
        }
        if let Some(v) = self.record_curve {
            cfg.record_curve = v;
        }
        if let Some(v) = self.greedy_accept {
            cfg.greedy_accept = v;
        }
        if let Some(v) = self.threads {
            cfg.threads = normalize_threads(v);
        }
        if let Some(v) = self.simd {
            cfg.simd = v;
        }
        if let Some(v) = self.tile_n {
            cfg.tile_n = (v > 0).then_some(v);
            cfg.tile_note = None;
        }
        if let Some(v) = self.tiles {
            if v == 0 {
                cfg.tile_n = None;
                cfg.tile_note = None;
            } else {
                let (t, note) = tiles_to_tile_n(cfg.grid, v);
                cfg.tile_n = Some(t);
                cfg.tile_note = note;
            }
        }
        if let Some(v) = self.tile_plan {
            cfg.tile_plan = v;
        }
        if let Some(v) = self.pyramid {
            cfg.pyramid = v;
        }
        for (k, v) in &self.overrides {
            cfg.set(k, v)
                .with_context(|| format!("invalid override '{k}={v}'"))?;
        }
        Ok(cfg)
    }
}

/// Configuration of the `serve` HTTP service layer (`sssort serve`).
/// Engine-side knobs (`--backend`, `--threads`, `--artifacts`) live in
/// `serve::EngineSpec`; this struct is the HTTP/queue/cache side. Bare
/// `k=v` pairs on the `serve` command line map onto [`ServeConfig::set`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Bind address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// HTTP worker threads (each accepts and handles connections).
    pub workers: usize,
    /// Result-cache byte budget in MiB.
    pub cache_mb: usize,
    /// Bounded job-queue depth; a full queue answers 503, not a stall.
    /// Each HTTP worker submits at most one job at a time, so the 503
    /// path only engages when `workers` exceeds this depth — the bound is
    /// a safety net for small-depth/many-worker configurations.
    pub queue_depth: usize,
    /// Largest accepted request body (413 above this, before reading it).
    pub max_body_bytes: usize,
    /// Keep-alive idle budget per connection, seconds.
    pub keep_alive_secs: u64,
    /// Largest N whose sort responses include the `arranged` rows by
    /// default. Above it the (potentially multi-megabyte) payload is
    /// omitted unless the request asks with `"include_arranged": true`;
    /// an explicit `false` strips it at any size.
    pub arranged_max_n: usize,
    /// Engine-host shard count (`--shards`). Jobs route by an affinity
    /// hash of (method, config, grid shape) so repeat shapes land on the
    /// same host's warm step sessions; 1 keeps the single-host layout.
    pub shards: usize,
    /// Result-cache spill file (`--cache-file`): append-only, checksummed,
    /// replayed on boot so cached results survive restarts. `None` keeps
    /// the cache memory-only.
    pub cache_file: Option<String>,
    /// Per-client steady request rate in requests/second (`--rate-limit`;
    /// burst 2x). 0 disables rate limiting.
    pub rate_limit: u64,
    /// Static bearer token (`--auth-token`); when set, every endpoint but
    /// `/healthz` requires `Authorization: Bearer <token>`.
    pub auth_token: Option<String>,
    /// Smallest N whose `include_arranged` responses stream as chunked
    /// transfer coding (and bypass the result cache) instead of buffering
    /// the full body.
    pub stream_min_n: usize,
    /// Request tracing (`trace=false` disables): each request gets a span
    /// tree (routing, queue wait, engine phases/tiles) retrievable at
    /// `GET /v1/trace/<id>` via the `X-Trace-Id` header, and convergence
    /// telemetry feeds the `/metrics` histograms. On by default — the
    /// per-step cost when a request is untraced is a relaxed atomic load.
    pub trace: bool,
    /// Head-based trace sampling (`--trace-sample K`): a deterministic
    /// counter at serve accept traces 1 in K requests. 0 disables tracing
    /// entirely (like `trace=false`), 1 — the default — traces every
    /// request. Untraced requests take the single load-and-branch path,
    /// bit-identically; sampled ones also fold into `GET /v1/profile`.
    pub trace_sample: u64,
    /// Finished traces kept for `GET /v1/trace/<id>` lookup before LRU
    /// eviction (`--trace-keep N`, minimum 1); evictions are counted in
    /// `/metrics`.
    pub trace_keep: usize,
    /// Tail-based trace sampling (`--trace-tail-ms T`, milliseconds): a
    /// request the 1-in-K head sampler would drop is traced anyway and
    /// *kept* iff its root span exceeds T ms (discarded otherwise), so
    /// slow outliers stay visible under aggressive head sampling. 0 — the
    /// default — disables the tail path; kept tails are counted in
    /// `/metrics` as `trace_tail_kept`.
    pub trace_tail_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let workers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8);
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            workers,
            cache_mb: 64,
            queue_depth: 256,
            max_body_bytes: 8 << 20,
            keep_alive_secs: 5,
            arranged_max_n: 4096,
            shards: 1,
            cache_file: None,
            rate_limit: 0,
            auth_token: None,
            stream_min_n: 4096,
            trace: true,
            trace_sample: 1,
            trace_keep: crate::trace::DEFAULT_FINISHED_CAP,
            trace_tail_ms: 0,
        }
    }
}

impl ServeConfig {
    /// Apply a `key=value` override (CLI syntax).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "addr" => self.addr = value.to_string(),
            "workers" => self.workers = value.parse()?,
            "cache_mb" => self.cache_mb = value.parse()?,
            "queue_depth" => self.queue_depth = value.parse()?,
            "max_body_bytes" => self.max_body_bytes = value.parse()?,
            "keep_alive_secs" => self.keep_alive_secs = value.parse()?,
            "arranged_max_n" => self.arranged_max_n = value.parse()?,
            "shards" => self.shards = value.parse::<usize>()?.max(1),
            "cache_file" => {
                self.cache_file = (!value.is_empty()).then(|| value.to_string());
            }
            "rate_limit" => self.rate_limit = value.parse()?,
            "auth_token" => {
                self.auth_token = (!value.is_empty()).then(|| value.to_string());
            }
            "stream_min_n" => self.stream_min_n = value.parse()?,
            "trace" => self.trace = value.parse()?,
            "trace_sample" => self.trace_sample = value.parse()?,
            "trace_keep" => self.trace_keep = value.parse::<usize>()?.max(1),
            "trace_tail_ms" => self.trace_tail_ms = value.parse()?,
            _ => bail!(
                "unknown serve config key '{key}' (allowed: addr, workers, cache_mb, \
                 queue_depth, max_body_bytes, keep_alive_secs, arranged_max_n, shards, \
                 cache_file, rate_limit, auth_token, stream_min_n, trace, trace_sample, \
                 trace_keep, trace_tail_ms)"
            ),
        }
        Ok(())
    }
}

/// Configuration shared by the baseline drivers.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineConfig {
    pub grid: GridShape,
    pub steps: usize,
    pub tau: TauSchedule,
    pub adam: AdamConfig,
    pub seed: u64,
    /// Gumbel noise scale for GS (annealed to 0 over the run).
    pub gumbel_scale: f32,
    /// Worker-pool size for the backend step session (`None` = backend
    /// default; `threads=0` resets). Never changes results.
    pub threads: Option<usize>,
    /// Step-kernel implementation for the native backend (see
    /// [`ShuffleSoftSortConfig::simd`]).
    pub simd: SimdChoice,
}

impl BaselineConfig {
    /// Builder-style construction mirroring
    /// [`ShuffleSoftSortConfig::builder`]; call `.gs_defaults()` for the
    /// Gumbel-Sinkhorn lr preset.
    pub fn builder() -> BaselineConfigBuilder {
        BaselineConfigBuilder::default()
    }

    pub fn for_grid(h: usize, w: usize) -> Self {
        let n = h * w;
        let steps = (16 * (n as f64).sqrt() as usize).clamp(256, 2048);
        BaselineConfig {
            grid: GridShape::new(h, w),
            steps,
            tau: TauSchedule::default(),
            adam: AdamConfig { lr: 0.5, ..Default::default() },
            seed: 42,
            gumbel_scale: 0.2,
            threads: None,
            simd: SimdChoice::Auto,
        }
    }

    /// The backend session knobs this config carries (see
    /// [`ShuffleSoftSortConfig::session_opts`]).
    pub fn session_opts(&self) -> SessionOpts {
        SessionOpts { threads: self.threads, simd: self.simd }
    }

    /// Gumbel-Sinkhorn variant: the N² logits want a much smaller Adam step
    /// (EXPERIMENTS.md §Tuning: lr 0.02 ≫ quality of lr 0.5 on this loss).
    pub fn for_gs(h: usize, w: usize) -> Self {
        let mut cfg = Self::for_grid(h, w);
        cfg.adam.lr = 0.02;
        cfg
    }

    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "steps" => self.steps = value.parse()?,
            "tau_start" => self.tau.tau_start = value.parse()?,
            "tau_end" => self.tau.tau_end = value.parse()?,
            "lr" => self.adam.lr = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "gumbel_scale" => self.gumbel_scale = value.parse()?,
            "threads" => self.threads = normalize_threads(value.parse()?),
            "simd" => self.simd = SimdChoice::parse(value)?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }
}

/// Builder for [`BaselineConfig`]. Same layering as the ShuffleSoftSort
/// builder: grid-scaled defaults → typed setters → `k=v` overrides.
#[derive(Clone, Debug, Default)]
pub struct BaselineConfigBuilder {
    grid: Option<(usize, usize)>,
    gs: bool,
    steps: Option<usize>,
    tau_start: Option<f32>,
    tau_end: Option<f32>,
    lr: Option<f32>,
    seed: Option<u64>,
    gumbel_scale: Option<f32>,
    threads: Option<usize>,
    simd: Option<SimdChoice>,
    overrides: Vec<(String, String)>,
}

impl BaselineConfigBuilder {
    /// Target grid (required).
    pub fn grid(mut self, h: usize, w: usize) -> Self {
        self.grid = Some((h, w));
        self
    }

    /// Start from the Gumbel-Sinkhorn defaults (`for_gs`: small Adam lr
    /// for the N² logits).
    pub fn gs_defaults(mut self) -> Self {
        self.gs = true;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    pub fn tau(mut self, tau_start: f32, tau_end: f32) -> Self {
        self.tau_start = Some(tau_start);
        self.tau_end = Some(tau_end);
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn gumbel_scale(mut self, gumbel_scale: f32) -> Self {
        self.gumbel_scale = Some(gumbel_scale);
        self
    }

    /// Session worker-pool size (like the `threads=` override; 0 keeps
    /// the backend default).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Step-kernel implementation (like the `simd=` override / the
    /// `--simd` CLI flag).
    pub fn simd(mut self, simd: SimdChoice) -> Self {
        self.simd = Some(simd);
        self
    }

    /// Queue one `k=v` override (applied last, CLI semantics).
    pub fn set(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.overrides.push((key.into(), value.into()));
        self
    }

    /// Queue many `k=v` overrides (applied last, in order, last-wins).
    pub fn overrides(mut self, pairs: impl IntoIterator<Item = (String, String)>) -> Self {
        self.overrides.extend(pairs);
        self
    }

    pub fn build(self) -> Result<BaselineConfig> {
        let (h, w) = self
            .grid
            .ok_or_else(|| anyhow!("BaselineConfig builder: grid(h, w) is required"))?;
        let mut cfg = if self.gs {
            BaselineConfig::for_gs(h, w)
        } else {
            BaselineConfig::for_grid(h, w)
        };
        if let Some(v) = self.steps {
            cfg.steps = v;
        }
        if let Some(v) = self.tau_start {
            cfg.tau.tau_start = v;
        }
        if let Some(v) = self.tau_end {
            cfg.tau.tau_end = v;
        }
        if let Some(v) = self.lr {
            cfg.adam.lr = v;
        }
        if let Some(v) = self.seed {
            cfg.seed = v;
        }
        if let Some(v) = self.gumbel_scale {
            cfg.gumbel_scale = v;
        }
        if let Some(v) = self.threads {
            cfg.threads = normalize_threads(v);
        }
        if let Some(v) = self.simd {
            cfg.simd = v;
        }
        for (k, v) in &self.overrides {
            cfg.set(k, v)
                .with_context(|| format!("invalid override '{k}={v}'"))?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_scale_with_n() {
        let small = ShuffleSoftSortConfig::for_grid(8, 8);
        let large = ShuffleSoftSortConfig::for_grid(64, 64);
        assert!(large.phases >= small.phases);
        assert_eq!(small.inner_iters, 4);
    }

    #[test]
    fn set_overrides() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        c.set("phases", "77").unwrap();
        c.set("lr", "0.25").unwrap();
        c.set("shuffle", "random").unwrap();
        assert_eq!(c.phases, 77);
        assert_eq!(c.adam.lr, 0.25);
        assert_eq!(c.shuffle, ShuffleStrategy::Random);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("shuffle", "nope").is_err());
    }

    #[test]
    fn threads_override_parses_and_zero_resets() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        assert_eq!(c.threads, None);
        c.set("threads", "4").unwrap();
        assert_eq!(c.threads, Some(4));
        c.set("threads", "0").unwrap();
        assert_eq!(c.threads, None);
        assert!(c.set("threads", "many").is_err());
        let b = BaselineConfig::builder().grid(8, 8).threads(2).build().unwrap();
        assert_eq!(b.threads, Some(2));
        let b = BaselineConfig::builder()
            .grid(8, 8)
            .threads(2)
            .set("threads", "0")
            .build()
            .unwrap();
        assert_eq!(b.threads, None);
        let s = ShuffleSoftSortConfig::builder().grid(8, 8).threads(3).build().unwrap();
        assert_eq!(s.threads, Some(3));
    }

    #[test]
    fn simd_override_parses_and_feeds_session_opts() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        assert_eq!(c.simd, SimdChoice::Auto);
        c.set("simd", "off").unwrap();
        assert_eq!(c.simd, SimdChoice::Off);
        assert_eq!(c.session_opts(), SessionOpts { threads: None, simd: SimdChoice::Off });
        c.set("simd", "auto").unwrap();
        assert_eq!(c.simd, SimdChoice::Auto);
        assert!(c.set("simd", "avx9000").is_err());
        let b = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .simd(SimdChoice::Off)
            .build()
            .unwrap();
        assert_eq!(b.simd, SimdChoice::Off);
        let mut base = BaselineConfig::for_grid(8, 8);
        assert_eq!(base.simd, SimdChoice::Auto);
        base.set("simd", "off").unwrap();
        assert_eq!(base.session_opts().simd, SimdChoice::Off);
        let bb = BaselineConfig::builder().grid(8, 8).simd(SimdChoice::Off).build().unwrap();
        assert_eq!(bb.simd, SimdChoice::Off);
    }

    #[test]
    fn tile_overrides_parse_and_zero_resets() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        assert_eq!(c.tile_n, None);
        c.set("tile_n", "16").unwrap();
        assert_eq!(c.tile_n, Some(16));
        c.set("tile_n", "0").unwrap();
        assert_eq!(c.tile_n, None);
        // `tiles=B` converts to row-quantized cells per tile, so the
        // executor's whole-row bands really come out as B tiles: on 8x8,
        // tiles=3 → ⌈8/3⌉ = 3 rows = 24 cells → bands of 24, 24, 16.
        c.set("tiles", "4").unwrap();
        assert_eq!(c.tile_n, Some(16));
        c.set("tiles", "3").unwrap();
        assert_eq!(c.tile_n, Some(24));
        c.set("tiles", "0").unwrap();
        assert_eq!(c.tile_n, None);
        // 1-D grids quantize by cells.
        let mut line = ShuffleSoftSortConfig::for_grid(1, 13);
        line.set("tiles", "3").unwrap();
        assert_eq!(line.tile_n, Some(5));
        assert!(c.set("tile_n", "many").is_err());
        assert!(c.set("tiles", "-1").is_err());

        // Builder paths mirror the string overrides; `tiles` wins over
        // `tile_n` among typed setters, and `k=v` pairs win over both.
        let b = ShuffleSoftSortConfig::builder().grid(8, 8).tile_n(12).build().unwrap();
        assert_eq!(b.tile_n, Some(12));
        let b = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .tile_n(12)
            .tiles(4)
            .build()
            .unwrap();
        assert_eq!(b.tile_n, Some(16));
        let b = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .tiles(4)
            .set("tile_n", "0")
            .build()
            .unwrap();
        assert_eq!(b.tile_n, None);
    }

    #[test]
    fn tile_plan_and_pyramid_overrides_parse() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        assert_eq!(c.tile_plan, TilePlanKind::Banded);
        assert!(!c.pyramid);
        c.set("tile_plan", "snake").unwrap();
        assert_eq!(c.tile_plan, TilePlanKind::Snake);
        c.set("tile_plan", "overlapped").unwrap();
        assert_eq!(c.tile_plan, TilePlanKind::Overlapped);
        c.set("tile_plan", "banded").unwrap();
        assert_eq!(c.tile_plan, TilePlanKind::Banded);
        assert!(c.set("tile_plan", "spiral").is_err());
        c.set("pyramid", "true").unwrap();
        assert!(c.pyramid);
        c.set("pyramid", "false").unwrap();
        assert!(!c.pyramid);
        assert!(c.set("pyramid", "maybe").is_err());
        // Builder setters mirror the overrides, and k=v pairs still win.
        let b = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .tile_plan(TilePlanKind::Snake)
            .pyramid(true)
            .build()
            .unwrap();
        assert_eq!(b.tile_plan, TilePlanKind::Snake);
        assert!(b.pyramid);
        let b = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .tile_plan(TilePlanKind::Snake)
            .set("tile_plan", "banded")
            .build()
            .unwrap();
        assert_eq!(b.tile_plan, TilePlanKind::Banded);
        // Round-trip name <-> parse.
        for k in [TilePlanKind::Banded, TilePlanKind::Snake, TilePlanKind::Overlapped] {
            assert_eq!(TilePlanKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn tiles_requests_beyond_the_grid_are_clamped_with_a_note() {
        // 8x8 supports at most 8 whole-row bands: tiles=100 clamps to 8.
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        c.set("tiles", "100").unwrap();
        assert_eq!(c.tile_n, Some(8));
        let note = c.tile_note.clone().expect("clamp emits a note");
        assert!(note.contains("tiles=100") && note.contains("8"), "{note}");
        // An exactly-satisfiable request leaves no note.
        c.set("tiles", "4").unwrap();
        assert_eq!(c.tile_n, Some(16));
        assert_eq!(c.tile_note, None);
        // tile_n= and tiles=0 clear a stale note.
        c.set("tiles", "100").unwrap();
        assert!(c.tile_note.is_some());
        c.set("tile_n", "16").unwrap();
        assert_eq!(c.tile_note, None);
        c.set("tiles", "100").unwrap();
        c.set("tiles", "0").unwrap();
        assert_eq!(c.tile_note, None);
        // 1-D grids cap at n/2 bands (every band needs >= 2 cells)...
        let mut line = ShuffleSoftSortConfig::for_grid(1, 12);
        line.set("tiles", "9").unwrap();
        assert_eq!(line.tile_n, Some(2));
        assert!(line.tile_note.is_some());
        // ...and w=1 grids at h/2 (whole-row bands of >= 2 rows).
        let mut thin = ShuffleSoftSortConfig::for_grid(9, 1);
        thin.set("tiles", "9").unwrap();
        assert_eq!(thin.tile_n, Some(3));
        assert!(thin.tile_note.clone().unwrap().contains("tiles=9"));
        // The builder path produces the identical clamp + note.
        let b = ShuffleSoftSortConfig::builder().grid(8, 8).tiles(100).build().unwrap();
        assert_eq!(b.tile_n, Some(8));
        assert!(b.tile_note.is_some());
        let mut by_set = ShuffleSoftSortConfig::for_grid(8, 8);
        by_set.set("tiles", "100").unwrap();
        assert_eq!(b, by_set);
    }

    #[test]
    fn serve_config_overrides_and_unknown_keys() {
        let mut c = ServeConfig::default();
        assert!(c.workers >= 1);
        c.set("addr", "0.0.0.0:8080").unwrap();
        c.set("workers", "4").unwrap();
        c.set("cache_mb", "16").unwrap();
        c.set("queue_depth", "32").unwrap();
        c.set("keep_alive_secs", "2").unwrap();
        assert_eq!(c.arranged_max_n, 4096);
        c.set("arranged_max_n", "256").unwrap();
        assert_eq!(c.addr, "0.0.0.0:8080");
        assert_eq!(c.workers, 4);
        assert_eq!(c.cache_mb, 16);
        assert_eq!(c.queue_depth, 32);
        assert_eq!(c.keep_alive_secs, 2);
        assert_eq!(c.arranged_max_n, 256);
        assert!(c.set("workers", "many").is_err());
        let err = c.set("frobnicate", "1").unwrap_err();
        assert!(format!("{err:#}").contains("frobnicate"));
    }

    #[test]
    fn serve_config_shard_and_persistence_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.shards, 1);
        assert_eq!(c.cache_file, None);
        assert_eq!(c.rate_limit, 0);
        assert_eq!(c.auth_token, None);
        assert_eq!(c.stream_min_n, 4096);
        c.set("shards", "4").unwrap();
        assert_eq!(c.shards, 4);
        // 0 shards would mean no engine hosts; clamp to 1 instead.
        c.set("shards", "0").unwrap();
        assert_eq!(c.shards, 1);
        c.set("cache_file", "/tmp/sssort.spill").unwrap();
        assert_eq!(c.cache_file.as_deref(), Some("/tmp/sssort.spill"));
        c.set("cache_file", "").unwrap();
        assert_eq!(c.cache_file, None);
        c.set("rate_limit", "25").unwrap();
        assert_eq!(c.rate_limit, 25);
        c.set("auth_token", "s3cret").unwrap();
        assert_eq!(c.auth_token.as_deref(), Some("s3cret"));
        c.set("auth_token", "").unwrap();
        assert_eq!(c.auth_token, None);
        c.set("stream_min_n", "8").unwrap();
        assert_eq!(c.stream_min_n, 8);
        assert!(c.set("shards", "many").is_err());
        assert!(c.set("rate_limit", "-2").is_err());
    }

    #[test]
    fn serve_config_trace_key() {
        let mut c = ServeConfig::default();
        assert!(c.trace, "tracing is on by default");
        c.set("trace", "false").unwrap();
        assert!(!c.trace);
        c.set("trace", "true").unwrap();
        assert!(c.trace);
        assert!(c.set("trace", "sometimes").is_err());
    }

    #[test]
    fn serve_config_sampling_and_keep_keys() {
        let mut c = ServeConfig::default();
        assert_eq!(c.trace_sample, 1, "default samples every request");
        assert_eq!(c.trace_keep, crate::trace::DEFAULT_FINISHED_CAP);
        c.set("trace_sample", "8").unwrap();
        assert_eq!(c.trace_sample, 8);
        c.set("trace_sample", "0").unwrap();
        assert_eq!(c.trace_sample, 0, "0 = tracing off");
        assert!(c.set("trace_sample", "-1").is_err());
        c.set("trace_keep", "512").unwrap();
        assert_eq!(c.trace_keep, 512);
        // 0 would make every finished trace immediately evictable.
        c.set("trace_keep", "0").unwrap();
        assert_eq!(c.trace_keep, 1);
        let err = c.set("nope", "1").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("trace_sample") && msg.contains("trace_keep"));
        assert!(msg.contains("trace_tail_ms"));
    }

    #[test]
    fn serve_config_trace_tail_key() {
        let mut c = ServeConfig::default();
        assert_eq!(c.trace_tail_ms, 0, "tail sampling is off by default");
        c.set("trace_tail_ms", "250").unwrap();
        assert_eq!(c.trace_tail_ms, 250);
        c.set("trace_tail_ms", "0").unwrap();
        assert_eq!(c.trace_tail_ms, 0);
        assert!(c.set("trace_tail_ms", "-5").is_err());
        assert!(c.set("trace_tail_ms", "fast").is_err());
    }

    #[test]
    fn json_overrides() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        c.apply_json(r#"{"phases": 12, "tau_end": 0.05, "shuffle": "scan"}"#).unwrap();
        assert_eq!(c.phases, 12);
        assert!((c.tau.tau_end - 0.05).abs() < 1e-9);
        assert!(c.apply_json("[1]").is_err());
    }

    #[test]
    fn builder_defaults_round_trip_for_grid() {
        // A bare builder must reproduce the struct-literal defaults exactly.
        for (h, w) in [(4usize, 4usize), (16, 16), (1, 16)] {
            let built = ShuffleSoftSortConfig::builder().grid(h, w).build().unwrap();
            assert_eq!(built, ShuffleSoftSortConfig::for_grid(h, w));
            let base = BaselineConfig::builder().grid(h, w).build().unwrap();
            assert_eq!(base, BaselineConfig::for_grid(h, w));
            let gs = BaselineConfig::builder().grid(h, w).gs_defaults().build().unwrap();
            assert_eq!(gs, BaselineConfig::for_gs(h, w));
        }
    }

    #[test]
    fn builder_typed_setters_match_set_overrides() {
        let typed = ShuffleSoftSortConfig::builder()
            .grid(16, 16)
            .phases(8)
            .seed(7)
            .lr(0.25)
            .shuffle(ShuffleStrategy::Mixed)
            .record_curve(false)
            .build()
            .unwrap();
        let mut by_set = ShuffleSoftSortConfig::for_grid(16, 16);
        by_set.set("phases", "8").unwrap();
        by_set.set("seed", "7").unwrap();
        by_set.set("lr", "0.25").unwrap();
        by_set.set("shuffle", "mixed").unwrap();
        by_set.set("record_curve", "false").unwrap();
        assert_eq!(typed, by_set);
        // Explicit lr disables the auto-scale in both paths.
        assert!(!typed.lr_auto_scale);
    }

    #[test]
    fn builder_requires_grid() {
        assert!(ShuffleSoftSortConfig::builder().build().is_err());
        assert!(BaselineConfig::builder().build().is_err());
    }

    #[test]
    fn builder_string_overrides_are_last_wins() {
        let cfg = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .phases(10)
            .set("phases", "20")
            .set("phases", "30")
            .build()
            .unwrap();
        assert_eq!(cfg.phases, 30);
    }

    #[test]
    fn builder_override_errors_name_the_key() {
        let err = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .set("phases", "not-a-number")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("phases"), "{err:#}");
        let err = ShuffleSoftSortConfig::builder()
            .grid(8, 8)
            .set("frobnicate", "1")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("frobnicate"), "{err:#}");
        let err = BaselineConfig::builder()
            .grid(8, 8)
            .set("steps", "x")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("steps"), "{err:#}");
    }
}
