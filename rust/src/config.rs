//! Typed configuration for every driver, loadable from JSON files and
//! overridable from `key=value` CLI pairs (no serde/clap offline — see
//! DESIGN.md §6).

use anyhow::{anyhow, bail, Result};

use crate::coordinator::shuffle::ShuffleStrategy;
use crate::coordinator::{optimizer::AdamConfig, schedule::TauSchedule};
use crate::grid::GridShape;
use crate::util::json::Json;

/// Configuration of the ShuffleSoftSort driver (Algorithm 1).
#[derive(Clone, Debug)]
pub struct ShuffleSoftSortConfig {
    pub grid: GridShape,
    /// Outer phases R.
    pub phases: usize,
    /// SoftSort iterations per phase I (paper: 4).
    pub inner_iters: usize,
    pub tau: TauSchedule,
    pub adam: AdamConfig,
    pub shuffle: ShuffleStrategy,
    /// Extra inner iterations allowed to reach a valid permutation
    /// (paper §II: "iterations are extended until a valid permutation is
    /// achieved") before greedy repair kicks in.
    pub max_extensions: usize,
    pub seed: u64,
    /// Record the loss curve (small overhead; on by default).
    pub record_curve: bool,
    /// Greedy phase acceptance: adopt a phase's hard permutation only if it
    /// does not worsen the hard neighbor metric. Guards the stochastic
    /// phases against regressions (ablated in benches/ablations.rs).
    pub greedy_accept: bool,
    /// Scale the Adam lr with feature dimension: lr · (d/3)^0.25
    /// (EXPERIMENTS.md §Tuning: 50-d wants ≈2× the 3-d step). Disabled
    /// automatically when `lr` is overridden explicitly.
    pub lr_auto_scale: bool,
}

impl ShuffleSoftSortConfig {
    /// Defaults from the EXPERIMENTS.md §Tuning sweep: random shuffles
    /// (Algorithm 1), τ 0.6→0.1, flat inner temperature (inner_frac = 1.0 —
    /// the paper's 0.2τ→τ ramp measurably hurts under greedy acceptance,
    /// see benches/ablations.rs), Adam lr 0.35·(d/3)^0.25, greedy phase
    /// acceptance, and R ≈ 16·N phases (capped — each phase is I=4 cheap
    /// steps).
    pub fn for_grid(h: usize, w: usize) -> Self {
        let n = h * w;
        let phases = (16 * n).clamp(512, 16384);
        ShuffleSoftSortConfig {
            grid: GridShape::new(h, w),
            phases,
            inner_iters: 4,
            tau: TauSchedule { tau_start: 0.6, tau_end: 0.1, inner_frac: 1.0 },
            adam: AdamConfig { lr: 0.35, ..Default::default() },
            shuffle: ShuffleStrategy::Random,
            max_extensions: 8,
            seed: 42,
            record_curve: true,
            greedy_accept: true,
            lr_auto_scale: true,
        }
    }

    /// Effective Adam lr for a d-dimensional dataset.
    pub fn effective_lr(&self, d: usize) -> f32 {
        if self.lr_auto_scale {
            self.adam.lr * (d as f32 / 3.0).powf(0.25)
        } else {
            self.adam.lr
        }
    }

    /// Apply a `key=value` override (CLI syntax).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "phases" | "r" => self.phases = value.parse()?,
            "inner_iters" | "i" => self.inner_iters = value.parse()?,
            "tau_start" => self.tau.tau_start = value.parse()?,
            "tau_end" => self.tau.tau_end = value.parse()?,
            "inner_frac" => self.tau.inner_frac = value.parse()?,
            "lr" => {
                self.adam.lr = value.parse()?;
                self.lr_auto_scale = false; // explicit lr wins
            }
            "seed" => self.seed = value.parse()?,
            "max_extensions" => self.max_extensions = value.parse()?,
            "shuffle" => {
                self.shuffle = ShuffleStrategy::parse(value)
                    .ok_or_else(|| anyhow!("unknown shuffle strategy '{value}'"))?
            }
            "record_curve" => self.record_curve = value.parse()?,
            "greedy_accept" | "accept" => self.greedy_accept = value.parse()?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file: {"phases": 300, ...}.
    pub fn apply_json(&mut self, text: &str) -> Result<()> {
        let j = Json::parse(text)?;
        let obj = match &j {
            Json::Obj(m) => m,
            _ => bail!("config file must be a JSON object"),
        };
        for (k, v) in obj {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => format!("{n}"),
                Json::Bool(b) => format!("{b}"),
                _ => bail!("config value for '{k}' must be scalar"),
            };
            self.set(k, &s)?;
        }
        Ok(())
    }
}

/// Configuration shared by the baseline drivers.
#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub grid: GridShape,
    pub steps: usize,
    pub tau: TauSchedule,
    pub adam: AdamConfig,
    pub seed: u64,
    /// Gumbel noise scale for GS (annealed to 0 over the run).
    pub gumbel_scale: f32,
}

impl BaselineConfig {
    pub fn for_grid(h: usize, w: usize) -> Self {
        let n = h * w;
        let steps = (16 * (n as f64).sqrt() as usize).clamp(256, 2048);
        BaselineConfig {
            grid: GridShape::new(h, w),
            steps,
            tau: TauSchedule::default(),
            adam: AdamConfig { lr: 0.5, ..Default::default() },
            seed: 42,
            gumbel_scale: 0.2,
        }
    }

    /// Gumbel-Sinkhorn variant: the N² logits want a much smaller Adam step
    /// (EXPERIMENTS.md §Tuning: lr 0.02 ≫ quality of lr 0.5 on this loss).
    pub fn for_gs(h: usize, w: usize) -> Self {
        let mut cfg = Self::for_grid(h, w);
        cfg.adam.lr = 0.02;
        cfg
    }

    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "steps" => self.steps = value.parse()?,
            "tau_start" => self.tau.tau_start = value.parse()?,
            "tau_end" => self.tau.tau_end = value.parse()?,
            "lr" => self.adam.lr = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "gumbel_scale" => self.gumbel_scale = value.parse()?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_scale_with_n() {
        let small = ShuffleSoftSortConfig::for_grid(8, 8);
        let large = ShuffleSoftSortConfig::for_grid(64, 64);
        assert!(large.phases >= small.phases);
        assert_eq!(small.inner_iters, 4);
    }

    #[test]
    fn set_overrides() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        c.set("phases", "77").unwrap();
        c.set("lr", "0.25").unwrap();
        c.set("shuffle", "random").unwrap();
        assert_eq!(c.phases, 77);
        assert_eq!(c.adam.lr, 0.25);
        assert_eq!(c.shuffle, ShuffleStrategy::Random);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("shuffle", "nope").is_err());
    }

    #[test]
    fn json_overrides() {
        let mut c = ShuffleSoftSortConfig::for_grid(8, 8);
        c.apply_json(r#"{"phases": 12, "tau_end": 0.05, "shuffle": "scan"}"#).unwrap();
        assert_eq!(c.phases, 12);
        assert!((c.tau.tau_end - 0.05).abs() < 1e-9);
        assert!(c.apply_json("[1]").is_err());
    }
}
