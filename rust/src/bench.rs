//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `rust/benches/*.rs` target with
//! `harness = false`; they use this module for warmup + timed repetitions
//! with mean/std/min reporting, and simple aligned-table printing for the
//! paper-table reproductions.

use std::time::Instant;

use crate::serve::json::{self, Json};
use crate::util::stats::{mean, std_dev};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub reps: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Sample {
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10.4}s ± {:>8.4}s (min {:>8.4}s, n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.reps
        )
    }

    /// This sample as a JSON value (built through `serve::json`, the one
    /// serializer in the crate — no ad-hoc string assembly).
    pub fn to_json(&self) -> Json {
        json::obj([
            ("name", Json::from(self.name.as_str())),
            ("reps", Json::from(self.reps)),
            ("mean_s", json::num(self.mean_s)),
            ("std_s", json::num(self.std_s)),
            ("min_s", json::num(self.min_s)),
        ])
    }

    /// One compact JSON object for the machine-readable bench report.
    pub fn json(&self) -> String {
        self.to_json().to_string_compact()
    }
}

/// Write a bench report as a JSON document: `{"bench": title, "samples":
/// [...]}`. Parent directories are created; used by `runtime_micro`,
/// `scaling` and `examples/perf_sweep` to record per-step numbers under
/// `target/bench_reports/` (uploaded as a CI artifact).
pub fn write_json_report(
    path: impl AsRef<std::path::Path>,
    title: &str,
    samples: &[Sample],
) -> std::io::Result<()> {
    let doc = json::obj([
        ("bench", Json::from(title)),
        ("samples", json::arr(samples.iter().map(Sample::to_json))),
    ]);
    write_report_doc(path, &doc)
}

/// Write a table-shaped bench report: `{"bench": title, "rows": [{header:
/// cell, ...}]}` — the machine-readable twin of `Table::print` for the
/// paper-table benches.
pub fn write_table_report(
    path: impl AsRef<std::path::Path>,
    title: &str,
    table: &Table,
) -> std::io::Result<()> {
    let doc = json::obj([("bench", Json::from(title)), ("rows", table.to_json())]);
    write_report_doc(path, &doc)
}

fn write_report_doc(path: impl AsRef<std::path::Path>, doc: &Json) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = json::to_string_pretty(doc);
    text.push('\n');
    std::fs::write(path, text)
}

/// Run `f` `warmup` + `reps` times, timing the reps.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    Sample {
        name: name.to_string(),
        reps: times.len(),
        mean_s: mean(&times),
        std_s: std_dev(&times),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Aligned table printer for the paper-table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Machine-readable form: one object per row, keyed by header.
    pub fn to_json(&self) -> Json {
        json::arr(self.rows.iter().map(|row| {
            json::obj(
                self.headers
                    .iter()
                    .cloned()
                    .zip(row.iter().map(|c| Json::from(c.as_str()))),
            )
        }))
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        println!("{sep}");
        for row in &self.rows {
            line(row);
        }
    }
}

/// Shared CLI convention for bench binaries: `--quick` shrinks workloads so
/// `cargo bench` completes in minutes on one core; full runs are opt-in.
pub fn quick_mode() -> bool {
    // `cargo bench` passes `--bench`; our own flag is `--full`.
    !std::env::args().any(|a| a == "--full")
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
    println!(
        "mode: {} (pass --full after `--` for paper-scale runs)",
        if quick_mode() { "quick" } else { "full" }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_reps() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.reps, 5);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.mean_s);
        assert!(s.line().contains("noop"));
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["Method", "Memory", "Quality"]);
        t.row(&["ours".into(), "1024".into(), "0.89".into()]);
        t.print();
        let j = t.to_json();
        assert_eq!(
            j.to_string_compact(),
            r#"[{"Memory":"1024","Method":"ours","Quality":"0.89"}]"#
        );
    }

    #[test]
    fn sample_json_round_trips_through_the_crate_parser() {
        use crate::util::json::Json;
        let s = bench("native \"sss\" n=64", 0, 2, || 1 + 1);
        let j = Json::parse(&s.json()).expect("sample json parses");
        assert_eq!(j.get("name").unwrap().as_str(), Some(r#"native "sss" n=64"#));
        assert!(j.get("mean_s").is_some());
        assert!(j.get("reps").is_some());
    }
}
