//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `rust/benches/*.rs` target with
//! `harness = false`; they use this module for warmup + timed repetitions
//! with mean/std/min reporting, and simple aligned-table printing for the
//! paper-table reproductions.

use std::time::Instant;

use crate::util::stats::{mean, std_dev};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub reps: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Sample {
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10.4}s ± {:>8.4}s (min {:>8.4}s, n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.reps
        )
    }

    /// One JSON object for the machine-readable bench report.
    pub fn json(&self) -> String {
        format!(
            r#"{{"name": "{}", "reps": {}, "mean_s": {}, "std_s": {}, "min_s": {}}}"#,
            json_escape(&self.name),
            self.reps,
            self.mean_s,
            self.std_s,
            self.min_s
        )
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write a bench report as a JSON document: `{"bench": title, "samples":
/// [...]}`. Parent directories are created; used by `runtime_micro` to
/// record the native-vs-pjrt per-step numbers.
pub fn write_json_report(
    path: impl AsRef<std::path::Path>,
    title: &str,
    samples: &[Sample],
) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let body: Vec<String> = samples.iter().map(|s| format!("    {}", s.json())).collect();
    let doc = format!(
        "{{\n  \"bench\": \"{}\",\n  \"samples\": [\n{}\n  ]\n}}\n",
        json_escape(title),
        body.join(",\n")
    );
    std::fs::write(path, doc)
}

/// Run `f` `warmup` + `reps` times, timing the reps.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    Sample {
        name: name.to_string(),
        reps: times.len(),
        mean_s: mean(&times),
        std_s: std_dev(&times),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Aligned table printer for the paper-table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        println!("{sep}");
        for row in &self.rows {
            line(row);
        }
    }
}

/// Shared CLI convention for bench binaries: `--quick` shrinks workloads so
/// `cargo bench` completes in minutes on one core; full runs are opt-in.
pub fn quick_mode() -> bool {
    // `cargo bench` passes `--bench`; our own flag is `--full`.
    !std::env::args().any(|a| a == "--full")
}

/// Standard bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
    println!(
        "mode: {} (pass --full after `--` for paper-scale runs)",
        if quick_mode() { "quick" } else { "full" }
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_reps() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.reps, 5);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.mean_s);
        assert!(s.line().contains("noop"));
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["Method", "Memory", "Quality"]);
        t.row(&["ours".into(), "1024".into(), "0.89".into()]);
        t.print();
    }

    #[test]
    fn sample_json_round_trips_through_the_crate_parser() {
        use crate::util::json::Json;
        let s = bench("native \"sss\" n=64", 0, 2, || 1 + 1);
        let j = Json::parse(&s.json()).expect("sample json parses");
        assert_eq!(j.get("name").unwrap().as_str(), Some(r#"native "sss" n=64"#));
        assert!(j.get("mean_s").is_some());
        assert!(j.get("reps").is_some());
    }
}
