//! Permutation algebra: validated permutation type, composition, inversion,
//! application to row-major data, and the `Tracker` that accumulates the
//! permutation learned across ShuffleSoftSort phases.
//!
//! Conventions. A `Permutation` `p` maps *positions to source indices*:
//! applying `p` to data `x` produces `y[i] = x[p[i]]` ("gather" form). This
//! matches the paper's `x_sort = P_hard · x` with `p[i] = argmax_j P[i, j]`.

mod tracker;

pub use tracker::Tracker;

/// A validated permutation of `0..n` in gather form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    idx: Vec<u32>,
}

impl Permutation {
    /// The identity permutation of size `n`.
    pub fn identity(n: usize) -> Self {
        Permutation { idx: (0..n as u32).collect() }
    }

    /// Validate and wrap `idx`; error if it is not a bijection on 0..n.
    pub fn from_vec(idx: Vec<u32>) -> Result<Self, InvalidPermutation> {
        let n = idx.len();
        let mut seen = vec![false; n];
        let mut dups = 0usize;
        let mut oob = 0usize;
        for &v in &idx {
            if (v as usize) >= n {
                oob += 1;
            } else if seen[v as usize] {
                dups += 1;
            } else {
                seen[v as usize] = true;
            }
        }
        if dups > 0 || oob > 0 {
            Err(InvalidPermutation { n, duplicates: dups, out_of_bounds: oob })
        } else {
            Ok(Permutation { idx })
        }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn as_slice(&self) -> &[u32] {
        &self.idx
    }

    /// Number of duplicate targets in a *candidate* index vector (the
    /// validity statistic the paper's "Stability" row measures).
    pub fn count_duplicates(idx: &[u32]) -> usize {
        let n = idx.len();
        let mut seen = vec![false; n];
        let mut dups = 0;
        for &v in idx {
            let v = v as usize;
            if v < n {
                if seen[v] {
                    dups += 1;
                } else {
                    seen[v] = true;
                }
            } else {
                dups += 1;
            }
        }
        dups
    }

    /// Inverse permutation: `inv[p[i]] = i`.
    pub fn inverse(&self) -> Permutation {
        let mut inv = vec![0u32; self.idx.len()];
        for (i, &v) in self.idx.iter().enumerate() {
            inv[v as usize] = i as u32;
        }
        Permutation { idx: inv }
    }

    /// Composition `self ∘ other`: applying the result equals applying
    /// `other` first, then `self`. `(a∘b)[i] = b[a[i]]`.
    pub fn compose(&self, other: &Permutation) -> Permutation {
        assert_eq!(self.len(), other.len());
        let idx = self.idx.iter().map(|&i| other.idx[i as usize]).collect();
        Permutation { idx }
    }

    /// Gather rows: `out[i] = data[p[i]]` for row-major `[n, d]` data.
    pub fn apply_rows(&self, data: &[f32], d: usize) -> Vec<f32> {
        let n = self.len();
        assert_eq!(data.len(), n * d);
        let mut out = vec![0.0f32; n * d];
        for (i, &src) in self.idx.iter().enumerate() {
            let s = src as usize * d;
            out[i * d..(i + 1) * d].copy_from_slice(&data[s..s + d]);
        }
        out
    }

    /// In-place variant reusing a scratch buffer (hot path).
    pub fn apply_rows_into(&self, data: &[f32], d: usize, out: &mut Vec<f32>) {
        let n = self.len();
        assert_eq!(data.len(), n * d);
        out.clear();
        out.reserve(n * d);
        for &src in &self.idx {
            let s = src as usize * d;
            out.extend_from_slice(&data[s..s + d]);
        }
    }

    /// Fixed points (used by tests and the properties bench).
    pub fn fixed_points(&self) -> usize {
        self.idx.iter().enumerate().filter(|(i, &v)| *i == v as usize).count()
    }
}

/// Why an index vector is not a permutation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InvalidPermutation {
    pub n: usize,
    pub duplicates: usize,
    pub out_of_bounds: usize,
}

impl std::fmt::Display for InvalidPermutation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid permutation of {}: {} duplicates, {} out of bounds",
            self.n, self.duplicates, self.out_of_bounds
        )
    }
}

impl std::error::Error for InvalidPermutation {}

/// Greedy repair of a near-permutation (paper §II: in rare cases SoftSort
/// yields duplicate columns; after the iteration-extension budget runs out
/// we resolve deterministically). Duplicate/oob positions are reassigned the
/// unused indices in ascending order, preserving every valid entry.
/// Returns the repaired permutation and how many entries were rewritten.
pub fn repair(idx: &[u32]) -> (Permutation, usize) {
    let n = idx.len();
    let mut seen = vec![false; n];
    let mut out = idx.to_vec();
    let mut bad = Vec::new();
    for (i, v) in out.iter().enumerate() {
        let v = *v as usize;
        if v < n && !seen[v] {
            seen[v] = true;
        } else {
            bad.push(i);
        }
    }
    let mut unused = (0..n as u32).filter(|&v| !seen[v as usize]);
    for &i in &bad {
        out[i] = unused.next().expect("counts must balance");
    }
    let repaired = bad.len();
    (Permutation::from_vec(out).expect("repair produces a bijection"), repaired)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_perm(rng: &mut Pcg32, n: usize) -> Permutation {
        Permutation::from_vec(rng.permutation(n)).unwrap()
    }

    #[test]
    fn identity_applies_as_noop() {
        let p = Permutation::identity(4);
        let data = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        assert_eq!(p.apply_rows(&data, 2), data);
        assert_eq!(p.fixed_points(), 4);
    }

    #[test]
    fn from_vec_rejects_duplicates_and_oob() {
        let e = Permutation::from_vec(vec![0, 1, 1, 5]).unwrap_err();
        assert_eq!(e.duplicates, 1);
        assert_eq!(e.out_of_bounds, 1);
        assert_eq!(Permutation::count_duplicates(&[0, 1, 1, 5]), 2);
    }

    #[test]
    fn inverse_round_trip_property() {
        let mut rng = Pcg32::new(11);
        for n in [1usize, 2, 7, 64, 257] {
            for _ in 0..5 {
                let p = random_perm(&mut rng, n);
                let inv = p.inverse();
                assert_eq!(p.compose(&inv), Permutation::identity(n));
                assert_eq!(inv.compose(&p), Permutation::identity(n));
            }
        }
    }

    #[test]
    fn compose_matches_sequential_application_property() {
        let mut rng = Pcg32::new(12);
        for _ in 0..10 {
            let n = 33;
            let d = 3;
            let a = random_perm(&mut rng, n);
            let b = random_perm(&mut rng, n);
            let data: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
            // apply b then a == apply (a∘b)
            let seq = a.apply_rows(&b.apply_rows(&data, d), d);
            let comp = a.compose(&b).apply_rows(&data, d);
            assert_eq!(seq, comp);
        }
    }

    #[test]
    fn apply_rows_gathers() {
        let p = Permutation::from_vec(vec![2, 0, 1]).unwrap();
        let data = vec![10.0, 20.0, 30.0];
        assert_eq!(p.apply_rows(&data, 1), vec![30.0, 10.0, 20.0]);
    }

    #[test]
    fn apply_rows_into_matches_apply_rows() {
        let mut rng = Pcg32::new(13);
        let p = random_perm(&mut rng, 40);
        let data: Vec<f32> = (0..40 * 5).map(|_| rng.f32()).collect();
        let mut buf = Vec::new();
        p.apply_rows_into(&data, 5, &mut buf);
        assert_eq!(buf, p.apply_rows(&data, 5));
    }

    #[test]
    fn repair_fixes_duplicates_minimally() {
        let (p, fixed) = repair(&[0, 2, 2, 3]);
        assert_eq!(fixed, 1);
        assert_eq!(p.as_slice(), &[0, 2, 1, 3]);

        let (p2, fixed2) = repair(&[1, 1, 1, 1]);
        assert_eq!(fixed2, 3);
        assert_eq!(p2.as_slice(), &[1, 0, 2, 3]);

        // Already valid → untouched.
        let (p3, fixed3) = repair(&[3, 1, 0, 2]);
        assert_eq!(fixed3, 0);
        assert_eq!(p3.as_slice(), &[3, 1, 0, 2]);
    }

    #[test]
    fn repair_always_valid_property() {
        let mut rng = Pcg32::new(14);
        for _ in 0..50 {
            let n = 20;
            let idx: Vec<u32> = (0..n).map(|_| rng.below(n as u32 + 4)).collect();
            let (p, _) = repair(&idx);
            assert_eq!(p.len(), n as usize);
        }
    }
}
