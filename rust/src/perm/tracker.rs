//! Tracks the permutation composed across ShuffleSoftSort phases.
//!
//! Algorithm 1 carries state implicitly by reordering the data between
//! phases (`x ← reverse_shuffle(sort(shuffle(x)))`). The coordinator instead
//! keeps the *original* data immutable and composes the per-phase
//! permutations here, so the final result is a single `Permutation` mapping
//! grid positions to original item indices. The invariant
//! `current_arrangement == tracker.perm().apply_rows(original, d)`
//! is enforced by tests and cheap to assert in debug builds.

use super::Permutation;

#[derive(Clone, Debug)]
pub struct Tracker {
    /// Composed permutation: grid position → original item index.
    perm: Permutation,
}

impl Tracker {
    pub fn new(n: usize) -> Self {
        Tracker { perm: Permutation::identity(n) }
    }

    pub fn perm(&self) -> &Permutation {
        &self.perm
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Record one phase: the arrangement was shuffled with `shuf`
    /// (`x_shuf[i] = x[shuf[i]]`), SoftSort produced `sort` over the
    /// shuffled order (`x_sorted[i] = x_shuf[sort[i]]`), and the result was
    /// scattered back through the shuffle
    /// (`x_new[shuf[i]] = x_sorted[i]`, Algorithm 1's
    /// `x_sort[shuf_idx] = x_shuf[sort_idx]`).
    ///
    /// Net per-phase update: `x_new = (shuf⁻¹ ∘ sort ∘ shuf)(x_old)`, so the
    /// tracked permutation becomes `phase ∘ perm`.
    pub fn record_phase(&mut self, shuf: &Permutation, sort: &Permutation) {
        assert_eq!(shuf.len(), self.len());
        assert_eq!(sort.len(), self.len());
        let phase = shuf.inverse().compose(sort).compose(shuf);
        self.perm = phase.compose(&self.perm);
    }

    /// Current arrangement of the original row-major data.
    pub fn arrange(&self, original: &[f32], d: usize) -> Vec<f32> {
        self.perm.apply_rows(original, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Reference implementation: mutate the data exactly as Algorithm 1 does.
    fn algo1_phase(x: &mut Vec<f32>, d: usize, shuf: &Permutation, sort: &Permutation) {
        let n = shuf.len();
        let x_shuf = shuf.apply_rows(x, d);
        let x_sorted = sort.apply_rows(&x_shuf, d);
        let mut x_new = vec![0.0f32; n * d];
        for i in 0..n {
            let dst = shuf.as_slice()[i] as usize;
            x_new[dst * d..(dst + 1) * d].copy_from_slice(&x_sorted[i * d..(i + 1) * d]);
        }
        *x = x_new;
    }

    #[test]
    fn tracker_invariant_over_many_random_phases() {
        let mut rng = Pcg32::new(21);
        let n = 48;
        let d = 3;
        let original: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let mut live = original.clone();
        let mut tracker = Tracker::new(n);
        for _ in 0..25 {
            let shuf = Permutation::from_vec(rng.permutation(n)).unwrap();
            let sort = Permutation::from_vec(rng.permutation(n)).unwrap();
            algo1_phase(&mut live, d, &shuf, &sort);
            tracker.record_phase(&shuf, &sort);
            assert_eq!(tracker.arrange(&original, d), live);
        }
    }

    #[test]
    fn identity_phases_keep_identity() {
        let n = 16;
        let mut t = Tracker::new(n);
        let id = Permutation::identity(n);
        t.record_phase(&id, &id);
        assert_eq!(t.perm(), &Permutation::identity(n));
    }

    #[test]
    fn single_phase_identity_shuffle_is_just_sort() {
        let mut rng = Pcg32::new(22);
        let n = 10;
        let id = Permutation::identity(n);
        let sort = Permutation::from_vec(rng.permutation(n)).unwrap();
        let mut t = Tracker::new(n);
        t.record_phase(&id, &sort);
        assert_eq!(t.perm(), &sort);
    }
}
