//! Deterministic PRNG substrate (offline replacement for the `rand` crate).
//!
//! `Pcg32` (O'Neill's PCG-XSH-RR 64/32) seeded through SplitMix64, plus the
//! samplers this project needs: uniform floats, bounded ints without modulo
//! bias (Lemire), Fisher–Yates shuffles, Gaussian (Box–Muller) and Gumbel
//! variates. Everything is reproducible from a single `u64` seed — every
//! experiment in EXPERIMENTS.md records its seed.

/// SplitMix64 — used to expand one seed into stream/state constants.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MUL: u64 = 6364136223846793005;

    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-phase / per-worker RNGs).
    pub fn split(&mut self) -> Pcg32 {
        Pcg32::new((self.next_u32() as u64) << 32 | self.next_u32() as u64)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 24 bits of mantissa entropy.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53 bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) — Lemire's method, no modulo bias.
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Standard Gumbel(0,1) variate: -ln(-ln(U)).
    pub fn gumbel(&mut self) -> f32 {
        loop {
            let u = self.f64();
            if u > 1e-12 && u < 1.0 - 1e-12 {
                return (-(-u.ln()).ln()) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::new(8);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn f32_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::new(1);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::new(2);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11100).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn permutation_is_valid_and_varies() {
        let mut r = Pcg32::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
        let q = r.permutation(257);
        assert_ne!(p, q);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(4);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.gaussian() as f64;
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut r = Pcg32::new(5);
        let n = 100_000;
        let mut s = 0.0f64;
        for _ in 0..n {
            s += r.gumbel() as f64;
        }
        let mean = s / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut r = Pcg32::new(6);
        let mut a = r.split();
        let mut b = r.split();
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u32() == b.next_u32() {
                same += 1;
            }
        }
        assert!(same < 3);
    }
}
