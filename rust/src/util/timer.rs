//! Lightweight wall-clock timing + per-section accumulators used by the
//! coordinator's metrics and the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A one-shot stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named section timings across a run (e.g. execute vs adam vs
/// shuffle) — the L3 profiling primitive behind EXPERIMENTS.md §Perf.
#[derive(Debug, Default)]
pub struct Sections {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

impl Sections {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        let e = self.acc.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += t.elapsed();
        e.1 += 1;
        out
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        let e = self.acc.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Fold another accumulator into this one (summing durations and call
    /// counts per section) — how the tiled executor's per-tile `Sections`
    /// reach the run's `RunReport.sections`. Merging is commutative, but
    /// callers fold in deterministic tile-index order anyway so reports
    /// are reproducible byte-for-byte.
    pub fn merge(&mut self, other: &Sections) {
        for (name, (dur, n)) in &other.acc {
            let e = self.acc.entry(name).or_insert((Duration::ZERO, 0));
            e.0 += *dur;
            e.1 += *n;
        }
    }

    pub fn total(&self, name: &str) -> Duration {
        self.acc.get(name).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.acc.get(name).map(|e| e.1).unwrap_or(0)
    }

    /// "execute: 1.234s/2400 calls (0.51ms avg); adam: ..." summary line.
    pub fn report(&self) -> String {
        let mut parts = Vec::new();
        for (name, (dur, n)) in &self.acc {
            let avg_ms = if *n > 0 {
                dur.as_secs_f64() * 1e3 / *n as f64
            } else {
                0.0
            };
            parts.push(format!(
                "{name}: {:.3}s/{n} calls ({avg_ms:.3}ms avg)",
                dur.as_secs_f64()
            ));
        }
        parts.join("; ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_accumulate() {
        let mut s = Sections::new();
        for _ in 0..3 {
            s.time("work", || std::thread::sleep(Duration::from_millis(2)));
        }
        assert_eq!(s.count("work"), 3);
        assert!(s.total("work") >= Duration::from_millis(6));
        assert!(s.report().contains("work"));
        assert_eq!(s.count("missing"), 0);
    }

    #[test]
    fn merge_sums_durations_and_counts() {
        let mut a = Sections::new();
        a.add("execute", Duration::from_millis(10));
        a.add("adam", Duration::from_millis(1));
        let mut b = Sections::new();
        b.add("execute", Duration::from_millis(5));
        b.add("execute", Duration::from_millis(5));
        b.add("shuffle", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.total("execute"), Duration::from_millis(20));
        assert_eq!(a.count("execute"), 3);
        assert_eq!(a.total("adam"), Duration::from_millis(1));
        assert_eq!(a.total("shuffle"), Duration::from_millis(2));
        assert_eq!(a.count("shuffle"), 1);
    }
}
