//! Lightweight wall-clock timing + per-section accumulators used by the
//! coordinator's metrics and the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A one-shot stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named section timings across a run (e.g. execute vs adam vs
/// shuffle) — the L3 profiling primitive behind EXPERIMENTS.md §Perf.
#[derive(Debug, Default)]
pub struct Sections {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

impl Sections {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        let e = self.acc.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += t.elapsed();
        e.1 += 1;
        out
    }

    pub fn add(&mut self, name: &'static str, d: Duration) {
        let e = self.acc.entry(name).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    pub fn total(&self, name: &str) -> Duration {
        self.acc.get(name).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.acc.get(name).map(|e| e.1).unwrap_or(0)
    }

    /// "execute: 1.234s/2400 calls (0.51ms avg); adam: ..." summary line.
    pub fn report(&self) -> String {
        let mut parts = Vec::new();
        for (name, (dur, n)) in &self.acc {
            let avg_ms = if *n > 0 {
                dur.as_secs_f64() * 1e3 / *n as f64
            } else {
                0.0
            };
            parts.push(format!(
                "{name}: {:.3}s/{n} calls ({avg_ms:.3}ms avg)",
                dur.as_secs_f64()
            ));
        }
        parts.join("; ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_accumulate() {
        let mut s = Sections::new();
        for _ in 0..3 {
            s.time("work", || std::thread::sleep(Duration::from_millis(2)));
        }
        assert_eq!(s.count("work"), 3);
        assert!(s.total("work") >= Duration::from_millis(6));
        assert!(s.report().contains("work"));
        assert_eq!(s.count("missing"), 0);
    }
}
