//! Self-contained utility substrates.
//!
//! The build is fully offline (DESIGN.md §6): no `rand`, `serde`,
//! `criterion` or `clap` — the pieces of those crates this project needs
//! are implemented (and tested) here.

pub mod json;
pub mod ppm;
pub mod rng;
pub mod stats;
pub mod timer;
