//! Binary PPM (P6) image writer — renders sorted color grids (Fig. 1/5
//! reproductions) without an image crate.

use std::io::Write;
use std::path::Path;

/// Write an H×W RGB image; `rgb` is row-major [h][w][3], values in [0,1].
pub fn write_ppm(path: &Path, rgb: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    assert_eq!(rgb.len(), h * w * 3);
    let mut buf = Vec::with_capacity(h * w * 3 + 32);
    write!(buf, "P6\n{w} {h}\n255\n")?;
    for &v in rgb {
        buf.push((v.clamp(0.0, 1.0) * 255.0).round() as u8);
    }
    std::fs::write(path, buf)
}

/// Upscale each grid cell to `cell`×`cell` pixels (viewable thumbnails).
pub fn write_ppm_upscaled(
    path: &Path,
    rgb: &[f32],
    h: usize,
    w: usize,
    cell: usize,
) -> std::io::Result<()> {
    assert_eq!(rgb.len(), h * w * 3);
    let (hh, ww) = (h * cell, w * cell);
    let mut big = vec![0.0f32; hh * ww * 3];
    for y in 0..hh {
        for x in 0..ww {
            let src = ((y / cell) * w + (x / cell)) * 3;
            let dst = (y * ww + x) * 3;
            big[dst..dst + 3].copy_from_slice(&rgb[src..src + 3]);
        }
    }
    write_ppm(path, &big, hh, ww)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_header_and_payload() {
        let dir = std::env::temp_dir().join("shufflesort_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        let img = vec![0.0, 0.5, 1.0, 1.0, 0.0, 0.0];
        write_ppm(&p, &img, 1, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n2 1\n255\n"));
        assert_eq!(bytes.len(), "P6\n2 1\n255\n".len() + 6);
        assert_eq!(&bytes[bytes.len() - 6..], &[0, 128, 255, 255, 0, 0]);
    }

    #[test]
    fn upscale_dimensions() {
        let dir = std::env::temp_dir().join("shufflesort_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("u.ppm");
        let img = vec![0.25; 4 * 3];
        write_ppm_upscaled(&p, &img, 2, 2, 3).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n6 6\n255\n"));
    }
}
