//! Minimal JSON parser/printer (offline replacement for `serde_json`).
//!
//! Supports the full JSON grammar the artifact manifest and config files
//! use: objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are stored as `f64` — fine for manifest shapes (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization (round-trips through `parse`).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: manifest content is ASCII, but
                            // handle them for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i..self.i + 4])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 4;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        _ => 3,
                    };
                    let start = self.i - 1;
                    self.i += len;
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{"version": 1, "artifacts": [{"name": "sss_step_n64_d3_h8",
            "n": 64, "inputs": [{"name": "w", "dtype": "f32", "shape": [64]}]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let a = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(a[0].get("name").unwrap().as_str(), Some("sss_step_n64_d3_h8"));
        assert_eq!(
            a[0].get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(64)
        );
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2.5,-3e2],"b":"x\ny","c":true,"d":null,"e":{}}"#,
            r#"[]"#,
            r#""unicode: é""#,
            r#"-0.125"#,
        ];
        for c in cases {
            let j = Json::parse(c).unwrap();
            let s = j.to_string_compact();
            assert_eq!(Json::parse(&s).unwrap(), j, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn nested_depth_and_escapes() {
        let j = Json::parse(r#"{"s": "tab\t\"q\" \\ end", "n": [[[[1]]]]}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("tab\t\"q\" \\ end"));
    }
}
