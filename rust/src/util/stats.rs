//! Small numeric/statistics helpers shared by metrics and benches.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Population std of an f32 slice (matches `jnp.std` over all entries).
pub fn std_f32(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let n = xs.len() as f64;
    let m = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let v = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    v.sqrt() as f32
}

/// Euclidean distance between two d-dim vectors.
#[inline]
pub fn l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s.sqrt()
}

/// Squared Euclidean distance (hot path of DPQ / heuristics — no sqrt).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Mean pairwise L2 distance, estimated from up to `max_pairs` random pairs.
/// This is the `norm` scalar fed to the L_nbr loss (DESIGN §7).
pub fn mean_pairwise_distance(
    data: &[f32],
    n: usize,
    d: usize,
    max_pairs: usize,
    rng: &mut crate::util::rng::Pcg32,
) -> f32 {
    assert_eq!(data.len(), n * d);
    if n < 2 {
        return 1.0;
    }
    let total_pairs = n * (n - 1) / 2;
    let mut sum = 0.0f64;
    let count = total_pairs.min(max_pairs);
    if total_pairs <= max_pairs {
        for i in 0..n {
            for j in (i + 1)..n {
                sum += l2(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]) as f64;
            }
        }
    } else {
        for _ in 0..count {
            let i = rng.below(n as u32) as usize;
            let mut j = rng.below(n as u32) as usize;
            while j == i {
                j = rng.below(n as u32) as usize;
            }
            sum += l2(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]) as f64;
        }
    }
    (sum / count as f64).max(1e-9) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn l2_matches_hand() {
        assert_eq!(l2(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(l2_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn pairwise_exact_vs_sampled_agree() {
        let mut rng = Pcg32::new(1);
        let n = 64;
        let d = 3;
        let data: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
        let exact = mean_pairwise_distance(&data, n, d, usize::MAX, &mut rng);
        let sampled = mean_pairwise_distance(&data, n, d, 1500, &mut rng);
        assert!((exact - sampled).abs() / exact < 0.08, "{exact} vs {sampled}");
    }
}
