//! PJRT-backed [`StepBackend`]: executes the AOT HLO artifacts through the
//! existing [`Runtime`] (manifest-driven compile cache, CPU PJRT client).
//!
//! This is the original compute path, now behind the backend trait so
//! drivers no longer know about artifacts at all. Only compiled with the
//! `pjrt` cargo feature; a `--no-default-features` build ships the
//! [`super::NativeBackend`] alone.
//!
//! Not `Send`/`Sync` (the runtime's compile cache is `Rc`/`RefCell`), so
//! `Engine::sort_batch` builds one `PjrtBackend` per worker — exactly the
//! per-worker-`Runtime` behavior this backend inherited.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::{Arg, Runtime};

use super::{GsStep, KissStep, SssStep, StepBackend, StepShape};

/// Backend executing AOT artifacts via the PJRT runtime.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Wrap an already-loaded runtime.
    pub fn new(rt: Runtime) -> Self {
        PjrtBackend { rt }
    }

    /// Load the artifact manifest at `dir` and start a CPU PJRT client.
    pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Self> {
        Runtime::from_manifest(dir).map(PjrtBackend::new)
    }

    /// The wrapped runtime (manifest inspection, direct executable access).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl StepBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn sss_step(
        &self,
        shape: StepShape,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
    ) -> Result<SssStep> {
        let StepShape { n, d, h, .. } = shape;
        let exe = self
            .rt
            .sss_step(n, d, h)
            .with_context(|| format!("no sss artifact for N={n} d={d} h={h}"))?;
        let out = exe.run(&[
            Arg::F32(w),
            Arg::F32(x_shuf),
            Arg::I32(inv_idx),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        Ok(SssStep {
            loss: out[0].scalar_f32()?,
            grad: out[1].as_f32()?.to_vec(),
            sort_idx: out[2].as_i32()?.to_vec(),
            colsum: out[3].as_f32()?.to_vec(),
            y: out[4].as_f32()?.to_vec(),
        })
    }

    fn gs_step(
        &self,
        shape: StepShape,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<GsStep> {
        let StepShape { n, d, h, .. } = shape;
        let exe = self
            .rt
            .gs_step(n, d, h)
            .with_context(|| format!("no gumbel-sinkhorn artifact for N={n} d={d} h={h}"))?;
        let out = exe.run(&[
            Arg::F32(logits),
            Arg::F32(x),
            Arg::F32(gumbel),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        Ok(GsStep { loss: out[0].scalar_f32()?, grad: out[1].as_f32()?.to_vec() })
    }

    fn gs_probe(&self, n: usize, logits: &[f32], tau: f32) -> Result<Vec<f32>> {
        let probe = self.rt.gs_probe(n)?;
        // The probe artifact takes a noise input; the final extraction is
        // always noise-free.
        let zeros = vec![0.0f32; n * n];
        let out = probe.run(&[Arg::F32(logits), Arg::F32(&zeros), Arg::ScalarF32(tau)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    fn gs_probe_ready(&self, n: usize) -> Result<()> {
        // Resolves + compiles the probe artifact now (the runtime caches
        // it, so the real probe call later reuses the compilation).
        self.rt
            .gs_probe(n)
            .with_context(|| format!("no gs_probe artifact for N={n}"))
            .map(|_| ())
    }

    fn kiss_rank(&self, n: usize, d: usize) -> Result<usize> {
        // Rank follows the manifest (kissing-number rule, shapes.py).
        self.rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.method == "kiss" && a.n == n && a.d == d)
            .map(|a| a.m)
            .with_context(|| format!("no kissing artifact for N={n} d={d}"))
    }

    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &self,
        shape: StepShape,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<KissStep> {
        let StepShape { n, d, .. } = shape;
        let exe = self
            .rt
            .kiss_step(n, m, d)
            .with_context(|| format!("no kissing artifact for N={n} M={m} d={d}"))?;
        let out = exe.run(&[
            Arg::F32(v),
            Arg::F32(wf),
            Arg::F32(x),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        Ok(KissStep {
            loss: out[0].scalar_f32()?,
            grad_v: out[1].as_f32()?.to_vec(),
            grad_w: out[2].as_f32()?.to_vec(),
            sort_idx: out[3].as_i32()?.to_vec(),
        })
    }
}
