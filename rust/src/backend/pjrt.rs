//! PJRT-backed [`StepBackend`]: executes the AOT HLO artifacts through the
//! existing [`Runtime`] (manifest-driven compile cache, CPU PJRT client).
//!
//! This is the original compute path, now behind the backend trait so
//! drivers no longer know about artifacts at all. Only compiled with the
//! `pjrt` cargo feature; a `--no-default-features` build ships the
//! [`super::NativeBackend`] alone.
//!
//! Sessions ([`StepBackend::session`]) wrap the runtime's executable
//! lookup: the `(n, d, h)` executables are resolved once per session and
//! pinned as `Rc<Executable>` handles, so the steady-state step loop skips
//! the name formatting + string-keyed cache probe entirely, and results
//! are copied into the caller's reusable out buffers. The runtime itself
//! is held behind an `Rc`, so sessions are `'static` like native ones.
//!
//! Not `Send`/`Sync` (the runtime's compile cache is `Rc`/`RefCell`), so
//! `Engine::sort_batch` builds one `PjrtBackend` per worker — exactly the
//! per-worker-`Runtime` behavior this backend inherited.

use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::runtime::{Arg, Executable, Runtime};

use super::{GsStep, KissStep, SessionOpts, SssStep, StepBackend, StepSession, StepShape};

/// Backend executing AOT artifacts via the PJRT runtime.
pub struct PjrtBackend {
    rt: Rc<Runtime>,
}

impl PjrtBackend {
    /// Wrap an already-loaded runtime.
    pub fn new(rt: Runtime) -> Self {
        PjrtBackend { rt: Rc::new(rt) }
    }

    /// Load the artifact manifest at `dir` and start a CPU PJRT client.
    pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Self> {
        Runtime::from_manifest(dir).map(PjrtBackend::new)
    }

    /// The wrapped runtime (manifest inspection, direct executable access).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

/// A step session pinning the resolved executables for one `(n, d, h)`
/// shape. Executables resolve lazily per step family (a GS run has no sss
/// artifact to resolve) and are cached for the session's lifetime.
struct PjrtSession {
    rt: Rc<Runtime>,
    shape: StepShape,
    sss_exe: Option<Rc<Executable>>,
    gs_exe: Option<Rc<Executable>>,
    probe_exe: Option<Rc<Executable>>,
    /// Keyed by the factor rank M (constant per driver run).
    kiss_exe: Option<(usize, Rc<Executable>)>,
    /// Zero noise for the probe artifact (lazily sized N²).
    probe_zeros: Vec<f32>,
}

fn copy_f32(dst: &mut Vec<f32>, src: &[f32]) {
    dst.clear();
    dst.extend_from_slice(src);
}

fn copy_i32(dst: &mut Vec<i32>, src: &[i32]) {
    dst.clear();
    dst.extend_from_slice(src);
}

impl StepSession for PjrtSession {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn shape(&self) -> StepShape {
        self.shape
    }

    fn sss_step(
        &mut self,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
        out: &mut SssStep,
    ) -> Result<()> {
        let StepShape { n, d, h, .. } = self.shape;
        if self.sss_exe.is_none() {
            let exe = self
                .rt
                .sss_step(n, d, h)
                .with_context(|| format!("no sss artifact for N={n} d={d} h={h}"))?;
            self.sss_exe = Some(exe);
        }
        let exe = self.sss_exe.as_ref().expect("resolved above");
        let vals = exe.run(&[
            Arg::F32(w),
            Arg::F32(x_shuf),
            Arg::I32(inv_idx),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        out.loss = vals[0].scalar_f32()?;
        copy_f32(&mut out.grad, vals[1].as_f32()?);
        copy_i32(&mut out.sort_idx, vals[2].as_i32()?);
        copy_f32(&mut out.colsum, vals[3].as_f32()?);
        copy_f32(&mut out.y, vals[4].as_f32()?);
        Ok(())
    }

    fn gs_step(
        &mut self,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
        out: &mut GsStep,
    ) -> Result<()> {
        let StepShape { n, d, h, .. } = self.shape;
        if self.gs_exe.is_none() {
            let exe = self
                .rt
                .gs_step(n, d, h)
                .with_context(|| format!("no gumbel-sinkhorn artifact for N={n} d={d} h={h}"))?;
            self.gs_exe = Some(exe);
        }
        let exe = self.gs_exe.as_ref().expect("resolved above");
        let vals = exe.run(&[
            Arg::F32(logits),
            Arg::F32(x),
            Arg::F32(gumbel),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        out.loss = vals[0].scalar_f32()?;
        copy_f32(&mut out.grad, vals[1].as_f32()?);
        Ok(())
    }

    fn gs_probe(&mut self, logits: &[f32], tau: f32, out: &mut Vec<f32>) -> Result<()> {
        let n = self.shape.n;
        if self.probe_exe.is_none() {
            let exe = self
                .rt
                .gs_probe(n)
                .with_context(|| format!("no gs_probe artifact for N={n}"))?;
            self.probe_exe = Some(exe);
        }
        let exe = self.probe_exe.as_ref().expect("resolved above");
        // The probe artifact takes a noise input; the final extraction is
        // always noise-free.
        self.probe_zeros.resize(n * n, 0.0);
        let vals = exe.run(&[
            Arg::F32(logits),
            Arg::F32(&self.probe_zeros),
            Arg::ScalarF32(tau),
        ])?;
        copy_f32(out, vals[0].as_f32()?);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &mut self,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
        out: &mut KissStep,
    ) -> Result<()> {
        let StepShape { n, d, .. } = self.shape;
        if self.kiss_exe.as_ref().map(|(mm, _)| *mm) != Some(m) {
            let exe = self
                .rt
                .kiss_step(n, m, d)
                .with_context(|| format!("no kissing artifact for N={n} M={m} d={d}"))?;
            self.kiss_exe = Some((m, exe));
        }
        let (_, exe) = self.kiss_exe.as_ref().expect("resolved above");
        let vals = exe.run(&[
            Arg::F32(v),
            Arg::F32(wf),
            Arg::F32(x),
            Arg::ScalarF32(tau),
            Arg::ScalarF32(norm),
        ])?;
        out.loss = vals[0].scalar_f32()?;
        copy_f32(&mut out.grad_v, vals[1].as_f32()?);
        copy_f32(&mut out.grad_w, vals[2].as_f32()?);
        copy_i32(&mut out.sort_idx, vals[3].as_i32()?);
        Ok(())
    }
}

impl StepBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn session(&self, shape: StepShape, _opts: SessionOpts) -> Result<Box<dyn StepSession>> {
        Ok(Box::new(PjrtSession {
            rt: Rc::clone(&self.rt),
            shape,
            sss_exe: None,
            gs_exe: None,
            probe_exe: None,
            kiss_exe: None,
            probe_zeros: Vec::new(),
        }))
    }

    fn gs_probe_ready(&self, n: usize) -> Result<()> {
        // Resolves + compiles the probe artifact now (the runtime caches
        // it, so the real probe call later reuses the compilation).
        self.rt
            .gs_probe(n)
            .with_context(|| format!("no gs_probe artifact for N={n}"))
            .map(|_| ())
    }

    fn kiss_rank(&self, n: usize, d: usize) -> Result<usize> {
        // Rank follows the manifest (kissing-number rule, shapes.py).
        self.rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.method == "kiss" && a.n == n && a.d == d)
            .map(|a| a.m)
            .with_context(|| format!("no kissing artifact for N={n} d={d}"))
    }
}
