//! Compute backends: the `StepBackend` abstraction over "execute one
//! optimization step" for the learned methods, with two interchangeable
//! implementations.
//!
//! The paper's methods decompose into an L3 policy loop (Rust, the
//! `coordinator` module) around a stateless per-step compute function
//! (SoftSort forward, grid loss, analytic gradient — see
//! `python/compile/model.py` / `losses.py`). Historically that step was
//! *only* reachable through AOT-compiled XLA artifacts executed by the
//! PJRT runtime, which made the whole crate untestable without
//! `make artifacts` and pinned `Engine::sort_batch` to one `Runtime` per
//! worker thread (the runtime's compile cache is `Rc`/`RefCell`).
//!
//! This module breaks that coupling:
//!
//! * [`StepBackend`] — the trait: one method per artifact family
//!   (`sss_step`, `gs_step`, `gs_probe`, `kiss_step`), mirroring the
//!   artifact signatures exactly, so drivers are backend-agnostic.
//! * [`NativeBackend`] — the full step in pure Rust: row-softmax of the
//!   N×N SoftSort matrix, the eq. (2) loss, and a hand-derived backward
//!   pass, chunk-parallel over rows with a deterministic reduction order
//!   (results are bit-identical for any thread count). `Send + Sync`, so
//!   batch workers share one instance. Zero native dependencies: every
//!   learned method runs on a bare machine with no `artifacts/` directory.
//! * [`PjrtBackend`] — the original path: wraps `runtime::Runtime` and
//!   executes the AOT HLO artifacts. Only compiled with the `pjrt` cargo
//!   feature (on by default); `--no-default-features` builds a pure-Rust
//!   crate.
//!
//! Selection is by [`BackendChoice`]: `native`, `pjrt`, or `auto` (prefer
//! artifacts when the manifest is present, fall back to native). The
//! `Engine` exposes it as the `--backend` CLI flag and the `backend=...`
//! override pair; see `api::engine`.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use anyhow::{anyhow, Result};

use crate::grid::GridShape;

/// Static problem shape of one step: N items of dimension d on an h×w grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepShape {
    pub n: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
}

impl StepShape {
    pub fn new(g: GridShape, d: usize) -> Self {
        StepShape { n: g.n(), d, h: g.h, w: g.w }
    }

    pub fn grid(&self) -> GridShape {
        GridShape::new(self.h, self.w)
    }
}

/// One SoftSort/ShuffleSoftSort step result (mirrors the `sss_step`
/// artifact outputs: loss, grad, sort_idx, colsum, y).
#[derive(Clone, Debug)]
pub struct SssStep {
    pub loss: f32,
    /// dL/dw, length N.
    pub grad: Vec<f32>,
    /// Row-argmax of P — the hard permutation draft, length N.
    pub sort_idx: Vec<i32>,
    /// Column sums of P (the L_s support), length N.
    pub colsum: Vec<f32>,
    /// Soft-sorted data P·x, length N·d.
    pub y: Vec<f32>,
}

/// One Gumbel-Sinkhorn step result (loss + dL/dlogits over N² entries).
#[derive(Clone, Debug)]
pub struct GsStep {
    pub loss: f32,
    pub grad: Vec<f32>,
}

/// One Kissing step result (loss, the two factor gradients, row argmax).
#[derive(Clone, Debug)]
pub struct KissStep {
    pub loss: f32,
    pub grad_v: Vec<f32>,
    pub grad_w: Vec<f32>,
    pub sort_idx: Vec<i32>,
}

/// A compute backend executing the learned methods' per-step functions.
///
/// Implementations mirror `python/compile/model.py` exactly — same inputs,
/// same outputs, same loss (eq. 2–4) — so the L3 drivers are oblivious to
/// where the arithmetic runs. The trait is object-safe; drivers hold a
/// `&dyn StepBackend`.
pub trait StepBackend {
    /// Human-readable backend name ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// One SoftSort/ShuffleSoftSort training step.
    ///
    /// `w`: trainable weights f32[N]; `x_shuf`: shuffled data f32[N·d];
    /// `inv_idx`: inverse shuffle permutation i32[N] (the loss is evaluated
    /// on the reverse-shuffled soft output); `tau`: temperature;
    /// `norm`: dataset mean pairwise distance (the L_nbr normalizer).
    fn sss_step(
        &self,
        shape: StepShape,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
    ) -> Result<SssStep>;

    /// One Gumbel-Sinkhorn training step over N² `logits`; `gumbel` is the
    /// pre-sampled noise (annealed Rust-side), same length.
    fn gs_step(
        &self,
        shape: StepShape,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<GsStep>;

    /// Noise-free dense doubly-stochastic P for final JV extraction.
    fn gs_probe(&self, n: usize, logits: &[f32], tau: f32) -> Result<Vec<f32>>;

    /// Fail fast if [`StepBackend::gs_probe`] would be unavailable for this
    /// `n` (e.g. a missing probe artifact). Called by the Gumbel-Sinkhorn
    /// driver *before* its optimization loop so a broken extraction path
    /// does not waste the whole run. Backends where the probe cannot fail
    /// to resolve keep this default no-op.
    fn gs_probe_ready(&self, n: usize) -> Result<()> {
        let _ = n;
        Ok(())
    }

    /// The Kissing low-rank dimension M for an (N, d) problem — from the
    /// artifact manifest (pjrt) or the kissing-number rule (native).
    fn kiss_rank(&self, n: usize, d: usize) -> Result<usize>;

    /// One Kissing step over the factor pair `v`, `wf` ∈ f32[N·M].
    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &self,
        shape: StepShape,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<KissStep>;
}

/// Which backend a session should use.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Prefer the PJRT artifacts when the manifest is present (and the
    /// `pjrt` feature is compiled in); fall back to native.
    #[default]
    Auto,
    /// Pure-Rust backend; never touches artifacts.
    Native,
    /// AOT artifacts via PJRT; errors when they are missing.
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Self::Auto),
            "native" | "rust" => Ok(Self::Native),
            "pjrt" | "xla" | "artifacts" => Ok(Self::Pjrt),
            other => Err(anyhow!(
                "unknown backend '{other}' — expected auto, native or pjrt"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_parses_and_round_trips() {
        for c in [BackendChoice::Auto, BackendChoice::Native, BackendChoice::Pjrt] {
            assert_eq!(BackendChoice::parse(c.name()).unwrap(), c);
        }
        assert_eq!(BackendChoice::parse("RUST").unwrap(), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("xla").unwrap(), BackendChoice::Pjrt);
        assert!(BackendChoice::parse("tpu").is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[test]
    fn step_shape_matches_grid() {
        let s = StepShape::new(GridShape::new(8, 4), 3);
        assert_eq!((s.n, s.d, s.h, s.w), (32, 3, 8, 4));
        assert_eq!(s.grid(), GridShape::new(8, 4));
    }
}
