//! Compute backends: the `StepBackend` abstraction over "execute one
//! optimization step" for the learned methods, with two interchangeable
//! implementations.
//!
//! The paper's methods decompose into an L3 policy loop (Rust, the
//! `coordinator` module) around a stateless per-step compute function
//! (SoftSort forward, grid loss, analytic gradient — see
//! `python/compile/model.py` / `losses.py`). Historically that step was
//! *only* reachable through AOT-compiled XLA artifacts executed by the
//! PJRT runtime, which made the whole crate untestable without
//! `make artifacts` and pinned `Engine::sort_batch` to one `Runtime` per
//! worker thread (the runtime's compile cache is `Rc`/`RefCell`).
//!
//! This module breaks that coupling:
//!
//! * [`StepBackend`] — the trait: opens a [`StepSession`] per problem
//!   shape, plus shape queries (`kiss_rank`) and stateless one-shot
//!   conveniences (`sss_step`, `gs_step`, `gs_probe`, `kiss_step`) that
//!   wrap a throwaway session, so drivers and old callers stay
//!   backend-agnostic.
//! * [`StepSession`] — the per-run hot path. The paper's whole point is
//!   that ShuffleSoftSort runs *many cheap steps* (Algorithm 1: R phases ×
//!   I inner iterations), so per-step overhead is the scaling bottleneck.
//!   A session owns (a) every per-shape scratch buffer — softmax rows,
//!   gradient chunk partials, column sums, the Sinkhorn state stack —
//!   allocated once and reused across steps, and (b) on the native
//!   backend, a persistent worker pool of parked threads replacing the
//!   old per-step `thread::scope`. Steps write their results into
//!   caller-owned [`SssStep`]/[`GsStep`]/[`KissStep`] buffers, so the
//!   steady-state step loop performs **zero heap allocations**. Sessions
//!   are `'static` (no borrow of the backend) but deliberately `!Send`-ish
//!   stateful: one session serves one driver loop; concurrent runs open
//!   one session each (see `Engine::sort_batch`).
//! * [`NativeBackend`] — the full step in pure Rust: row-softmax of the
//!   N×N SoftSort matrix, the eq. (2) loss, and a hand-derived backward
//!   pass, chunk-parallel over rows with a deterministic reduction order
//!   (results are bit-identical for any pool size — partials are
//!   accumulated per fixed-size chunk and folded in chunk-index order, so
//!   the f32 rounding sequence never depends on the thread count).
//!   `Send + Sync`, so batch workers share one instance; each worker's
//!   session owns its own pool. Zero native dependencies: every learned
//!   method runs on a bare machine with no `artifacts/` directory.
//! * [`PjrtBackend`] — the original path: wraps `runtime::Runtime` and
//!   executes the AOT HLO artifacts; its sessions pin the resolved
//!   `(n, d, h)` executables so steps skip the name-keyed cache lookup.
//!   Only compiled with the `pjrt` cargo feature (on by default);
//!   `--no-default-features` builds a pure-Rust crate.
//!
//! Selection is by [`BackendChoice`]: `native`, `pjrt`, or `auto` (prefer
//! artifacts when the manifest is present, fall back to native). The
//! `Engine` exposes it as the `--backend` CLI flag and the `backend=...`
//! override pair; pool sizing is the `--threads` flag / `threads=` config
//! override (0 = backend default); see `api::engine`.

pub mod native;
pub(crate) mod pool;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use simd::{SimdChoice, SimdLevel};

use anyhow::{anyhow, Result};

use crate::grid::GridShape;

/// Per-session construction knobs, passed to [`StepBackend::session`].
///
/// `Default` means "the backend's configured defaults": pool width from
/// the backend, SIMD level from runtime feature detection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SessionOpts {
    /// Row-parallel worker pool width for the native session (`None` =
    /// the backend's configured default; ignored by pjrt). Results never
    /// depend on the pool size.
    pub threads: Option<usize>,
    /// Which step-kernel implementation to use (`Auto` = best detected at
    /// runtime; `Off` = the scalar bit-exactness oracle; ignored by pjrt).
    pub simd: SimdChoice,
}

impl SessionOpts {
    /// Shorthand for a default-SIMD session with an explicit pool width.
    pub fn threads(t: usize) -> Self {
        SessionOpts { threads: Some(t), ..Default::default() }
    }
}

/// Static problem shape of one step: N items of dimension d on an h×w grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepShape {
    pub n: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
}

impl StepShape {
    pub fn new(g: GridShape, d: usize) -> Self {
        StepShape { n: g.n(), d, h: g.h, w: g.w }
    }

    pub fn grid(&self) -> GridShape {
        GridShape::new(self.h, self.w)
    }
}

/// One SoftSort/ShuffleSoftSort step result (mirrors the `sss_step`
/// artifact outputs: loss, grad, sort_idx, colsum, y). Doubles as the
/// session out-parameter: allocate once with [`SssStep::new_for`], pass
/// `&mut` to every [`StepSession::sss_step`] — the buffers are reused.
#[derive(Clone, Debug)]
pub struct SssStep {
    pub loss: f32,
    /// dL/dw, length N.
    pub grad: Vec<f32>,
    /// Row-argmax of P — the hard permutation draft, length N.
    pub sort_idx: Vec<i32>,
    /// Column sums of P (the L_s support), length N.
    pub colsum: Vec<f32>,
    /// Soft-sorted data P·x, length N·d.
    pub y: Vec<f32>,
}

impl SssStep {
    /// Zeroed output buffers sized for `shape` (one allocation per run).
    pub fn new_for(shape: StepShape) -> Self {
        SssStep {
            loss: 0.0,
            grad: vec![0.0; shape.n],
            sort_idx: vec![0; shape.n],
            colsum: vec![0.0; shape.n],
            y: vec![0.0; shape.n * shape.d],
        }
    }
}

/// One Gumbel-Sinkhorn step result (loss + dL/dlogits over N² entries).
#[derive(Clone, Debug)]
pub struct GsStep {
    pub loss: f32,
    pub grad: Vec<f32>,
}

impl GsStep {
    /// Zeroed output buffers for an N-item problem (grad is N²).
    pub fn new_for(n: usize) -> Self {
        GsStep { loss: 0.0, grad: vec![0.0; n * n] }
    }
}

/// One Kissing step result (loss, the two factor gradients, row argmax).
#[derive(Clone, Debug)]
pub struct KissStep {
    pub loss: f32,
    pub grad_v: Vec<f32>,
    pub grad_w: Vec<f32>,
    pub sort_idx: Vec<i32>,
}

impl KissStep {
    /// Zeroed output buffers for an (N, M) factor pair.
    pub fn new_for(n: usize, m: usize) -> Self {
        KissStep {
            loss: 0.0,
            grad_v: vec![0.0; n * m],
            grad_w: vec![0.0; n * m],
            sort_idx: vec![0; n],
        }
    }
}

/// A stateful per-shape step executor: the hot path of every learned
/// method. Obtained from [`StepBackend::session`]; owns all per-shape
/// scratch (and, natively, a persistent worker pool) so that driving many
/// steps through one session performs no steady-state heap allocation and
/// no per-step thread spawn. Results are written into caller-owned out
/// buffers (resized on first use if needed).
///
/// Sessions are single-consumer: `&mut self` methods, one optimization
/// loop per session. They do not borrow their backend (`'static`), so a
/// driver can own one outright; concurrent runs each open their own.
/// Outputs are bit-identical to the stateless [`StepBackend`] entry
/// points for any pool size.
pub trait StepSession {
    /// Name of the backend that opened this session.
    fn backend_name(&self) -> &'static str;

    /// The problem shape this session's buffers are sized for.
    fn shape(&self) -> StepShape;

    /// One SoftSort/ShuffleSoftSort step into `out` (see
    /// [`StepBackend::sss_step`] for the argument contract).
    fn sss_step(
        &mut self,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
        out: &mut SssStep,
    ) -> Result<()>;

    /// One Gumbel-Sinkhorn step into `out` (see [`StepBackend::gs_step`]).
    fn gs_step(
        &mut self,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
        out: &mut GsStep,
    ) -> Result<()>;

    /// Noise-free dense doubly-stochastic P into `out` (resized to N²).
    fn gs_probe(&mut self, logits: &[f32], tau: f32, out: &mut Vec<f32>) -> Result<()>;

    /// One Kissing step into `out` (see [`StepBackend::kiss_step`]).
    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &mut self,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
        out: &mut KissStep,
    ) -> Result<()>;
}

/// A compute backend executing the learned methods' per-step functions.
///
/// Implementations mirror `python/compile/model.py` exactly — same inputs,
/// same outputs, same loss (eq. 2–4) — so the L3 drivers are oblivious to
/// where the arithmetic runs. The trait is object-safe; drivers hold a
/// `&dyn StepBackend` and open one [`StepSession`] per optimization run.
///
/// The stateless `*_step` methods are compatibility conveniences: each
/// call opens a throwaway session, so they pay the full buffer-allocation
/// (and, natively, pool-spawn) cost per step — fine for one-shot calls and
/// tests, wrong for loops. Drivers use [`StepBackend::session`].
pub trait StepBackend {
    /// Human-readable backend name ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Open a step session for `shape`: all per-shape scratch is allocated
    /// up front (per step family, on first use) and reused across steps.
    ///
    /// `opts` carries the per-session knobs — pool width and SIMD level;
    /// `SessionOpts::default()` means the backend's configured defaults.
    /// Results never depend on either knob beyond the documented
    /// scalar-vs-SIMD tolerance (and never on the pool size at all).
    fn session(&self, shape: StepShape, opts: SessionOpts) -> Result<Box<dyn StepSession>>;

    /// Like [`StepBackend::session`], but the returned session may move
    /// across threads — what executors that dispatch independent
    /// sub-problems in parallel (the coordinator's tiled phase executor)
    /// need. Backends whose sessions are inherently thread-bound (PJRT:
    /// `Rc` caches) return `Ok(None)` and callers fall back to sequential
    /// dispatch; results are identical either way.
    fn session_sendable(
        &self,
        shape: StepShape,
        opts: SessionOpts,
    ) -> Result<Option<Box<dyn StepSession + Send>>> {
        let _ = (shape, opts);
        Ok(None)
    }

    /// What `opts.threads: None` means to [`StepBackend::session`]: the
    /// backend's configured pool width. Executors that spread their own
    /// parallelism (tile dispatch) budget against this, so an engine that
    /// capped the backend for batching caps them too.
    fn default_threads(&self) -> usize {
        1
    }

    /// Fail fast if the GS probe would be unavailable for this `n` (e.g. a
    /// missing probe artifact). Called by the Gumbel-Sinkhorn driver
    /// *before* its optimization loop so a broken extraction path does not
    /// waste the whole run. Backends where the probe cannot fail to
    /// resolve keep this default no-op.
    fn gs_probe_ready(&self, n: usize) -> Result<()> {
        let _ = n;
        Ok(())
    }

    /// The Kissing low-rank dimension M for an (N, d) problem — from the
    /// artifact manifest (pjrt) or the kissing-number rule (native).
    fn kiss_rank(&self, n: usize, d: usize) -> Result<usize>;

    /// One stateless SoftSort/ShuffleSoftSort training step (throwaway
    /// session; bit-identical to the session path).
    ///
    /// `w`: trainable weights f32[N]; `x_shuf`: shuffled data f32[N·d];
    /// `inv_idx`: inverse shuffle permutation i32[N] (the loss is evaluated
    /// on the reverse-shuffled soft output); `tau`: temperature;
    /// `norm`: dataset mean pairwise distance (the L_nbr normalizer).
    fn sss_step(
        &self,
        shape: StepShape,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
    ) -> Result<SssStep> {
        let mut session = self.session(shape, SessionOpts::default())?;
        let mut out = SssStep::new_for(shape);
        session.sss_step(w, x_shuf, inv_idx, tau, norm, &mut out)?;
        Ok(out)
    }

    /// One stateless Gumbel-Sinkhorn training step over N² `logits`;
    /// `gumbel` is the pre-sampled noise (annealed Rust-side), same
    /// length. Throwaway session; see [`StepBackend::session`].
    fn gs_step(
        &self,
        shape: StepShape,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<GsStep> {
        let mut session = self.session(shape, SessionOpts::default())?;
        let mut out = GsStep::new_for(shape.n);
        session.gs_step(logits, x, gumbel, tau, norm, &mut out)?;
        Ok(out)
    }

    /// Noise-free dense doubly-stochastic P for final JV extraction
    /// (stateless; the probe is once-per-run, not hot).
    fn gs_probe(&self, n: usize, logits: &[f32], tau: f32) -> Result<Vec<f32>> {
        // A probe needs no data/grid buffers: a degenerate 1×n shape keeps
        // the session's lazy per-family workspaces untouched.
        let mut session = self.session(StepShape { n, d: 0, h: 1, w: n }, SessionOpts::default())?;
        let mut out = Vec::new();
        session.gs_probe(logits, tau, &mut out)?;
        Ok(out)
    }

    /// One stateless Kissing step over the factor pair `v`, `wf` ∈
    /// f32[N·M]. Throwaway session; see [`StepBackend::session`].
    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &self,
        shape: StepShape,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<KissStep> {
        let mut session = self.session(shape, SessionOpts::default())?;
        let mut out = KissStep::new_for(shape.n, m);
        session.kiss_step(m, v, wf, x, tau, norm, &mut out)?;
        Ok(out)
    }
}

/// Which backend a session should use.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Prefer the PJRT artifacts when the manifest is present (and the
    /// `pjrt` feature is compiled in); fall back to native.
    #[default]
    Auto,
    /// Pure-Rust backend; never touches artifacts.
    Native,
    /// AOT artifacts via PJRT; errors when they are missing.
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Self::Auto),
            "native" | "rust" => Ok(Self::Native),
            "pjrt" | "xla" | "artifacts" => Ok(Self::Pjrt),
            other => Err(anyhow!(
                "unknown backend '{other}' — expected auto, native or pjrt"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_parses_and_round_trips() {
        for c in [BackendChoice::Auto, BackendChoice::Native, BackendChoice::Pjrt] {
            assert_eq!(BackendChoice::parse(c.name()).unwrap(), c);
        }
        assert_eq!(BackendChoice::parse("RUST").unwrap(), BackendChoice::Native);
        assert_eq!(BackendChoice::parse("xla").unwrap(), BackendChoice::Pjrt);
        assert!(BackendChoice::parse("tpu").is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[test]
    fn step_shape_matches_grid() {
        let s = StepShape::new(GridShape::new(8, 4), 3);
        assert_eq!((s.n, s.d, s.h, s.w), (32, 3, 8, 4));
        assert_eq!(s.grid(), GridShape::new(8, 4));
    }
}
