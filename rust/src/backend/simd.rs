//! Explicit SIMD kernels for the native backend's hottest inner loops.
//!
//! Every kernel takes a [`SimdLevel`] and dispatches between the scalar
//! oracle (a verbatim copy of the pre-SIMD loop, preserving every f32
//! rounding) and hand-written `core::arch` x86-64 paths. The level is
//! resolved once per session from a [`SimdChoice`] (the `simd=` config
//! key / `--simd` CLI flag) capped at what the CPU reports at runtime, so
//! a binary built on an AVX2 machine still runs — on the scalar or SSE2
//! path — anywhere.
//!
//! Exactness contract (asserted by the tests below and by the step-level
//! scalar-vs-SIMD sweep in `backend/native.rs`):
//!
//! * **Bit-exact at every level:** `logits_row`, `max_scan`/`max_argmax`
//!   (same first-maximum tie resolution as the scalar `>` scan), `scale`,
//!   `scale_colsum`, the d = 3 `fold_y_d3`/`gbuf_dot_d3` element math,
//!   `fold_y`, `scatter_pair`, `axpy_mean`, and the per-element `dl_pass`
//!   column gradient — each output element's dependency chain is the same
//!   op sequence as the scalar loop.
//! * **Tolerance (documented ~1e-6 relative):** anything flowing through
//!   the vector `exp` (a Cephes polynomial, not libm) or a lane-reordered
//!   horizontal reduction — softmax denominators, dot products, the
//!   log-sum-exp normalizations, and the f64 loss accumulators.
//!
//! NaN inputs are outside the kernel contract (the session layer
//! validates scalars; weights/data are caller-supplied finite floats).
//! No FMA is used anywhere: fused contractions would change roundings
//! across otherwise-identical CPUs.

use anyhow::{bail, Result};

/// User-facing SIMD selection — what the config/CLI asks for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdChoice {
    /// Highest level the CPU supports (the default).
    #[default]
    Auto,
    /// Force the scalar oracle (`simd=off`).
    Off,
    /// Cap at SSE2 (always available on x86-64).
    Sse2,
    /// Cap at AVX2.
    Avx2,
}

impl SimdChoice {
    /// Parse a config/CLI value. `scalar` is accepted as an alias of
    /// `off`.
    pub fn parse(s: &str) -> Result<SimdChoice> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(SimdChoice::Auto),
            "off" | "scalar" => Ok(SimdChoice::Off),
            "sse2" => Ok(SimdChoice::Sse2),
            "avx2" => Ok(SimdChoice::Avx2),
            other => bail!("unknown simd level '{other}' (expected auto|off|sse2|avx2)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdChoice::Auto => "auto",
            SimdChoice::Off => "off",
            SimdChoice::Sse2 => "sse2",
            SimdChoice::Avx2 => "avx2",
        }
    }

    /// Resolve the request against runtime CPU detection. Requests above
    /// what the CPU offers degrade silently (never an error): `auto`
    /// semantics for portability, and CI can pin `avx2` in a matrix
    /// without gating on runner hardware.
    pub fn resolve(self) -> SimdLevel {
        let top = detected();
        match self {
            SimdChoice::Auto => top,
            SimdChoice::Off => SimdLevel::Scalar,
            SimdChoice::Sse2 => top.min(SimdLevel::Sse2),
            SimdChoice::Avx2 => top.min(SimdLevel::Avx2),
        }
    }
}

impl std::fmt::Display for SimdChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A resolved, runtime-supported instruction level. Ordered so `min`
/// against the detected level caps a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    Scalar,
    Sse2,
    Avx2,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// Highest level this CPU supports. `is_x86_64_feature_detected!` caches
/// internally, so calling per session is free.
#[cfg(target_arch = "x86_64")]
pub fn detected() -> SimdLevel {
    if std::arch::is_x86_64_feature_detected!("avx2") {
        SimdLevel::Avx2
    } else {
        // SSE2 is part of the x86-64 baseline.
        SimdLevel::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub fn detected() -> SimdLevel {
    SimdLevel::Scalar
}

// --------------------------------------------------------------------------
// Forward row kernels (softmax row of the SoftSort matrix).
// --------------------------------------------------------------------------

/// `row[j] = -|wsi - w[j]| / tau` — bit-exact at every level.
pub fn logits_row(level: SimdLevel, row: &mut [f32], w: &[f32], wsi: f32, tau: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::logits_row_sse2(row, w, wsi, tau) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::logits_row_avx2(row, w, wsi, tau) },
        _ => logits_row_scalar(row, w, wsi, tau),
    }
}

fn logits_row_scalar(row: &mut [f32], w: &[f32], wsi: f32, tau: f32) {
    for (rj, &wj) in row.iter_mut().zip(w) {
        *rj = -(wsi - wj).abs() / tau;
    }
}

/// Maximum of `row` — bit-exact (f32 max is order-independent for
/// non-NaN inputs).
pub fn max_scan(level: SimdLevel, row: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::max_scan_sse2(row) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::max_scan_avx2(row) },
        _ => max_scan_scalar(row),
    }
}

fn max_scan_scalar(row: &[f32]) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for &pj in row.iter() {
        if pj > mx {
            mx = pj;
        }
    }
    mx
}

/// Maximum and the index of its **first** occurrence — the same tie
/// resolution as the scalar `>` scan, so `sort_idx` is exactly equal on
/// every level.
pub fn max_argmax(level: SimdLevel, row: &[f32]) -> (f32, usize) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            let mx = unsafe { x86::max_scan_sse2(row) };
            (mx, unsafe { x86::find_first_eq_sse2(row, mx) })
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            let mx = unsafe { x86::max_scan_avx2(row) };
            (mx, unsafe { x86::find_first_eq_avx2(row, mx) })
        }
        _ => max_argmax_scalar(row),
    }
}

fn max_argmax_scalar(row: &[f32]) -> (f32, usize) {
    let mut mx = f32::NEG_INFINITY;
    let mut arg = 0usize;
    for (j, &rj) in row.iter().enumerate() {
        if rj > mx {
            mx = rj;
            arg = j;
        }
    }
    (mx, arg)
}

/// `row[j] = exp(row[j] - mx)`, returns the sum. The vector path uses a
/// Cephes polynomial `exp` and lane-reordered summation — tolerance, not
/// bit-exact (`exp(0) = 1` exactly on both paths, so the row maximum
/// stays exact).
pub fn exp_pass(level: SimdLevel, row: &mut [f32], mx: f32) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::exp_pass_sse2(row, mx) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::exp_pass_avx2(row, mx) },
        _ => exp_pass_scalar(row, mx),
    }
}

fn exp_pass_scalar(row: &mut [f32], mx: f32) -> f32 {
    let mut denom = 0.0f32;
    for rj in row.iter_mut() {
        *rj = (*rj - mx).exp();
        denom += *rj;
    }
    denom
}

/// `row[j] *= inv` — bit-exact.
pub fn scale(level: SimdLevel, row: &mut [f32], inv: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::scale_sse2(row, inv) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::scale_avx2(row, inv) },
        _ => scale_scalar(row, inv),
    }
}

fn scale_scalar(row: &mut [f32], inv: f32) {
    for rj in row.iter_mut() {
        *rj *= inv;
    }
}

/// `row[j] *= inv; cs[j] += row[j]` — bit-exact (element-wise only).
pub fn scale_colsum(level: SimdLevel, row: &mut [f32], cs: &mut [f32], inv: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::scale_colsum_sse2(row, cs, inv) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::scale_colsum_avx2(row, cs, inv) },
        _ => scale_colsum_scalar(row, cs, inv),
    }
}

fn scale_colsum_scalar(row: &mut [f32], cs: &mut [f32], inv: f32) {
    for (rj, cj) in row.iter_mut().zip(cs.iter_mut()) {
        *rj *= inv;
        *cj += *rj;
    }
}

/// d = 3 output fold: `y[t] = Σ_j row[j]·x[3j+t]`. The vector path keeps
/// each component in its own lane accumulating in j order — bit-exact.
/// (The last j is handled scalar so the 4-float load never reads past
/// `x`.)
pub fn fold_y_d3(level: SimdLevel, row: &[f32], x: &[f32]) -> [f32; 3] {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 | SimdLevel::Avx2 => unsafe { x86::fold_y_d3_sse2(row, x) },
        _ => fold_y_d3_scalar(row, x),
    }
}

fn fold_y_d3_scalar(row: &[f32], x: &[f32]) -> [f32; 3] {
    let (mut y0, mut y1, mut y2) = (0.0f32, 0.0f32, 0.0f32);
    for (j, &p) in row.iter().enumerate() {
        let b = j * 3;
        y0 += p * x[b];
        y1 += p * x[b + 1];
        y2 += p * x[b + 2];
    }
    [y0, y1, y2]
}

/// Generic output fold: `yi[t] += Σ_j row[j]·x[jd+t]`, vectorized over t
/// when d ≥ 8 (each `yi[t]` still accumulates in j order — bit-exact).
pub fn fold_y(level: SimdLevel, row: &[f32], x: &[f32], yi: &mut [f32], d: usize) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if d >= 8 => unsafe { x86::fold_y_avx2(row, x, yi, d) },
        _ => fold_y_scalar(row, x, yi, d),
    }
}

fn fold_y_scalar(row: &[f32], x: &[f32], yi: &mut [f32], d: usize) {
    for (j, &p) in row.iter().enumerate() {
        let xj = &x[j * d..(j + 1) * d];
        for (yc, &xc) in yi.iter_mut().zip(xj) {
            *yc += p * xc;
        }
    }
}

// --------------------------------------------------------------------------
// Backward row kernels (dL/dP through the softmax row).
// --------------------------------------------------------------------------

/// d = 3 cotangent row: `gbuf[j] = ((ct_cs[j] + c0·x[3j]) + c1·x[3j+1])
/// + c2·x[3j+2]` (bit-exact element math via AVX2 gathers), returns
/// `Σ_j gbuf[j]·prob[j]` (lane-reordered — tolerance). SSE2 falls back
/// to the scalar oracle (no gather instruction).
pub fn gbuf_dot_d3(
    level: SimdLevel,
    ct_cs: &[f32],
    x: &[f32],
    cti: [f32; 3],
    prob: &[f32],
    gbuf: &mut [f32],
) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::gbuf_dot_d3_avx2(ct_cs, x, cti, prob, gbuf) },
        _ => gbuf_dot_d3_scalar(ct_cs, x, cti, prob, gbuf),
    }
}

fn gbuf_dot_d3_scalar(
    ct_cs: &[f32],
    x: &[f32],
    cti: [f32; 3],
    prob: &[f32],
    gbuf: &mut [f32],
) -> f32 {
    let (c0, c1, c2) = (cti[0], cti[1], cti[2]);
    let mut dot = 0.0f32;
    for (j, gj) in gbuf.iter_mut().enumerate() {
        let b = j * 3;
        let g = ((ct_cs[j] + c0 * x[b]) + c1 * x[b + 1]) + c2 * x[b + 2];
        *gj = g;
        dot += g * prob[j];
    }
    dot
}

/// Generic cotangent row, vectorized over t when d ≥ 8 (the per-j dot is
/// a lane-reordered reduction — tolerance); returns `Σ_j gbuf[j]·prob[j]`
/// accumulated in j order.
pub fn gbuf_dot(
    level: SimdLevel,
    ct_cs: &[f32],
    x: &[f32],
    cti: &[f32],
    d: usize,
    prob: &[f32],
    gbuf: &mut [f32],
) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if d >= 8 => unsafe { x86::gbuf_dot_avx2(ct_cs, x, cti, d, prob, gbuf) },
        _ => gbuf_dot_scalar(ct_cs, x, cti, d, prob, gbuf),
    }
}

fn gbuf_dot_scalar(
    ct_cs: &[f32],
    x: &[f32],
    cti: &[f32],
    d: usize,
    prob: &[f32],
    gbuf: &mut [f32],
) -> f32 {
    let mut dot = 0.0f32;
    for (j, gj) in gbuf.iter_mut().enumerate() {
        let mut g = ct_cs[j];
        let xj = &x[j * d..(j + 1) * d];
        for (ct, &xc) in cti.iter().zip(xj) {
            g += ct * xc;
        }
        *gj = g;
        dot += g * prob[j];
    }
    dot
}

/// Softmax backward + |·| kernel: per j, `dl = prob[j]·(gbuf[j] − dot)`,
/// `s = sgn(wsi − w[j])`, `gw[j] += dl·s/τ` (bit-exact element math);
/// returns `gws_i = −Σ_j dl·s/τ` (lane-reordered — tolerance).
#[allow(clippy::too_many_arguments)]
pub fn dl_pass(
    level: SimdLevel,
    prob: &[f32],
    gbuf: &[f32],
    dot: f32,
    wsi: f32,
    w: &[f32],
    tau: f32,
    gw: &mut [f32],
) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => unsafe { x86::dl_pass_sse2(prob, gbuf, dot, wsi, w, tau, gw) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dl_pass_avx2(prob, gbuf, dot, wsi, w, tau, gw) },
        _ => dl_pass_scalar(prob, gbuf, dot, wsi, w, tau, gw),
    }
}

#[inline]
fn sgn(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

fn dl_pass_scalar(
    prob: &[f32],
    gbuf: &[f32],
    dot: f32,
    wsi: f32,
    w: &[f32],
    tau: f32,
    gw: &mut [f32],
) -> f32 {
    let mut gws_i = 0.0f32;
    for (j, gwj) in gw.iter_mut().enumerate() {
        let dl = prob[j] * (gbuf[j] - dot);
        let s = sgn(wsi - w[j]);
        gws_i -= dl * s / tau;
        *gwj += dl * s / tau;
    }
    gws_i
}

// --------------------------------------------------------------------------
// Eq. 2-4 loss reduction kernels.
// --------------------------------------------------------------------------

/// Pair displacement + squared norm: `diff[t] = a[t] − b[t]` (bit-exact),
/// returns `Σ diff²` (lane-reordered when d ≥ 8 — tolerance; d < 8, e.g.
/// the d = 3 hot case, stays on the scalar oracle).
pub fn diff_normsq(level: SimdLevel, a: &[f32], b: &[f32], diff: &mut [f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if diff.len() >= 8 => unsafe { x86::diff_normsq_avx2(a, b, diff) },
        _ => diff_normsq_scalar(a, b, diff),
    }
}

fn diff_normsq_scalar(a: &[f32], b: &[f32], diff: &mut [f32]) -> f32 {
    let mut s = 0.0f32;
    for ((dt, &av), &bv) in diff.iter_mut().zip(a).zip(b) {
        let dd = av - bv;
        *dt = dd;
        s += dd * dd;
    }
    s
}

/// Scatter a pair gradient: `d1[t] += diff[t]·g; d2[t] -= diff[t]·g` —
/// bit-exact.
pub fn scatter_pair(level: SimdLevel, d1: &mut [f32], d2: &mut [f32], diff: &[f32], g: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 if diff.len() >= 8 => unsafe { x86::scatter_pair_avx2(d1, d2, diff, g) },
        _ => scatter_pair_scalar(d1, d2, diff, g),
    }
}

fn scatter_pair_scalar(d1: &mut [f32], d2: &mut [f32], diff: &[f32], g: f32) {
    for ((&dt, e1), e2) in diff.iter().zip(d1.iter_mut()).zip(d2.iter_mut()) {
        *e1 += dt * g;
        *e2 -= dt * g;
    }
}

/// Eq. 3 column-sum deviation: `ct_cs[j] = λ2·dev/n` (bit-exact), returns
/// `Σ dev²` accumulated in f64 (lane-reordered — tolerance).
pub fn colsum_loss(level: SimdLevel, cs: &[f32], lambda2: f32, ct_cs: &mut [f32]) -> f64 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::colsum_loss_avx2(cs, lambda2, ct_cs) },
        _ => colsum_loss_scalar(cs, lambda2, ct_cs),
    }
}

fn colsum_loss_scalar(cs: &[f32], lambda2: f32, ct_cs: &mut [f32]) -> f64 {
    let n = cs.len();
    let mut acc = 0.0f64;
    for (ct, &c) in ct_cs.iter_mut().zip(cs) {
        let dev = c - 1.0;
        acc += (dev * dev) as f64;
        *ct = lambda2 * dev / n as f32;
    }
    acc
}

/// `Σ y[k]` widened to f64 per element (lane-reordered — tolerance).
pub fn sum_f64(level: SimdLevel, y: &[f32]) -> f64 {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::sum_f64_avx2(y) },
        _ => sum_f64_scalar(y),
    }
}

fn sum_f64_scalar(y: &[f32]) -> f64 {
    y.iter().map(|&v| v as f64).sum::<f64>()
}

/// Eq. 4 cotangent: `ct[k] += a·(y[k] − mu)` — bit-exact.
pub fn axpy_mean(level: SimdLevel, ct_y: &mut [f32], y: &[f32], a: f32, mu: f32) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::axpy_mean_avx2(ct_y, y, a, mu) },
        _ => axpy_mean_scalar(ct_y, y, a, mu),
    }
}

fn axpy_mean_scalar(ct_y: &mut [f32], y: &[f32], a: f32, mu: f32) {
    for (ct, &v) in ct_y.iter_mut().zip(y) {
        *ct += a * (v - mu);
    }
}

// --------------------------------------------------------------------------
// Sinkhorn log-space normalization kernels.
// --------------------------------------------------------------------------

/// Subtract the log-sum-exp from every row of the n×n matrix `la`.
pub fn row_lse_normalize(level: SimdLevel, la: &mut [f32], n: usize) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            for i in 0..n {
                unsafe { x86::row_lse_one_avx2(&mut la[i * n..(i + 1) * n]) };
            }
        }
        _ => {
            for i in 0..n {
                row_lse_one_scalar(&mut la[i * n..(i + 1) * n]);
            }
        }
    }
}

fn row_lse_one_scalar(row: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut s = 0.0f32;
    for &v in row.iter() {
        s += (v - mx).exp();
    }
    let lse = mx + s.ln();
    for v in row.iter_mut() {
        *v -= lse;
    }
}

/// Subtract the log-sum-exp from every column of the n×n matrix `la`.
/// The vector path walks 8 columns at a time down the rows, keeping each
/// column's accumulation in row order.
pub fn col_lse_normalize(level: SimdLevel, la: &mut [f32], n: usize) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::col_lse_normalize_avx2(la, n) },
        _ => {
            for j in 0..n {
                col_lse_one_scalar(la, n, j);
            }
        }
    }
}

fn col_lse_one_scalar(la: &mut [f32], n: usize, j: usize) {
    let mut mx = f32::NEG_INFINITY;
    for i in 0..n {
        mx = mx.max(la[i * n + j]);
    }
    let mut s = 0.0f32;
    for i in 0..n {
        s += (la[i * n + j] - mx).exp();
    }
    let lse = mx + s.ln();
    for i in 0..n {
        la[i * n + j] -= lse;
    }
}

/// `buf[k] = exp(buf[k])` (Cephes on the vector path — tolerance).
pub fn exp_in_place(level: SimdLevel, buf: &mut [f32]) {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::exp_in_place_avx2(buf) },
        _ => {
            for v in buf.iter_mut() {
                *v = v.exp();
            }
        }
    }
}

// --------------------------------------------------------------------------
// x86-64 implementations.
// --------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[allow(clippy::excessive_precision)]
mod x86 {
    use core::arch::x86_64::*;

    // Cephes single-precision exp (the sse_mathfun/avx_mathfun
    // constants): range-reduce by log2(e), Cody-Waite subtract the two
    // halves of ln(2), degree-5 polynomial, scale by 2^n through the
    // exponent bits. Max observed error ~2 ulp; exp(0) = 1 exactly.
    const EXP_HI: f32 = 88.3762626647949;
    const EXP_LO: f32 = -88.3762626647949;
    const LN2_HI: f32 = 0.693359375;
    const LN2_LO: f32 = -2.12194440e-4;
    const P0: f32 = 1.9875691500e-4;
    const P1: f32 = 1.3981999507e-3;
    const P2: f32 = 8.3334519073e-3;
    const P3: f32 = 4.1665795894e-2;
    const P4: f32 = 1.6666665459e-1;
    const P5: f32 = 5.0000001201e-1;

    #[target_feature(enable = "sse2")]
    unsafe fn exp128(v: __m128) -> __m128 {
        let x = _mm_min_ps(_mm_set1_ps(EXP_HI), _mm_max_ps(_mm_set1_ps(EXP_LO), v));
        let log2e = _mm_set1_ps(std::f32::consts::LOG2_E);
        let fx = _mm_add_ps(_mm_mul_ps(x, log2e), _mm_set1_ps(0.5));
        // floor(fx) without SSE4.1: truncate toward zero, then subtract 1
        // where truncation rounded up (negative non-integers).
        let t = _mm_cvtepi32_ps(_mm_cvttps_epi32(fx));
        let fl = _mm_sub_ps(t, _mm_and_ps(_mm_cmpgt_ps(t, fx), _mm_set1_ps(1.0)));
        let x = _mm_sub_ps(x, _mm_mul_ps(fl, _mm_set1_ps(LN2_HI)));
        let x = _mm_sub_ps(x, _mm_mul_ps(fl, _mm_set1_ps(LN2_LO)));
        let mut y = _mm_set1_ps(P0);
        y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(P1));
        y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(P2));
        y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(P3));
        y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(P4));
        y = _mm_add_ps(_mm_mul_ps(y, x), _mm_set1_ps(P5));
        let x2 = _mm_mul_ps(x, x);
        let y = _mm_add_ps(_mm_add_ps(_mm_mul_ps(y, x2), x), _mm_set1_ps(1.0));
        let e = _mm_add_epi32(_mm_cvtps_epi32(fl), _mm_set1_epi32(127));
        _mm_mul_ps(y, _mm_castsi128_ps(_mm_slli_epi32::<23>(e)))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exp256(v: __m256) -> __m256 {
        let x = _mm256_min_ps(_mm256_set1_ps(EXP_HI), _mm256_max_ps(_mm256_set1_ps(EXP_LO), v));
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        let fx = _mm256_add_ps(_mm256_mul_ps(x, log2e), _mm256_set1_ps(0.5));
        let fl = _mm256_floor_ps(fx);
        let x = _mm256_sub_ps(x, _mm256_mul_ps(fl, _mm256_set1_ps(LN2_HI)));
        let x = _mm256_sub_ps(x, _mm256_mul_ps(fl, _mm256_set1_ps(LN2_LO)));
        let mut y = _mm256_set1_ps(P0);
        y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(P1));
        y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(P2));
        y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(P3));
        y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(P4));
        y = _mm256_add_ps(_mm256_mul_ps(y, x), _mm256_set1_ps(P5));
        let x2 = _mm256_mul_ps(x, x);
        let y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, x2), x), _mm256_set1_ps(1.0));
        let e = _mm256_add_epi32(_mm256_cvtps_epi32(fl), _mm256_set1_epi32(127));
        _mm256_mul_ps(y, _mm256_castsi256_ps(_mm256_slli_epi32::<23>(e)))
    }

    // Fixed-shape horizontal reductions (deterministic lane fold order).

    #[target_feature(enable = "sse2")]
    unsafe fn hsum128(v: __m128) -> f32 {
        let s = _mm_add_ps(v, _mm_movehl_ps(v, v));
        let s1 = _mm_shuffle_ps::<0b01>(s, s);
        _mm_cvtss_f32(_mm_add_ss(s, s1))
    }

    #[target_feature(enable = "sse2")]
    unsafe fn hmax128(v: __m128) -> f32 {
        let s = _mm_max_ps(v, _mm_movehl_ps(v, v));
        let s1 = _mm_shuffle_ps::<0b01>(s, s);
        _mm_cvtss_f32(_mm_max_ss(s, s1))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum256(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s1 = _mm_shuffle_ps::<0b01>(s, s);
        _mm_cvtss_f32(_mm_add_ss(s, s1))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hmax256(v: __m256) -> f32 {
        let s = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s1 = _mm_shuffle_ps::<0b01>(s, s);
        _mm_cvtss_f32(_mm_max_ss(s, s1))
    }

    #[target_feature(enable = "avx2")]
    unsafe fn hsum256d(v: __m256d) -> f64 {
        let s = _mm_add_pd(_mm256_castpd256_pd128(v), _mm256_extractf128_pd::<1>(v));
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    // ---- forward row kernels ----

    #[target_feature(enable = "sse2")]
    pub unsafe fn logits_row_sse2(row: &mut [f32], w: &[f32], wsi: f32, tau: f32) {
        let n = row.len();
        let wsi_v = _mm_set1_ps(wsi);
        let tau_v = _mm_set1_ps(tau);
        // |x| = andnot(signbit, x); negate by xor with the sign bit.
        let sign = _mm_set1_ps(-0.0);
        let mut j = 0;
        while j + 4 <= n {
            let wv = _mm_loadu_ps(w.as_ptr().add(j));
            let a = _mm_andnot_ps(sign, _mm_sub_ps(wsi_v, wv));
            let r = _mm_div_ps(_mm_xor_ps(a, sign), tau_v);
            _mm_storeu_ps(row.as_mut_ptr().add(j), r);
            j += 4;
        }
        while j < n {
            row[j] = -(wsi - w[j]).abs() / tau;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn logits_row_avx2(row: &mut [f32], w: &[f32], wsi: f32, tau: f32) {
        let n = row.len();
        let wsi_v = _mm256_set1_ps(wsi);
        let tau_v = _mm256_set1_ps(tau);
        let sign = _mm256_set1_ps(-0.0);
        let mut j = 0;
        while j + 8 <= n {
            let wv = _mm256_loadu_ps(w.as_ptr().add(j));
            let a = _mm256_andnot_ps(sign, _mm256_sub_ps(wsi_v, wv));
            let r = _mm256_div_ps(_mm256_xor_ps(a, sign), tau_v);
            _mm256_storeu_ps(row.as_mut_ptr().add(j), r);
            j += 8;
        }
        while j < n {
            row[j] = -(wsi - w[j]).abs() / tau;
            j += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn max_scan_sse2(row: &[f32]) -> f32 {
        let n = row.len();
        let mut mx = f32::NEG_INFINITY;
        let mut j = 0;
        if n >= 4 {
            let mut acc = _mm_set1_ps(f32::NEG_INFINITY);
            while j + 4 <= n {
                acc = _mm_max_ps(acc, _mm_loadu_ps(row.as_ptr().add(j)));
                j += 4;
            }
            mx = hmax128(acc);
        }
        while j < n {
            mx = mx.max(row[j]);
            j += 1;
        }
        mx
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn max_scan_avx2(row: &[f32]) -> f32 {
        let n = row.len();
        let mut mx = f32::NEG_INFINITY;
        let mut j = 0;
        if n >= 8 {
            let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
            while j + 8 <= n {
                acc = _mm256_max_ps(acc, _mm256_loadu_ps(row.as_ptr().add(j)));
                j += 8;
            }
            mx = hmax256(acc);
        }
        while j < n {
            mx = mx.max(row[j]);
            j += 1;
        }
        mx
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn find_first_eq_sse2(row: &[f32], mx: f32) -> usize {
        let n = row.len();
        let target = _mm_set1_ps(mx);
        let mut j = 0;
        while j + 4 <= n {
            let v = _mm_loadu_ps(row.as_ptr().add(j));
            let m = _mm_movemask_ps(_mm_cmpeq_ps(v, target));
            if m != 0 {
                return j + m.trailing_zeros() as usize;
            }
            j += 4;
        }
        while j < n {
            if row[j] == mx {
                return j;
            }
            j += 1;
        }
        0
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn find_first_eq_avx2(row: &[f32], mx: f32) -> usize {
        let n = row.len();
        let target = _mm256_set1_ps(mx);
        let mut j = 0;
        while j + 8 <= n {
            let v = _mm256_loadu_ps(row.as_ptr().add(j));
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_EQ_OQ>(v, target));
            if m != 0 {
                return j + m.trailing_zeros() as usize;
            }
            j += 8;
        }
        while j < n {
            if row[j] == mx {
                return j;
            }
            j += 1;
        }
        0
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn exp_pass_sse2(row: &mut [f32], mx: f32) -> f32 {
        let n = row.len();
        let mxv = _mm_set1_ps(mx);
        let mut acc = _mm_setzero_ps();
        let mut j = 0;
        while j + 4 <= n {
            let p = row.as_mut_ptr().add(j);
            let e = exp128(_mm_sub_ps(_mm_loadu_ps(p), mxv));
            _mm_storeu_ps(p, e);
            acc = _mm_add_ps(acc, e);
            j += 4;
        }
        let mut denom = hsum128(acc);
        while j < n {
            row[j] = (row[j] - mx).exp();
            denom += row[j];
            j += 1;
        }
        denom
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn exp_pass_avx2(row: &mut [f32], mx: f32) -> f32 {
        let n = row.len();
        let mxv = _mm256_set1_ps(mx);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let p = row.as_mut_ptr().add(j);
            let e = exp256(_mm256_sub_ps(_mm256_loadu_ps(p), mxv));
            _mm256_storeu_ps(p, e);
            acc = _mm256_add_ps(acc, e);
            j += 8;
        }
        let mut denom = hsum256(acc);
        while j < n {
            row[j] = (row[j] - mx).exp();
            denom += row[j];
            j += 1;
        }
        denom
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn scale_sse2(row: &mut [f32], inv: f32) {
        let n = row.len();
        let iv = _mm_set1_ps(inv);
        let mut j = 0;
        while j + 4 <= n {
            let p = row.as_mut_ptr().add(j);
            _mm_storeu_ps(p, _mm_mul_ps(_mm_loadu_ps(p), iv));
            j += 4;
        }
        while j < n {
            row[j] *= inv;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_avx2(row: &mut [f32], inv: f32) {
        let n = row.len();
        let iv = _mm256_set1_ps(inv);
        let mut j = 0;
        while j + 8 <= n {
            let p = row.as_mut_ptr().add(j);
            _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), iv));
            j += 8;
        }
        while j < n {
            row[j] *= inv;
            j += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn scale_colsum_sse2(row: &mut [f32], cs: &mut [f32], inv: f32) {
        let n = row.len();
        let iv = _mm_set1_ps(inv);
        let mut j = 0;
        while j + 4 <= n {
            let rp = row.as_mut_ptr().add(j);
            let cp = cs.as_mut_ptr().add(j);
            let p = _mm_mul_ps(_mm_loadu_ps(rp), iv);
            _mm_storeu_ps(rp, p);
            _mm_storeu_ps(cp, _mm_add_ps(_mm_loadu_ps(cp), p));
            j += 4;
        }
        while j < n {
            row[j] *= inv;
            cs[j] += row[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_colsum_avx2(row: &mut [f32], cs: &mut [f32], inv: f32) {
        let n = row.len();
        let iv = _mm256_set1_ps(inv);
        let mut j = 0;
        while j + 8 <= n {
            let rp = row.as_mut_ptr().add(j);
            let cp = cs.as_mut_ptr().add(j);
            let p = _mm256_mul_ps(_mm256_loadu_ps(rp), iv);
            _mm256_storeu_ps(rp, p);
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), p));
            j += 8;
        }
        while j < n {
            row[j] *= inv;
            cs[j] += row[j];
            j += 1;
        }
    }

    /// d = 3 fold; 4-lane (SSE2-wide) on purpose: lanes are [y0 y1 y2 _],
    /// each accumulating its component in j order — bit-exact vs the
    /// scalar registers. The last j is scalar so the 4-float load stays
    /// inside `x`.
    #[target_feature(enable = "sse2")]
    pub unsafe fn fold_y_d3_sse2(row: &[f32], x: &[f32]) -> [f32; 3] {
        let n = row.len();
        let mut acc = _mm_setzero_ps();
        for j in 0..n.saturating_sub(1) {
            let p = _mm_set1_ps(row[j]);
            let xv = _mm_loadu_ps(x.as_ptr().add(3 * j));
            acc = _mm_add_ps(acc, _mm_mul_ps(p, xv));
        }
        let mut out = [0.0f32; 4];
        _mm_storeu_ps(out.as_mut_ptr(), acc);
        if n > 0 {
            let j = n - 1;
            let p = row[j];
            out[0] += p * x[3 * j];
            out[1] += p * x[3 * j + 1];
            out[2] += p * x[3 * j + 2];
        }
        [out[0], out[1], out[2]]
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn fold_y_avx2(row: &[f32], x: &[f32], yi: &mut [f32], d: usize) {
        for (j, &p) in row.iter().enumerate() {
            let pv = _mm256_set1_ps(p);
            let xj = x.as_ptr().add(j * d);
            let mut t = 0;
            while t + 8 <= d {
                let yp = yi.as_mut_ptr().add(t);
                let prod = _mm256_mul_ps(pv, _mm256_loadu_ps(xj.add(t)));
                _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), prod));
                t += 8;
            }
            while t < d {
                yi[t] += p * *xj.add(t);
                t += 1;
            }
        }
    }

    // ---- backward row kernels ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn gbuf_dot_d3_avx2(
        ct_cs: &[f32],
        x: &[f32],
        cti: [f32; 3],
        prob: &[f32],
        gbuf: &mut [f32],
    ) -> f32 {
        let n = gbuf.len();
        let c0 = _mm256_set1_ps(cti[0]);
        let c1 = _mm256_set1_ps(cti[1]);
        let c2 = _mm256_set1_ps(cti[2]);
        // Strided component loads: lanes j..j+8 of x[3j+t] via gathers.
        let idx = _mm256_setr_epi32(0, 3, 6, 9, 12, 15, 18, 21);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let base = x.as_ptr().add(3 * j);
            let x0 = _mm256_i32gather_ps::<4>(base, idx);
            let x1 = _mm256_i32gather_ps::<4>(base.add(1), idx);
            let x2 = _mm256_i32gather_ps::<4>(base.add(2), idx);
            let ct = _mm256_loadu_ps(ct_cs.as_ptr().add(j));
            let g0 = _mm256_add_ps(ct, _mm256_mul_ps(c0, x0));
            let g1 = _mm256_add_ps(g0, _mm256_mul_ps(c1, x1));
            let g = _mm256_add_ps(g1, _mm256_mul_ps(c2, x2));
            _mm256_storeu_ps(gbuf.as_mut_ptr().add(j), g);
            let p = _mm256_loadu_ps(prob.as_ptr().add(j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(g, p));
            j += 8;
        }
        let mut dot = hsum256(acc);
        while j < n {
            let b = j * 3;
            let g = ((ct_cs[j] + cti[0] * x[b]) + cti[1] * x[b + 1]) + cti[2] * x[b + 2];
            gbuf[j] = g;
            dot += g * prob[j];
            j += 1;
        }
        dot
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gbuf_dot_avx2(
        ct_cs: &[f32],
        x: &[f32],
        cti: &[f32],
        d: usize,
        prob: &[f32],
        gbuf: &mut [f32],
    ) -> f32 {
        let mut dot = 0.0f32;
        for (j, gj) in gbuf.iter_mut().enumerate() {
            let xj = x.as_ptr().add(j * d);
            let mut acc = _mm256_setzero_ps();
            let mut t = 0;
            while t + 8 <= d {
                let cv = _mm256_loadu_ps(cti.as_ptr().add(t));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(cv, _mm256_loadu_ps(xj.add(t))));
                t += 8;
            }
            let mut g = ct_cs[j] + hsum256(acc);
            while t < d {
                g += cti[t] * *xj.add(t);
                t += 1;
            }
            *gj = g;
            dot += g * prob[j];
        }
        dot
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn dl_pass_sse2(
        prob: &[f32],
        gbuf: &[f32],
        dot: f32,
        wsi: f32,
        w: &[f32],
        tau: f32,
        gw: &mut [f32],
    ) -> f32 {
        let n = gw.len();
        let dotv = _mm_set1_ps(dot);
        let wsi_v = _mm_set1_ps(wsi);
        let tau_v = _mm_set1_ps(tau);
        let zero = _mm_setzero_ps();
        let one = _mm_set1_ps(1.0);
        let mone = _mm_set1_ps(-1.0);
        let mut acc = _mm_setzero_ps();
        let mut j = 0;
        while j + 4 <= n {
            let p = _mm_loadu_ps(prob.as_ptr().add(j));
            let g = _mm_loadu_ps(gbuf.as_ptr().add(j));
            let dl = _mm_mul_ps(p, _mm_sub_ps(g, dotv));
            let dw = _mm_sub_ps(wsi_v, _mm_loadu_ps(w.as_ptr().add(j)));
            let pos = _mm_and_ps(_mm_cmpgt_ps(dw, zero), one);
            let neg = _mm_and_ps(_mm_cmplt_ps(dw, zero), mone);
            let s = _mm_or_ps(pos, neg);
            let term = _mm_div_ps(_mm_mul_ps(dl, s), tau_v);
            let gp = gw.as_mut_ptr().add(j);
            _mm_storeu_ps(gp, _mm_add_ps(_mm_loadu_ps(gp), term));
            acc = _mm_add_ps(acc, term);
            j += 4;
        }
        let mut gws_i = -hsum128(acc);
        while j < n {
            let dl = prob[j] * (gbuf[j] - dot);
            let s = super::sgn(wsi - w[j]);
            gws_i -= dl * s / tau;
            gw[j] += dl * s / tau;
            j += 1;
        }
        gws_i
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dl_pass_avx2(
        prob: &[f32],
        gbuf: &[f32],
        dot: f32,
        wsi: f32,
        w: &[f32],
        tau: f32,
        gw: &mut [f32],
    ) -> f32 {
        let n = gw.len();
        let dotv = _mm256_set1_ps(dot);
        let wsi_v = _mm256_set1_ps(wsi);
        let tau_v = _mm256_set1_ps(tau);
        let zero = _mm256_setzero_ps();
        let one = _mm256_set1_ps(1.0);
        let mone = _mm256_set1_ps(-1.0);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let p = _mm256_loadu_ps(prob.as_ptr().add(j));
            let g = _mm256_loadu_ps(gbuf.as_ptr().add(j));
            let dl = _mm256_mul_ps(p, _mm256_sub_ps(g, dotv));
            let dw = _mm256_sub_ps(wsi_v, _mm256_loadu_ps(w.as_ptr().add(j)));
            let pos = _mm256_and_ps(_mm256_cmp_ps::<_CMP_GT_OQ>(dw, zero), one);
            let neg = _mm256_and_ps(_mm256_cmp_ps::<_CMP_LT_OQ>(dw, zero), mone);
            let s = _mm256_or_ps(pos, neg);
            let term = _mm256_div_ps(_mm256_mul_ps(dl, s), tau_v);
            let gp = gw.as_mut_ptr().add(j);
            _mm256_storeu_ps(gp, _mm256_add_ps(_mm256_loadu_ps(gp), term));
            acc = _mm256_add_ps(acc, term);
            j += 8;
        }
        let mut gws_i = -hsum256(acc);
        while j < n {
            let dl = prob[j] * (gbuf[j] - dot);
            let s = super::sgn(wsi - w[j]);
            gws_i -= dl * s / tau;
            gw[j] += dl * s / tau;
            j += 1;
        }
        gws_i
    }

    // ---- loss reduction kernels ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn diff_normsq_avx2(a: &[f32], b: &[f32], diff: &mut [f32]) -> f32 {
        let d = diff.len();
        let mut acc = _mm256_setzero_ps();
        let mut t = 0;
        while t + 8 <= d {
            let av = _mm256_loadu_ps(a.as_ptr().add(t));
            let bv = _mm256_loadu_ps(b.as_ptr().add(t));
            let dd = _mm256_sub_ps(av, bv);
            _mm256_storeu_ps(diff.as_mut_ptr().add(t), dd);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(dd, dd));
            t += 8;
        }
        let mut s = hsum256(acc);
        while t < d {
            let dd = a[t] - b[t];
            diff[t] = dd;
            s += dd * dd;
            t += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scatter_pair_avx2(d1: &mut [f32], d2: &mut [f32], diff: &[f32], g: f32) {
        let d = diff.len();
        let gv = _mm256_set1_ps(g);
        let mut t = 0;
        while t + 8 <= d {
            let dd = _mm256_mul_ps(_mm256_loadu_ps(diff.as_ptr().add(t)), gv);
            let p1 = d1.as_mut_ptr().add(t);
            let p2 = d2.as_mut_ptr().add(t);
            _mm256_storeu_ps(p1, _mm256_add_ps(_mm256_loadu_ps(p1), dd));
            _mm256_storeu_ps(p2, _mm256_sub_ps(_mm256_loadu_ps(p2), dd));
            t += 8;
        }
        while t < d {
            d1[t] += diff[t] * g;
            d2[t] -= diff[t] * g;
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn colsum_loss_avx2(cs: &[f32], lambda2: f32, ct_cs: &mut [f32]) -> f64 {
        let n = cs.len();
        let nf = _mm256_set1_ps(n as f32);
        let l2 = _mm256_set1_ps(lambda2);
        let one = _mm256_set1_ps(1.0);
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let mut j = 0;
        while j + 8 <= n {
            let dev = _mm256_sub_ps(_mm256_loadu_ps(cs.as_ptr().add(j)), one);
            let sq = _mm256_mul_ps(dev, dev);
            acc_lo = _mm256_add_pd(acc_lo, _mm256_cvtps_pd(_mm256_castps256_ps128(sq)));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(sq)));
            let ct = _mm256_div_ps(_mm256_mul_ps(l2, dev), nf);
            _mm256_storeu_ps(ct_cs.as_mut_ptr().add(j), ct);
            j += 8;
        }
        let mut acc = hsum256d(acc_lo) + hsum256d(acc_hi);
        while j < n {
            let dev = cs[j] - 1.0;
            acc += (dev * dev) as f64;
            ct_cs[j] = lambda2 * dev / n as f32;
            j += 1;
        }
        acc
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_f64_avx2(y: &[f32]) -> f64 {
        let n = y.len();
        let mut acc = _mm256_setzero_pd();
        let mut k = 0;
        while k + 4 <= n {
            acc = _mm256_add_pd(acc, _mm256_cvtps_pd(_mm_loadu_ps(y.as_ptr().add(k))));
            k += 4;
        }
        let mut s = hsum256d(acc);
        while k < n {
            s += y[k] as f64;
            k += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_mean_avx2(ct_y: &mut [f32], y: &[f32], a: f32, mu: f32) {
        let n = ct_y.len();
        let av = _mm256_set1_ps(a);
        let muv = _mm256_set1_ps(mu);
        let mut k = 0;
        while k + 8 <= n {
            let yv = _mm256_sub_ps(_mm256_loadu_ps(y.as_ptr().add(k)), muv);
            let cp = ct_y.as_mut_ptr().add(k);
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), _mm256_mul_ps(av, yv)));
            k += 8;
        }
        while k < n {
            ct_y[k] += a * (y[k] - mu);
            k += 1;
        }
    }

    // ---- Sinkhorn normalization kernels ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn row_lse_one_avx2(row: &mut [f32]) {
        let n = row.len();
        let mx = max_scan_avx2(row);
        let mxv = _mm256_set1_ps(mx);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let v = _mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(j)), mxv);
            acc = _mm256_add_ps(acc, exp256(v));
            j += 8;
        }
        let mut s = hsum256(acc);
        while j < n {
            s += (row[j] - mx).exp();
            j += 1;
        }
        let lse = mx + s.ln();
        let lv = _mm256_set1_ps(lse);
        let mut j = 0;
        while j + 8 <= n {
            let p = row.as_mut_ptr().add(j);
            _mm256_storeu_ps(p, _mm256_sub_ps(_mm256_loadu_ps(p), lv));
            j += 8;
        }
        while j < n {
            row[j] -= lse;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn col_lse_normalize_avx2(la: &mut [f32], n: usize) {
        let mut j = 0;
        while j + 8 <= n {
            let mut mxv = _mm256_set1_ps(f32::NEG_INFINITY);
            for i in 0..n {
                mxv = _mm256_max_ps(mxv, _mm256_loadu_ps(la.as_ptr().add(i * n + j)));
            }
            let mut sv = _mm256_setzero_ps();
            for i in 0..n {
                let v = _mm256_sub_ps(_mm256_loadu_ps(la.as_ptr().add(i * n + j)), mxv);
                sv = _mm256_add_ps(sv, exp256(v));
            }
            let mut mxa = [0.0f32; 8];
            let mut sa = [0.0f32; 8];
            _mm256_storeu_ps(mxa.as_mut_ptr(), mxv);
            _mm256_storeu_ps(sa.as_mut_ptr(), sv);
            let mut lse = [0.0f32; 8];
            for k in 0..8 {
                lse[k] = mxa[k] + sa[k].ln();
            }
            let lv = _mm256_loadu_ps(lse.as_ptr());
            for i in 0..n {
                let p = la.as_mut_ptr().add(i * n + j);
                _mm256_storeu_ps(p, _mm256_sub_ps(_mm256_loadu_ps(p), lv));
            }
            j += 8;
        }
        while j < n {
            super::col_lse_one_scalar(la, n, j);
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn exp_in_place_avx2(buf: &mut [f32]) {
        let n = buf.len();
        let mut k = 0;
        while k + 8 <= n {
            let p = buf.as_mut_ptr().add(k);
            _mm256_storeu_ps(p, exp256(_mm256_loadu_ps(p)));
            k += 8;
        }
        while k < n {
            buf[k] = buf[k].exp();
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-data (same idiom as the native backend
    /// tests), shifted to a mixed-sign range.
    fn pattern(len: usize, salt: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
                (h % 10_000) as f32 / 10_000.0 - 0.5
            })
            .collect()
    }

    /// Levels with a vector path on this machine (empty on non-x86-64:
    /// the sweep degenerates to scalar-vs-scalar, which is fine).
    fn vector_levels() -> Vec<SimdLevel> {
        let mut v = Vec::new();
        if detected() >= SimdLevel::Sse2 {
            v.push(SimdLevel::Sse2);
        }
        if detected() >= SimdLevel::Avx2 {
            v.push(SimdLevel::Avx2);
        }
        v
    }

    fn assert_bits(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: len");
        for (k, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{k}]: {x} vs {y}");
        }
    }

    fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{what}: {a} vs {b}");
    }

    /// The remainder-tail sizes the satellite asks for: below one lane,
    /// straddling lane multiples, and a large O(n) size.
    const NS: &[usize] = &[1, 2, 3, 127, 128, 129, 4096];

    #[test]
    fn choice_parses_resolves_and_displays() {
        assert_eq!(SimdChoice::parse("auto").unwrap(), SimdChoice::Auto);
        assert_eq!(SimdChoice::parse("OFF").unwrap(), SimdChoice::Off);
        assert_eq!(SimdChoice::parse("scalar").unwrap(), SimdChoice::Off);
        assert_eq!(SimdChoice::parse("sse2").unwrap(), SimdChoice::Sse2);
        assert_eq!(SimdChoice::parse("avx2").unwrap(), SimdChoice::Avx2);
        assert!(SimdChoice::parse("avx512").is_err());
        assert_eq!(SimdChoice::default(), SimdChoice::Auto);
        assert_eq!(SimdChoice::Off.to_string(), "off");
        // Off always resolves scalar; requests never exceed detection.
        assert_eq!(SimdChoice::Off.resolve(), SimdLevel::Scalar);
        assert!(SimdChoice::Auto.resolve() <= detected());
        assert!(SimdChoice::Avx2.resolve() <= detected());
    }

    #[test]
    fn forward_row_kernels_match_the_scalar_oracle() {
        for lv in vector_levels() {
            for &n in NS {
                let w = pattern(n, 3);
                let x = pattern(n * 3, 5);
                let (wsi, tau) = (0.21f32, 0.4f32);

                let mut base = vec![0.0f32; n];
                let mut got = vec![0.0f32; n];
                logits_row(SimdLevel::Scalar, &mut base, &w, wsi, tau);
                logits_row(lv, &mut got, &w, wsi, tau);
                assert_bits(&got, &base, &format!("logits {lv:?} n={n}"));

                let (mx_s, arg_s) = max_argmax(SimdLevel::Scalar, &base);
                let (mx_v, arg_v) = max_argmax(lv, &base);
                assert_eq!(mx_s.to_bits(), mx_v.to_bits(), "max {lv:?} n={n}");
                assert_eq!(arg_s, arg_v, "argmax {lv:?} n={n}");

                let mut exp_s = base.clone();
                let mut exp_v = base.clone();
                let den_s = exp_pass(SimdLevel::Scalar, &mut exp_s, mx_s);
                let den_v = exp_pass(lv, &mut exp_v, mx_s);
                assert_close(den_v, den_s, 1e-5, &format!("denom {lv:?} n={n}"));
                for (a, b) in exp_v.iter().zip(&exp_s) {
                    assert_close(*a, *b, 1e-5, &format!("exp {lv:?} n={n}"));
                }

                // Element-wise passes are bit-exact given the same input
                // row (use the scalar exp row for both sides).
                let inv = 1.0 / den_s;
                let mut cs_s = pattern(n, 7);
                let mut cs_v = cs_s.clone();
                let mut row_s = exp_s.clone();
                let mut row_v = exp_s.clone();
                scale_colsum(SimdLevel::Scalar, &mut row_s, &mut cs_s, inv);
                scale_colsum(lv, &mut row_v, &mut cs_v, inv);
                assert_bits(&row_v, &row_s, &format!("scale_colsum row {lv:?} n={n}"));
                assert_bits(&cs_v, &cs_s, &format!("scale_colsum cs {lv:?} n={n}"));

                let mut p_s = exp_s.clone();
                let mut p_v = exp_s.clone();
                scale(SimdLevel::Scalar, &mut p_s, inv);
                scale(lv, &mut p_v, inv);
                assert_bits(&p_v, &p_s, &format!("scale {lv:?} n={n}"));

                let y_s = fold_y_d3(SimdLevel::Scalar, &row_s, &x);
                let y_v = fold_y_d3(lv, &row_s, &x);
                assert_bits(&y_v, &y_s, &format!("fold_y_d3 {lv:?} n={n}"));

                let d = 64usize;
                let xw = pattern(n * d, 9);
                let mut yi_s = vec![0.0f32; d];
                let mut yi_v = vec![0.0f32; d];
                fold_y(SimdLevel::Scalar, &row_s, &xw, &mut yi_s, d);
                fold_y(lv, &row_s, &xw, &mut yi_v, d);
                assert_bits(&yi_v, &yi_s, &format!("fold_y {lv:?} n={n}"));
            }
        }
    }

    #[test]
    fn backward_row_kernels_match_the_scalar_oracle() {
        for lv in vector_levels() {
            for &n in NS {
                let w = pattern(n, 11);
                let x = pattern(n * 3, 13);
                let ct_cs = pattern(n, 15);
                let prob: Vec<f32> = pattern(n, 17).iter().map(|v| v + 0.6).collect();
                let cti = [0.3f32, -0.2, 0.7];
                let (wsi, tau) = (0.11f32, 0.5f32);

                let mut gb_s = vec![0.0f32; n];
                let mut gb_v = vec![0.0f32; n];
                let dot_s = gbuf_dot_d3(SimdLevel::Scalar, &ct_cs, &x, cti, &prob, &mut gb_s);
                let dot_v = gbuf_dot_d3(lv, &ct_cs, &x, cti, &prob, &mut gb_v);
                assert_bits(&gb_v, &gb_s, &format!("gbuf_d3 {lv:?} n={n}"));
                assert_close(dot_v, dot_s, 1e-5, &format!("dot_d3 {lv:?} n={n}"));

                let d = 64usize;
                let xw = pattern(n * d, 19);
                let ctw = pattern(d, 21);
                let mut gw_s = vec![0.0f32; n];
                let mut gw_v = vec![0.0f32; n];
                let ds = gbuf_dot(SimdLevel::Scalar, &ct_cs, &xw, &ctw, d, &prob, &mut gw_s);
                let dv = gbuf_dot(lv, &ct_cs, &xw, &ctw, d, &prob, &mut gw_v);
                assert_close(dv, ds, 1e-4, &format!("dot {lv:?} n={n}"));
                for (a, b) in gw_v.iter().zip(&gw_s) {
                    assert_close(*a, *b, 1e-5, &format!("gbuf {lv:?} n={n}"));
                }

                // dl_pass: identical inputs → bit-exact column gradient.
                let mut g1 = pattern(n, 23);
                let mut g2 = g1.clone();
                let a = dl_pass(SimdLevel::Scalar, &prob, &gb_s, dot_s, wsi, &w, tau, &mut g1);
                let b = dl_pass(lv, &prob, &gb_s, dot_s, wsi, &w, tau, &mut g2);
                assert_bits(&g2, &g1, &format!("dl gw {lv:?} n={n}"));
                assert_close(b, a, 1e-4, &format!("dl gws {lv:?} n={n}"));
            }
        }
    }

    #[test]
    fn loss_kernels_match_the_scalar_oracle() {
        for lv in vector_levels() {
            for &d in &[1usize, 3, 64] {
                let a = pattern(d, 25);
                let b = pattern(d, 27);
                let mut df_s = vec![0.0f32; d];
                let mut df_v = vec![0.0f32; d];
                let s_s = diff_normsq(SimdLevel::Scalar, &a, &b, &mut df_s);
                let s_v = diff_normsq(lv, &a, &b, &mut df_v);
                assert_bits(&df_v, &df_s, &format!("diff {lv:?} d={d}"));
                assert_close(s_v, s_s, 1e-5, &format!("normsq {lv:?} d={d}"));

                let mut p1_s = pattern(d, 29);
                let mut p2_s = pattern(d, 31);
                let mut p1_v = p1_s.clone();
                let mut p2_v = p2_s.clone();
                scatter_pair(SimdLevel::Scalar, &mut p1_s, &mut p2_s, &df_s, 0.37);
                scatter_pair(lv, &mut p1_v, &mut p2_v, &df_s, 0.37);
                assert_bits(&p1_v, &p1_s, &format!("scatter1 {lv:?} d={d}"));
                assert_bits(&p2_v, &p2_s, &format!("scatter2 {lv:?} d={d}"));
            }
            for &n in NS {
                let cs: Vec<f32> = pattern(n, 33).iter().map(|v| v + 1.0).collect();
                let mut ct_s = vec![0.0f32; n];
                let mut ct_v = vec![0.0f32; n];
                let a_s = colsum_loss(SimdLevel::Scalar, &cs, 2.0, &mut ct_s);
                let a_v = colsum_loss(lv, &cs, 2.0, &mut ct_v);
                assert_bits(&ct_v, &ct_s, &format!("ct_cs {lv:?} n={n}"));
                assert!((a_v - a_s).abs() <= 1e-6 * (1.0 + a_s.abs()), "acc {lv:?} n={n}");

                let y = pattern(n, 35);
                let m_s = sum_f64(SimdLevel::Scalar, &y);
                let m_v = sum_f64(lv, &y);
                assert!((m_v - m_s).abs() <= 1e-6 * (1.0 + m_s.abs()), "sum {lv:?} n={n}");

                let mut c_s = pattern(n, 37);
                let mut c_v = c_s.clone();
                axpy_mean(SimdLevel::Scalar, &mut c_s, &y, 0.21, 0.05);
                axpy_mean(lv, &mut c_v, &y, 0.21, 0.05);
                assert_bits(&c_v, &c_s, &format!("axpy {lv:?} n={n}"));
            }
        }
    }

    #[test]
    fn sinkhorn_kernels_match_the_scalar_oracle() {
        for lv in vector_levels() {
            for &n in &[1usize, 2, 3, 8, 9, 16, 33] {
                let base: Vec<f32> = pattern(n * n, 39).iter().map(|v| v * 4.0).collect();

                let mut la_s = base.clone();
                let mut la_v = base.clone();
                row_lse_normalize(SimdLevel::Scalar, &mut la_s, n);
                row_lse_normalize(lv, &mut la_v, n);
                for (a, b) in la_v.iter().zip(&la_s) {
                    assert!((a - b).abs() < 1e-5, "row_lse {lv:?} n={n}: {a} vs {b}");
                }

                let mut lc_s = base.clone();
                let mut lc_v = base.clone();
                col_lse_normalize(SimdLevel::Scalar, &mut lc_s, n);
                col_lse_normalize(lv, &mut lc_v, n);
                for (a, b) in lc_v.iter().zip(&lc_s) {
                    assert!((a - b).abs() < 1e-5, "col_lse {lv:?} n={n}: {a} vs {b}");
                }

                let mut e_s = la_s.clone();
                let mut e_v = la_s.clone();
                exp_in_place(SimdLevel::Scalar, &mut e_s);
                exp_in_place(lv, &mut e_v);
                for (a, b) in e_v.iter().zip(&e_s) {
                    assert_close(*a, *b, 1e-5, &format!("exp_in_place {lv:?} n={n}"));
                }
            }
        }
    }
}
