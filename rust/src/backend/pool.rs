//! Persistent worker pool for the native backend's row-parallel kernels.
//!
//! The pre-session code paid a `std::thread::scope` (spawn + join of every
//! worker) *twice per optimization step* — once for the forward row sweep,
//! once for the backward. A [`WorkerPool`] replaces that with threads
//! spawned once per [`super::StepSession`] and parked between dispatches:
//! a dispatch publishes a borrowed job under a mutex, wakes the workers
//! through a condvar, runs the dispatcher's own share inline, and blocks
//! until every worker has acknowledged — no heap allocation, no thread
//! creation, two mutex round-trips per worker per dispatch.
//!
//! Determinism is not the pool's concern: callers assign work by *logical
//! worker index* (`0` is the dispatching thread, `1..=spawned` the pool
//! threads) exactly as the old scoped code assigned chunk strides, so the
//! arithmetic — and therefore every f32 rounding — is unchanged.
//!
//! # Safety model
//!
//! The job is a `&(dyn Fn(usize) + Sync)` borrowed from the dispatcher's
//! stack, lifetime-erased into a raw fat pointer so it can sit in the
//! shared slot. This is sound because [`WorkerPool::dispatch`] cannot
//! return — not even by unwinding — before every worker has finished the
//! epoch: the wait lives in a drop guard, and workers acknowledge each
//! published epoch exactly once (wrapping their job call in
//! `catch_unwind`). The borrow therefore strictly outlives every use.
//!
//! # Failure model
//!
//! A panicking job does **not** abort the process (the old behavior was to
//! re-raise in the dispatcher, which would take down a long-running server
//! thread). Instead `dispatch` returns a typed [`PoolError::JobPanicked`]
//! and the pool marks itself **poisoned**: a panic may have left the
//! caller's chunk slabs half-written, so every later dispatch on the same
//! pool fails fast with [`PoolError::Poisoned`]. Sessions own their pool,
//! so recovery is "open a fresh session" — exactly what every driver run
//! does anyway.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Typed dispatch failure: the pool never panics across `dispatch`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// The job closure panicked on at least one worker (or on the
    /// dispatching thread itself) during this dispatch. The epoch still
    /// completed — every worker acknowledged — but results are suspect and
    /// the pool is now poisoned.
    JobPanicked,
    /// A previous dispatch on this pool panicked; the scratch state it was
    /// filling cannot be trusted. Open a fresh session (which spawns a
    /// fresh pool) to recover.
    Poisoned,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PoolError::JobPanicked => {
                write!(f, "native step worker panicked while executing a row sweep")
            }
            PoolError::Poisoned => write!(
                f,
                "worker pool is poisoned by an earlier panic — open a fresh step session"
            ),
        }
    }
}

impl std::error::Error for PoolError {}

/// Lifetime-erased fat pointer to the current job closure. Only ever
/// dereferenced between an epoch's publication and its acknowledgement,
/// while the dispatcher's frame (which owns the borrow) is pinned.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// Safety: the pointee is `Sync` (shared calls from many threads are fine)
// and `dispatch` guarantees it outlives every dereference (see module
// docs); the pointer itself is just an address.
unsafe impl Send for JobPtr {}

struct State {
    /// Monotonic epoch counter; a bump publishes `job`/`active`.
    epoch: u64,
    job: Option<JobPtr>,
    /// Logical workers that should run this epoch (index < active).
    active: usize,
    /// Spawned workers that have not yet acknowledged this epoch.
    remaining: usize,
    shutdown: bool,
    panicked: bool,
    /// Sticky: set once any epoch panicked; later dispatches fail fast.
    poisoned: bool,
}

struct Control {
    state: Mutex<State>,
    /// Workers wait here for a new epoch (or shutdown).
    work: Condvar,
    /// The dispatcher waits here for `remaining == 0`.
    done: Condvar,
}

/// A set of parked worker threads executing borrowed row-sweep jobs.
pub(crate) struct WorkerPool {
    ctl: Arc<Control>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `spawned` parked workers, logical indices `1..=spawned`
    /// (index 0 is the dispatching thread itself, so a pool for T-way
    /// parallelism spawns T−1 threads).
    pub fn new(spawned: usize) -> Self {
        let mut span = crate::trace::Span::child("pool_spawn");
        span.attr_u64("threads", spawned as u64);
        let ctl = Arc::new(Control {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                remaining: 0,
                shutdown: false,
                panicked: false,
                poisoned: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..spawned)
            .map(|t| {
                let ctl = Arc::clone(&ctl);
                std::thread::Builder::new()
                    .name(format!("sss-step-{}", t + 1))
                    .spawn(move || worker_loop(&ctl, t + 1))
                    .expect("spawn native step worker")
            })
            .collect();
        WorkerPool { ctl, handles }
    }

    /// Number of spawned (parked) worker threads.
    pub fn spawned(&self) -> usize {
        self.handles.len()
    }

    /// Run `job(i)` once for every logical worker index `i < active`,
    /// index 0 on the calling thread. Blocks until all workers (active or
    /// not — every spawned worker acknowledges every epoch) are done.
    /// A panic in any worker (or in the dispatcher's own `job(0)` call) is
    /// caught, reported as [`PoolError::JobPanicked`], and poisons the
    /// pool; it never unwinds out of `dispatch` or aborts the process.
    pub fn dispatch(
        &self,
        active: usize,
        job: &(dyn Fn(usize) + Sync),
    ) -> Result<(), PoolError> {
        // Hard invariant, checked in release too: an over-wide dispatch
        // would silently skip the chunks of the never-spawned workers and
        // let the chunk-ordered folds sum stale slab contents.
        assert!(
            active <= self.handles.len() + 1,
            "active {} > pool capacity {}",
            active,
            self.handles.len() + 1
        );
        if self.ctl.state.lock().expect("pool mutex poisoned").poisoned {
            return Err(PoolError::Poisoned);
        }
        if active <= 1 || self.handles.is_empty() {
            return match catch_unwind(AssertUnwindSafe(|| job(0))) {
                Ok(()) => Ok(()),
                Err(_) => {
                    self.ctl.state.lock().expect("pool mutex poisoned").poisoned = true;
                    Err(PoolError::JobPanicked)
                }
            };
        }
        // Erase the borrow's lifetime; see the module-level safety model.
        let ptr = JobPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(job)
        });
        {
            let mut st = self.ctl.state.lock().expect("pool mutex poisoned");
            st.job = Some(ptr);
            st.active = active;
            st.remaining = self.handles.len();
            st.panicked = false;
            st.epoch += 1;
            self.ctl.work.notify_all();
        }
        // The wait lives in a guard so it runs even if `job(0)` unwinds:
        // workers may still be reading the borrowed job.
        let guard = WaitGuard { ctl: &self.ctl };
        let local_ok = catch_unwind(AssertUnwindSafe(|| job(0))).is_ok();
        drop(guard);
        let mut st = self.ctl.state.lock().expect("pool mutex poisoned");
        if st.panicked || !local_ok {
            st.poisoned = true;
            return Err(PoolError::JobPanicked);
        }
        Ok(())
    }
}

struct WaitGuard<'a> {
    ctl: &'a Control,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.ctl.state.lock().expect("pool mutex poisoned");
        while st.remaining > 0 {
            st = self.ctl.done.wait(st).expect("pool mutex poisoned");
        }
        st.job = None;
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.ctl.state.lock().expect("pool mutex poisoned");
            st.shutdown = true;
            self.ctl.work.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(ctl: &Control, index: usize) {
    let mut seen = 0u64;
    loop {
        let (job, active) = {
            let mut st = ctl.state.lock().expect("pool mutex poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = ctl.work.wait(st).expect("pool mutex poisoned");
            }
            seen = st.epoch;
            (st.job.expect("published epoch carries a job"), st.active)
        };
        let ok = if index < active {
            catch_unwind(AssertUnwindSafe(|| (unsafe { &*job.0 })(index))).is_ok()
        } else {
            true
        };
        let mut st = ctl.state.lock().expect("pool mutex poisoned");
        if !ok {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            ctl.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn dispatch_covers_every_active_index_exactly_once() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.spawned(), 3);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.dispatch(4, &|wk| {
                hits[wk].fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        for (wk, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 50, "worker {wk}");
        }
    }

    #[test]
    fn inactive_workers_stay_idle() {
        let pool = WorkerPool::new(3);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.dispatch(2, &|wk| {
            hits[wk].fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits[0].load(Ordering::Relaxed), 1);
        assert_eq!(hits[1].load(Ordering::Relaxed), 1);
        assert_eq!(hits[2].load(Ordering::Relaxed), 0);
        assert_eq!(hits[3].load(Ordering::Relaxed), 0);
    }

    #[test]
    fn borrowed_state_is_visible_and_disjointly_writable() {
        // The realistic use: workers write disjoint stripes of a buffer
        // borrowed from the dispatcher's stack.
        let pool = WorkerPool::new(1);
        let mut out = vec![0u32; 8];
        let base = out.as_mut_ptr() as usize;
        pool.dispatch(2, &|wk| {
            for c in (wk..8).step_by(2) {
                // Safety: stripes are disjoint across worker indices.
                unsafe { *(base as *mut u32).add(c) = (10 + wk) as u32 };
            }
        })
        .unwrap();
        assert_eq!(out, vec![10, 11, 10, 11, 10, 11, 10, 11]);
    }

    #[test]
    fn worker_panic_is_a_typed_error_and_poisons_the_pool() {
        // Regression for the server path: a panicking job must surface as
        // an `Err` the caller can turn into a failed request — never as a
        // panic that unwinds through (and aborts) a long-running process.
        let pool = WorkerPool::new(1);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.dispatch(2, &|wk| {
                if wk == 1 {
                    panic!("boom");
                }
            })
        }));
        assert_eq!(caught.expect("dispatch must not panic"), Err(PoolError::JobPanicked));
        // The panic may have left caller scratch half-written: the pool is
        // poisoned and every later dispatch fails fast (recovery = fresh
        // session = fresh pool).
        let hits = AtomicUsize::new(0);
        let again = pool.dispatch(2, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(again, Err(PoolError::Poisoned));
        assert_eq!(hits.load(Ordering::Relaxed), 0, "poisoned pool must not run jobs");
    }

    #[test]
    fn dispatcher_thread_panic_is_also_caught() {
        // Index 0 runs on the dispatching thread; its panic takes the same
        // typed-error path as a pool worker's.
        let pool = WorkerPool::new(1);
        let r = pool.dispatch(2, &|wk| {
            if wk == 0 {
                panic!("boom on the dispatcher");
            }
        });
        assert_eq!(r, Err(PoolError::JobPanicked));
        assert_eq!(pool.dispatch(2, &|_| {}), Err(PoolError::Poisoned));
    }

    #[test]
    fn inline_dispatch_panic_poisons_too() {
        // With no spawned workers the job runs inline — same failure model.
        let pool = WorkerPool::new(0);
        let r = pool.dispatch(1, &|_| panic!("inline boom"));
        assert_eq!(r, Err(PoolError::JobPanicked));
        assert_eq!(pool.dispatch(1, &|_| {}), Err(PoolError::Poisoned));
    }

    #[test]
    fn single_worker_dispatch_runs_inline() {
        // With no spawned workers the job runs on the caller thread only.
        let pool = WorkerPool::new(0);
        let hits = AtomicUsize::new(0);
        pool.dispatch(1, &|wk| {
            hits.fetch_add(wk + 1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
