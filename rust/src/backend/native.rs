//! Pure-Rust compute backend: the learned methods' per-step functions with
//! hand-derived backward passes — no JAX, no XLA, no artifacts.
//!
//! Mirrors `python/compile/model.py` + `losses.py` operation by operation
//! (same f32 arithmetic, same constants), so a `NativeBackend` step agrees
//! with the AOT artifact to float tolerance — enforced by the parity tests
//! in `rust/tests/integration.rs` and by the finite-difference gradient
//! checks below (which run on every `cargo test`, artifacts or not).
//!
//! Memory follows the paper's "row-wise" requirement (§II): the N×N
//! SoftSort matrix is never materialized — forward computes each row,
//! consumes it and keeps only y/colsum/argmax; backward *recomputes* the
//! row (the chunked-oracle trick of `python/compile/kernels/ref.py`) and
//! reduces straight into the weight gradient. Working set is O(C·N) for a
//! fixed row chunk C.
//!
//! Hot path: all per-shape state lives in a [`NativeSession`]. Every
//! scratch buffer — sort state, per-chunk reduction slabs, per-worker row
//! stripes, loss cotangents, the Sinkhorn state stack, kiss factor
//! buffers — is a typed view into **one 64-byte-aligned arena
//! allocation** ([`Arena`]), laid out by a [`LayoutCursor`] with every
//! slot padded to a cache-line boundary. A session holds exactly one live
//! allocation per memoized shape; the layout is rebuilt only when a new
//! step family first joins (or the kissing rank changes). The
//! steady-state step loop allocates nothing and spawns nothing.
//!
//! The row kernels (logits, max-scan, exp, accumulate, the dL/dP pass,
//! the eq. 2-4 loss reductions, and the Sinkhorn normalizations) dispatch
//! through [`simd`]: explicit SSE2/AVX2 `core::arch` paths behind runtime
//! detection, with the original scalar loops kept verbatim as the
//! bit-exactness oracle (`simd=off`). Element-wise math is bit-exact
//! across levels; anything through the vector `exp` or a horizontal
//! reduction agrees to ~1e-6 relative (see `backend/simd.rs` for the
//! per-kernel contract).
//!
//! Parallelism: rows are independent, so both SoftSort passes fan chunks
//! of [`ROW_CHUNK`] rows across the session pool. Reductions (colsum,
//! dL/dw) are accumulated per chunk into preallocated slabs and folded
//! **in chunk index order**, so results are bit-identical for any pool
//! size — the property `Engine::sort_batch` relies on when batch workers
//! share one backend. Per-worker stripes are cache-line padded so
//! adjacent workers never false-share a stripe boundary. Small problems
//! (N < [`PAR_MIN_N`]) stay sequential and never spawn pool threads.
//!
//! The Gumbel-Sinkhorn and Kissing baselines are implemented sequentially
//! (they are comparison points, not the hot path); GS reverse-mode keeps
//! the 2·`SINKHORN_ITERS` intermediate N² log-matrices in one arena slot
//! that is reused every step.

use std::alloc::Layout;
use std::ptr::NonNull;

use anyhow::{bail, ensure, Result};

use crate::util::stats::std_f32;

use super::pool::{PoolError, WorkerPool};
use super::simd::{self, SimdLevel};
use super::{
    GsStep, KissStep, SessionOpts, SssStep, StepBackend, StepSession, StepShape,
};

/// Loss weights and epsilons — must match `python/compile/losses.py`.
const LAMBDA_S: f32 = 1.0;
const LAMBDA_SIGMA: f32 = 2.0;
const EPS: f32 = 1e-12;

/// Kissing softmax sharpness — must match `model.py::KISS_SCALE`.
const KISS_SCALE: f32 = 30.0;
/// Sinkhorn normalization sweeps — must match `model.py::SINKHORN_ITERS`.
const SINKHORN_ITERS: usize = 20;
/// Row-norm guard — must match the `1e-8` in `model.py::make_kiss_step`.
const KISS_NORM_EPS: f32 = 1e-8;

/// Rows per parallel work unit. Fixed (not derived from the thread count)
/// so the reduction tree — and therefore every f32 rounding — is identical
/// no matter how many workers run.
const ROW_CHUNK: usize = 128;
/// Below this N a step is cheaper than coordinating threads; sessions for
/// smaller shapes stay sequential and never spawn a pool.
pub const PAR_MIN_N: usize = 512;

/// The pure-Rust step backend. `Send + Sync`: one instance can serve any
/// number of threads concurrently (all mutable state lives in the
/// per-caller [`NativeSession`]s it opens).
#[derive(Clone, Debug)]
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NativeBackend { threads }
    }
}

impl NativeBackend {
    /// Backend with an explicit default session pool size (1 = sequential).
    /// Individual sessions can override it (`StepBackend::session`).
    pub fn new(threads: usize) -> Self {
        NativeBackend { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Like [`StepBackend::session`], preserving the concrete `Send` bound
    /// the trait-object return type erases (native sessions are plain
    /// owned data + a pool, so they may move across threads).
    pub fn session_send(
        &self,
        shape: StepShape,
        opts: SessionOpts,
    ) -> Result<Box<dyn StepSession + Send>> {
        let requested = opts.threads.unwrap_or(self.threads).max(1);
        // Below PAR_MIN_N a step is cheaper than coordinating workers:
        // stay sequential (and never spawn pool threads). Never keep more
        // workers than there are row chunks to hand out — extra threads
        // would only wake to acknowledge epochs they can't work on.
        let effective = if shape.n < PAR_MIN_N {
            1
        } else {
            requested.min(shape.n.div_ceil(ROW_CHUNK))
        };
        let level = opts.simd.resolve();
        let mut span = crate::trace::Span::child("session_build");
        span.attr_u64("n", shape.n as u64);
        span.attr_u64("d", shape.d as u64);
        span.attr_u64("threads", effective as u64);
        Ok(Box::new(NativeSession::new(shape, effective, level)?))
    }
}

// --------------------------------------------------------------------------
// Shared helpers.
// --------------------------------------------------------------------------

#[inline]
fn sgn(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Raw `f32` base pointer that may cross into pool workers. Each worker
/// touches a disjoint region determined by its logical index, so shared
/// access is sound (see the dispatch sites).
#[derive(Clone, Copy)]
struct SendPtrF32(*mut f32);
unsafe impl Send for SendPtrF32 {}
unsafe impl Sync for SendPtrF32 {}

/// Same for `i32` outputs (sort_idx).
#[derive(Clone, Copy)]
struct SendPtrI32(*mut i32);
unsafe impl Send for SendPtrI32 {}
unsafe impl Sync for SendPtrI32 {}

/// Run `job(worker)` for workers `0..active` — on the pool when one
/// exists and parallelism is requested, inline otherwise. Pool-worker
/// panics surface as a typed [`PoolError`] (and poison the session's
/// pool) instead of unwinding into — and aborting — the caller's thread.
fn dispatch(
    pool: Option<&WorkerPool>,
    active: usize,
    job: &(dyn Fn(usize) + Sync),
) -> Result<(), PoolError> {
    match pool {
        Some(p) if active > 1 => p.dispatch(active, job),
        _ => {
            job(0);
            Ok(())
        }
    }
}

/// Stable descending argsort of `w` into `idx` (ties keep index order,
/// matching `jnp.argsort(-w)`), bottom-up merge into the preallocated
/// `tmp` buffer — no per-call allocation. Produces the same permutation
/// as `slice::sort_by` with the descending comparator (a stable sort's
/// output is unique).
fn stable_argsort_desc(idx: &mut [u32], tmp: &mut [u32], w: &[f32]) {
    let n = idx.len();
    debug_assert_eq!(tmp.len(), n);
    let mut width = 1;
    while width < n {
        let mut lo = 0;
        while lo < n {
            let mid = (lo + width).min(n);
            let hi = (lo + 2 * width).min(n);
            let (mut i, mut j, mut k) = (lo, mid, lo);
            while i < mid && j < hi {
                let (a, b) = (idx[i], idx[j]);
                // Descending by w; NaN and ties compare Equal, which keeps
                // the left run first (stability), exactly like the
                // `partial_cmp(..).unwrap_or(Equal)` comparator.
                let take_left = !matches!(
                    w[b as usize].partial_cmp(&w[a as usize]),
                    Some(std::cmp::Ordering::Greater)
                );
                if take_left {
                    tmp[k] = a;
                    i += 1;
                } else {
                    tmp[k] = b;
                    j += 1;
                }
                k += 1;
            }
            let left = mid - i;
            tmp[k..k + left].copy_from_slice(&idx[i..mid]);
            tmp[k + left..hi].copy_from_slice(&idx[j..hi]);
            lo = hi;
        }
        idx.copy_from_slice(tmp);
        width *= 2;
    }
}

// --------------------------------------------------------------------------
// The session arena: one 64-byte-aligned allocation for all scratch.
// --------------------------------------------------------------------------

/// Arena alignment: one x86 cache line, which also satisfies every SIMD
/// load the kernels issue.
const ARENA_ALIGN: usize = 64;
/// f32 words per cache line — slot offsets and per-worker stripe widths
/// are rounded up to this, so no two slots (or stripes) share a line.
const LINE_WORDS: usize = ARENA_ALIGN / std::mem::size_of::<f32>();

/// A sub-range of the arena, in f32 words.
#[derive(Clone, Copy, Debug)]
struct Slot {
    off: usize,
    len: usize,
}

/// Carves cache-line-aligned slots out of a growing word count. All the
/// slots of a layout are reserved in one pass, so offsets never overlap.
struct LayoutCursor {
    words: usize,
}

impl LayoutCursor {
    fn new() -> Self {
        LayoutCursor { words: 0 }
    }

    fn slot(&mut self, len: usize) -> Slot {
        let off = self.words;
        self.words += len.div_ceil(LINE_WORDS) * LINE_WORDS;
        Slot { off, len }
    }
}

/// The single backing allocation. Zero-initialized, so a freshly rebuilt
/// layout starts clean — every slot is fully rewritten before it is read
/// in each step anyway.
struct Arena {
    ptr: NonNull<u8>,
    words: usize,
}

impl Arena {
    fn new(words: usize) -> Self {
        let layout = Self::layout(words);
        // Safety: the layout always has a non-zero size.
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        let Some(ptr) = NonNull::new(raw) else {
            std::alloc::handle_alloc_error(layout)
        };
        Arena { ptr, words }
    }

    fn layout(words: usize) -> Layout {
        let bytes = (words * std::mem::size_of::<f32>()).max(ARENA_ALIGN);
        Layout::from_size_align(bytes, ARENA_ALIGN).expect("arena layout")
    }

    fn base(&self) -> *mut f32 {
        self.ptr.as_ptr() as *mut f32
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        // Safety: `ptr` came from `alloc_zeroed` with this exact layout.
        unsafe { std::alloc::dealloc(self.ptr.as_ptr(), Self::layout(self.words)) };
    }
}

// Plain owned memory; sessions (and their arena) may cross threads.
unsafe impl Send for Arena {}

/// Materialize a slot as f32. Safety: the caller must hold at most one
/// live view per slot (slots from one `LayoutCursor` pass never overlap)
/// and drop every view before the arena is rebuilt or dropped.
unsafe fn view_f32<'a>(base: *mut f32, slot: Slot) -> &'a mut [f32] {
    std::slice::from_raw_parts_mut(base.add(slot.off), slot.len)
}

/// Same, reinterpreted as u32 (σ and the merge buffer; same size/align).
unsafe fn view_u32<'a>(base: *mut f32, slot: Slot) -> &'a mut [u32] {
    std::slice::from_raw_parts_mut(base.add(slot.off) as *mut u32, slot.len)
}

// --------------------------------------------------------------------------
// Eq. (2) grid loss into reusable arena views.
// --------------------------------------------------------------------------

/// Arena slots for [`grid_loss_into`]'s cotangent buffers.
#[derive(Clone, Copy)]
struct LossSlots {
    /// dL/d(gathered grid output), n·d.
    dyg: Slot,
    /// dL/dy after un-gathering, n·d.
    ct_y: Slot,
    /// dL/dcolsum, n.
    ct_cs: Slot,
    /// Per-pair displacement, d.
    diff: Slot,
}

impl LossSlots {
    fn reserve(cur: &mut LayoutCursor, n: usize, d: usize) -> Self {
        LossSlots {
            dyg: cur.slot(n * d),
            ct_y: cur.slot(n * d),
            ct_cs: cur.slot(n),
            diff: cur.slot(d),
        }
    }
}

/// Materialized loss workspace. After [`grid_loss_into`], `ct_y` holds
/// dL/dy and `ct_cs` dL/dcolsum.
struct LossViews<'a> {
    dyg: &'a mut [f32],
    ct_y: &'a mut [f32],
    ct_cs: &'a mut [f32],
    diff: &'a mut [f32],
}

/// Eq. (2) objective on a soft output `y`; returns the loss and leaves the
/// cotangents the backward passes need in `ws` (`ct_y = dL/dy`,
/// `ct_cs = dL/dcolsum`).
///
/// `inv_idx`: when `Some`, the neighbor term is evaluated on the
/// reverse-shuffled output `y[inv_idx]` (the ShuffleSoftSort gather);
/// `None` means the identity arrangement (GS/Kissing).
/// `colsum`: when `Some`, the stochastic-constraint term λ_s·L_s is
/// included (GS omits it — Sinkhorn already enforces stochasticity).
#[allow(clippy::too_many_arguments)]
fn grid_loss_into(
    level: SimdLevel,
    shape: StepShape,
    x: &[f32],
    y: &[f32],
    inv_idx: Option<&[i32]>,
    colsum: Option<&[f32]>,
    norm: f32,
    ws: &mut LossViews,
) -> f32 {
    let StepShape { n, d, h, w } = shape;
    let row_of = |k: usize| -> usize {
        match inv_idx {
            Some(iv) => iv[k] as usize,
            None => k,
        }
    };

    // L_nbr and its gradient w.r.t. the (gathered) grid output.
    let horiz = h * (w.saturating_sub(1));
    let vert = if h > 1 { (h - 1) * w } else { 0 };
    let count = (horiz + vert).max(1) as f32;
    let coef = 1.0 / (count * norm);
    ws.dyg.fill(0.0);
    let mut total = 0.0f64;
    {
        let diff = &mut *ws.diff;
        let dyg = &mut *ws.dyg;
        let mut pair = |k1: usize, k2: usize| {
            let (a, b) = (row_of(k1) * d, row_of(k2) * d);
            let s = simd::diff_normsq(level, &y[a..a + d], &y[b..b + d], diff);
            let dist = (s + EPS).sqrt();
            total += dist as f64;
            let g = coef / dist;
            // Every grid-neighbor pair has k1 < k2, so the split is safe.
            let (lo, hi) = dyg.split_at_mut(k2 * d);
            simd::scatter_pair(level, &mut lo[k1 * d..k1 * d + d], &mut hi[..d], diff, g);
        };
        for r in 0..h {
            for c in 0..w.saturating_sub(1) {
                let k = r * w + c;
                pair(k, k + 1);
            }
        }
        if h > 1 {
            for r in 0..h - 1 {
                for c in 0..w {
                    let k = r * w + c;
                    pair(k, k + w);
                }
            }
        }
    }
    let l_nbr = total as f32 * coef;

    // Scatter d/dy_grid back through the gather (bijective → plain adds).
    if inv_idx.is_some() {
        ws.ct_y.fill(0.0);
        for k in 0..n {
            let r = row_of(k) * d;
            for t in 0..d {
                ws.ct_y[r + t] += ws.dyg[k * d + t];
            }
        }
    } else {
        ws.ct_y.copy_from_slice(ws.dyg);
    }

    // λ_s · L_s (eq. 3) on the column sums.
    ws.ct_cs.fill(0.0);
    let mut l_s = 0.0f32;
    if let Some(cs) = colsum {
        let acc = simd::colsum_loss(level, cs, LAMBDA_S * 2.0, ws.ct_cs);
        l_s = (acc / n as f64) as f32;
    }

    // λ_σ · L_σ (eq. 4): |σ_X − σ_Y| / σ_X over all N·d entries.
    let sx = std_f32(x);
    let sy = std_f32(y);
    let l_sigma = (sx - sy).abs() / (sx + EPS);
    if sy > 0.0 && sx != sy {
        let m = (n * d) as f64;
        let mu_y = (simd::sum_f64(level, y) / m) as f32;
        let a = LAMBDA_SIGMA * sgn(sy - sx) / (sx + EPS) / (m as f32 * sy);
        simd::axpy_mean(level, ws.ct_y, y, a, mu_y);
    }

    l_nbr + LAMBDA_S * l_s + LAMBDA_SIGMA * l_sigma
}

// --------------------------------------------------------------------------
// SoftSort / ShuffleSoftSort step kernels.
// --------------------------------------------------------------------------

/// Arena slots for the SoftSort step family: sort state, per-chunk
/// reduction slabs, per-worker scratch stripes.
#[derive(Clone, Copy)]
struct SssSlots {
    /// Cache-line-padded per-worker stripe width (≥ n words), so adjacent
    /// workers never false-share a stripe boundary.
    stripe: usize,
    /// Stable descending argsort of w (σ), n (u32).
    sigma: Slot,
    /// Merge-sort ping buffer, n (u32).
    sort_tmp: Slot,
    /// w gathered through σ (the sorted weights), n.
    ws_sorted: Slot,
    /// Per-chunk colsum partials (n_chunks × n), folded in chunk order.
    chunk_cs: Slot,
    /// Per-chunk column-side gradient partials (n_chunks × n).
    chunk_gw: Slot,
    /// Sorted-row gradients by global row index, n.
    gws: Slot,
    /// Per-worker softmax-row scratch stripes (threads × stripe).
    row_scratch: Slot,
    /// Per-worker dL/dP-row scratch stripes (threads × stripe).
    g_scratch: Slot,
}

impl SssSlots {
    fn reserve(cur: &mut LayoutCursor, n: usize, threads: usize) -> Self {
        let n_chunks = n.div_ceil(ROW_CHUNK);
        let stripe = n.div_ceil(LINE_WORDS) * LINE_WORDS;
        SssSlots {
            stripe,
            sigma: cur.slot(n),
            sort_tmp: cur.slot(n),
            ws_sorted: cur.slot(n),
            chunk_cs: cur.slot(n_chunks * n),
            chunk_gw: cur.slot(n_chunks * n),
            gws: cur.slot(n),
            row_scratch: cur.slot(threads * stripe),
            g_scratch: cur.slot(threads * stripe),
        }
    }
}

/// Row-block forward: y = P·x, sort_idx = argmax rows, colsum = Σ rows.
/// P rows are computed, consumed and dropped (row-wise memory). Writes
/// y/sort_idx directly into `out` (disjoint chunk regions per worker) and
/// folds the per-chunk colsum partials in chunk index order.
#[allow(clippy::too_many_arguments)]
fn sss_forward(
    pool: Option<&WorkerPool>,
    threads: usize,
    level: SimdLevel,
    stripe: usize,
    n: usize,
    d: usize,
    ws_sorted: &[f32],
    w: &[f32],
    x: &[f32],
    tau: f32,
    chunk_cs: &mut [f32],
    row_scratch: &mut [f32],
    out: &mut SssStep,
) -> Result<(), PoolError> {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let active = threads.min(n_chunks).max(1);
    let y_ptr = SendPtrF32(out.y.as_mut_ptr());
    let idx_ptr = SendPtrI32(out.sort_idx.as_mut_ptr());
    let cs_ptr = SendPtrF32(chunk_cs.as_mut_ptr());
    let row_ptr = SendPtrF32(row_scratch.as_mut_ptr());
    let job = move |wk: usize| {
        // Safety: worker `wk` owns cache-line-padded scratch stripe `wk`
        // and exactly the chunks c ≡ wk (mod active) — all regions
        // disjoint across workers, and the dispatch blocks until every
        // worker finished.
        let row =
            unsafe { std::slice::from_raw_parts_mut(row_ptr.0.add(wk * stripe), n) };
        let mut c = wk;
        while c < n_chunks {
            let r0 = c * ROW_CHUNK;
            let r1 = (r0 + ROW_CHUNK).min(n);
            let cs = unsafe { std::slice::from_raw_parts_mut(cs_ptr.0.add(c * n), n) };
            cs.fill(0.0);
            for i in r0..r1 {
                let wsi = ws_sorted[i];
                simd::logits_row(level, row, w, wsi, tau);
                let (mx, arg) = simd::max_argmax(level, row);
                let denom = simd::exp_pass(level, row, mx);
                let inv = 1.0 / denom;
                unsafe { *idx_ptr.0.add(i) = arg as i32 };
                // Probabilities → colsum + y: scale the row in place
                // (adding each probability into the chunk's colsum), then
                // fold the output row — same per-element op order as the
                // fused scalar loop had.
                simd::scale_colsum(level, row, cs, inv);
                if d == 3 {
                    let y3 = simd::fold_y_d3(level, row, x);
                    unsafe {
                        *y_ptr.0.add(i * 3) = y3[0];
                        *y_ptr.0.add(i * 3 + 1) = y3[1];
                        *y_ptr.0.add(i * 3 + 2) = y3[2];
                    }
                } else {
                    let yi =
                        unsafe { std::slice::from_raw_parts_mut(y_ptr.0.add(i * d), d) };
                    yi.fill(0.0);
                    simd::fold_y(level, row, x, yi, d);
                }
            }
            c += active;
        }
    };
    dispatch(pool, active, &job)?;

    // Deterministic reduction: fold per-chunk column partials in chunk
    // index order — bit-identical for any pool size.
    out.colsum.fill(0.0);
    for c in 0..n_chunks {
        for (dst, &s) in out.colsum.iter_mut().zip(&chunk_cs[c * n..(c + 1) * n]) {
            *dst += s;
        }
    }
    Ok(())
}

/// Row-block backward: recompute each P row, pull the loss cotangents
/// through softmax and the |ws_i − w_j| kernel, reduce into dL/dw via the
/// chunk-ordered fold + the σ scatter (sort_desc's VJP).
#[allow(clippy::too_many_arguments)]
fn sss_backward(
    pool: Option<&WorkerPool>,
    threads: usize,
    level: SimdLevel,
    stripe: usize,
    n: usize,
    d: usize,
    ws_sorted: &[f32],
    w: &[f32],
    sigma: &[u32],
    x: &[f32],
    tau: f32,
    ct_y: &[f32],
    ct_cs: &[f32],
    chunk_gw: &mut [f32],
    gws: &mut [f32],
    row_scratch: &mut [f32],
    g_scratch: &mut [f32],
    grad: &mut [f32],
) -> Result<(), PoolError> {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let active = threads.min(n_chunks).max(1);
    let gw_ptr = SendPtrF32(chunk_gw.as_mut_ptr());
    let gws_ptr = SendPtrF32(gws.as_mut_ptr());
    let prob_ptr = SendPtrF32(row_scratch.as_mut_ptr());
    let gbuf_ptr = SendPtrF32(g_scratch.as_mut_ptr());
    let job = move |wk: usize| {
        // Safety: disjoint padded stripes/chunks per worker, as in the
        // forward.
        let prob =
            unsafe { std::slice::from_raw_parts_mut(prob_ptr.0.add(wk * stripe), n) };
        let gbuf =
            unsafe { std::slice::from_raw_parts_mut(gbuf_ptr.0.add(wk * stripe), n) };
        let mut c = wk;
        while c < n_chunks {
            let r0 = c * ROW_CHUNK;
            let r1 = (r0 + ROW_CHUNK).min(n);
            let gw = unsafe { std::slice::from_raw_parts_mut(gw_ptr.0.add(c * n), n) };
            gw.fill(0.0);
            for i in r0..r1 {
                let wsi = ws_sorted[i];
                // Recompute the probability row (identical pass structure
                // to the forward, so the same f32 roundings reproduce).
                simd::logits_row(level, prob, w, wsi, tau);
                let mx = simd::max_scan(level, prob);
                let denom = simd::exp_pass(level, prob, mx);
                simd::scale(level, prob, 1.0 / denom);

                // dL/dP_ij = ct_y[i]·x_j + ct_cs[j]; softmax row backward.
                let cti = &ct_y[i * d..(i + 1) * d];
                let dot = if d == 3 {
                    simd::gbuf_dot_d3(
                        level,
                        ct_cs,
                        x,
                        [cti[0], cti[1], cti[2]],
                        prob,
                        gbuf,
                    )
                } else {
                    simd::gbuf_dot(level, ct_cs, x, cti, d, prob, gbuf)
                };
                let gws_i = simd::dl_pass(level, prob, gbuf, dot, wsi, w, tau, gw);
                unsafe { *gws_ptr.0.add(i) = gws_i };
            }
            c += active;
        }
    };
    dispatch(pool, active, &job)?;

    // Deterministic reduction: chunk-ordered column partials, then the
    // sorted-side scatter through σ in ascending row order (identical to
    // the pre-session chunk-then-row iteration).
    grad.fill(0.0);
    for c in 0..n_chunks {
        for (g, &p) in grad.iter_mut().zip(&chunk_gw[c * n..(c + 1) * n]) {
            *g += p;
        }
    }
    for (i, &gv) in gws.iter().enumerate() {
        grad[sigma[i] as usize] += gv;
    }
    Ok(())
}

// --------------------------------------------------------------------------
// Gumbel-Sinkhorn helpers.
// --------------------------------------------------------------------------

/// Arena slots for the GS step family. `states` is the reverse-mode state
/// stack: one flat slab for the 2·`SINKHORN_ITERS` post-normalization
/// log-matrices, reused every step.
#[derive(Clone, Copy)]
struct GsSlots {
    la: Slot,
    states: Slot,
    dz: Slot,
    y: Slot,
}

impl GsSlots {
    fn reserve(cur: &mut LayoutCursor, n: usize, d: usize) -> Self {
        GsSlots {
            la: cur.slot(n * n),
            states: cur.slot(2 * SINKHORN_ITERS * n * n),
            dz: cur.slot(n * n),
            y: cur.slot(n * d),
        }
    }
}

/// Log-space Sinkhorn forward, in place. When `states` is `Some`, the
/// output of every normalization is copied into the slab (reverse-mode
/// needs exactly those values). Ends by exponentiating `la` into P.
fn sinkhorn_log_in_place(
    level: SimdLevel,
    la: &mut [f32],
    n: usize,
    mut states: Option<&mut [f32]>,
) {
    let n2 = n * n;
    for it in 0..SINKHORN_ITERS {
        simd::row_lse_normalize(level, la, n);
        if let Some(s) = states.as_deref_mut() {
            s[2 * it * n2..(2 * it + 1) * n2].copy_from_slice(la);
        }
        simd::col_lse_normalize(level, la, n);
        if let Some(s) = states.as_deref_mut() {
            s[(2 * it + 1) * n2..(2 * it + 2) * n2].copy_from_slice(la);
        }
    }
    simd::exp_in_place(level, la);
}

// --------------------------------------------------------------------------
// Kissing helpers.
// --------------------------------------------------------------------------

/// Classic kissing numbers K(M) — mirrors `python/compile/shapes.py`
/// (`kissing_number(M) ≥ N` picks the rank; Table 2 pins M(1024) = 13).
const KISSING_TABLE: &[(usize, usize)] =
    &[(240, 8), (306, 9), (500, 10), (582, 11), (840, 12), (1154, 13), (4320, 16)];

/// Arena slots for the Kissing step family (sized for one factor rank
/// `m`; the layout is rebuilt if a caller switches ranks mid-session,
/// which drivers never do).
#[derive(Clone, Copy)]
struct KissSlots {
    m: usize,
    norms_v: Slot,
    norms_w: Slot,
    vn: Slot,
    wn: Slot,
    dvn: Slot,
    dwn: Slot,
    y: Slot,
    colsum: Slot,
    row: Slot,
    gbuf: Slot,
}

impl KissSlots {
    fn reserve(cur: &mut LayoutCursor, n: usize, d: usize, m: usize) -> Self {
        KissSlots {
            m,
            norms_v: cur.slot(n),
            norms_w: cur.slot(n),
            vn: cur.slot(n * m),
            wn: cur.slot(n * m),
            dvn: cur.slot(n * m),
            dwn: cur.slot(n * m),
            y: cur.slot(n * d),
            colsum: cur.slot(n),
            row: cur.slot(n),
            gbuf: cur.slot(n),
        }
    }
}

/// Row L2 norms and the row-normalized matrix v̂ = v / (‖v_row‖ + ε),
/// written into the preallocated `norms`/`vn`.
fn normalize_rows_into(v: &[f32], n: usize, m: usize, norms: &mut [f32], vn: &mut [f32]) {
    for i in 0..n {
        let row = &v[i * m..(i + 1) * m];
        let mut s = 0.0f32;
        for &a in row {
            s += a * a;
        }
        let r = s.sqrt();
        norms[i] = r;
        let inv = 1.0 / (r + KISS_NORM_EPS);
        for (dst, &a) in vn[i * m..(i + 1) * m].iter_mut().zip(row) {
            *dst = a * inv;
        }
    }
}

/// VJP of row normalization: given dL/dv̂ in `dvn`, write dL/dv into `dv`.
fn normalize_rows_backward_into(
    v: &[f32],
    norms: &[f32],
    dvn: &[f32],
    n: usize,
    m: usize,
    dv: &mut [f32],
) {
    for i in 0..n {
        let r = norms[i];
        let denom = r + KISS_NORM_EPS;
        let vi = &v[i * m..(i + 1) * m];
        let di = &dvn[i * m..(i + 1) * m];
        let mut dot = 0.0f32;
        for (&a, &b) in vi.iter().zip(di) {
            dot += a * b;
        }
        let out = &mut dv[i * m..(i + 1) * m];
        if r > 0.0 {
            let k = dot / (r * denom * denom);
            for ((o, &b), &a) in out.iter_mut().zip(di).zip(vi) {
                *o = b / denom - a * k;
            }
        } else {
            for (o, &b) in out.iter_mut().zip(di) {
                *o = b / denom;
            }
        }
    }
}

/// One row of P = row-softmax(scale·v̂ŵᵀ/τ) into `row`; returns the argmax.
fn kiss_softmax_row(
    i: usize,
    m: usize,
    scale_t: f32,
    vn: &[f32],
    wn: &[f32],
    row: &mut [f32],
) -> usize {
    let vi = &vn[i * m..(i + 1) * m];
    let mut mx = f32::NEG_INFINITY;
    let mut arg = 0usize;
    for (j, rj) in row.iter_mut().enumerate() {
        let wj = &wn[j * m..(j + 1) * m];
        let mut dot = 0.0f32;
        for (&a, &b) in vi.iter().zip(wj) {
            dot += a * b;
        }
        let l = scale_t * dot;
        *rj = l;
        if l > mx {
            mx = l;
            arg = j;
        }
    }
    let mut denom = 0.0f32;
    for rj in row.iter_mut() {
        *rj = (*rj - mx).exp();
        denom += *rj;
    }
    let inv = 1.0 / denom;
    for rj in row.iter_mut() {
        *rj *= inv;
    }
    arg
}

// --------------------------------------------------------------------------
// Session + trait implementation.
// --------------------------------------------------------------------------

fn check_shape(shape: StepShape) -> Result<()> {
    ensure!(shape.n >= 2, "native backend needs N >= 2 (got {})", shape.n);
    ensure!(
        shape.h * shape.w == shape.n,
        "grid {}x{} != N={}",
        shape.h,
        shape.w,
        shape.n
    );
    Ok(())
}

fn check_scalars(tau: f32, norm: f32) -> Result<()> {
    ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
    ensure!(norm.is_finite() && norm > 0.0, "norm must be positive, got {norm}");
    Ok(())
}

/// The native backend's stateful per-shape session: every scratch buffer
/// lives in one arena allocation (slots reserved when a step family is
/// first used), plus a persistent worker pool (spawned lazily on the
/// first parallel dispatch). The steady-state step loop allocates nothing
/// and spawns nothing.
struct NativeSession {
    shape: StepShape,
    /// Effective row-parallel width for this shape (PAR_MIN_N-gated).
    threads: usize,
    /// Resolved SIMD level every kernel in this session dispatches on.
    level: SimdLevel,
    pool: Option<WorkerPool>,
    /// The one backing allocation for every slot below; rebuilt only when
    /// a new step family joins the layout (or the kissing rank changes).
    arena: Option<Arena>,
    sss: Option<SssSlots>,
    loss: Option<LossSlots>,
    gs: Option<GsSlots>,
    kiss: Option<KissSlots>,
}

impl NativeSession {
    fn new(shape: StepShape, threads: usize, level: SimdLevel) -> Result<Self> {
        check_shape(shape)?;
        Ok(NativeSession {
            shape,
            threads,
            level,
            pool: None,
            arena: None,
            sss: None,
            loss: None,
            gs: None,
            kiss: None,
        })
    }

    fn ensure_pool(&mut self) {
        if self.threads > 1 && self.pool.is_none() {
            self.pool = Some(WorkerPool::new(self.threads - 1));
        }
    }

    /// (Re)build the arena so it covers every step family requested so
    /// far. The layout is recomputed from scratch whenever a new family
    /// joins (or the kissing rank changes); all slots are fully rewritten
    /// before they are read in every step, so swapping to a fresh zeroed
    /// allocation never changes results.
    fn ensure_arena(
        &mut self,
        want_sss: bool,
        want_loss: bool,
        want_gs: bool,
        want_kiss: Option<usize>,
    ) {
        let StepShape { n, d, .. } = self.shape;
        let threads = self.threads;
        let sss = want_sss || self.sss.is_some();
        let loss = want_loss || self.loss.is_some();
        let gs = want_gs || self.gs.is_some();
        let kiss_m = want_kiss.or(self.kiss.map(|k| k.m));
        let unchanged = self.arena.is_some()
            && sss == self.sss.is_some()
            && loss == self.loss.is_some()
            && gs == self.gs.is_some()
            && kiss_m == self.kiss.map(|k| k.m);
        if unchanged {
            return;
        }
        let mut cur = LayoutCursor::new();
        self.sss = if sss { Some(SssSlots::reserve(&mut cur, n, threads)) } else { None };
        self.loss = if loss { Some(LossSlots::reserve(&mut cur, n, d)) } else { None };
        self.gs = if gs { Some(GsSlots::reserve(&mut cur, n, d)) } else { None };
        self.kiss = kiss_m.map(|m| KissSlots::reserve(&mut cur, n, d, m));
        self.arena = Some(Arena::new(cur.words));
    }
}

impl StepSession for NativeSession {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn shape(&self) -> StepShape {
        self.shape
    }

    fn sss_step(
        &mut self,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
        out: &mut SssStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "sss_step needs d >= 1 (this session has d={d})");
        ensure!(w.len() == n, "w length {} != N={n}", w.len());
        ensure!(x_shuf.len() == n * d, "x length {} != N*d={}", x_shuf.len(), n * d);
        ensure!(inv_idx.len() == n, "inv_idx length {} != N={n}", inv_idx.len());
        for &i in inv_idx {
            ensure!((0..n as i32).contains(&i), "inv_idx entry {i} out of range 0..{n}");
        }

        self.ensure_pool();
        self.ensure_arena(true, true, false, None);
        let threads = self.threads;
        let level = self.level;
        // Size caller buffers on first use (no-ops afterwards).
        out.grad.resize(n, 0.0);
        out.sort_idx.resize(n, 0);
        out.colsum.resize(n, 0.0);
        out.y.resize(n * d, 0.0);

        let pool = self.pool.as_ref();
        let slots = self.sss.expect("reserved above");
        let lslots = self.loss.expect("reserved above");
        let base = self.arena.as_ref().expect("allocated above").base();
        // Safety: all slots come from one layout pass (disjoint ranges),
        // each is viewed exactly once here, and every view dies before
        // the arena can be rebuilt (the next step call at the earliest).
        let sigma = unsafe { view_u32(base, slots.sigma) };
        let sort_tmp = unsafe { view_u32(base, slots.sort_tmp) };
        let ws_sorted = unsafe { view_f32(base, slots.ws_sorted) };
        let chunk_cs = unsafe { view_f32(base, slots.chunk_cs) };
        let chunk_gw = unsafe { view_f32(base, slots.chunk_gw) };
        let gws = unsafe { view_f32(base, slots.gws) };
        let row_scratch = unsafe { view_f32(base, slots.row_scratch) };
        let g_scratch = unsafe { view_f32(base, slots.g_scratch) };
        let mut lws = unsafe {
            LossViews {
                dyg: view_f32(base, lslots.dyg),
                ct_y: view_f32(base, lslots.ct_y),
                ct_cs: view_f32(base, lslots.ct_cs),
                diff: view_f32(base, lslots.diff),
            }
        };

        // sort_desc(w): stable descending argsort (ties keep index order,
        // matching jnp.argsort(-w)); its VJP is the scatter through σ.
        for (i, s) in sigma.iter_mut().enumerate() {
            *s = i as u32;
        }
        stable_argsort_desc(sigma, sort_tmp, w);
        for (dst, &i) in ws_sorted.iter_mut().zip(sigma.iter()) {
            *dst = w[i as usize];
        }

        sss_forward(
            pool,
            threads,
            level,
            slots.stripe,
            n,
            d,
            &*ws_sorted,
            w,
            x_shuf,
            tau,
            chunk_cs,
            row_scratch,
            out,
        )?;
        out.loss = grid_loss_into(
            level,
            shape,
            x_shuf,
            &out.y,
            Some(inv_idx),
            Some(&out.colsum),
            norm,
            &mut lws,
        );
        sss_backward(
            pool,
            threads,
            level,
            slots.stripe,
            n,
            d,
            &*ws_sorted,
            w,
            &*sigma,
            x_shuf,
            tau,
            &*lws.ct_y,
            &*lws.ct_cs,
            chunk_gw,
            gws,
            row_scratch,
            g_scratch,
            &mut out.grad,
        )?;
        Ok(())
    }

    fn gs_step(
        &mut self,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
        out: &mut GsStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "gs_step needs d >= 1 (this session has d={d})");
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(gumbel.len() == n * n, "gumbel length {} != N²={}", gumbel.len(), n * n);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        self.ensure_arena(false, true, true, None);
        out.grad.resize(n * n, 0.0);
        let level = self.level;
        let gslots = self.gs.expect("reserved above");
        let lslots = self.loss.expect("reserved above");
        let base = self.arena.as_ref().expect("allocated above").base();
        // Safety: disjoint slots, one view each, dropped before rebuild.
        let la = unsafe { view_f32(base, gslots.la) };
        let states = unsafe { view_f32(base, gslots.states) };
        let dz = unsafe { view_f32(base, gslots.dz) };
        let y = unsafe { view_f32(base, gslots.y) };
        let mut lws = unsafe {
            LossViews {
                dyg: view_f32(base, lslots.dyg),
                ct_y: view_f32(base, lslots.ct_y),
                ct_cs: view_f32(base, lslots.ct_cs),
                diff: view_f32(base, lslots.diff),
            }
        };

        // Forward, recording every normalization output for reverse-mode.
        for (dst, (&l, &g)) in la.iter_mut().zip(logits.iter().zip(gumbel)) {
            *dst = (l + g) / tau;
        }
        sinkhorn_log_in_place(level, la, n, Some(&mut *states));
        let p = &*la; // now the dense doubly stochastic P

        for i in 0..n {
            let yi = &mut y[i * d..(i + 1) * d];
            yi.fill(0.0);
            for j in 0..n {
                let pij = p[i * n + j];
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += pij * xc;
                }
            }
        }

        // GS loss omits L_s (Sinkhorn already enforces stochasticity).
        out.loss = grid_loss_into(level, shape, x, y, None, None, norm, &mut lws);

        // dL/dP → through exp → reverse the 2·iters normalizations.
        for i in 0..n {
            let cti = &lws.ct_y[i * d..(i + 1) * d];
            for j in 0..n {
                let mut g = 0.0f32;
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                dz[i * n + j] = p[i * n + j] * g;
            }
        }
        for t in (0..2 * SINKHORN_ITERS).rev() {
            let z = &states[t * n * n..(t + 1) * n * n];
            // z = la − lse(la) ⇒ dla = dz − softmax(la)·Σdz, softmax = exp(z).
            if t % 2 == 1 {
                // Column normalization (second in each sweep).
                for j in 0..n {
                    let mut s = 0.0f32;
                    for i in 0..n {
                        s += dz[i * n + j];
                    }
                    for i in 0..n {
                        dz[i * n + j] -= z[i * n + j].exp() * s;
                    }
                }
            } else {
                for i in 0..n {
                    let row = &mut dz[i * n..(i + 1) * n];
                    let zr = &z[i * n..(i + 1) * n];
                    let s: f32 = row.iter().sum();
                    for (dv, &zv) in row.iter_mut().zip(zr) {
                        *dv -= zv.exp() * s;
                    }
                }
            }
        }
        for (g, &v) in out.grad.iter_mut().zip(dz.iter()) {
            *g = v / tau;
        }
        Ok(())
    }

    fn gs_probe(&mut self, logits: &[f32], tau: f32, out: &mut Vec<f32>) -> Result<()> {
        let n = self.shape.n;
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
        out.resize(n * n, 0.0);
        for (dst, &l) in out.iter_mut().zip(logits) {
            *dst = l / tau;
        }
        sinkhorn_log_in_place(self.level, out, n, None);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &mut self,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
        out: &mut KissStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "kiss_step needs d >= 1 (this session has d={d})");
        ensure!(m >= 1, "kissing rank must be >= 1");
        ensure!(v.len() == n * m, "v length {} != N*M={}", v.len(), n * m);
        ensure!(wf.len() == n * m, "w length {} != N*M={}", wf.len(), n * m);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        self.ensure_arena(false, true, false, Some(m));
        out.grad_v.resize(n * m, 0.0);
        out.grad_w.resize(n * m, 0.0);
        out.sort_idx.resize(n, 0);
        let level = self.level;
        let kslots = self.kiss.expect("reserved above");
        let lslots = self.loss.expect("reserved above");
        let base = self.arena.as_ref().expect("allocated above").base();
        // Safety: disjoint slots, one view each, dropped before rebuild.
        let norms_v = unsafe { view_f32(base, kslots.norms_v) };
        let norms_w = unsafe { view_f32(base, kslots.norms_w) };
        let vn = unsafe { view_f32(base, kslots.vn) };
        let wn = unsafe { view_f32(base, kslots.wn) };
        let dvn = unsafe { view_f32(base, kslots.dvn) };
        let dwn = unsafe { view_f32(base, kslots.dwn) };
        let y = unsafe { view_f32(base, kslots.y) };
        let colsum = unsafe { view_f32(base, kslots.colsum) };
        let row = unsafe { view_f32(base, kslots.row) };
        let gbuf = unsafe { view_f32(base, kslots.gbuf) };
        let mut lws = unsafe {
            LossViews {
                dyg: view_f32(base, lslots.dyg),
                ct_y: view_f32(base, lslots.ct_y),
                ct_cs: view_f32(base, lslots.ct_cs),
                diff: view_f32(base, lslots.diff),
            }
        };

        normalize_rows_into(v, n, m, norms_v, vn);
        normalize_rows_into(wf, n, m, norms_w, wn);
        let scale_t = KISS_SCALE / tau;

        // Forward: P = row-softmax(scale·v̂ŵᵀ/τ); rows recomputed in the
        // backward pass (memory stays O(N·(M+d))).
        colsum.fill(0.0);
        for i in 0..n {
            let arg = kiss_softmax_row(i, m, scale_t, &*vn, &*wn, row);
            out.sort_idx[i] = arg as i32;
            let yi = &mut y[i * d..(i + 1) * d];
            yi.fill(0.0);
            for (j, &p) in row.iter().enumerate() {
                colsum[j] += p;
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += p * xc;
                }
            }
        }

        out.loss =
            grid_loss_into(level, shape, x, y, None, Some(&*colsum), norm, &mut lws);

        // Backward: softmax rows → the two normalized factors → v, w.
        dvn.fill(0.0);
        dwn.fill(0.0);
        for i in 0..n {
            kiss_softmax_row(i, m, scale_t, &*vn, &*wn, row);
            let cti = &lws.ct_y[i * d..(i + 1) * d];
            let mut dot = 0.0f32;
            for (j, gj) in gbuf.iter_mut().enumerate() {
                let mut g = lws.ct_cs[j];
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                *gj = g;
                dot += g * row[j];
            }
            let vi = &vn[i * m..(i + 1) * m];
            for (j, &p) in row.iter().enumerate() {
                let a = scale_t * p * (gbuf[j] - dot);
                let wj = &wn[j * m..(j + 1) * m];
                let dvi = &mut dvn[i * m..(i + 1) * m];
                for (dv, &b) in dvi.iter_mut().zip(wj) {
                    *dv += a * b;
                }
                let dwj = &mut dwn[j * m..(j + 1) * m];
                for (dw, &b) in dwj.iter_mut().zip(vi) {
                    *dw += a * b;
                }
            }
        }
        normalize_rows_backward_into(v, norms_v, dvn, n, m, &mut out.grad_v);
        normalize_rows_backward_into(wf, norms_w, dwn, n, m, &mut out.grad_w);
        Ok(())
    }
}

impl StepBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn session(&self, shape: StepShape, opts: SessionOpts) -> Result<Box<dyn StepSession>> {
        Ok(self.session_send(shape, opts)?)
    }

    fn session_sendable(
        &self,
        shape: StepShape,
        opts: SessionOpts,
    ) -> Result<Option<Box<dyn StepSession + Send>>> {
        Ok(Some(self.session_send(shape, opts)?))
    }

    fn default_threads(&self) -> usize {
        self.threads
    }

    fn kiss_rank(&self, n: usize, _d: usize) -> Result<usize> {
        for &(max_n, m) in KISSING_TABLE {
            if n <= max_n {
                return Ok(m);
            }
        }
        bail!("no tabulated kissing rank covers N={n} (max 4320)")
    }
}

#[cfg(test)]
mod tests {
    use super::simd::SimdChoice;
    use super::*;
    use crate::grid::GridShape;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn native_backend_is_send_sync() {
        assert_send_sync::<NativeBackend>();
    }

    #[test]
    fn sendable_sessions_match_plain_sessions_and_report_the_pool_width() {
        // The tiled executor's contract: native sessions may cross threads
        // and compute exactly what a plain session computes, and the
        // backend reports its configured width for budgeting.
        let backend = NativeBackend::new(3);
        assert_eq!(backend.default_threads(), 3);
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let x = pattern(16 * 3, 1);
        let w = ramp_w(16);
        let inv: Vec<i32> = (0..16).collect();
        let mut sendable =
            backend.session_sendable(shape, SessionOpts::threads(1)).unwrap().expect("native");
        let plain = backend.sss_step(shape, &w, &x, &inv, 0.3, 0.5).unwrap();
        let mut out = SssStep::new_for(shape);
        std::thread::scope(|scope| {
            scope
                .spawn(|| sendable.sss_step(&w, &x, &inv, 0.3, 0.5, &mut out).unwrap())
                .join()
                .unwrap();
        });
        assert_eq!(out.loss.to_bits(), plain.loss.to_bits());
        assert_eq!(out.sort_idx, plain.sort_idx);
    }

    /// Deterministic pseudo-data in [0, 1) without pulling in the RNG.
    fn pattern(len: usize, salt: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
                (h % 10_000) as f32 / 10_000.0
            })
            .collect()
    }

    /// Well-separated weights (spacing ≈ 1) so finite differences never
    /// cross a sort-order kink.
    fn ramp_w(n: usize) -> Vec<f32> {
        (0..n).map(|i| (n - i) as f32 + 0.3 * (i as f32).sin()).collect()
    }

    fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (y as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-9)) as f32
    }

    /// Centered finite differences of `f` at `p`.
    fn fd_grad(p: &[f32], eps: f32, mut f: impl FnMut(&[f32]) -> f32) -> Vec<f32> {
        let mut g = vec![0.0f32; p.len()];
        let mut q = p.to_vec();
        for i in 0..p.len() {
            let orig = q[i];
            q[i] = orig + eps;
            let hi = f(&q);
            q[i] = orig - eps;
            let lo = f(&q);
            q[i] = orig;
            g[i] = (hi - lo) / (2.0 * eps);
        }
        g
    }

    /// One sss step through a session built with explicit opts.
    fn sss_with(
        opts: SessionOpts,
        shape: StepShape,
        w: &[f32],
        x: &[f32],
        inv: &[i32],
        tau: f32,
        norm: f32,
    ) -> SssStep {
        let be = NativeBackend::new(1);
        let mut session = be.session(shape, opts).unwrap();
        let mut out = SssStep::new_for(shape);
        session.sss_step(w, x, inv, tau, norm, &mut out).unwrap();
        out
    }

    #[test]
    fn sss_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(4, 4), 2);
        let be = NativeBackend::new(1);
        let w = ramp_w(16);
        let x = pattern(16 * 2, 7);
        // A non-identity shuffle inverse (5 is coprime to 16).
        let inv: Vec<i32> = (0..16).map(|k| (k * 5) % 16).collect();
        let (tau, norm) = (0.7f32, 0.5f32);

        let ana = be.sss_step(shape, &w, &x, &inv, tau, norm).unwrap().grad;
        let fd = fd_grad(&w, 1e-2, |wp| {
            be.sss_step(shape, wp, &x, &inv, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "sss grad rel-L2 error {err} (ana {ana:?} fd {fd:?})");
    }

    #[test]
    fn gs_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let gumbel = vec![0.0f32; 81];
        let x = pattern(9 * 2, 11);
        let (tau, norm) = (1.0f32, 0.5f32);

        let ana = be.gs_step(shape, &logits, &x, &gumbel, tau, norm).unwrap().grad;
        let fd = fd_grad(&logits, 1e-2, |lp| {
            be.gs_step(shape, lp, &x, &gumbel, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "gs grad rel-L2 error {err}");
    }

    #[test]
    fn kiss_gradients_match_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let m = be.kiss_rank(9, 2).unwrap();
        let v: Vec<f32> = pattern(9 * m, 5).iter().map(|a| a + 0.2).collect();
        let wf: Vec<f32> = pattern(9 * m, 9).iter().map(|a| a + 0.2).collect();
        let x = pattern(9 * 2, 13);
        // Soft temperature keeps the scale·τ⁻¹ softmax smooth enough for
        // f32 finite differences.
        let (tau, norm) = (6.0f32, 0.5f32);

        let out = be.kiss_step(shape, m, &v, &wf, &x, tau, norm).unwrap();
        let fd_v = fd_grad(&v, 5e-3, |vp| {
            be.kiss_step(shape, m, vp, &wf, &x, tau, norm).unwrap().loss
        });
        let fd_w = fd_grad(&wf, 5e-3, |wp| {
            be.kiss_step(shape, m, &v, wp, &x, tau, norm).unwrap().loss
        });
        let ev = rel_l2(&fd_v, &out.grad_v);
        let ew = rel_l2(&fd_w, &out.grad_w);
        assert!(ev < 0.08, "kiss grad_v rel-L2 error {ev}");
        assert!(ew < 0.08, "kiss grad_w rel-L2 error {ew}");
    }

    #[test]
    fn gradients_match_finite_differences_with_simd_off() {
        // The stateless-wrapper fd checks above run the session default
        // (`auto` — the SIMD path on any x86-64 host); this runs the same
        // checks on the forced scalar oracle so both paths stay covered.
        let off = SessionOpts { threads: Some(1), simd: SimdChoice::Off };
        let shape = StepShape::new(GridShape::new(4, 4), 2);
        let w = ramp_w(16);
        let x = pattern(16 * 2, 7);
        let inv: Vec<i32> = (0..16).map(|k| (k * 5) % 16).collect();
        let ana = sss_with(off, shape, &w, &x, &inv, 0.7, 0.5).grad;
        let fd =
            fd_grad(&w, 1e-2, |wp| sss_with(off, shape, wp, &x, &inv, 0.7, 0.5).loss);
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "sss scalar-path grad rel-L2 error {err}");

        let gshape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let gumbel = vec![0.0f32; 81];
        let gx = pattern(9 * 2, 11);
        let gs_run = |lp: &[f32]| {
            let mut s = be.session(gshape, off).unwrap();
            let mut out = GsStep::new_for(9);
            s.gs_step(lp, &gx, &gumbel, 1.0, 0.5, &mut out).unwrap();
            out
        };
        let ana = gs_run(&logits).grad;
        let fd = fd_grad(&logits, 1e-2, |lp| gs_run(lp).loss);
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "gs scalar-path grad rel-L2 error {err}");
    }

    #[test]
    fn scalar_and_simd_steps_agree_across_the_shape_sweep() {
        // The remainder-tail sweep from the issue: n straddling the
        // 4/8-lane widths, d ∈ {1, 3, 64} (covering the d = 3 fast path
        // and the wide generic path). sort_idx must agree exactly; loss,
        // y and grad to the documented vector-exp tolerance.
        if simd::detected() == SimdLevel::Scalar {
            return; // nothing to compare against on non-x86-64 hosts
        }
        let off = SessionOpts { threads: Some(1), simd: SimdChoice::Off };
        let on = SessionOpts { threads: Some(1), simd: SimdChoice::Auto };
        for &n in &[2usize, 3, 127, 128, 129] {
            for &d in &[1usize, 3, 64] {
                let shape = StepShape { n, d, h: 1, w: n };
                let w = ramp_w(n);
                let x = pattern(n * d, n as u32 + d as u32);
                let inv: Vec<i32> = (0..n).map(|k| ((k * 7 + 3) % n) as i32).collect();
                let a = sss_with(off, shape, &w, &x, &inv, 0.7, 0.5);
                let b = sss_with(on, shape, &w, &x, &inv, 0.7, 0.5);
                assert_eq!(a.sort_idx, b.sort_idx, "n={n} d={d}: sort_idx");
                let lr = (a.loss - b.loss).abs() / (1.0 + a.loss.abs());
                assert!(lr < 1e-4, "n={n} d={d}: loss {} vs {}", a.loss, b.loss);
                let eg = rel_l2(&b.grad, &a.grad);
                assert!(eg < 1e-3, "n={n} d={d}: grad rel-L2 {eg}");
                let ey = rel_l2(&b.y, &a.y);
                assert!(ey < 1e-3, "n={n} d={d}: y rel-L2 {ey}");
            }
        }
    }

    fn assert_sss_bits_eq(a: &SssStep, b: &SssStep, what: &str) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{what}: loss");
        assert_eq!(a.sort_idx, b.sort_idx, "{what}: sort_idx");
        for (ga, gb) in a.grad.iter().zip(&b.grad) {
            assert_eq!(ga.to_bits(), gb.to_bits(), "{what}: grad");
        }
        for (ya, yb) in a.y.iter().zip(&b.y) {
            assert_eq!(ya.to_bits(), yb.to_bits(), "{what}: y");
        }
        for (ca, cb) in a.colsum.iter().zip(&b.colsum) {
            assert_eq!(ca.to_bits(), cb.to_bits(), "{what}: colsum");
        }
    }

    #[test]
    fn sss_step_is_bit_identical_across_pool_sizes() {
        // N=600 exceeds PAR_MIN_N → multi-thread sessions really run the
        // pool path; fixed chunking must make 1, 2 and 8 threads (and the
        // stateless wrapper) bit-identical.
        let shape = StepShape::new(GridShape::new(20, 30), 3);
        let w = ramp_w(600);
        let x = pattern(600 * 3, 17);
        let inv: Vec<i32> = (0..600).map(|k| ((k * 7) % 600) as i32).collect();
        let base = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
        for threads in [2usize, 8] {
            let out =
                NativeBackend::new(threads).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
            assert_sss_bits_eq(&out, &base, &format!("{threads} threads"));
        }
        // Explicit per-session thread override through the session API.
        let be = NativeBackend::new(1);
        let mut session = be.session(shape, SessionOpts::threads(8)).unwrap();
        let mut out = SssStep::new_for(shape);
        session.sss_step(&w, &x, &inv, 0.4, 0.5, &mut out).unwrap();
        assert_sss_bits_eq(&out, &base, "session threads=8 override");
    }

    #[test]
    fn padded_stripes_keep_steps_bit_identical_for_any_pool_width() {
        // N=1024 > PAR_MIN_N: sessions really fan rows over the
        // cache-line-padded arena stripes; fixed chunking must keep every
        // pool width 1..=8 bit-identical.
        let shape = StepShape::new(GridShape::new(32, 32), 3);
        let w = ramp_w(1024);
        let x = pattern(1024 * 3, 41);
        let inv: Vec<i32> = (0..1024).map(|k| ((k * 11) % 1024) as i32).collect();
        let be = NativeBackend::new(1);
        let mut base: Option<SssStep> = None;
        for threads in 1..=8usize {
            let mut session = be.session(shape, SessionOpts::threads(threads)).unwrap();
            let mut out = SssStep::new_for(shape);
            session.sss_step(&w, &x, &inv, 0.4, 0.5, &mut out).unwrap();
            match &base {
                None => base = Some(out),
                Some(b) => assert_sss_bits_eq(&out, b, &format!("{threads} threads")),
            }
        }
    }

    #[test]
    fn arena_regrowth_across_step_families_keeps_results_bit_identical() {
        // sss first (the arena holds sss+loss slots), then a gs step
        // forces a layout rebuild (gs slots join), then sss again — the
        // rebuilt arena must reproduce the first result bit for bit.
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let mut session = be.session(shape, SessionOpts::default()).unwrap();
        let w = ramp_w(9);
        let x = pattern(9 * 2, 19);
        let inv: Vec<i32> = (0..9).map(|k| ((k * 2 + 1) % 9) as i32).collect();
        let mut first = SssStep::new_for(shape);
        session.sss_step(&w, &x, &inv, 0.7, 0.5, &mut first).unwrap();
        let logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let gumbel = vec![0.0f32; 81];
        let mut gout = GsStep::new_for(9);
        session.gs_step(&logits, &x, &gumbel, 1.0, 0.5, &mut gout).unwrap();
        assert!(gout.loss.is_finite());
        let mut again = SssStep::new_for(shape);
        session.sss_step(&w, &x, &inv, 0.7, 0.5, &mut again).unwrap();
        assert_sss_bits_eq(&again, &first, "after arena regrowth");
    }

    #[test]
    fn session_reuse_matches_fresh_sessions_on_an_sss_trajectory() {
        // Drive a small gradient-descent trajectory twice: stateless calls
        // (fresh session per step) vs one session reused — every step must
        // be bit-identical, including after buffer reuse kicks in.
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let be = NativeBackend::new(2);
        let x = pattern(16 * 3, 31);
        let inv: Vec<i32> = (0..16).map(|k| (k * 3) % 16).collect();
        let mut w_fresh = ramp_w(16);
        let mut w_sess = w_fresh.clone();
        let mut session = be.session(shape, SessionOpts::default()).unwrap();
        let mut out = SssStep::new_for(shape);
        for step in 0..5 {
            let fresh = be.sss_step(shape, &w_fresh, &x, &inv, 0.5, 0.5).unwrap();
            session.sss_step(&w_sess, &x, &inv, 0.5, 0.5, &mut out).unwrap();
            assert_sss_bits_eq(&out, &fresh, &format!("step {step}"));
            for (wv, &g) in w_fresh.iter_mut().zip(&fresh.grad) {
                *wv -= 0.1 * g;
            }
            for (wv, &g) in w_sess.iter_mut().zip(&out.grad) {
                *wv -= 0.1 * g;
            }
        }
    }

    #[test]
    fn session_reuse_matches_fresh_sessions_for_gs_and_kiss() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let x = pattern(9 * 2, 11);
        let gumbel = vec![0.0f32; 81];
        let mut logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let mut session = be.session(shape, SessionOpts::default()).unwrap();
        let mut gout = GsStep::new_for(9);
        for step in 0..3 {
            let fresh = be.gs_step(shape, &logits, &x, &gumbel, 1.0, 0.5).unwrap();
            session.gs_step(&logits, &x, &gumbel, 1.0, 0.5, &mut gout).unwrap();
            assert_eq!(gout.loss.to_bits(), fresh.loss.to_bits(), "gs step {step}");
            for (a, b) in gout.grad.iter().zip(&fresh.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "gs step {step}: grad");
            }
            for (l, &g) in logits.iter_mut().zip(&fresh.grad) {
                *l -= 0.05 * g;
            }
        }
        // Probe through the same session reuses its buffers too.
        let probe_fresh = be.gs_probe(9, &logits, 0.5).unwrap();
        let mut probe_sess = Vec::new();
        session.gs_probe(&logits, 0.5, &mut probe_sess).unwrap();
        for (a, b) in probe_sess.iter().zip(&probe_fresh) {
            assert_eq!(a.to_bits(), b.to_bits(), "probe");
        }

        let m = be.kiss_rank(9, 2).unwrap();
        let mut v: Vec<f32> = pattern(9 * m, 5).iter().map(|a| a + 0.2).collect();
        let wf: Vec<f32> = pattern(9 * m, 9).iter().map(|a| a + 0.2).collect();
        let mut kout = KissStep::new_for(9, m);
        for step in 0..3 {
            let fresh = be.kiss_step(shape, m, &v, &wf, &x, 6.0, 0.5).unwrap();
            session.kiss_step(m, &v, &wf, &x, 6.0, 0.5, &mut kout).unwrap();
            assert_eq!(kout.loss.to_bits(), fresh.loss.to_bits(), "kiss step {step}");
            assert_eq!(kout.sort_idx, fresh.sort_idx, "kiss step {step}");
            for (a, b) in kout.grad_v.iter().zip(&fresh.grad_v) {
                assert_eq!(a.to_bits(), b.to_bits(), "kiss step {step}: grad_v");
            }
            for (a, b) in kout.grad_w.iter().zip(&fresh.grad_w) {
                assert_eq!(a.to_bits(), b.to_bits(), "kiss step {step}: grad_w");
            }
            for (vv, &g) in v.iter_mut().zip(&fresh.grad_v) {
                *vv -= 0.05 * g;
            }
        }
    }

    #[test]
    fn stable_argsort_matches_std_stable_sort() {
        for salt in [1u32, 2, 3] {
            let mut w = pattern(137, salt);
            // Inject ties to exercise stability.
            w[10] = w[90];
            w[20] = w[40];
            let mut idx: Vec<u32> = (0..137).collect();
            let mut tmp = vec![0u32; 137];
            stable_argsort_desc(&mut idx, &mut tmp, &w);
            let mut expect: Vec<u32> = (0..137).collect();
            expect.sort_by(|&a, &b| {
                w[b as usize]
                    .partial_cmp(&w[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            assert_eq!(idx, expect, "salt {salt}");
        }
    }

    #[test]
    fn sharp_tau_on_ordered_weights_gives_identity_argmax() {
        // Mirrors the PJRT integration check: order-preserving init at a
        // sharp temperature ⇒ identity sort_idx and colsum ≈ 1.
        let n = 32;
        let shape = StepShape::new(GridShape::new(4, 8), 3);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let x = pattern(n * 3, 23);
        let inv: Vec<i32> = (0..n as i32).collect();
        let out = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.05, 0.5).unwrap();
        for (i, &v) in out.sort_idx.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
        for &c in &out.colsum {
            assert!((c - 1.0).abs() < 1e-3, "colsum {c}");
        }
        assert!(out.loss.is_finite());
    }

    #[test]
    fn gs_probe_is_approximately_doubly_stochastic() {
        let n = 8;
        let logits: Vec<f32> = pattern(64, 29).iter().map(|v| (v - 0.5) * 4.0).collect();
        let p = NativeBackend::new(1).gs_probe(n, &logits, 0.5).unwrap();
        for i in 0..n {
            let rs: f32 = p[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-3, "row {i} sum {rs}");
        }
        for j in 0..n {
            let cs: f32 = (0..n).map(|i| p[i * n + j]).sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {j} sum {cs}");
        }
    }

    #[test]
    fn kiss_rank_follows_the_kissing_number_table() {
        let be = NativeBackend::new(1);
        assert_eq!(be.kiss_rank(64, 3).unwrap(), 8);
        assert_eq!(be.kiss_rank(256, 3).unwrap(), 9);
        assert_eq!(be.kiss_rank(1024, 3).unwrap(), 13);
        assert_eq!(be.kiss_rank(4096, 3).unwrap(), 16);
        assert!(be.kiss_rank(100_000, 3).is_err());
    }

    #[test]
    fn shape_and_scalar_validation_errors_are_described() {
        let be = NativeBackend::new(1);
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let w = vec![0.0f32; 16];
        let x = vec![0.0f32; 16 * 3];
        let inv: Vec<i32> = (0..16).collect();
        assert!(be.sss_step(shape, &w[..8], &x, &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x[..10], &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.0, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.5, -1.0).is_err());
        let bad_inv = vec![99i32; 16];
        assert!(be.sss_step(shape, &w, &x, &bad_inv, 0.5, 0.5).is_err());
        // Bad shapes now fail at session creation.
        let bad_shape = StepShape { n: 16, d: 3, h: 4, w: 5 };
        assert!(be.session(bad_shape, SessionOpts::default()).is_err());
    }
}
