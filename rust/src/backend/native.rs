//! Pure-Rust compute backend: the learned methods' per-step functions with
//! hand-derived backward passes — no JAX, no XLA, no artifacts.
//!
//! Mirrors `python/compile/model.py` + `losses.py` operation by operation
//! (same f32 arithmetic, same constants), so a `NativeBackend` step agrees
//! with the AOT artifact to float tolerance — enforced by the parity tests
//! in `rust/tests/integration.rs` and by the finite-difference gradient
//! checks below (which run on every `cargo test`, artifacts or not).
//!
//! Memory follows the paper's "row-wise" requirement (§II): the N×N
//! SoftSort matrix is never materialized — forward computes each row,
//! consumes it and keeps only y/colsum/argmax; backward *recomputes* the
//! row (the chunked-oracle trick of `python/compile/kernels/ref.py`) and
//! reduces straight into the weight gradient. Working set is O(C·N) for a
//! fixed row chunk C.
//!
//! Parallelism: rows are independent, so both passes fan chunks of
//! [`ROW_CHUNK`] rows across `std::thread` scoped workers. Reductions
//! (colsum, dL/dw) are accumulated per chunk and folded **in chunk index
//! order**, so results are bit-identical for any thread count — the
//! property `Engine::sort_batch` relies on when batch workers share one
//! backend. Small problems (N < [`PAR_MIN_N`]) skip thread spawn entirely.
//!
//! The Gumbel-Sinkhorn and Kissing baselines are implemented sequentially
//! (they are comparison points, not the hot path); GS reverse-mode stores
//! the 2·`SINKHORN_ITERS` intermediate log-matrices, i.e. O(iters·N²)
//! transient memory — same asymptotics as its N² parameter vector.

use anyhow::{bail, ensure, Result};

use crate::util::stats::std_f32;

use super::{GsStep, KissStep, SssStep, StepBackend, StepShape};

/// Loss weights and epsilons — must match `python/compile/losses.py`.
const LAMBDA_S: f32 = 1.0;
const LAMBDA_SIGMA: f32 = 2.0;
const EPS: f32 = 1e-12;

/// Kissing softmax sharpness — must match `model.py::KISS_SCALE`.
const KISS_SCALE: f32 = 30.0;
/// Sinkhorn normalization sweeps — must match `model.py::SINKHORN_ITERS`.
const SINKHORN_ITERS: usize = 20;
/// Row-norm guard — must match the `1e-8` in `model.py::make_kiss_step`.
const KISS_NORM_EPS: f32 = 1e-8;

/// Rows per parallel work unit. Fixed (not derived from the thread count)
/// so the reduction tree — and therefore every f32 rounding — is identical
/// no matter how many workers run.
const ROW_CHUNK: usize = 128;
/// Below this N a step is cheaper than spawning threads; stay sequential.
const PAR_MIN_N: usize = 512;

/// The pure-Rust step backend. `Send + Sync`: one instance can serve any
/// number of threads concurrently (all state is per-call).
#[derive(Clone, Debug)]
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NativeBackend { threads }
    }
}

impl NativeBackend {
    /// Backend with an explicit row-parallel worker cap (1 = sequential).
    pub fn new(threads: usize) -> Self {
        NativeBackend { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn effective_threads(&self, n: usize) -> usize {
        if n < PAR_MIN_N {
            1
        } else {
            self.threads
        }
    }
}

// --------------------------------------------------------------------------
// Shared helpers.
// --------------------------------------------------------------------------

#[inline]
fn sgn(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Run `f(chunk_index)` for every chunk, on up to `threads` workers.
/// Results come back ordered by chunk index regardless of scheduling.
fn run_chunks<T, F>(threads: usize, n_chunks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.min(n_chunks);
    if workers <= 1 {
        return (0..n_chunks).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n_chunks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for wk in 0..workers {
            let f = &f;
            handles.push(scope.spawn(move || {
                (wk..n_chunks)
                    .step_by(workers)
                    .map(|c| (c, f(c)))
                    .collect::<Vec<(usize, T)>>()
            }));
        }
        for handle in handles {
            for (c, v) in handle.join().expect("native backend worker panicked") {
                out[c] = Some(v);
            }
        }
    });
    out.into_iter()
        .map(|v| v.expect("every chunk index is assigned to exactly one worker"))
        .collect()
}

/// Eq. (2) objective on a soft output `y`, plus the cotangents the backward
/// passes need: `ct_y = dL/dy` and `ct_cs = dL/dcolsum`.
///
/// `inv_idx`: when `Some`, the neighbor term is evaluated on the
/// reverse-shuffled output `y[inv_idx]` (the ShuffleSoftSort gather);
/// `None` means the identity arrangement (GS/Kissing).
/// `colsum`: when `Some`, the stochastic-constraint term λ_s·L_s is
/// included (GS omits it — Sinkhorn already enforces stochasticity).
struct GridLoss {
    loss: f32,
    ct_y: Vec<f32>,
    ct_cs: Vec<f32>,
}

fn grid_loss(
    shape: StepShape,
    x: &[f32],
    y: &[f32],
    inv_idx: Option<&[i32]>,
    colsum: Option<&[f32]>,
    norm: f32,
) -> GridLoss {
    let StepShape { n, d, h, w } = shape;
    let row_of = |k: usize| -> usize {
        match inv_idx {
            Some(iv) => iv[k] as usize,
            None => k,
        }
    };

    // L_nbr and its gradient w.r.t. the (gathered) grid output.
    let horiz = h * (w.saturating_sub(1));
    let vert = if h > 1 { (h - 1) * w } else { 0 };
    let count = (horiz + vert).max(1) as f32;
    let coef = 1.0 / (count * norm);
    let mut dyg = vec![0.0f32; n * d];
    let mut diff = vec![0.0f32; d];
    let mut total = 0.0f64;
    let mut pair = |k1: usize, k2: usize, dyg: &mut [f32]| {
        let (a, b) = (row_of(k1) * d, row_of(k2) * d);
        let mut s = 0.0f32;
        for (t, dt) in diff.iter_mut().enumerate() {
            let dd = y[a + t] - y[b + t];
            *dt = dd;
            s += dd * dd;
        }
        let dist = (s + EPS).sqrt();
        total += dist as f64;
        let g = coef / dist;
        for (t, &dt) in diff.iter().enumerate() {
            dyg[k1 * d + t] += dt * g;
            dyg[k2 * d + t] -= dt * g;
        }
    };
    for r in 0..h {
        for c in 0..w.saturating_sub(1) {
            let k = r * w + c;
            pair(k, k + 1, &mut dyg);
        }
    }
    if h > 1 {
        for r in 0..h - 1 {
            for c in 0..w {
                let k = r * w + c;
                pair(k, k + w, &mut dyg);
            }
        }
    }
    let l_nbr = total as f32 * coef;

    // Scatter d/dy_grid back through the gather (bijective → plain adds).
    let mut ct_y = if inv_idx.is_some() {
        let mut ct = vec![0.0f32; n * d];
        for k in 0..n {
            let r = row_of(k) * d;
            for t in 0..d {
                ct[r + t] += dyg[k * d + t];
            }
        }
        ct
    } else {
        dyg
    };

    // λ_s · L_s (eq. 3) on the column sums.
    let mut ct_cs = vec![0.0f32; n];
    let mut l_s = 0.0f32;
    if let Some(cs) = colsum {
        let mut acc = 0.0f64;
        for (j, &c) in cs.iter().enumerate() {
            let dev = c - 1.0;
            acc += (dev * dev) as f64;
            ct_cs[j] = LAMBDA_S * 2.0 * dev / n as f32;
        }
        l_s = (acc / n as f64) as f32;
    }

    // λ_σ · L_σ (eq. 4): |σ_X − σ_Y| / σ_X over all N·d entries.
    let sx = std_f32(x);
    let sy = std_f32(y);
    let l_sigma = (sx - sy).abs() / (sx + EPS);
    if sy > 0.0 && sx != sy {
        let m = (n * d) as f64;
        let mu_y = (y.iter().map(|&v| v as f64).sum::<f64>() / m) as f32;
        let a = LAMBDA_SIGMA * sgn(sy - sx) / (sx + EPS) / (m as f32 * sy);
        for (ct, &v) in ct_y.iter_mut().zip(y) {
            *ct += a * (v - mu_y);
        }
    }

    GridLoss { loss: l_nbr + LAMBDA_S * l_s + LAMBDA_SIGMA * l_sigma, ct_y, ct_cs }
}

// --------------------------------------------------------------------------
// SoftSort / ShuffleSoftSort step.
// --------------------------------------------------------------------------

struct SssForwardChunk {
    y: Vec<f32>,
    idx: Vec<i32>,
    cs: Vec<f32>,
}

/// Row-block forward: y = P·x, sort_idx = argmax rows, colsum = Σ rows.
/// P rows are computed, consumed and dropped (row-wise memory).
fn softsort_forward(
    threads: usize,
    n: usize,
    d: usize,
    ws: &[f32],
    w: &[f32],
    x: &[f32],
    tau: f32,
) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let chunks = run_chunks(threads, n_chunks, |c| {
        let r0 = c * ROW_CHUNK;
        let r1 = (r0 + ROW_CHUNK).min(n);
        let rows = r1 - r0;
        let mut ch = SssForwardChunk {
            y: vec![0.0f32; rows * d],
            idx: vec![0i32; rows],
            cs: vec![0.0f32; n],
        };
        let mut row = vec![0.0f32; n];
        for i in r0..r1 {
            let wsi = ws[i];
            let mut mx = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, rj) in row.iter_mut().enumerate() {
                let l = -(wsi - w[j]).abs() / tau;
                *rj = l;
                if l > mx {
                    mx = l;
                    arg = j;
                }
            }
            let mut denom = 0.0f32;
            for rj in row.iter_mut() {
                *rj = (*rj - mx).exp();
                denom += *rj;
            }
            let inv = 1.0 / denom;
            let li = i - r0;
            ch.idx[li] = arg as i32;
            let yi = &mut ch.y[li * d..(li + 1) * d];
            for (j, rj) in row.iter_mut().enumerate() {
                let p = *rj * inv;
                *rj = p;
                ch.cs[j] += p;
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += p * xc;
                }
            }
        }
        ch
    });

    let mut y = vec![0.0f32; n * d];
    let mut idx = vec![0i32; n];
    let mut colsum = vec![0.0f32; n];
    for (c, ch) in chunks.into_iter().enumerate() {
        let r0 = c * ROW_CHUNK;
        y[r0 * d..r0 * d + ch.y.len()].copy_from_slice(&ch.y);
        idx[r0..r0 + ch.idx.len()].copy_from_slice(&ch.idx);
        for (dst, src) in colsum.iter_mut().zip(&ch.cs) {
            *dst += src;
        }
    }
    (y, idx, colsum)
}

struct SssBackwardChunk {
    /// dL/dws for this chunk's rows (sorted-side weight gradient).
    gws: Vec<f32>,
    /// dL/dw partial from the column side (full length N).
    gw: Vec<f32>,
}

/// Row-block backward: recompute each P row, pull the loss cotangents
/// through softmax and the |ws_i − w_j| kernel, reduce into dL/dw.
#[allow(clippy::too_many_arguments)]
fn softsort_backward(
    threads: usize,
    n: usize,
    d: usize,
    ws: &[f32],
    w: &[f32],
    sigma: &[u32],
    x: &[f32],
    tau: f32,
    ct_y: &[f32],
    ct_cs: &[f32],
) -> Vec<f32> {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let chunks = run_chunks(threads, n_chunks, |c| {
        let r0 = c * ROW_CHUNK;
        let r1 = (r0 + ROW_CHUNK).min(n);
        let mut ch = SssBackwardChunk { gws: vec![0.0f32; r1 - r0], gw: vec![0.0f32; n] };
        let mut prob = vec![0.0f32; n];
        let mut gbuf = vec![0.0f32; n];
        for i in r0..r1 {
            let wsi = ws[i];
            // Recompute the probability row (identical code path to the
            // forward, so the same f32 roundings are reproduced).
            let mut mx = f32::NEG_INFINITY;
            for (j, pj) in prob.iter_mut().enumerate() {
                let l = -(wsi - w[j]).abs() / tau;
                *pj = l;
                if l > mx {
                    mx = l;
                }
            }
            let mut denom = 0.0f32;
            for pj in prob.iter_mut() {
                *pj = (*pj - mx).exp();
                denom += *pj;
            }
            let inv = 1.0 / denom;
            for pj in prob.iter_mut() {
                *pj *= inv;
            }

            // dL/dP_ij = ct_y[i]·x_j + ct_cs[j]; softmax row backward.
            let cti = &ct_y[i * d..(i + 1) * d];
            let mut dot = 0.0f32;
            for (j, gj) in gbuf.iter_mut().enumerate() {
                let mut g = ct_cs[j];
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                *gj = g;
                dot += g * prob[j];
            }
            let mut gws_i = 0.0f32;
            for j in 0..n {
                let dl = prob[j] * (gbuf[j] - dot);
                let s = sgn(wsi - w[j]);
                gws_i -= dl * s / tau;
                ch.gw[j] += dl * s / tau;
            }
            ch.gws[i - r0] = gws_i;
        }
        ch
    });

    // Deterministic reduction: chunk-ordered column partials, then the
    // sorted-side scatter through σ (sort_desc's VJP).
    let mut grad = vec![0.0f32; n];
    for ch in &chunks {
        for (g, p) in grad.iter_mut().zip(&ch.gw) {
            *g += p;
        }
    }
    for (c, ch) in chunks.iter().enumerate() {
        let r0 = c * ROW_CHUNK;
        for (li, &gv) in ch.gws.iter().enumerate() {
            grad[sigma[r0 + li] as usize] += gv;
        }
    }
    grad
}

// --------------------------------------------------------------------------
// Gumbel-Sinkhorn helpers.
// --------------------------------------------------------------------------

fn row_lse_normalize(la: &mut [f32], n: usize) {
    for i in 0..n {
        let row = &mut la[i * n..(i + 1) * n];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0.0f32;
        for &v in row.iter() {
            s += (v - mx).exp();
        }
        let lse = mx + s.ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

fn col_lse_normalize(la: &mut [f32], n: usize) {
    for j in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for i in 0..n {
            mx = mx.max(la[i * n + j]);
        }
        let mut s = 0.0f32;
        for i in 0..n {
            s += (la[i * n + j] - mx).exp();
        }
        let lse = mx + s.ln();
        for i in 0..n {
            la[i * n + j] -= lse;
        }
    }
}

/// Log-space Sinkhorn forward. When `states` is `Some`, the output of every
/// normalization is recorded (reverse-mode needs exactly those values).
fn sinkhorn_log(mut la: Vec<f32>, n: usize, mut states: Option<&mut Vec<Vec<f32>>>) -> Vec<f32> {
    for _ in 0..SINKHORN_ITERS {
        row_lse_normalize(&mut la, n);
        if let Some(s) = states.as_mut() {
            s.push(la.clone());
        }
        col_lse_normalize(&mut la, n);
        if let Some(s) = states.as_mut() {
            s.push(la.clone());
        }
    }
    la.iter_mut().for_each(|v| *v = v.exp());
    la
}

// --------------------------------------------------------------------------
// Kissing helpers.
// --------------------------------------------------------------------------

/// Classic kissing numbers K(M) — mirrors `python/compile/shapes.py`
/// (`kissing_number(M) ≥ N` picks the rank; Table 2 pins M(1024) = 13).
const KISSING_TABLE: &[(usize, usize)] =
    &[(240, 8), (306, 9), (500, 10), (582, 11), (840, 12), (1154, 13), (4320, 16)];

/// Row L2 norms, and the row-normalized matrix v̂ = v / (‖v_row‖ + ε).
fn normalize_rows(v: &[f32], n: usize, m: usize) -> (Vec<f32>, Vec<f32>) {
    let mut norms = vec![0.0f32; n];
    let mut vn = vec![0.0f32; n * m];
    for i in 0..n {
        let row = &v[i * m..(i + 1) * m];
        let mut s = 0.0f32;
        for &a in row {
            s += a * a;
        }
        let r = s.sqrt();
        norms[i] = r;
        let inv = 1.0 / (r + KISS_NORM_EPS);
        for (dst, &a) in vn[i * m..(i + 1) * m].iter_mut().zip(row) {
            *dst = a * inv;
        }
    }
    (norms, vn)
}

/// VJP of row normalization: given dL/dv̂, return dL/dv.
fn normalize_rows_backward(
    v: &[f32],
    norms: &[f32],
    dvn: &[f32],
    n: usize,
    m: usize,
) -> Vec<f32> {
    let mut dv = vec![0.0f32; n * m];
    for i in 0..n {
        let r = norms[i];
        let denom = r + KISS_NORM_EPS;
        let vi = &v[i * m..(i + 1) * m];
        let di = &dvn[i * m..(i + 1) * m];
        let mut dot = 0.0f32;
        for (&a, &b) in vi.iter().zip(di) {
            dot += a * b;
        }
        let out = &mut dv[i * m..(i + 1) * m];
        if r > 0.0 {
            let k = dot / (r * denom * denom);
            for ((o, &b), &a) in out.iter_mut().zip(di).zip(vi) {
                *o = b / denom - a * k;
            }
        } else {
            for (o, &b) in out.iter_mut().zip(di) {
                *o = b / denom;
            }
        }
    }
    dv
}

// --------------------------------------------------------------------------
// Trait implementation.
// --------------------------------------------------------------------------

fn check_shape(shape: StepShape) -> Result<()> {
    ensure!(shape.n >= 2, "native backend needs N >= 2 (got {})", shape.n);
    ensure!(
        shape.h * shape.w == shape.n,
        "grid {}x{} != N={}",
        shape.h,
        shape.w,
        shape.n
    );
    Ok(())
}

fn check_scalars(tau: f32, norm: f32) -> Result<()> {
    ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
    ensure!(norm.is_finite() && norm > 0.0, "norm must be positive, got {norm}");
    Ok(())
}

impl StepBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn sss_step(
        &self,
        shape: StepShape,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
    ) -> Result<SssStep> {
        let StepShape { n, d, .. } = shape;
        check_shape(shape)?;
        check_scalars(tau, norm)?;
        ensure!(w.len() == n, "w length {} != N={n}", w.len());
        ensure!(x_shuf.len() == n * d, "x length {} != N*d={}", x_shuf.len(), n * d);
        ensure!(inv_idx.len() == n, "inv_idx length {} != N={n}", inv_idx.len());
        for &i in inv_idx {
            ensure!((0..n as i32).contains(&i), "inv_idx entry {i} out of range 0..{n}");
        }

        // sort_desc(w): stable descending argsort (ties keep index order,
        // matching jnp.argsort(-w)); its VJP is the scatter through σ.
        let mut sigma: Vec<u32> = (0..n as u32).collect();
        sigma.sort_by(|&a, &b| {
            w[b as usize]
                .partial_cmp(&w[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let ws: Vec<f32> = sigma.iter().map(|&i| w[i as usize]).collect();

        let threads = self.effective_threads(n);
        let (y, sort_idx, colsum) = softsort_forward(threads, n, d, &ws, w, x_shuf, tau);
        let gl = grid_loss(shape, x_shuf, &y, Some(inv_idx), Some(&colsum), norm);
        let grad = softsort_backward(
            threads, n, d, &ws, w, &sigma, x_shuf, tau, &gl.ct_y, &gl.ct_cs,
        );
        Ok(SssStep { loss: gl.loss, grad, sort_idx, colsum, y })
    }

    fn gs_step(
        &self,
        shape: StepShape,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<GsStep> {
        let StepShape { n, d, .. } = shape;
        check_shape(shape)?;
        check_scalars(tau, norm)?;
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(gumbel.len() == n * n, "gumbel length {} != N²={}", gumbel.len(), n * n);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        // Forward, recording every normalization output for reverse-mode.
        let la0: Vec<f32> =
            logits.iter().zip(gumbel).map(|(&l, &g)| (l + g) / tau).collect();
        let mut states: Vec<Vec<f32>> = Vec::with_capacity(2 * SINKHORN_ITERS);
        let p = sinkhorn_log(la0, n, Some(&mut states));

        let mut y = vec![0.0f32; n * d];
        for i in 0..n {
            let yi = &mut y[i * d..(i + 1) * d];
            for j in 0..n {
                let pij = p[i * n + j];
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += pij * xc;
                }
            }
        }

        // GS loss omits L_s (Sinkhorn already enforces stochasticity).
        let gl = grid_loss(shape, x, &y, None, None, norm);

        // dL/dP → through exp → reverse the 2·iters normalizations.
        let mut dz = vec![0.0f32; n * n];
        for i in 0..n {
            let cti = &gl.ct_y[i * d..(i + 1) * d];
            for j in 0..n {
                let mut g = 0.0f32;
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                dz[i * n + j] = p[i * n + j] * g;
            }
        }
        for (t, z) in states.iter().enumerate().rev() {
            // z = la − lse(la) ⇒ dla = dz − softmax(la)·Σdz, softmax = exp(z).
            if t % 2 == 1 {
                // Column normalization (second in each sweep).
                for j in 0..n {
                    let mut s = 0.0f32;
                    for i in 0..n {
                        s += dz[i * n + j];
                    }
                    for i in 0..n {
                        dz[i * n + j] -= z[i * n + j].exp() * s;
                    }
                }
            } else {
                for i in 0..n {
                    let row = &mut dz[i * n..(i + 1) * n];
                    let zr = &z[i * n..(i + 1) * n];
                    let s: f32 = row.iter().sum();
                    for (dv, &zv) in row.iter_mut().zip(zr) {
                        *dv -= zv.exp() * s;
                    }
                }
            }
        }
        let grad: Vec<f32> = dz.iter().map(|&v| v / tau).collect();
        Ok(GsStep { loss: gl.loss, grad })
    }

    fn gs_probe(&self, n: usize, logits: &[f32], tau: f32) -> Result<Vec<f32>> {
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
        let la: Vec<f32> = logits.iter().map(|&l| l / tau).collect();
        Ok(sinkhorn_log(la, n, None))
    }

    fn kiss_rank(&self, n: usize, _d: usize) -> Result<usize> {
        for &(max_n, m) in KISSING_TABLE {
            if n <= max_n {
                return Ok(m);
            }
        }
        bail!("no tabulated kissing rank covers N={n} (max 4320)")
    }

    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &self,
        shape: StepShape,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
    ) -> Result<KissStep> {
        let StepShape { n, d, .. } = shape;
        check_shape(shape)?;
        check_scalars(tau, norm)?;
        ensure!(m >= 1, "kissing rank must be >= 1");
        ensure!(v.len() == n * m, "v length {} != N*M={}", v.len(), n * m);
        ensure!(wf.len() == n * m, "w length {} != N*M={}", wf.len(), n * m);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        let (rv, vn) = normalize_rows(v, n, m);
        let (rw, wn) = normalize_rows(wf, n, m);
        let scale_t = KISS_SCALE / tau;

        // Forward: P = row-softmax(scale·v̂ŵᵀ/τ); rows recomputed in the
        // backward pass (memory stays O(N·(M+d))).
        let mut y = vec![0.0f32; n * d];
        let mut colsum = vec![0.0f32; n];
        let mut sort_idx = vec![0i32; n];
        let mut row = vec![0.0f32; n];
        let softmax_row = |i: usize, row: &mut [f32]| {
            let vi = &vn[i * m..(i + 1) * m];
            let mut mx = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, rj) in row.iter_mut().enumerate() {
                let wj = &wn[j * m..(j + 1) * m];
                let mut dot = 0.0f32;
                for (&a, &b) in vi.iter().zip(wj) {
                    dot += a * b;
                }
                let l = scale_t * dot;
                *rj = l;
                if l > mx {
                    mx = l;
                    arg = j;
                }
            }
            let mut denom = 0.0f32;
            for rj in row.iter_mut() {
                *rj = (*rj - mx).exp();
                denom += *rj;
            }
            let inv = 1.0 / denom;
            for rj in row.iter_mut() {
                *rj *= inv;
            }
            arg
        };
        for i in 0..n {
            let arg = softmax_row(i, &mut row);
            sort_idx[i] = arg as i32;
            let yi = &mut y[i * d..(i + 1) * d];
            for (j, &p) in row.iter().enumerate() {
                colsum[j] += p;
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += p * xc;
                }
            }
        }

        let gl = grid_loss(shape, x, &y, None, Some(&colsum), norm);

        // Backward: softmax rows → the two normalized factors → v, w.
        let mut dvn = vec![0.0f32; n * m];
        let mut dwn = vec![0.0f32; n * m];
        let mut gbuf = vec![0.0f32; n];
        for i in 0..n {
            softmax_row(i, &mut row);
            let cti = &gl.ct_y[i * d..(i + 1) * d];
            let mut dot = 0.0f32;
            for (j, gj) in gbuf.iter_mut().enumerate() {
                let mut g = gl.ct_cs[j];
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                *gj = g;
                dot += g * row[j];
            }
            let vi = &vn[i * m..(i + 1) * m];
            for (j, &p) in row.iter().enumerate() {
                let a = scale_t * p * (gbuf[j] - dot);
                let wj = &wn[j * m..(j + 1) * m];
                let dvi = &mut dvn[i * m..(i + 1) * m];
                for (dv, &b) in dvi.iter_mut().zip(wj) {
                    *dv += a * b;
                }
                let dwj = &mut dwn[j * m..(j + 1) * m];
                for (dw, &b) in dwj.iter_mut().zip(vi) {
                    *dw += a * b;
                }
            }
        }
        let grad_v = normalize_rows_backward(v, &rv, &dvn, n, m);
        let grad_w = normalize_rows_backward(wf, &rw, &dwn, n, m);
        Ok(KissStep { loss: gl.loss, grad_v, grad_w, sort_idx })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridShape;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn native_backend_is_send_sync() {
        assert_send_sync::<NativeBackend>();
    }

    /// Deterministic pseudo-data in [0, 1) without pulling in the RNG.
    fn pattern(len: usize, salt: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
                (h % 10_000) as f32 / 10_000.0
            })
            .collect()
    }

    /// Well-separated weights (spacing ≈ 1) so finite differences never
    /// cross a sort-order kink.
    fn ramp_w(n: usize) -> Vec<f32> {
        (0..n).map(|i| (n - i) as f32 + 0.3 * (i as f32).sin()).collect()
    }

    fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (y as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-9)) as f32
    }

    /// Centered finite differences of `f` at `p`.
    fn fd_grad(p: &[f32], eps: f32, mut f: impl FnMut(&[f32]) -> f32) -> Vec<f32> {
        let mut g = vec![0.0f32; p.len()];
        let mut q = p.to_vec();
        for i in 0..p.len() {
            let orig = q[i];
            q[i] = orig + eps;
            let hi = f(&q);
            q[i] = orig - eps;
            let lo = f(&q);
            q[i] = orig;
            g[i] = (hi - lo) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn sss_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(4, 4), 2);
        let be = NativeBackend::new(1);
        let w = ramp_w(16);
        let x = pattern(16 * 2, 7);
        // A non-identity shuffle inverse (5 is coprime to 16).
        let inv: Vec<i32> = (0..16).map(|k| (k * 5) % 16).collect();
        let (tau, norm) = (0.7f32, 0.5f32);

        let ana = be.sss_step(shape, &w, &x, &inv, tau, norm).unwrap().grad;
        let fd = fd_grad(&w, 1e-2, |wp| {
            be.sss_step(shape, wp, &x, &inv, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "sss grad rel-L2 error {err} (ana {ana:?} fd {fd:?})");
    }

    #[test]
    fn gs_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let gumbel = vec![0.0f32; 81];
        let x = pattern(9 * 2, 11);
        let (tau, norm) = (1.0f32, 0.5f32);

        let ana = be.gs_step(shape, &logits, &x, &gumbel, tau, norm).unwrap().grad;
        let fd = fd_grad(&logits, 1e-2, |lp| {
            be.gs_step(shape, lp, &x, &gumbel, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "gs grad rel-L2 error {err}");
    }

    #[test]
    fn kiss_gradients_match_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let m = be.kiss_rank(9, 2).unwrap();
        let v: Vec<f32> = pattern(9 * m, 5).iter().map(|a| a + 0.2).collect();
        let wf: Vec<f32> = pattern(9 * m, 9).iter().map(|a| a + 0.2).collect();
        let x = pattern(9 * 2, 13);
        // Soft temperature keeps the scale·τ⁻¹ softmax smooth enough for
        // f32 finite differences.
        let (tau, norm) = (6.0f32, 0.5f32);

        let out = be.kiss_step(shape, m, &v, &wf, &x, tau, norm).unwrap();
        let fd_v = fd_grad(&v, 5e-3, |vp| {
            be.kiss_step(shape, m, vp, &wf, &x, tau, norm).unwrap().loss
        });
        let fd_w = fd_grad(&wf, 5e-3, |wp| {
            be.kiss_step(shape, m, &v, wp, &x, tau, norm).unwrap().loss
        });
        let ev = rel_l2(&fd_v, &out.grad_v);
        let ew = rel_l2(&fd_w, &out.grad_w);
        assert!(ev < 0.08, "kiss grad_v rel-L2 error {ev}");
        assert!(ew < 0.08, "kiss grad_w rel-L2 error {ew}");
    }

    #[test]
    fn sss_step_is_bit_identical_across_thread_counts() {
        // N=600 exceeds PAR_MIN_N → the 4-thread backend really runs the
        // parallel path; fixed chunking must make it bit-identical.
        let shape = StepShape::new(GridShape::new(20, 30), 3);
        let w = ramp_w(600);
        let x = pattern(600 * 3, 17);
        let inv: Vec<i32> = (0..600).map(|k| ((k * 7) % 600) as i32).collect();
        let a = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
        let b = NativeBackend::new(4).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.sort_idx, b.sort_idx);
        for (ga, gb) in a.grad.iter().zip(&b.grad) {
            assert_eq!(ga.to_bits(), gb.to_bits());
        }
        for (ya, yb) in a.y.iter().zip(&b.y) {
            assert_eq!(ya.to_bits(), yb.to_bits());
        }
        for (ca, cb) in a.colsum.iter().zip(&b.colsum) {
            assert_eq!(ca.to_bits(), cb.to_bits());
        }
    }

    #[test]
    fn sharp_tau_on_ordered_weights_gives_identity_argmax() {
        // Mirrors the PJRT integration check: order-preserving init at a
        // sharp temperature ⇒ identity sort_idx and colsum ≈ 1.
        let n = 32;
        let shape = StepShape::new(GridShape::new(4, 8), 3);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let x = pattern(n * 3, 23);
        let inv: Vec<i32> = (0..n as i32).collect();
        let out = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.05, 0.5).unwrap();
        for (i, &v) in out.sort_idx.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
        for &c in &out.colsum {
            assert!((c - 1.0).abs() < 1e-3, "colsum {c}");
        }
        assert!(out.loss.is_finite());
    }

    #[test]
    fn gs_probe_is_approximately_doubly_stochastic() {
        let n = 8;
        let logits: Vec<f32> = pattern(64, 29).iter().map(|v| (v - 0.5) * 4.0).collect();
        let p = NativeBackend::new(1).gs_probe(n, &logits, 0.5).unwrap();
        for i in 0..n {
            let rs: f32 = p[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-3, "row {i} sum {rs}");
        }
        for j in 0..n {
            let cs: f32 = (0..n).map(|i| p[i * n + j]).sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {j} sum {cs}");
        }
    }

    #[test]
    fn kiss_rank_follows_the_kissing_number_table() {
        let be = NativeBackend::new(1);
        assert_eq!(be.kiss_rank(64, 3).unwrap(), 8);
        assert_eq!(be.kiss_rank(256, 3).unwrap(), 9);
        assert_eq!(be.kiss_rank(1024, 3).unwrap(), 13);
        assert_eq!(be.kiss_rank(4096, 3).unwrap(), 16);
        assert!(be.kiss_rank(100_000, 3).is_err());
    }

    #[test]
    fn shape_and_scalar_validation_errors_are_described() {
        let be = NativeBackend::new(1);
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let w = vec![0.0f32; 16];
        let x = vec![0.0f32; 16 * 3];
        let inv: Vec<i32> = (0..16).collect();
        assert!(be.sss_step(shape, &w[..8], &x, &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x[..10], &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.0, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.5, -1.0).is_err());
        let bad_inv = vec![99i32; 16];
        assert!(be.sss_step(shape, &w, &x, &bad_inv, 0.5, 0.5).is_err());
    }
}
