//! Pure-Rust compute backend: the learned methods' per-step functions with
//! hand-derived backward passes — no JAX, no XLA, no artifacts.
//!
//! Mirrors `python/compile/model.py` + `losses.py` operation by operation
//! (same f32 arithmetic, same constants), so a `NativeBackend` step agrees
//! with the AOT artifact to float tolerance — enforced by the parity tests
//! in `rust/tests/integration.rs` and by the finite-difference gradient
//! checks below (which run on every `cargo test`, artifacts or not).
//!
//! Memory follows the paper's "row-wise" requirement (§II): the N×N
//! SoftSort matrix is never materialized — forward computes each row,
//! consumes it and keeps only y/colsum/argmax; backward *recomputes* the
//! row (the chunked-oracle trick of `python/compile/kernels/ref.py`) and
//! reduces straight into the weight gradient. Working set is O(C·N) for a
//! fixed row chunk C.
//!
//! Hot path: all per-shape state lives in a [`NativeSession`] — scratch
//! rows, per-chunk reduction slabs, the Sinkhorn state stack, and a
//! persistent [`pool::WorkerPool`] of parked threads. Driving a run
//! through one session performs **zero steady-state heap allocations**
//! (buffers are allocated when a step family is first used) and no
//! per-step thread spawn; the old stateless entry points remain as
//! throwaway-session wrappers. Row kernels are restructured into separate
//! stride-1 passes (logits, max-scan, exp, accumulate — with an unrolled
//! d = 3 fast path) so the compiler can vectorize the inner loops, while
//! keeping the f32 operation order — and therefore every rounding —
//! exactly as before.
//!
//! Parallelism: rows are independent, so both SoftSort passes fan chunks
//! of [`ROW_CHUNK`] rows across the session pool. Reductions (colsum,
//! dL/dw) are accumulated per chunk into preallocated slabs and folded
//! **in chunk index order**, so results are bit-identical for any pool
//! size — the property `Engine::sort_batch` relies on when batch workers
//! share one backend. Small problems (N < [`PAR_MIN_N`]) stay sequential
//! and never spawn pool threads.
//!
//! The Gumbel-Sinkhorn and Kissing baselines are implemented sequentially
//! (they are comparison points, not the hot path); GS reverse-mode keeps
//! the 2·`SINKHORN_ITERS` intermediate N² log-matrices in one session slab
//! that is reused every step — O(iters·N²) once per session instead of
//! re-allocated per step.

use anyhow::{bail, ensure, Result};

use crate::util::stats::std_f32;

use super::pool::{PoolError, WorkerPool};
use super::{GsStep, KissStep, SssStep, StepBackend, StepSession, StepShape};

/// Loss weights and epsilons — must match `python/compile/losses.py`.
const LAMBDA_S: f32 = 1.0;
const LAMBDA_SIGMA: f32 = 2.0;
const EPS: f32 = 1e-12;

/// Kissing softmax sharpness — must match `model.py::KISS_SCALE`.
const KISS_SCALE: f32 = 30.0;
/// Sinkhorn normalization sweeps — must match `model.py::SINKHORN_ITERS`.
const SINKHORN_ITERS: usize = 20;
/// Row-norm guard — must match the `1e-8` in `model.py::make_kiss_step`.
const KISS_NORM_EPS: f32 = 1e-8;

/// Rows per parallel work unit. Fixed (not derived from the thread count)
/// so the reduction tree — and therefore every f32 rounding — is identical
/// no matter how many workers run.
const ROW_CHUNK: usize = 128;
/// Below this N a step is cheaper than coordinating threads; sessions for
/// smaller shapes stay sequential and never spawn a pool.
pub const PAR_MIN_N: usize = 512;

/// The pure-Rust step backend. `Send + Sync`: one instance can serve any
/// number of threads concurrently (all mutable state lives in the
/// per-caller [`NativeSession`]s it opens).
#[derive(Clone, Debug)]
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NativeBackend { threads }
    }
}

impl NativeBackend {
    /// Backend with an explicit default session pool size (1 = sequential).
    /// Individual sessions can override it (`StepBackend::session`).
    pub fn new(threads: usize) -> Self {
        NativeBackend { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Like [`StepBackend::session`], preserving the concrete `Send` bound
    /// the trait-object return type erases (native sessions are plain
    /// owned data + a pool, so they may move across threads).
    pub fn session_send(
        &self,
        shape: StepShape,
        threads: Option<usize>,
    ) -> Result<Box<dyn StepSession + Send>> {
        let requested = threads.unwrap_or(self.threads).max(1);
        // Below PAR_MIN_N a step is cheaper than coordinating workers:
        // stay sequential (and never spawn pool threads). Never keep more
        // workers than there are row chunks to hand out — extra threads
        // would only wake to acknowledge epochs they can't work on.
        let effective = if shape.n < PAR_MIN_N {
            1
        } else {
            requested.min(shape.n.div_ceil(ROW_CHUNK))
        };
        let mut span = crate::trace::Span::child("session_build");
        span.attr_u64("n", shape.n as u64);
        span.attr_u64("d", shape.d as u64);
        span.attr_u64("threads", effective as u64);
        Ok(Box::new(NativeSession::new(shape, effective)?))
    }
}

// --------------------------------------------------------------------------
// Shared helpers.
// --------------------------------------------------------------------------

#[inline]
fn sgn(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Raw `f32` base pointer that may cross into pool workers. Each worker
/// touches a disjoint region determined by its logical index, so shared
/// access is sound (see the dispatch sites).
#[derive(Clone, Copy)]
struct SendPtrF32(*mut f32);
unsafe impl Send for SendPtrF32 {}
unsafe impl Sync for SendPtrF32 {}

/// Same for `i32` outputs (sort_idx).
#[derive(Clone, Copy)]
struct SendPtrI32(*mut i32);
unsafe impl Send for SendPtrI32 {}
unsafe impl Sync for SendPtrI32 {}

/// Run `job(worker)` for workers `0..active` — on the pool when one
/// exists and parallelism is requested, inline otherwise. Pool-worker
/// panics surface as a typed [`PoolError`] (and poison the session's
/// pool) instead of unwinding into — and aborting — the caller's thread.
fn dispatch(
    pool: Option<&WorkerPool>,
    active: usize,
    job: &(dyn Fn(usize) + Sync),
) -> Result<(), PoolError> {
    match pool {
        Some(p) if active > 1 => p.dispatch(active, job),
        _ => {
            job(0);
            Ok(())
        }
    }
}

/// Stable descending argsort of `w` into `idx` (ties keep index order,
/// matching `jnp.argsort(-w)`), bottom-up merge into the preallocated
/// `tmp` buffer — no per-call allocation. Produces the same permutation
/// as `slice::sort_by` with the descending comparator (a stable sort's
/// output is unique).
fn stable_argsort_desc(idx: &mut [u32], tmp: &mut [u32], w: &[f32]) {
    let n = idx.len();
    debug_assert_eq!(tmp.len(), n);
    let mut width = 1;
    while width < n {
        let mut lo = 0;
        while lo < n {
            let mid = (lo + width).min(n);
            let hi = (lo + 2 * width).min(n);
            let (mut i, mut j, mut k) = (lo, mid, lo);
            while i < mid && j < hi {
                let (a, b) = (idx[i], idx[j]);
                // Descending by w; NaN and ties compare Equal, which keeps
                // the left run first (stability), exactly like the
                // `partial_cmp(..).unwrap_or(Equal)` comparator.
                let take_left = !matches!(
                    w[b as usize].partial_cmp(&w[a as usize]),
                    Some(std::cmp::Ordering::Greater)
                );
                if take_left {
                    tmp[k] = a;
                    i += 1;
                } else {
                    tmp[k] = b;
                    j += 1;
                }
                k += 1;
            }
            let left = mid - i;
            tmp[k..k + left].copy_from_slice(&idx[i..mid]);
            tmp[k + left..hi].copy_from_slice(&idx[j..hi]);
            lo = hi;
        }
        idx.copy_from_slice(tmp);
        width *= 2;
    }
}

// --------------------------------------------------------------------------
// Eq. (2) grid loss into a reusable workspace.
// --------------------------------------------------------------------------

/// Scratch for [`grid_loss_into`]: cotangent buffers sized once per
/// session. After a call, `ct_y` holds dL/dy and `ct_cs` dL/dcolsum.
struct LossWs {
    /// dL/d(gathered grid output), n·d.
    dyg: Vec<f32>,
    /// dL/dy after un-gathering, n·d.
    ct_y: Vec<f32>,
    /// dL/dcolsum, n.
    ct_cs: Vec<f32>,
    /// Per-pair displacement, d.
    diff: Vec<f32>,
}

impl LossWs {
    fn new(n: usize, d: usize) -> Self {
        LossWs {
            dyg: vec![0.0; n * d],
            ct_y: vec![0.0; n * d],
            ct_cs: vec![0.0; n],
            diff: vec![0.0; d],
        }
    }
}

/// Eq. (2) objective on a soft output `y`; returns the loss and leaves the
/// cotangents the backward passes need in `ws` (`ct_y = dL/dy`,
/// `ct_cs = dL/dcolsum`).
///
/// `inv_idx`: when `Some`, the neighbor term is evaluated on the
/// reverse-shuffled output `y[inv_idx]` (the ShuffleSoftSort gather);
/// `None` means the identity arrangement (GS/Kissing).
/// `colsum`: when `Some`, the stochastic-constraint term λ_s·L_s is
/// included (GS omits it — Sinkhorn already enforces stochasticity).
fn grid_loss_into(
    shape: StepShape,
    x: &[f32],
    y: &[f32],
    inv_idx: Option<&[i32]>,
    colsum: Option<&[f32]>,
    norm: f32,
    ws: &mut LossWs,
) -> f32 {
    let StepShape { n, d, h, w } = shape;
    let row_of = |k: usize| -> usize {
        match inv_idx {
            Some(iv) => iv[k] as usize,
            None => k,
        }
    };

    // L_nbr and its gradient w.r.t. the (gathered) grid output.
    let horiz = h * (w.saturating_sub(1));
    let vert = if h > 1 { (h - 1) * w } else { 0 };
    let count = (horiz + vert).max(1) as f32;
    let coef = 1.0 / (count * norm);
    ws.dyg.fill(0.0);
    let mut total = 0.0f64;
    {
        let diff = &mut ws.diff;
        let dyg = &mut ws.dyg;
        let mut pair = |k1: usize, k2: usize| {
            let (a, b) = (row_of(k1) * d, row_of(k2) * d);
            let mut s = 0.0f32;
            for (t, dt) in diff.iter_mut().enumerate() {
                let dd = y[a + t] - y[b + t];
                *dt = dd;
                s += dd * dd;
            }
            let dist = (s + EPS).sqrt();
            total += dist as f64;
            let g = coef / dist;
            for (t, &dt) in diff.iter().enumerate() {
                dyg[k1 * d + t] += dt * g;
                dyg[k2 * d + t] -= dt * g;
            }
        };
        for r in 0..h {
            for c in 0..w.saturating_sub(1) {
                let k = r * w + c;
                pair(k, k + 1);
            }
        }
        if h > 1 {
            for r in 0..h - 1 {
                for c in 0..w {
                    let k = r * w + c;
                    pair(k, k + w);
                }
            }
        }
    }
    let l_nbr = total as f32 * coef;

    // Scatter d/dy_grid back through the gather (bijective → plain adds).
    if inv_idx.is_some() {
        ws.ct_y.fill(0.0);
        for k in 0..n {
            let r = row_of(k) * d;
            for t in 0..d {
                ws.ct_y[r + t] += ws.dyg[k * d + t];
            }
        }
    } else {
        ws.ct_y.copy_from_slice(&ws.dyg);
    }

    // λ_s · L_s (eq. 3) on the column sums.
    ws.ct_cs.fill(0.0);
    let mut l_s = 0.0f32;
    if let Some(cs) = colsum {
        let mut acc = 0.0f64;
        for (j, &c) in cs.iter().enumerate() {
            let dev = c - 1.0;
            acc += (dev * dev) as f64;
            ws.ct_cs[j] = LAMBDA_S * 2.0 * dev / n as f32;
        }
        l_s = (acc / n as f64) as f32;
    }

    // λ_σ · L_σ (eq. 4): |σ_X − σ_Y| / σ_X over all N·d entries.
    let sx = std_f32(x);
    let sy = std_f32(y);
    let l_sigma = (sx - sy).abs() / (sx + EPS);
    if sy > 0.0 && sx != sy {
        let m = (n * d) as f64;
        let mu_y = (y.iter().map(|&v| v as f64).sum::<f64>() / m) as f32;
        let a = LAMBDA_SIGMA * sgn(sy - sx) / (sx + EPS) / (m as f32 * sy);
        for (ct, &v) in ws.ct_y.iter_mut().zip(y) {
            *ct += a * (v - mu_y);
        }
    }

    l_nbr + LAMBDA_S * l_s + LAMBDA_SIGMA * l_sigma
}

// --------------------------------------------------------------------------
// SoftSort / ShuffleSoftSort step kernels.
// --------------------------------------------------------------------------

/// Per-shape SoftSort workspace: the sort state, per-chunk reduction
/// slabs, and per-worker scratch stripes, all allocated once.
struct SssWs {
    /// Stable descending argsort of w (σ), n.
    sigma: Vec<u32>,
    /// Merge-sort ping buffer, n.
    sort_tmp: Vec<u32>,
    /// w gathered through σ (the sorted weights), n.
    ws_sorted: Vec<f32>,
    /// Per-chunk colsum partials (n_chunks × n), folded in chunk order.
    chunk_cs: Vec<f32>,
    /// Per-chunk column-side gradient partials (n_chunks × n).
    chunk_gw: Vec<f32>,
    /// Sorted-row gradients by global row index, n.
    gws: Vec<f32>,
    /// Per-worker softmax-row scratch stripes (threads × n).
    row_scratch: Vec<f32>,
    /// Per-worker dL/dP-row scratch stripes (threads × n).
    g_scratch: Vec<f32>,
}

impl SssWs {
    fn new(n: usize, threads: usize) -> Self {
        let n_chunks = n.div_ceil(ROW_CHUNK);
        SssWs {
            sigma: Vec::with_capacity(n),
            sort_tmp: vec![0u32; n],
            ws_sorted: vec![0.0; n],
            chunk_cs: vec![0.0; n_chunks * n],
            chunk_gw: vec![0.0; n_chunks * n],
            gws: vec![0.0; n],
            row_scratch: vec![0.0; threads * n],
            g_scratch: vec![0.0; threads * n],
        }
    }
}

/// Row-block forward: y = P·x, sort_idx = argmax rows, colsum = Σ rows.
/// P rows are computed, consumed and dropped (row-wise memory). Writes
/// y/sort_idx directly into `out` (disjoint chunk regions per worker) and
/// folds the per-chunk colsum partials in chunk index order.
#[allow(clippy::too_many_arguments)]
fn sss_forward(
    pool: Option<&WorkerPool>,
    threads: usize,
    n: usize,
    d: usize,
    ws_sorted: &[f32],
    w: &[f32],
    x: &[f32],
    tau: f32,
    chunk_cs: &mut [f32],
    row_scratch: &mut [f32],
    out: &mut SssStep,
) -> Result<(), PoolError> {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let active = threads.min(n_chunks).max(1);
    let y_ptr = SendPtrF32(out.y.as_mut_ptr());
    let idx_ptr = SendPtrI32(out.sort_idx.as_mut_ptr());
    let cs_ptr = SendPtrF32(chunk_cs.as_mut_ptr());
    let row_ptr = SendPtrF32(row_scratch.as_mut_ptr());
    let job = move |wk: usize| {
        // Safety: worker `wk` owns scratch stripe `wk` and exactly the
        // chunks c ≡ wk (mod active) — all regions disjoint across
        // workers, and the dispatch blocks until every worker finished.
        let row = unsafe { std::slice::from_raw_parts_mut(row_ptr.0.add(wk * n), n) };
        let mut c = wk;
        while c < n_chunks {
            let r0 = c * ROW_CHUNK;
            let r1 = (r0 + ROW_CHUNK).min(n);
            let cs = unsafe { std::slice::from_raw_parts_mut(cs_ptr.0.add(c * n), n) };
            cs.fill(0.0);
            for i in r0..r1 {
                let wsi = ws_sorted[i];
                // Pass 1: logits (stride-1, branch-free).
                for (rj, &wj) in row.iter_mut().zip(w) {
                    *rj = -(wsi - wj).abs() / tau;
                }
                // Pass 2: max + argmax (same `>` scan order as the fused
                // loop had, so ties resolve identically).
                let mut mx = f32::NEG_INFINITY;
                let mut arg = 0usize;
                for (j, &rj) in row.iter().enumerate() {
                    if rj > mx {
                        mx = rj;
                        arg = j;
                    }
                }
                // Pass 3: exp + denominator.
                let mut denom = 0.0f32;
                for rj in row.iter_mut() {
                    *rj = (*rj - mx).exp();
                    denom += *rj;
                }
                let inv = 1.0 / denom;
                unsafe { *idx_ptr.0.add(i) = arg as i32 };
                // Pass 4: probabilities → colsum + y (unrolled d = 3 fast
                // path accumulates in registers; same per-component add
                // order as the generic path).
                if d == 3 {
                    let (mut y0, mut y1, mut y2) = (0.0f32, 0.0f32, 0.0f32);
                    for (j, (rj, cj)) in row.iter().zip(cs.iter_mut()).enumerate() {
                        let p = *rj * inv;
                        *cj += p;
                        let b = j * 3;
                        y0 += p * x[b];
                        y1 += p * x[b + 1];
                        y2 += p * x[b + 2];
                    }
                    unsafe {
                        *y_ptr.0.add(i * 3) = y0;
                        *y_ptr.0.add(i * 3 + 1) = y1;
                        *y_ptr.0.add(i * 3 + 2) = y2;
                    }
                } else {
                    let yi =
                        unsafe { std::slice::from_raw_parts_mut(y_ptr.0.add(i * d), d) };
                    yi.fill(0.0);
                    for (j, &rj) in row.iter().enumerate() {
                        let p = rj * inv;
                        cs[j] += p;
                        let xj = &x[j * d..(j + 1) * d];
                        for (yc, &xc) in yi.iter_mut().zip(xj) {
                            *yc += p * xc;
                        }
                    }
                }
            }
            c += active;
        }
    };
    dispatch(pool, active, &job)?;

    // Deterministic reduction: fold per-chunk column partials in chunk
    // index order — bit-identical for any pool size.
    out.colsum.fill(0.0);
    for c in 0..n_chunks {
        for (dst, &s) in out.colsum.iter_mut().zip(&chunk_cs[c * n..(c + 1) * n]) {
            *dst += s;
        }
    }
    Ok(())
}

/// Row-block backward: recompute each P row, pull the loss cotangents
/// through softmax and the |ws_i − w_j| kernel, reduce into dL/dw via the
/// chunk-ordered fold + the σ scatter (sort_desc's VJP).
#[allow(clippy::too_many_arguments)]
fn sss_backward(
    pool: Option<&WorkerPool>,
    threads: usize,
    n: usize,
    d: usize,
    ws_sorted: &[f32],
    w: &[f32],
    sigma: &[u32],
    x: &[f32],
    tau: f32,
    ct_y: &[f32],
    ct_cs: &[f32],
    chunk_gw: &mut [f32],
    gws: &mut [f32],
    row_scratch: &mut [f32],
    g_scratch: &mut [f32],
    grad: &mut [f32],
) -> Result<(), PoolError> {
    let n_chunks = n.div_ceil(ROW_CHUNK);
    let active = threads.min(n_chunks).max(1);
    let gw_ptr = SendPtrF32(chunk_gw.as_mut_ptr());
    let gws_ptr = SendPtrF32(gws.as_mut_ptr());
    let prob_ptr = SendPtrF32(row_scratch.as_mut_ptr());
    let gbuf_ptr = SendPtrF32(g_scratch.as_mut_ptr());
    let job = move |wk: usize| {
        // Safety: disjoint stripes/chunks per worker, as in the forward.
        let prob = unsafe { std::slice::from_raw_parts_mut(prob_ptr.0.add(wk * n), n) };
        let gbuf = unsafe { std::slice::from_raw_parts_mut(gbuf_ptr.0.add(wk * n), n) };
        let mut c = wk;
        while c < n_chunks {
            let r0 = c * ROW_CHUNK;
            let r1 = (r0 + ROW_CHUNK).min(n);
            let gw = unsafe { std::slice::from_raw_parts_mut(gw_ptr.0.add(c * n), n) };
            gw.fill(0.0);
            for i in r0..r1 {
                let wsi = ws_sorted[i];
                // Recompute the probability row (identical pass structure
                // to the forward, so the same f32 roundings reproduce).
                for (pj, &wj) in prob.iter_mut().zip(w) {
                    *pj = -(wsi - wj).abs() / tau;
                }
                let mut mx = f32::NEG_INFINITY;
                for &pj in prob.iter() {
                    if pj > mx {
                        mx = pj;
                    }
                }
                let mut denom = 0.0f32;
                for pj in prob.iter_mut() {
                    *pj = (*pj - mx).exp();
                    denom += *pj;
                }
                let inv = 1.0 / denom;
                for pj in prob.iter_mut() {
                    *pj *= inv;
                }

                // dL/dP_ij = ct_y[i]·x_j + ct_cs[j]; softmax row backward.
                let cti = &ct_y[i * d..(i + 1) * d];
                let mut dot = 0.0f32;
                if d == 3 {
                    let (c0, c1, c2) = (cti[0], cti[1], cti[2]);
                    for (j, gj) in gbuf.iter_mut().enumerate() {
                        let b = j * 3;
                        let g = ((ct_cs[j] + c0 * x[b]) + c1 * x[b + 1]) + c2 * x[b + 2];
                        *gj = g;
                        dot += g * prob[j];
                    }
                } else {
                    for (j, gj) in gbuf.iter_mut().enumerate() {
                        let mut g = ct_cs[j];
                        let xj = &x[j * d..(j + 1) * d];
                        for (ct, &xc) in cti.iter().zip(xj) {
                            g += ct * xc;
                        }
                        *gj = g;
                        dot += g * prob[j];
                    }
                }
                let mut gws_i = 0.0f32;
                for j in 0..n {
                    let dl = prob[j] * (gbuf[j] - dot);
                    let s = sgn(wsi - w[j]);
                    gws_i -= dl * s / tau;
                    gw[j] += dl * s / tau;
                }
                unsafe { *gws_ptr.0.add(i) = gws_i };
            }
            c += active;
        }
    };
    dispatch(pool, active, &job)?;

    // Deterministic reduction: chunk-ordered column partials, then the
    // sorted-side scatter through σ in ascending row order (identical to
    // the pre-session chunk-then-row iteration).
    grad.fill(0.0);
    for c in 0..n_chunks {
        for (g, &p) in grad.iter_mut().zip(&chunk_gw[c * n..(c + 1) * n]) {
            *g += p;
        }
    }
    for (i, &gv) in gws.iter().enumerate() {
        grad[sigma[i] as usize] += gv;
    }
    Ok(())
}

// --------------------------------------------------------------------------
// Gumbel-Sinkhorn helpers.
// --------------------------------------------------------------------------

/// Per-shape GS workspace. `states` is the reverse-mode state stack: one
/// flat slab for the 2·`SINKHORN_ITERS` post-normalization log-matrices,
/// reused every step (the pre-session code re-allocated a `Vec<Vec<f32>>`
/// of N² clones per step).
struct GsWs {
    la: Vec<f32>,
    states: Vec<f32>,
    dz: Vec<f32>,
    y: Vec<f32>,
}

impl GsWs {
    fn new(n: usize, d: usize) -> Self {
        GsWs {
            la: vec![0.0; n * n],
            states: vec![0.0; 2 * SINKHORN_ITERS * n * n],
            dz: vec![0.0; n * n],
            y: vec![0.0; n * d],
        }
    }
}

fn row_lse_normalize(la: &mut [f32], n: usize) {
    for i in 0..n {
        let row = &mut la[i * n..(i + 1) * n];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0.0f32;
        for &v in row.iter() {
            s += (v - mx).exp();
        }
        let lse = mx + s.ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

fn col_lse_normalize(la: &mut [f32], n: usize) {
    for j in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for i in 0..n {
            mx = mx.max(la[i * n + j]);
        }
        let mut s = 0.0f32;
        for i in 0..n {
            s += (la[i * n + j] - mx).exp();
        }
        let lse = mx + s.ln();
        for i in 0..n {
            la[i * n + j] -= lse;
        }
    }
}

/// Log-space Sinkhorn forward, in place. When `states` is `Some`, the
/// output of every normalization is copied into the slab (reverse-mode
/// needs exactly those values). Ends by exponentiating `la` into P.
fn sinkhorn_log_in_place(la: &mut [f32], n: usize, mut states: Option<&mut [f32]>) {
    let n2 = n * n;
    for it in 0..SINKHORN_ITERS {
        row_lse_normalize(la, n);
        if let Some(s) = states.as_deref_mut() {
            s[2 * it * n2..(2 * it + 1) * n2].copy_from_slice(la);
        }
        col_lse_normalize(la, n);
        if let Some(s) = states.as_deref_mut() {
            s[(2 * it + 1) * n2..(2 * it + 2) * n2].copy_from_slice(la);
        }
    }
    for v in la.iter_mut() {
        *v = v.exp();
    }
}

// --------------------------------------------------------------------------
// Kissing helpers.
// --------------------------------------------------------------------------

/// Classic kissing numbers K(M) — mirrors `python/compile/shapes.py`
/// (`kissing_number(M) ≥ N` picks the rank; Table 2 pins M(1024) = 13).
const KISSING_TABLE: &[(usize, usize)] =
    &[(240, 8), (306, 9), (500, 10), (582, 11), (840, 12), (1154, 13), (4320, 16)];

/// Per-shape Kissing workspace (sized for one factor rank `m`; reallocated
/// only if a caller switches ranks mid-session, which drivers never do).
struct KissWs {
    m: usize,
    norms_v: Vec<f32>,
    norms_w: Vec<f32>,
    vn: Vec<f32>,
    wn: Vec<f32>,
    dvn: Vec<f32>,
    dwn: Vec<f32>,
    y: Vec<f32>,
    colsum: Vec<f32>,
    row: Vec<f32>,
    gbuf: Vec<f32>,
}

impl KissWs {
    fn new(n: usize, d: usize, m: usize) -> Self {
        KissWs {
            m,
            norms_v: vec![0.0; n],
            norms_w: vec![0.0; n],
            vn: vec![0.0; n * m],
            wn: vec![0.0; n * m],
            dvn: vec![0.0; n * m],
            dwn: vec![0.0; n * m],
            y: vec![0.0; n * d],
            colsum: vec![0.0; n],
            row: vec![0.0; n],
            gbuf: vec![0.0; n],
        }
    }
}

/// Row L2 norms and the row-normalized matrix v̂ = v / (‖v_row‖ + ε),
/// written into the preallocated `norms`/`vn`.
fn normalize_rows_into(v: &[f32], n: usize, m: usize, norms: &mut [f32], vn: &mut [f32]) {
    for i in 0..n {
        let row = &v[i * m..(i + 1) * m];
        let mut s = 0.0f32;
        for &a in row {
            s += a * a;
        }
        let r = s.sqrt();
        norms[i] = r;
        let inv = 1.0 / (r + KISS_NORM_EPS);
        for (dst, &a) in vn[i * m..(i + 1) * m].iter_mut().zip(row) {
            *dst = a * inv;
        }
    }
}

/// VJP of row normalization: given dL/dv̂ in `dvn`, write dL/dv into `dv`.
fn normalize_rows_backward_into(
    v: &[f32],
    norms: &[f32],
    dvn: &[f32],
    n: usize,
    m: usize,
    dv: &mut [f32],
) {
    for i in 0..n {
        let r = norms[i];
        let denom = r + KISS_NORM_EPS;
        let vi = &v[i * m..(i + 1) * m];
        let di = &dvn[i * m..(i + 1) * m];
        let mut dot = 0.0f32;
        for (&a, &b) in vi.iter().zip(di) {
            dot += a * b;
        }
        let out = &mut dv[i * m..(i + 1) * m];
        if r > 0.0 {
            let k = dot / (r * denom * denom);
            for ((o, &b), &a) in out.iter_mut().zip(di).zip(vi) {
                *o = b / denom - a * k;
            }
        } else {
            for (o, &b) in out.iter_mut().zip(di) {
                *o = b / denom;
            }
        }
    }
}

/// One row of P = row-softmax(scale·v̂ŵᵀ/τ) into `row`; returns the argmax.
fn kiss_softmax_row(
    i: usize,
    m: usize,
    scale_t: f32,
    vn: &[f32],
    wn: &[f32],
    row: &mut [f32],
) -> usize {
    let vi = &vn[i * m..(i + 1) * m];
    let mut mx = f32::NEG_INFINITY;
    let mut arg = 0usize;
    for (j, rj) in row.iter_mut().enumerate() {
        let wj = &wn[j * m..(j + 1) * m];
        let mut dot = 0.0f32;
        for (&a, &b) in vi.iter().zip(wj) {
            dot += a * b;
        }
        let l = scale_t * dot;
        *rj = l;
        if l > mx {
            mx = l;
            arg = j;
        }
    }
    let mut denom = 0.0f32;
    for rj in row.iter_mut() {
        *rj = (*rj - mx).exp();
        denom += *rj;
    }
    let inv = 1.0 / denom;
    for rj in row.iter_mut() {
        *rj *= inv;
    }
    arg
}

// --------------------------------------------------------------------------
// Session + trait implementation.
// --------------------------------------------------------------------------

fn check_shape(shape: StepShape) -> Result<()> {
    ensure!(shape.n >= 2, "native backend needs N >= 2 (got {})", shape.n);
    ensure!(
        shape.h * shape.w == shape.n,
        "grid {}x{} != N={}",
        shape.h,
        shape.w,
        shape.n
    );
    Ok(())
}

fn check_scalars(tau: f32, norm: f32) -> Result<()> {
    ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
    ensure!(norm.is_finite() && norm > 0.0, "norm must be positive, got {norm}");
    Ok(())
}

/// The native backend's stateful per-shape session: owns every scratch
/// buffer (allocated on first use of each step family) and a persistent
/// worker pool (spawned lazily on the first parallel dispatch). The
/// steady-state step loop allocates nothing and spawns nothing.
struct NativeSession {
    shape: StepShape,
    /// Effective row-parallel width for this shape (PAR_MIN_N-gated).
    threads: usize,
    pool: Option<WorkerPool>,
    sss: Option<SssWs>,
    loss: Option<LossWs>,
    gs: Option<GsWs>,
    kiss: Option<KissWs>,
}

impl NativeSession {
    fn new(shape: StepShape, threads: usize) -> Result<Self> {
        check_shape(shape)?;
        Ok(NativeSession {
            shape,
            threads,
            pool: None,
            sss: None,
            loss: None,
            gs: None,
            kiss: None,
        })
    }

    fn ensure_pool(&mut self) {
        if self.threads > 1 && self.pool.is_none() {
            self.pool = Some(WorkerPool::new(self.threads - 1));
        }
    }
}

impl StepSession for NativeSession {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn shape(&self) -> StepShape {
        self.shape
    }

    fn sss_step(
        &mut self,
        w: &[f32],
        x_shuf: &[f32],
        inv_idx: &[i32],
        tau: f32,
        norm: f32,
        out: &mut SssStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "sss_step needs d >= 1 (this session has d={d})");
        ensure!(w.len() == n, "w length {} != N={n}", w.len());
        ensure!(x_shuf.len() == n * d, "x length {} != N*d={}", x_shuf.len(), n * d);
        ensure!(inv_idx.len() == n, "inv_idx length {} != N={n}", inv_idx.len());
        for &i in inv_idx {
            ensure!((0..n as i32).contains(&i), "inv_idx entry {i} out of range 0..{n}");
        }

        self.ensure_pool();
        let threads = self.threads;
        if self.sss.is_none() {
            self.sss = Some(SssWs::new(n, threads));
        }
        if self.loss.is_none() {
            self.loss = Some(LossWs::new(n, d));
        }
        // Size caller buffers on first use (no-ops afterwards).
        out.grad.resize(n, 0.0);
        out.sort_idx.resize(n, 0);
        out.colsum.resize(n, 0.0);
        out.y.resize(n * d, 0.0);

        let pool = self.pool.as_ref();
        let sss = self.sss.as_mut().expect("allocated above");
        let lws = self.loss.as_mut().expect("allocated above");

        // sort_desc(w): stable descending argsort (ties keep index order,
        // matching jnp.argsort(-w)); its VJP is the scatter through σ.
        sss.sigma.clear();
        sss.sigma.extend(0..n as u32);
        stable_argsort_desc(&mut sss.sigma, &mut sss.sort_tmp, w);
        for (dst, &i) in sss.ws_sorted.iter_mut().zip(&sss.sigma) {
            *dst = w[i as usize];
        }

        sss_forward(
            pool,
            threads,
            n,
            d,
            &sss.ws_sorted,
            w,
            x_shuf,
            tau,
            &mut sss.chunk_cs,
            &mut sss.row_scratch,
            out,
        )?;
        out.loss =
            grid_loss_into(shape, x_shuf, &out.y, Some(inv_idx), Some(&out.colsum), norm, lws);
        sss_backward(
            pool,
            threads,
            n,
            d,
            &sss.ws_sorted,
            w,
            &sss.sigma,
            x_shuf,
            tau,
            &lws.ct_y,
            &lws.ct_cs,
            &mut sss.chunk_gw,
            &mut sss.gws,
            &mut sss.row_scratch,
            &mut sss.g_scratch,
            &mut out.grad,
        )?;
        Ok(())
    }

    fn gs_step(
        &mut self,
        logits: &[f32],
        x: &[f32],
        gumbel: &[f32],
        tau: f32,
        norm: f32,
        out: &mut GsStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "gs_step needs d >= 1 (this session has d={d})");
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(gumbel.len() == n * n, "gumbel length {} != N²={}", gumbel.len(), n * n);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        if self.gs.is_none() {
            self.gs = Some(GsWs::new(n, d));
        }
        if self.loss.is_none() {
            self.loss = Some(LossWs::new(n, d));
        }
        out.grad.resize(n * n, 0.0);
        let gs = self.gs.as_mut().expect("allocated above");
        let lws = self.loss.as_mut().expect("allocated above");

        // Forward, recording every normalization output for reverse-mode.
        for (dst, (&l, &g)) in gs.la.iter_mut().zip(logits.iter().zip(gumbel)) {
            *dst = (l + g) / tau;
        }
        sinkhorn_log_in_place(&mut gs.la, n, Some(&mut gs.states));
        let p = &gs.la; // now the dense doubly stochastic P

        for i in 0..n {
            let yi = &mut gs.y[i * d..(i + 1) * d];
            yi.fill(0.0);
            for j in 0..n {
                let pij = p[i * n + j];
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += pij * xc;
                }
            }
        }

        // GS loss omits L_s (Sinkhorn already enforces stochasticity).
        out.loss = grid_loss_into(shape, x, &gs.y, None, None, norm, lws);

        // dL/dP → through exp → reverse the 2·iters normalizations.
        for i in 0..n {
            let cti = &lws.ct_y[i * d..(i + 1) * d];
            for j in 0..n {
                let mut g = 0.0f32;
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                gs.dz[i * n + j] = p[i * n + j] * g;
            }
        }
        let dz = &mut gs.dz;
        for t in (0..2 * SINKHORN_ITERS).rev() {
            let z = &gs.states[t * n * n..(t + 1) * n * n];
            // z = la − lse(la) ⇒ dla = dz − softmax(la)·Σdz, softmax = exp(z).
            if t % 2 == 1 {
                // Column normalization (second in each sweep).
                for j in 0..n {
                    let mut s = 0.0f32;
                    for i in 0..n {
                        s += dz[i * n + j];
                    }
                    for i in 0..n {
                        dz[i * n + j] -= z[i * n + j].exp() * s;
                    }
                }
            } else {
                for i in 0..n {
                    let row = &mut dz[i * n..(i + 1) * n];
                    let zr = &z[i * n..(i + 1) * n];
                    let s: f32 = row.iter().sum();
                    for (dv, &zv) in row.iter_mut().zip(zr) {
                        *dv -= zv.exp() * s;
                    }
                }
            }
        }
        for (g, &v) in out.grad.iter_mut().zip(dz.iter()) {
            *g = v / tau;
        }
        Ok(())
    }

    fn gs_probe(&mut self, logits: &[f32], tau: f32, out: &mut Vec<f32>) -> Result<()> {
        let n = self.shape.n;
        ensure!(logits.len() == n * n, "logits length {} != N²={}", logits.len(), n * n);
        ensure!(tau.is_finite() && tau > 0.0, "temperature must be positive, got {tau}");
        out.resize(n * n, 0.0);
        for (dst, &l) in out.iter_mut().zip(logits) {
            *dst = l / tau;
        }
        sinkhorn_log_in_place(out, n, None);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn kiss_step(
        &mut self,
        m: usize,
        v: &[f32],
        wf: &[f32],
        x: &[f32],
        tau: f32,
        norm: f32,
        out: &mut KissStep,
    ) -> Result<()> {
        let shape = self.shape;
        let StepShape { n, d, .. } = shape;
        check_scalars(tau, norm)?;
        ensure!(d >= 1, "kiss_step needs d >= 1 (this session has d={d})");
        ensure!(m >= 1, "kissing rank must be >= 1");
        ensure!(v.len() == n * m, "v length {} != N*M={}", v.len(), n * m);
        ensure!(wf.len() == n * m, "w length {} != N*M={}", wf.len(), n * m);
        ensure!(x.len() == n * d, "x length {} != N*d={}", x.len(), n * d);

        if self.kiss.as_ref().map(|k| k.m) != Some(m) {
            self.kiss = Some(KissWs::new(n, d, m));
        }
        if self.loss.is_none() {
            self.loss = Some(LossWs::new(n, d));
        }
        out.grad_v.resize(n * m, 0.0);
        out.grad_w.resize(n * m, 0.0);
        out.sort_idx.resize(n, 0);
        let kw = self.kiss.as_mut().expect("allocated above");
        let lws = self.loss.as_mut().expect("allocated above");

        normalize_rows_into(v, n, m, &mut kw.norms_v, &mut kw.vn);
        normalize_rows_into(wf, n, m, &mut kw.norms_w, &mut kw.wn);
        let scale_t = KISS_SCALE / tau;

        // Forward: P = row-softmax(scale·v̂ŵᵀ/τ); rows recomputed in the
        // backward pass (memory stays O(N·(M+d))).
        kw.colsum.fill(0.0);
        for i in 0..n {
            let arg = kiss_softmax_row(i, m, scale_t, &kw.vn, &kw.wn, &mut kw.row);
            out.sort_idx[i] = arg as i32;
            let yi = &mut kw.y[i * d..(i + 1) * d];
            yi.fill(0.0);
            for (j, &p) in kw.row.iter().enumerate() {
                kw.colsum[j] += p;
                let xj = &x[j * d..(j + 1) * d];
                for (yc, &xc) in yi.iter_mut().zip(xj) {
                    *yc += p * xc;
                }
            }
        }

        out.loss = grid_loss_into(shape, x, &kw.y, None, Some(&kw.colsum), norm, lws);

        // Backward: softmax rows → the two normalized factors → v, w.
        kw.dvn.fill(0.0);
        kw.dwn.fill(0.0);
        for i in 0..n {
            kiss_softmax_row(i, m, scale_t, &kw.vn, &kw.wn, &mut kw.row);
            let cti = &lws.ct_y[i * d..(i + 1) * d];
            let mut dot = 0.0f32;
            for (j, gj) in kw.gbuf.iter_mut().enumerate() {
                let mut g = lws.ct_cs[j];
                let xj = &x[j * d..(j + 1) * d];
                for (ct, &xc) in cti.iter().zip(xj) {
                    g += ct * xc;
                }
                *gj = g;
                dot += g * kw.row[j];
            }
            let vi = &kw.vn[i * m..(i + 1) * m];
            for (j, &p) in kw.row.iter().enumerate() {
                let a = scale_t * p * (kw.gbuf[j] - dot);
                let wj = &kw.wn[j * m..(j + 1) * m];
                let dvi = &mut kw.dvn[i * m..(i + 1) * m];
                for (dv, &b) in dvi.iter_mut().zip(wj) {
                    *dv += a * b;
                }
                let dwj = &mut kw.dwn[j * m..(j + 1) * m];
                for (dw, &b) in dwj.iter_mut().zip(vi) {
                    *dw += a * b;
                }
            }
        }
        normalize_rows_backward_into(v, &kw.norms_v, &kw.dvn, n, m, &mut out.grad_v);
        normalize_rows_backward_into(wf, &kw.norms_w, &kw.dwn, n, m, &mut out.grad_w);
        Ok(())
    }
}

impl StepBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn session(&self, shape: StepShape, threads: Option<usize>) -> Result<Box<dyn StepSession>> {
        Ok(self.session_send(shape, threads)?)
    }

    fn session_sendable(
        &self,
        shape: StepShape,
        threads: Option<usize>,
    ) -> Result<Option<Box<dyn StepSession + Send>>> {
        Ok(Some(self.session_send(shape, threads)?))
    }

    fn default_threads(&self) -> usize {
        self.threads
    }

    fn kiss_rank(&self, n: usize, _d: usize) -> Result<usize> {
        for &(max_n, m) in KISSING_TABLE {
            if n <= max_n {
                return Ok(m);
            }
        }
        bail!("no tabulated kissing rank covers N={n} (max 4320)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridShape;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn native_backend_is_send_sync() {
        assert_send_sync::<NativeBackend>();
    }

    #[test]
    fn sendable_sessions_match_plain_sessions_and_report_the_pool_width() {
        // The tiled executor's contract: native sessions may cross threads
        // and compute exactly what a plain session computes, and the
        // backend reports its configured width for budgeting.
        let backend = NativeBackend::new(3);
        assert_eq!(backend.default_threads(), 3);
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let x = pattern(16 * 3, 1);
        let w = ramp_w(16);
        let inv: Vec<i32> = (0..16).collect();
        let mut sendable = backend.session_sendable(shape, Some(1)).unwrap().expect("native");
        let plain = backend.sss_step(shape, &w, &x, &inv, 0.3, 0.5).unwrap();
        let mut out = SssStep::new_for(shape);
        std::thread::scope(|scope| {
            scope
                .spawn(|| sendable.sss_step(&w, &x, &inv, 0.3, 0.5, &mut out).unwrap())
                .join()
                .unwrap();
        });
        assert_eq!(out.loss.to_bits(), plain.loss.to_bits());
        assert_eq!(out.sort_idx, plain.sort_idx);
    }

    /// Deterministic pseudo-data in [0, 1) without pulling in the RNG.
    fn pattern(len: usize, salt: u32) -> Vec<f32> {
        (0..len)
            .map(|i| {
                let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
                (h % 10_000) as f32 / 10_000.0
            })
            .collect()
    }

    /// Well-separated weights (spacing ≈ 1) so finite differences never
    /// cross a sort-order kink.
    fn ramp_w(n: usize) -> Vec<f32> {
        (0..n).map(|i| (n - i) as f32 + 0.3 * (i as f32).sin()).collect()
    }

    fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (y as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-9)) as f32
    }

    /// Centered finite differences of `f` at `p`.
    fn fd_grad(p: &[f32], eps: f32, mut f: impl FnMut(&[f32]) -> f32) -> Vec<f32> {
        let mut g = vec![0.0f32; p.len()];
        let mut q = p.to_vec();
        for i in 0..p.len() {
            let orig = q[i];
            q[i] = orig + eps;
            let hi = f(&q);
            q[i] = orig - eps;
            let lo = f(&q);
            q[i] = orig;
            g[i] = (hi - lo) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn sss_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(4, 4), 2);
        let be = NativeBackend::new(1);
        let w = ramp_w(16);
        let x = pattern(16 * 2, 7);
        // A non-identity shuffle inverse (5 is coprime to 16).
        let inv: Vec<i32> = (0..16).map(|k| (k * 5) % 16).collect();
        let (tau, norm) = (0.7f32, 0.5f32);

        let ana = be.sss_step(shape, &w, &x, &inv, tau, norm).unwrap().grad;
        let fd = fd_grad(&w, 1e-2, |wp| {
            be.sss_step(shape, wp, &x, &inv, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "sss grad rel-L2 error {err} (ana {ana:?} fd {fd:?})");
    }

    #[test]
    fn gs_gradient_matches_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let gumbel = vec![0.0f32; 81];
        let x = pattern(9 * 2, 11);
        let (tau, norm) = (1.0f32, 0.5f32);

        let ana = be.gs_step(shape, &logits, &x, &gumbel, tau, norm).unwrap().grad;
        let fd = fd_grad(&logits, 1e-2, |lp| {
            be.gs_step(shape, lp, &x, &gumbel, tau, norm).unwrap().loss
        });
        let err = rel_l2(&fd, &ana);
        assert!(err < 0.05, "gs grad rel-L2 error {err}");
    }

    #[test]
    fn kiss_gradients_match_finite_differences() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let m = be.kiss_rank(9, 2).unwrap();
        let v: Vec<f32> = pattern(9 * m, 5).iter().map(|a| a + 0.2).collect();
        let wf: Vec<f32> = pattern(9 * m, 9).iter().map(|a| a + 0.2).collect();
        let x = pattern(9 * 2, 13);
        // Soft temperature keeps the scale·τ⁻¹ softmax smooth enough for
        // f32 finite differences.
        let (tau, norm) = (6.0f32, 0.5f32);

        let out = be.kiss_step(shape, m, &v, &wf, &x, tau, norm).unwrap();
        let fd_v = fd_grad(&v, 5e-3, |vp| {
            be.kiss_step(shape, m, vp, &wf, &x, tau, norm).unwrap().loss
        });
        let fd_w = fd_grad(&wf, 5e-3, |wp| {
            be.kiss_step(shape, m, &v, wp, &x, tau, norm).unwrap().loss
        });
        let ev = rel_l2(&fd_v, &out.grad_v);
        let ew = rel_l2(&fd_w, &out.grad_w);
        assert!(ev < 0.08, "kiss grad_v rel-L2 error {ev}");
        assert!(ew < 0.08, "kiss grad_w rel-L2 error {ew}");
    }

    fn assert_sss_bits_eq(a: &SssStep, b: &SssStep, what: &str) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{what}: loss");
        assert_eq!(a.sort_idx, b.sort_idx, "{what}: sort_idx");
        for (ga, gb) in a.grad.iter().zip(&b.grad) {
            assert_eq!(ga.to_bits(), gb.to_bits(), "{what}: grad");
        }
        for (ya, yb) in a.y.iter().zip(&b.y) {
            assert_eq!(ya.to_bits(), yb.to_bits(), "{what}: y");
        }
        for (ca, cb) in a.colsum.iter().zip(&b.colsum) {
            assert_eq!(ca.to_bits(), cb.to_bits(), "{what}: colsum");
        }
    }

    #[test]
    fn sss_step_is_bit_identical_across_pool_sizes() {
        // N=600 exceeds PAR_MIN_N → multi-thread sessions really run the
        // pool path; fixed chunking must make 1, 2 and 8 threads (and the
        // stateless wrapper) bit-identical.
        let shape = StepShape::new(GridShape::new(20, 30), 3);
        let w = ramp_w(600);
        let x = pattern(600 * 3, 17);
        let inv: Vec<i32> = (0..600).map(|k| ((k * 7) % 600) as i32).collect();
        let base = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
        for threads in [2usize, 8] {
            let out =
                NativeBackend::new(threads).sss_step(shape, &w, &x, &inv, 0.4, 0.5).unwrap();
            assert_sss_bits_eq(&out, &base, &format!("{threads} threads"));
        }
        // Explicit per-session thread override through the session API.
        let be = NativeBackend::new(1);
        let mut session = be.session(shape, Some(8)).unwrap();
        let mut out = SssStep::new_for(shape);
        session.sss_step(&w, &x, &inv, 0.4, 0.5, &mut out).unwrap();
        assert_sss_bits_eq(&out, &base, "session threads=8 override");
    }

    #[test]
    fn session_reuse_matches_fresh_sessions_on_an_sss_trajectory() {
        // Drive a small gradient-descent trajectory twice: stateless calls
        // (fresh session per step) vs one session reused — every step must
        // be bit-identical, including after buffer reuse kicks in.
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let be = NativeBackend::new(2);
        let x = pattern(16 * 3, 31);
        let inv: Vec<i32> = (0..16).map(|k| (k * 3) % 16).collect();
        let mut w_fresh = ramp_w(16);
        let mut w_sess = w_fresh.clone();
        let mut session = be.session(shape, None).unwrap();
        let mut out = SssStep::new_for(shape);
        for step in 0..5 {
            let fresh = be.sss_step(shape, &w_fresh, &x, &inv, 0.5, 0.5).unwrap();
            session.sss_step(&w_sess, &x, &inv, 0.5, 0.5, &mut out).unwrap();
            assert_sss_bits_eq(&out, &fresh, &format!("step {step}"));
            for (wv, &g) in w_fresh.iter_mut().zip(&fresh.grad) {
                *wv -= 0.1 * g;
            }
            for (wv, &g) in w_sess.iter_mut().zip(&out.grad) {
                *wv -= 0.1 * g;
            }
        }
    }

    #[test]
    fn session_reuse_matches_fresh_sessions_for_gs_and_kiss() {
        let shape = StepShape::new(GridShape::new(3, 3), 2);
        let be = NativeBackend::new(1);
        let x = pattern(9 * 2, 11);
        let gumbel = vec![0.0f32; 81];
        let mut logits: Vec<f32> = pattern(81, 3).iter().map(|v| v - 0.5).collect();
        let mut session = be.session(shape, None).unwrap();
        let mut gout = GsStep::new_for(9);
        for step in 0..3 {
            let fresh = be.gs_step(shape, &logits, &x, &gumbel, 1.0, 0.5).unwrap();
            session.gs_step(&logits, &x, &gumbel, 1.0, 0.5, &mut gout).unwrap();
            assert_eq!(gout.loss.to_bits(), fresh.loss.to_bits(), "gs step {step}");
            for (a, b) in gout.grad.iter().zip(&fresh.grad) {
                assert_eq!(a.to_bits(), b.to_bits(), "gs step {step}: grad");
            }
            for (l, &g) in logits.iter_mut().zip(&fresh.grad) {
                *l -= 0.05 * g;
            }
        }
        // Probe through the same session reuses its buffers too.
        let probe_fresh = be.gs_probe(9, &logits, 0.5).unwrap();
        let mut probe_sess = Vec::new();
        session.gs_probe(&logits, 0.5, &mut probe_sess).unwrap();
        for (a, b) in probe_sess.iter().zip(&probe_fresh) {
            assert_eq!(a.to_bits(), b.to_bits(), "probe");
        }

        let m = be.kiss_rank(9, 2).unwrap();
        let mut v: Vec<f32> = pattern(9 * m, 5).iter().map(|a| a + 0.2).collect();
        let wf: Vec<f32> = pattern(9 * m, 9).iter().map(|a| a + 0.2).collect();
        let mut kout = KissStep::new_for(9, m);
        for step in 0..3 {
            let fresh = be.kiss_step(shape, m, &v, &wf, &x, 6.0, 0.5).unwrap();
            session.kiss_step(m, &v, &wf, &x, 6.0, 0.5, &mut kout).unwrap();
            assert_eq!(kout.loss.to_bits(), fresh.loss.to_bits(), "kiss step {step}");
            assert_eq!(kout.sort_idx, fresh.sort_idx, "kiss step {step}");
            for (a, b) in kout.grad_v.iter().zip(&fresh.grad_v) {
                assert_eq!(a.to_bits(), b.to_bits(), "kiss step {step}: grad_v");
            }
            for (a, b) in kout.grad_w.iter().zip(&fresh.grad_w) {
                assert_eq!(a.to_bits(), b.to_bits(), "kiss step {step}: grad_w");
            }
            for (vv, &g) in v.iter_mut().zip(&fresh.grad_v) {
                *vv -= 0.05 * g;
            }
        }
    }

    #[test]
    fn stable_argsort_matches_std_stable_sort() {
        for salt in [1u32, 2, 3] {
            let mut w = pattern(137, salt);
            // Inject ties to exercise stability.
            w[10] = w[90];
            w[20] = w[40];
            let mut idx: Vec<u32> = (0..137).collect();
            let mut tmp = vec![0u32; 137];
            stable_argsort_desc(&mut idx, &mut tmp, &w);
            let mut expect: Vec<u32> = (0..137).collect();
            expect.sort_by(|&a, &b| {
                w[b as usize]
                    .partial_cmp(&w[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            assert_eq!(idx, expect, "salt {salt}");
        }
    }

    #[test]
    fn sharp_tau_on_ordered_weights_gives_identity_argmax() {
        // Mirrors the PJRT integration check: order-preserving init at a
        // sharp temperature ⇒ identity sort_idx and colsum ≈ 1.
        let n = 32;
        let shape = StepShape::new(GridShape::new(4, 8), 3);
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let x = pattern(n * 3, 23);
        let inv: Vec<i32> = (0..n as i32).collect();
        let out = NativeBackend::new(1).sss_step(shape, &w, &x, &inv, 0.05, 0.5).unwrap();
        for (i, &v) in out.sort_idx.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
        for &c in &out.colsum {
            assert!((c - 1.0).abs() < 1e-3, "colsum {c}");
        }
        assert!(out.loss.is_finite());
    }

    #[test]
    fn gs_probe_is_approximately_doubly_stochastic() {
        let n = 8;
        let logits: Vec<f32> = pattern(64, 29).iter().map(|v| (v - 0.5) * 4.0).collect();
        let p = NativeBackend::new(1).gs_probe(n, &logits, 0.5).unwrap();
        for i in 0..n {
            let rs: f32 = p[i * n..(i + 1) * n].iter().sum();
            assert!((rs - 1.0).abs() < 1e-3, "row {i} sum {rs}");
        }
        for j in 0..n {
            let cs: f32 = (0..n).map(|i| p[i * n + j]).sum();
            assert!((cs - 1.0).abs() < 1e-3, "col {j} sum {cs}");
        }
    }

    #[test]
    fn kiss_rank_follows_the_kissing_number_table() {
        let be = NativeBackend::new(1);
        assert_eq!(be.kiss_rank(64, 3).unwrap(), 8);
        assert_eq!(be.kiss_rank(256, 3).unwrap(), 9);
        assert_eq!(be.kiss_rank(1024, 3).unwrap(), 13);
        assert_eq!(be.kiss_rank(4096, 3).unwrap(), 16);
        assert!(be.kiss_rank(100_000, 3).is_err());
    }

    #[test]
    fn shape_and_scalar_validation_errors_are_described() {
        let be = NativeBackend::new(1);
        let shape = StepShape::new(GridShape::new(4, 4), 3);
        let w = vec![0.0f32; 16];
        let x = vec![0.0f32; 16 * 3];
        let inv: Vec<i32> = (0..16).collect();
        assert!(be.sss_step(shape, &w[..8], &x, &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x[..10], &inv, 0.5, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.0, 0.5).is_err());
        assert!(be.sss_step(shape, &w, &x, &inv, 0.5, -1.0).is_err());
        let bad_inv = vec![99i32; 16];
        assert!(be.sss_step(shape, &w, &x, &bad_inv, 0.5, 0.5).is_err());
        // Bad shapes now fail at session creation.
        let bad_shape = StepShape { n: 16, d: 3, h: 4, w: 5 };
        assert!(be.session(bad_shape, None).is_err());
    }
}
