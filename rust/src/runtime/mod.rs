//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts`) and executes them from the L3 hot path.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6 → xla_extension 0.5.1 CPU):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. The interchange is HLO *text* — see
//! `python/compile/aot.py` for why serialized protos don't work.
//!
//! Design:
//! * `Manifest` / `ArtifactMeta` — parsed from `manifest.json` with the
//!   in-crate JSON parser; the runtime is fully manifest-driven (Rust never
//!   hard-codes shapes).
//! * `Runtime` — owns the client and a lazy compile cache keyed by artifact
//!   name (compiling an HLO module costs ~10–100 ms; every step reuses it).
//! * `Executable::run` — typed execute with shape checking against the
//!   manifest, returning decomposed output literals.

mod manifest;

pub use manifest::{ArtifactMeta, IoSpec, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

/// Input argument for an artifact call.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
}

/// Typed accessor error for artifact outputs: what was asked for vs what
/// the artifact actually produced, naming the artifact and output index so
/// a driver bug reads as "which artifact, which output, which type" instead
/// of a panic backtrace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OutputTypeError {
    Dtype {
        artifact: String,
        index: usize,
        expected: &'static str,
        actual: &'static str,
    },
    Shape {
        artifact: String,
        index: usize,
        expected_len: usize,
        actual_len: usize,
    },
}

impl std::fmt::Display for OutputTypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OutputTypeError::Dtype { artifact, index, expected, actual } => write!(
                f,
                "artifact '{artifact}': output #{index} is {actual}, expected {expected}"
            ),
            OutputTypeError::Shape { artifact, index, expected_len, actual_len } => write!(
                f,
                "artifact '{artifact}': output #{index} has {actual_len} elements, \
                 expected {expected_len}"
            ),
        }
    }
}

impl std::error::Error for OutputTypeError {}

/// Raw payload of one artifact output.
#[derive(Debug, Clone)]
pub enum OutData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// One output value from an artifact call, tagged with its provenance
/// (artifact name + output index) so dtype/shape mismatches produce
/// [`OutputTypeError`]s naming the artifact instead of panicking.
#[derive(Debug, Clone)]
pub struct OutValue {
    artifact: Rc<str>,
    index: usize,
    data: OutData,
}

impl OutValue {
    pub fn new(artifact: impl Into<Rc<str>>, index: usize, data: OutData) -> Self {
        OutValue { artifact: artifact.into(), index, data }
    }

    pub fn dtype(&self) -> &'static str {
        match self.data {
            OutData::F32(_) => "f32",
            OutData::I32(_) => "i32",
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            OutData::F32(v) => v.len(),
            OutData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype_error(&self, expected: &'static str) -> OutputTypeError {
        OutputTypeError::Dtype {
            artifact: self.artifact.to_string(),
            index: self.index,
            expected,
            actual: self.dtype(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32], OutputTypeError> {
        match &self.data {
            OutData::F32(v) => Ok(v),
            _ => Err(self.dtype_error("f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32], OutputTypeError> {
        match &self.data {
            OutData::I32(v) => Ok(v),
            _ => Err(self.dtype_error("i32")),
        }
    }

    /// The single f32 element of a scalar output (shape-checked).
    pub fn scalar_f32(&self) -> Result<f32, OutputTypeError> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(OutputTypeError::Shape {
                artifact: self.artifact.to_string(),
                index: self.index,
                expected_len: 1,
                actual_len: v.len(),
            });
        }
        Ok(v[0])
    }
}

/// A compiled artifact bound to its manifest metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host inputs; validates arity/shape/dtype against the
    /// manifest and returns one `OutValue` per manifest output.
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<OutValue>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.meta.inputs) {
            literals.push(to_literal(arg, spec).with_context(|| {
                format!("{}: input '{}'", self.meta.name, spec.name)
            })?);
        }
        let bufs = self.exe.execute::<xla::Literal>(&literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        decompose(result, &self.meta)
    }
}

fn to_literal(arg: &Arg<'_>, spec: &IoSpec) -> Result<xla::Literal> {
    let want: usize = spec.shape.iter().product::<usize>().max(1);
    match (arg, spec.dtype.as_str()) {
        (Arg::F32(v), "f32") => {
            if v.len() != want {
                bail!("length {} != expected {}", v.len(), want);
            }
            let lit = xla::Literal::vec1(v);
            if spec.shape.len() == 1 {
                Ok(lit)
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&s| s as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }
        (Arg::I32(v), "i32") => {
            if v.len() != want {
                bail!("length {} != expected {}", v.len(), want);
            }
            let lit = xla::Literal::vec1(v);
            if spec.shape.len() == 1 {
                Ok(lit)
            } else {
                let dims: Vec<i64> = spec.shape.iter().map(|&s| s as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }
        (Arg::ScalarF32(v), "f32") => {
            if !spec.shape.is_empty() {
                bail!("scalar passed for non-scalar input");
            }
            Ok(xla::Literal::scalar(*v))
        }
        _ => bail!("dtype mismatch (spec {})", spec.dtype),
    }
}

fn decompose(result: xla::Literal, meta: &ArtifactMeta) -> Result<Vec<OutValue>> {
    // aot.py lowers with return_tuple=True → always a tuple literal.
    let parts = result.to_tuple()?;
    if parts.len() != meta.outputs.len() {
        bail!(
            "{}: expected {} outputs, got {}",
            meta.name,
            meta.outputs.len(),
            parts.len()
        );
    }
    let artifact: Rc<str> = Rc::from(meta.name.as_str());
    let mut out = Vec::with_capacity(parts.len());
    for (index, (lit, spec)) in parts.into_iter().zip(&meta.outputs).enumerate() {
        let data = match spec.dtype.as_str() {
            "f32" => OutData::F32(lit.to_vec::<f32>()?),
            "i32" => OutData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported output dtype {other}"),
        };
        out.push(OutValue::new(artifact.clone(), index, data));
    }
    Ok(out)
}

/// The PJRT runtime: client + artifact registry + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Load the manifest from `dir` (usually "artifacts") and start a CPU
    /// PJRT client.
    pub fn from_manifest(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all available artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Load + compile an artifact by exact name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = Rc::new(Executable { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    /// Find the SoftSort/ShuffleSoftSort step artifact for (n, d, h).
    pub fn sss_step(&self, n: usize, d: usize, h: usize) -> Result<Rc<Executable>> {
        self.load(&format!("sss_step_n{n}_d{d}_h{h}"))
    }

    pub fn gs_step(&self, n: usize, d: usize, h: usize) -> Result<Rc<Executable>> {
        self.load(&format!("gs_step_n{n}_d{d}_h{h}"))
    }

    pub fn gs_probe(&self, n: usize) -> Result<Rc<Executable>> {
        self.load(&format!("gs_probe_n{n}"))
    }

    pub fn kiss_step(&self, n: usize, m: usize, d: usize) -> Result<Rc<Executable>> {
        self.load(&format!("kiss_step_n{n}_m{m}_d{d}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_value_accessors_return_typed_errors_naming_the_artifact() {
        let v = OutValue::new("sss_step_n64_d3_h8", 2, OutData::I32(vec![1, 2, 3]));
        assert_eq!(v.dtype(), "i32");
        assert_eq!(v.len(), 3);
        assert_eq!(v.as_i32().unwrap(), &[1, 2, 3]);
        let err = v.as_f32().unwrap_err();
        assert_eq!(
            err,
            OutputTypeError::Dtype {
                artifact: "sss_step_n64_d3_h8".into(),
                index: 2,
                expected: "f32",
                actual: "i32",
            }
        );
        let msg = err.to_string();
        assert!(msg.contains("sss_step_n64_d3_h8"), "{msg}");
        assert!(msg.contains("output #2"), "{msg}");
    }

    #[test]
    fn scalar_accessor_shape_checks() {
        let ok = OutValue::new("gs_probe_n64", 0, OutData::F32(vec![0.25]));
        assert_eq!(ok.scalar_f32().unwrap(), 0.25);
        let bad = OutValue::new("gs_probe_n64", 0, OutData::F32(vec![1.0, 2.0]));
        let err = bad.scalar_f32().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("gs_probe_n64") && msg.contains("2 elements"), "{msg}");
        let wrong = OutValue::new("gs_probe_n64", 0, OutData::I32(vec![1]));
        assert!(wrong.scalar_f32().is_err());
    }
}
