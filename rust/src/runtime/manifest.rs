//! Artifact manifest: typed view of `artifacts/manifest.json`.

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One input/output tensor description.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    /// Empty for scalars.
    pub shape: Vec<usize>,
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub method: String,
    pub file: String,
    pub n: usize,
    pub d: usize,
    pub h: usize,
    pub w: usize,
    pub m: usize,
    pub block: usize,
    pub param_count: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub jax_version: String,
    pub artifacts: Vec<ArtifactMeta>,
}

fn io_list(j: &Json, key: &str) -> Result<Vec<IoSpec>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest entry missing '{key}'"))?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("io missing name"))?
                    .to_string(),
                dtype: e
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("io missing dtype"))?
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("io missing shape"))?
                    .iter()
                    .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| anyhow!("missing '{key}'"))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = req_usize(&j, "version")?;
        let jax_version = j
            .get("jax_version")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let artifacts = arts
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    method: a
                        .get("method")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact missing file"))?
                        .to_string(),
                    n: req_usize(a, "n")?,
                    d: req_usize(a, "d")?,
                    h: req_usize(a, "h")?,
                    w: req_usize(a, "w")?,
                    m: req_usize(a, "m")?,
                    block: req_usize(a, "block")?,
                    param_count: req_usize(a, "param_count")?,
                    inputs: io_list(a, "inputs")?,
                    outputs: io_list(a, "outputs")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version, jax_version, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax_version": "0.8.2", "interchange": "hlo-text",
      "artifacts": [{
        "name": "sss_step_n64_d3_h8", "method": "sss", "file": "sss_step_n64_d3_h8.hlo.txt",
        "n": 64, "d": 3, "h": 8, "w": 8, "m": 0, "block": 32, "param_count": 64,
        "inputs": [
          {"name": "w", "dtype": "f32", "shape": [64]},
          {"name": "tau", "dtype": "f32", "shape": []}
        ],
        "outputs": [{"name": "loss", "dtype": "f32", "shape": []}]
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let a = m.find("sss_step_n64_d3_h8").unwrap();
        assert_eq!(a.n, 64);
        assert_eq!(a.inputs[0].shape, vec![64]);
        assert!(a.inputs[1].shape.is_empty());
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"version": 1, "artifacts": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.len() >= 6);
            let a = m.find("sss_step_n1024_d3_h32").expect("headline artifact");
            assert_eq!(a.param_count, 1024);
            assert_eq!(a.inputs.len(), 5);
            assert_eq!(a.outputs.len(), 5);
        }
    }
}
