//! The [`Engine`] session: resolves a compute backend per
//! [`BackendChoice`] (`auto` / `native` / `pjrt`), owns the backend
//! instances (lazily constructed), and fans [`Engine::sort_batch`]
//! requests out across `std::thread` workers.
//!
//! Backend selection happens in one place — here — and is exposed to users
//! three ways: the `EngineBuilder::backend` setter, the CLI `--backend`
//! flag, and a `backend=native|pjrt|auto` override pair (peeled off before
//! the remaining pairs reach the config builders, so it composes with any
//! method). `auto` prefers the AOT artifacts when `manifest.json` is
//! present and the crate was built with the `pjrt` feature, and falls back
//! to the pure-Rust [`NativeBackend`] otherwise — a bare checkout with no
//! artifacts can run every learned method. Session worker-pool sizing is
//! analogous: `EngineBuilder::threads` / `--threads` sets a default that
//! per-call `threads=` config pairs override; and
//! [`Engine::step_session`] memoizes `(n, d, h)` step sessions next to
//! the executable cache for callers driving raw steps.
//!
//! Determinism: every sort is a pure function of (method, overrides,
//! dataset, grid) — batched results are bit-identical to sequential ones.
//! On the native backend all workers *share one* `Send + Sync` backend
//! (its chunk reduction is thread-count-invariant); on PJRT each worker
//! builds its own runtime (the compile cache is `Rc`/`RefCell`). Enforced
//! by `rust/tests/api.rs`.

use std::cell::{OnceCell, RefCell, RefMut};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::{anyhow, ensure, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::backend::{
    BackendChoice, NativeBackend, SessionOpts, SimdChoice, StepBackend, StepSession, StepShape,
};
#[cfg(feature = "pjrt")]
use crate::backend::PjrtBackend;
use crate::coordinator::SortOutcome;
use crate::data::Dataset;
use crate::grid::GridShape;
use crate::trace;
#[cfg(feature = "pjrt")]
use crate::runtime::{Executable, Runtime};

use super::registry::{MethodKind, MethodRegistry};
use super::sorter::Sorter;

/// The backend kind a [`BackendChoice`] resolved to for this session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Resolved {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt,
}

/// A memoized step session, kept per backend kind so the native-only
/// build stores `dyn StepSession + Send` boxes — keeping `Engine: Send`
/// on `--no-default-features` exactly as before this cache existed (the
/// pjrt variant is `!Send` anyway via its `Rc` caches).
enum CachedSession {
    Native(Box<dyn StepSession + Send>),
    #[cfg(feature = "pjrt")]
    Pjrt(Box<dyn StepSession>),
}

impl CachedSession {
    fn as_step_session(&mut self) -> &mut dyn StepSession {
        match self {
            CachedSession::Native(s) => s.as_mut(),
            #[cfg(feature = "pjrt")]
            CachedSession::Pjrt(s) => s.as_mut(),
        }
    }
}

/// Split the `backend=...` pair (if any) off an override list. Last one
/// wins, mirroring the config builders' override semantics. The remaining
/// pairs (including any `threads=`, which IS a config key) pass through to
/// the config builders untouched.
fn split_backend_override(
    default: BackendChoice,
    overrides: &[(String, String)],
) -> Result<(BackendChoice, Vec<(String, String)>)> {
    let mut choice = default;
    let mut rest = Vec::with_capacity(overrides.len());
    for (k, v) in overrides {
        if k == "backend" {
            choice = BackendChoice::parse(v)?;
        } else {
            rest.push((k.clone(), v.clone()));
        }
    }
    Ok((choice, rest))
}

/// A sorting session bound to an artifacts directory and a backend choice.
pub struct Engine {
    artifacts_dir: PathBuf,
    registry: MethodRegistry,
    choice: BackendChoice,
    /// Lazily constructed; shared by all batch workers (`Send + Sync`).
    native: OnceCell<NativeBackend>,
    /// Lazily constructed so heuristic-only and native-only sessions never
    /// require artifacts.
    #[cfg(feature = "pjrt")]
    pjrt: OnceCell<PjrtBackend>,
    /// `(n, d, h)` → compiled step executable, for callers that drive step
    /// executables directly (serving experiments, micro-benches). The
    /// runtime's own cache is keyed by artifact *name*; this front cache
    /// additionally skips the name formatting + string hashing per lookup.
    /// The driver-based `sort`/`sort_batch` paths resolve executables
    /// through the backend instead.
    #[cfg(feature = "pjrt")]
    step_cache: RefCell<HashMap<(usize, usize, usize), Rc<Executable>>>,
    /// `(n, d, h)` → live step session on the session's default backend,
    /// memoized alongside the executable cache for callers that drive
    /// steps directly (serving experiments, micro-benches): repeated calls
    /// hit warm scratch buffers and, natively, a warm worker pool.
    sessions: RefCell<HashMap<(usize, usize, usize), CachedSession>>,
    /// Default session pool size for learned methods (`--threads`). For
    /// single sorts it is injected as a leading `threads=` override (so
    /// per-call pairs win); for `sort_batch` it is the *total* row-thread
    /// budget divided across workers.
    threads: Option<usize>,
    /// Default step-kernel level for learned methods (`--simd`). Injected
    /// as a leading `simd=` override for sorts (per-call pairs win) and
    /// passed to memoized step sessions directly.
    simd: SimdChoice,
    workers: usize,
}

impl Engine {
    /// Eagerly load the artifacts at `dir` (errors early if missing) and
    /// pin the session to the PJRT backend.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Engine> {
        let engine = Engine::builder(dir).backend(BackendChoice::Pjrt).build();
        engine.pjrt_backend()?;
        Ok(engine)
    }

    pub fn builder(dir: impl AsRef<Path>) -> EngineBuilder {
        EngineBuilder {
            artifacts_dir: dir.as_ref().to_path_buf(),
            backend: None,
            threads: None,
            simd: None,
            workers: None,
            registry: None,
        }
    }

    /// The session knobs memoized step sessions are opened with.
    fn session_opts(&self) -> SessionOpts {
        SessionOpts { threads: self.threads, simd: self.simd }
    }

    pub fn registry(&self) -> &MethodRegistry {
        &self.registry
    }

    /// Number of worker threads `sort_batch` may use.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The session's default backend choice (overridable per call with a
    /// `backend=...` pair).
    pub fn backend_choice(&self) -> BackendChoice {
        self.choice
    }

    /// The shared pure-Rust backend (constructed on first use).
    pub fn native_backend(&self) -> &NativeBackend {
        self.native.get_or_init(NativeBackend::default)
    }

    /// The PJRT backend, loading the artifact manifest on first use.
    #[cfg(feature = "pjrt")]
    pub fn pjrt_backend(&self) -> Result<&PjrtBackend> {
        if self.pjrt.get().is_none() {
            let backend =
                PjrtBackend::from_artifacts(&self.artifacts_dir).with_context(|| {
                    format!("loading artifacts from {}", self.artifacts_dir.display())
                })?;
            // A concurrent set is impossible (Engine is not Sync); ignore
            // the Err(value) that would signal one.
            let _ = self.pjrt.set(backend);
        }
        Ok(self.pjrt.get().expect("backend initialized above"))
    }

    /// The session runtime (PJRT backend's), loading artifacts on first use.
    #[cfg(feature = "pjrt")]
    pub fn runtime(&self) -> Result<&Runtime> {
        Ok(self.pjrt_backend()?.runtime())
    }

    /// Memoized `(n, d, h)` lookup of the ShuffleSoftSort/SoftSort step
    /// executable.
    #[cfg(feature = "pjrt")]
    pub fn sss_step(&self, n: usize, d: usize, h: usize) -> Result<Rc<Executable>> {
        if let Some(exe) = self.step_cache.borrow().get(&(n, d, h)) {
            return Ok(exe.clone());
        }
        let exe = self.runtime()?.sss_step(n, d, h)?;
        self.step_cache.borrow_mut().insert((n, d, h), exe.clone());
        Ok(exe)
    }

    /// Memoized per-`(n, d, h)` step session on the session's default
    /// backend choice. The returned guard holds the cache borrow: one
    /// live session borrow at a time (sessions are single-consumer).
    ///
    /// This is the serving-style entry point: `sort`/`sort_batch` open
    /// their own per-run sessions internally; use this when driving raw
    /// steps in a loop (micro-benches, step servers) so repeated calls on
    /// one shape reuse scratch and the native worker pool.
    pub fn step_session(
        &self,
        n: usize,
        d: usize,
        h: usize,
    ) -> Result<RefMut<'_, dyn StepSession>> {
        let key = (n, d, h);
        if !self.sessions.borrow().contains_key(&key) {
            ensure!(h > 0 && n % h == 0, "grid height {h} does not divide N={n}");
            let shape = StepShape { n, d, h, w: n / h };
            let session = match self.resolve_choice(self.choice)? {
                Resolved::Native => CachedSession::Native(
                    self.native_backend().session_send(shape, self.session_opts())?,
                ),
                #[cfg(feature = "pjrt")]
                Resolved::Pjrt => CachedSession::Pjrt(
                    self.pjrt_backend()?.session(shape, self.session_opts())?,
                ),
            };
            self.sessions.borrow_mut().insert(key, session);
        }
        Ok(RefMut::map(self.sessions.borrow_mut(), |m| {
            m.get_mut(&key).expect("inserted above").as_step_session()
        }))
    }

    /// Number of `(n, d, h)` step sessions currently memoized — the serve
    /// layer's per-shard warmth gauge (hashed job affinity exists to keep
    /// this cache hot on each shard's home shapes).
    pub fn session_memo_entries(&self) -> usize {
        self.sessions.borrow().len()
    }

    /// Prepend the engine-level `--threads` default for learned methods
    /// (explicit `threads=` override pairs still win: last-wins).
    fn with_default_threads(
        &self,
        kind: MethodKind,
        rest: Vec<(String, String)>,
    ) -> Vec<(String, String)> {
        match self.threads {
            Some(t) if kind == MethodKind::Learned => {
                let mut out = Vec::with_capacity(rest.len() + 1);
                out.push(("threads".to_string(), t.to_string()));
                out.extend(rest);
                out
            }
            _ => rest,
        }
    }

    /// Prepend the engine-level `--simd` default for learned methods
    /// (explicit `simd=` override pairs still win: last-wins). Unlike the
    /// threads default this applies to batches too — the SIMD level is a
    /// per-session knob, not a shared budget.
    fn with_default_simd(
        &self,
        kind: MethodKind,
        rest: Vec<(String, String)>,
    ) -> Vec<(String, String)> {
        match self.simd {
            choice if choice != SimdChoice::Auto && kind == MethodKind::Learned => {
                let mut out = Vec::with_capacity(rest.len() + 1);
                out.push(("simd".to_string(), choice.name().to_string()));
                out.extend(rest);
                out
            }
            _ => rest,
        }
    }

    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn artifacts_present(&self) -> bool {
        self.artifacts_dir.join("manifest.json").exists()
    }

    fn resolve_choice(&self, choice: BackendChoice) -> Result<Resolved> {
        match choice {
            BackendChoice::Native => Ok(Resolved::Native),
            BackendChoice::Pjrt => {
                #[cfg(feature = "pjrt")]
                return Ok(Resolved::Pjrt);
                #[cfg(not(feature = "pjrt"))]
                return Err(anyhow!(
                    "this build has no PJRT support (compiled without the 'pjrt' \
                     feature) — use the native backend"
                ));
            }
            BackendChoice::Auto => {
                #[cfg(feature = "pjrt")]
                if self.artifacts_present() {
                    return Ok(Resolved::Pjrt);
                }
                Ok(Resolved::Native)
            }
        }
    }

    fn backend_for(&self, choice: BackendChoice) -> Result<&dyn StepBackend> {
        match self.resolve_choice(choice)? {
            Resolved::Native => Ok(self.native_backend() as &dyn StepBackend),
            #[cfg(feature = "pjrt")]
            Resolved::Pjrt => Ok(self.pjrt_backend()? as &dyn StepBackend),
        }
    }

    /// Human-readable description of the backend the given overrides would
    /// resolve to (e.g. `native (pure Rust, 8 threads)` or `pjrt (Host)`).
    pub fn backend_desc(&self, overrides: &[(String, String)]) -> Result<String> {
        let (choice, _) = split_backend_override(self.choice, overrides)?;
        match self.resolve_choice(choice)? {
            Resolved::Native => Ok(format!(
                "native (pure Rust, {} threads)",
                self.native_backend().threads()
            )),
            #[cfg(feature = "pjrt")]
            Resolved::Pjrt => {
                Ok(format!("pjrt ({})", self.pjrt_backend()?.runtime().platform()))
            }
        }
    }

    /// Build a sorter by registry name; a compute backend is resolved and
    /// attached only for learned methods. A `backend=...` override pair
    /// selects the backend per call.
    pub fn sorter(
        &self,
        method: &str,
        overrides: &[(String, String)],
    ) -> Result<Box<dyn Sorter + '_>> {
        let spec = self.registry.resolve_or_err(method)?;
        let (choice, rest) = split_backend_override(self.choice, overrides)?;
        let rest = self.with_default_threads(spec.kind, rest);
        let rest = self.with_default_simd(spec.kind, rest);
        let backend: Option<&dyn StepBackend> = match spec.kind {
            MethodKind::Learned => Some(self.backend_for(choice)?),
            MethodKind::Heuristic => None,
        };
        self.registry.build(spec.name, backend, &rest)
    }

    /// Sort one dataset with the named method.
    pub fn sort(
        &self,
        method: &str,
        data: &Dataset,
        g: GridShape,
        overrides: &[(String, String)],
    ) -> Result<SortOutcome> {
        self.sorter(method, overrides)?.sort(data, g)
    }

    /// Sort many datasets with the named method, across up to
    /// `self.workers()` threads. Results are positionally aligned with the
    /// input and bit-identical to sequential `sort` calls: per-item state
    /// is never shared — every run opens its own `StepSession` over the
    /// shared backend (native: one `Send + Sync` instance, per-worker
    /// sessions with pool-size-invariant reductions; PJRT: one runtime
    /// per worker).
    pub fn sort_batch(
        &self,
        method: &str,
        datasets: &[Dataset],
        g: GridShape,
        overrides: &[(String, String)],
    ) -> Vec<Result<SortOutcome>> {
        let m = datasets.len();
        if m == 0 {
            return Vec::new();
        }
        let all_err = |e: anyhow::Error| -> Vec<Result<SortOutcome>> {
            let msg = format!("{e:#}");
            (0..m).map(|_| Err(anyhow!("{msg}"))).collect()
        };
        let workers = self.workers.clamp(1, m);
        if workers == 1 {
            return match self.sorter(method, overrides) {
                Ok(sorter) => datasets.iter().map(|ds| sorter.sort(ds, g)).collect(),
                Err(e) => all_err(e),
            };
        }

        /// How each batch worker obtains its compute backend.
        #[derive(Clone, Copy)]
        enum BatchBackend<'e> {
            /// Pure-Rust methods: no backend at all.
            Heuristic,
            /// One `Send + Sync` native backend shared by every worker.
            Native(&'e NativeBackend),
            /// Each worker loads its own runtime (`Rc`/`RefCell` caches).
            #[cfg(feature = "pjrt")]
            PerWorkerPjrt,
        }

        let spec = match self.registry.resolve_or_err(method) {
            Ok(spec) => spec,
            Err(e) => return all_err(e),
        };
        // NOTE: the engine-level threads default is deliberately NOT
        // injected here — in a batch it acts as the *total* row-thread
        // budget divided across workers (below), not a per-run pool size;
        // an explicit per-call `threads=` pair still overrides the cap.
        let (choice, rest) = match split_backend_override(self.choice, overrides) {
            Ok(split) => split,
            Err(e) => return all_err(e),
        };
        let rest = self.with_default_simd(spec.kind, rest);
        // Shared native backend for this batch, with row-parallelism capped
        // so workers × row-threads ≈ machine parallelism instead of
        // workers² (results are unaffected: the chunk reduction is
        // thread-count-invariant by construction).
        let capped_native: NativeBackend;
        let batch_backend = match spec.kind {
            MethodKind::Heuristic => BatchBackend::Heuristic,
            MethodKind::Learned => match self.resolve_choice(choice) {
                Ok(Resolved::Native) => {
                    let total =
                        self.threads.unwrap_or_else(|| self.native_backend().threads());
                    capped_native = NativeBackend::new((total / workers).max(1));
                    BatchBackend::Native(&capped_native)
                }
                #[cfg(feature = "pjrt")]
                Ok(Resolved::Pjrt) => BatchBackend::PerWorkerPjrt,
                Err(e) => return all_err(e),
            },
        };

        let registry = self.registry;
        let dir = &self.artifacts_dir;
        let rest = &rest;
        // Trace context crosses the thread boundary by value: each worker
        // re-parents per-item spans under the caller's current span.
        let batch_ctx = trace::current();
        let mut out: Vec<Option<Result<SortOutcome>>> = (0..m).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for wk in 0..workers {
                handles.push(scope.spawn(move || {
                    let idxs: Vec<usize> = (wk..m).step_by(workers).collect();
                    let fail = |e: anyhow::Error, idxs: Vec<usize>| {
                        let msg = format!("{e:#}");
                        idxs.into_iter()
                            .map(|i| (i, Err(anyhow!("{msg}"))))
                            .collect::<Vec<_>>()
                    };
                    // Worker-owned PJRT backend, when that path is active
                    // (must outlive the sorter borrowing it).
                    #[cfg(feature = "pjrt")]
                    let worker_pjrt: Option<PjrtBackend> = match batch_backend {
                        BatchBackend::PerWorkerPjrt => {
                            match PjrtBackend::from_artifacts(dir) {
                                Ok(backend) => Some(backend),
                                Err(e) => return fail(e, idxs),
                            }
                        }
                        _ => None,
                    };
                    #[cfg(not(feature = "pjrt"))]
                    let _ = dir;
                    let backend: Option<&dyn StepBackend> = match batch_backend {
                        BatchBackend::Heuristic => None,
                        BatchBackend::Native(shared) => Some(shared),
                        #[cfg(feature = "pjrt")]
                        BatchBackend::PerWorkerPjrt => Some(
                            worker_pjrt.as_ref().expect("constructed above"),
                        ),
                    };
                    let sorter = match registry.build(spec.name, backend, rest) {
                        Ok(sorter) => sorter,
                        Err(e) => return fail(e, idxs),
                    };
                    idxs.into_iter()
                        .map(|i| {
                            let mut span = trace::Span::child_of(batch_ctx, "batch_item");
                            span.attr_u64("item", i as u64);
                            let _cur = span.make_current();
                            (i, sorter.sort(&datasets[i], g))
                        })
                        .collect::<Vec<_>>()
                }));
            }
            for handle in handles {
                for (i, result) in handle.join().expect("sort_batch worker panicked") {
                    out[i] = Some(result);
                }
            }
        });

        out.into_iter()
            .map(|slot| slot.expect("every batch index is assigned to exactly one worker"))
            .collect()
    }
}

/// Builder for [`Engine`] sessions.
pub struct EngineBuilder {
    artifacts_dir: PathBuf,
    backend: Option<BackendChoice>,
    threads: Option<usize>,
    simd: Option<SimdChoice>,
    workers: Option<usize>,
    registry: Option<MethodRegistry>,
}

impl EngineBuilder {
    /// Default backend choice for the session (default: `auto`).
    pub fn backend(mut self, choice: BackendChoice) -> Self {
        self.backend = Some(choice);
        self
    }

    /// Method registry for the session (default: the built-in set). Pass
    /// `MethodRegistry::with_methods(..)` to serve plugin methods through
    /// this engine — `sort`, `sort_batch` and `registry()` (and therefore
    /// the serve layer's `GET /v1/methods`) all reflect it.
    pub fn registry(mut self, registry: MethodRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Default step-session worker-pool size for learned methods (the
    /// `--threads` CLI flag; 0 keeps the backend default). Per-call
    /// `threads=` override pairs still win; in `sort_batch` the value is
    /// the total row-thread budget divided across batch workers.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = crate::config::normalize_threads(threads);
        self
    }

    /// Default step-kernel level for learned methods (the `--simd` CLI
    /// flag; `Auto` = runtime detection). Per-call `simd=` override pairs
    /// still win.
    pub fn simd(mut self, simd: SimdChoice) -> Self {
        self.simd = Some(simd);
        self
    }

    /// Cap the number of `sort_batch` worker threads (default: the
    /// machine's available parallelism).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    pub fn build(self) -> Engine {
        let workers = self.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        Engine {
            artifacts_dir: self.artifacts_dir,
            registry: self.registry.unwrap_or_default(),
            choice: self.backend.unwrap_or_default(),
            native: OnceCell::new(),
            #[cfg(feature = "pjrt")]
            pjrt: OnceCell::new(),
            #[cfg(feature = "pjrt")]
            step_cache: RefCell::new(HashMap::new()),
            sessions: RefCell::new(HashMap::new()),
            threads: self.threads,
            simd: self.simd.unwrap_or_default(),
            workers,
        }
    }
}
