//! The [`Engine`] session: owns the PJRT [`Runtime`] (lazily loaded),
//! memoizes `Executable` lookups per `(n, d, h)`, and fans
//! [`Engine::sort_batch`] requests out across `std::thread` workers.
//!
//! Determinism: every sort is a pure function of (method, overrides,
//! dataset, grid) — each batch worker runs its own runtime + sorter, so
//! batched results are bit-identical to sequential ones. Enforced by
//! `rust/tests/api.rs`.

use std::cell::{OnceCell, RefCell};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::SortOutcome;
use crate::data::Dataset;
use crate::grid::GridShape;
use crate::runtime::{Executable, Runtime};

use super::registry::{MethodKind, MethodRegistry};
use super::sorter::Sorter;

/// A sorting session bound to an artifacts directory.
pub struct Engine {
    artifacts_dir: PathBuf,
    registry: MethodRegistry,
    /// Lazily constructed so heuristic-only sessions never require
    /// artifacts (`sssort sort --method flas` works without `make
    /// artifacts`).
    rt: OnceCell<Runtime>,
    /// `(n, d, h)` → compiled step executable, for callers that drive step
    /// executables directly (serving experiments, micro-benches). The
    /// runtime's own cache is keyed by artifact *name*; this front cache
    /// additionally skips the name formatting + string hashing per lookup.
    /// The driver-based `sort`/`sort_batch` paths resolve executables
    /// through the runtime instead.
    step_cache: RefCell<HashMap<(usize, usize, usize), Rc<Executable>>>,
    workers: usize,
}

impl Engine {
    /// Eagerly load the artifacts at `dir` (errors early if missing).
    pub fn from_artifacts(dir: impl AsRef<Path>) -> Result<Engine> {
        let engine = Engine::builder(dir).build();
        engine.runtime()?;
        Ok(engine)
    }

    pub fn builder(dir: impl AsRef<Path>) -> EngineBuilder {
        EngineBuilder {
            artifacts_dir: dir.as_ref().to_path_buf(),
            workers: None,
        }
    }

    pub fn registry(&self) -> &MethodRegistry {
        &self.registry
    }

    /// Number of worker threads `sort_batch` may use.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The session runtime, loading the artifact manifest on first use.
    pub fn runtime(&self) -> Result<&Runtime> {
        if self.rt.get().is_none() {
            let rt = Runtime::from_manifest(&self.artifacts_dir).with_context(|| {
                format!("loading artifacts from {}", self.artifacts_dir.display())
            })?;
            // A concurrent set is impossible (Engine is not Sync); ignore
            // the Err(value) that would signal one.
            let _ = self.rt.set(rt);
        }
        Ok(self.rt.get().expect("runtime initialized above"))
    }

    /// Memoized `(n, d, h)` lookup of the ShuffleSoftSort/SoftSort step
    /// executable.
    pub fn sss_step(&self, n: usize, d: usize, h: usize) -> Result<Rc<Executable>> {
        if let Some(exe) = self.step_cache.borrow().get(&(n, d, h)) {
            return Ok(exe.clone());
        }
        let exe = self.runtime()?.sss_step(n, d, h)?;
        self.step_cache.borrow_mut().insert((n, d, h), exe.clone());
        Ok(exe)
    }

    /// Build a sorter by registry name; the runtime is attached only for
    /// learned methods.
    pub fn sorter(
        &self,
        method: &str,
        overrides: &[(String, String)],
    ) -> Result<Box<dyn Sorter + '_>> {
        let spec = self.registry.resolve_or_err(method)?;
        let rt = match spec.kind {
            MethodKind::Learned => Some(self.runtime()?),
            MethodKind::Heuristic => None,
        };
        self.registry.build(spec.name, rt, overrides)
    }

    /// Sort one dataset with the named method.
    pub fn sort(
        &self,
        method: &str,
        data: &Dataset,
        g: GridShape,
        overrides: &[(String, String)],
    ) -> Result<SortOutcome> {
        self.sorter(method, overrides)?.sort(data, g)
    }

    /// Sort many datasets with the named method, across up to
    /// `self.workers()` threads. Results are positionally aligned with the
    /// input and bit-identical to sequential `sort` calls (each worker
    /// builds its own runtime + sorter; per-item state is never shared).
    pub fn sort_batch(
        &self,
        method: &str,
        datasets: &[Dataset],
        g: GridShape,
        overrides: &[(String, String)],
    ) -> Vec<Result<SortOutcome>> {
        let m = datasets.len();
        if m == 0 {
            return Vec::new();
        }
        let workers = self.workers.clamp(1, m);
        if workers == 1 {
            return match self.sorter(method, overrides) {
                Ok(sorter) => datasets.iter().map(|ds| sorter.sort(ds, g)).collect(),
                Err(e) => {
                    let msg = format!("{e:#}");
                    (0..m).map(|_| Err(anyhow!("{msg}"))).collect()
                }
            };
        }

        let needs_rt = matches!(
            self.registry.resolve(method).map(|s| s.kind),
            Some(MethodKind::Learned)
        );
        let registry = self.registry;
        let dir = self.artifacts_dir.clone();
        let mut out: Vec<Option<Result<SortOutcome>>> = (0..m).map(|_| None).collect();

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for wk in 0..workers {
                let dir = dir.clone();
                handles.push(scope.spawn(move || {
                    let idxs: Vec<usize> = (wk..m).step_by(workers).collect();
                    // Each worker owns an independent runtime: `Runtime` is
                    // single-threaded (Rc/RefCell caches), and per-worker
                    // compile caches keep workers fully isolated.
                    let rt = if needs_rt {
                        match Runtime::from_manifest(&dir) {
                            Ok(rt) => Some(rt),
                            Err(e) => {
                                let msg = format!("{e:#}");
                                return idxs
                                    .into_iter()
                                    .map(|i| (i, Err(anyhow!("{msg}"))))
                                    .collect::<Vec<_>>();
                            }
                        }
                    } else {
                        None
                    };
                    let sorter = match registry.build(method, rt.as_ref(), overrides) {
                        Ok(sorter) => sorter,
                        Err(e) => {
                            let msg = format!("{e:#}");
                            return idxs
                                .into_iter()
                                .map(|i| (i, Err(anyhow!("{msg}"))))
                                .collect::<Vec<_>>();
                        }
                    };
                    idxs.into_iter()
                        .map(|i| (i, sorter.sort(&datasets[i], g)))
                        .collect::<Vec<_>>()
                }));
            }
            for handle in handles {
                for (i, result) in handle.join().expect("sort_batch worker panicked") {
                    out[i] = Some(result);
                }
            }
        });

        out.into_iter()
            .map(|slot| slot.expect("every batch index is assigned to exactly one worker"))
            .collect()
    }
}

/// Builder for [`Engine`] sessions.
pub struct EngineBuilder {
    artifacts_dir: PathBuf,
    workers: Option<usize>,
}

impl EngineBuilder {
    /// Cap the number of `sort_batch` worker threads (default: the
    /// machine's available parallelism).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    pub fn build(self) -> Engine {
        let workers = self.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        Engine {
            artifacts_dir: self.artifacts_dir,
            registry: MethodRegistry::new(),
            rt: OnceCell::new(),
            step_cache: RefCell::new(HashMap::new()),
            workers,
        }
    }
}
