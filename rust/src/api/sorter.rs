//! The [`Sorter`] trait and its adapters.
//!
//! `Sorter` is grid-late-bound: the target grid is a *call* argument, not a
//! construction argument, so one boxed sorter can serve many shapes. The
//! learned drivers carry a grid inside their config; their trait impls
//! therefore check that the requested grid matches the configured one,
//! while the registry-built [`LearnedSorter`] derives a fresh config (grid
//! defaults + stored `k=v` overrides) per call.

use anyhow::{ensure, Result};

use crate::backend::StepBackend;
use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
use crate::coordinator::baselines::{GumbelSinkhornDriver, KissingDriver, SoftSortDriver};
use crate::coordinator::events::RunReport;
use crate::coordinator::{ShuffleSoftSort, SortOutcome};
use crate::data::Dataset;
use crate::grid::GridShape;
use crate::heuristics::GridSorter;
use crate::metrics::dpq16;
use crate::util::timer::Stopwatch;

/// A method that sorts a dataset onto a grid. Every learned driver and
/// every heuristic adapter returns the same [`SortOutcome`] shape
/// (permutation + arranged rows + `RunReport`), so callers treat methods
/// uniformly.
pub trait Sorter {
    /// Canonical registry name of the method (e.g. `"shuffle-softsort"`).
    fn name(&self) -> &str;

    /// Sort `data` onto grid `g`.
    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome>;
}

fn ensure_grid(configured: GridShape, asked: GridShape, method: &str) -> Result<()> {
    ensure!(
        configured == asked,
        "{method} driver is configured for {}x{} but was asked to sort onto {}x{} \
         (build via the registry/Engine for grid-late binding)",
        configured.h,
        configured.w,
        asked.h,
        asked.w
    );
    Ok(())
}

impl Sorter for ShuffleSoftSort<'_> {
    fn name(&self) -> &str {
        "shuffle-softsort"
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure_grid(self.config().grid, g, Sorter::name(self))?;
        ShuffleSoftSort::sort(self, data)
    }
}

impl Sorter for SoftSortDriver<'_> {
    fn name(&self) -> &str {
        "softsort"
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure_grid(self.cfg.grid, g, Sorter::name(self))?;
        SoftSortDriver::sort(self, data)
    }
}

impl Sorter for GumbelSinkhornDriver<'_> {
    fn name(&self) -> &str {
        "gumbel-sinkhorn"
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure_grid(self.cfg.grid, g, Sorter::name(self))?;
        GumbelSinkhornDriver::sort(self, data)
    }
}

impl Sorter for KissingDriver<'_> {
    fn name(&self) -> &str {
        "kissing"
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure_grid(self.cfg.grid, g, Sorter::name(self))?;
        KissingDriver::sort(self, data)
    }
}

/// Which learned driver a registry-built [`LearnedSorter`] dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LearnedKind {
    ShuffleSoftSort,
    SoftSort,
    GumbelSinkhorn,
    Kissing,
}

impl LearnedKind {
    pub fn name(&self) -> &'static str {
        match self {
            LearnedKind::ShuffleSoftSort => "shuffle-softsort",
            LearnedKind::SoftSort => "softsort",
            LearnedKind::GumbelSinkhorn => "gumbel-sinkhorn",
            LearnedKind::Kissing => "kissing",
        }
    }
}

/// Registry-built adapter over the learned drivers: holds the compute
/// backend and the raw `k=v` overrides, and derives the concrete config
/// from the grid at sort time (grid-scaled defaults, then overrides,
/// last-wins).
pub struct LearnedSorter<'b> {
    kind: LearnedKind,
    backend: &'b dyn StepBackend,
    overrides: Vec<(String, String)>,
}

impl<'b> LearnedSorter<'b> {
    pub fn new(
        kind: LearnedKind,
        backend: &'b dyn StepBackend,
        overrides: Vec<(String, String)>,
    ) -> Self {
        LearnedSorter { kind, backend, overrides }
    }

    /// The backend this sorter executes on.
    pub fn backend(&self) -> &'b dyn StepBackend {
        self.backend
    }

    fn sss_config(&self, g: GridShape) -> Result<ShuffleSoftSortConfig> {
        ShuffleSoftSortConfig::builder()
            .grid(g.h, g.w)
            .overrides(self.overrides.iter().cloned())
            .build()
    }

    fn baseline_config(&self, g: GridShape) -> Result<BaselineConfig> {
        let mut b = BaselineConfig::builder().grid(g.h, g.w);
        if self.kind == LearnedKind::GumbelSinkhorn {
            b = b.gs_defaults();
        }
        b.overrides(self.overrides.iter().cloned()).build()
    }
}

impl Sorter for LearnedSorter<'_> {
    fn name(&self) -> &str {
        self.kind.name()
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure!(
            data.n == g.n(),
            "dataset N={} != grid {}x{}",
            data.n,
            g.h,
            g.w
        );
        match self.kind {
            LearnedKind::ShuffleSoftSort => {
                ShuffleSoftSort::new(self.backend, self.sss_config(g)?)?.sort(data)
            }
            LearnedKind::SoftSort => {
                SoftSortDriver::new(self.backend, self.baseline_config(g)?).sort(data)
            }
            LearnedKind::GumbelSinkhorn => {
                GumbelSinkhornDriver::new(self.backend, self.baseline_config(g)?).sort(data)
            }
            LearnedKind::Kissing => {
                KissingDriver::new(self.backend, self.baseline_config(g)?).sort(data)
            }
        }
    }
}

/// Adapter lifting a [`GridSorter`] heuristic into the unified [`Sorter`]
/// interface. Heuristic runs thereby produce the same `RunReport` as the
/// learned methods: section timings ("sort", "arrange", "dpq"), wall time
/// and the final DPQ16.
pub struct HeuristicSorter {
    name: &'static str,
    seed: u64,
    inner: Box<dyn GridSorter>,
}

impl HeuristicSorter {
    pub fn new(name: &'static str, inner: Box<dyn GridSorter>, seed: u64) -> Self {
        HeuristicSorter { name, seed, inner }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl Sorter for HeuristicSorter {
    fn name(&self) -> &str {
        self.name
    }

    fn sort(&self, data: &Dataset, g: GridShape) -> Result<SortOutcome> {
        ensure!(
            data.n == g.n(),
            "dataset N={} != grid {}x{}",
            data.n,
            g.h,
            g.w
        );
        let watch = Stopwatch::start();
        let mut report = RunReport {
            method: self.name.to_string(),
            n: data.n,
            d: data.d,
            // Heuristics optimize the layout in place; there is no learned
            // parameter vector.
            param_count: 0,
            phases: 0,
            valid_without_repair: true,
            ..Default::default()
        };
        let perm = report
            .sections
            .time("sort", || self.inner.sort(&data.rows, data.d, g, self.seed));
        let arranged = report
            .sections
            .time("arrange", || perm.apply_rows(&data.rows, data.d));
        report.final_dpq = report
            .sections
            .time("dpq", || dpq16(&arranged, data.d, g));
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}
