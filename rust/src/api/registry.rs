//! String-keyed method construction: one path from a method name + `k=v`
//! override pairs to a boxed [`Sorter`], shared by the CLI, every bench
//! target and every example.
//!
//! Overrides follow the CLI's `ParsedArgs` semantics: applied in order
//! (last one wins), unknown keys and unparsable values are errors naming
//! the offending key. Overrides are validated eagerly at `build` time (on a
//! probe config) so bad pairs fail before any optimization runs.

use anyhow::{anyhow, bail, Result};

use crate::backend::StepBackend;
use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
use crate::dimred::DrLap;
use crate::heuristics::{flas::Flas, som::Som, ssm::Ssm, GridSorter};

use super::sorter::{HeuristicSorter, LearnedKind, LearnedSorter, Sorter};

/// Whether a method needs a compute backend (learned) or is a pure-Rust
/// heuristic that never executes optimization steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    Learned,
    Heuristic,
}

/// Constructor signature for externally registered (plugin) methods.
///
/// `backend` is `Some` for [`MethodKind::Learned`] specs (the `Engine`
/// resolves one) and `None` for heuristics; `overrides` are the CLI's
/// `k=v` pairs. Implementations should validate overrides eagerly and
/// return errors naming the offending key, like the built-ins do.
pub type MethodCtor = for<'b> fn(
    Option<&'b dyn StepBackend>,
    &[(String, String)],
) -> Result<Box<dyn Sorter + 'b>>;

/// Static description of one registered method.
#[derive(Clone, Copy, Debug)]
pub struct MethodSpec {
    /// Canonical name (the key `build` resolves and `Sorter::name` reports).
    pub name: &'static str,
    /// Accepted aliases (historical CLI spellings).
    pub aliases: &'static [&'static str],
    pub kind: MethodKind,
    /// One-line summary for `sssort help`.
    pub summary: &'static str,
    /// Constructor for plugin methods registered via
    /// [`MethodRegistry::with_methods`]; `None` for the built-in set
    /// (which the registry constructs itself).
    pub ctor: Option<MethodCtor>,
}

const SPECS: &[MethodSpec] = &[
    MethodSpec {
        name: "shuffle-softsort",
        aliases: &["sss", "shufflesoftsort"],
        kind: MethodKind::Learned,
        summary: "the paper's Algorithm 1: N params, shuffled SoftSort phases",
        ctor: None,
    },
    MethodSpec {
        name: "softsort",
        aliases: &[],
        kind: MethodKind::Learned,
        summary: "plain SoftSort baseline (Prillo & Eisenschlos), N params",
        ctor: None,
    },
    MethodSpec {
        name: "gumbel-sinkhorn",
        aliases: &["gs"],
        kind: MethodKind::Learned,
        summary: "Gumbel-Sinkhorn baseline (Mena et al.), N^2 params",
        ctor: None,
    },
    MethodSpec {
        name: "kissing",
        aliases: &["kiss"],
        kind: MethodKind::Learned,
        summary: "low-rank Kissing baseline (Droege et al.), 2NM params",
        ctor: None,
    },
    MethodSpec {
        name: "flas",
        aliases: &[],
        kind: MethodKind::Heuristic,
        summary: "Fast Linear Assignment Sorting (subset LAPs per epoch)",
        ctor: None,
    },
    MethodSpec {
        name: "las",
        aliases: &[],
        kind: MethodKind::Heuristic,
        summary: "Linear Assignment Sorting (full-grid LAP per epoch)",
        ctor: None,
    },
    MethodSpec {
        name: "som",
        aliases: &[],
        kind: MethodKind::Heuristic,
        summary: "Self-Organizing Map layout (Kohonen)",
        ctor: None,
    },
    MethodSpec {
        name: "ssm",
        aliases: &[],
        kind: MethodKind::Heuristic,
        summary: "Self-Sorting Map (hierarchical quad swaps)",
        ctor: None,
    },
    MethodSpec {
        name: "pca-lap",
        aliases: &["pca"],
        kind: MethodKind::Heuristic,
        summary: "PCA projection to 2-D + Jonker-Volgenant grid assignment",
        ctor: None,
    },
    MethodSpec {
        name: "tsne-lap",
        aliases: &["tsne"],
        kind: MethodKind::Heuristic,
        summary: "t-SNE projection to 2-D + Jonker-Volgenant grid assignment",
        ctor: None,
    },
];

/// The method set: the crate's built-in drivers plus, optionally, a
/// `'static` slice of externally registered plugin specs (see
/// [`MethodRegistry::with_methods`]). Two words and `Copy`, so it is still
/// cheap to hand around and safe to share across threads.
#[derive(Clone, Copy, Debug, Default)]
pub struct MethodRegistry {
    /// Externally registered methods; built-ins take precedence on
    /// name/alias collisions.
    extra: &'static [MethodSpec],
}

impl MethodRegistry {
    /// The built-in method set only.
    pub fn new() -> Self {
        MethodRegistry { extra: &[] }
    }

    /// The built-in set extended with plugin methods. `extra` specs must
    /// carry a `ctor` (the registry has no driver of its own for them);
    /// building a ctor-less extra method is an error at `build` time.
    /// Everything downstream — `Engine::sort`, the CLI `--method` lookup,
    /// `GET /v1/methods` on the serve layer — sees the extended set when
    /// handed this registry (e.g. via `Engine::builder(..).registry(..)`).
    pub fn with_methods(extra: &'static [MethodSpec]) -> Self {
        MethodRegistry { extra }
    }

    /// All method specs: built-ins in canonical order, then extras.
    pub fn specs(&self) -> Vec<&'static MethodSpec> {
        let extra: &'static [MethodSpec] = self.extra;
        SPECS.iter().chain(extra.iter()).collect()
    }

    /// Canonical names of every registered method.
    pub fn names(&self) -> Vec<&'static str> {
        self.specs().iter().map(|s| s.name).collect()
    }

    /// Resolve a name or alias to its spec. Case-insensitive, and `_` is
    /// accepted for `-` (so `shuffle_softsort` hits `shuffle-softsort`).
    pub fn resolve(&self, name: &str) -> Option<&'static MethodSpec> {
        let lower = name.to_ascii_lowercase().replace('_', "-");
        let extra: &'static [MethodSpec] = self.extra;
        SPECS
            .iter()
            .chain(extra.iter())
            .find(|s| s.name == lower || s.aliases.contains(&lower.as_str()))
    }

    /// `resolve` with the canonical "unknown method" error listing every
    /// available name — the single source of that message for the registry,
    /// `Engine` and the CLI.
    pub fn resolve_or_err(&self, name: &str) -> Result<&'static MethodSpec> {
        self.resolve(name).ok_or_else(|| {
            anyhow!(
                "unknown method '{name}' — available: {}",
                self.names().join(", ")
            )
        })
    }

    /// Build a sorter by name. `backend` is the compute backend learned
    /// methods execute on (`NativeBackend`, `PjrtBackend`, or whatever the
    /// `Engine` resolved); heuristics ignore it. Overrides are the CLI's
    /// `k=v` pairs, validated here (last-wins; errors name the bad key).
    pub fn build<'b>(
        &self,
        name: &str,
        backend: Option<&'b dyn StepBackend>,
        overrides: &[(String, String)],
    ) -> Result<Box<dyn Sorter + 'b>> {
        let spec = self.resolve_or_err(name)?;
        // Plugin methods construct through their registered ctor; the
        // backend contract matches the built-ins (Some for learned specs).
        if let Some(ctor) = spec.ctor {
            return ctor(backend, overrides);
        }
        match spec.kind {
            MethodKind::Learned => {
                let kind = match spec.name {
                    "shuffle-softsort" => LearnedKind::ShuffleSoftSort,
                    "softsort" => LearnedKind::SoftSort,
                    "gumbel-sinkhorn" => LearnedKind::GumbelSinkhorn,
                    "kissing" => LearnedKind::Kissing,
                    other => bail!(
                        "method '{other}' has no built-in driver and no registered \
                         constructor (plugin MethodSpecs need `ctor: Some(..)`)"
                    ),
                };
                validate_learned_overrides(kind, overrides)?;
                let backend = backend.ok_or_else(|| {
                    anyhow!(
                        "method '{}' needs a compute backend — pass a \
                         backend::NativeBackend (pure Rust, artifact-free) or a \
                         backend::PjrtBackend, or go through api::Engine which \
                         resolves one automatically",
                        spec.name
                    )
                })?;
                Ok(Box::new(LearnedSorter::new(kind, backend, overrides.to_vec())))
            }
            MethodKind::Heuristic => {
                Ok(Box::new(build_heuristic(spec.name, overrides)?))
            }
        }
    }
}

/// Check learned-method overrides against a probe config so type errors and
/// unknown keys surface at build time. Goes through the same builder path
/// `LearnedSorter` uses at sort time, so validation cannot diverge from
/// application.
fn validate_learned_overrides(kind: LearnedKind, overrides: &[(String, String)]) -> Result<()> {
    match kind {
        LearnedKind::ShuffleSoftSort => {
            ShuffleSoftSortConfig::builder()
                .grid(4, 4)
                .overrides(overrides.iter().cloned())
                .build()?;
        }
        _ => {
            BaselineConfig::builder()
                .grid(4, 4)
                .overrides(overrides.iter().cloned())
                .build()?;
        }
    }
    Ok(())
}

fn parse_val<T: std::str::FromStr>(k: &str, v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse()
        .map_err(|e| anyhow!("invalid override '{k}={v}': {e}"))
}

/// Construct a configured heuristic adapter from `k=v` overrides.
fn build_heuristic(name: &'static str, overrides: &[(String, String)]) -> Result<HeuristicSorter> {
    let mut seed = 42u64;
    let inner: Box<dyn GridSorter> = match name {
        "flas" | "las" => {
            let mut f = if name == "las" { Flas::las(24) } else { Flas::default() };
            for (k, v) in overrides {
                match k.as_str() {
                    "seed" => seed = parse_val(k, v)?,
                    "epochs" => f.epochs = parse_val(k, v)?,
                    "subset" => f.subset = Some(parse_val(k, v)?),
                    "sigma_end" => f.sigma_end = parse_val(k, v)?,
                    _ => bail!(
                        "unknown config key '{k}' for {name} \
                         (allowed: seed, epochs, subset, sigma_end)"
                    ),
                }
            }
            Box::new(f)
        }
        "som" => {
            let mut s = Som::default();
            for (k, v) in overrides {
                match k.as_str() {
                    "seed" => seed = parse_val(k, v)?,
                    "epochs" => s.epochs = parse_val(k, v)?,
                    "sigma_start" => s.sigma_start = parse_val(k, v)?,
                    "sigma_end" => s.sigma_end = parse_val(k, v)?,
                    _ => bail!(
                        "unknown config key '{k}' for som \
                         (allowed: seed, epochs, sigma_start, sigma_end)"
                    ),
                }
            }
            Box::new(s)
        }
        "ssm" => {
            let mut s = Ssm::default();
            for (k, v) in overrides {
                match k.as_str() {
                    "seed" => seed = parse_val(k, v)?,
                    "sweeps" | "sweeps_per_stage" => s.sweeps_per_stage = parse_val(k, v)?,
                    _ => bail!("unknown config key '{k}' for ssm (allowed: seed, sweeps)"),
                }
            }
            Box::new(s)
        }
        "pca-lap" | "tsne-lap" => {
            for (k, v) in overrides {
                match k.as_str() {
                    "seed" => seed = parse_val(k, v)?,
                    _ => bail!("unknown config key '{k}' for {name} (allowed: seed)"),
                }
            }
            Box::new(DrLap { use_tsne: name == "tsne-lap" })
        }
        other => bail!(
            "heuristic '{other}' has no built-in driver and no registered \
             constructor (plugin MethodSpecs need `ctor: Some(..)`)"
        ),
    };
    Ok(HeuristicSorter::new(name, inner, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_colors;
    use crate::grid::GridShape;

    #[test]
    fn registry_covers_learned_and_heuristic_methods() {
        let reg = MethodRegistry::new();
        let names = reg.names();
        assert!(names.len() >= 7, "got {names:?}");
        for want in [
            "shuffle-softsort",
            "softsort",
            "gumbel-sinkhorn",
            "kissing",
            "flas",
            "som",
            "ssm",
        ] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        let learned = reg.specs().iter().filter(|s| s.kind == MethodKind::Learned).count();
        let heuristic = reg.specs().iter().filter(|s| s.kind == MethodKind::Heuristic).count();
        assert_eq!(learned, 4);
        assert!(heuristic >= 3);
    }

    #[test]
    fn aliases_resolve_to_canonical_names() {
        let reg = MethodRegistry::new();
        assert_eq!(reg.resolve("sss").unwrap().name, "shuffle-softsort");
        assert_eq!(reg.resolve("gs").unwrap().name, "gumbel-sinkhorn");
        assert_eq!(reg.resolve("kiss").unwrap().name, "kissing");
        assert_eq!(reg.resolve("SSS").unwrap().name, "shuffle-softsort");
        // Underscore spellings normalize to the canonical hyphen form.
        assert_eq!(reg.resolve("shuffle_softsort").unwrap().name, "shuffle-softsort");
        assert_eq!(reg.resolve("gumbel_sinkhorn").unwrap().name, "gumbel-sinkhorn");
        assert_eq!(reg.resolve("pca_lap").unwrap().name, "pca-lap");
        assert!(reg.resolve("bogus").is_none());
    }

    #[test]
    fn unknown_method_error_lists_available_names() {
        let reg = MethodRegistry::new();
        let err = reg.build("nope", None, &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown method 'nope'"), "{msg}");
        assert!(msg.contains("shuffle-softsort"), "{msg}");
        assert!(msg.contains("flas"), "{msg}");
    }

    #[test]
    fn learned_without_backend_is_a_helpful_error() {
        let reg = MethodRegistry::new();
        let err = reg.build("sss", None, &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("backend"), "{msg}");
        assert!(msg.contains("NativeBackend"), "{msg}");
    }

    #[test]
    fn learned_methods_build_and_sort_on_the_native_backend() {
        // The registry + native backend path needs no artifacts at all.
        let reg = MethodRegistry::new();
        let backend = crate::backend::NativeBackend::default();
        let g = GridShape::new(4, 4);
        let ds = random_colors(16, 21);
        let ov = crate::api::overrides(&[("steps", "24")]);
        for name in ["softsort", "gumbel-sinkhorn", "kissing"] {
            let out = reg
                .build(name, Some(&backend), &ov)
                .unwrap()
                .sort(&ds, g)
                .unwrap();
            assert_eq!(out.perm.len(), 16, "{name}");
            assert!(out.report.final_dpq.is_finite(), "{name}");
        }
        let ov = crate::api::overrides(&[("phases", "32"), ("record_curve", "false")]);
        let out = reg
            .build("shuffle-softsort", Some(&backend), &ov)
            .unwrap()
            .sort(&ds, g)
            .unwrap();
        assert_eq!(out.perm.len(), 16);
        assert_eq!(out.report.steps, 32 * 4);
    }

    #[test]
    fn override_errors_name_the_offending_key() {
        let reg = MethodRegistry::new();
        // Learned: type error, validated eagerly (before the runtime check).
        let bad = crate::api::overrides(&[("phases", "not-a-number")]);
        let err = reg.build("sss", None, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("phases"), "{err:#}");
        // Learned: unknown key.
        let bad = crate::api::overrides(&[("frobnicate", "1")]);
        let err = reg.build("sss", None, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("frobnicate"));
        // Heuristic: type error and unknown key.
        let bad = crate::api::overrides(&[("epochs", "x")]);
        let err = reg.build("flas", None, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("epochs"));
        let bad = crate::api::overrides(&[("epochs", "3")]);
        let err = reg.build("ssm", None, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("epochs"));
    }

    #[test]
    fn every_heuristic_sorts_a_tiny_grid_to_a_valid_permutation() {
        let reg = MethodRegistry::new();
        let g = GridShape::new(4, 4);
        let ds = random_colors(16, 9);
        for spec in reg.specs().iter().filter(|s| s.kind == MethodKind::Heuristic) {
            let sorter = reg.build(spec.name, None, &[]).unwrap();
            let out = sorter.sort(&ds, g).unwrap();
            // `Permutation` is validated on construction: length check
            // suffices to prove a duplicate-free bijection on 0..16.
            assert_eq!(out.perm.len(), 16, "{}", spec.name);
            assert!(out.report.final_dpq.is_finite(), "{}", spec.name);
            assert_eq!(out.arranged.len(), 16 * 3, "{}", spec.name);
            assert_eq!(out.report.method, spec.name);
            assert!(out.report.sections.count("sort") > 0, "{}", spec.name);
        }
    }

    /// A toy plugin method for the `with_methods` tests: lays items out in
    /// their input order (the identity permutation).
    struct IdentityLayout;

    impl crate::heuristics::GridSorter for IdentityLayout {
        fn name(&self) -> &'static str {
            "identity"
        }

        fn sort(
            &self,
            _data: &[f32],
            _d: usize,
            g: crate::grid::GridShape,
            _seed: u64,
        ) -> crate::perm::Permutation {
            crate::perm::Permutation::identity(g.n())
        }
    }

    fn build_identity<'b>(
        _backend: Option<&'b dyn StepBackend>,
        overrides: &[(String, String)],
    ) -> Result<Box<dyn Sorter + 'b>> {
        let mut seed = 0u64;
        for (k, v) in overrides {
            match k.as_str() {
                "seed" => seed = parse_val(k, v)?,
                _ => bail!("unknown config key '{k}' for identity (allowed: seed)"),
            }
        }
        Ok(Box::new(HeuristicSorter::new("identity", Box::new(IdentityLayout), seed)))
    }

    static PLUGIN_SPECS: &[MethodSpec] = &[MethodSpec {
        name: "identity",
        aliases: &["noop"],
        kind: MethodKind::Heuristic,
        summary: "test plugin: identity layout",
        ctor: Some(build_identity),
    }];

    #[test]
    fn with_methods_registers_buildable_plugin_specs() {
        let reg = MethodRegistry::with_methods(PLUGIN_SPECS);
        // Listed after the built-ins, resolvable by name and alias.
        assert!(reg.names().contains(&"identity"));
        assert!(reg.names().contains(&"shuffle-softsort"));
        assert_eq!(reg.resolve("noop").unwrap().name, "identity");
        assert_eq!(reg.specs().len(), SPECS.len() + 1);
        // Builds and sorts through the ctor.
        let g = GridShape::new(4, 4);
        let ds = random_colors(16, 5);
        let out = reg.build("identity", None, &[]).unwrap().sort(&ds, g).unwrap();
        assert_eq!(out.perm.as_slice(), (0..16).collect::<Vec<u32>>().as_slice());
        assert_eq!(out.report.method, "identity");
        // Ctor-level override validation still names the offending key.
        let bad = crate::api::overrides(&[("frobnicate", "1")]);
        let err = reg.build("identity", None, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("frobnicate"));
        // The default registry does not know the plugin.
        assert!(MethodRegistry::new().resolve("identity").is_none());
    }

    #[test]
    fn heuristic_overrides_are_applied_and_deterministic() {
        let reg = MethodRegistry::new();
        let g = GridShape::new(4, 4);
        let ds = random_colors(16, 10);
        let ov = crate::api::overrides(&[("seed", "7"), ("epochs", "8")]);
        let a = reg.build("flas", None, &ov).unwrap().sort(&ds, g).unwrap();
        let b = reg.build("flas", None, &ov).unwrap().sort(&ds, g).unwrap();
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.report.final_dpq.to_bits(), b.report.final_dpq.to_bits());
    }
}
