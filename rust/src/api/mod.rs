//! Unified public API: one interface for "a method that sorts a dataset
//! onto a grid", regardless of whether the method is learned (PJRT-backed)
//! or a pure-Rust heuristic.
//!
//! Three layers:
//!
//! * [`Sorter`] — the single trait every method implements. The four
//!   learned drivers (`ShuffleSoftSort`, `SoftSortDriver`,
//!   `GumbelSinkhornDriver`, `KissingDriver`) implement it directly;
//!   the heuristics (FLAS/LAS/SOM/SSM/PCA+LAP/t-SNE+LAP) are wrapped by
//!   [`sorter::HeuristicSorter`], so heuristic runs also produce a full
//!   `RunReport` with section timings and the final DPQ.
//! * [`MethodRegistry`] — string-keyed construction
//!   (`registry.build("shuffle-softsort", &rt, &overrides)?`) consuming the
//!   CLI's `k=v` override pairs. The CLI, every bench target and every
//!   example dispatch through it; nothing constructs a driver by hand.
//! * [`Engine`] — a session that owns the `Runtime` (lazily loaded, so
//!   heuristic-only sessions never touch the artifacts), memoizes
//!   `Executable` lookups per `(n, d, h)`, and runs
//!   [`Engine::sort_batch`] across `std::thread` workers — the first step
//!   toward the ROADMAP's serving story.

pub mod engine;
pub mod registry;
pub mod sorter;

pub use engine::{Engine, EngineBuilder};
pub use registry::{MethodKind, MethodRegistry, MethodSpec};
pub use sorter::{HeuristicSorter, LearnedSorter, Sorter};

/// Convenience: turn `&[("k", "v"), ...]` literals into the owned override
/// pairs the registry and config builders consume.
pub fn overrides(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}
