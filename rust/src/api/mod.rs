//! Unified public API: one interface for "a method that sorts a dataset
//! onto a grid", regardless of whether the method is learned (running on a
//! compute backend) or a pure-Rust heuristic.
//!
//! Three layers:
//!
//! * [`Sorter`] — the single trait every method implements. The four
//!   learned drivers (`ShuffleSoftSort`, `SoftSortDriver`,
//!   `GumbelSinkhornDriver`, `KissingDriver`) implement it directly;
//!   the heuristics (FLAS/LAS/SOM/SSM/PCA+LAP/t-SNE+LAP) are wrapped by
//!   [`sorter::HeuristicSorter`], so heuristic runs also produce a full
//!   `RunReport` with section timings and the final DPQ.
//! * [`MethodRegistry`] — string-keyed construction
//!   (`registry.build("shuffle-softsort", Some(&backend), &overrides)?`)
//!   consuming the CLI's `k=v` override pairs. The CLI, every bench target
//!   and every example dispatch through it; nothing constructs a driver by
//!   hand.
//! * [`Engine`] — a session that resolves the compute backend
//!   ([`BackendChoice`]: `auto`/`native`/`pjrt`; `auto` prefers artifacts
//!   when present and falls back to the pure-Rust `NativeBackend`),
//!   memoizes backend construction, and runs [`Engine::sort_batch`] across
//!   `std::thread` workers — on the native backend all workers share one
//!   `Send + Sync` backend instance.

pub mod engine;
pub mod registry;
pub mod sorter;

pub use engine::{Engine, EngineBuilder};
pub use registry::{MethodCtor, MethodKind, MethodRegistry, MethodSpec};
pub use sorter::{HeuristicSorter, LearnedSorter, Sorter};

// Backend selection is part of the public sorting API surface, as is the
// step-kernel level knob (`--simd` / `simd=`).
pub use crate::backend::{BackendChoice, SimdChoice};

/// Convenience: turn `&[("k", "v"), ...]` literals into the owned override
/// pairs the registry and config builders consume.
pub fn overrides(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}
