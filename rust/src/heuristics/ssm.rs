//! Self-Sorting Map (Strong & Gong [17], [18]).
//!
//! Hierarchical swap scheme: cells are compared against the *blurred
//! neighborhood average* of the current arrangement, and all 4! orderings
//! of small cell groups are tried, keeping the one minimizing the summed
//! distance to each cell's neighborhood target. Block size starts at half
//! the grid and halves every stage down to adjacent 2×2 quads.

use super::{blur_map, GridSorter};
use crate::grid::GridShape;
use crate::perm::Permutation;
use crate::util::rng::Pcg32;
use crate::util::stats::l2_sq;

pub struct Ssm {
    pub sweeps_per_stage: usize,
}

impl Default for Ssm {
    fn default() -> Self {
        Ssm { sweeps_per_stage: 4 }
    }
}

/// All permutations of 0..4 (4! = 24) — precomputed swap candidates.
const PERMS4: [[u8; 4]; 24] = [
    [0, 1, 2, 3], [0, 1, 3, 2], [0, 2, 1, 3], [0, 2, 3, 1], [0, 3, 1, 2], [0, 3, 2, 1],
    [1, 0, 2, 3], [1, 0, 3, 2], [1, 2, 0, 3], [1, 2, 3, 0], [1, 3, 0, 2], [1, 3, 2, 0],
    [2, 0, 1, 3], [2, 0, 3, 1], [2, 1, 0, 3], [2, 1, 3, 0], [2, 3, 0, 1], [2, 3, 1, 0],
    [3, 0, 1, 2], [3, 0, 2, 1], [3, 1, 0, 2], [3, 1, 2, 0], [3, 2, 0, 1], [3, 2, 1, 0],
];

impl GridSorter for Ssm {
    fn name(&self) -> &'static str {
        "SSM"
    }

    fn sort(&self, data: &[f32], d: usize, g: GridShape, seed: u64) -> Permutation {
        let n = g.n();
        assert_eq!(data.len(), n * d);
        let mut rng = Pcg32::new(seed);
        let mut assign = rng.permutation(n); // cell -> item

        let mut stride = (g.w.min(g.h) / 2).max(1);
        while stride >= 1 {
            for _ in 0..self.sweeps_per_stage {
                // Neighborhood target = blurred current arrangement.
                let mut target =
                    Permutation::from_vec(assign.clone()).unwrap().apply_rows(data, d);
                blur_map(&mut target, d, g, stride as f32);

                // Visit quads {(r,c),(r,c+s),(r+s,c),(r+s,c+s)} at all four
                // phase offsets so every cell participates (the original
                // SSM alternates offsets between sweeps).
                for (or_, oc) in [(0usize, 0usize), (0, stride), (stride, 0), (stride, stride)] {
                    let mut r = or_;
                    while r + stride < g.h {
                        let mut c = oc;
                        while c + stride < g.w {
                            let cells = [
                                g.index(r, c),
                                g.index(r, c + stride),
                                g.index(r + stride, c),
                                g.index(r + stride, c + stride),
                            ];
                            best_quad(&mut assign, data, d, &target, &cells);
                            c += 2 * stride;
                        }
                        r += 2 * stride;
                    }
                }
            }
            if stride == 1 {
                break;
            }
            stride /= 2;
        }
        Permutation::from_vec(assign).expect("swaps preserve bijectivity")
    }
}

/// Try all 24 arrangements of the quad's items; keep the best.
fn best_quad(assign: &mut [u32], data: &[f32], d: usize, target: &[f32], cells: &[usize; 4]) {
    let items = [
        assign[cells[0]], assign[cells[1]], assign[cells[2]], assign[cells[3]],
    ];
    let mut best_cost = f32::INFINITY;
    let mut best = &PERMS4[0];
    for perm in &PERMS4 {
        let mut cost = 0.0f32;
        for (slot, &p) in perm.iter().enumerate() {
            let item = items[p as usize] as usize;
            cost += l2_sq(
                &data[item * d..(item + 1) * d],
                &target[cells[slot] * d..(cells[slot] + 1) * d],
            );
        }
        if cost < best_cost {
            best_cost = cost;
            best = perm;
        }
    }
    for (slot, &p) in best.iter().enumerate() {
        assign[cells[slot]] = items[p as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_colors;
    use crate::metrics::mean_neighbor_distance;

    #[test]
    fn perms4_table_is_complete() {
        let mut seen = std::collections::BTreeSet::new();
        for p in &PERMS4 {
            let mut q = *p;
            q.sort();
            assert_eq!(q, [0, 1, 2, 3]);
            seen.insert(*p);
        }
        assert_eq!(seen.len(), 24);
    }

    #[test]
    fn improves_over_random_layout() {
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 15);
        let p = Ssm::default().sort(&ds.rows, 3, g, 8);
        let arranged = p.apply_rows(&ds.rows, 3);
        let before = mean_neighbor_distance(&ds.rows, 3, g);
        let after = mean_neighbor_distance(&arranged, 3, g);
        assert!(after < before, "SSM {after} vs random {before}");
    }
}
