//! Fast Linear Assignment Sorting (Barthel et al. [3]).
//!
//! LAS merges SOM's continuously filtered map with SSM's swaps by solving a
//! *linear assignment* between items and (blurred) map cells each epoch.
//! FLAS keeps the quality close to LAS at much lower cost by solving the
//! assignment on random subsets of cells instead of the full grid. Both are
//! provided: `Flas { subset: None }` is exact LAS, `subset: Some(k)` is
//! FLAS with k-cell batches.

use super::{blur_map, GridSorter};
use crate::assignment::jv;
use crate::grid::GridShape;
use crate::perm::Permutation;
use crate::util::rng::Pcg32;
use crate::util::stats::l2_sq;

pub struct Flas {
    pub epochs: usize,
    /// None → full-grid assignment every epoch (LAS).
    /// Some(k) → per-epoch random disjoint batches of k cells (FLAS).
    pub subset: Option<usize>,
    pub sigma_end: f32,
}

impl Default for Flas {
    fn default() -> Self {
        Flas { epochs: 24, subset: Some(64), sigma_end: 0.25 }
    }
}

impl Flas {
    pub fn las(epochs: usize) -> Self {
        Flas { epochs, subset: None, sigma_end: 0.25 }
    }

    fn sigma(&self, g: GridShape, e: usize) -> f32 {
        let s0 = g.w.max(g.h) as f32 / 3.0;
        let t = e as f32 / (self.epochs.max(2) - 1) as f32;
        s0 * (self.sigma_end / s0).powf(t)
    }
}

impl GridSorter for Flas {
    fn name(&self) -> &'static str {
        if self.subset.is_none() {
            "LAS"
        } else {
            "FLAS"
        }
    }

    fn sort(&self, data: &[f32], d: usize, g: GridShape, seed: u64) -> Permutation {
        let n = g.n();
        assert_eq!(data.len(), n * d);
        let mut rng = Pcg32::new(seed);
        let mut assign = rng.permutation(n); // cell -> item

        for e in 0..self.epochs {
            // Blurred map of the current arrangement = assignment targets.
            let mut map = Permutation::from_vec(assign.clone()).unwrap().apply_rows(data, d);
            blur_map(&mut map, d, g, self.sigma(g, e));

            match self.subset {
                None => {
                    // LAS: full n×n assignment item→cell.
                    let mut cost = vec![0.0f64; n * n];
                    for (cell, chunk) in map.chunks_exact(d).enumerate() {
                        for item in 0..n {
                            cost[item * n + cell] =
                                l2_sq(&data[item * d..(item + 1) * d], chunk) as f64;
                        }
                    }
                    let item_to_cell = jv::solve(&cost, n);
                    for (item, &cell) in item_to_cell.iter().enumerate() {
                        assign[cell as usize] = item as u32;
                    }
                }
                Some(k) => {
                    // FLAS: shuffle cells, solve disjoint k-cell LAPs among
                    // the items currently occupying those cells.
                    let mut cells = rng.permutation(n);
                    let k = k.clamp(2, n);
                    for batch in cells.chunks_mut(k) {
                        let b = batch.len();
                        let mut cost = vec![0.0f64; b * b];
                        for (ci, &cell) in batch.iter().enumerate() {
                            let target = &map[cell as usize * d..(cell as usize + 1) * d];
                            for (ii, &src_cell) in batch.iter().enumerate() {
                                let item = assign[src_cell as usize] as usize;
                                cost[ii * b + ci] =
                                    l2_sq(&data[item * d..(item + 1) * d], target) as f64;
                            }
                        }
                        let sol = jv::solve(&cost, b);
                        let items: Vec<u32> =
                            batch.iter().map(|&c| assign[c as usize]).collect();
                        for (ii, &ci) in sol.iter().enumerate() {
                            assign[batch[ci as usize] as usize] = items[ii];
                        }
                    }
                }
            }
        }
        Permutation::from_vec(assign).expect("assignment rounds preserve bijectivity")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_colors;
    use crate::metrics::{dpq16, mean_neighbor_distance};

    #[test]
    fn flas_improves_over_random() {
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 25);
        let p = Flas::default().sort(&ds.rows, 3, g, 9);
        let arranged = p.apply_rows(&ds.rows, 3);
        assert!(
            mean_neighbor_distance(&arranged, 3, g)
                < 0.75 * mean_neighbor_distance(&ds.rows, 3, g)
        );
    }

    #[test]
    fn las_at_least_as_good_as_flas_small() {
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 26);
        let flas = Flas::default().sort(&ds.rows, 3, g, 10);
        let las = Flas::las(24).sort(&ds.rows, 3, g, 10);
        let q_flas = dpq16(&flas.apply_rows(&ds.rows, 3), 3, g);
        let q_las = dpq16(&las.apply_rows(&ds.rows, 3), 3, g);
        // LAS solves the full assignment; allow small stochastic slack.
        assert!(q_las > q_flas - 0.07, "LAS {q_las} vs FLAS {q_flas}");
    }
}
