//! Heuristic distance-preserving grid-layout baselines (paper §I-B).
//!
//! All operate on row-major `[n, d]` data and return a `Permutation`
//! (grid position → item index), so they plug into the same DPQ/metrics
//! pipeline as the learned methods. Compared in `benches/heuristics.rs`.

pub mod flas;
pub mod som;
pub mod ssm;

use crate::grid::GridShape;
use crate::perm::Permutation;

/// Low-level heuristic interface over raw row-major slices. External
/// callers should prefer the unified `api::Sorter` trait — the registry
/// wraps every `GridSorter` in an `api::HeuristicSorter` adapter that adds
/// dataset handling, timing sections and the final DPQ to the outcome.
pub trait GridSorter {
    fn name(&self) -> &'static str;
    fn sort(&self, data: &[f32], d: usize, g: GridShape, seed: u64) -> Permutation;
}

/// 2-D Gaussian blur of a grid-arranged map (shared by SOM/LAS-style
/// methods). `sigma` in cells; separable two-pass implementation.
pub(crate) fn blur_map(map: &mut [f32], d: usize, g: GridShape, sigma: f32) {
    if sigma <= 0.05 {
        return;
    }
    let radius = (sigma * 3.0).ceil() as isize;
    let mut kernel = Vec::with_capacity((2 * radius + 1) as usize);
    for k in -radius..=radius {
        kernel.push((-0.5 * (k as f32 / sigma).powi(2)).exp());
    }
    let ksum: f32 = kernel.iter().sum();
    for k in &mut kernel {
        *k /= ksum;
    }

    let (h, w) = (g.h as isize, g.w as isize);
    let mut tmp = vec![0.0f32; map.len()];
    // Horizontal pass (clamped borders).
    for r in 0..h {
        for c in 0..w {
            let dst = ((r * w + c) as usize) * d;
            for ch in 0..d {
                let mut acc = 0.0f32;
                for (ki, k) in kernel.iter().enumerate() {
                    let cc = (c + ki as isize - radius).clamp(0, w - 1);
                    acc += k * map[((r * w + cc) as usize) * d + ch];
                }
                tmp[dst + ch] = acc;
            }
        }
    }
    // Vertical pass.
    for r in 0..h {
        for c in 0..w {
            let dst = ((r * w + c) as usize) * d;
            for ch in 0..d {
                let mut acc = 0.0f32;
                for (ki, k) in kernel.iter().enumerate() {
                    let rr = (r + ki as isize - radius).clamp(0, h - 1);
                    acc += k * tmp[((rr * w + c) as usize) * d + ch];
                }
                map[dst + ch] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blur_smooths_a_delta() {
        // Clamped-border convolution is an *averaging* filter (each output
        // is a convex combination), so: peak shrinks, neighbors gain,
        // max ≤ old max, all values ≥ 0. (It is not mass-preserving — a
        // corner delta gets re-sampled by clamping.)
        let g = GridShape::new(8, 8);
        let mut map = vec![0.0f32; 64];
        map[0] = 64.0; // delta at the corner
        blur_map(&mut map, 1, g, 1.5);
        assert!(map[0] < 64.0);
        assert!(map[9] > 0.0); // diagonal neighbor gained energy
        assert!(map.iter().all(|&v| (0.0..=64.0).contains(&v)));
        // An interior constant map is a fixed point.
        let mut flat = vec![3.0f32; 64];
        blur_map(&mut flat, 1, g, 1.5);
        assert!(flat.iter().all(|&v| (v - 3.0).abs() < 1e-5));
    }

    #[test]
    fn zero_sigma_is_noop() {
        let g = GridShape::new(4, 4);
        let mut map: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let orig = map.clone();
        blur_map(&mut map, 1, g, 0.0);
        assert_eq!(map, orig);
    }
}
