//! Self-Organizing Map grid sorter (Kohonen [8], [9]).
//!
//! Classic SOM adapted to *layout* use: map vectors live on the grid, each
//! epoch assigns every input to its best-matching free cell (greedy by
//! sample order), then map vectors are pulled toward their assigned inputs
//! and neighborhood-blurred with a shrinking radius. The final epoch's
//! assignment is the layout.

use super::{blur_map, GridSorter};
use crate::grid::GridShape;
use crate::perm::Permutation;
use crate::util::rng::Pcg32;
use crate::util::stats::l2_sq;

pub struct Som {
    pub epochs: usize,
    pub sigma_start: f32,
    pub sigma_end: f32,
}

impl Default for Som {
    fn default() -> Self {
        Som { epochs: 30, sigma_start: 0.0, sigma_end: 0.3 }
    }
}

impl Som {
    fn sigma(&self, g: GridShape, e: usize) -> f32 {
        let s0 = if self.sigma_start > 0.0 { self.sigma_start } else { g.w.max(g.h) as f32 / 3.0 };
        let t = e as f32 / (self.epochs.max(2) - 1) as f32;
        s0 * (self.sigma_end / s0).powf(t)
    }
}

impl GridSorter for Som {
    fn name(&self) -> &'static str {
        "SOM"
    }

    fn sort(&self, data: &[f32], d: usize, g: GridShape, seed: u64) -> Permutation {
        let n = g.n();
        assert_eq!(data.len(), n * d);
        let mut rng = Pcg32::new(seed);

        // Init map with a random arrangement of the inputs.
        let mut assign = rng.permutation(n); // cell -> item
        let mut map: Vec<f32> = Permutation::from_vec(assign.clone()).unwrap().apply_rows(data, d);

        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut taken = vec![false; n];

        for e in 0..self.epochs {
            blur_map(&mut map, d, g, self.sigma(g, e));

            // Greedy assignment of items to best free cells, random order.
            rng.shuffle(&mut order);
            taken.iter_mut().for_each(|t| *t = false);
            let mut new_assign = vec![0u32; n];
            for &item in &order {
                let x = &data[item as usize * d..(item as usize + 1) * d];
                let mut best = usize::MAX;
                let mut best_d = f32::INFINITY;
                for cell in 0..n {
                    if !taken[cell] {
                        let dist = l2_sq(x, &map[cell * d..(cell + 1) * d]);
                        if dist < best_d {
                            best_d = dist;
                            best = cell;
                        }
                    }
                }
                taken[best] = true;
                new_assign[best] = item;
            }
            assign = new_assign;

            // Pull map toward assigned inputs (full replacement, as LAS's
            // continuous map update with lr=1 before filtering).
            for cell in 0..n {
                let item = assign[cell] as usize;
                map[cell * d..(cell + 1) * d].copy_from_slice(&data[item * d..(item + 1) * d]);
            }
        }
        Permutation::from_vec(assign).expect("greedy assignment is a bijection")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_colors;
    use crate::metrics::mean_neighbor_distance;

    #[test]
    fn improves_over_random_layout() {
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 5);
        let p = Som::default().sort(&ds.rows, 3, g, 7);
        let arranged = p.apply_rows(&ds.rows, 3);
        let before = mean_neighbor_distance(&ds.rows, 3, g);
        let after = mean_neighbor_distance(&arranged, 3, g);
        assert!(after < before * 0.8, "SOM {after} vs random {before}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = GridShape::new(4, 4);
        let ds = random_colors(16, 6);
        let a = Som::default().sort(&ds.rows, 3, g, 1);
        let b = Som::default().sort(&ds.rows, 3, g, 1);
        assert_eq!(a, b);
    }
}
