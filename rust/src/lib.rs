//! # shufflesort
//!
//! Production reproduction of *"Permutation Learning with Only N Parameters:
//! From SoftSort to Self-Organizing Gaussians"* (Barthel, Barthel, Eisert,
//! 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas fused SoftSort-apply kernel (`python/compile/kernels/`),
//!   compiled at build time, never touched at run time.
//! * **L2** — the per-method training-step functions, available through two
//!   interchangeable [`backend`] implementations: AOT-lowered HLO artifacts
//!   executed via PJRT (`python/compile/model.py` → `artifacts/*.hlo.txt`,
//!   `pjrt` cargo feature), or the pure-Rust `NativeBackend` that needs no
//!   artifacts at all.
//! * **L3** — this crate: the optimization coordinator (Algorithm 1), the
//!   baselines, every substrate (metrics, heuristics, assignment solvers,
//!   the Self-Organizing-Gaussians pipeline) and the benchmark harness.
//!
//! All methods — learned and heuristic — are reached through the unified
//! [`api`] layer: the [`api::Sorter`] trait, the string-keyed
//! [`api::MethodRegistry`], and the [`api::Engine`] session that resolves
//! the compute backend (`auto` prefers artifacts when present, else falls
//! back to native) and batches work across threads.
//!
//! Quick start — works on a bare checkout, no artifacts required:
//!
//! ```no_run
//! use shufflesort::prelude::*;
//!
//! let engine = Engine::builder("artifacts").build(); // backend: auto
//! let data = shufflesort::data::random_colors(256, 42);
//! let g = GridShape::new(16, 16);
//!
//! // One call, any method: try "flas" or "som" for the heuristics.
//! let out = engine.sort("shuffle-softsort", &data, g, &[]).unwrap();
//! println!("DPQ16 = {}", out.report.final_dpq);
//!
//! // Batched sorting across worker threads, bit-identical to sequential.
//! let batch: Vec<_> = (0..4).map(|s| shufflesort::data::random_colors(256, s)).collect();
//! for result in engine.sort_batch("shuffle-softsort", &batch, g, &[]) {
//!     println!("{}", result.unwrap().report.summary());
//! }
//! ```
//!
//! Fine-grained control goes through the config builders, an explicit
//! backend and the drivers directly:
//!
//! ```no_run
//! use shufflesort::backend::NativeBackend;
//! use shufflesort::prelude::*;
//!
//! let backend = NativeBackend::default(); // or backend::PjrtBackend::from_artifacts(..)
//! let cfg = ShuffleSoftSortConfig::builder()
//!     .grid(16, 16)
//!     .phases(2048)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let data = shufflesort::data::random_colors(256, 42);
//! let out = ShuffleSoftSort::new(&backend, cfg).unwrap().sort(&data).unwrap();
//! println!("DPQ16 = {}", out.report.final_dpq);
//! ```

pub mod api;
pub mod assignment;
pub mod backend;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dimred;
pub mod grid;
pub mod heuristics;
pub mod metrics;
pub mod perm;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod sog;
pub mod trace;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::api::{BackendChoice, Engine, MethodKind, MethodRegistry, Sorter};
    pub use crate::backend::{NativeBackend, SessionOpts, SimdChoice, StepBackend};
    pub use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
    pub use crate::coordinator::{ShuffleSoftSort, SortOutcome};
    pub use crate::data::Dataset;
    pub use crate::grid::GridShape;
    pub use crate::metrics::dpq::dpq;
    #[cfg(feature = "pjrt")]
    pub use crate::runtime::Runtime;
}
