//! # shufflesort
//!
//! Production reproduction of *"Permutation Learning with Only N Parameters:
//! From SoftSort to Self-Organizing Gaussians"* (Barthel, Barthel, Eisert,
//! 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas fused SoftSort-apply kernel (`python/compile/kernels/`),
//!   compiled at build time, never touched at run time.
//! * **L2** — JAX training-step functions per method, AOT-lowered to HLO
//!   text artifacts (`python/compile/model.py` → `artifacts/*.hlo.txt`).
//! * **L3** — this crate: the optimization coordinator (Algorithm 1), the
//!   baselines, every substrate (metrics, heuristics, assignment solvers,
//!   the Self-Organizing-Gaussians pipeline) and the benchmark harness.
//!
//! All methods — learned and heuristic — are reached through the unified
//! [`api`] layer: the [`api::Sorter`] trait, the string-keyed
//! [`api::MethodRegistry`], and the [`api::Engine`] session that owns the
//! runtime and batches work across threads.
//!
//! Quick start (after `make artifacts`):
//!
//! ```no_run
//! use shufflesort::prelude::*;
//!
//! let engine = Engine::from_artifacts("artifacts").unwrap();
//! let data = shufflesort::data::random_colors(256, 42);
//! let g = GridShape::new(16, 16);
//!
//! // One call, any method: try "flas" or "som" for runtime-free heuristics.
//! let out = engine.sort("shuffle-softsort", &data, g, &[]).unwrap();
//! println!("DPQ16 = {}", out.report.final_dpq);
//!
//! // Batched sorting across worker threads, bit-identical to sequential.
//! let batch: Vec<_> = (0..4).map(|s| shufflesort::data::random_colors(256, s)).collect();
//! for result in engine.sort_batch("shuffle-softsort", &batch, g, &[]) {
//!     println!("{}", result.unwrap().report.summary());
//! }
//! ```
//!
//! Fine-grained control goes through the config builders and the drivers
//! directly:
//!
//! ```no_run
//! use shufflesort::prelude::*;
//!
//! let rt = Runtime::from_manifest("artifacts").unwrap();
//! let cfg = ShuffleSoftSortConfig::builder()
//!     .grid(16, 16)
//!     .phases(2048)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let data = shufflesort::data::random_colors(256, 42);
//! let out = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&data).unwrap();
//! println!("DPQ16 = {}", out.report.final_dpq);
//! ```

pub mod api;
pub mod assignment;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dimred;
pub mod grid;
pub mod heuristics;
pub mod metrics;
pub mod perm;
pub mod runtime;
pub mod sog;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::api::{Engine, MethodKind, MethodRegistry, Sorter};
    pub use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
    pub use crate::coordinator::{ShuffleSoftSort, SortOutcome};
    pub use crate::data::Dataset;
    pub use crate::grid::GridShape;
    pub use crate::metrics::dpq::dpq;
    pub use crate::runtime::Runtime;
}
