//! # shufflesort
//!
//! Production reproduction of *"Permutation Learning with Only N Parameters:
//! From SoftSort to Self-Organizing Gaussians"* (Barthel, Barthel, Eisert,
//! 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas fused SoftSort-apply kernel (`python/compile/kernels/`),
//!   compiled at build time, never touched at run time.
//! * **L2** — JAX training-step functions per method, AOT-lowered to HLO
//!   text artifacts (`python/compile/model.py` → `artifacts/*.hlo.txt`).
//! * **L3** — this crate: the optimization coordinator (Algorithm 1), the
//!   baselines, every substrate (metrics, heuristics, assignment solvers,
//!   the Self-Organizing-Gaussians pipeline) and the benchmark harness.
//!
//! Quick start (after `make artifacts`):
//!
//! ```no_run
//! use shufflesort::prelude::*;
//!
//! let rt = Runtime::from_manifest("artifacts").unwrap();
//! let data = shufflesort::data::random_colors(256, 42);
//! let cfg = ShuffleSoftSortConfig::for_grid(16, 16);
//! let result = ShuffleSoftSort::new(&rt, cfg).unwrap().sort(&data).unwrap();
//! println!("DPQ16 = {}", result.report.final_dpq);
//! ```

pub mod assignment;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dimred;
pub mod grid;
pub mod heuristics;
pub mod metrics;
pub mod perm;
pub mod runtime;
pub mod sog;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::config::ShuffleSoftSortConfig;
    pub use crate::coordinator::{ShuffleSoftSort, SortOutcome};
    pub use crate::data::Dataset;
    pub use crate::grid::GridShape;
    pub use crate::metrics::dpq::dpq;
    pub use crate::runtime::Runtime;
}
