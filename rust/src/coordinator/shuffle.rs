//! Shuffle strategies — the "Shuffle" in ShuffleSoftSort.
//!
//! Algorithm 1 uses `randperm(N)`. The paper's conclusion additionally
//! mentions alternating horizontal/vertical sorting for grids, which is a
//! *scan-order* shuffle (grid/ScanOrder). `Mixed` interleaves both: scan
//! orders give SoftSort direct row/column mobility, random permutations
//! give long-range moves. The ablation bench (E8) compares all three.

use crate::grid::{GridShape, ScanOrder};
use crate::perm::Permutation;
use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShuffleStrategy {
    /// Fresh uniform random permutation every phase (Algorithm 1).
    Random,
    /// Cycle snake-rows / snake-cols scans (pure H/V alternation).
    AlternatingScan,
    /// Alternate scan phases with random phases (default).
    Mixed,
    /// No shuffling at all — turns the driver into plain SoftSort.
    Identity,
}

impl ShuffleStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "random" => Some(Self::Random),
            "scan" | "alternating" => Some(Self::AlternatingScan),
            "mixed" => Some(Self::Mixed),
            "identity" | "none" => Some(Self::Identity),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Random => "random",
            Self::AlternatingScan => "scan",
            Self::Mixed => "mixed",
            Self::Identity => "identity",
        }
    }

    /// The shuffle permutation for phase `r`.
    pub fn shuffle_for_phase(&self, r: usize, g: GridShape, rng: &mut Pcg32) -> Permutation {
        let scans = [ScanOrder::SnakeRows, ScanOrder::SnakeCols];
        match self {
            Self::Identity => Permutation::identity(g.n()),
            Self::Random => Permutation::from_vec(rng.permutation(g.n()))
                .expect("rng permutations are valid"),
            Self::AlternatingScan => {
                if g.h == 1 {
                    // 1-D problem: alternate identity and reversal-ish snake.
                    scans[0].permutation(g)
                } else {
                    scans[r % 2].permutation(g)
                }
            }
            Self::Mixed => {
                if r % 2 == 0 {
                    if g.h == 1 {
                        Permutation::from_vec(rng.permutation(g.n())).unwrap()
                    } else {
                        scans[(r / 2) % 2].permutation(g)
                    }
                } else {
                    Permutation::from_vec(rng.permutation(g.n())).unwrap()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for s in [
            ShuffleStrategy::Random,
            ShuffleStrategy::AlternatingScan,
            ShuffleStrategy::Mixed,
            ShuffleStrategy::Identity,
        ] {
            assert_eq!(ShuffleStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ShuffleStrategy::parse("bogus"), None);
    }

    #[test]
    fn identity_is_identity() {
        let g = GridShape::new(4, 4);
        let mut rng = Pcg32::new(1);
        let p = ShuffleStrategy::Identity.shuffle_for_phase(3, g, &mut rng);
        assert_eq!(p, Permutation::identity(16));
    }

    #[test]
    fn all_strategies_produce_valid_perms() {
        let g = GridShape::new(8, 8);
        let mut rng = Pcg32::new(2);
        for s in [
            ShuffleStrategy::Random,
            ShuffleStrategy::AlternatingScan,
            ShuffleStrategy::Mixed,
        ] {
            for r in 0..6 {
                let p = s.shuffle_for_phase(r, g, &mut rng);
                assert_eq!(p.len(), 64);
            }
        }
    }

    #[test]
    fn mixed_alternates_scan_and_random() {
        let g = GridShape::new(4, 4);
        let mut rng = Pcg32::new(3);
        let p0 = ShuffleStrategy::Mixed.shuffle_for_phase(0, g, &mut rng);
        assert_eq!(p0, ScanOrder::SnakeRows.permutation(g));
        let p2 = ShuffleStrategy::Mixed.shuffle_for_phase(2, g, &mut rng);
        assert_eq!(p2, ScanOrder::SnakeCols.permutation(g));
    }
}
