//! Run reporting: loss curves, validity statistics and section timings —
//! everything EXPERIMENTS.md records per run.

use crate::trace;
use crate::util::timer::Sections;

/// One recorded optimization event (per inner iteration or per phase).
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub phase: usize,
    pub iter: usize,
    pub tau: f32,
    pub loss: f64,
}

/// Aggregated statistics of one optimization run.
#[derive(Debug, Default)]
pub struct RunReport {
    pub method: String,
    pub n: usize,
    pub d: usize,
    pub param_count: usize,
    pub phases: usize,
    pub steps: usize,
    pub curve: Vec<CurvePoint>,
    /// Phases whose argmax extraction needed extension iterations.
    pub extensions: usize,
    /// Phases rejected by greedy acceptance (ShuffleSoftSort only).
    pub rejected_phases: usize,
    /// Entries rewritten by greedy repair (0 in healthy runs).
    pub repaired: usize,
    /// Tiles per phase under the tiled phase executor (1 = the full
    /// executor; 0 for methods without a phase executor at all).
    pub tiles: usize,
    /// Which executor/plan laid out the phases: "full", "banded", "snake",
    /// "overlapped", "pyramid" — empty for methods without one.
    pub tile_plan: String,
    /// Human-readable configuration notes surfaced to the caller: clamped
    /// `tiles=` requests, pyramid fallbacks, and similar adjustments that
    /// would otherwise happen silently.
    pub notes: Vec<String>,
    /// Whether the final permutation came out valid without repair.
    pub valid_without_repair: bool,
    pub wall_secs: f64,
    pub final_loss: f64,
    /// DPQ16 of the final layout (filled by the caller that knows the data).
    pub final_dpq: f64,
    pub sections: Sections,
}

impl RunReport {
    pub fn record(&mut self, phase: usize, iter: usize, tau: f32, loss: f64) {
        self.curve.push(CurvePoint { phase, iter, tau, loss });
        self.final_loss = loss;
        self.steps += 1;
    }

    /// Attach the run's convergence summary to a trace span — the bridge
    /// between `RunReport` and the observability layer. No-op when the
    /// span is not recording.
    pub fn trace_attrs(&self, span: &mut trace::Span) {
        if !span.is_recording() {
            return;
        }
        span.attr_u64("steps", self.steps as u64);
        span.attr_u64("extensions", self.extensions as u64);
        span.attr_u64("rejected_phases", self.rejected_phases as u64);
        span.attr_u64("tiles", self.tiles as u64);
        span.attr_f64("final_loss", self.final_loss);
        span.attr_f64("final_dpq", self.final_dpq);
        span.attr_f64("wall_secs", self.wall_secs);
    }

    /// Loss of the first/last recorded step — convergence summary.
    pub fn loss_span(&self) -> (f64, f64) {
        match (self.curve.first(), self.curve.last()) {
            (Some(a), Some(b)) => (a.loss, b.loss),
            _ => (f64::NAN, f64::NAN),
        }
    }

    /// Compact one-line summary for CLI/bench output. Methods that take no
    /// optimization steps (the heuristic adapters) omit the loss clause.
    pub fn summary(&self) -> String {
        let progress = if self.steps == 0 {
            String::new()
        } else if self.curve.is_empty() {
            // record_curve=false: only the last loss is known.
            format!("steps={} loss ->{:.4} ", self.steps, self.final_loss)
        } else {
            let (l0, l1) = self.loss_span();
            format!("steps={} loss {l0:.4}->{l1:.4} ", self.steps)
        };
        format!(
            "{}: N={} params={} {progress}dpq={:.3} valid={} repairs={} {:.1}s",
            self.method,
            self.n,
            self.param_count,
            self.final_dpq,
            self.valid_without_repair,
            self.repaired,
            self.wall_secs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_span() {
        let mut r = RunReport { method: "sss".into(), ..Default::default() };
        r.record(0, 0, 1.0, 2.0);
        r.record(0, 1, 0.9, 1.5);
        r.record(1, 0, 0.8, 1.0);
        assert_eq!(r.steps, 3);
        assert_eq!(r.loss_span(), (2.0, 1.0));
        assert!(r.summary().contains("loss 2.0000->1.0000"));
    }
}
