//! Baseline drivers: plain SoftSort [14], Gumbel-Sinkhorn [11] and
//! Kissing-to-Find-a-Match [4] — the comparison set of the paper's Table 2.
//!
//! All parameters live in Rust; the AOT artifacts are stateless step
//! functions (see `python/compile/model.py`). Every driver returns the same
//! `SortOutcome` shape so the benches treat methods uniformly.

use anyhow::{Context, Result};

use crate::assignment::jv;
use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
use crate::data::Dataset;
use crate::metrics::dpq16;
use crate::perm::{repair, Permutation};
use crate::runtime::{Arg, Runtime};
use crate::util::rng::Pcg32;
use crate::util::stats::mean_pairwise_distance;
use crate::util::timer::Stopwatch;

use super::events::RunReport;
use super::optimizer::Adam;
use super::shuffle::ShuffleStrategy;
use super::SortOutcome;

/// Plain SoftSort: the ShuffleSoftSort driver with the identity shuffle and
/// ONE long phase over which `w` persists and τ anneals per-step — i.e. the
/// original 1-D method the paper improves on.
pub struct SoftSortDriver<'rt> {
    rt: &'rt Runtime,
    pub cfg: BaselineConfig,
}

impl<'rt> SoftSortDriver<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: BaselineConfig) -> Self {
        SoftSortDriver { rt, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        // Reuse the shared driver: steps = phases × 1 inner iteration with a
        // persistent w is NOT what run_shuffle_softsort does (it re-inits w
        // per phase), so plain SoftSort gets its own loop here.
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        let exe = self.rt.sss_step(n, d, g.h)?;
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "SoftSort".into(),
            n,
            d,
            param_count: n,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);
        let identity_inv: Vec<i32> = (0..n as i32).collect();

        // Unit-spacing descending ramp — same bandwidth rationale as the
        // ShuffleSoftSort driver (coordinator/mod.rs).
        let mut w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let mut adam = Adam::new(self.cfg.adam.clone(), n);
        let mut idx = vec![0u32; n];
        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            let out = report.sections.time("execute", || {
                exe.run(&[
                    Arg::F32(&w),
                    Arg::F32(&data.rows),
                    Arg::I32(&identity_inv),
                    Arg::ScalarF32(tau),
                    Arg::ScalarF32(norm),
                ])
            })?;
            adam.step(&mut w, out[1].as_f32());
            report.record(0, s, tau, out[0].scalar_f32() as f64);
            if s + 1 == self.cfg.steps {
                for (dst, &v) in idx.iter_mut().zip(out[2].as_i32()) {
                    *dst = v as u32;
                }
            }
        }

        let perm = if Permutation::count_duplicates(&idx) == 0 {
            Permutation::from_vec(idx).expect("checked")
        } else {
            let (p, fixed) = repair(&idx);
            report.repaired += fixed;
            report.valid_without_repair = false;
            p
        };
        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Gumbel-Sinkhorn: N² logits, Rust-side Gumbel noise (annealed), JV-based
/// hard extraction from the probe artifact's doubly stochastic matrix.
pub struct GumbelSinkhornDriver<'rt> {
    rt: &'rt Runtime,
    pub cfg: BaselineConfig,
}

impl<'rt> GumbelSinkhornDriver<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: BaselineConfig) -> Self {
        GumbelSinkhornDriver { rt, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        let exe = self
            .rt
            .gs_step(n, d, g.h)
            .context("no gumbel-sinkhorn artifact for this shape")?;
        let probe = self.rt.gs_probe(n)?;
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "Gumbel-Sinkhorn".into(),
            n,
            d,
            param_count: n * n,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);

        let mut logits = vec![0.0f32; n * n];
        // Small random init breaks the uniform-P symmetry.
        for v in logits.iter_mut() {
            *v = rng.gaussian() * 0.01;
        }
        let mut adam = Adam::new(self.cfg.adam.clone(), n * n);
        let mut gumbel = vec![0.0f32; n * n];

        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            // Fresh noise each step, annealed with the temperature.
            let scale = self.cfg.gumbel_scale * (1.0 - s as f32 / self.cfg.steps as f32);
            report.sections.time("noise", || {
                for v in gumbel.iter_mut() {
                    *v = rng.gumbel() * scale;
                }
            });
            let out = report.sections.time("execute", || {
                exe.run(&[
                    Arg::F32(&logits),
                    Arg::F32(&data.rows),
                    Arg::F32(&gumbel),
                    Arg::ScalarF32(tau),
                    Arg::ScalarF32(norm),
                ])
            })?;
            report.sections.time("adam", || {
                adam.step(&mut logits, out[1].as_f32());
            });
            report.record(0, s, tau, out[0].scalar_f32() as f64);
        }

        // Final hard extraction: P from the probe (noise-free, sharp τ),
        // then the optimal assignment via Jonker–Volgenant on -P.
        let zeros = vec![0.0f32; n * n];
        let p = report.sections.time("execute", || {
            probe.run(&[
                Arg::F32(&logits),
                Arg::F32(&zeros),
                Arg::ScalarF32(self.cfg.tau.tau_end),
            ])
        })?;
        let p = p[0].as_f32();
        let perm = report.sections.time("extract", || {
            let mut cost = vec![0.0f64; n * n];
            for (c, &v) in cost.iter_mut().zip(p) {
                *c = -(v as f64);
            }
            let assign = jv::solve(&cost, n); // row -> col (grid pos -> item)
            Permutation::from_vec(assign).expect("JV yields a bijection")
        });

        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Kissing-to-Find-a-Match: low-rank V, W ∈ R^{N×M}. Extraction is plain
/// row-argmax (the method's softmax is row-only) — the paper's observation
/// that it "often fails to produce valid permutation matrices" is exactly
/// what `valid_without_repair` records.
pub struct KissingDriver<'rt> {
    rt: &'rt Runtime,
    pub cfg: BaselineConfig,
}

impl<'rt> KissingDriver<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: BaselineConfig) -> Self {
        KissingDriver { rt, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        // Rank follows the manifest (kissing-number rule, shapes.py).
        let meta = self
            .rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.method == "kiss" && a.n == n && a.d == d)
            .context("no kissing artifact for this shape")?
            .clone();
        let m = meta.m;
        let exe = self.rt.load(&meta.name)?;
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "Kissing".into(),
            n,
            d,
            param_count: 2 * n * m,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);

        let mut v: Vec<f32> = (0..n * m).map(|_| rng.gaussian()).collect();
        let mut wf: Vec<f32> = (0..n * m).map(|_| rng.gaussian()).collect();
        let mut adam_v = Adam::new(self.cfg.adam.clone(), n * m);
        let mut adam_w = Adam::new(self.cfg.adam.clone(), n * m);
        let mut idx = vec![0u32; n];

        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            let out = report.sections.time("execute", || {
                exe.run(&[
                    Arg::F32(&v),
                    Arg::F32(&wf),
                    Arg::F32(&data.rows),
                    Arg::ScalarF32(tau),
                    Arg::ScalarF32(norm),
                ])
            })?;
            report.sections.time("adam", || {
                adam_v.step(&mut v, out[1].as_f32());
                adam_w.step(&mut wf, out[2].as_f32());
            });
            report.record(0, s, tau, out[0].scalar_f32() as f64);
            if s + 1 == self.cfg.steps {
                for (dst, &x) in idx.iter_mut().zip(out[3].as_i32()) {
                    *dst = x as u32;
                }
            }
        }

        let dups = Permutation::count_duplicates(&idx);
        let perm = if dups == 0 {
            Permutation::from_vec(idx).expect("checked")
        } else {
            let (p, fixed) = repair(&idx);
            report.repaired += fixed;
            report.valid_without_repair = false;
            p
        };
        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Build a plain-SoftSort config equivalent in step budget to a
/// ShuffleSoftSort config (for the Table 2 bench's fairness note).
pub fn softsort_budget_of(cfg: &ShuffleSoftSortConfig) -> BaselineConfig {
    BaselineConfig {
        grid: cfg.grid,
        steps: cfg.phases * cfg.inner_iters,
        tau: cfg.tau.clone(),
        adam: cfg.adam.clone(),
        seed: cfg.seed,
        gumbel_scale: 0.0,
    }
}

// Re-export for convenience in benches.
pub use super::shuffle::ShuffleStrategy as Strategy;

/// Make a ShuffleSoftSort config that *is* plain SoftSort via policy
/// (identity shuffle, single phase) — used by the ablation bench to verify
/// the equivalence claim.
pub fn softsort_as_policy(mut cfg: ShuffleSoftSortConfig) -> ShuffleSoftSortConfig {
    cfg.shuffle = ShuffleStrategy::Identity;
    cfg
}
