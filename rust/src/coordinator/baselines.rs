//! Baseline drivers: plain SoftSort [14], Gumbel-Sinkhorn [11] and
//! Kissing-to-Find-a-Match [4] — the comparison set of the paper's Table 2.
//!
//! All parameters live in Rust; the per-step compute functions execute on
//! whichever [`StepBackend`] the driver holds — PJRT artifacts or the
//! pure-Rust native backend. Like the ShuffleSoftSort driver, every
//! baseline opens ONE `StepSession` per run and drives all of its Adam
//! steps through it (reused scratch + out buffers, `cfg.threads` pool
//! sizing). Every driver returns the same `SortOutcome` shape so the
//! benches treat methods uniformly.

use anyhow::Result;

use crate::assignment::jv;
use crate::backend::{GsStep, KissStep, SssStep, StepBackend, StepSession, StepShape};
use crate::config::{BaselineConfig, ShuffleSoftSortConfig};
use crate::data::Dataset;
use crate::metrics::dpq16;
use crate::perm::{repair, Permutation};
use crate::trace;
use crate::util::rng::Pcg32;
use crate::util::stats::mean_pairwise_distance;
use crate::util::timer::Stopwatch;

use super::events::RunReport;
use super::optimizer::Adam;
use super::shuffle::ShuffleStrategy;
use super::SortOutcome;

/// Plain SoftSort: the ShuffleSoftSort driver with the identity shuffle and
/// ONE long phase over which `w` persists and τ anneals per-step — i.e. the
/// original 1-D method the paper improves on.
pub struct SoftSortDriver<'b> {
    backend: &'b dyn StepBackend,
    pub cfg: BaselineConfig,
}

impl<'b> SoftSortDriver<'b> {
    pub fn new(backend: &'b dyn StepBackend, cfg: BaselineConfig) -> Self {
        SoftSortDriver { backend, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        // Reuse the shared driver: steps = phases × 1 inner iteration with a
        // persistent w is NOT what run_shuffle_softsort does (it re-inits w
        // per phase), so plain SoftSort gets its own loop here.
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        let shape = StepShape::new(g, d);
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "SoftSort".into(),
            n,
            d,
            param_count: n,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);
        let identity_inv: Vec<i32> = (0..n as i32).collect();

        // One session for the whole run (reused scratch, pool, out bufs).
        let mut session = self.backend.session(shape, self.cfg.session_opts())?;
        let mut step = SssStep::new_for(shape);

        // Unit-spacing descending ramp — same bandwidth rationale as the
        // ShuffleSoftSort driver (coordinator/mod.rs).
        let mut w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let mut adam = Adam::new(self.cfg.adam.clone(), n);
        let mut idx = vec![0u32; n];
        let mut clock = trace::StepClock::start(trace::current());
        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            report.sections.time("execute", || {
                clock.time(trace::FAM_SSS, || {
                    session.sss_step(&w, &data.rows, &identity_inv, tau, norm, &mut step)
                })
            })?;
            clock.time(trace::FAM_ADAM, || adam.step(&mut w, &step.grad));
            report.record(0, s, tau, step.loss as f64);
            if s + 1 == self.cfg.steps {
                for (dst, &v) in idx.iter_mut().zip(&step.sort_idx) {
                    *dst = v as u32;
                }
            }
        }
        clock.emit();

        let perm = if Permutation::count_duplicates(&idx) == 0 {
            Permutation::from_vec(idx).expect("checked")
        } else {
            let (p, fixed) = repair(&idx);
            report.repaired += fixed;
            report.valid_without_repair = false;
            p
        };
        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Gumbel-Sinkhorn: N² logits, Rust-side Gumbel noise (annealed), JV-based
/// hard extraction from the probe's doubly stochastic matrix.
pub struct GumbelSinkhornDriver<'b> {
    backend: &'b dyn StepBackend,
    pub cfg: BaselineConfig,
}

impl<'b> GumbelSinkhornDriver<'b> {
    pub fn new(backend: &'b dyn StepBackend, cfg: BaselineConfig) -> Self {
        GumbelSinkhornDriver { backend, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        let shape = StepShape::new(g, d);
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "Gumbel-Sinkhorn".into(),
            n,
            d,
            param_count: n * n,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);
        // Fail fast: the final extraction needs the probe; surface a
        // missing probe artifact before the optimization loop, not after.
        self.backend.gs_probe_ready(n)?;

        // One session per run. Its Sinkhorn state slab (2·iters N²
        // log-matrices) is allocated once and reused by every step — the
        // pre-session code re-allocated that stack per step.
        let mut session = self.backend.session(shape, self.cfg.session_opts())?;
        let mut step = GsStep::new_for(n);

        let mut logits = vec![0.0f32; n * n];
        // Small random init breaks the uniform-P symmetry.
        for v in logits.iter_mut() {
            *v = rng.gaussian() * 0.01;
        }
        let mut adam = Adam::new(self.cfg.adam.clone(), n * n);
        let mut gumbel = vec![0.0f32; n * n];

        let mut clock = trace::StepClock::start(trace::current());
        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            // Fresh noise each step, annealed with the temperature.
            let scale = self.cfg.gumbel_scale * (1.0 - s as f32 / self.cfg.steps as f32);
            report.sections.time("noise", || {
                for v in gumbel.iter_mut() {
                    *v = rng.gumbel() * scale;
                }
            });
            report.sections.time("execute", || {
                clock.time(trace::FAM_GS, || {
                    session.gs_step(&logits, &data.rows, &gumbel, tau, norm, &mut step)
                })
            })?;
            report.sections.time("adam", || {
                clock.time(trace::FAM_ADAM, || adam.step(&mut logits, &step.grad));
            });
            report.record(0, s, tau, step.loss as f64);
        }

        // Final hard extraction: P from the probe (noise-free, sharp τ),
        // then the optimal assignment via Jonker–Volgenant on -P.
        let mut p = Vec::new();
        report.sections.time("execute", || {
            clock.time(trace::FAM_GS, || {
                session.gs_probe(&logits, self.cfg.tau.tau_end, &mut p)
            })
        })?;
        clock.emit();
        let perm = report.sections.time("extract", || {
            let mut cost = vec![0.0f64; n * n];
            for (c, &v) in cost.iter_mut().zip(&p) {
                *c = -(v as f64);
            }
            let assign = jv::solve(&cost, n); // row -> col (grid pos -> item)
            Permutation::from_vec(assign).expect("JV yields a bijection")
        });

        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Kissing-to-Find-a-Match: low-rank V, W ∈ R^{N×M}. Extraction is plain
/// row-argmax (the method's softmax is row-only) — the paper's observation
/// that it "often fails to produce valid permutation matrices" is exactly
/// what `valid_without_repair` records.
pub struct KissingDriver<'b> {
    backend: &'b dyn StepBackend,
    pub cfg: BaselineConfig,
}

impl<'b> KissingDriver<'b> {
    pub fn new(backend: &'b dyn StepBackend, cfg: BaselineConfig) -> Self {
        KissingDriver { backend, cfg }
    }

    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        let (n, d) = (data.n, data.d);
        anyhow::ensure!(n == g.n());
        let shape = StepShape::new(g, d);
        // Rank from the backend: manifest-driven (pjrt) or the
        // kissing-number rule (native) — identical values either way.
        let m = self.backend.kiss_rank(n, d)?;
        let watch = Stopwatch::start();
        let mut rng = Pcg32::new(self.cfg.seed);
        let mut report = RunReport {
            method: "Kissing".into(),
            n,
            d,
            param_count: 2 * n * m,
            phases: 1,
            valid_without_repair: true,
            ..Default::default()
        };
        let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);

        // One session per run (reused factor/normalization scratch).
        let mut session = self.backend.session(shape, self.cfg.session_opts())?;
        let mut step = KissStep::new_for(n, m);

        let mut v: Vec<f32> = (0..n * m).map(|_| rng.gaussian()).collect();
        let mut wf: Vec<f32> = (0..n * m).map(|_| rng.gaussian()).collect();
        let mut adam_v = Adam::new(self.cfg.adam.clone(), n * m);
        let mut adam_w = Adam::new(self.cfg.adam.clone(), n * m);
        let mut idx = vec![0u32; n];

        let mut clock = trace::StepClock::start(trace::current());
        for s in 0..self.cfg.steps {
            let tau = self.cfg.tau.phase_tau(s, self.cfg.steps);
            report.sections.time("execute", || {
                clock.time(trace::FAM_KISS, || {
                    session.kiss_step(m, &v, &wf, &data.rows, tau, norm, &mut step)
                })
            })?;
            report.sections.time("adam", || {
                clock.time(trace::FAM_ADAM, || {
                    adam_v.step(&mut v, &step.grad_v);
                    adam_w.step(&mut wf, &step.grad_w);
                });
            });
            report.record(0, s, tau, step.loss as f64);
            if s + 1 == self.cfg.steps {
                for (dst, &x) in idx.iter_mut().zip(&step.sort_idx) {
                    *dst = x as u32;
                }
            }
        }
        clock.emit();

        let dups = Permutation::count_duplicates(&idx);
        let perm = if dups == 0 {
            Permutation::from_vec(idx).expect("checked")
        } else {
            let (p, fixed) = repair(&idx);
            report.repaired += fixed;
            report.valid_without_repair = false;
            p
        };
        let arranged = perm.apply_rows(&data.rows, d);
        report.final_dpq = dpq16(&arranged, d, g);
        report.wall_secs = watch.secs();
        Ok(SortOutcome { perm, arranged, report })
    }
}

/// Build a plain-SoftSort config equivalent in step budget to a
/// ShuffleSoftSort config (for the Table 2 bench's fairness note).
pub fn softsort_budget_of(cfg: &ShuffleSoftSortConfig) -> BaselineConfig {
    BaselineConfig {
        grid: cfg.grid,
        steps: cfg.phases * cfg.inner_iters,
        tau: cfg.tau.clone(),
        adam: cfg.adam.clone(),
        seed: cfg.seed,
        gumbel_scale: 0.0,
        threads: cfg.threads,
        simd: cfg.simd,
    }
}

// Re-export for convenience in benches.
pub use super::shuffle::ShuffleStrategy as Strategy;

/// Make a ShuffleSoftSort config that *is* plain SoftSort via policy
/// (identity shuffle, single phase) — used by the ablation bench to verify
/// the equivalence claim.
pub fn softsort_as_policy(mut cfg: ShuffleSoftSortConfig) -> ShuffleSoftSortConfig {
    cfg.shuffle = ShuffleStrategy::Identity;
    cfg
}
