//! L3 coordinator — the paper's contribution, Algorithm 1 (ShuffleSoftSort),
//! plus the three baseline drivers (plain SoftSort, Gumbel-Sinkhorn,
//! Kissing) in `baselines`.
//!
//! Per phase r of R:
//!   1. τ ← geometric decay (schedule::TauSchedule),
//!   2. w ← order-preserving linear ramp (descending — see
//!      `python/tests/test_kernel.py::test_linear_init_conventions`),
//!   3. shuffle the current arrangement (shuffle::ShuffleStrategy),
//!   4. I Adam steps on the `sss_step` compute function (L2+L1), executed
//!      by whichever [`StepBackend`] the driver was built with — the AOT
//!      PJRT artifact or the pure-Rust native implementation — with the
//!      inner τ_i ramp 0.2τ → τ,
//!   5. argmax extraction; if duplicated, extend iterations at sharpened τ
//!      (paper's rule), finally greedy `perm::repair` (counted),
//!   6. compose the phase permutation into `perm::Tracker`.
//!
//! The original data never moves; the tracker owns the arrangement. The
//! drivers never touch the runtime or artifacts directly — all compute
//! dispatches through `&dyn StepBackend` (see `crate::backend`). Each run
//! opens ONE `StepSession` up front and drives every Adam step through it:
//! scratch buffers and the native worker pool are allocated once, the
//! inner step loop is allocation-free (results land in a reusable
//! `SssStep`), and `cfg.threads` sizes the session pool.

pub mod baselines;
pub mod events;
pub mod optimizer;
pub mod schedule;
pub mod shuffle;

use anyhow::Result;

use crate::backend::{SssStep, StepBackend, StepSession, StepShape};
use crate::config::ShuffleSoftSortConfig;
use crate::data::Dataset;
use crate::metrics::dpq16;
use crate::perm::{repair, Permutation, Tracker};
use crate::util::rng::Pcg32;
use crate::util::stats::mean_pairwise_distance;
use crate::util::timer::Stopwatch;

use events::RunReport;
use optimizer::Adam;

/// Result of a sorting run: the learned permutation (grid position →
/// original item index), the arranged data, and the run report.
pub struct SortOutcome {
    pub perm: Permutation,
    pub arranged: Vec<f32>,
    pub report: RunReport,
}

/// The ShuffleSoftSort driver bound to a compute backend and a config.
pub struct ShuffleSoftSort<'b> {
    backend: &'b dyn StepBackend,
    cfg: ShuffleSoftSortConfig,
}

impl<'b> ShuffleSoftSort<'b> {
    pub fn new(backend: &'b dyn StepBackend, cfg: ShuffleSoftSortConfig) -> Result<Self> {
        Ok(ShuffleSoftSort { backend, cfg })
    }

    pub fn config(&self) -> &ShuffleSoftSortConfig {
        &self.cfg
    }

    /// Sort `data` onto the configured grid.
    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        anyhow::ensure!(data.n == g.n(), "dataset N={} != grid {}x{}", data.n, g.h, g.w);
        run_shuffle_softsort(self.backend, data, &self.cfg, "ShuffleSoftSort")
    }
}

/// Shared driver for ShuffleSoftSort and (via `ShuffleStrategy::Identity` +
/// one long phase) plain SoftSort — the paper's point that the methods
/// differ only in L3 policy.
pub(crate) fn run_shuffle_softsort(
    backend: &dyn StepBackend,
    data: &Dataset,
    cfg: &ShuffleSoftSortConfig,
    method: &str,
) -> Result<SortOutcome> {
    let g = cfg.grid;
    let (n, d) = (data.n, data.d);
    let shape = StepShape::new(g, d);
    let watch = Stopwatch::start();
    let mut rng = Pcg32::new(cfg.seed);

    let mut report = RunReport {
        method: method.to_string(),
        n,
        d,
        param_count: n,
        phases: cfg.phases,
        valid_without_repair: true,
        ..Default::default()
    };

    // Loss normalizer: dataset mean pairwise distance (DESIGN §7).
    let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);

    // One session for the whole run: scratch + worker pool allocated here,
    // every step below reuses them (zero steady-state allocations).
    let mut session = backend.session(shape, cfg.threads)?;
    let mut step = SssStep::new_for(shape);
    let mut last_sort_idx = vec![0i32; n];

    let mut tracker = Tracker::new(n);
    let mut adam_cfg = cfg.adam.clone();
    adam_cfg.lr = cfg.effective_lr(d);
    let mut adam = Adam::new(adam_cfg, n);
    let mut w = vec![0.0f32; n];
    let mut x_cur = data.rows.clone();
    let mut x_shuf: Vec<f32> = Vec::with_capacity(n * d);
    let mut x_trial: Vec<f32> = Vec::with_capacity(n * d);
    let mut inv_idx_i32 = vec![0i32; n];
    // Cached hard neighbor metric of the current arrangement (greedy
    // acceptance recomputes only the trial side — §Perf L3 optimization).
    let mut nbr_cur = crate::metrics::mean_neighbor_distance(&x_cur, d, g);

    for r in 0..cfg.phases {
        let tau = cfg.tau.phase_tau(r, cfg.phases);

        // Fresh order-preserving weights + fresh optimizer moments. The
        // ramp has unit spacing, so τ directly reads as the softmax
        // bandwidth in *positions*: τ=8 blends ≈8 grid neighbors, τ<1 is
        // effectively hard. The schedule anneals that bandwidth (see
        // EXPERIMENTS.md §Tuning for the sweep that pinned this down).
        for (i, wi) in w.iter_mut().enumerate() {
            *wi = (n - i) as f32;
        }
        adam.reset();

        let shuf = cfg.shuffle.shuffle_for_phase(r, g, &mut rng);
        shuf.apply_rows_into(&x_cur, d, &mut x_shuf);
        let inv = shuf.inverse();
        for (dst, &v) in inv_idx_i32.iter_mut().zip(inv.as_slice()) {
            *dst = v as i32;
        }

        // Inner SoftSort iterations with the τ_i ramp. The step loop is
        // allocation-free: the session owns all scratch, `step` is reused.
        for i in 0..cfg.inner_iters {
            let tau_i = cfg.tau.inner_tau(tau, i, cfg.inner_iters);
            report.sections.time("execute", || {
                session.sss_step(&w, &x_shuf, &inv_idx_i32, tau_i, norm, &mut step)
            })?;
            let loss = step.loss as f64;
            report.sections.time("adam", || {
                adam.step(&mut w, &step.grad);
            });
            if cfg.record_curve {
                report.record(r, i, tau_i, loss);
            } else {
                report.final_loss = loss;
                report.steps += 1;
            }
            if i + 1 == cfg.inner_iters {
                last_sort_idx.copy_from_slice(&step.sort_idx);
            }
        }

        // Hard extraction with the paper's extension rule.
        let sort_perm = extract_valid(
            session.as_mut(),
            &mut step,
            &w,
            &x_shuf,
            &inv_idx_i32,
            tau,
            norm,
            &last_sort_idx,
            cfg.max_extensions,
            &mut adam,
            &mut report,
        )?;

        // Greedy acceptance: adopt the phase only if the *hard* neighbor
        // metric does not regress. The trial arrangement is the phase
        // permutation applied to the CURRENT arrangement (no tracker clone,
        // no re-arrangement from the originals — §Perf L3 optimization),
        // and the current metric is cached.
        if cfg.greedy_accept {
            let (accept, nbr_trial) = report.sections.time("accept", || {
                let phase = inv.compose(&sort_perm).compose(&shuf);
                phase.apply_rows_into(&x_cur, d, &mut x_trial);
                let nbr_trial = crate::metrics::mean_neighbor_distance(&x_trial, d, g);
                (nbr_trial <= nbr_cur + 1e-12, nbr_trial)
            });
            if accept {
                tracker.record_phase(&shuf, &sort_perm);
                std::mem::swap(&mut x_cur, &mut x_trial);
                nbr_cur = nbr_trial;
            } else {
                report.rejected_phases += 1;
            }
        } else {
            // Maintain the live arrangement by applying the phase
            // permutation into the reusable trial buffer — no per-phase
            // allocation, no O(N·d) re-arrangement from the originals
            // (matches the greedy branch; tracker invariant:
            // x_new = (shuf⁻¹ ∘ sort ∘ shuf)(x_old)).
            report.sections.time("compose", || {
                tracker.record_phase(&shuf, &sort_perm);
                let phase = inv.compose(&sort_perm).compose(&shuf);
                phase.apply_rows_into(&x_cur, d, &mut x_trial);
            });
            std::mem::swap(&mut x_cur, &mut x_trial);
        }
    }

    let arranged = x_cur;
    report.final_dpq = report
        .sections
        .time("dpq", || dpq16(&arranged, d, g));
    report.wall_secs = watch.secs();
    Ok(SortOutcome { perm: tracker.perm().clone(), arranged, report })
}

/// Argmax → validity check → extension iterations at sharpened τ → repair.
/// Extensions run through the same run-level session (`step` is the run's
/// reusable out buffer).
#[allow(clippy::too_many_arguments)]
fn extract_valid(
    session: &mut dyn StepSession,
    step: &mut SssStep,
    w: &[f32],
    x_shuf: &[f32],
    inv_idx: &[i32],
    tau: f32,
    norm: f32,
    first_idx: &[i32],
    max_extensions: usize,
    adam: &mut Adam,
    report: &mut RunReport,
) -> Result<Permutation> {
    let to_u32 = |v: &[i32]| v.iter().map(|&x| x as u32).collect::<Vec<u32>>();
    let mut idx = to_u32(first_idx);
    if Permutation::count_duplicates(&idx) == 0 {
        return Ok(Permutation::from_vec(idx).expect("checked"));
    }

    // Extend: keep optimizing at a sharpening temperature until valid.
    let mut w = w.to_vec();
    let mut tau_ext = tau;
    for _ in 0..max_extensions {
        report.extensions += 1;
        tau_ext *= 0.6;
        report.sections.time("execute", || {
            session.sss_step(&w, x_shuf, inv_idx, tau_ext, norm, step)
        })?;
        adam.step(&mut w, &step.grad);
        idx.clear();
        idx.extend(step.sort_idx.iter().map(|&x| x as u32));
        if Permutation::count_duplicates(&idx) == 0 {
            return Ok(Permutation::from_vec(idx).expect("checked"));
        }
    }

    // Rare fallback: deterministic greedy repair (counted in the report —
    // this is what the paper's "Stability" row measures).
    let (perm, fixed) = repair(&idx);
    report.repaired += fixed;
    report.valid_without_repair = false;
    Ok(perm)
}
