//! L3 coordinator — the paper's contribution, Algorithm 1 (ShuffleSoftSort),
//! plus the three baseline drivers (plain SoftSort, Gumbel-Sinkhorn,
//! Kissing) in `baselines`.
//!
//! Per phase r of R:
//!   1. τ ← geometric decay (schedule::TauSchedule),
//!   2. shuffle the current arrangement (shuffle::ShuffleStrategy),
//!   3. hand the shuffled arrangement to the run's
//!      [`executor::PhaseExecutor`], which owns everything inside a phase:
//!      the fresh order-preserving weight ramp, I Adam steps on the
//!      `sss_step` compute function (L2+L1) with the inner τ_i ramp,
//!      argmax extraction, the paper's extension rule, and greedy
//!      `perm::repair` (counted). Two executors exist: `Full` (one
//!      `StepSession` over the whole N — today's classic loop) and
//!      `Tiled { tile_n }` (independent per-tile SoftSort solves over
//!      contiguous grid bands, O(Σ n_b²) per step instead of O(N²); see
//!      `executor.rs` and `cfg.tile_n`),
//!   4. compose the phase permutation into `perm::Tracker` (optionally
//!      gated by greedy acceptance on the hard neighbor metric).
//!
//! The original data never moves; the tracker owns the arrangement. The
//! drivers never touch the runtime or artifacts directly — all compute
//! dispatches through `&dyn StepBackend` (see `crate::backend`). Each run
//! opens its sessions once up front (one per problem shape), the inner
//! step loop is allocation-free, and `cfg.threads` sizes the session
//! pool(s).

pub mod baselines;
pub mod events;
pub(crate) mod executor;
pub mod optimizer;
pub mod schedule;
pub mod shuffle;

use anyhow::Result;

use crate::backend::StepBackend;
use crate::config::ShuffleSoftSortConfig;
use crate::data::Dataset;
use crate::metrics::dpq16;
use crate::perm::{Permutation, Tracker};
use crate::trace;
use crate::util::rng::Pcg32;
use crate::util::stats::mean_pairwise_distance;
use crate::util::timer::Stopwatch;

use events::RunReport;

/// Result of a sorting run: the learned permutation (grid position →
/// original item index), the arranged data, and the run report.
pub struct SortOutcome {
    pub perm: Permutation,
    pub arranged: Vec<f32>,
    pub report: RunReport,
}

/// The ShuffleSoftSort driver bound to a compute backend and a config.
pub struct ShuffleSoftSort<'b> {
    backend: &'b dyn StepBackend,
    cfg: ShuffleSoftSortConfig,
}

impl<'b> ShuffleSoftSort<'b> {
    pub fn new(backend: &'b dyn StepBackend, cfg: ShuffleSoftSortConfig) -> Result<Self> {
        Ok(ShuffleSoftSort { backend, cfg })
    }

    pub fn config(&self) -> &ShuffleSoftSortConfig {
        &self.cfg
    }

    /// Sort `data` onto the configured grid.
    pub fn sort(&self, data: &Dataset) -> Result<SortOutcome> {
        let g = self.cfg.grid;
        anyhow::ensure!(data.n == g.n(), "dataset N={} != grid {}x{}", data.n, g.h, g.w);
        run_shuffle_softsort(self.backend, data, &self.cfg, "ShuffleSoftSort")
    }
}

/// Shared driver for ShuffleSoftSort and (via `ShuffleStrategy::Identity` +
/// one long phase) plain SoftSort — the paper's point that the methods
/// differ only in L3 policy.
pub(crate) fn run_shuffle_softsort(
    backend: &dyn StepBackend,
    data: &Dataset,
    cfg: &ShuffleSoftSortConfig,
    method: &str,
) -> Result<SortOutcome> {
    let g = cfg.grid;
    let (n, d) = (data.n, data.d);
    let watch = Stopwatch::start();
    let mut rng = Pcg32::new(cfg.seed);

    let mut report = RunReport {
        method: method.to_string(),
        n,
        d,
        param_count: n,
        phases: cfg.phases,
        valid_without_repair: true,
        ..Default::default()
    };

    // Loss normalizer: dataset mean pairwise distance (DESIGN §7).
    let norm = mean_pairwise_distance(&data.rows, n, d, 20_000, &mut rng);

    // The phase executor owns all inner-loop compute state — sessions,
    // optimizer, step scratch — allocated once here and reused per phase.
    let mut exec = executor::executor_for(backend, cfg, d, norm)?;
    report.tiles = exec.tiles();
    exec.annotate(&mut report);
    if let Some(note) = &cfg.tile_note {
        report.notes.push(note.clone());
    }

    let mut tracker = Tracker::new(n);
    let mut x_cur = data.rows.clone();
    let mut x_shuf: Vec<f32> = Vec::with_capacity(n * d);
    let mut x_trial: Vec<f32> = Vec::with_capacity(n * d);
    let mut inv_idx_i32 = vec![0i32; n];
    // Cached hard neighbor metric of the current arrangement (greedy
    // acceptance recomputes only the trial side — §Perf L3 optimization).
    let mut nbr_cur = crate::metrics::mean_neighbor_distance(&x_cur, d, g);

    // Phase spans are sampled so long runs (phases in the tens of
    // thousands) keep at most ~64 of them per trace — the step-family
    // clocks inside the executor aggregate the rest regardless.
    let trace_parent = trace::current();
    let trace_stride = (cfg.phases / 64).max(1);

    for r in 0..cfg.phases {
        let tau = cfg.tau.phase_tau(r, cfg.phases);
        let mut pspan = trace::Span::child_of(
            trace_parent.filter(|_| r % trace_stride == 0),
            "phase",
        );
        pspan.attr_u64("phase", r as u64);
        pspan.attr_f64("tau", tau as f64);
        let rejected_before = report.rejected_phases;

        let shuf = cfg.shuffle.shuffle_for_phase(r, g, &mut rng);
        shuf.apply_rows_into(&x_cur, d, &mut x_shuf);
        let inv = shuf.inverse();
        for (dst, &v) in inv_idx_i32.iter_mut().zip(inv.as_slice()) {
            *dst = v as i32;
        }

        // Inner optimization + hard extraction, executor-specific.
        let sort_perm = exec.run_phase(
            r,
            tau,
            &x_shuf,
            &shuf,
            &inv,
            &inv_idx_i32,
            &mut report,
            pspan.ctx(),
        )?;

        // Greedy acceptance: adopt the phase only if the *hard* neighbor
        // metric does not regress. The trial arrangement is the phase
        // permutation applied to the CURRENT arrangement (no tracker clone,
        // no re-arrangement from the originals — §Perf L3 optimization),
        // and the current metric is cached.
        if cfg.greedy_accept {
            let (accept, nbr_trial) = report.sections.time("accept", || {
                let phase = inv.compose(&sort_perm).compose(&shuf);
                phase.apply_rows_into(&x_cur, d, &mut x_trial);
                let nbr_trial = crate::metrics::mean_neighbor_distance(&x_trial, d, g);
                (nbr_trial <= nbr_cur + 1e-12, nbr_trial)
            });
            if accept {
                tracker.record_phase(&shuf, &sort_perm);
                std::mem::swap(&mut x_cur, &mut x_trial);
                nbr_cur = nbr_trial;
            } else {
                report.rejected_phases += 1;
            }
        } else {
            // Maintain the live arrangement by applying the phase
            // permutation into the reusable trial buffer — no per-phase
            // allocation, no O(N·d) re-arrangement from the originals
            // (matches the greedy branch; tracker invariant:
            // x_new = (shuf⁻¹ ∘ sort ∘ shuf)(x_old)).
            report.sections.time("compose", || {
                tracker.record_phase(&shuf, &sort_perm);
                let phase = inv.compose(&sort_perm).compose(&shuf);
                phase.apply_rows_into(&x_cur, d, &mut x_trial);
            });
            std::mem::swap(&mut x_cur, &mut x_trial);
        }

        if pspan.is_recording() {
            if let Some(p) = report.curve.last() {
                pspan.attr_f64("loss", p.loss);
            }
            pspan.attr_u64(
                "accepted",
                (report.rejected_phases == rejected_before) as u64,
            );
        }
        pspan.end();
    }

    let arranged = x_cur;
    report.final_dpq = report
        .sections
        .time("dpq", || dpq16(&arranged, d, g));
    report.wall_secs = watch.secs();
    Ok(SortOutcome { perm: tracker.perm().clone(), arranged, report })
}
