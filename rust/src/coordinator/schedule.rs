//! Temperature schedules (Algorithm 1).
//!
//! Outer: τ decays geometrically from τ_start=1 to τ_end=0.1 across the R
//! phases. Inner: within a phase's I SoftSort iterations, τ_i ramps *up*
//! from 0.2·τ to τ — the small initial temperature keeps the fresh linear
//! weights locked to the previous order before exploration widens.

#[derive(Clone, Debug, PartialEq)]
pub struct TauSchedule {
    pub tau_start: f32,
    pub tau_end: f32,
    /// Inner ramp start as a fraction of the phase temperature (paper: 0.2).
    pub inner_frac: f32,
}

impl Default for TauSchedule {
    fn default() -> Self {
        TauSchedule { tau_start: 1.0, tau_end: 0.1, inner_frac: 0.2 }
    }
}

impl TauSchedule {
    /// Phase temperature: τ_start · (τ_end/τ_start)^(r/R)  (r is 1-based as
    /// in Algorithm 1's exponent r/R; r=R gives exactly τ_end).
    pub fn phase_tau(&self, r: usize, total: usize) -> f32 {
        let total = total.max(1);
        let t = (r + 1) as f32 / total as f32;
        self.tau_start * (self.tau_end / self.tau_start).powf(t)
    }

    /// Inner iteration temperature: linear ramp inner_frac·τ → τ over I.
    pub fn inner_tau(&self, phase_tau: f32, i: usize, inner_total: usize) -> f32 {
        if inner_total <= 1 {
            return phase_tau;
        }
        let t = i as f32 / (inner_total - 1) as f32;
        phase_tau * (self.inner_frac + (1.0 - self.inner_frac) * t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_tau_endpoints_and_monotonicity() {
        let s = TauSchedule::default();
        let r_total = 100;
        assert!((s.phase_tau(r_total - 1, r_total) - 0.1).abs() < 1e-6);
        assert!(s.phase_tau(0, r_total) < 1.0);
        for r in 1..r_total {
            assert!(s.phase_tau(r, r_total) < s.phase_tau(r - 1, r_total));
        }
    }

    #[test]
    fn inner_ramp_bounds() {
        let s = TauSchedule::default();
        let tau = 0.5;
        assert!((s.inner_tau(tau, 0, 4) - 0.1).abs() < 1e-6); // 0.2 · 0.5
        assert!((s.inner_tau(tau, 3, 4) - 0.5).abs() < 1e-6);
        assert!(s.inner_tau(tau, 1, 4) < s.inner_tau(tau, 2, 4));
        assert_eq!(s.inner_tau(tau, 0, 1), tau);
    }
}
