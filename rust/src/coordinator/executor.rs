//! Phase executors — the per-phase inner-optimization strategy of the
//! ShuffleSoftSort driver.
//!
//! `run_shuffle_softsort` owns the outer policy loop (τ schedule, shuffle,
//! greedy acceptance, permutation tracking); everything *inside* a phase —
//! the I Adam steps on `sss_step`, argmax extraction, the paper's
//! extension rule, greedy repair — is delegated to a [`PhaseExecutor`]:
//!
//! * [`FullExecutor`] — the classic loop: one `StepSession` for the whole
//!   `(N, d, h, w)` problem, every phase optimizes all N weights against
//!   the full grid loss. Per-step cost and scratch are O(N²) (the SoftSort
//!   matrix row sweep), which stops being payable around N ≈ 4k.
//! * [`TiledExecutor`] — the scaling path. Each phase partitions the grid
//!   into contiguous bands of ≈`tile_n` cells (whole grid rows — every
//!   band an `h_b × w` sub-grid — or 1-row column segments when the grid
//!   is wider than a tile, so the tile_n² bound holds on any shape),
//!   pulls each band's shuffled items into a tile-local sub-problem, and
//!   runs an *independent* SoftSort inner loop + extraction per tile:
//!   O(Σ n_b²) work and O(tile_n²)-bounded step scratch per phase instead
//!   of O(N²). The per-tile permutations compose block-diagonally (in
//!   tile-local coordinates) into one always-valid phase permutation, and
//!   the *next* phase's shuffle moves items across tile boundaries — the
//!   same mechanism by which shuffling restores global mobility between
//!   cheap local solves in the paper's 1-D story. Tiles are dispatched in
//!   parallel over a [`WorkerPool`] when the backend's sessions can move
//!   across threads (native); composition folds per-tile results in tile
//!   index order, so results never depend on dispatch interleaving.
//!
//! Degeneracy contract (tested at driver and Engine level): a tile plan
//! with **one** tile reproduces the full executor **bit-identically** —
//! the single band is the whole grid, the tile-local gather is the
//! identity, and both executors drive the same [`run_inner_loop`] helper,
//! so every f32 rounding matches.

use std::sync::Mutex;

use anyhow::{anyhow, ensure, Context, Result};

use crate::backend::pool::WorkerPool;
use crate::backend::{SessionOpts, SssStep, StepBackend, StepSession, StepShape};
use crate::config::ShuffleSoftSortConfig;
use crate::grid::GridShape;
use crate::perm::{repair, Permutation};
use crate::trace;
use crate::util::timer::Sections;

use super::events::RunReport;
use super::optimizer::Adam;

/// One phase's inner optimization: turn the shuffled arrangement into the
/// phase sort permutation (over shuffled slots). Implementations own all
/// per-phase compute state (sessions, optimizer, scratch).
pub(crate) trait PhaseExecutor {
    /// Tiles per phase (1 for the full executor).
    fn tiles(&self) -> usize;

    /// Run phase `r` at temperature `tau` over `x_shuf` (the shuffled
    /// arrangement) and return the sort permutation in shuffled-slot
    /// coordinates. `shuf`/`inv` are the phase shuffle and its inverse
    /// (`inv_idx` is `inv` pre-widened to the step's i32 argument).
    /// `trace_ctx` is the phase span the executor's tile spans hang under
    /// (`None` — the usual case — records nothing; sampling decisions made
    /// by the driver flow through it).
    #[allow(clippy::too_many_arguments)]
    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        shuf: &Permutation,
        inv: &Permutation,
        inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation>;
}

/// Build the executor the config asks for: `tile_n = None` → full,
/// `Some(t)` → tiled with ≈t items per tile.
pub(crate) fn executor_for(
    backend: &dyn StepBackend,
    cfg: &ShuffleSoftSortConfig,
    d: usize,
    norm: f32,
) -> Result<Box<dyn PhaseExecutor>> {
    let exec: Box<dyn PhaseExecutor> = match cfg.tile_n {
        None => Box::new(FullExecutor::new(backend, cfg, d, norm)?),
        Some(tile_n) => Box::new(TiledExecutor::new(backend, cfg, d, norm, tile_n)?),
    };
    Ok(exec)
}

// ---------------------------------------------------------------------------
// The shared inner loop.
// ---------------------------------------------------------------------------

/// Run-level reusable buffers for one inner-loop consumer: weights, loss
/// trace, last hard draft, and the extraction scratch (`idx`/`w_ext`) that
/// used to be reallocated per `extract_valid` call — hoisted here so the
/// extension iterations are as allocation-free as the step loop.
#[derive(Default)]
struct LoopBufs {
    w: Vec<f32>,
    losses: Vec<f64>,
    last_idx: Vec<i32>,
    idx: Vec<u32>,
    w_ext: Vec<f32>,
}

/// Validity bookkeeping of one inner loop (per phase or per tile).
#[derive(Clone, Copy, Default)]
struct LoopStats {
    extensions: usize,
    repaired: usize,
}

/// The phase kernel both executors share: fresh order-preserving weights,
/// I Adam steps on `sss_step` with the τ_i ramp, then argmax extraction
/// with the paper's extension rule and greedy repair as the last resort.
/// Arithmetic (and therefore every f32 rounding) is identical to the
/// pre-executor driver loop; this function's steady state allocates
/// nothing — only the returned `Permutation` owns fresh memory. (The tiled
/// executor's per-tile bookkeeping around it — the losses clone, the
/// composed sort vector — allocates O(I) and O(N) per phase, the same
/// order the pre-executor extraction already paid.)
#[allow(clippy::too_many_arguments)]
fn run_inner_loop<S: StepSession + ?Sized>(
    session: &mut S,
    step: &mut SssStep,
    adam: &mut Adam,
    bufs: &mut LoopBufs,
    x: &[f32],
    inv_idx: &[i32],
    tau: f32,
    norm: f32,
    cfg: &ShuffleSoftSortConfig,
    trace_ctx: Option<trace::SpanContext>,
) -> Result<(Permutation, LoopStats)> {
    // Step-family telemetry: aggregated per family and emitted as one
    // span per family at loop end — inert (no clock reads, no records)
    // unless tracing is on AND this loop was handed a parent span.
    let mut clock = trace::StepClock::start(trace_ctx);
    let n = inv_idx.len();
    // Fresh order-preserving weights + fresh optimizer moments. The ramp
    // has unit spacing, so τ directly reads as the softmax bandwidth in
    // *positions* (see EXPERIMENTS.md §Tuning).
    bufs.w.clear();
    bufs.w.extend((0..n).map(|i| (n - i) as f32));
    adam.reset();
    bufs.losses.clear();
    // Seed the hard draft with zeros (matching the pre-executor driver's
    // `vec![0i32; n]`), so a degenerate `inner_iters=0` config still
    // reaches the extension/repair path instead of returning an empty
    // permutation.
    bufs.last_idx.clear();
    bufs.last_idx.resize(n, 0);

    for i in 0..cfg.inner_iters {
        let tau_i = cfg.tau.inner_tau(tau, i, cfg.inner_iters);
        clock.time(trace::FAM_SSS, || session.sss_step(&bufs.w, x, inv_idx, tau_i, norm, step))?;
        bufs.losses.push(step.loss as f64);
        clock.time(trace::FAM_ADAM, || adam.step(&mut bufs.w, &step.grad));
        if i + 1 == cfg.inner_iters {
            bufs.last_idx.clear();
            bufs.last_idx.extend_from_slice(&step.sort_idx);
        }
    }

    // Hard extraction with the paper's extension rule.
    let mut stats = LoopStats::default();
    bufs.idx.clear();
    bufs.idx.extend(bufs.last_idx.iter().map(|&v| v as u32));
    if Permutation::count_duplicates(&bufs.idx) == 0 {
        clock.emit();
        return Ok((Permutation::from_vec(bufs.idx.clone()).expect("checked"), stats));
    }

    // Extend: keep optimizing a weight copy at a sharpening temperature
    // (same Adam moments) until valid.
    bufs.w_ext.clear();
    bufs.w_ext.extend_from_slice(&bufs.w);
    let mut tau_ext = tau;
    for _ in 0..cfg.max_extensions {
        stats.extensions += 1;
        tau_ext *= 0.6;
        clock
            .time(trace::FAM_SSS, || session.sss_step(&bufs.w_ext, x, inv_idx, tau_ext, norm, step))?;
        clock.time(trace::FAM_ADAM, || adam.step(&mut bufs.w_ext, &step.grad));
        bufs.idx.clear();
        bufs.idx.extend(step.sort_idx.iter().map(|&v| v as u32));
        if Permutation::count_duplicates(&bufs.idx) == 0 {
            clock.emit();
            return Ok((Permutation::from_vec(bufs.idx.clone()).expect("checked"), stats));
        }
    }
    clock.emit();

    // Rare fallback: deterministic greedy repair (counted in the report —
    // this is what the paper's "Stability" row measures).
    let (perm, fixed) = repair(&bufs.idx);
    stats.repaired = fixed;
    Ok((perm, stats))
}

/// Replay one phase's losses and validity stats into the report. Shared by
/// both executors so the report shape is executor-independent (tiled
/// phases record the per-iteration mean across tiles — identical to the
/// full trace when there is one tile).
fn record_phase(
    report: &mut RunReport,
    cfg: &ShuffleSoftSortConfig,
    r: usize,
    tau: f32,
    losses: &[f64],
    stats: LoopStats,
) {
    for (i, &loss) in losses.iter().enumerate() {
        let tau_i = cfg.tau.inner_tau(tau, i, cfg.inner_iters);
        if cfg.record_curve {
            report.record(r, i, tau_i, loss);
        } else {
            report.final_loss = loss;
            report.steps += 1;
        }
    }
    report.extensions += stats.extensions;
    if stats.repaired > 0 {
        report.repaired += stats.repaired;
        report.valid_without_repair = false;
    }
}

/// Effective Adam config for a d-dimensional run (the lr auto-scale).
fn adam_for(cfg: &ShuffleSoftSortConfig, d: usize, n: usize) -> Adam {
    let mut adam_cfg = cfg.adam.clone();
    adam_cfg.lr = cfg.effective_lr(d);
    Adam::new(adam_cfg, n)
}

// ---------------------------------------------------------------------------
// Full executor: one session, the whole problem per phase.
// ---------------------------------------------------------------------------

pub(crate) struct FullExecutor {
    cfg: ShuffleSoftSortConfig,
    norm: f32,
    session: Box<dyn StepSession>,
    step: SssStep,
    adam: Adam,
    bufs: LoopBufs,
}

impl FullExecutor {
    pub fn new(
        backend: &dyn StepBackend,
        cfg: &ShuffleSoftSortConfig,
        d: usize,
        norm: f32,
    ) -> Result<Self> {
        let shape = StepShape::new(cfg.grid, d);
        // One session for the whole run: scratch + worker pool allocated
        // here, every phase reuses them (zero steady-state allocations).
        let session = backend.session(shape, cfg.session_opts())?;
        Ok(FullExecutor {
            cfg: cfg.clone(),
            norm,
            session,
            step: SssStep::new_for(shape),
            adam: adam_for(cfg, d, shape.n),
            bufs: LoopBufs::default(),
        })
    }
}

impl PhaseExecutor for FullExecutor {
    fn tiles(&self) -> usize {
        1
    }

    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        _shuf: &Permutation,
        _inv: &Permutation,
        inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation> {
        // The full executor is one whole-problem tile, and traces as one:
        // sampled phases get a single `tile` span covering the inner loop.
        let mut tspan = trace::Span::child_of(trace_ctx, "tile");
        tspan.attr_u64("tile", 0);
        tspan.attr_u64("n", inv_idx.len() as u64);
        let tile_ctx = tspan.ctx();
        // The "execute" section now covers the whole inner loop — steps,
        // optimizer and extraction — where the pre-executor driver split
        // out a separate "adam" section (the baselines still do).
        let (perm, stats) = report.sections.time("execute", || {
            run_inner_loop(
                self.session.as_mut(),
                &mut self.step,
                &mut self.adam,
                &mut self.bufs,
                x_shuf,
                inv_idx,
                tau,
                self.norm,
                &self.cfg,
                tile_ctx,
            )
        })?;
        tspan.end();
        record_phase(report, &self.cfg, r, tau, &self.bufs.losses, stats);
        Ok(perm)
    }
}

// ---------------------------------------------------------------------------
// Tile plan: contiguous grid bands, each a sub-grid.
// ---------------------------------------------------------------------------

/// One tile: a contiguous row-major grid-position band `[pos0,
/// pos0 + shape.n)` that is itself a valid sub-grid, plus the index of its
/// shape in the plan's deduplicated shape list (ragged splits have at most
/// two distinct shapes, so sessions/scratch memoize per shape).
#[derive(Clone, Copy, Debug)]
pub(crate) struct TileSpec {
    pub pos0: usize,
    pub shape: StepShape,
    pub shape_idx: usize,
}

/// How a grid splits into tiles for a requested per-tile item count.
#[derive(Debug)]
pub(crate) struct TilePlan {
    pub tiles: Vec<TileSpec>,
    /// Deduplicated tile shapes (`TileSpec::shape_idx` indexes this).
    pub shapes: Vec<StepShape>,
    /// Grid position → tile index.
    pub tile_of: Vec<u32>,
}

impl TilePlan {
    /// Split `g` into contiguous position bands of ≈`tile_n` cells, each a
    /// valid sub-grid: whole grid rows (`h_b × w` bands) when `tile_n >=
    /// w`, column segments of single rows (`1 × n_b` chains — contiguous
    /// in row-major order, so still position bands) when the grid is wider
    /// than a tile. The latter keeps the O(tile_n²) per-step work/scratch
    /// contract on wide grids instead of silently rounding a tile up to a
    /// full `w`-cell row. A trailing remainder of a single row/cell is
    /// absorbed into the previous tile so every tile holds ≥ 2 items (a
    /// 1-item SoftSort is degenerate). `tile_n >= n` yields exactly one
    /// tile of the full grid shape.
    pub fn new(g: GridShape, d: usize, tile_n: usize) -> Self {
        let (h, w) = (g.h, g.w);
        let mut tiles: Vec<TileSpec> = Vec::new();
        let mut shapes: Vec<StepShape> = Vec::new();
        let mut push = |pos0: usize, shape: StepShape| {
            let shape_idx = match shapes.iter().position(|s| *s == shape) {
                Some(i) => i,
                None => {
                    shapes.push(shape);
                    shapes.len() - 1
                }
            };
            tiles.push(TileSpec { pos0, shape, shape_idx });
        };
        // 1-D chunking of `count` cells starting at `base`, ≈`per` each,
        // ≥ 2 each (trailing singleton absorbed into the last chunk).
        fn chunk_row(
            base: usize,
            count: usize,
            per: usize,
            d: usize,
            push: &mut dyn FnMut(usize, StepShape),
        ) {
            let per = per.clamp(2, count.max(2));
            let mut c0 = 0usize;
            while c0 < count {
                let mut take = per.min(count - c0);
                if count - c0 - take == 1 {
                    take += 1;
                }
                push(base + c0, StepShape { n: take, d, h: 1, w: take });
                c0 += take;
            }
        }

        if h > 1 && tile_n.max(1) >= w {
            // Whole-row bands of ≈tile_n/w rows.
            let rows = (tile_n.max(1) / w).max(1).max(2usize.div_ceil(w));
            let mut r0 = 0usize;
            while r0 < h {
                let mut take = rows.min(h - r0);
                if (h - r0 - take) * w == 1 {
                    take += 1;
                }
                push(r0 * w, StepShape { n: take * w, d, h: take, w });
                r0 += take;
            }
        } else if h == 1 {
            chunk_row(0, w, tile_n.max(1), d, &mut push);
        } else {
            // Wide grid, tile_n < w: column segments, one row at a time.
            for r in 0..h {
                chunk_row(r * w, w, tile_n.max(1), d, &mut push);
            }
        }

        let mut tile_of = vec![0u32; g.n()];
        for (b, t) in tiles.iter().enumerate() {
            for p in t.pos0..t.pos0 + t.shape.n {
                tile_of[p] = b as u32;
            }
        }
        TilePlan { tiles, shapes, tile_of }
    }
}

// ---------------------------------------------------------------------------
// Tiled executor.
// ---------------------------------------------------------------------------

/// Per-shape compute state of one tile worker (session kept separately —
/// its `Send`-ness differs between the parallel and sequential paths).
struct ShapeSlot {
    shape: StepShape,
    step: SssStep,
    adam: Adam,
}

impl ShapeSlot {
    fn new(cfg: &ShuffleSoftSortConfig, shape: StepShape) -> Self {
        ShapeSlot { shape, step: SssStep::new_for(shape), adam: adam_for(cfg, shape.d, shape.n) }
    }
}

/// One tile worker's compute state: per-shape sessions + scratch, and the
/// gather buffers for the tile currently being solved. `S` is the session
/// payload type — `dyn StepSession + Send` for pool-dispatched workers
/// (each locked only by the one pool thread its index maps to), plain
/// `dyn StepSession` for the sequential fallback — so both dispatch paths
/// share this struct and [`TileWorker::run_tile`].
struct TileWorker<S: ?Sized> {
    sessions: Vec<Box<S>>,
    slots: Vec<ShapeSlot>,
    bufs: LoopBufs,
    x_tile: Vec<f32>,
    inv_tile: Vec<i32>,
}

impl<S: StepSession + ?Sized> TileWorker<S> {
    fn new(cfg: &ShuffleSoftSortConfig, shapes: &[StepShape], sessions: Vec<Box<S>>) -> Self {
        TileWorker {
            sessions,
            slots: shapes.iter().map(|&s| ShapeSlot::new(cfg, s)).collect(),
            bufs: LoopBufs::default(),
            x_tile: Vec::new(),
            inv_tile: Vec::new(),
        }
    }

    /// Gather + solve one tile. `members` are the tile's shuffled slots in
    /// ascending order; `rank` maps a shuffled slot to its tile-local
    /// index; `inv_perm` is the phase's global inverse shuffle, so
    /// `rank[inv_perm[pos]]` is the tile-local slot shown at grid position
    /// `pos` — the restriction of the full step's `inv_idx` to the band.
    #[allow(clippy::too_many_arguments)]
    fn run_tile(
        &mut self,
        tile: usize,
        spec: &TileSpec,
        x_shuf: &[f32],
        inv_perm: &[u32],
        members: &[u32],
        rank: &[u32],
        cfg: &ShuffleSoftSortConfig,
        tau: f32,
        norm: f32,
        d: usize,
        phase_ctx: Option<trace::SpanContext>,
    ) -> Result<TileOutcome> {
        let mut span = trace::Span::child_of(phase_ctx, "tile");
        span.attr_u64("tile", tile as u64);
        span.attr_u64("n", members.len() as u64);
        let slot = &mut self.slots[spec.shape_idx];
        let n_b = members.len();
        debug_assert_eq!(n_b, slot.shape.n);
        self.x_tile.clear();
        for &j in members {
            let s = j as usize * d;
            self.x_tile.extend_from_slice(&x_shuf[s..s + d]);
        }
        self.inv_tile.clear();
        self.inv_tile
            .extend((0..n_b).map(|q| rank[inv_perm[spec.pos0 + q] as usize] as i32));
        // Per-tile sections, folded into `RunReport.sections` in
        // tile-index order by the fold — the tile timings used to be
        // dropped on the floor here, leaving tiled runs with a bare
        // wall-clock "execute" entry.
        let mut sections = Sections::new();
        let (perm, stats) = sections.time("execute", || {
            run_inner_loop(
                self.sessions[spec.shape_idx].as_mut(),
                &mut slot.step,
                &mut slot.adam,
                &mut self.bufs,
                &self.x_tile,
                &self.inv_tile,
                tau,
                norm,
                cfg,
                span.ctx(),
            )
        })?;
        span.end();
        Ok(TileOutcome { perm, losses: self.bufs.losses.clone(), stats, sections })
    }
}

/// Everything one finished tile hands back to the fold.
struct TileOutcome {
    perm: Permutation,
    losses: Vec<f64>,
    stats: LoopStats,
    sections: Sections,
}

/// A tile's result slot: written once by whichever worker ran the tile,
/// taken by the tile-index-ordered fold.
type TileSlot = Mutex<Option<Result<TileOutcome>>>;

pub(crate) struct TiledExecutor {
    cfg: ShuffleSoftSortConfig,
    d: usize,
    norm: f32,
    plan: TilePlan,
    /// Tile → its shuffled slots this phase, ascending (rebuilt per phase).
    members: Vec<Vec<u32>>,
    /// Shuffled slot → tile-local rank (companion to `members`).
    rank: Vec<u32>,
    /// Per-tile result slots; disjoint writes, folded in tile order.
    results: Vec<TileSlot>,
    /// Parallel workers + their pool (`None` → `seq` is used instead).
    par_workers: Vec<Mutex<TileWorker<dyn StepSession + Send>>>,
    pool: Option<WorkerPool>,
    seq: Option<TileWorker<dyn StepSession>>,
    agg_losses: Vec<f64>,
}

impl TiledExecutor {
    pub fn new(
        backend: &dyn StepBackend,
        cfg: &ShuffleSoftSortConfig,
        d: usize,
        norm: f32,
        tile_n: usize,
    ) -> Result<Self> {
        let plan = TilePlan::new(cfg.grid, d, tile_n);
        let b = plan.tiles.len();
        // Parallelism budget: the explicit `threads` knob, else what the
        // backend would give one full-problem session — so a backend the
        // engine capped for batching caps tile dispatch identically.
        let budget = cfg.threads.unwrap_or_else(|| backend.default_threads()).max(1);
        let wanted = budget.clamp(1, b);

        // Parallel tile dispatch needs sessions that may cross threads;
        // back off to the sequential path when the backend cannot provide
        // them (results are identical either way — the fold is
        // tile-index-ordered and tiles are independent).
        let mut par_workers = Vec::new();
        if wanted > 1 {
            // Split the row-thread budget across tile workers so tile
            // parallelism × in-tile row parallelism ≈ the budget.
            let per_tile_threads = (budget / wanted).max(1);
            'build: for _ in 0..wanted {
                let mut sessions = Vec::with_capacity(plan.shapes.len());
                for &shape in &plan.shapes {
                    let opts = SessionOpts { threads: Some(per_tile_threads), simd: cfg.simd };
                    match backend.session_sendable(shape, opts)? {
                        Some(s) => sessions.push(s),
                        None => {
                            par_workers.clear();
                            break 'build;
                        }
                    }
                }
                par_workers.push(Mutex::new(TileWorker::new(cfg, &plan.shapes, sessions)));
            }
        }
        let (pool, seq) = if par_workers.is_empty() {
            let mut sessions = Vec::with_capacity(plan.shapes.len());
            for &shape in &plan.shapes {
                sessions.push(backend.session(shape, cfg.session_opts())?);
            }
            (None, Some(TileWorker::new(cfg, &plan.shapes, sessions)))
        } else {
            (Some(WorkerPool::new(par_workers.len() - 1)), None)
        };

        Ok(TiledExecutor {
            cfg: cfg.clone(),
            d,
            norm,
            members: (0..b).map(|_| Vec::new()).collect(),
            rank: vec![0; cfg.grid.n()],
            results: (0..b).map(|_| Mutex::new(None)).collect(),
            plan,
            par_workers,
            pool,
            seq,
            agg_losses: Vec::new(),
        })
    }

    /// Dispatch every tile (parallel when a pool exists) and leave each
    /// outcome in its `results` slot.
    fn dispatch_tiles(
        &mut self,
        tau: f32,
        x_shuf: &[f32],
        inv: &Permutation,
        phase_ctx: Option<trace::SpanContext>,
    ) -> Result<()> {
        let plan = &self.plan;
        let members = &self.members;
        let rank = &self.rank;
        let results = &self.results;
        let cfg = &self.cfg;
        let (norm, d) = (self.norm, self.d);
        let inv_perm = inv.as_slice();
        let b_total = plan.tiles.len();

        if let Some(pool) = &self.pool {
            let workers = &self.par_workers;
            let active = workers.len();
            pool.dispatch(active, &|wk| {
                let mut w = workers[wk].lock().expect("tile worker mutex poisoned");
                let mut b = wk;
                while b < b_total {
                    let out = w.run_tile(
                        b,
                        &plan.tiles[b],
                        x_shuf,
                        inv_perm,
                        &members[b],
                        rank,
                        cfg,
                        tau,
                        norm,
                        d,
                        phase_ctx,
                    );
                    *results[b].lock().expect("tile result mutex poisoned") = Some(out);
                    b += active;
                }
            })
            .context("dispatching tile workers")?;
        } else {
            let w = self.seq.as_mut().expect("tiled executor has a sequential worker");
            for (b, spec) in plan.tiles.iter().enumerate() {
                let out = w.run_tile(
                    b, spec, x_shuf, inv_perm, &members[b], rank, cfg, tau, norm, d, phase_ctx,
                );
                *results[b].lock().expect("tile result mutex poisoned") = Some(out);
            }
        }
        Ok(())
    }
}

impl PhaseExecutor for TiledExecutor {
    fn tiles(&self) -> usize {
        self.plan.tiles.len()
    }

    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        shuf: &Permutation,
        inv: &Permutation,
        _inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation> {
        let started = std::time::Instant::now();
        let n = shuf.len();
        let b_total = self.plan.tiles.len();

        // Tile membership for this phase: shuffled slot j belongs to the
        // tile owning grid position shuf[j]; slots stay in ascending order
        // within a tile, so a one-tile plan gathers the identity.
        for m in &mut self.members {
            m.clear();
        }
        let shuf_s = shuf.as_slice();
        for (j, &pos) in shuf_s.iter().enumerate() {
            let t = self.plan.tile_of[pos as usize] as usize;
            self.rank[j] = self.members[t].len() as u32;
            self.members[t].push(j as u32);
        }

        self.dispatch_tiles(tau, x_shuf, inv, trace_ctx)?;

        // Fold in tile-index order: deterministic no matter how the
        // dispatch interleaved. The per-tile permutations compose into one
        // block-diagonal (in tile-local coordinates) phase permutation —
        // valid whenever every tile's is, since the member sets partition
        // the shuffled slots.
        self.agg_losses.clear();
        self.agg_losses.resize(self.cfg.inner_iters, 0.0);
        let mut stats = LoopStats::default();
        let mut sort_vec = vec![0u32; n];
        for b in 0..b_total {
            let out = self.results[b]
                .lock()
                .expect("tile result mutex poisoned")
                .take()
                .ok_or_else(|| anyhow!("tile {b} produced no result"))?
                .with_context(|| format!("tile {b} of phase {r}"))?;
            let mem = &self.members[b];
            // Item-weighted loss mean: ragged plans would otherwise give a
            // 7-item tile the same weight as a 14-item one. A single tile
            // has weight exactly 1.0, so `l * 1.0` keeps the one-tile
            // curve bit-identical to the full executor's.
            let wgt = mem.len() as f64 / n as f64;
            for (i, &l) in out.losses.iter().enumerate() {
                self.agg_losses[i] += l * wgt;
            }
            stats.extensions += out.stats.extensions;
            stats.repaired += out.stats.repaired;
            // Per-tile timings fold in tile-index order — "execute" now
            // sums the tiles' compute (it can exceed the phase wall time
            // under parallel dispatch; "dispatch" below is the wall).
            report.sections.merge(&out.sections);
            ensure!(
                out.perm.len() == mem.len(),
                "tile {b}: permutation over {} slots, expected {}",
                out.perm.len(),
                mem.len()
            );
            for (t, &p) in out.perm.as_slice().iter().enumerate() {
                sort_vec[mem[t] as usize] = mem[p as usize];
            }
        }
        report.sections.add("dispatch", started.elapsed());
        record_phase(report, &self.cfg, r, tau, &self.agg_losses, stats);
        Permutation::from_vec(sort_vec)
            .map_err(|e| anyhow!("tiled phase composition is not a bijection: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes(plan: &TilePlan) -> Vec<usize> {
        plan.tiles.iter().map(|t| t.shape.n).collect()
    }

    #[test]
    fn plan_splits_rows_and_absorbs_ragged_remainders() {
        // 8x8, tile_n=16 → 2 rows per tile → 4 tiles of 16.
        let p = TilePlan::new(GridShape::new(8, 8), 3, 16);
        assert_eq!(sizes(&p), vec![16, 16, 16, 16]);
        assert_eq!(p.shapes.len(), 1);
        assert_eq!((p.shapes[0].h, p.shapes[0].w), (2, 8));

        // Ragged: 5 rows of 7 with 2-row tiles → 14, 14, 7.
        let p = TilePlan::new(GridShape::new(5, 7), 3, 14);
        assert_eq!(sizes(&p), vec![14, 14, 7]);
        assert_eq!(p.shapes.len(), 2);

        // 1-D grid splits by cells; a trailing single cell is absorbed.
        let p = TilePlan::new(GridShape::new(1, 13), 3, 4);
        assert_eq!(sizes(&p), vec![4, 4, 5]);
        for t in &p.tiles {
            assert_eq!(t.shape.h, 1);
            assert!(t.shape.n >= 2);
        }

        // Tall-and-thin (w=1): whole rows but never a 1-item tile.
        let p = TilePlan::new(GridShape::new(9, 1), 2, 1);
        assert!(sizes(&p).iter().all(|&s| s >= 2), "{:?}", sizes(&p));
        assert_eq!(sizes(&p).iter().sum::<usize>(), 9);
    }

    #[test]
    fn plan_splits_wide_rows_into_column_segments() {
        // tile_n smaller than the grid width must NOT round up to full
        // w-cell rows (that would break the O(tile_n²) scratch contract on
        // wide grids) — each row splits into 1-D column segments instead.
        let p = TilePlan::new(GridShape::new(4, 16), 3, 4);
        assert_eq!(p.tiles.len(), 16);
        for t in &p.tiles {
            assert_eq!((t.shape.n, t.shape.h, t.shape.w), (4, 1, 4));
        }
        // Ragged segment split, trailing singleton absorbed per row.
        let p = TilePlan::new(GridShape::new(3, 13), 3, 4);
        assert_eq!(sizes(&p), vec![4, 4, 5, 4, 4, 5, 4, 4, 5]);
        assert!(p.tiles.iter().all(|t| t.shape.h == 1));
        // Coverage still exact.
        let g = GridShape::new(3, 13);
        let mut covered = vec![false; g.n()];
        for (b, spec) in p.tiles.iter().enumerate() {
            for pos in spec.pos0..spec.pos0 + spec.shape.n {
                assert!(!covered[pos]);
                covered[pos] = true;
                assert_eq!(p.tile_of[pos], b as u32);
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn plan_with_tile_n_at_least_n_is_one_full_tile() {
        for (h, w) in [(8usize, 8usize), (1, 16), (5, 3)] {
            let g = GridShape::new(h, w);
            for tile_n in [g.n(), g.n() + 1, 10 * g.n()] {
                let p = TilePlan::new(g, 3, tile_n);
                assert_eq!(p.tiles.len(), 1, "{h}x{w} tile_n={tile_n}");
                let s = p.tiles[0].shape;
                assert_eq!((s.n, s.h, s.w), (g.n(), h, w));
            }
        }
    }

    #[test]
    fn plan_positions_cover_the_grid_exactly_once() {
        for (h, w, t) in [(8usize, 8usize, 16usize), (5, 7, 10), (1, 40, 7), (9, 4, 13)] {
            let g = GridShape::new(h, w);
            let p = TilePlan::new(g, 3, t);
            let mut covered = vec![false; g.n()];
            for (b, spec) in p.tiles.iter().enumerate() {
                for pos in spec.pos0..spec.pos0 + spec.shape.n {
                    assert!(!covered[pos], "{h}x{w} t={t}: position {pos} covered twice");
                    covered[pos] = true;
                    assert_eq!(p.tile_of[pos], b as u32);
                }
            }
            assert!(covered.iter().all(|&c| c), "{h}x{w} t={t}: gap in coverage");
        }
    }
}
