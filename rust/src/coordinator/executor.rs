//! Phase executors — the per-phase inner-optimization strategy of the
//! ShuffleSoftSort driver.
//!
//! `run_shuffle_softsort` owns the outer policy loop (τ schedule, shuffle,
//! greedy acceptance, permutation tracking); everything *inside* a phase —
//! the I Adam steps on `sss_step`, argmax extraction, the paper's
//! extension rule, greedy repair — is delegated to a [`PhaseExecutor`]:
//!
//! * [`FullExecutor`] — the classic loop: one `StepSession` for the whole
//!   `(N, d, h, w)` problem, every phase optimizes all N weights against
//!   the full grid loss. Per-step cost and scratch are O(N²) (the SoftSort
//!   matrix row sweep), which stops being payable around N ≈ 4k.
//! * [`TiledExecutor`] — the scaling path. Each phase partitions the grid
//!   into contiguous bands of ≈`tile_n` cells (whole grid rows — every
//!   band an `h_b × w` sub-grid — or 1-row column segments when the grid
//!   is wider than a tile, so the tile_n² bound holds on any shape),
//!   pulls each band's shuffled items into a tile-local sub-problem, and
//!   runs an *independent* SoftSort inner loop + extraction per tile:
//!   O(Σ n_b²) work and O(tile_n²)-bounded step scratch per phase instead
//!   of O(N²). The per-tile permutations compose block-diagonally (in
//!   tile-local coordinates) into one always-valid phase permutation, and
//!   the *next* phase's shuffle moves items across tile boundaries — the
//!   same mechanism by which shuffling restores global mobility between
//!   cheap local solves in the paper's 1-D story. Tiles are dispatched in
//!   parallel over a [`WorkerPool`] when the backend's sessions can move
//!   across threads (native); composition folds per-tile results in tile
//!   index order, so results never depend on dispatch interleaving.
//!
//! Degeneracy contract (tested at driver and Engine level): a tile plan
//! with **one** tile reproduces the full executor **bit-identically** —
//! the single band is the whole grid, the tile-local gather is the
//! identity, and both executors drive the same [`run_inner_loop`] helper,
//! so every f32 rounding matches.

use std::sync::Mutex;

use anyhow::{anyhow, ensure, Context, Result};

use crate::backend::pool::WorkerPool;
use crate::backend::{SessionOpts, SssStep, StepBackend, StepSession, StepShape};
use crate::config::{ShuffleSoftSortConfig, TilePlanKind};
use crate::grid::GridShape;
use crate::perm::{repair, Permutation};
use crate::trace;
use crate::util::timer::Sections;

use super::events::RunReport;
use super::optimizer::Adam;

/// One phase's inner optimization: turn the shuffled arrangement into the
/// phase sort permutation (over shuffled slots). Implementations own all
/// per-phase compute state (sessions, optimizer, scratch).
pub(crate) trait PhaseExecutor {
    /// Tiles per phase (1 for the full executor).
    fn tiles(&self) -> usize;

    /// Stamp executor-specific identity onto the report before the run:
    /// the plan name, plus any plan-construction notes (clamps,
    /// fallbacks).
    fn annotate(&self, _report: &mut RunReport) {}

    /// Run phase `r` at temperature `tau` over `x_shuf` (the shuffled
    /// arrangement) and return the sort permutation in shuffled-slot
    /// coordinates. `shuf`/`inv` are the phase shuffle and its inverse
    /// (`inv_idx` is `inv` pre-widened to the step's i32 argument).
    /// `trace_ctx` is the phase span the executor's tile spans hang under
    /// (`None` — the usual case — records nothing; sampling decisions made
    /// by the driver flow through it).
    #[allow(clippy::too_many_arguments)]
    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        shuf: &Permutation,
        inv: &Permutation,
        inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation>;
}

/// Per-region item budget the pyramid assumes when `tile_n` is unset.
pub(crate) const DEFAULT_PYRAMID_TILE_N: usize = 512;

/// Build the executor the config asks for: `pyramid=true` → the
/// coarse-to-fine pyramid (budgeted by `tile_n`, default
/// [`DEFAULT_PYRAMID_TILE_N`]); else `tile_n = None` → full, `Some(t)` →
/// tiled with ≈t items per tile laid out by `cfg.tile_plan`.
pub(crate) fn executor_for(
    backend: &dyn StepBackend,
    cfg: &ShuffleSoftSortConfig,
    d: usize,
    norm: f32,
) -> Result<Box<dyn PhaseExecutor>> {
    let exec: Box<dyn PhaseExecutor> = if cfg.pyramid {
        let tile_n = cfg.tile_n.unwrap_or(DEFAULT_PYRAMID_TILE_N);
        Box::new(PyramidExecutor::new(backend, cfg, d, norm, tile_n)?)
    } else {
        match cfg.tile_n {
            None => Box::new(FullExecutor::new(backend, cfg, d, norm)?),
            Some(tile_n) => Box::new(TiledExecutor::new(backend, cfg, d, norm, tile_n)?),
        }
    };
    Ok(exec)
}

// ---------------------------------------------------------------------------
// The shared inner loop.
// ---------------------------------------------------------------------------

/// Run-level reusable buffers for one inner-loop consumer: weights, loss
/// trace, last hard draft, and the extraction scratch (`idx`/`w_ext`) that
/// used to be reallocated per `extract_valid` call — hoisted here so the
/// extension iterations are as allocation-free as the step loop.
#[derive(Default)]
struct LoopBufs {
    w: Vec<f32>,
    losses: Vec<f64>,
    last_idx: Vec<i32>,
    idx: Vec<u32>,
    w_ext: Vec<f32>,
}

/// Validity bookkeeping of one inner loop (per phase or per tile).
#[derive(Clone, Copy, Default)]
struct LoopStats {
    extensions: usize,
    repaired: usize,
}

/// The phase kernel both executors share: fresh order-preserving weights,
/// I Adam steps on `sss_step` with the τ_i ramp, then argmax extraction
/// with the paper's extension rule and greedy repair as the last resort.
/// Arithmetic (and therefore every f32 rounding) is identical to the
/// pre-executor driver loop; this function's steady state allocates
/// nothing — only the returned `Permutation` owns fresh memory. (The tiled
/// executor's per-tile bookkeeping around it — the losses clone, the
/// composed sort vector — allocates O(I) and O(N) per phase, the same
/// order the pre-executor extraction already paid.)
#[allow(clippy::too_many_arguments)]
fn run_inner_loop<S: StepSession + ?Sized>(
    session: &mut S,
    step: &mut SssStep,
    adam: &mut Adam,
    bufs: &mut LoopBufs,
    x: &[f32],
    inv_idx: &[i32],
    tau: f32,
    norm: f32,
    cfg: &ShuffleSoftSortConfig,
    trace_ctx: Option<trace::SpanContext>,
) -> Result<(Permutation, LoopStats)> {
    // Step-family telemetry: aggregated per family and emitted as one
    // span per family at loop end — inert (no clock reads, no records)
    // unless tracing is on AND this loop was handed a parent span.
    let mut clock = trace::StepClock::start(trace_ctx);
    let n = inv_idx.len();
    // Fresh order-preserving weights + fresh optimizer moments. The ramp
    // has unit spacing, so τ directly reads as the softmax bandwidth in
    // *positions* (see EXPERIMENTS.md §Tuning).
    bufs.w.clear();
    bufs.w.extend((0..n).map(|i| (n - i) as f32));
    adam.reset();
    bufs.losses.clear();
    // Seed the hard draft with zeros (matching the pre-executor driver's
    // `vec![0i32; n]`), so a degenerate `inner_iters=0` config still
    // reaches the extension/repair path instead of returning an empty
    // permutation.
    bufs.last_idx.clear();
    bufs.last_idx.resize(n, 0);

    for i in 0..cfg.inner_iters {
        let tau_i = cfg.tau.inner_tau(tau, i, cfg.inner_iters);
        clock.time(trace::FAM_SSS, || session.sss_step(&bufs.w, x, inv_idx, tau_i, norm, step))?;
        bufs.losses.push(step.loss as f64);
        clock.time(trace::FAM_ADAM, || adam.step(&mut bufs.w, &step.grad));
        if i + 1 == cfg.inner_iters {
            bufs.last_idx.clear();
            bufs.last_idx.extend_from_slice(&step.sort_idx);
        }
    }

    // Hard extraction with the paper's extension rule.
    let mut stats = LoopStats::default();
    bufs.idx.clear();
    bufs.idx.extend(bufs.last_idx.iter().map(|&v| v as u32));
    if Permutation::count_duplicates(&bufs.idx) == 0 {
        clock.emit();
        return Ok((Permutation::from_vec(bufs.idx.clone()).expect("checked"), stats));
    }

    // Extend: keep optimizing a weight copy at a sharpening temperature
    // (same Adam moments) until valid.
    bufs.w_ext.clear();
    bufs.w_ext.extend_from_slice(&bufs.w);
    let mut tau_ext = tau;
    for _ in 0..cfg.max_extensions {
        stats.extensions += 1;
        tau_ext *= 0.6;
        clock
            .time(trace::FAM_SSS, || session.sss_step(&bufs.w_ext, x, inv_idx, tau_ext, norm, step))?;
        clock.time(trace::FAM_ADAM, || adam.step(&mut bufs.w_ext, &step.grad));
        bufs.idx.clear();
        bufs.idx.extend(step.sort_idx.iter().map(|&v| v as u32));
        if Permutation::count_duplicates(&bufs.idx) == 0 {
            clock.emit();
            return Ok((Permutation::from_vec(bufs.idx.clone()).expect("checked"), stats));
        }
    }
    clock.emit();

    // Rare fallback: deterministic greedy repair (counted in the report —
    // this is what the paper's "Stability" row measures).
    let (perm, fixed) = repair(&bufs.idx);
    stats.repaired = fixed;
    Ok((perm, stats))
}

/// Replay one phase's losses and validity stats into the report. Shared by
/// both executors so the report shape is executor-independent (tiled
/// phases record the per-iteration mean across tiles — identical to the
/// full trace when there is one tile).
fn record_phase(
    report: &mut RunReport,
    cfg: &ShuffleSoftSortConfig,
    r: usize,
    tau: f32,
    losses: &[f64],
    stats: LoopStats,
) {
    for (i, &loss) in losses.iter().enumerate() {
        let tau_i = cfg.tau.inner_tau(tau, i, cfg.inner_iters);
        if cfg.record_curve {
            report.record(r, i, tau_i, loss);
        } else {
            report.final_loss = loss;
            report.steps += 1;
        }
    }
    report.extensions += stats.extensions;
    if stats.repaired > 0 {
        report.repaired += stats.repaired;
        report.valid_without_repair = false;
    }
}

/// Effective Adam config for a d-dimensional run (the lr auto-scale).
fn adam_for(cfg: &ShuffleSoftSortConfig, d: usize, n: usize) -> Adam {
    let mut adam_cfg = cfg.adam.clone();
    adam_cfg.lr = cfg.effective_lr(d);
    Adam::new(adam_cfg, n)
}

// ---------------------------------------------------------------------------
// Full executor: one session, the whole problem per phase.
// ---------------------------------------------------------------------------

pub(crate) struct FullExecutor {
    cfg: ShuffleSoftSortConfig,
    norm: f32,
    session: Box<dyn StepSession>,
    step: SssStep,
    adam: Adam,
    bufs: LoopBufs,
}

impl FullExecutor {
    pub fn new(
        backend: &dyn StepBackend,
        cfg: &ShuffleSoftSortConfig,
        d: usize,
        norm: f32,
    ) -> Result<Self> {
        let shape = StepShape::new(cfg.grid, d);
        // One session for the whole run: scratch + worker pool allocated
        // here, every phase reuses them (zero steady-state allocations).
        let session = backend.session(shape, cfg.session_opts())?;
        Ok(FullExecutor {
            cfg: cfg.clone(),
            norm,
            session,
            step: SssStep::new_for(shape),
            adam: adam_for(cfg, d, shape.n),
            bufs: LoopBufs::default(),
        })
    }
}

impl PhaseExecutor for FullExecutor {
    fn tiles(&self) -> usize {
        1
    }

    fn annotate(&self, report: &mut RunReport) {
        report.tile_plan = "full".to_string();
    }

    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        _shuf: &Permutation,
        _inv: &Permutation,
        inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation> {
        // The full executor is one whole-problem tile, and traces as one:
        // sampled phases get a single `tile` span covering the inner loop.
        let mut tspan = trace::Span::child_of(trace_ctx, "tile");
        tspan.attr_u64("tile", 0);
        tspan.attr_u64("n", inv_idx.len() as u64);
        let tile_ctx = tspan.ctx();
        // The "execute" section now covers the whole inner loop — steps,
        // optimizer and extraction — where the pre-executor driver split
        // out a separate "adam" section (the baselines still do).
        let (perm, stats) = report.sections.time("execute", || {
            run_inner_loop(
                self.session.as_mut(),
                &mut self.step,
                &mut self.adam,
                &mut self.bufs,
                x_shuf,
                inv_idx,
                tau,
                self.norm,
                &self.cfg,
                tile_ctx,
            )
        })?;
        tspan.end();
        record_phase(report, &self.cfg, r, tau, &self.bufs.losses, stats);
        Ok(perm)
    }
}

// ---------------------------------------------------------------------------
// Tile plan: contiguous grid bands, each a sub-grid.
// ---------------------------------------------------------------------------

/// One tile: `shape.n` grid positions at `[start, start + shape.n)` of the
/// plan's flat position buffer, solved as a `shape.h × shape.w` sub-grid,
/// plus the index of its shape in the plan's deduplicated shape list
/// (ragged splits have a handful of distinct shapes, so sessions/scratch
/// memoize per shape). Banded plans store contiguous row-major runs; snake
/// plans store boustrophedon paths — the executor only ever sees the
/// explicit position list.
#[derive(Clone, Copy, Debug)]
pub(crate) struct TileSpec {
    pub start: usize,
    pub shape: StepShape,
    pub shape_idx: usize,
}

/// How a grid splits into tiles for a requested per-tile item count.
#[derive(Debug)]
pub(crate) struct TilePlan {
    pub tiles: Vec<TileSpec>,
    /// Deduplicated tile shapes (`TileSpec::shape_idx` indexes this). When
    /// plans are built against a shared registry (the phase-alternating
    /// pairs), this is the registry as of this plan's construction — a
    /// superset of the shapes this plan uses, with stable indices.
    pub shapes: Vec<StepShape>,
    /// Grid position → tile index.
    pub tile_of: Vec<u32>,
    /// Flat grid-position buffer; tile `b` owns
    /// `pos[tiles[b].start .. tiles[b].start + tiles[b].shape.n]`, local
    /// grid position `q` of the tile being `pos[start + q]`.
    pub pos: Vec<u32>,
}

/// Plan construction state: tiles + positions accumulating against a
/// shared (possibly cross-plan) shape registry.
struct PlanBuilder<'a> {
    n: usize,
    d: usize,
    shapes: &'a mut Vec<StepShape>,
    tiles: Vec<TileSpec>,
    pos: Vec<u32>,
}

impl<'a> PlanBuilder<'a> {
    fn new(n: usize, d: usize, shapes: &'a mut Vec<StepShape>) -> Self {
        PlanBuilder { n, d, shapes, tiles: Vec::new(), pos: Vec::with_capacity(n) }
    }

    fn shape_idx(&mut self, shape: StepShape) -> usize {
        match self.shapes.iter().position(|s| *s == shape) {
            Some(i) => i,
            None => {
                self.shapes.push(shape);
                self.shapes.len() - 1
            }
        }
    }

    /// A contiguous row-major band `[pos0, pos0 + shape.n)`.
    fn push_range(&mut self, pos0: usize, shape: StepShape) {
        let start = self.pos.len();
        self.pos.extend((pos0..pos0 + shape.n).map(|p| p as u32));
        let shape_idx = self.shape_idx(shape);
        self.tiles.push(TileSpec { start, shape, shape_idx });
    }

    /// An explicit position path, solved as a 1-D `1 × len` chain.
    fn push_path(&mut self, path: &[u32]) {
        let shape = StepShape { n: path.len(), d: self.d, h: 1, w: path.len() };
        let start = self.pos.len();
        self.pos.extend_from_slice(path);
        let shape_idx = self.shape_idx(shape);
        self.tiles.push(TileSpec { start, shape, shape_idx });
    }

    /// 1-D chunking of `count` contiguous cells starting at `base`, ≈`per`
    /// each, ≥ 2 each (trailing singleton absorbed into the last chunk).
    /// With `offset`, a half-length lead chunk shifts every seam by per/2.
    fn chunk_span(&mut self, base: usize, count: usize, per: usize, offset: bool) {
        let per = per.clamp(2, count.max(2));
        let d = self.d;
        let lead = if offset { per / 2 } else { 0 };
        let mut c0 = 0usize;
        if lead >= 2 && count >= lead + 2 {
            self.push_range(base, StepShape { n: lead, d, h: 1, w: lead });
            c0 = lead;
        }
        while c0 < count {
            let mut take = per.min(count - c0);
            if count - c0 - take == 1 {
                take += 1;
            }
            self.push_range(base + c0, StepShape { n: take, d, h: 1, w: take });
            c0 += take;
        }
    }

    fn finish(self) -> TilePlan {
        debug_assert_eq!(self.pos.len(), self.n, "plan must cover the grid");
        let mut tile_of = vec![0u32; self.n];
        for (b, t) in self.tiles.iter().enumerate() {
            for &p in &self.pos[t.start..t.start + t.shape.n] {
                tile_of[p as usize] = b as u32;
            }
        }
        TilePlan { tiles: self.tiles, shapes: self.shapes.clone(), tile_of, pos: self.pos }
    }
}

impl TilePlan {
    /// The tile's grid positions, in tile-local grid order.
    pub fn positions(&self, b: usize) -> &[u32] {
        let t = &self.tiles[b];
        &self.pos[t.start..t.start + t.shape.n]
    }

    /// Whether two plans cut the grid identically (used to collapse a
    /// degenerate phase-alternating pair into one plan).
    fn same_partition(&self, other: &TilePlan) -> bool {
        self.pos == other.pos
            && self.tiles.len() == other.tiles.len()
            && self
                .tiles
                .iter()
                .zip(&other.tiles)
                .all(|(a, b)| a.start == b.start && a.shape == b.shape)
    }

    /// The block-diagonal baseline plan (`tile_plan=banded`, offset off).
    pub fn new(g: GridShape, d: usize, tile_n: usize) -> Self {
        let mut shapes = Vec::new();
        Self::banded(g, d, tile_n, false, &mut shapes)
    }

    /// Split `g` into contiguous position bands of ≈`tile_n` cells, each a
    /// valid sub-grid: whole grid rows (`h_b × w` bands) when `tile_n >=
    /// w`, column segments of single rows (`1 × n_b` chains — contiguous
    /// in row-major order, so still position bands) when the grid is wider
    /// than a tile. The latter keeps the O(tile_n²) per-step work/scratch
    /// contract on wide grids instead of silently rounding a tile up to a
    /// full `w`-cell row. A trailing remainder of a single row/cell is
    /// absorbed into the previous tile so every tile holds ≥ 2 items (a
    /// 1-item SoftSort is degenerate). `tile_n >= n` yields exactly one
    /// tile of the full grid shape (the degeneracy contract; `offset` is
    /// ignored there so the contract survives plan alternation).
    ///
    /// With `offset`, the first band is half-height (half-length for 1-D /
    /// wide segment splits), shifting every seam by half a tile relative
    /// to the unoffset variant — alternating the two between phases is the
    /// `overlapped` plan: every seam of one phase lies mid-tile in the
    /// next, so items migrate across band boundaries over the run.
    pub fn banded(
        g: GridShape,
        d: usize,
        tile_n: usize,
        offset: bool,
        shapes: &mut Vec<StepShape>,
    ) -> Self {
        let (h, w) = (g.h, g.w);
        let per = tile_n.max(1);
        let offset = offset && per < g.n();
        let mut b = PlanBuilder::new(g.n(), d, shapes);

        if h > 1 && per >= w {
            // Whole-row bands of ≈tile_n/w rows.
            let rows = (per / w).max(1).max(2usize.div_ceil(w));
            let lead = if offset { rows / 2 } else { 0 };
            let mut r0 = 0usize;
            // Half-height lead band — skipped when degenerate (< 2 cells,
            // taller than the grid, or leaving a single trailing cell).
            if lead > 0 && lead < h && lead * w >= 2 && (h - lead) * w != 1 {
                b.push_range(0, StepShape { n: lead * w, d, h: lead, w });
                r0 = lead;
            }
            while r0 < h {
                let mut take = rows.min(h - r0);
                if (h - r0 - take) * w == 1 {
                    take += 1;
                }
                b.push_range(r0 * w, StepShape { n: take * w, d, h: take, w });
                r0 += take;
            }
        } else if h == 1 {
            b.chunk_span(0, w, per, offset);
        } else {
            // Wide grid, tile_n < w: column segments, one row at a time.
            for r in 0..h {
                b.chunk_span(r * w, w, per, offset);
            }
        }
        b.finish()
    }

    /// Boustrophedon chains: walk the grid row-major with every odd row
    /// reversed (so consecutive path cells are always grid neighbors) and
    /// chunk the path into 1-D chains of ≈`tile_n` cells. Chains cross row
    /// boundaries — the seams that block-diagonal bands never move — and
    /// `offset` shifts every chain seam by half a tile, so alternating the
    /// two variants lets items migrate along the whole path over phases
    /// (the FLAS/SOM scan trick). Falls back to the banded split when the
    /// path degenerates to it (single row, or one tile covering the grid —
    /// preserving the one-tile degeneracy contract).
    pub fn snake(
        g: GridShape,
        d: usize,
        tile_n: usize,
        offset: bool,
        shapes: &mut Vec<StepShape>,
    ) -> Self {
        let (h, w) = (g.h, g.w);
        let n = g.n();
        let per = tile_n.max(1).clamp(2, n.max(2));
        if per >= n || h == 1 {
            return Self::banded(g, d, tile_n, offset, shapes);
        }
        let mut path = Vec::with_capacity(n);
        for r in 0..h {
            if r % 2 == 0 {
                path.extend((0..w).map(|c| (r * w + c) as u32));
            } else {
                path.extend((0..w).rev().map(|c| (r * w + c) as u32));
            }
        }
        let mut b = PlanBuilder::new(n, d, shapes);
        let lead = if offset { per / 2 } else { 0 };
        let mut c0 = 0usize;
        if lead >= 2 && n >= lead + 2 {
            b.push_path(&path[..lead]);
            c0 = lead;
        }
        while c0 < n {
            let mut take = per.min(n - c0);
            if n - c0 - take == 1 {
                take += 1;
            }
            b.push_path(&path[c0..c0 + take]);
            c0 += take;
        }
        b.finish()
    }

    /// The phase-alternating plan set for a kind: one fixed plan for
    /// `banded`, an (unoffset, half-offset) pair for `snake`/`overlapped`
    /// — collapsed back to one plan when the offset variant degenerates to
    /// the base cut. All plans register shapes in the shared `shapes`
    /// registry so one session set covers every phase.
    pub fn plan_set(
        kind: TilePlanKind,
        g: GridShape,
        d: usize,
        tile_n: usize,
        shapes: &mut Vec<StepShape>,
    ) -> Vec<TilePlan> {
        let mut plans = match kind {
            TilePlanKind::Banded => vec![Self::banded(g, d, tile_n, false, shapes)],
            TilePlanKind::Overlapped => vec![
                Self::banded(g, d, tile_n, false, shapes),
                Self::banded(g, d, tile_n, true, shapes),
            ],
            TilePlanKind::Snake => vec![
                Self::snake(g, d, tile_n, false, shapes),
                Self::snake(g, d, tile_n, true, shapes),
            ],
        };
        if plans.len() == 2 && plans[1].same_partition(&plans[0]) {
            plans.truncate(1);
        }
        plans
    }
}

// ---------------------------------------------------------------------------
// Tiled executor.
// ---------------------------------------------------------------------------

/// Per-shape compute state of one tile worker (session kept separately —
/// its `Send`-ness differs between the parallel and sequential paths).
struct ShapeSlot {
    shape: StepShape,
    step: SssStep,
    adam: Adam,
}

impl ShapeSlot {
    fn new(cfg: &ShuffleSoftSortConfig, shape: StepShape) -> Self {
        ShapeSlot { shape, step: SssStep::new_for(shape), adam: adam_for(cfg, shape.d, shape.n) }
    }
}

/// One tile worker's compute state: per-shape sessions + scratch, and the
/// gather buffers for the tile currently being solved. `S` is the session
/// payload type — `dyn StepSession + Send` for pool-dispatched workers
/// (each locked only by the one pool thread its index maps to), plain
/// `dyn StepSession` for the sequential fallback — so both dispatch paths
/// share this struct and [`TileWorker::run_tile`].
struct TileWorker<S: ?Sized> {
    sessions: Vec<Box<S>>,
    slots: Vec<ShapeSlot>,
    bufs: LoopBufs,
    x_tile: Vec<f32>,
    inv_tile: Vec<i32>,
}

impl<S: StepSession + ?Sized> TileWorker<S> {
    fn new(cfg: &ShuffleSoftSortConfig, shapes: &[StepShape], sessions: Vec<Box<S>>) -> Self {
        TileWorker {
            sessions,
            slots: shapes.iter().map(|&s| ShapeSlot::new(cfg, s)).collect(),
            bufs: LoopBufs::default(),
            x_tile: Vec::new(),
            inv_tile: Vec::new(),
        }
    }

    /// Gather + solve one tile. `members` are the tile's shuffled slots in
    /// ascending order; `rank` maps a shuffled slot to its tile-local
    /// index; `inv_perm` is the phase's global inverse shuffle and
    /// `positions` the tile's grid positions in tile-local order, so
    /// `rank[inv_perm[positions[q]]]` is the tile-local slot shown at the
    /// tile's local position `q` — the restriction of the full step's
    /// `inv_idx` to the tile.
    #[allow(clippy::too_many_arguments)]
    fn run_tile(
        &mut self,
        tile: usize,
        spec: &TileSpec,
        positions: &[u32],
        x_shuf: &[f32],
        inv_perm: &[u32],
        members: &[u32],
        rank: &[u32],
        cfg: &ShuffleSoftSortConfig,
        tau: f32,
        norm: f32,
        d: usize,
        phase_ctx: Option<trace::SpanContext>,
    ) -> Result<TileOutcome> {
        let mut span = trace::Span::child_of(phase_ctx, "tile");
        span.attr_u64("tile", tile as u64);
        span.attr_u64("n", members.len() as u64);
        let slot = &mut self.slots[spec.shape_idx];
        let n_b = members.len();
        debug_assert_eq!(n_b, slot.shape.n);
        self.x_tile.clear();
        for &j in members {
            let s = j as usize * d;
            self.x_tile.extend_from_slice(&x_shuf[s..s + d]);
        }
        self.inv_tile.clear();
        self.inv_tile
            .extend(positions.iter().map(|&p| rank[inv_perm[p as usize] as usize] as i32));
        // Per-tile sections, folded into `RunReport.sections` in
        // tile-index order by the fold — the tile timings used to be
        // dropped on the floor here, leaving tiled runs with a bare
        // wall-clock "execute" entry.
        let mut sections = Sections::new();
        let (perm, stats) = sections.time("execute", || {
            run_inner_loop(
                self.sessions[spec.shape_idx].as_mut(),
                &mut slot.step,
                &mut slot.adam,
                &mut self.bufs,
                &self.x_tile,
                &self.inv_tile,
                tau,
                norm,
                cfg,
                span.ctx(),
            )
        })?;
        span.end();
        Ok(TileOutcome { perm, losses: self.bufs.losses.clone(), stats, sections })
    }
}

/// Everything one finished tile hands back to the fold.
struct TileOutcome {
    perm: Permutation,
    losses: Vec<f64>,
    stats: LoopStats,
    sections: Sections,
}

/// A tile's result slot: written once by whichever worker ran the tile,
/// taken by the tile-index-ordered fold.
type TileSlot = Mutex<Option<Result<TileOutcome>>>;

pub(crate) struct TiledExecutor {
    cfg: ShuffleSoftSortConfig,
    d: usize,
    norm: f32,
    /// The phase-alternating plan set (phase `r` runs `plans[r % len]`);
    /// one entry for `banded`, an (unoffset, half-offset) pair for
    /// `snake`/`overlapped`.
    plans: Vec<TilePlan>,
    /// Tile → its shuffled slots this phase, ascending (rebuilt per phase;
    /// sized to the largest plan in the set).
    members: Vec<Vec<u32>>,
    /// Shuffled slot → tile-local rank (companion to `members`).
    rank: Vec<u32>,
    /// Per-tile result slots; disjoint writes, folded in tile order.
    results: Vec<TileSlot>,
    /// Parallel workers + their pool (`None` → `seq` is used instead).
    par_workers: Vec<Mutex<TileWorker<dyn StepSession + Send>>>,
    pool: Option<WorkerPool>,
    seq: Option<TileWorker<dyn StepSession>>,
    agg_losses: Vec<f64>,
}

impl TiledExecutor {
    pub fn new(
        backend: &dyn StepBackend,
        cfg: &ShuffleSoftSortConfig,
        d: usize,
        norm: f32,
        tile_n: usize,
    ) -> Result<Self> {
        // The plan set shares one shape registry, so every worker's
        // session vector covers every phase's tiles regardless of which
        // plan a phase selects.
        let mut shapes = Vec::new();
        let plans = TilePlan::plan_set(cfg.tile_plan, cfg.grid, d, tile_n, &mut shapes);
        let max_tiles = plans.iter().map(|p| p.tiles.len()).max().unwrap_or(1);
        // Parallelism budget: the explicit `threads` knob, else what the
        // backend would give one full-problem session — so a backend the
        // engine capped for batching caps tile dispatch identically.
        let budget = cfg.threads.unwrap_or_else(|| backend.default_threads()).max(1);
        let wanted = budget.clamp(1, max_tiles);

        // Parallel tile dispatch needs sessions that may cross threads;
        // back off to the sequential path when the backend cannot provide
        // them (results are identical either way — the fold is
        // tile-index-ordered and tiles are independent).
        let mut par_workers = Vec::new();
        if wanted > 1 {
            // Split the row-thread budget across tile workers so tile
            // parallelism × in-tile row parallelism ≈ the budget.
            let per_tile_threads = (budget / wanted).max(1);
            'build: for _ in 0..wanted {
                let mut sessions = Vec::with_capacity(shapes.len());
                for &shape in &shapes {
                    let opts = SessionOpts { threads: Some(per_tile_threads), simd: cfg.simd };
                    match backend.session_sendable(shape, opts)? {
                        Some(s) => sessions.push(s),
                        None => {
                            par_workers.clear();
                            break 'build;
                        }
                    }
                }
                par_workers.push(Mutex::new(TileWorker::new(cfg, &shapes, sessions)));
            }
        }
        let (pool, seq) = if par_workers.is_empty() {
            let mut sessions = Vec::with_capacity(shapes.len());
            for &shape in &shapes {
                sessions.push(backend.session(shape, cfg.session_opts())?);
            }
            (None, Some(TileWorker::new(cfg, &shapes, sessions)))
        } else {
            (Some(WorkerPool::new(par_workers.len() - 1)), None)
        };

        Ok(TiledExecutor {
            cfg: cfg.clone(),
            d,
            norm,
            members: (0..max_tiles).map(|_| Vec::new()).collect(),
            rank: vec![0; cfg.grid.n()],
            results: (0..max_tiles).map(|_| Mutex::new(None)).collect(),
            plans,
            par_workers,
            pool,
            seq,
            agg_losses: Vec::new(),
        })
    }

    /// Dispatch every tile of `plans[plan_idx]` (parallel when a pool
    /// exists) and leave each outcome in its `results` slot.
    fn dispatch_tiles(
        &mut self,
        plan_idx: usize,
        tau: f32,
        x_shuf: &[f32],
        inv: &Permutation,
        phase_ctx: Option<trace::SpanContext>,
    ) -> Result<()> {
        let plan = &self.plans[plan_idx];
        let members = &self.members;
        let rank = &self.rank;
        let results = &self.results;
        let cfg = &self.cfg;
        let (norm, d) = (self.norm, self.d);
        let inv_perm = inv.as_slice();
        let b_total = plan.tiles.len();

        if let Some(pool) = &self.pool {
            let workers = &self.par_workers;
            let active = workers.len();
            pool.dispatch(active, &|wk| {
                let mut w = workers[wk].lock().expect("tile worker mutex poisoned");
                let mut b = wk;
                while b < b_total {
                    let out = w.run_tile(
                        b,
                        &plan.tiles[b],
                        plan.positions(b),
                        x_shuf,
                        inv_perm,
                        &members[b],
                        rank,
                        cfg,
                        tau,
                        norm,
                        d,
                        phase_ctx,
                    );
                    *results[b].lock().expect("tile result mutex poisoned") = Some(out);
                    b += active;
                }
            })
            .context("dispatching tile workers")?;
        } else {
            let w = self.seq.as_mut().expect("tiled executor has a sequential worker");
            for (b, spec) in plan.tiles.iter().enumerate() {
                let out = w.run_tile(
                    b,
                    spec,
                    plan.positions(b),
                    x_shuf,
                    inv_perm,
                    &members[b],
                    rank,
                    cfg,
                    tau,
                    norm,
                    d,
                    phase_ctx,
                );
                *results[b].lock().expect("tile result mutex poisoned") = Some(out);
            }
        }
        Ok(())
    }
}

impl PhaseExecutor for TiledExecutor {
    fn tiles(&self) -> usize {
        self.plans[0].tiles.len()
    }

    fn annotate(&self, report: &mut RunReport) {
        report.tile_plan = self.cfg.tile_plan.name().to_string();
    }

    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        shuf: &Permutation,
        inv: &Permutation,
        _inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation> {
        let started = std::time::Instant::now();
        let n = shuf.len();
        // Phase-alternating plan selection: successive phases cycle
        // through the plan set, so seams shift between phases (a no-op
        // for `banded`, whose set has one plan).
        let plan_idx = r % self.plans.len();
        let b_total = self.plans[plan_idx].tiles.len();

        // Tile membership for this phase: shuffled slot j belongs to the
        // tile owning grid position shuf[j]; slots stay in ascending order
        // within a tile, so a one-tile plan gathers the identity.
        for m in &mut self.members {
            m.clear();
        }
        let shuf_s = shuf.as_slice();
        for (j, &pos) in shuf_s.iter().enumerate() {
            let t = self.plans[plan_idx].tile_of[pos as usize] as usize;
            self.rank[j] = self.members[t].len() as u32;
            self.members[t].push(j as u32);
        }

        self.dispatch_tiles(plan_idx, tau, x_shuf, inv, trace_ctx)?;

        // Fold in tile-index order: deterministic no matter how the
        // dispatch interleaved. The per-tile permutations compose into one
        // block-diagonal (in tile-local coordinates) phase permutation —
        // valid whenever every tile's is, since the member sets partition
        // the shuffled slots.
        self.agg_losses.clear();
        self.agg_losses.resize(self.cfg.inner_iters, 0.0);
        let mut stats = LoopStats::default();
        let mut sort_vec = vec![0u32; n];
        for b in 0..b_total {
            let out = self.results[b]
                .lock()
                .expect("tile result mutex poisoned")
                .take()
                .ok_or_else(|| anyhow!("tile {b} produced no result"))?
                .with_context(|| format!("tile {b} of phase {r}"))?;
            let mem = &self.members[b];
            // Item-weighted loss mean: ragged plans would otherwise give a
            // 7-item tile the same weight as a 14-item one. A single tile
            // has weight exactly 1.0, so `l * 1.0` keeps the one-tile
            // curve bit-identical to the full executor's.
            let wgt = mem.len() as f64 / n as f64;
            for (i, &l) in out.losses.iter().enumerate() {
                self.agg_losses[i] += l * wgt;
            }
            stats.extensions += out.stats.extensions;
            stats.repaired += out.stats.repaired;
            // Per-tile timings fold in tile-index order — "execute" now
            // sums the tiles' compute (it can exceed the phase wall time
            // under parallel dispatch; "dispatch" below is the wall).
            report.sections.merge(&out.sections);
            ensure!(
                out.perm.len() == mem.len(),
                "tile {b}: permutation over {} slots, expected {}",
                out.perm.len(),
                mem.len()
            );
            for (t, &p) in out.perm.as_slice().iter().enumerate() {
                sort_vec[mem[t] as usize] = mem[p as usize];
            }
        }
        report.sections.add("dispatch", started.elapsed());
        record_phase(report, &self.cfg, r, tau, &self.agg_losses, stats);
        Permutation::from_vec(sort_vec)
            .map_err(|e| anyhow!("tiled phase composition is not a bijection: {e}"))
    }
}

// ---------------------------------------------------------------------------
// Pyramid executor: coarse-to-fine hierarchical phases.
// ---------------------------------------------------------------------------

/// One node of the pyramid's recursive split schedule, computed once per
/// run from (grid, tile_n) and identical for every phase. Every region the
/// recursion visits is a rectangle of the grid; splits are exact integer
/// divisors, so all children of a `Split` share one region shape and one
/// child node describes them all.
enum PyrNode {
    /// Region fits the budget: one SoftSort solve over the region grid.
    Solve { shape_idx: usize },
    /// No integer coarse split exists (prime-ish region): chunk the
    /// region's row-major cells into independent ≈tile_n 1-D chains —
    /// no cross-chain exchange at this level, noted in the run report.
    Chains { chains: Vec<(usize, usize)> },
    /// Sort the ch×cw subtile centroids on the coarse grid with the full
    /// path, relocate whole subtiles by the coarse permutation, then
    /// recurse into each subtile.
    Split { ch: usize, cw: usize, coarse_idx: usize, sub_h: usize, sub_w: usize, child: Box<PyrNode> },
}

/// Pick the coarse split of an `h_r × w_r` region: among exact divisor
/// pairs with 2 ≤ ch·cw ≤ tile_n and ≥ 2 cells per subtile, prefer the
/// smallest coarse problem whose subtiles already fit the budget (its
/// children are leaves — two levels total), tie-broken toward squarer
/// subtiles; when no split reaches the budget in one step, take the
/// largest coarse problem (fastest shrink), same tie-break. `None` when
/// the region has no usable divisor pair at all.
fn pick_split(h_r: usize, w_r: usize, tile_n: usize) -> Option<(usize, usize)> {
    let n_r = h_r * w_r;
    let mut best: Option<(bool, usize, usize, (usize, usize))> = None;
    for ch in 1..=h_r {
        if h_r % ch != 0 {
            continue;
        }
        for cw in 1..=w_r {
            if w_r % cw != 0 {
                continue;
            }
            let b = ch * cw;
            if b < 2 || b > tile_n {
                continue;
            }
            let (sh, sw) = (h_r / ch, w_r / cw);
            if sh * sw < 2 {
                continue;
            }
            let fits = n_r / b <= tile_n;
            // Rank: fits first; among fits smaller b wins, among non-fits
            // larger b wins; then the squarer subtile.
            let coarse_rank = if fits { tile_n - b } else { b };
            let cand = (fits, coarse_rank, sh.min(sw), (ch, cw));
            if best.as_ref().map_or(true, |bst| cand > *bst) {
                best = Some(cand);
            }
        }
    }
    best.map(|(_, _, _, split)| split)
}

/// Build the split schedule for an `h_r × w_r` region. Returns the node
/// and the number of leaf solves per region instance (`Split` multiplies
/// by its subtile count). `levels` tracks the deepest `Split` nesting;
/// `fallback` records whether any region needed the chains fallback.
fn build_pyramid(
    h_r: usize,
    w_r: usize,
    d: usize,
    tile_n: usize,
    depth: usize,
    shapes: &mut Vec<StepShape>,
    levels: &mut usize,
    fallback: &mut bool,
) -> (PyrNode, usize) {
    let n_r = h_r * w_r;
    let reg = |shapes: &mut Vec<StepShape>, shape: StepShape| match shapes
        .iter()
        .position(|s| *s == shape)
    {
        Some(i) => i,
        None => {
            shapes.push(shape);
            shapes.len() - 1
        }
    };
    if n_r <= tile_n || n_r <= 2 {
        let idx = reg(shapes, StepShape { n: n_r, d, h: h_r, w: w_r });
        return (PyrNode::Solve { shape_idx: idx }, 1);
    }
    match pick_split(h_r, w_r, tile_n) {
        Some((ch, cw)) => {
            *levels = (*levels).max(depth + 1);
            let coarse_idx = reg(shapes, StepShape { n: ch * cw, d, h: ch, w: cw });
            let (sub_h, sub_w) = (h_r / ch, w_r / cw);
            let (child, child_leaves) =
                build_pyramid(sub_h, sub_w, d, tile_n, depth + 1, shapes, levels, fallback);
            let leaves = ch * cw * child_leaves;
            (
                PyrNode::Split { ch, cw, coarse_idx, sub_h, sub_w, child: Box::new(child) },
                leaves,
            )
        }
        None => {
            // Prime-ish region: independent row-major chains (the banded
            // wide-grid cut applied to the region), no coarse exchange.
            *fallback = true;
            let per = tile_n.clamp(2, n_r.max(2));
            let mut chains = Vec::new();
            let mut c0 = 0usize;
            while c0 < n_r {
                let mut take = per.min(n_r - c0);
                if n_r - c0 - take == 1 {
                    take += 1;
                }
                let idx = reg(shapes, StepShape { n: take, d, h: 1, w: take });
                chains.push((take, idx));
                c0 += take;
            }
            let count = chains.len();
            (PyrNode::Chains { chains }, count)
        }
    }
}

/// The per-phase mutable state of the pyramid recursion, split out of the
/// executor so the recursion can borrow it wholesale alongside the
/// schedule. All buffers are allocated once and reused phase to phase.
struct PyrState {
    sessions: Vec<Box<dyn StepSession>>,
    slots: Vec<ShapeSlot>,
    bufs: LoopBufs,
    /// Grid position → shuffled slot currently assigned there; seeded from
    /// the phase's inverse shuffle, permuted in place by every coarse
    /// relocation and leaf solve, and read out as the phase result.
    slot_at: Vec<u32>,
    /// Region-sized staging for subtile relocation and leaf gathers.
    scratch: Vec<u32>,
    /// Leaf gather: the leaf's slots ascending + slot → local rank.
    members: Vec<u32>,
    rank: Vec<u32>,
    x_tile: Vec<f32>,
    inv_tile: Vec<i32>,
    /// Centroid rows for coarse solves (coarse-position order).
    cent: Vec<f32>,
    agg_losses: Vec<f64>,
    stats: LoopStats,
}

/// Read-only per-phase context of the recursion.
struct PyrEnv<'a> {
    cfg: &'a ShuffleSoftSortConfig,
    norm: f32,
    d: usize,
    grid_w: usize,
    n: usize,
    tau: f32,
    /// Span context for the *root* solves only — deeper levels run
    /// unparented so a sampled phase stays within the span budget no
    /// matter how many regions the pyramid visits.
    ctx: Option<trace::SpanContext>,
}

/// Solve one leaf over an explicit cell window: region cells are
/// enumerated row-major (`k ∈ [k0, k0+len)`, cell `(top + k/w_r,
/// left + k%w_r)`), gathered exactly like a tile (ascending-slot members,
/// rank-composed inverse), solved on `shapes[shape_idx]`, and written back
/// into `slot_at`. Losses fold item-weighted into the phase aggregate.
#[allow(clippy::too_many_arguments)]
fn pyr_solve_cells(
    st: &mut PyrState,
    env: &PyrEnv,
    x_shuf: &[f32],
    shape_idx: usize,
    top: usize,
    left: usize,
    w_r: usize,
    k0: usize,
    len: usize,
    ctx: Option<trace::SpanContext>,
) -> Result<()> {
    let PyrState {
        sessions, slots, bufs, slot_at, scratch, members, rank, x_tile, inv_tile, agg_losses,
        stats, ..
    } = st;
    let cell = |k: usize| (top + k / w_r) * env.grid_w + left + k % w_r;
    // Current slots at the window's cells, in cell order.
    scratch.clear();
    scratch.extend((k0..k0 + len).map(|k| slot_at[cell(k)]));
    members.clear();
    members.extend_from_slice(scratch);
    members.sort_unstable();
    for (t, &s) in members.iter().enumerate() {
        rank[s as usize] = t as u32;
    }
    x_tile.clear();
    for &s in members.iter() {
        let o = s as usize * env.d;
        x_tile.extend_from_slice(&x_shuf[o..o + env.d]);
    }
    inv_tile.clear();
    inv_tile.extend(scratch.iter().map(|&s| rank[s as usize] as i32));
    let slot = &mut slots[shape_idx];
    debug_assert_eq!(slot.shape.n, len);
    let (perm, lstats) = run_inner_loop(
        sessions[shape_idx].as_mut(),
        &mut slot.step,
        &mut slot.adam,
        bufs,
        x_tile,
        inv_tile,
        env.tau,
        env.norm,
        env.cfg,
        ctx,
    )?;
    let wgt = len as f64 / env.n as f64;
    for (i, &l) in bufs.losses.iter().enumerate() {
        agg_losses[i] += l * wgt;
    }
    stats.extensions += lstats.extensions;
    stats.repaired += lstats.repaired;
    // New slot at local position q = members[p[inv_tile[q]]] — the same
    // algebra as the tiled fold, applied in place.
    let p = perm.as_slice();
    for (q, k) in (k0..k0 + len).enumerate() {
        slot_at[cell(k)] = members[p[inv_tile[q] as usize] as usize];
    }
    Ok(())
}

/// Run one pyramid node over the region at (top, left) of size h_r × w_r.
#[allow(clippy::too_many_arguments)]
fn pyr_solve_node(
    node: &PyrNode,
    st: &mut PyrState,
    env: &PyrEnv,
    x_shuf: &[f32],
    top: usize,
    left: usize,
    h_r: usize,
    w_r: usize,
    depth: usize,
) -> Result<()> {
    let ctx = if depth == 0 { env.ctx } else { None };
    match node {
        PyrNode::Solve { shape_idx } => {
            pyr_solve_cells(st, env, x_shuf, *shape_idx, top, left, w_r, 0, h_r * w_r, ctx)
        }
        PyrNode::Chains { chains } => {
            let mut k0 = 0usize;
            for &(len, shape_idx) in chains {
                pyr_solve_cells(st, env, x_shuf, shape_idx, top, left, w_r, k0, len, None)?;
                k0 += len;
            }
            Ok(())
        }
        PyrNode::Split { ch, cw, coarse_idx, sub_h, sub_w, child } => {
            let (ch, cw) = (*ch, *cw);
            let bb = ch * cw;
            let sub_n = sub_h * sub_w;
            let d = env.d;
            // Subtile centroids in coarse row-major order: the mean row of
            // the items currently assigned to each subtile.
            {
                let PyrState { slot_at, cent, .. } = &mut *st;
                cent.clear();
                cent.resize(bb * d, 0.0);
                for rr in 0..h_r {
                    let bi = rr / sub_h;
                    for cc in 0..w_r {
                        let b = bi * cw + cc / sub_w;
                        let s = slot_at[(top + rr) * env.grid_w + left + cc] as usize;
                        let (co, xo) = (b * d, s * d);
                        for k in 0..d {
                            cent[co + k] += x_shuf[xo + k];
                        }
                    }
                }
                let inv_n = 1.0 / sub_n as f32;
                for v in cent.iter_mut() {
                    *v *= inv_n;
                }
            }
            // Coarse solve: B centroids on the ch×cw grid, identity
            // current assignment (centroid b sits at coarse position b).
            // Auxiliary to the item loss, so its losses stay out of the
            // curve; its validity stats still count.
            let perm_c = {
                let PyrState { sessions, slots, bufs, inv_tile, cent, stats, .. } = &mut *st;
                inv_tile.clear();
                inv_tile.extend(0..bb as i32);
                let slot = &mut slots[*coarse_idx];
                let (perm_c, lstats) = run_inner_loop(
                    sessions[*coarse_idx].as_mut(),
                    &mut slot.step,
                    &mut slot.adam,
                    bufs,
                    cent,
                    inv_tile,
                    env.tau,
                    env.norm,
                    env.cfg,
                    ctx,
                )?;
                stats.extensions += lstats.extensions;
                stats.repaired += lstats.repaired;
                perm_c
            };
            // Relocate whole subtiles: coarse position b receives subtile
            // perm_c[b]'s items, row-major layout preserved.
            {
                let PyrState { slot_at, scratch, .. } = &mut *st;
                scratch.clear();
                for b in 0..bb {
                    let (bi, bj) = (b / cw, b % cw);
                    for rr in 0..*sub_h {
                        let row = (top + bi * sub_h + rr) * env.grid_w + left + bj * sub_w;
                        scratch.extend_from_slice(&slot_at_row(slot_at, row, *sub_w));
                    }
                }
                let pc = perm_c.as_slice();
                for b in 0..bb {
                    let src = pc[b] as usize * sub_n;
                    let (bi, bj) = (b / cw, b % cw);
                    for rr in 0..*sub_h {
                        let row = (top + bi * sub_h + rr) * env.grid_w + left + bj * sub_w;
                        slot_at[row..row + sub_w]
                            .copy_from_slice(&scratch[src + rr * sub_w..src + (rr + 1) * sub_w]);
                    }
                }
            }
            // Refine within each relocated subtile.
            for b in 0..bb {
                let (bi, bj) = (b / cw, b % cw);
                pyr_solve_node(
                    child,
                    st,
                    env,
                    x_shuf,
                    top + bi * sub_h,
                    left + bj * sub_w,
                    *sub_h,
                    *sub_w,
                    depth + 1,
                )?;
            }
            Ok(())
        }
    }
}

/// `slot_at[row .. row + w]` — a named helper only so the relocation's
/// gather reads symmetrically to its scatter.
fn slot_at_row(slot_at: &[u32], row: usize, w: usize) -> &[u32] {
    &slot_at[row..row + w]
}

/// The coarse-to-fine executor (`pyramid=true`): every phase sorts
/// subtile centroids on a coarse grid (whole-subtile relocation — items
/// cross the entire grid in one phase), then refines recursively until
/// regions fit the O(tile_n²) budget. Runs its solves sequentially (each
/// session still uses the config's row-thread budget); the per-phase
/// result is a single in-place permutation of the position→slot
/// assignment, so the bijection invariant is checked once per phase like
/// the tiled fold. With `tile_n >= N` the schedule is a single leaf and
/// the phase is bit-identical to the full executor.
pub(crate) struct PyramidExecutor {
    cfg: ShuffleSoftSortConfig,
    norm: f32,
    root: PyrNode,
    levels: usize,
    leaf_tiles: usize,
    notes: Vec<String>,
    st: PyrState,
    d: usize,
}

impl PyramidExecutor {
    pub fn new(
        backend: &dyn StepBackend,
        cfg: &ShuffleSoftSortConfig,
        d: usize,
        norm: f32,
        tile_n: usize,
    ) -> Result<Self> {
        let g = cfg.grid;
        let tile_n = tile_n.max(2);
        let mut shapes = Vec::new();
        let (mut levels, mut fallback) = (0usize, false);
        let (root, leaf_tiles) =
            build_pyramid(g.h, g.w, d, tile_n, 0, &mut shapes, &mut levels, &mut fallback);
        let mut notes = Vec::new();
        if fallback {
            notes.push(format!(
                "pyramid: no integer coarse split for a {}x{} region; items there refine in \
                 independent chains without cross-tile exchange",
                g.h, g.w
            ));
        }
        let mut sessions = Vec::with_capacity(shapes.len());
        for &shape in &shapes {
            sessions.push(backend.session(shape, cfg.session_opts())?);
        }
        let max_b = shapes.iter().map(|s| s.n).max().unwrap_or(0);
        let st = PyrState {
            sessions,
            slots: shapes.iter().map(|&s| ShapeSlot::new(cfg, s)).collect(),
            bufs: LoopBufs::default(),
            slot_at: vec![0; g.n()],
            scratch: Vec::with_capacity(g.n()),
            members: Vec::with_capacity(max_b),
            rank: vec![0; g.n()],
            x_tile: Vec::with_capacity(max_b * d),
            inv_tile: Vec::with_capacity(max_b),
            cent: Vec::new(),
            agg_losses: Vec::new(),
            stats: LoopStats::default(),
        };
        Ok(PyramidExecutor { cfg: cfg.clone(), norm, root, levels, leaf_tiles, notes, st, d })
    }
}

impl PhaseExecutor for PyramidExecutor {
    fn tiles(&self) -> usize {
        self.leaf_tiles
    }

    fn annotate(&self, report: &mut RunReport) {
        report.tile_plan = "pyramid".to_string();
        report.notes.extend(self.notes.iter().cloned());
    }

    fn run_phase(
        &mut self,
        r: usize,
        tau: f32,
        x_shuf: &[f32],
        _shuf: &Permutation,
        inv: &Permutation,
        _inv_idx: &[i32],
        report: &mut RunReport,
        trace_ctx: Option<trace::SpanContext>,
    ) -> Result<Permutation> {
        let started = std::time::Instant::now();
        let n = inv.len();
        let g = self.cfg.grid;
        let mut span = trace::Span::child_of(trace_ctx, "pyramid");
        span.attr_u64("levels", self.levels as u64);
        span.attr_u64("leaves", self.leaf_tiles as u64);

        self.st.slot_at.clear();
        self.st.slot_at.extend_from_slice(inv.as_slice());
        self.st.agg_losses.clear();
        self.st.agg_losses.resize(self.cfg.inner_iters, 0.0);
        self.st.stats = LoopStats::default();

        let env = PyrEnv {
            cfg: &self.cfg,
            norm: self.norm,
            d: self.d,
            grid_w: g.w,
            n,
            tau,
            ctx: span.ctx(),
        };
        pyr_solve_node(&self.root, &mut self.st, &env, x_shuf, 0, 0, g.h, g.w, 0)
            .with_context(|| format!("pyramid phase {r}"))?;
        span.end();
        report.sections.add("execute", started.elapsed());

        // slot_at is the desired position→slot assignment; the driver's
        // convention is slot_at[pos] = sort_perm[inv[pos]], so scatter
        // through the inverse shuffle.
        let mut sort_vec = vec![0u32; n];
        for (pos, &s) in self.st.slot_at.iter().enumerate() {
            sort_vec[inv.as_slice()[pos] as usize] = s;
        }
        record_phase(report, &self.cfg, r, tau, &self.st.agg_losses, self.st.stats);
        Permutation::from_vec(sort_vec)
            .map_err(|e| anyhow!("pyramid phase composition is not a bijection: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes(plan: &TilePlan) -> Vec<usize> {
        plan.tiles.iter().map(|t| t.shape.n).collect()
    }

    #[test]
    fn plan_splits_rows_and_absorbs_ragged_remainders() {
        // 8x8, tile_n=16 → 2 rows per tile → 4 tiles of 16.
        let p = TilePlan::new(GridShape::new(8, 8), 3, 16);
        assert_eq!(sizes(&p), vec![16, 16, 16, 16]);
        assert_eq!(p.shapes.len(), 1);
        assert_eq!((p.shapes[0].h, p.shapes[0].w), (2, 8));

        // Ragged: 5 rows of 7 with 2-row tiles → 14, 14, 7.
        let p = TilePlan::new(GridShape::new(5, 7), 3, 14);
        assert_eq!(sizes(&p), vec![14, 14, 7]);
        assert_eq!(p.shapes.len(), 2);

        // 1-D grid splits by cells; a trailing single cell is absorbed.
        let p = TilePlan::new(GridShape::new(1, 13), 3, 4);
        assert_eq!(sizes(&p), vec![4, 4, 5]);
        for t in &p.tiles {
            assert_eq!(t.shape.h, 1);
            assert!(t.shape.n >= 2);
        }

        // Tall-and-thin (w=1): whole rows but never a 1-item tile.
        let p = TilePlan::new(GridShape::new(9, 1), 2, 1);
        assert!(sizes(&p).iter().all(|&s| s >= 2), "{:?}", sizes(&p));
        assert_eq!(sizes(&p).iter().sum::<usize>(), 9);
    }

    #[test]
    fn plan_splits_wide_rows_into_column_segments() {
        // tile_n smaller than the grid width must NOT round up to full
        // w-cell rows (that would break the O(tile_n²) scratch contract on
        // wide grids) — each row splits into 1-D column segments instead.
        let p = TilePlan::new(GridShape::new(4, 16), 3, 4);
        assert_eq!(p.tiles.len(), 16);
        for t in &p.tiles {
            assert_eq!((t.shape.n, t.shape.h, t.shape.w), (4, 1, 4));
        }
        // Ragged segment split, trailing singleton absorbed per row.
        let p = TilePlan::new(GridShape::new(3, 13), 3, 4);
        assert_eq!(sizes(&p), vec![4, 4, 5, 4, 4, 5, 4, 4, 5]);
        assert!(p.tiles.iter().all(|t| t.shape.h == 1));
        // Coverage still exact.
        let g = GridShape::new(3, 13);
        let mut covered = vec![false; g.n()];
        for b in 0..p.tiles.len() {
            for &pos in p.positions(b) {
                let pos = pos as usize;
                assert!(!covered[pos]);
                covered[pos] = true;
                assert_eq!(p.tile_of[pos], b as u32);
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn plan_with_tile_n_at_least_n_is_one_full_tile() {
        for (h, w) in [(8usize, 8usize), (1, 16), (5, 3)] {
            let g = GridShape::new(h, w);
            for tile_n in [g.n(), g.n() + 1, 10 * g.n()] {
                let p = TilePlan::new(g, 3, tile_n);
                assert_eq!(p.tiles.len(), 1, "{h}x{w} tile_n={tile_n}");
                let s = p.tiles[0].shape;
                assert_eq!((s.n, s.h, s.w), (g.n(), h, w));
            }
        }
    }

    #[test]
    fn plan_positions_cover_the_grid_exactly_once() {
        for (h, w, t) in [(8usize, 8usize, 16usize), (5, 7, 10), (1, 40, 7), (9, 4, 13)] {
            let g = GridShape::new(h, w);
            let p = TilePlan::new(g, 3, t);
            let mut covered = vec![false; g.n()];
            for b in 0..p.tiles.len() {
                for &pos in p.positions(b) {
                    let pos = pos as usize;
                    assert!(!covered[pos], "{h}x{w} t={t}: position {pos} covered twice");
                    covered[pos] = true;
                    assert_eq!(p.tile_of[pos], b as u32);
                }
            }
            assert!(covered.iter().all(|&c| c), "{h}x{w} t={t}: gap in coverage");
        }
    }

    /// Validity of a plan: every grid position appears exactly once across
    /// the plan's tiles (a bijection between positions and (tile, local)
    /// pairs), `tile_of` agrees with the position lists, and every tile
    /// holds ≥ 2 items with a consistent shape.
    fn assert_plan_valid(p: &TilePlan, g: GridShape, tag: &str) {
        let mut covered = vec![false; g.n()];
        for b in 0..p.tiles.len() {
            let t = &p.tiles[b];
            assert_eq!(t.shape.n, t.shape.h * t.shape.w, "{tag}: tile {b} shape inconsistent");
            assert_eq!(t.shape, p.shapes[t.shape_idx], "{tag}: tile {b} shape_idx mismatch");
            assert!(t.shape.n >= 2 || g.n() < 2, "{tag}: tile {b} holds < 2 items");
            for &pos in p.positions(b) {
                let pos = pos as usize;
                assert!(pos < g.n(), "{tag}: tile {b} position {pos} out of grid");
                assert!(!covered[pos], "{tag}: position {pos} covered twice");
                covered[pos] = true;
                assert_eq!(p.tile_of[pos], b as u32, "{tag}: tile_of disagrees at {pos}");
            }
        }
        assert!(covered.iter().all(|&c| c), "{tag}: gap in coverage");
    }

    #[test]
    fn snake_and_overlapped_plans_are_valid_on_ragged_shapes() {
        for (h, w, t) in [
            (8usize, 8usize, 16usize),
            (5, 7, 10),
            (1, 40, 7),
            (40, 1, 7),
            (9, 4, 13),
            (3, 50, 8),
            (2, 2, 2),
            (1, 5, 2),
        ] {
            let g = GridShape::new(h, w);
            for kind in [TilePlanKind::Banded, TilePlanKind::Snake, TilePlanKind::Overlapped] {
                let mut shapes = Vec::new();
                let plans = TilePlan::plan_set(kind, g, 3, t, &mut shapes);
                assert!(!plans.is_empty());
                for (i, p) in plans.iter().enumerate() {
                    assert_plan_valid(p, g, &format!("{kind:?}[{i}] {h}x{w} t={t}"));
                }
            }
        }
    }

    #[test]
    fn offset_variants_shift_seams() {
        // On shapes big enough to carry an offset, the phase-alternating
        // pair must actually differ — otherwise overlapped degenerates to
        // banded and seams never move.
        for (kind, h, w, t) in [
            (TilePlanKind::Overlapped, 16usize, 8usize, 16usize),
            (TilePlanKind::Overlapped, 1, 40, 8),
            (TilePlanKind::Snake, 16, 8, 16),
            (TilePlanKind::Snake, 9, 4, 8),
        ] {
            let g = GridShape::new(h, w);
            let mut shapes = Vec::new();
            let plans = TilePlan::plan_set(kind, g, 3, t, &mut shapes);
            assert_eq!(plans.len(), 2, "{kind:?} {h}x{w} t={t}: expected an alternating pair");
            assert!(
                !plans[1].same_partition(&plans[0]),
                "{kind:?} {h}x{w} t={t}: offset variant equals the base cut"
            );
        }
    }

    #[test]
    fn snake_path_is_boustrophedon() {
        // Snake tiles walk row-major with odd rows reversed, so consecutive
        // path positions are always grid neighbors (|Δrow| + |Δcol| == 1).
        let g = GridShape::new(6, 5);
        let mut shapes = Vec::new();
        let p = TilePlan::snake(g, 3, 7, false, &mut shapes);
        assert_plan_valid(&p, g, "snake 6x5");
        let mut flat = Vec::new();
        for b in 0..p.tiles.len() {
            flat.extend_from_slice(p.positions(b));
        }
        for pair in flat.windows(2) {
            let (a, b) = (pair[0] as usize, pair[1] as usize);
            let (ar, ac) = (a / g.w, a % g.w);
            let (br, bc) = (b / g.w, b % g.w);
            let dist = ar.abs_diff(br) + ac.abs_diff(bc);
            assert_eq!(dist, 1, "path jump between {a} and {b}");
        }
    }

    #[test]
    fn plan_set_collapses_when_one_tile_covers_the_grid() {
        // tile_n >= n: every kind degenerates to the single full-grid tile
        // (and the pair collapses), preserving the one-tile contract.
        for kind in [TilePlanKind::Banded, TilePlanKind::Snake, TilePlanKind::Overlapped] {
            let g = GridShape::new(4, 4);
            let mut shapes = Vec::new();
            let plans = TilePlan::plan_set(kind, g, 3, 16, &mut shapes);
            assert_eq!(plans.len(), 1, "{kind:?}");
            assert_eq!(plans[0].tiles.len(), 1, "{kind:?}");
            let s = plans[0].tiles[0].shape;
            assert_eq!((s.n, s.h, s.w), (16, 4, 4), "{kind:?}");
        }
    }

    #[test]
    fn pyramid_schedule_splits_to_budget() {
        // 256x256 with tile_n=512: one coarse level (128 subtiles of 512)
        // then leaves; every leaf fits the budget.
        let mut shapes = Vec::new();
        let (mut levels, mut fallback) = (0usize, false);
        let (root, leaves) =
            build_pyramid(256, 256, 3, 512, 0, &mut shapes, &mut levels, &mut fallback);
        assert!(!fallback);
        assert!(levels >= 1, "large grid must split at least once");
        assert!(leaves > 1);
        match &root {
            PyrNode::Split { ch, cw, sub_h, sub_w, .. } => {
                assert!(ch * cw >= 2 && ch * cw <= 512);
                assert_eq!(ch * sub_h, 256);
                assert_eq!(cw * sub_w, 256);
            }
            _ => panic!("256x256/512 must be a Split at the root"),
        }
        for s in &shapes {
            assert!(s.n <= 512, "shape {s:?} exceeds the budget");
        }

        // Budget >= n: a single leaf solve of the whole grid.
        let mut shapes = Vec::new();
        let (mut levels, mut fallback) = (0usize, false);
        let (root, leaves) =
            build_pyramid(8, 8, 3, 512, 0, &mut shapes, &mut levels, &mut fallback);
        assert_eq!((levels, leaves), (0, 1));
        assert!(matches!(root, PyrNode::Solve { .. }));
        assert_eq!(shapes, vec![StepShape { n: 64, d: 3, h: 8, w: 8 }]);

        // Prime 1-D span falls back to chains but still covers everything.
        let mut shapes = Vec::new();
        let (mut levels, mut fallback) = (0usize, false);
        let (root, _) = build_pyramid(1, 97, 3, 8, 0, &mut shapes, &mut levels, &mut fallback);
        assert!(fallback);
        match &root {
            PyrNode::Chains { chains } => {
                assert_eq!(chains.iter().map(|&(l, _)| l).sum::<usize>(), 97);
                assert!(chains.iter().all(|&(l, _)| l >= 2));
            }
            _ => panic!("prime span must fall back to chains"),
        }
    }
}
