//! Adam optimizer (Kingma & Ba) over flat f32 parameter vectors.
//!
//! Parameters live in Rust (the L2 artifacts are stateless step functions
//! returning gradients), so the optimizer is Rust-side. The update loop is
//! allocation-free after construction — it sits on the per-iteration hot
//! path (N to N² parameters).

#[derive(Clone, Debug, PartialEq)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

pub struct Adam {
    cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(cfg: AdamConfig, n: usize) -> Self {
        Adam { cfg, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Reset moments (fresh-`w` phases re-use the allocation).
    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }

    pub fn lr(&self) -> f32 {
        self.cfg.lr
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    /// One in-place update step.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.cfg.lr;
        let eps = self.cfg.eps;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam must minimize a simple convex quadratic.
    #[test]
    fn minimizes_quadratic() {
        let mut adam = Adam::new(AdamConfig { lr: 0.1, ..Default::default() }, 3);
        let target = [1.0f32, -2.0, 0.5];
        let mut p = vec![5.0f32, 5.0, 5.0];
        let mut g = vec![0.0f32; 3];
        for _ in 0..500 {
            for i in 0..3 {
                g[i] = 2.0 * (p[i] - target[i]);
            }
            adam.step(&mut p, &g);
        }
        for i in 0..3 {
            assert!((p[i] - target[i]).abs() < 1e-2, "p={p:?}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // With bias correction, |Δp| of step 1 ≈ lr regardless of grad scale.
        let mut adam = Adam::new(AdamConfig { lr: 0.25, ..Default::default() }, 1);
        let mut p = vec![0.0f32];
        adam.step(&mut p, &[1e-3]);
        assert!((p[0] + 0.25).abs() < 1e-3, "p={}", p[0]);
    }

    #[test]
    fn reset_restores_initial_state() {
        // After reset, the update DELTA for a given gradient must equal a
        // fresh optimizer's delta (moments zeroed, t back to 0).
        let mut adam = Adam::new(AdamConfig::default(), 2);
        let mut p = vec![1.0f32, 1.0];
        adam.step(&mut p, &[0.5, -0.5]);
        adam.reset();
        let before = p.clone();
        adam.step(&mut p, &[0.1, 0.2]);
        let delta_reset = [p[0] - before[0], p[1] - before[1]];

        let mut adam2 = Adam::new(AdamConfig::default(), 2);
        let mut q = vec![7.0f32, -3.0];
        adam2.step(&mut q, &[0.1, 0.2]);
        let delta_fresh = [q[0] - 7.0, q[1] + 3.0];
        // f32 subtraction at different magnitudes: tolerate a few ulps of 7.
        assert!((delta_reset[0] - delta_fresh[0]).abs() < 1e-5);
        assert!((delta_reset[1] - delta_fresh[1]).abs() < 1e-5);
    }
}
