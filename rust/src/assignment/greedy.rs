//! Greedy approximate assignment: repeatedly take the globally cheapest
//! remaining (row, col) pair. O(n² log n), no optimality guarantee — the
//! cheap comparator for the heuristics bench and a fast fallback.

/// Greedy row→col assignment for a dense n×n cost matrix.
pub fn solve(cost: &[f64], n: usize) -> Vec<u32> {
    assert_eq!(cost.len(), n * n);
    let mut entries: Vec<(f64, u32, u32)> = Vec::with_capacity(n * n);
    for r in 0..n {
        for c in 0..n {
            entries.push((cost[r * n + c], r as u32, c as u32));
        }
    }
    entries.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut row_done = vec![false; n];
    let mut col_done = vec![false; n];
    let mut assign = vec![u32::MAX; n];
    let mut remaining = n;
    for (_, r, c) in entries {
        let (r, c) = (r as usize, c as usize);
        if !row_done[r] && !col_done[c] {
            row_done[r] = true;
            col_done[c] = true;
            assign[r] = c as u32;
            remaining -= 1;
            if remaining == 0 {
                break;
            }
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assignment::jv;
    use crate::perm::Permutation;
    use crate::util::rng::Pcg32;

    #[test]
    fn produces_valid_permutation() {
        let mut rng = Pcg32::new(41);
        let n = 32;
        let cost: Vec<f64> = (0..n * n).map(|_| rng.f64()).collect();
        let a = solve(&cost, n);
        Permutation::from_vec(a).unwrap();
    }

    #[test]
    fn never_beats_jv_property() {
        let mut rng = Pcg32::new(42);
        for _ in 0..5 {
            let n = 16;
            let cost: Vec<f64> = (0..n * n).map(|_| rng.f64()).collect();
            let g = jv::assignment_cost(&cost, n, &solve(&cost, n));
            let o = jv::assignment_cost(&cost, n, &jv::solve(&cost, n));
            assert!(g >= o - 1e-9, "greedy {g} < optimal {o}?!");
        }
    }
}
