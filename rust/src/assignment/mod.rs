//! Linear assignment substrate.
//!
//! * `jv::solve` — the Jonker–Volgenant shortest-augmenting-path LAP solver
//!   [6], used for (a) hard extraction of Gumbel-Sinkhorn's doubly
//!   stochastic matrix and (b) the dimensionality-reduction + LAP grid
//!   baseline of §I-B.
//! * `greedy` — cheap approximate assignment, used as a fallback and as a
//!   baseline in the heuristics bench.

pub mod greedy;
pub mod jv;

pub use jv::solve as solve_lap;
