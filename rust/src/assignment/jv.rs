//! Jonker–Volgenant linear assignment (dense, f64 costs).
//!
//! Shortest-augmenting-path formulation ("A shortest augmenting path
//! algorithm for dense and sparse linear assignment problems", Computing
//! 38(4), 1987) in the lapjv style: for each row, grow a Dijkstra tree over
//! columns until a free column is reached, then augment along the path while
//! updating dual potentials. O(n³) worst case, very fast in practice.
//!
//! Returns the row→column assignment minimizing total cost. Optimality is
//! property-tested against exhaustive search for small n.

/// Solve the LAP for a dense row-major `n×n` cost matrix.
/// Returns `assign` with `assign[row] = col`.
pub fn solve(cost: &[f64], n: usize) -> Vec<u32> {
    assert_eq!(cost.len(), n * n);
    if n == 0 {
        return Vec::new();
    }
    const INF: f64 = f64::INFINITY;

    // Dual potentials and matching state. Column 0 is a virtual root.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut col_to_row = vec![usize::MAX; n + 1]; // p[col] = matched row
    let mut way = vec![0usize; n + 1];

    for row in 0..n {
        // Augment starting from `row` (1-indexed virtual column 0).
        col_to_row[0] = row;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = col_to_row[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 * n + (j - 1)] - u[i0 + 1] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[col_to_row[j] + 1] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if col_to_row[j0] == usize::MAX {
                break;
            }
        }
        // Augment along the alternating path.
        loop {
            let j1 = way[j0];
            col_to_row[j0] = col_to_row[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![0u32; n];
    for j in 1..=n {
        if col_to_row[j] != usize::MAX && col_to_row[j] < n {
            assign[col_to_row[j]] = (j - 1) as u32;
        }
    }
    assign
}

/// Total cost of an assignment.
pub fn assignment_cost(cost: &[f64], n: usize, assign: &[u32]) -> f64 {
    assign.iter().enumerate().map(|(r, &c)| cost[r * n + c as usize]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perm::Permutation;
    use crate::util::rng::Pcg32;

    fn brute_force(cost: &[f64], n: usize) -> f64 {
        fn rec(cost: &[f64], n: usize, row: usize, used: &mut [bool], acc: f64, best: &mut f64) {
            if row == n {
                *best = best.min(acc);
                return;
            }
            if acc >= *best {
                return;
            }
            for c in 0..n {
                if !used[c] {
                    used[c] = true;
                    rec(cost, n, row + 1, used, acc + cost[row * n + c], best);
                    used[c] = false;
                }
            }
        }
        let mut best = f64::INFINITY;
        rec(cost, n, 0, &mut vec![false; n], 0.0, &mut best);
        best
    }

    #[test]
    fn trivial_cases() {
        assert!(solve(&[], 0).is_empty());
        assert_eq!(solve(&[5.0], 1), vec![0]);
        // 2x2: diagonal cheaper
        assert_eq!(solve(&[1.0, 10.0, 10.0, 1.0], 2), vec![0, 1]);
        // 2x2: anti-diagonal cheaper
        assert_eq!(solve(&[10.0, 1.0, 1.0, 10.0], 2), vec![1, 0]);
    }

    #[test]
    fn known_3x3() {
        // classic example: optimal = 5 (0→1, 1→0, 2→2 costs 2+1+2)
        let cost = [4.0, 2.0, 8.0, 1.0, 3.0, 9.0, 5.0, 6.0, 2.0];
        let a = solve(&cost, 3);
        assert_eq!(assignment_cost(&cost, 3, &a), 5.0);
    }

    #[test]
    fn matches_brute_force_property() {
        let mut rng = Pcg32::new(31);
        for n in 2..=7 {
            for _ in 0..8 {
                let cost: Vec<f64> = (0..n * n).map(|_| rng.f64() * 10.0).collect();
                let a = solve(&cost, n);
                // Valid permutation
                Permutation::from_vec(a.clone()).unwrap();
                let got = assignment_cost(&cost, n, &a);
                let best = brute_force(&cost, n);
                assert!((got - best).abs() < 1e-9, "n={n}: {got} vs {best}");
            }
        }
    }

    #[test]
    fn large_instance_valid_and_better_than_identity() {
        let mut rng = Pcg32::new(32);
        let n = 128;
        let cost: Vec<f64> = (0..n * n).map(|_| rng.f64()).collect();
        let a = solve(&cost, n);
        Permutation::from_vec(a.clone()).unwrap();
        let idcost: f64 = (0..n).map(|i| cost[i * n + i]).sum();
        assert!(assignment_cost(&cost, n, &a) <= idcost);
    }

    #[test]
    fn permutation_matrix_recovers_permutation() {
        // cost = 1 - P for a permutation matrix P must recover exactly P.
        let mut rng = Pcg32::new(33);
        let n = 24;
        let p = rng.permutation(n);
        let mut cost = vec![1.0f64; n * n];
        for (r, &c) in p.iter().enumerate() {
            cost[r * n + c as usize] = 0.0;
        }
        assert_eq!(solve(&cost, n), p);
    }
}
