//! Span-driven profile aggregator: folds finished span trees into
//! collapsed-stack profiles (Brendan Gregg's folded format, one line per
//! unique root→leaf path: `request;engine_job;phase;tile;sss_step 1234`).
//!
//! The weight of each line is the path's accumulated *self* time in
//! microseconds — a span's duration minus the durations of its direct
//! children (clamped at zero: parallel children, e.g. tiles fanned out
//! under one phase, can sum past their parent's wall time). That makes the
//! folded output directly consumable by `flamegraph.pl` or speedscope,
//! where box width should show where wall-time is actually spent rather
//! than double-counting every ancestor.
//!
//! A [`Profile`] is an ordinary value, not a process-global: the serve
//! plane owns one per server (fed with every *sampled* request trace and
//! served at `GET /v1/profile`), the CLI builds a throwaway one for
//! `--profile-file`, and the bench suite folds its own runs into a
//! `profile.folded` artifact. Folding runs once per finished trace — off
//! the request fast path — so a `Mutex<BTreeMap>` is plenty.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::FinishedTrace;
use crate::serve::json::{self as json, Json};

/// Parent-chain walks stop here: deeper "trees" indicate a parent-id
/// cycle from dropped records, not a real stack.
const MAX_DEPTH: usize = 64;

/// Aggregated timings for one unique span-name path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PathStat {
    /// Spans folded into this path.
    pub count: u64,
    /// Sum of the spans' full durations (µs).
    pub total_us: u64,
    /// Sum of the spans' durations minus their direct children's (µs).
    pub self_us: u64,
}

#[derive(Default)]
struct Inner {
    stacks: std::collections::BTreeMap<String, PathStat>,
}

/// Accumulator of folded stacks across many finished traces.
#[derive(Default)]
pub struct Profile {
    inner: Mutex<Inner>,
    traces: AtomicU64,
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    /// Fold one finished trace into the profile. Spans whose parent was
    /// dropped fold as a shorter chain starting at the first reachable
    /// ancestor — still attributed, never silently skipped.
    pub fn observe(&self, t: &FinishedTrace) {
        let spans = &t.spans;
        let mut index: HashMap<u64, usize> = HashMap::with_capacity(spans.len());
        for (i, s) in spans.iter().enumerate() {
            index.insert(s.span_id, i);
        }
        // Direct-children duration per span, for self-time.
        let mut child_us = vec![0u64; spans.len()];
        for s in spans {
            if let Some(&p) = index.get(&s.parent_id) {
                child_us[p] = child_us[p].saturating_add(s.dur_us);
            }
        }
        let mut inner = lock(&self.inner);
        let mut names: Vec<&str> = Vec::with_capacity(8);
        for (i, s) in spans.iter().enumerate() {
            names.clear();
            names.push(s.name);
            let mut up = s.parent_id;
            while up != 0 && names.len() < MAX_DEPTH {
                let Some(&pi) = index.get(&up) else { break };
                names.push(spans[pi].name);
                up = spans[pi].parent_id;
            }
            names.reverse();
            let path = names.join(";");
            let stat = inner.stacks.entry(path).or_default();
            stat.count += 1;
            stat.total_us = stat.total_us.saturating_add(s.dur_us);
            stat.self_us = stat.self_us.saturating_add(s.dur_us.saturating_sub(child_us[i]));
        }
        self.traces.fetch_add(1, Ordering::Relaxed);
    }

    /// Traces folded in since creation (or the last [`Profile::reset`]).
    pub fn traces(&self) -> u64 {
        self.traces.load(Ordering::Relaxed)
    }

    /// Unique paths currently held.
    pub fn len(&self) -> usize {
        lock(&self.inner).stacks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all accumulated stacks (`GET /v1/profile?reset=1`).
    pub fn reset(&self) {
        lock(&self.inner).stacks.clear();
        self.traces.store(0, Ordering::Relaxed);
    }

    /// Snapshot as `(path, stat)` pairs, heaviest total time first (ties
    /// break on the path for determinism).
    pub fn snapshot(&self) -> Vec<(String, PathStat)> {
        let mut v: Vec<(String, PathStat)> =
            lock(&self.inner).stacks.iter().map(|(k, s)| (k.clone(), *s)).collect();
        v.sort_by(|a, b| b.1.total_us.cmp(&a.1.total_us).then_with(|| a.0.cmp(&b.0)));
        v
    }

    /// Brendan Gregg folded format: one `path self_us` line per unique
    /// path, heaviest first. Paste-ready for `flamegraph.pl` / speedscope.
    pub fn folded(&self) -> String {
        let mut out = String::new();
        for (path, stat) in self.snapshot() {
            out.push_str(&path);
            out.push(' ');
            out.push_str(&stat.self_us.to_string());
            out.push('\n');
        }
        out
    }

    /// JSON projection (`GET /v1/profile?format=json`).
    pub fn to_json(&self) -> Json {
        let stacks = self.snapshot().into_iter().map(|(path, stat)| {
            json::obj([
                ("stack", Json::from(path)),
                ("count", Json::from(stat.count)),
                ("total_us", Json::from(stat.total_us)),
                ("self_us", Json::from(stat.self_us)),
            ])
        });
        json::obj([
            ("traces", Json::from(self.traces())),
            ("stacks", json::arr(stacks)),
        ])
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SpanRecord, MAX_ATTRS};

    fn rec(
        trace_id: u64,
        span_id: u64,
        parent_id: u64,
        name: &'static str,
        start_us: u64,
        dur_us: u64,
    ) -> SpanRecord {
        SpanRecord {
            trace_id,
            span_id,
            parent_id,
            name,
            start_us,
            dur_us,
            tid: 1,
            attrs: [None; MAX_ATTRS],
        }
    }

    fn sample_trace() -> FinishedTrace {
        // request(100) -> engine_job(80) -> {phase(30) -> tile(20), phase#2(40)}
        FinishedTrace {
            trace_id: 7,
            spans: vec![
                rec(7, 1, 0, "request", 0, 100),
                rec(7, 2, 1, "engine_job", 5, 80),
                rec(7, 3, 2, "phase", 10, 30),
                rec(7, 4, 3, "tile", 12, 20),
                rec(7, 5, 2, "phase", 45, 40),
            ],
            dropped: 0,
        }
    }

    #[test]
    fn folds_self_and_total_time_per_path() {
        let p = Profile::new();
        p.observe(&sample_trace());
        let stacks: std::collections::HashMap<String, PathStat> =
            p.snapshot().into_iter().collect();
        assert_eq!(stacks["request"].total_us, 100);
        assert_eq!(stacks["request"].self_us, 20); // 100 - 80
        assert_eq!(stacks["request;engine_job"].self_us, 10); // 80 - 30 - 40
        // Both phases fold into one path: count 2, total 70, self 70-20.
        let phase = stacks["request;engine_job;phase"];
        assert_eq!((phase.count, phase.total_us, phase.self_us), (2, 70, 50));
        assert_eq!(stacks["request;engine_job;phase;tile"].self_us, 20);
        assert_eq!(p.traces(), 1);
    }

    #[test]
    fn parallel_children_clamp_self_time_at_zero() {
        let p = Profile::new();
        // Two 60µs tiles under a 100µs phase (parallel workers): the sum
        // of children exceeds the parent — self clamps to 0.
        let t = FinishedTrace {
            trace_id: 9,
            spans: vec![
                rec(9, 1, 0, "phase", 0, 100),
                rec(9, 2, 1, "tile", 0, 60),
                rec(9, 3, 1, "tile", 0, 60),
            ],
            dropped: 0,
        };
        p.observe(&t);
        let stacks: std::collections::HashMap<String, PathStat> =
            p.snapshot().into_iter().collect();
        assert_eq!(stacks["phase"].self_us, 0);
        assert_eq!(stacks["phase;tile"].self_us, 120);
    }

    #[test]
    fn orphan_spans_fold_from_first_reachable_ancestor() {
        let p = Profile::new();
        // Span 4's parent (99) was dropped from the ring: it folds as a
        // root-level "tile" chain instead of vanishing.
        let t = FinishedTrace {
            trace_id: 3,
            spans: vec![rec(3, 1, 0, "request", 0, 10), rec(3, 4, 99, "tile", 2, 5)],
            dropped: 1,
        };
        p.observe(&t);
        let stacks: std::collections::HashMap<String, PathStat> =
            p.snapshot().into_iter().collect();
        assert_eq!(stacks["tile"].count, 1);
        assert_eq!(stacks["request"].self_us, 10);
    }

    #[test]
    fn folded_lines_and_json_round_trip() {
        let p = Profile::new();
        p.observe(&sample_trace());
        p.observe(&sample_trace());
        let folded = p.folded();
        let lines: Vec<&str> = folded.lines().collect();
        assert_eq!(lines.len(), 4);
        for line in &lines {
            let (path, weight) = line.rsplit_once(' ').expect("`path weight` shape");
            assert!(!path.is_empty());
            weight.parse::<u64>().expect("integer weight");
        }
        // Heaviest total first: the request root leads.
        assert!(lines[0].starts_with("request "));
        assert!(folded.contains("request;engine_job;phase;tile 40\n"));

        let parsed = Json::parse(&p.to_json().to_string_compact()).unwrap();
        assert_eq!(parsed.get("traces").and_then(Json::as_f64), Some(2.0));
        let stacks = parsed.get("stacks").and_then(Json::as_arr).unwrap();
        assert_eq!(stacks.len(), 4);
        assert!(stacks.iter().any(|s| {
            s.get("stack").and_then(Json::as_str) == Some("request;engine_job;phase;tile")
                && s.get("count").and_then(Json::as_f64) == Some(2.0)
        }));

        p.reset();
        assert!(p.is_empty());
        assert_eq!(p.traces(), 0);
        assert_eq!(p.folded(), "");
    }
}
