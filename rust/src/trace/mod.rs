//! End-to-end tracing spine: one span tree per request (or CLI run),
//! threaded from the serve accept loop through shard routing, queue wait,
//! the engine job, driver phases, executor tiles and down to the backend
//! step-family kernels.
//!
//! Disabled-path contract (the PR 3 invariant): every instrumentation
//! point starts with a single relaxed [`AtomicBool`] load and a branch —
//! no clock reads, no allocation, and bit-identical results whether
//! tracing is on or off. The enabled path reads clocks but still never
//! allocates per record: spans end as fixed-size `Copy` [`SpanRecord`]s
//! pushed into a preallocated per-thread ring buffer ([`RING_CAP`] slots,
//! wraparound counted in `dropped`), under a per-thread mutex that is
//! uncontended except while a collector drains it. A ring lives exactly
//! as long as its thread: exit flushes residual records into the pending
//! store and deregisters the ring, so short-lived worker threads (scoped
//! sort workers, per-sort pools) never accumulate rings process-wide.
//!
//! Assembly is pull-based — there is no background thread. Ending a root
//! span and calling [`finish`] drains every registered ring, routes the
//! records to their traces, and files the finished trace in a bounded LRU
//! that [`get`] (the `GET /v1/trace/<id>` endpoint) serves from. Two JSON
//! projections exist: [`trace_json`] (the span-tree document) and
//! [`chrome_trace_json`] (`chrome://tracing` trace-event format, what
//! `--trace-file` writes).
//!
//! Span identity is process-local: `trace_id` is a `RandomState` hash of a
//! global counter (no system entropy needed), `span_id` a plain counter,
//! `parent_id == 0` marks a root. Cross-thread parenting is explicit —
//! pass a [`SpanContext`] (`Copy`) into the worker and open spans with
//! [`Span::child_of`]; same-thread nesting can use the thread-local
//! current stack ([`Span::make_current`] / [`Span::child`]).

pub mod profile;

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::serve::json::{self as json, Json};

/// Per-thread ring capacity in records (~2048 × ~200 B per thread that
/// records at least once).
pub const RING_CAP: usize = 2048;
/// Default finished-trace LRU capacity (`--trace-keep` overrides at boot).
pub const DEFAULT_FINISHED_CAP: usize = 128;
/// Distinct unfinished traces the pending map will hold between drains;
/// inserting beyond this evicts the oldest pending trace (its span count
/// lands in the next finished trace's `dropped`).
const PENDING_CAP: usize = 64;
/// Spans kept per trace; the excess is counted in `FinishedTrace::dropped`.
pub const MAX_SPANS_PER_TRACE: usize = 4096;
/// Attribute slots per span (fixed array — no allocation on the record path).
pub const MAX_ATTRS: usize = 8;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static FINISHED_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_FINISHED_CAP);
static FINISHED_EVICTIONS: AtomicU64 = AtomicU64::new(0);

/// Finished traces kept for `GET /v1/trace/<id>` (LRU eviction). Runtime
/// value of the `--trace-keep` serve knob; defaults to
/// [`DEFAULT_FINISHED_CAP`].
pub fn finished_cap() -> usize {
    FINISHED_CAP.load(Ordering::Relaxed)
}

/// Set the finished-trace LRU capacity (clamped to ≥ 1). Called once at
/// serve boot from `--trace-keep`; existing excess traces age out on the
/// next [`finish`].
pub fn set_finished_cap(n: usize) {
    FINISHED_CAP.store(n.max(1), Ordering::Relaxed);
}

/// Total finished traces evicted from the LRU since process start
/// (monotonic; exported by `/metrics`).
pub fn finished_evictions() -> u64 {
    FINISHED_EVICTIONS.load(Ordering::Relaxed)
}

/// Whether tracing is globally enabled (one relaxed load).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable tracing for the process (idempotent; also pins the time epoch).
pub fn enable() {
    set_enabled(true);
}

/// Toggle tracing. Production code only ever *enables* (serve at boot, the
/// CLI under `--trace-file`); disabling exists for tests and benches,
/// which must hold [`exclusive_test_lock`] while toggling.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Process-wide timestamp origin for `start_us` (pinned on first use).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn micros_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_micros() as u64).unwrap_or(0)
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn next_span_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Fresh trace id: a `RandomState` hash of a global counter — well-spread
/// and unique per process without system entropy, never 0.
pub fn new_trace_id() -> u64 {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hash, Hasher};
    static STATE: OnceLock<RandomState> = OnceLock::new();
    let mut h = STATE.get_or_init(RandomState::new).build_hasher();
    NEXT_ID.fetch_add(1, Ordering::Relaxed).hash(&mut h);
    h.finish() | 1
}

/// Wire form of a trace id (16 lowercase hex digits, `X-Trace-Id` /
/// `/v1/trace/<id>`).
pub fn format_trace_id(id: u64) -> String {
    format!("{id:016x}")
}

/// Parse the wire form (1–16 hex digits; 0 and non-hex are rejected).
pub fn parse_trace_id(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() || s.len() > 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok().filter(|id| *id != 0)
}

/// A typed span attribute. `Str` is `&'static` on purpose: attribute
/// recording may not allocate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttrValue {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

impl AttrValue {
    fn to_json(self) -> Json {
        match self {
            AttrValue::U64(v) => Json::Num(v as f64),
            AttrValue::F64(v) => json::num(v),
            AttrValue::Str(s) => Json::from(s),
        }
    }
}

/// The `Copy` handle that crosses thread and queue boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanContext {
    pub trace_id: u64,
    pub span_id: u64,
}

pub type Attrs = [Option<(&'static str, AttrValue)>; MAX_ATTRS];

/// One ended span, as stored in the rings and in finished traces.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    /// 0 = root.
    pub parent_id: u64,
    pub name: &'static str,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Recording-thread index (1-based registration order).
    pub tid: u64,
    pub attrs: Attrs,
}

// -- per-thread rings + collector registry ---------------------------------

struct Ring {
    buf: Vec<SpanRecord>,
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() < RING_CAP {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
            self.dropped += 1;
        }
        self.next = (self.next + 1) % RING_CAP;
    }

    fn drain(&mut self) -> (Vec<SpanRecord>, u64) {
        self.next = 0;
        // `drain(..)` keeps the ring's capacity, so the record path stays
        // allocation-free after the first fill.
        (self.buf.drain(..).collect(), std::mem::take(&mut self.dropped))
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// 1-based display tids; a global counter (not the registry length) so
/// they stay unique across ring deregistrations.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// The thread-local owner of a registered ring. Its `Drop` runs at thread
/// exit: residual records are flushed into the pending store (so an
/// in-flight trace keeps spans recorded by a worker that exits before
/// `finish`), and the ring is removed from the registry — short-lived
/// recording threads (scoped sort workers, per-sort pools) must not leak
/// a ring per thread for the life of the process.
struct ThreadRing {
    tid: u64,
    ring: Arc<Mutex<Ring>>,
}

impl Drop for ThreadRing {
    fn drop(&mut self) {
        let (recs, dropped) = lock(&self.ring).drain();
        {
            let mut st = lock(store());
            st.orphan_dropped += dropped;
            for r in recs {
                park(&mut st, r, 0);
            }
        }
        lock(registry()).retain(|r| !Arc::ptr_eq(r, &self.ring));
    }
}

thread_local! {
    static LOCAL_RING: RefCell<Option<ThreadRing>> = const { RefCell::new(None) };
    static CURRENT: RefCell<Vec<SpanContext>> = const { RefCell::new(Vec::new()) };
}

fn record(mut rec: SpanRecord) {
    LOCAL_RING.with(|cell| {
        let mut slot = cell.borrow_mut();
        let tr = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring {
                buf: Vec::with_capacity(RING_CAP),
                next: 0,
                dropped: 0,
            }));
            lock(registry()).push(ring.clone());
            ThreadRing { tid: NEXT_TID.fetch_add(1, Ordering::Relaxed), ring }
        });
        rec.tid = tr.tid;
        lock(&tr.ring).push(rec);
    });
}

/// The innermost span this thread made current, if any.
pub fn current() -> Option<SpanContext> {
    CURRENT.with(|c| c.borrow().last().copied())
}

// -- spans ------------------------------------------------------------------

/// An in-flight span. A disabled span (`None` inner) is free to hold and
/// drop: constructors return it after the one-load gate, so call sites
/// need no `if enabled()` of their own. The record is written when the
/// span drops (or [`Span::end`] consumes it).
pub struct Span {
    inner: Option<ActiveSpan>,
}

struct ActiveSpan {
    ctx: SpanContext,
    parent_id: u64,
    name: &'static str,
    start: Instant,
    attrs: Attrs,
}

impl Span {
    /// The always-disabled span (records nothing).
    pub fn off() -> Span {
        Span { inner: None }
    }

    fn open(trace_id: u64, parent_id: u64, name: &'static str) -> Span {
        Span {
            inner: Some(ActiveSpan {
                ctx: SpanContext { trace_id, span_id: next_span_id() },
                parent_id,
                name,
                start: Instant::now(),
                attrs: [None; MAX_ATTRS],
            }),
        }
    }

    /// Root span with a fresh trace id.
    pub fn root(name: &'static str) -> Span {
        if !enabled() {
            return Span::off();
        }
        Span::open(new_trace_id(), 0, name)
    }

    /// Child of this thread's current span (disabled when there is none).
    pub fn child(name: &'static str) -> Span {
        if !enabled() {
            return Span::off();
        }
        match current() {
            Some(p) => Span::open(p.trace_id, p.span_id, name),
            None => Span::off(),
        }
    }

    /// Child of an explicit parent — the cross-thread form (disabled when
    /// the parent is `None`, which lets sampling decisions flow through).
    pub fn child_of(parent: Option<SpanContext>, name: &'static str) -> Span {
        if !enabled() {
            return Span::off();
        }
        match parent {
            Some(p) => Span::open(p.trace_id, p.span_id, name),
            None => Span::off(),
        }
    }

    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }

    pub fn ctx(&self) -> Option<SpanContext> {
        self.inner.as_ref().map(|a| a.ctx)
    }

    /// Set an attribute (first [`MAX_ATTRS`] stick; the rest are ignored).
    pub fn attr(&mut self, key: &'static str, value: AttrValue) {
        if let Some(a) = &mut self.inner {
            if let Some(slot) = a.attrs.iter_mut().find(|s| s.is_none()) {
                *slot = Some((key, value));
            }
        }
    }

    pub fn attr_u64(&mut self, key: &'static str, v: u64) {
        self.attr(key, AttrValue::U64(v));
    }

    pub fn attr_f64(&mut self, key: &'static str, v: f64) {
        self.attr(key, AttrValue::F64(v));
    }

    pub fn attr_str(&mut self, key: &'static str, v: &'static str) {
        self.attr(key, AttrValue::Str(v));
    }

    /// Push this span onto the thread's current stack; the guard pops it.
    /// No-op for disabled spans.
    pub fn make_current(&self) -> CurrentGuard {
        match self.ctx() {
            Some(ctx) => {
                CURRENT.with(|c| c.borrow_mut().push(ctx));
                CurrentGuard { active: true }
            }
            None => CurrentGuard { active: false },
        }
    }

    /// End the span now (identical to dropping it; reads better at call
    /// sites that also hold a `make_current` guard).
    pub fn end(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(a) = self.inner.take() {
            record(SpanRecord {
                trace_id: a.ctx.trace_id,
                span_id: a.ctx.span_id,
                parent_id: a.parent_id,
                name: a.name,
                start_us: micros_since_epoch(a.start),
                dur_us: a.start.elapsed().as_micros() as u64,
                tid: 0, // filled by `record`
                attrs: a.attrs,
            });
        }
    }
}

/// Pops the thread-current span on drop (see [`Span::make_current`]).
pub struct CurrentGuard {
    active: bool,
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        if self.active {
            CURRENT.with(|c| {
                c.borrow_mut().pop();
            });
        }
    }
}

/// Record a span whose interval was measured externally (e.g. queue wait,
/// timed from the enqueue instant in the dequeuing thread).
pub fn record_span(
    parent: SpanContext,
    name: &'static str,
    start: Instant,
    dur: Duration,
    attrs: &[(&'static str, AttrValue)],
) {
    if !enabled() {
        return;
    }
    let mut a: Attrs = [None; MAX_ATTRS];
    for (slot, kv) in a.iter_mut().zip(attrs) {
        *slot = Some(*kv);
    }
    record(SpanRecord {
        trace_id: parent.trace_id,
        span_id: next_span_id(),
        parent_id: parent.span_id,
        name,
        start_us: micros_since_epoch(start),
        dur_us: dur.as_micros() as u64,
        tid: 0,
        attrs: a,
    });
}

// -- step-family clocks -----------------------------------------------------

/// Step-family span names, index-aligned with the `FAM_*` constants (and
/// with the per-family totals in `serve::metrics`).
pub const FAMILY_NAMES: [&str; 4] = ["sss_step", "gs_step", "kiss_step", "adam_step"];
pub const FAM_SSS: usize = 0;
pub const FAM_GS: usize = 1;
pub const FAM_KISS: usize = 2;
pub const FAM_ADAM: usize = 3;

/// Aggregating timer for the per-step backend kernels. Per-step spans
/// would swamp the rings (R·I records per family), so the inner loops
/// accumulate per-family totals and [`StepClock::emit`] writes ONE span
/// per family at loop end, with the call count as a `steps` attribute.
/// Inert — no clock reads — when tracing is off or `parent` is `None`.
pub struct StepClock {
    parent: Option<SpanContext>,
    acc: [(Duration, u64); FAMILY_NAMES.len()],
}

impl StepClock {
    /// Families will be emitted under `parent` (typically the tile or
    /// engine-job span the loop runs in).
    pub fn start(parent: Option<SpanContext>) -> StepClock {
        StepClock {
            parent: if enabled() { parent } else { None },
            acc: [(Duration::ZERO, 0); FAMILY_NAMES.len()],
        }
    }

    #[inline]
    pub fn time<T>(&mut self, family: usize, f: impl FnOnce() -> T) -> T {
        if self.parent.is_none() {
            return f();
        }
        let t = Instant::now();
        let out = f();
        self.acc[family].0 += t.elapsed();
        self.acc[family].1 += 1;
        out
    }

    /// Emit one aggregate span per family that ran (synthetic start: the
    /// family's total duration back from now).
    pub fn emit(self) {
        let Some(p) = self.parent else { return };
        let now = Instant::now();
        for (i, (total, count)) in self.acc.iter().enumerate() {
            if *count == 0 {
                continue;
            }
            let start = now.checked_sub(*total).unwrap_or(now);
            record_span(p, FAMILY_NAMES[i], start, *total, &[("steps", AttrValue::U64(*count))]);
        }
    }
}

// -- finished-trace store ---------------------------------------------------

/// An assembled trace: records sorted by `(start_us, span_id)`.
#[derive(Debug)]
pub struct FinishedTrace {
    pub trace_id: u64,
    pub spans: Vec<SpanRecord>,
    /// Records lost to ring wraparound, per-trace caps, or pending-map
    /// eviction. Losses that cannot be attributed to a trace are charged
    /// to whichever trace finishes next — an upper bound, never an
    /// undercount.
    pub dropped: u64,
}

struct Store {
    /// Drained records for traces not yet finished, keyed by trace id.
    pending: HashMap<u64, (Vec<SpanRecord>, u64)>,
    /// Insertion order of `pending` ids (front = oldest) — the eviction
    /// order when the map is full, so stale ids (traces whose `finish`
    /// already ran, late-arriving records) age out instead of occupying
    /// slots forever.
    pending_order: VecDeque<u64>,
    /// Records lost outside any live pending entry: ring overwrites, ring
    /// flushes from exited threads, and pending entries evicted at
    /// [`PENDING_CAP`]. Charged to the next trace that finishes.
    orphan_dropped: u64,
    finished: HashMap<u64, Arc<FinishedTrace>>,
    /// LRU order of `finished` (front = oldest; [`get`] bumps recency).
    order: VecDeque<u64>,
}

fn store() -> &'static Mutex<Store> {
    static STORE: OnceLock<Mutex<Store>> = OnceLock::new();
    STORE.get_or_init(|| {
        Mutex::new(Store {
            pending: HashMap::new(),
            pending_order: VecDeque::new(),
            orphan_dropped: 0,
            finished: HashMap::new(),
            order: VecDeque::new(),
        })
    })
}

/// Route one drained record into the pending map. A full map evicts its
/// oldest entries — never `protect`, the trace currently being finished
/// (0 = protect nothing) — and counts the evicted spans into
/// `orphan_dropped` rather than silently refusing the new record.
fn park(st: &mut Store, r: SpanRecord, protect: u64) {
    if let Some(e) = st.pending.get_mut(&r.trace_id) {
        if e.0.len() < MAX_SPANS_PER_TRACE {
            e.0.push(r);
        } else {
            e.1 += 1;
        }
        return;
    }
    while st.pending.len() >= PENDING_CAP {
        let Some(old) = st.pending_order.pop_front() else { break };
        if old == protect {
            st.pending_order.push_back(old);
            if st.pending_order.len() <= 1 {
                break;
            }
            continue;
        }
        if let Some((spans, dropped)) = st.pending.remove(&old) {
            st.orphan_dropped += spans.len() as u64 + dropped;
        }
    }
    st.pending.insert(r.trace_id, (vec![r], 0));
    st.pending_order.push_back(r.trace_id);
}

/// Drain every thread's ring, route records to their traces, and file
/// `trace_id` as finished. Returns `None` when tracing is off or nothing
/// was recorded for the id. Call *after* all of the trace's spans have
/// ended (e.g. the engine reply has been received and the root dropped).
pub fn finish(trace_id: u64) -> Option<Arc<FinishedTrace>> {
    if !enabled() {
        return None;
    }
    // Drain the rings before taking the store lock: rings are locked one
    // at a time and never nested inside the store's (the thread-exit
    // flush takes them in the same ring-then-store order).
    let rings: Vec<Arc<Mutex<Ring>>> = lock(registry()).clone();
    let mut drained: Vec<SpanRecord> = Vec::new();
    let mut unattributed = 0u64;
    for ring in &rings {
        let (recs, dropped) = lock(ring).drain();
        unattributed += dropped;
        drained.extend(recs);
    }
    drop(rings);
    // Backstop for threads whose TLS destructor never ran (abnormal
    // exit): a ring referenced only by the registry can no longer receive
    // records, so it is dead weight — prune it.
    lock(registry()).retain(|r| Arc::strong_count(r) > 1);
    let mut st = lock(store());
    st.orphan_dropped += unattributed;
    for r in drained {
        park(&mut st, r, trace_id);
    }
    let (mut spans, mut dropped) = st.pending.remove(&trace_id).unwrap_or_default();
    st.pending_order.retain(|id| *id != trace_id);
    if spans.is_empty() {
        return None;
    }
    dropped += std::mem::take(&mut st.orphan_dropped);
    spans.sort_by_key(|s| (s.start_us, s.span_id));
    let t = Arc::new(FinishedTrace { trace_id, spans, dropped });
    st.order.retain(|id| *id != trace_id);
    st.finished.insert(trace_id, t.clone());
    st.order.push_back(trace_id);
    while st.order.len() > finished_cap() {
        if let Some(old) = st.order.pop_front() {
            st.finished.remove(&old);
            FINISHED_EVICTIONS.fetch_add(1, Ordering::Relaxed);
        }
    }
    Some(t)
}

/// Drop a trace the caller decided not to keep (tail-based sampling: a
/// speculatively-traced request that finished fast). Drains the rings the
/// same way [`finish`] does — so other traces' records still park in the
/// pending map — then removes the discarded trace's records outright:
/// they never enter the finished LRU and are not counted as orphans
/// (dropping them is the caller's explicit intent, not record loss).
pub fn discard(trace_id: u64) {
    if !enabled() {
        return;
    }
    let rings: Vec<Arc<Mutex<Ring>>> = lock(registry()).clone();
    let mut drained: Vec<SpanRecord> = Vec::new();
    let mut unattributed = 0u64;
    for ring in &rings {
        let (recs, dropped) = lock(ring).drain();
        unattributed += dropped;
        drained.extend(recs);
    }
    drop(rings);
    lock(registry()).retain(|r| Arc::strong_count(r) > 1);
    let mut st = lock(store());
    st.orphan_dropped += unattributed;
    for r in drained {
        if r.trace_id == trace_id {
            continue;
        }
        park(&mut st, r, trace_id);
    }
    st.pending.remove(&trace_id);
    st.pending_order.retain(|id| *id != trace_id);
}

/// Look up a finished trace (`GET /v1/trace/<id>`) and bump its LRU
/// recency — a trace a client is actively polling must not be the
/// eviction victim while never-read traces survive.
pub fn get(trace_id: u64) -> Option<Arc<FinishedTrace>> {
    let mut st = lock(store());
    let t = st.finished.get(&trace_id).cloned()?;
    st.order.retain(|id| *id != trace_id);
    st.order.push_back(trace_id);
    Some(t)
}

// -- JSON projections -------------------------------------------------------

/// The span-tree document `/v1/trace/<id>` serves: a flat span list with
/// parent links (`parent == 0` marks the root).
pub fn trace_json(t: &FinishedTrace) -> Json {
    let spans = t.spans.iter().map(|s| {
        let attrs = s.attrs.iter().flatten().map(|(k, v)| (*k, v.to_json()));
        json::obj([
            ("id", Json::from(s.span_id)),
            ("parent", Json::from(s.parent_id)),
            ("name", Json::from(s.name)),
            ("start_us", Json::from(s.start_us)),
            ("dur_us", Json::from(s.dur_us)),
            ("tid", Json::from(s.tid)),
            ("attrs", json::obj(attrs)),
        ])
    });
    json::obj([
        ("trace_id", Json::from(format_trace_id(t.trace_id))),
        ("span_count", Json::from(t.spans.len())),
        ("dropped", Json::from(t.dropped)),
        ("spans", json::arr(spans)),
    ])
}

/// `chrome://tracing` trace-event form (`ph:"X"` complete events, µs
/// timestamps) — what `--trace-file` writes and `?format=chrome` serves.
pub fn chrome_trace_json(t: &FinishedTrace) -> Json {
    let events = t.spans.iter().map(|s| {
        let args = s
            .attrs
            .iter()
            .flatten()
            .map(|(k, v)| (*k, v.to_json()))
            .chain([
                ("span_id", Json::from(s.span_id)),
                ("parent_id", Json::from(s.parent_id)),
            ]);
        json::obj([
            ("name", Json::from(s.name)),
            ("cat", Json::from("sssort")),
            ("ph", Json::from("X")),
            ("ts", Json::from(s.start_us)),
            ("dur", Json::from(s.dur_us)),
            ("pid", Json::from(1u64)),
            ("tid", Json::from(s.tid)),
            ("args", json::obj(args)),
        ])
    });
    json::obj([
        ("traceEvents", json::arr(events)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

/// Serializes tests (and benches) that toggle the global flag or assert
/// on trace presence — the flag is process-wide, so such tests must not
/// interleave. Production code never calls this.
#[doc(hidden)]
pub fn exclusive_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Restores the enabled flag on drop so a panicking test cannot leak
    /// tracing into its neighbors.
    struct Enabled {
        _guard: MutexGuard<'static, ()>,
        prev: bool,
    }

    impl Enabled {
        fn new() -> Enabled {
            let guard = exclusive_test_lock();
            let prev = enabled();
            set_enabled(true);
            Enabled { _guard: guard, prev }
        }
    }

    impl Drop for Enabled {
        fn drop(&mut self) {
            set_enabled(self.prev);
        }
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _t = exclusive_test_lock();
        let prev = enabled();
        set_enabled(false);
        let mut s = Span::root("x");
        assert!(!s.is_recording());
        assert_eq!(s.ctx(), None);
        s.attr_u64("k", 1);
        let g = s.make_current();
        assert_eq!(current(), None);
        drop(g);
        s.end();
        assert!(Span::child("y").ctx().is_none());
        assert!(finish(123).is_none());
        set_enabled(prev);
    }

    #[test]
    fn discard_drops_a_trace_but_preserves_others() {
        let _e = Enabled::new();
        // Two concurrent traces; discarding one must not lose the other's
        // already-recorded spans, and the discarded id must be gone.
        let keep = Span::root("kept");
        let keep_id = keep.ctx().unwrap().trace_id;
        let inner = Span::child_of(keep.ctx(), "work");
        inner.end();
        let drop_root = Span::root("dropped");
        let drop_id = drop_root.ctx().unwrap().trace_id;
        drop_root.end();
        discard(drop_id);
        assert!(finish(drop_id).is_none(), "discarded trace must not finish");
        assert!(get(drop_id).is_none(), "discarded trace must not be retrievable");
        keep.end();
        let t = finish(keep_id).expect("sibling trace survives a discard");
        assert_eq!(t.spans.len(), 2);
        assert_eq!(t.dropped, 0, "a discard is not record loss");
    }

    #[test]
    fn ids_parse_and_format_round_trip() {
        let id = new_trace_id();
        assert_ne!(id, 0);
        let s = format_trace_id(id);
        assert_eq!(s.len(), 16);
        assert_eq!(parse_trace_id(&s), Some(id));
        assert_eq!(parse_trace_id("deadbeef"), Some(0xdeadbeef));
        assert_eq!(parse_trace_id(""), None);
        assert_eq!(parse_trace_id("0"), None);
        assert_eq!(parse_trace_id("xyz"), None);
        assert_eq!(parse_trace_id("11112222333344445"), None);
    }

    #[test]
    fn span_tree_assembles_with_parent_links() {
        let _e = Enabled::new();
        let mut root = Span::root("request");
        root.attr_str("kind", "test");
        let root_ctx = root.ctx().expect("enabled root records");
        {
            let _g = root.make_current();
            assert_eq!(current(), Some(root_ctx));
            let child = Span::child("phase");
            let child_ctx = child.ctx().unwrap();
            assert_eq!(child_ctx.trace_id, root_ctx.trace_id);
            let grand = Span::child_of(child.ctx(), "tile");
            assert_eq!(grand.ctx().unwrap().trace_id, root_ctx.trace_id);
            grand.end();
            child.end();
        }
        assert_eq!(current(), None);
        root.end();
        let t = finish(root_ctx.trace_id).expect("trace finished");
        assert_eq!(t.spans.len(), 3);
        let by_name = |n: &str| t.spans.iter().find(|s| s.name == n).unwrap();
        let (r, p, tl) = (by_name("request"), by_name("phase"), by_name("tile"));
        assert_eq!(r.parent_id, 0);
        assert_eq!(p.parent_id, r.span_id);
        assert_eq!(tl.parent_id, p.span_id);
        assert!(r.attrs.iter().flatten().any(|(k, v)| *k == "kind"
            && *v == AttrValue::Str("test")));
        // Retained in the LRU for later lookup.
        assert!(get(root_ctx.trace_id).is_some());
        assert!(get(root_ctx.trace_id ^ 0x5555).is_none());
    }

    #[test]
    fn ring_wraparound_counts_drops() {
        let _e = Enabled::new();
        let root = Span::root("burst");
        let ctx = root.ctx().unwrap();
        let n = RING_CAP + 300;
        let now = Instant::now();
        for _ in 0..n {
            record_span(ctx, "tick", now, Duration::from_micros(1), &[]);
        }
        root.end();
        let t = finish(ctx.trace_id).expect("trace finished");
        // This thread's ring holds RING_CAP records; everything older was
        // overwritten and counted.
        assert!(t.spans.len() <= RING_CAP);
        assert!(t.dropped >= 300, "dropped={}", t.dropped);
    }

    #[test]
    fn cross_thread_children_link_under_threads_1_to_8() {
        let _e = Enabled::new();
        for threads in 1..=8usize {
            let root = Span::root("run");
            let ctx = root.ctx().unwrap();
            std::thread::scope(|scope| {
                for w in 0..threads {
                    scope.spawn(move || {
                        let mut s = Span::child_of(Some(ctx), "tile");
                        s.attr_u64("worker", w as u64);
                        let inner = Span::child_of(s.ctx(), "sss_step");
                        inner.end();
                        s.end();
                    });
                }
            });
            root.end();
            let t = finish(ctx.trace_id).expect("trace finished");
            assert_eq!(t.spans.len(), 1 + 2 * threads);
            let ids: std::collections::HashSet<u64> =
                t.spans.iter().map(|s| s.span_id).collect();
            assert_eq!(ids.len(), t.spans.len(), "span ids unique");
            let tiles: Vec<_> = t.spans.iter().filter(|s| s.name == "tile").collect();
            assert_eq!(tiles.len(), threads);
            for s in &t.spans {
                match s.name {
                    "run" => assert_eq!(s.parent_id, 0),
                    "tile" => assert_eq!(
                        s.parent_id,
                        t.spans.iter().find(|r| r.name == "run").unwrap().span_id
                    ),
                    "sss_step" => assert!(
                        tiles.iter().any(|tl| tl.span_id == s.parent_id),
                        "step span parents a tile"
                    ),
                    other => panic!("unexpected span {other}"),
                }
            }
        }
    }

    #[test]
    fn step_clock_aggregates_families() {
        let _e = Enabled::new();
        let root = Span::root("loop");
        let ctx = root.ctx().unwrap();
        let mut clock = StepClock::start(ctx.into());
        let mut acc = 0u64;
        for i in 0..10u64 {
            acc += clock.time(FAM_SSS, || i * i);
            clock.time(FAM_ADAM, || acc += 1);
        }
        clock.emit();
        root.end();
        let t = finish(ctx.trace_id).expect("trace finished");
        let fam = |n: &str| t.spans.iter().find(|s| s.name == n).unwrap();
        for name in ["sss_step", "adam_step"] {
            let s = fam(name);
            assert_eq!(s.parent_id, ctx.span_id);
            assert!(s
                .attrs
                .iter()
                .flatten()
                .any(|(k, v)| *k == "steps" && *v == AttrValue::U64(10)));
        }
        assert!(t.spans.iter().all(|s| s.name != "gs_step"));
        // Inert without a parent: no records, closure still runs.
        let mut off = StepClock::start(None);
        assert_eq!(off.time(FAM_GS, || 7), 7);
        off.emit();
    }

    #[test]
    fn json_projections_parse_and_carry_span_names() {
        let _e = Enabled::new();
        let mut root = Span::root("request");
        root.attr_f64("loss", 0.25);
        let ctx = root.ctx().unwrap();
        Span::child_of(Some(ctx), "queue_wait").end();
        root.end();
        let t = finish(ctx.trace_id).unwrap();

        let doc = trace_json(&t);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("trace_id").and_then(Json::as_str), Some(format_trace_id(ctx.trace_id)).as_deref());
        let spans = parsed.get("spans").and_then(Json::as_arr).unwrap();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().any(|s| s.get("name").and_then(Json::as_str) == Some("queue_wait")));

        let chrome = chrome_trace_json(&t);
        let parsed = Json::parse(&chrome.to_string_compact()).unwrap();
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).is_some());
        }
    }

    #[test]
    fn thread_exit_flushes_and_deregisters_its_ring() {
        let _e = Enabled::new();
        let root = Span::root("run");
        let ctx = root.ctx().unwrap();
        let before = lock(registry()).len();
        const WORKERS: usize = 4;
        for w in 0..WORKERS {
            std::thread::spawn(move || {
                let mut s = Span::child_of(Some(ctx), "tile");
                s.attr_u64("worker", w as u64);
                s.end();
            })
            .join()
            .unwrap();
        }
        // Every worker deregistered at exit. Slack of 1 tolerates a
        // neighboring test thread's own exit racing this window; a leak
        // would grow the registry by WORKERS.
        assert!(
            lock(registry()).len() <= before + 1,
            "exited threads' rings are deregistered, not leaked"
        );
        root.end();
        // The exiting threads' spans were flushed to pending, not lost.
        let t = finish(ctx.trace_id).expect("trace finished");
        assert_eq!(
            t.spans.iter().filter(|s| s.name == "tile").count(),
            WORKERS,
            "flushed spans survive"
        );
        assert!(t.spans.iter().any(|s| s.name == "run"));
    }

    #[test]
    fn pending_overflow_evicts_oldest_and_counts_drops() {
        let _e = Enabled::new();
        // Park PENDING_CAP + 5 distinct never-finished traces in this
        // thread's ring, then finish one more: routing overflows the
        // pending map, which must evict the oldest entries and count
        // their spans rather than refuse the newest.
        let orphans: Vec<u64> = (0..PENDING_CAP + 5)
            .map(|_| {
                let s = Span::root("orphan");
                let id = s.ctx().unwrap().trace_id;
                s.end();
                id
            })
            .collect();
        let root = Span::root("target");
        let ctx = root.ctx().unwrap();
        root.end();
        let t = finish(ctx.trace_id).expect("target trace finished");
        assert!(
            t.dropped >= 6,
            "evicted orphan spans are counted, got dropped={}",
            t.dropped
        );
        // The oldest orphans were evicted; their finish finds nothing and
        // the map is back under its cap.
        assert!(finish(orphans[0]).is_none(), "evicted trace is gone");
        assert!(lock(store()).pending.len() <= PENDING_CAP);
    }

    #[test]
    fn polled_traces_survive_lru_pressure() {
        let _e = Enabled::new();
        let mk = || {
            let root = Span::root("r");
            let id = root.ctx().unwrap().trace_id;
            root.end();
            finish(id).unwrap();
            id
        };
        let polled = mk();
        let idle = mk();
        for _ in 0..(finished_cap() - 1) {
            mk();
            // Polling bumps recency, so the polled trace outlives the
            // idle one filed after it.
            assert!(get(polled).is_some(), "actively polled trace survives");
        }
        assert!(get(idle).is_none(), "never-read trace is the eviction victim");
    }

    #[test]
    fn lru_evicts_oldest_finished_trace() {
        let _e = Enabled::new();
        let mut first = 0u64;
        for i in 0..(finished_cap() + 4) {
            let root = Span::root("r");
            let ctx = root.ctx().unwrap();
            if i == 0 {
                first = ctx.trace_id;
            }
            root.end();
            finish(ctx.trace_id).unwrap();
        }
        assert!(get(first).is_none(), "oldest trace evicted");
    }

    #[test]
    fn finished_cap_is_runtime_settable_and_evictions_are_counted() {
        let _e = Enabled::new();
        let prev_cap = finished_cap();
        set_finished_cap(0); // clamps to 1
        assert_eq!(finished_cap(), 1);
        set_finished_cap(3);
        let before = finished_evictions();
        let ids: Vec<u64> = (0..5)
            .map(|_| {
                let root = Span::root("r");
                let id = root.ctx().unwrap().trace_id;
                root.end();
                finish(id).unwrap();
                id
            })
            .collect();
        // Cap 3: the two oldest of the five are gone and counted.
        assert!(get(ids[0]).is_none());
        assert!(get(ids[1]).is_none());
        assert!(get(ids[4]).is_some());
        assert!(
            finished_evictions() >= before + 2,
            "evictions counted: before={before} after={}",
            finished_evictions()
        );
        set_finished_cap(prev_cap);
    }
}
