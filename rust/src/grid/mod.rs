//! Grid topology: shapes, scan orders (the 1-D ↔ 2-D bridge ShuffleSoftSort
//! relies on), coordinates and neighbor enumeration.
//!
//! ShuffleSoftSort learns a *1-D* order; grid sorting interprets that order
//! through a scan. The "alternating horizontal and vertical" shuffles the
//! paper's conclusion mentions are exactly scan-order changes: sorting along
//! the column-major scan lets elements move within columns, which the
//! row-major order cannot express (Fig. 3's failure mode).

use crate::perm::Permutation;

/// An H×W grid; cells are addressed by the row-major linear index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridShape {
    pub h: usize,
    pub w: usize,
}

impl GridShape {
    pub fn new(h: usize, w: usize) -> Self {
        assert!(h >= 1 && w >= 1);
        GridShape { h, w }
    }

    pub fn n(&self) -> usize {
        self.h * self.w
    }

    #[inline]
    pub fn coords(&self, i: usize) -> (usize, usize) {
        (i / self.w, i % self.w)
    }

    #[inline]
    pub fn index(&self, r: usize, c: usize) -> usize {
        r * self.w + c
    }

    /// All horizontally/vertically adjacent cell pairs (the L_nbr support).
    pub fn neighbor_pairs(&self) -> Vec<(u32, u32)> {
        let mut pairs = Vec::with_capacity(2 * self.n());
        for r in 0..self.h {
            for c in 0..self.w {
                let i = self.index(r, c) as u32;
                if c + 1 < self.w {
                    pairs.push((i, self.index(r, c + 1) as u32));
                }
                if r + 1 < self.h {
                    pairs.push((i, self.index(r + 1, c) as u32));
                }
            }
        }
        pairs
    }

    /// Squared Euclidean distance between two cells' centers.
    #[inline]
    pub fn cell_dist_sq(&self, a: usize, b: usize) -> f32 {
        let (ar, ac) = self.coords(a);
        let (br, bc) = self.coords(b);
        let dr = ar as f32 - br as f32;
        let dc = ac as f32 - bc as f32;
        dr * dr + dc * dc
    }
}

/// Scan orders: permutations mapping *scan position → row-major cell index*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanOrder {
    /// Row-major (the identity scan).
    RowMajor,
    /// Column-major: transposes the roles of rows and columns.
    ColMajor,
    /// Boustrophedon rows (every odd row reversed) — keeps 1-D neighbors
    /// spatially adjacent across row boundaries.
    SnakeRows,
    /// Boustrophedon columns.
    SnakeCols,
}

impl ScanOrder {
    /// The scan as a permutation: `p[k]` = row-major index of the k-th cell
    /// visited.
    pub fn permutation(&self, g: GridShape) -> Permutation {
        let mut idx = Vec::with_capacity(g.n());
        match self {
            ScanOrder::RowMajor => {
                return Permutation::identity(g.n());
            }
            ScanOrder::ColMajor => {
                for c in 0..g.w {
                    for r in 0..g.h {
                        idx.push(g.index(r, c) as u32);
                    }
                }
            }
            ScanOrder::SnakeRows => {
                for r in 0..g.h {
                    if r % 2 == 0 {
                        for c in 0..g.w {
                            idx.push(g.index(r, c) as u32);
                        }
                    } else {
                        for c in (0..g.w).rev() {
                            idx.push(g.index(r, c) as u32);
                        }
                    }
                }
            }
            ScanOrder::SnakeCols => {
                for c in 0..g.w {
                    if c % 2 == 0 {
                        for r in 0..g.h {
                            idx.push(g.index(r, c) as u32);
                        }
                    } else {
                        for r in (0..g.h).rev() {
                            idx.push(g.index(r, c) as u32);
                        }
                    }
                }
            }
        }
        Permutation::from_vec(idx).expect("scan orders are bijections")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_round_trip() {
        let g = GridShape::new(3, 5);
        for i in 0..g.n() {
            let (r, c) = g.coords(i);
            assert_eq!(g.index(r, c), i);
        }
    }

    #[test]
    fn neighbor_pair_count() {
        // H*(W-1) horizontal + (H-1)*W vertical
        let g = GridShape::new(4, 7);
        assert_eq!(g.neighbor_pairs().len(), 4 * 6 + 3 * 7);
        let line = GridShape::new(1, 9);
        assert_eq!(line.neighbor_pairs().len(), 8);
    }

    #[test]
    fn all_scans_are_bijections() {
        let g = GridShape::new(6, 4);
        for s in [ScanOrder::RowMajor, ScanOrder::ColMajor, ScanOrder::SnakeRows, ScanOrder::SnakeCols] {
            let p = s.permutation(g);
            assert_eq!(p.len(), 24); // from_vec validates bijectivity
        }
    }

    #[test]
    fn colmajor_small_example() {
        let g = GridShape::new(2, 3);
        // cells: 0 1 2 / 3 4 5 ; column-major visit: 0,3,1,4,2,5
        let p = ScanOrder::ColMajor.permutation(g);
        assert_eq!(p.as_slice(), &[0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn snake_rows_small_example() {
        let g = GridShape::new(2, 3);
        let p = ScanOrder::SnakeRows.permutation(g);
        assert_eq!(p.as_slice(), &[0, 1, 2, 5, 4, 3]);
    }

    #[test]
    fn snake_scan_consecutive_cells_are_grid_adjacent() {
        let g = GridShape::new(5, 8);
        for s in [ScanOrder::SnakeRows, ScanOrder::SnakeCols] {
            let p = s.permutation(g);
            for k in 0..g.n() - 1 {
                let d = g.cell_dist_sq(p.as_slice()[k] as usize, p.as_slice()[k + 1] as usize);
                assert_eq!(d, 1.0, "scan {s:?} breaks adjacency at {k}");
            }
        }
    }

    #[test]
    fn cell_dist() {
        let g = GridShape::new(4, 4);
        assert_eq!(g.cell_dist_sq(0, 5), 2.0);
        assert_eq!(g.cell_dist_sq(0, 3), 9.0);
    }
}
