//! Workload generators for every experiment (DESIGN.md §4):
//! random RGB colors (Table 2 / Fig. 1), clustered feature vectors
//! (Fig. 5's e-commerce stand-in) and re-exported synthetic Gaussian scenes
//! (Fig. 6, see `crate::sog::scene`).

use crate::util::rng::Pcg32;

/// A row-major `[n, d]` dataset with provenance metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub rows: Vec<f32>,
    /// Optional ground-truth cluster labels (Fig. 5 coherence metric).
    pub labels: Option<Vec<u32>>,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.d..(i + 1) * self.d]
    }
}

/// `n` uniform random RGB colors — the paper's Table 2 / Fig. 1 workload.
pub fn random_colors(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed);
    let rows = (0..n * 3).map(|_| rng.f32()).collect();
    Dataset { name: format!("colors{n}"), n, d: 3, rows, labels: None }
}

/// Clustered synthetic "low-level visual feature" vectors — the Fig. 5
/// e-commerce stand-in (DESIGN.md §3 substitutions): `k` isotropic Gaussian
/// clusters in `d` dims with per-cluster spread, L2-clipped to [0, 1].
pub fn clustered_features(n: usize, d: usize, k: usize, spread: f32, seed: u64) -> Dataset {
    assert!(k >= 1);
    let mut rng = Pcg32::new(seed);
    let centers: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
    let mut rows = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    // Balanced but randomly ordered assignment — a cyclic i%k would align
    // cluster-mates vertically on a k-divisible grid and pre-sort the data.
    let mut assignment: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    rng.shuffle(&mut assignment);
    for i in 0..n {
        let c = assignment[i];
        labels.push(c);
        for j in 0..d {
            let v = centers[c as usize * d + j] + rng.gaussian() * spread;
            rows.push(v.clamp(0.0, 1.0));
        }
    }
    Dataset { name: format!("features{n}x{d}k{k}"), n, d, rows, labels: Some(labels) }
}

/// The Fig. 3 1-D toy: colors around the hue circle, deliberately arranged
/// so plain SoftSort starts in the local optimum the paper illustrates
/// (yellow and magenta swapped relative to the smooth circular order).
pub fn fig3_colors() -> Dataset {
    // 8 hues; perfect order is the hue circle; start order swaps two distant
    // entries so fixing it requires moving through dissimilar intermediates.
    let hues = [
        [1.0, 0.0, 0.0], // red
        [1.0, 0.0, 1.0], // magenta  (swapped with yellow)
        [1.0, 1.0, 0.0], // ...
        [0.5, 1.0, 0.0],
        [0.0, 1.0, 0.0], // green
        [0.0, 1.0, 1.0], // cyan
        [0.0, 0.0, 1.0], // blue
        [0.5, 0.0, 1.0],
    ];
    let mut rows = Vec::with_capacity(8 * 3);
    let order = [0usize, 2, 1, 3, 4, 5, 6, 7]; // swap yellow/magenta
    for &i in &order {
        rows.extend_from_slice(&hues[i]);
    }
    // Tile to N=16 by interpolating midpoints (keeps the structure, matches
    // the smallest shipped artifact).
    let mut out = Vec::with_capacity(16 * 3);
    for i in 0..8 {
        let a = &rows[i * 3..i * 3 + 3];
        let b = &rows[((i + 1) % 8) * 3..((i + 1) % 8) * 3 + 3];
        out.extend_from_slice(a);
        out.extend(a.iter().zip(b).map(|(x, y)| 0.5 * (x + y)));
    }
    Dataset { name: "fig3".into(), n: 16, d: 3, rows: out, labels: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colors_shape_and_range() {
        let ds = random_colors(100, 1);
        assert_eq!((ds.n, ds.d, ds.rows.len()), (100, 3, 300));
        assert!(ds.rows.iter().all(|v| (0.0..1.0).contains(v)));
        // Deterministic per seed, varies across seeds.
        assert_eq!(random_colors(100, 1).rows, ds.rows);
        assert_ne!(random_colors(100, 2).rows, ds.rows);
    }

    #[test]
    fn clusters_are_separated() {
        let ds = clustered_features(200, 8, 4, 0.02, 3);
        let labels = ds.labels.as_ref().unwrap();
        // Mean intra-cluster distance must be well below inter-cluster.
        let (mut intra, mut inter, mut ni, mut ne) = (0.0f64, 0.0f64, 0, 0);
        for i in 0..ds.n {
            for j in (i + 1)..ds.n {
                let dist = crate::util::stats::l2(ds.row(i), ds.row(j)) as f64;
                if labels[i] == labels[j] {
                    intra += dist;
                    ni += 1;
                } else {
                    inter += dist;
                    ne += 1;
                }
            }
        }
        assert!(intra / ni as f64 * 3.0 < inter / ne as f64);
    }

    #[test]
    fn fig3_has_16_rgb_rows() {
        let ds = fig3_colors();
        assert_eq!((ds.n, ds.d), (16, 3));
        assert!(ds.rows.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}
