//! PCA via power iteration with deflation — enough for the 2-D projection
//! the DR+LAP baseline needs (no LAPACK offline).

/// Project `[n, d]` data onto its top-2 principal components → `[n, 2]`.
pub fn project_2d(data: &[f32], n: usize, d: usize) -> Vec<f32> {
    assert_eq!(data.len(), n * d);
    if d <= 2 {
        // Already ≤2-D: pad/copy.
        let mut out = vec![0.0f32; n * 2];
        for i in 0..n {
            out[i * 2] = data[i * d];
            out[i * 2 + 1] = if d > 1 { data[i * d + 1] } else { 0.0 };
        }
        return out;
    }

    // Column means.
    let mut mean = vec![0.0f64; d];
    for row in data.chunks_exact(d) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }

    // Covariance (d×d, f64).
    let mut cov = vec![0.0f64; d * d];
    for row in data.chunks_exact(d) {
        for i in 0..d {
            let ci = row[i] as f64 - mean[i];
            for j in i..d {
                cov[i * d + j] += ci * (row[j] as f64 - mean[j]);
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            cov[i * d + j] = cov[j * d + i];
        }
    }
    let scale = 1.0 / (n.max(2) - 1) as f64;
    cov.iter_mut().for_each(|v| *v *= scale);

    // Top-2 eigenvectors by power iteration + deflation.
    let mut components = Vec::with_capacity(2);
    let mut work = cov.clone();
    for k in 0..2 {
        let mut v: Vec<f64> = (0..d).map(|i| ((i + k + 1) as f64).sin() + 0.5).collect();
        normalize(&mut v);
        let mut lambda = 0.0f64;
        for _ in 0..200 {
            let mut nv = vec![0.0f64; d];
            for i in 0..d {
                let mut s = 0.0;
                for j in 0..d {
                    s += work[i * d + j] * v[j];
                }
                nv[i] = s;
            }
            let nl = normalize(&mut nv);
            let delta: f64 = nv.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
            v = nv;
            lambda = nl;
            if delta < 1e-12 {
                break;
            }
        }
        // Deflate: work -= λ v vᵀ
        for i in 0..d {
            for j in 0..d {
                work[i * d + j] -= lambda * v[i] * v[j];
            }
        }
        components.push(v);
    }

    let mut out = vec![0.0f32; n * 2];
    for (i, row) in data.chunks_exact(d).enumerate() {
        for (k, comp) in components.iter().enumerate() {
            let mut s = 0.0f64;
            for j in 0..d {
                s += (row[j] as f64 - mean[j]) * comp[j];
            }
            out[i * 2 + k] = s as f32;
        }
    }
    out
}

fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
    v.iter_mut().for_each(|x| *x /= norm);
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn recovers_dominant_axis() {
        // Data stretched 10x along a known direction in 5-D.
        let mut rng = Pcg32::new(51);
        let n = 300;
        let d = 5;
        let axis = [1.0f32, 2.0, -1.0, 0.5, 0.0];
        let norm: f32 = axis.iter().map(|a| a * a).sum::<f32>().sqrt();
        let mut data = vec![0.0f32; n * d];
        for i in 0..n {
            let t = rng.gaussian() * 10.0;
            for j in 0..d {
                data[i * d + j] = t * axis[j] / norm + rng.gaussian() * 0.1;
            }
        }
        let proj = project_2d(&data, n, d);
        // Variance of PC1 must dwarf PC2.
        let (mut v1, mut v2) = (0.0f64, 0.0f64);
        for p in proj.chunks_exact(2) {
            v1 += (p[0] as f64).powi(2);
            v2 += (p[1] as f64).powi(2);
        }
        assert!(v1 > 20.0 * v2, "v1={v1} v2={v2}");
    }

    #[test]
    fn low_dim_passthrough() {
        let data = vec![1.0, 2.0, 3.0, 4.0];
        let out = project_2d(&data, 2, 2);
        assert_eq!(out, data);
    }
}
