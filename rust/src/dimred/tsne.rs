//! Exact (O(N²)) t-SNE [19] — small-N projection for the DR+LAP baseline.
//!
//! Standard formulation: per-point perplexity calibration by bisection on
//! the Gaussian bandwidth, symmetrized affinities, Student-t low-dim
//! kernel, gradient descent with momentum and early exaggeration. N ≤ a few
//! thousand is fine; the baseline benches use N ≤ 1024.

use crate::dimred::pca::project_2d;
use crate::util::rng::Pcg32;
use crate::util::stats::l2_sq;

pub struct TsneConfig {
    pub perplexity: f64,
    pub iters: usize,
    pub learning_rate: f64,
    pub early_exaggeration: f64,
    pub exaggeration_iters: usize,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            perplexity: 20.0,
            iters: 300,
            learning_rate: 100.0,
            early_exaggeration: 4.0,
            exaggeration_iters: 60,
        }
    }
}

/// Project `[n, d]` data to 2-D with exact t-SNE. Deterministic per seed.
pub fn tsne_2d(data: &[f32], n: usize, d: usize, cfg: &TsneConfig, seed: u64) -> Vec<f32> {
    assert_eq!(data.len(), n * d);
    if n <= 3 {
        return project_2d(data, n, d);
    }

    // Pairwise squared distances.
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = l2_sq(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]) as f64;
            d2[i * n + j] = v;
            d2[j * n + i] = v;
        }
    }

    // Conditional affinities with per-point bandwidth matching perplexity.
    let target_h = cfg.perplexity.min((n - 1) as f64 / 3.0).max(2.0).ln();
    let mut p = vec![0.0f64; n * n];
    for i in 0..n {
        let row = &d2[i * n..(i + 1) * n];
        let (mut beta, mut beta_lo, mut beta_hi) = (1.0f64, 0.0f64, f64::INFINITY);
        for _ in 0..50 {
            let mut sum = 0.0;
            let mut sum_dp = 0.0;
            for j in 0..n {
                if j != i {
                    let e = (-row[j] * beta).exp();
                    sum += e;
                    sum_dp += row[j] * e;
                }
            }
            let sum = sum.max(1e-300);
            let h = sum.ln() + beta * sum_dp / sum;
            if (h - target_h).abs() < 1e-5 {
                break;
            }
            if h > target_h {
                beta_lo = beta;
                beta = if beta_hi.is_finite() { 0.5 * (beta + beta_hi) } else { beta * 2.0 };
            } else {
                beta_hi = beta;
                beta = 0.5 * (beta + beta_lo);
            }
        }
        let mut sum = 0.0;
        for j in 0..n {
            if j != i {
                let e = (-row[j] * beta).exp();
                p[i * n + j] = e;
                sum += e;
            }
        }
        let inv = 1.0 / sum.max(1e-300);
        for j in 0..n {
            p[i * n + j] *= inv;
        }
    }

    // Symmetrize; apply early exaggeration.
    let mut pij = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            pij[i * n + j] = ((p[i * n + j] + p[j * n + i]) / (2.0 * n as f64)).max(1e-12);
        }
    }

    // Init from PCA (deterministic) + tiny jitter.
    let mut rng = Pcg32::new(seed);
    let pca = project_2d(data, n, d);
    let scale = {
        let m = pca.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
        1e-2 / m
    };
    let mut y: Vec<f64> = pca.iter().map(|&v| (v * scale) as f64).collect();
    for v in &mut y {
        *v += rng.gaussian() as f64 * 1e-4;
    }
    let mut vel = vec![0.0f64; n * 2];
    let mut grad = vec![0.0f64; n * 2];
    let mut q = vec![0.0f64; n * n];

    for it in 0..cfg.iters {
        let exag = if it < cfg.exaggeration_iters { cfg.early_exaggeration } else { 1.0 };

        // Student-t kernel and normalizer.
        let mut zsum = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = y[i * 2] - y[j * 2];
                let dy = y[i * 2 + 1] - y[j * 2 + 1];
                let qv = 1.0 / (1.0 + dx * dx + dy * dy);
                q[i * n + j] = qv;
                q[j * n + i] = qv;
                zsum += 2.0 * qv;
            }
        }
        let zsum = zsum.max(1e-300);

        grad.iter_mut().for_each(|g| *g = 0.0);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    let qv = q[i * n + j];
                    let mult = (exag * pij[i * n + j] - qv / zsum) * qv;
                    let dx = y[i * 2] - y[j * 2];
                    let dy = y[i * 2 + 1] - y[j * 2 + 1];
                    grad[i * 2] += 4.0 * mult * dx;
                    grad[i * 2 + 1] += 4.0 * mult * dy;
                }
            }
        }

        let momentum = if it < 100 { 0.5 } else { 0.8 };
        for k in 0..n * 2 {
            vel[k] = momentum * vel[k] - cfg.learning_rate * grad[k];
            y[k] += vel[k];
        }
        // Re-center (translation invariance).
        let (mut mx, mut my) = (0.0f64, 0.0f64);
        for i in 0..n {
            mx += y[i * 2];
            my += y[i * 2 + 1];
        }
        mx /= n as f64;
        my /= n as f64;
        for i in 0..n {
            y[i * 2] -= mx;
            y[i * 2 + 1] -= my;
        }
    }

    y.iter().map(|&v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Two well-separated clusters must stay separated in the embedding.
    #[test]
    fn separates_two_clusters() {
        let mut rng = Pcg32::new(61);
        let n = 60;
        let d = 8;
        let mut data = vec![0.0f32; n * d];
        for i in 0..n {
            let offset = if i < n / 2 { 0.0 } else { 5.0 };
            for j in 0..d {
                data[i * d + j] = offset + rng.gaussian() * 0.2;
            }
        }
        let y = tsne_2d(&data, n, d, &TsneConfig { iters: 200, ..Default::default() }, 1);
        // Centroid distance must exceed mean intra-cluster spread.
        let centroid = |range: std::ops::Range<usize>| -> (f64, f64) {
            let mut c = (0.0, 0.0);
            for i in range.clone() {
                c.0 += y[i * 2] as f64;
                c.1 += y[i * 2 + 1] as f64;
            }
            let len = range.len() as f64;
            (c.0 / len, c.1 / len)
        };
        let a = centroid(0..n / 2);
        let b = centroid(n / 2..n);
        let sep = ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt();
        let mut spread = 0.0f64;
        for i in 0..n / 2 {
            spread += ((y[i * 2] as f64 - a.0).powi(2)
                + (y[i * 2 + 1] as f64 - a.1).powi(2))
            .sqrt();
        }
        spread /= (n / 2) as f64;
        assert!(sep > 2.0 * spread, "sep={sep} spread={spread}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg32::new(62);
        let data: Vec<f32> = (0..40 * 4).map(|_| rng.f32()).collect();
        let cfg = TsneConfig { iters: 50, ..Default::default() };
        assert_eq!(tsne_2d(&data, 40, 4, &cfg, 3), tsne_2d(&data, 40, 4, &cfg, 3));
    }
}
