//! Dimensionality-reduction substrate for the "project then LAP to grid"
//! baseline (paper §I-B: t-SNE/UMAP + Jonker–Volgenant).

pub mod pca;
pub mod tsne;

use crate::assignment::jv;
use crate::grid::GridShape;
use crate::heuristics::GridSorter;
use crate::perm::Permutation;

/// Project to 2-D (PCA or t-SNE) then assign points to grid cells with JV —
/// the §I-B pipeline [5], [6].
pub struct DrLap {
    pub use_tsne: bool,
}

impl GridSorter for DrLap {
    fn name(&self) -> &'static str {
        if self.use_tsne {
            "tSNE+LAP"
        } else {
            "PCA+LAP"
        }
    }

    fn sort(&self, data: &[f32], d: usize, g: GridShape, seed: u64) -> Permutation {
        let n = g.n();
        let pos = if self.use_tsne {
            tsne::tsne_2d(data, n, d, &tsne::TsneConfig::default(), seed)
        } else {
            pca::project_2d(data, n, d)
        };
        // Normalize projected coords to grid extent.
        let (mut min_x, mut max_x, mut min_y, mut max_y) =
            (f32::INFINITY, f32::NEG_INFINITY, f32::INFINITY, f32::NEG_INFINITY);
        for p in pos.chunks_exact(2) {
            min_x = min_x.min(p[0]);
            max_x = max_x.max(p[0]);
            min_y = min_y.min(p[1]);
            max_y = max_y.max(p[1]);
        }
        let sx = (g.w - 1) as f32 / (max_x - min_x).max(1e-9);
        let sy = (g.h - 1) as f32 / (max_y - min_y).max(1e-9);

        // Cost: squared distance from scaled point to cell center.
        let mut cost = vec![0.0f64; n * n];
        for item in 0..n {
            let px = (pos[item * 2] - min_x) * sx;
            let py = (pos[item * 2 + 1] - min_y) * sy;
            for cell in 0..n {
                let (r, c) = g.coords(cell);
                let dx = px - c as f32;
                let dy = py - r as f32;
                cost[item * n + cell] = (dx * dx + dy * dy) as f64;
            }
        }
        let item_to_cell = jv::solve(&cost, n);
        let mut assign = vec![0u32; n];
        for (item, &cell) in item_to_cell.iter().enumerate() {
            assign[cell as usize] = item as u32;
        }
        Permutation::from_vec(assign).expect("JV yields a bijection")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::random_colors;
    use crate::metrics::mean_neighbor_distance;

    #[test]
    fn pca_lap_improves_over_random() {
        let g = GridShape::new(8, 8);
        let ds = random_colors(64, 35);
        let p = DrLap { use_tsne: false }.sort(&ds.rows, 3, g, 11);
        let arranged = p.apply_rows(&ds.rows, 3);
        assert!(
            mean_neighbor_distance(&arranged, 3, g)
                < mean_neighbor_distance(&ds.rows, 3, g)
        );
    }
}
