//! Synthetic 3D-Gaussian-Splatting scenes.
//!
//! A real 3DGS reconstruction is an unordered point set whose attributes
//! (position, scale, rotation, opacity, color) are *spatially correlated* —
//! nearby Gaussians look alike because they sample the same surface. That
//! correlation is the entire substrate SOG needs, so the generator builds
//! scenes from procedural primitives that reproduce it:
//!
//! * `planes` — textured wall/floor patches (smooth color fields, thin
//!   anisotropic splats aligned to the surface),
//! * `blobs`  — volumetric clutter clusters (rounder, noisier splats),
//!
//! then *shuffles* all splats: like a real exported .ply, the stored order
//! carries no spatial structure — recovering it is the sorter's job.
//!
//! Attribute layout per splat (d = 14):
//!   pos.xyz (3) | log-scale.xyz (3) | rot quaternion (4) | opacity (1) | rgb (3)

use crate::util::rng::Pcg32;

pub const ATTR_DIM: usize = 14;

#[derive(Clone, Debug)]
pub struct SceneConfig {
    pub n_splats: usize,
    pub n_planes: usize,
    pub n_blobs: usize,
    /// Color-field smoothness on surfaces (higher = smoother).
    pub texture_scale: f32,
    pub seed: u64,
}

impl Default for SceneConfig {
    fn default() -> Self {
        SceneConfig { n_splats: 4096, n_planes: 4, n_blobs: 6, texture_scale: 2.0, seed: 7 }
    }
}

/// A generated scene: `attrs` is row-major `[n, ATTR_DIM]`, already
/// randomly shuffled (order-free, as exported 3DGS data is).
#[derive(Clone, Debug)]
pub struct GaussianScene {
    pub n: usize,
    pub attrs: Vec<f32>,
}

impl GaussianScene {
    pub fn generate(cfg: &SceneConfig) -> GaussianScene {
        let mut rng = Pcg32::new(cfg.seed);
        let n = cfg.n_splats;
        let mut attrs = Vec::with_capacity(n * ATTR_DIM);

        // Primitive definitions.
        struct Plane {
            origin: [f32; 3],
            u: [f32; 3],
            v: [f32; 3],
            base_color: [f32; 3],
        }
        let mut planes = Vec::new();
        for _ in 0..cfg.n_planes {
            planes.push(Plane {
                origin: [rng.f32() * 4.0 - 2.0, rng.f32() * 4.0 - 2.0, rng.f32() * 4.0 - 2.0],
                u: rand_unit(&mut rng),
                v: rand_unit(&mut rng),
                base_color: [rng.f32(), rng.f32(), rng.f32()],
            });
        }
        struct Blob {
            center: [f32; 3],
            radius: f32,
            color: [f32; 3],
        }
        let mut blobs = Vec::new();
        for _ in 0..cfg.n_blobs {
            blobs.push(Blob {
                center: [rng.f32() * 4.0 - 2.0, rng.f32() * 4.0 - 2.0, rng.f32() * 4.0 - 2.0],
                radius: 0.2 + rng.f32() * 0.5,
                color: [rng.f32(), rng.f32(), rng.f32()],
            });
        }

        let n_surface = n * 7 / 10; // 70% surface splats, 30% clutter
        for i in 0..n {
            if i < n_surface && !planes.is_empty() {
                let p = &planes[i % planes.len()];
                let (su, sv) = (rng.f32() * 2.0 - 1.0, rng.f32() * 2.0 - 1.0);
                let pos = [
                    p.origin[0] + su * p.u[0] + sv * p.v[0] + rng.gaussian() * 0.01,
                    p.origin[1] + su * p.u[1] + sv * p.v[1] + rng.gaussian() * 0.01,
                    p.origin[2] + su * p.u[2] + sv * p.v[2] + rng.gaussian() * 0.01,
                ];
                // Smooth procedural texture over (su, sv).
                let t = cfg.texture_scale;
                let tex = 0.5 + 0.5 * (su * t).sin() * (sv * t).cos();
                let color = [
                    (p.base_color[0] * tex + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                    (p.base_color[1] * tex + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                    (p.base_color[2] * (1.0 - 0.3 * tex) + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                ];
                // Thin splats aligned with the plane: small normal-axis
                // scale; scale varies smoothly with surface position (real
                // reconstructions size splats by local texture frequency).
                let s_mod = 0.3 * (su * 1.3).cos();
                let ls = [
                    -3.0 + s_mod + rng.gaussian() * 0.05,
                    -3.0 + s_mod + rng.gaussian() * 0.05,
                    -5.5 + rng.gaussian() * 0.05,
                ];
                let rot = quat_from_uv(&p.u, &p.v, &mut rng);
                let opacity = 0.85 + 0.1 * rng.f32();
                push_splat(&mut attrs, pos, ls, rot, opacity, color);
            } else {
                let b = &blobs[i % blobs.len().max(1)];
                let dir = rand_unit(&mut rng);
                let r = b.radius * rng.f32().powf(0.333);
                let pos = [
                    b.center[0] + dir[0] * r,
                    b.center[1] + dir[1] * r,
                    b.center[2] + dir[2] * r,
                ];
                // Shade varies smoothly with radius (denser core = darker).
                let shade = 0.65 + 0.35 * (1.0 - r / b.radius.max(1e-6));
                let color = [
                    (b.color[0] * shade + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                    (b.color[1] * shade + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                    (b.color[2] * shade + 0.01 * rng.gaussian()).clamp(0.0, 1.0),
                ];
                let ls = [
                    -4.0 + rng.gaussian() * 0.15,
                    -4.0 + rng.gaussian() * 0.15,
                    -4.0 + rng.gaussian() * 0.15,
                ];
                let rot = rand_quat(&mut rng);
                let opacity = 0.35 + 0.45 * (1.0 - r / b.radius.max(1e-6)) + 0.05 * rng.f32();
                push_splat(&mut attrs, pos, ls, rot, opacity, color);
            }
        }

        // Destroy the storage order (real exports are unordered).
        let perm = rng.permutation(n);
        let mut shuffled = vec![0.0f32; attrs.len()];
        for (dst, &src) in perm.iter().enumerate() {
            let s = src as usize * ATTR_DIM;
            shuffled[dst * ATTR_DIM..(dst + 1) * ATTR_DIM]
                .copy_from_slice(&attrs[s..s + ATTR_DIM]);
        }
        GaussianScene { n, attrs: shuffled }
    }

    /// Channel-normalized copy in [0,1] per attribute — what the sorter and
    /// the codec consume (the codec stores per-channel min/max to undo it).
    pub fn normalized(&self) -> (Vec<f32>, Vec<(f32, f32)>) {
        let n = self.n;
        let mut ranges = Vec::with_capacity(ATTR_DIM);
        let mut out = self.attrs.clone();
        for ch in 0..ATTR_DIM {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for i in 0..n {
                let v = self.attrs[i * ATTR_DIM + ch];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = (hi - lo).max(1e-9);
            for i in 0..n {
                out[i * ATTR_DIM + ch] = (self.attrs[i * ATTR_DIM + ch] - lo) / span;
            }
            ranges.push((lo, hi));
        }
        (out, ranges)
    }
}

fn push_splat(
    attrs: &mut Vec<f32>,
    pos: [f32; 3],
    log_scale: [f32; 3],
    rot: [f32; 4],
    opacity: f32,
    color: [f32; 3],
) {
    attrs.extend_from_slice(&pos);
    attrs.extend_from_slice(&log_scale);
    attrs.extend_from_slice(&rot);
    attrs.push(opacity);
    attrs.extend_from_slice(&color);
}

fn rand_unit(rng: &mut Pcg32) -> [f32; 3] {
    loop {
        let v = [rng.gaussian(), rng.gaussian(), rng.gaussian()];
        let norm = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
        if norm > 1e-6 {
            return [v[0] / norm, v[1] / norm, v[2] / norm];
        }
    }
}

fn rand_quat(rng: &mut Pcg32) -> [f32; 4] {
    loop {
        let q = [rng.gaussian(), rng.gaussian(), rng.gaussian(), rng.gaussian()];
        let norm = q.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 1e-6 {
            let mut q = [q[0] / norm, q[1] / norm, q[2] / norm, q[3] / norm];
            if q[0] < 0.0 {
                q.iter_mut().for_each(|x| *x = -*x); // canonical hemisphere
            }
            return q;
        }
    }
}

/// Quaternion roughly aligning a splat with the (u,v) plane, jittered.
fn quat_from_uv(u: &[f32; 3], v: &[f32; 3], rng: &mut Pcg32) -> [f32; 4] {
    // Normal = u × v; encode as an axis-angle-ish quat with jitter. The
    // codec only needs *correlated* rotations, not exact geometry.
    let n = [
        u[1] * v[2] - u[2] * v[1],
        u[2] * v[0] - u[0] * v[2],
        u[0] * v[1] - u[1] * v[0],
    ];
    let norm = (n[0] * n[0] + n[1] * n[1] + n[2] * n[2]).sqrt().max(1e-6);
    let angle = 0.3 * rng.gaussian();
    let (s, c) = (angle * 0.5).sin_cos();
    let mut q = [c, s * n[0] / norm, s * n[1] / norm, s * n[2] / norm];
    if q[0] < 0.0 {
        q.iter_mut().for_each(|x| *x = -*x);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_and_dim() {
        let s = GaussianScene::generate(&SceneConfig { n_splats: 256, ..Default::default() });
        assert_eq!(s.n, 256);
        assert_eq!(s.attrs.len(), 256 * ATTR_DIM);
        assert!(s.attrs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quaternions_are_unit_and_canonical() {
        let s = GaussianScene::generate(&SceneConfig { n_splats: 128, ..Default::default() });
        for i in 0..s.n {
            let q = &s.attrs[i * ATTR_DIM + 6..i * ATTR_DIM + 10];
            let norm: f32 = q.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3, "splat {i}: |q|={norm}");
            assert!(q[0] >= -1e-6);
        }
    }

    #[test]
    fn normalized_is_unit_range() {
        let s = GaussianScene::generate(&SceneConfig { n_splats: 200, ..Default::default() });
        let (norm, ranges) = s.normalized();
        assert_eq!(ranges.len(), ATTR_DIM);
        assert!(norm.iter().all(|&v| (-1e-6..=1.0 + 1e-6).contains(&v)));
        // Undo: x = lo + v*(hi-lo) must reproduce the input.
        for i in [0usize, 57, 199] {
            for ch in 0..ATTR_DIM {
                let (lo, hi) = ranges[ch];
                let rec = lo + norm[i * ATTR_DIM + ch] * (hi - lo).max(1e-9);
                assert!((rec - s.attrs[i * ATTR_DIM + ch]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = GaussianScene::generate(&SceneConfig { n_splats: 64, ..Default::default() });
        let b = GaussianScene::generate(&SceneConfig { n_splats: 64, ..Default::default() });
        assert_eq!(a.attrs, b.attrs);
    }
}
