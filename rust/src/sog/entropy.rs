//! Adaptive binary range coder (LZMA-style) with an order-0 bit-tree byte
//! model — the codec's default entropy stage.
//!
//! Why not just zstd: SOG attribute planes can be small (a 16×16 grid is
//! 256 residual bytes) and zstd/deflate pay fixed header + dictionary
//! warm-up costs that swamp such inputs. An adaptive coder has *no* header
//! and converges within a few dozen symbols, compressing skewed residual
//! histograms (what prediction produces on sorted grids) close to their
//! order-0 entropy at any size.
//!
//! Encoder/decoder are the classic carry-propagating range coder used by
//! LZMA; the byte model is a 255-node probability tree (one adaptive
//! binary probability per internal node, MSB-first).

const PROB_BITS: u32 = 11;
const PROB_INIT: u16 = 1 << (PROB_BITS - 1);
const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RangeEncoder {
    fn new() -> Self {
        RangeEncoder { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    #[inline]
    fn shift_low(&mut self) {
        if self.low < 0xFF00_0000 || self.low > 0xFFFF_FFFF {
            let carry = (self.low >> 32) as u8;
            if self.cache_size > 0 {
                self.out.push(self.cache.wrapping_add(carry));
                for _ in 1..self.cache_size {
                    self.out.push(0xFFu8.wrapping_add(carry));
                }
            }
            self.cache = (self.low >> 24) as u8;
            self.cache_size = 0;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    #[inline]
    fn encode_bit(&mut self, prob: &mut u16, bit: u32) {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        if bit == 0 {
            self.range = bound;
            *prob += ((1 << PROB_BITS) - *prob) >> MOVE_BITS;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            *prob -= *prob >> MOVE_BITS;
        }
        while self.range < TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RangeDecoder<'a> {
    code: u32,
    range: u32,
    input: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    fn new(input: &'a [u8]) -> Self {
        let mut d = RangeDecoder { code: 0, range: u32::MAX, input, pos: 1 };
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = self.input.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    #[inline]
    fn decode_bit(&mut self, prob: &mut u16) -> u32 {
        let bound = (self.range >> PROB_BITS) * (*prob as u32);
        let bit;
        if self.code < bound {
            self.range = bound;
            *prob += ((1 << PROB_BITS) - *prob) >> MOVE_BITS;
            bit = 0;
        } else {
            self.code -= bound;
            self.range -= bound;
            *prob -= *prob >> MOVE_BITS;
            bit = 1;
        }
        while self.range < TOP {
            self.code = (self.code << 8) | self.next_byte() as u32;
            self.range <<= 8;
        }
        bit
    }
}

/// Compress `data` with the order-0 adaptive byte model.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut probs = vec![PROB_INIT; 256];
    let mut enc = RangeEncoder::new();
    for &byte in data {
        let mut ctx = 1usize;
        for i in (0..8).rev() {
            let bit = ((byte >> i) & 1) as u32;
            enc.encode_bit(&mut probs[ctx], bit);
            ctx = (ctx << 1) | bit as usize;
        }
    }
    enc.finish()
}

/// Decompress exactly `len` bytes.
pub fn decompress(data: &[u8], len: usize) -> Vec<u8> {
    let mut probs = vec![PROB_INIT; 256];
    let mut dec = RangeDecoder::new(data);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let mut ctx = 1usize;
        for _ in 0..8 {
            let bit = dec.decode_bit(&mut probs[ctx]);
            ctx = (ctx << 1) | bit as usize;
        }
        out.push((ctx & 0xFF) as u8);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn round_trip_property() {
        let mut rng = Pcg32::new(81);
        for len in [0usize, 1, 7, 255, 256, 1000, 5000] {
            let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let enc = compress(&data);
            assert_eq!(decompress(&enc, len), data, "len={len}");
        }
    }

    #[test]
    fn round_trip_skewed() {
        let mut rng = Pcg32::new(82);
        // Geometric-ish residual distribution around 0.
        let data: Vec<u8> = (0..4000)
            .map(|_| {
                let mut v = 0u8;
                while rng.f32() < 0.55 && v < 40 {
                    v += 1;
                }
                v
            })
            .collect();
        let enc = compress(&data);
        assert_eq!(decompress(&enc, data.len()), data);
        // Skewed input must actually compress.
        assert!(enc.len() < data.len() / 2, "{} vs {}", enc.len(), data.len());
    }

    #[test]
    fn constant_input_compresses_hard() {
        let data = vec![7u8; 2048];
        let enc = compress(&data);
        assert!(enc.len() < 80, "constant 2048 bytes -> {}", enc.len());
        assert_eq!(decompress(&enc, 2048), data);
    }

    #[test]
    fn uniform_random_does_not_explode() {
        let mut rng = Pcg32::new(83);
        let data: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
        let enc = compress(&data);
        // Incompressible: at most ~2% expansion + the 5-byte flush.
        assert!(enc.len() <= data.len() + data.len() / 50 + 8);
        assert_eq!(decompress(&enc, data.len()), data);
    }

    #[test]
    fn small_inputs_have_no_header_penalty() {
        // 40 identical bytes: the adaptation transient costs ~2 bits/byte
        // early on but there is no container/header floor — must beat raw
        // and stay well under 40 bytes (zstd's framing alone is ~13).
        let data = vec![3u8; 40];
        let enc = compress(&data);
        assert!(enc.len() <= 30, "tiny constant input -> {} bytes", enc.len());
        // and a longer constant run amortizes far below 1 bit/byte:
        let enc2 = compress(&vec![3u8; 400]);
        assert!(enc2.len() <= 40, "400 constant bytes -> {}", enc2.len());
    }
}
