//! End-to-end SOG pipeline (Fig. 6): scene → normalize → grid sort →
//! per-channel compression → ratio + PSNR + spatial-correlation report.

use anyhow::Result;

use crate::api::Sorter;
use crate::data::Dataset;
use crate::grid::GridShape;
use crate::metrics::corr::mean_lag1_autocorr;
use crate::perm::Permutation;
use crate::sog::codec::{self, CodecConfig};
use crate::sog::scene::{GaussianScene, ATTR_DIM};
use crate::util::rng::Pcg32;

/// Which sorter arranges the splats on the grid.
pub enum SorterKind<'a> {
    /// Any unified-API sorter — learned or heuristic — built via
    /// `api::MethodRegistry` / `api::Engine`.
    Sorter(&'a dyn Sorter),
    /// No sorting — the shuffled baseline.
    Shuffled,
}

/// Result of one pipeline run.
#[derive(Debug)]
pub struct PipelineResult {
    pub label: String,
    pub n: usize,
    pub grid: GridShape,
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    pub ratio: f64,
    pub mean_psnr_db: f64,
    pub spatial_corr: f64,
    pub sort_secs: f64,
    /// Optional per-channel (bytes, psnr).
    pub per_channel: Vec<(usize, f64)>,
}

impl PipelineResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<12} N={} grid={}x{} raw={}B comp={}B ratio={:.2}x psnr={:.1}dB corr={:.3} sort={:.1}s",
            self.label,
            self.n,
            self.grid.h,
            self.grid.w,
            self.raw_bytes,
            self.compressed_bytes,
            self.ratio,
            self.mean_psnr_db,
            self.spatial_corr,
            self.sort_secs
        )
    }
}

/// Run the pipeline on `scene` with the chosen sorter and codec settings.
pub fn run_pipeline(
    scene: &GaussianScene,
    grid: GridShape,
    sorter: SorterKind<'_>,
    codec_cfg: &CodecConfig,
) -> Result<PipelineResult> {
    anyhow::ensure!(scene.n == grid.n(), "scene N={} != grid {}", scene.n, grid.n());
    let (normalized, ranges) = scene.normalized();

    let (label, arranged, sort_secs) = match sorter {
        SorterKind::Shuffled => ("shuffled".to_string(), normalized.clone(), 0.0),
        SorterKind::Sorter(s) => {
            let ds = Dataset {
                name: "sog".into(),
                n: scene.n,
                d: ATTR_DIM,
                rows: normalized.clone(),
                labels: None,
            };
            let out = s.sort(&ds, grid)?;
            // "sort s" means the sorting work itself. Heuristic adapters
            // time it as the "sort" section (their wall time also covers
            // arrange + DPQ); learned drivers fold DPQ into their wall
            // time in all paths, so report it unchanged.
            let sort = out.report.sections.total("sort");
            let secs = if sort > std::time::Duration::ZERO {
                sort.as_secs_f64()
            } else {
                out.report.wall_secs
            };
            (s.name().to_string(), out.arranged, secs)
        }
    };

    let spatial_corr = mean_lag1_autocorr(&arranged, ATTR_DIM, grid);

    // Compress each attribute channel as its own plane (SOG stores one map
    // per attribute).
    let mut plane = vec![0.0f32; grid.n()];
    let mut compressed = 0usize;
    let mut psnr_acc = 0.0f64;
    let mut per_channel = Vec::with_capacity(ATTR_DIM);
    for ch in 0..ATTR_DIM {
        for i in 0..grid.n() {
            plane[i] = arranged[i * ATTR_DIM + ch];
        }
        let (lo, hi) = ranges[ch];
        let enc = codec::encode_plane(&plane, grid, lo, hi, codec_cfg)?;
        let dec = codec::decode_plane(&enc)?;
        let p = codec::psnr(&plane, &dec);
        compressed += enc.compressed_bytes();
        psnr_acc += p;
        per_channel.push((enc.compressed_bytes(), p));
    }

    let raw_bytes = scene.n * ATTR_DIM * 4; // f32 storage
    Ok(PipelineResult {
        label,
        n: scene.n,
        grid,
        raw_bytes,
        compressed_bytes: compressed,
        ratio: raw_bytes as f64 / compressed as f64,
        mean_psnr_db: psnr_acc / ATTR_DIM as f64,
        spatial_corr,
        sort_secs,
        per_channel,
    })
}

/// Convenience: a fresh random permutation baseline (distinct from the
/// scene's intrinsic shuffle) for variance checks.
pub fn random_baseline(
    scene: &GaussianScene,
    grid: GridShape,
    codec_cfg: &CodecConfig,
    seed: u64,
) -> Result<PipelineResult> {
    let mut rng = Pcg32::new(seed);
    let perm = Permutation::from_vec(rng.permutation(scene.n)).unwrap();
    let (normalized, ranges) = scene.normalized();
    let arranged = perm.apply_rows(&normalized, ATTR_DIM);
    let spatial_corr = mean_lag1_autocorr(&arranged, ATTR_DIM, grid);
    let mut plane = vec![0.0f32; grid.n()];
    let mut compressed = 0usize;
    let mut psnr_acc = 0.0f64;
    for ch in 0..ATTR_DIM {
        for i in 0..grid.n() {
            plane[i] = arranged[i * ATTR_DIM + ch];
        }
        let (lo, hi) = ranges[ch];
        let enc = codec::encode_plane(&plane, grid, lo, hi, codec_cfg)?;
        let dec = codec::decode_plane(&enc)?;
        psnr_acc += codec::psnr(&plane, &dec);
        compressed += enc.compressed_bytes();
    }
    let raw_bytes = scene.n * ATTR_DIM * 4;
    Ok(PipelineResult {
        label: "random".into(),
        n: scene.n,
        grid,
        raw_bytes,
        compressed_bytes: compressed,
        ratio: raw_bytes as f64 / compressed as f64,
        mean_psnr_db: psnr_acc / ATTR_DIM as f64,
        spatial_corr,
        sort_secs: 0.0,
        per_channel: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::MethodRegistry;
    use crate::sog::scene::SceneConfig;

    #[test]
    fn heuristic_sort_beats_shuffled_compression() {
        let scene = GaussianScene::generate(&SceneConfig {
            n_splats: 256,
            seed: 5,
            ..Default::default()
        });
        let g = GridShape::new(16, 16);
        let cfg = CodecConfig::default();
        let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &cfg).unwrap();
        let flas = MethodRegistry::new()
            .build("flas", None, &crate::api::overrides(&[("seed", "11")]))
            .unwrap();
        let sorted = run_pipeline(&scene, g, SorterKind::Sorter(flas.as_ref()), &cfg).unwrap();
        assert!(
            sorted.compressed_bytes < shuffled.compressed_bytes,
            "sorted {} vs shuffled {}",
            sorted.compressed_bytes,
            shuffled.compressed_bytes
        );
        assert!(sorted.spatial_corr > shuffled.spatial_corr + 0.1);
        // PSNR is quantization-limited, identical data → comparable PSNR.
        assert!((sorted.mean_psnr_db - shuffled.mean_psnr_db).abs() < 3.0);
    }
}
