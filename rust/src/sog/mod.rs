//! Self-Organizing Gaussians (paper §IV-B): sort the attributes of a 3D
//! Gaussian Splatting scene into 2-D grids to raise spatial correlation,
//! then compress the attribute planes with a standard image-style codec.
//!
//! * `scene` — synthetic 3DGS scene generator (DESIGN.md §3 substitution
//!   for real captured scenes: surfaces + clutter with correlated
//!   attributes, preserving the order-invariance SOG exploits).
//! * `codec` — attribute-plane codec: per-plane quantization → 2-D
//!   prediction (PNG-style filters incl. Paeth) → entropy stage
//!   (zstd / deflate), plus exact reconstruction for PSNR.
//! * `pipeline` — end-to-end: scene → grid sort (learned or heuristic) →
//!   compress → ratio + PSNR, the Fig. 6 experiment.

pub mod codec;
pub mod entropy;
pub mod pipeline;
pub mod scene;

pub use pipeline::{run_pipeline, PipelineResult, SorterKind};
pub use scene::{GaussianScene, SceneConfig};
