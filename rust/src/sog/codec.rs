//! Attribute-plane codec — the compression stage of SOG (§IV-B).
//!
//! Pipeline per scalar plane (one attribute channel arranged on the H×W
//! grid):
//!
//!   1. uniform quantization to `bits` (≤16),
//!   2. PNG-style per-row predictive filtering — each row picks the best of
//!      {None, Left, Up, Average, Paeth} by minimum sum of absolute
//!      residuals (the PNG heuristic); residuals are zigzag-mapped so small
//!      magnitudes become small byte values,
//!   3. entropy coding of the residual stream: adaptive binary range coder
//!      (`entropy.rs`, default — header-free, effective on small planes),
//!      or zstd / deflate.
//!
//! This is the same rate–distortion mechanic as the PNG/WebP-class codecs
//! the SOG paper uses; what the experiment measures — sorted grids compress
//! several times better than shuffled ones because prediction residuals
//! shrink — carries over directly. Decoding is exact (lossless given the
//! quantized values), so PSNR is quantization-only.

use anyhow::{bail, Result};

use crate::grid::GridShape;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Entropy {
    /// Adaptive binary range coder (entropy.rs) — default.
    Arith,
    Zstd,
    Deflate,
}

#[derive(Clone, Debug)]
pub struct CodecConfig {
    pub bits: u8,
    pub entropy: Entropy,
    /// zstd level (1–19) / deflate level (0–9 mapped). Unused by Arith.
    pub level: i32,
}

impl Default for CodecConfig {
    fn default() -> Self {
        CodecConfig { bits: 8, entropy: Entropy::Arith, level: 9 }
    }
}

/// One compressed plane.
pub struct EncodedPlane {
    pub payload: Vec<u8>,
    pub bits: u8,
    pub entropy: Entropy,
    pub h: usize,
    pub w: usize,
    /// Channel range for dequantization.
    pub lo: f32,
    pub hi: f32,
}

impl EncodedPlane {
    pub fn compressed_bytes(&self) -> usize {
        self.payload.len() + 16 // payload + tiny header (ranges/dims)
    }
}

const FILTERS: usize = 5; // none, left, up, avg, paeth

fn paeth(a: i32, b: i32, c: i32) -> i32 {
    let p = a + b - c;
    let (pa, pb, pc) = ((p - a).abs(), (p - b).abs(), (p - c).abs());
    if pa <= pb && pa <= pc {
        a
    } else if pb <= pc {
        b
    } else {
        c
    }
}

/// Quantize a [0,1]-normalized plane to integer levels.
fn quantize(plane: &[f32], bits: u8) -> Vec<u16> {
    let max = ((1u32 << bits) - 1) as f32;
    plane.iter().map(|&v| (v.clamp(0.0, 1.0) * max).round() as u16).collect()
}

fn dequantize(q: &[u16], bits: u8) -> Vec<f32> {
    let max = ((1u32 << bits) - 1) as f32;
    q.iter().map(|&v| v as f32 / max).collect()
}

/// Zigzag map of a signed ring residual: small magnitudes → small codes.
#[inline]
fn zigzag(s: i32) -> u16 {
    ((s << 1) ^ (s >> 31)) as u16
}

#[inline]
fn unzigzag(z: u16) -> i32 {
    ((z >> 1) as i32) ^ -((z & 1) as i32)
}

/// Signed interpretation of `(x - p) mod 2^bits` in `[-2^(b-1), 2^(b-1))`.
#[inline]
fn ring_signed(x: i32, p: i32, bits: u8) -> i32 {
    let modulus = 1i32 << bits;
    let half = modulus >> 1;
    let mut r = (x - p) % modulus;
    if r >= half {
        r -= modulus;
    }
    if r < -half {
        r += modulus;
    }
    r
}

/// Per-row best-filter prediction; returns filter ids + zigzagged residual
/// stream (little-endian; one byte per value for bits ≤ 8, two otherwise).
fn filter_rows(q: &[u16], g: GridShape, bits: u8) -> (Vec<u8>, Vec<u8>) {
    let bytes_per = if bits <= 8 { 1 } else { 2 };
    let mut filter_ids = Vec::with_capacity(g.h);
    let mut out = Vec::with_capacity(g.n() * bytes_per);
    let mut row_res: Vec<Vec<u16>> = vec![Vec::with_capacity(g.w); FILTERS];

    for r in 0..g.h {
        for v in row_res.iter_mut() {
            v.clear();
        }
        for c in 0..g.w {
            let x = q[g.index(r, c)] as i32;
            let left = if c > 0 { q[g.index(r, c - 1)] as i32 } else { 0 };
            let up = if r > 0 { q[g.index(r - 1, c)] as i32 } else { 0 };
            let ul = if r > 0 && c > 0 { q[g.index(r - 1, c - 1)] as i32 } else { 0 };
            let preds = [0, left, up, (left + up) / 2, paeth(left, up, ul)];
            for (f, &p) in preds.iter().enumerate() {
                row_res[f].push(zigzag(ring_signed(x, p, bits)));
            }
        }
        // PNG heuristic: minimize the summed zigzag codes (∝ |residual|).
        let score = |res: &[u16]| -> u64 { res.iter().map(|&v| v as u64).sum() };
        let best = (0..FILTERS).min_by_key(|&f| score(&row_res[f])).unwrap();
        filter_ids.push(best as u8);
        for &v in &row_res[best] {
            out.push(v as u8);
            if bytes_per == 2 {
                out.push((v >> 8) as u8);
            }
        }
    }
    (filter_ids, out)
}

fn unfilter_rows(filter_ids: &[u8], data: &[u8], g: GridShape, bits: u8) -> Vec<u16> {
    let bytes_per = if bits <= 8 { 1 } else { 2 };
    let mask = ((1u32 << bits) - 1) as u16;
    let mut q = vec![0u16; g.n()];
    for r in 0..g.h {
        let f = filter_ids[r];
        for c in 0..g.w {
            let pos = (r * g.w + c) * bytes_per;
            let mut z = data[pos] as u16;
            if bytes_per == 2 {
                z |= (data[pos + 1] as u16) << 8;
            }
            let s = unzigzag(z);
            let left = if c > 0 { q[g.index(r, c - 1)] as i32 } else { 0 };
            let up = if r > 0 { q[g.index(r - 1, c)] as i32 } else { 0 };
            let ul = if r > 0 && c > 0 { q[g.index(r - 1, c - 1)] as i32 } else { 0 };
            let pred = match f {
                0 => 0,
                1 => left,
                2 => up,
                3 => (left + up) / 2,
                4 => paeth(left, up, ul),
                _ => unreachable!(),
            };
            q[g.index(r, c)] = ((pred + s).rem_euclid(1 << bits) as u16) & mask;
        }
    }
    q
}

fn entropy_encode(data: &[u8], cfg: &CodecConfig) -> Result<Vec<u8>> {
    Ok(match cfg.entropy {
        Entropy::Arith => super::entropy::compress(data),
        Entropy::Zstd => zstd::bulk::compress(data, cfg.level)?,
        Entropy::Deflate => {
            use flate2::write::ZlibEncoder;
            use flate2::Compression;
            use std::io::Write;
            let mut enc =
                ZlibEncoder::new(Vec::new(), Compression::new(cfg.level.clamp(0, 9) as u32));
            enc.write_all(data)?;
            enc.finish()?
        }
    })
}

fn entropy_decode(data: &[u8], entropy: Entropy, expect: usize) -> Result<Vec<u8>> {
    Ok(match entropy {
        Entropy::Arith => super::entropy::decompress(data, expect),
        Entropy::Zstd => zstd::bulk::decompress(data, expect + 64)?,
        Entropy::Deflate => {
            use flate2::read::ZlibDecoder;
            use std::io::Read;
            let mut out = Vec::with_capacity(expect);
            ZlibDecoder::new(data).read_to_end(&mut out)?;
            out
        }
    })
}

/// Encode one [0,1] plane arranged on the grid.
pub fn encode_plane(
    plane: &[f32],
    g: GridShape,
    lo: f32,
    hi: f32,
    cfg: &CodecConfig,
) -> Result<EncodedPlane> {
    if plane.len() != g.n() {
        bail!("plane size {} != grid {}", plane.len(), g.n());
    }
    if cfg.bits == 0 || cfg.bits > 16 {
        bail!("bits must be 1..=16");
    }
    let q = quantize(plane, cfg.bits);
    let (filter_ids, residuals) = filter_rows(&q, g, cfg.bits);
    let mut stream = Vec::with_capacity(filter_ids.len() + residuals.len());
    stream.extend_from_slice(&filter_ids);
    stream.extend_from_slice(&residuals);
    let payload = entropy_encode(&stream, cfg)?;
    Ok(EncodedPlane { payload, bits: cfg.bits, entropy: cfg.entropy, h: g.h, w: g.w, lo, hi })
}

/// Decode back to the [0,1] plane (exact up to quantization).
pub fn decode_plane(enc: &EncodedPlane) -> Result<Vec<f32>> {
    let g = GridShape::new(enc.h, enc.w);
    let bytes_per = if enc.bits <= 8 { 1 } else { 2 };
    let expect = g.h + g.n() * bytes_per;
    let stream = entropy_decode(&enc.payload, enc.entropy, expect)?;
    if stream.len() != expect {
        bail!("corrupt stream: {} != {}", stream.len(), expect);
    }
    let (filter_ids, data) = stream.split_at(g.h);
    Ok(dequantize(&unfilter_rows(filter_ids, data, g, enc.bits), enc.bits))
}

/// PSNR (dB) between original and reconstruction in [0,1].
pub fn psnr(orig: &[f32], rec: &[f32]) -> f64 {
    assert_eq!(orig.len(), rec.len());
    let mse = orig
        .iter()
        .zip(rec)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / orig.len() as f64;
    if mse < 1e-20 {
        return 99.0;
    }
    10.0 * (1.0 / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn roundtrip(plane: &[f32], g: GridShape, cfg: &CodecConfig) -> (Vec<f32>, usize) {
        let enc = encode_plane(plane, g, 0.0, 1.0, cfg).unwrap();
        let dec = decode_plane(&enc).unwrap();
        (dec, enc.compressed_bytes())
    }

    #[test]
    fn lossless_at_quantized_levels() {
        let g = GridShape::new(16, 16);
        let mut rng = Pcg32::new(71);
        for bits in [4u8, 8, 12] {
            let max = ((1u32 << bits) - 1) as f32;
            let plane: Vec<f32> =
                (0..g.n()).map(|_| (rng.below(1 << bits) as f32) / max).collect();
            let (dec, _) = roundtrip(&plane, g, &CodecConfig { bits, ..Default::default() });
            for (a, b) in plane.iter().zip(&dec) {
                assert!((a - b).abs() < 1e-6, "bits={bits}");
            }
        }
    }

    #[test]
    fn deflate_backend_round_trips() {
        let g = GridShape::new(8, 8);
        let plane: Vec<f32> = (0..64).map(|i| i as f32 / 63.0).collect();
        let cfg = CodecConfig { entropy: Entropy::Deflate, level: 6, ..Default::default() };
        let (dec, _) = roundtrip(&plane, g, &cfg);
        let q = quantize(&plane, 8);
        let qd = quantize(&dec, 8);
        assert_eq!(q, qd);
    }

    #[test]
    fn smooth_plane_compresses_much_better_than_noise() {
        let g = GridShape::new(32, 32);
        let smooth: Vec<f32> = (0..g.n())
            .map(|i| {
                let (r, c) = g.coords(i);
                ((r as f32 / 32.0 + c as f32 / 32.0) / 2.0).fract()
            })
            .collect();
        let mut rng = Pcg32::new(72);
        let noise: Vec<f32> = (0..g.n()).map(|_| rng.f32()).collect();
        let cfg = CodecConfig::default();
        let (_, smooth_bytes) = roundtrip(&smooth, g, &cfg);
        let (_, noise_bytes) = roundtrip(&noise, g, &cfg);
        assert!(
            (smooth_bytes as f64) < 0.5 * noise_bytes as f64,
            "smooth {smooth_bytes} vs noise {noise_bytes}"
        );
    }

    #[test]
    fn psnr_bounds() {
        let a = vec![0.5f32; 100];
        assert_eq!(psnr(&a, &a), 99.0);
        let b = vec![0.6f32; 100];
        let p = psnr(&a, &b);
        assert!((p - 20.0).abs() < 0.1, "p={p}"); // mse=0.01 → 20dB
    }

    #[test]
    fn quantization_psnr_scales_with_bits() {
        let g = GridShape::new(16, 16);
        let mut rng = Pcg32::new(73);
        let plane: Vec<f32> = (0..g.n()).map(|_| rng.f32()).collect();
        let mut last = 0.0;
        for bits in [4u8, 6, 8, 10] {
            let (dec, _) = roundtrip(&plane, g, &CodecConfig { bits, ..Default::default() });
            let p = psnr(&plane, &dec);
            assert!(p > last, "bits={bits}: {p} <= {last}");
            last = p;
        }
        assert!(last > 55.0); // 10-bit quantization ≈ 66 dB theoretical
    }

    #[test]
    fn rejects_bad_config_and_sizes() {
        let g = GridShape::new(4, 4);
        let plane = vec![0.0f32; 16];
        assert!(encode_plane(&plane, g, 0.0, 1.0, &CodecConfig { bits: 0, ..Default::default() }).is_err());
        assert!(encode_plane(&plane[..8], g, 0.0, 1.0, &CodecConfig::default()).is_err());
    }
}
