//! `sssort` — the leader binary: CLI over the unified `api` layer
//! (`MethodRegistry` + `Engine`), the coordinator and the SOG pipeline.
//! See `cli::usage()`.

use anyhow::{anyhow, bail, Result};

use shufflesort::api::{BackendChoice, Engine, MethodKind, MethodRegistry, SimdChoice};
use shufflesort::cli::{parse_grid, usage, ParsedArgs};
use shufflesort::config::{normalize_threads, ServeConfig};
use shufflesort::coordinator::SortOutcome;
use shufflesort::data::{self, Dataset};
use shufflesort::grid::GridShape;
use shufflesort::metrics::{dpq16, mean_neighbor_distance};
use shufflesort::serve::{self, EngineSpec};
use shufflesort::serve::json;
use shufflesort::sog::codec::CodecConfig;
use shufflesort::sog::scene::{GaussianScene, SceneConfig};
use shufflesort::sog::{run_pipeline, SorterKind};
use shufflesort::trace;
use shufflesort::util::ppm;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = ParsedArgs::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "sort" => cmd_sort(&args),
        "serve" => cmd_serve(&args),
        "sog" => cmd_sog(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{}", usage()),
    }
}

fn artifacts_dir(args: &ParsedArgs) -> String {
    args.opt("artifacts").unwrap_or("artifacts").to_string()
}

fn engine_for(args: &ParsedArgs) -> Result<Engine> {
    let mut builder = Engine::builder(artifacts_dir(args));
    if let Some(w) = args.opt("workers") {
        let w: usize = w.parse().map_err(|_| anyhow!("--workers must be an integer"))?;
        builder = builder.workers(w);
    }
    if let Some(b) = args.opt("backend") {
        builder = builder.backend(BackendChoice::parse(b)?);
    }
    if let Some(t) = args.opt("threads") {
        let t: usize = t.parse().map_err(|_| anyhow!("--threads must be an integer"))?;
        builder = builder.threads(t);
    }
    if let Some(s) = args.opt("simd") {
        builder = builder.simd(SimdChoice::parse(s)?);
    }
    Ok(builder.build())
}

fn cmd_sort(args: &ParsedArgs) -> Result<()> {
    let (h, w) = parse_grid(args.opt("grid").unwrap_or("16x16"))?;
    let n = h * w;
    let seed: u64 = args.opt("seed").unwrap_or("42").parse()?;
    let batch = args.opt_usize("batch", 1)?;
    let g = GridShape::new(h, w);

    let engine = engine_for(args)?;
    let method = args.opt("method").unwrap_or("sss");
    let spec = engine.registry().resolve_or_err(method)?;

    // `--seed` / `--tile-n` participate as leading overrides so explicit
    // `seed=...` / `tile_n=...` pairs still win (last-wins semantics).
    let mut overrides: Vec<(String, String)> = vec![("seed".into(), seed.to_string())];
    if let Some(t) = args.opt("tile-n") {
        t.parse::<usize>().map_err(|_| anyhow!("--tile-n must be an integer"))?;
        overrides.push(("tile_n".into(), t.to_string()));
    }
    if let Some(p) = args.opt("tile-plan") {
        overrides.push(("tile_plan".into(), p.to_string()));
    }
    if args.flag("pyramid") {
        overrides.push(("pyramid".into(), "true".to_string()));
    }
    overrides.extend(args.overrides.iter().cloned());

    let make_dataset = |seed: u64| -> Result<Dataset> {
        match args.opt("dataset").unwrap_or("colors") {
            "colors" => Ok(data::random_colors(n, seed)),
            "features" => Ok(data::clustered_features(n, 50, 16, 0.06, seed)),
            other => bail!("unknown dataset '{other}'"),
        }
    };

    // `--trace-file PATH` / `--profile-file PATH`: record the run's span
    // tree (phases, tiles, step kernels) once, then write it as Chrome
    // trace-event JSON and/or a collapsed-stack folded profile.
    let trace_file = args.opt("trace-file");
    let profile_file = args.opt("profile-file");
    let tracing = trace_file.is_some() || profile_file.is_some();
    if tracing {
        trace::enable();
    }

    if batch > 1 {
        let datasets: Vec<Dataset> =
            (0..batch).map(|i| make_dataset(seed + i as u64)).collect::<Result<_>>()?;
        println!(
            "batch sort: {} x {n} items on {h}x{w} via '{}' ({} workers)",
            batch,
            spec.name,
            engine.workers().min(batch)
        );
        let root = if tracing { trace::Span::root("sort_batch") } else { trace::Span::off() };
        let results = {
            let _cur = root.make_current();
            engine.sort_batch(spec.name, &datasets, g, &overrides)
        };
        let trace_id = root.ctx().map(|c| c.trace_id);
        root.end();
        let mut failed = 0usize;
        for (i, result) in results.iter().enumerate() {
            match result {
                Ok(out) => {
                    println!("[{i}] {}", out.report.summary());
                    if let Some(dir) = args.opt("out") {
                        write_outputs(dir, spec.name, g, &format!("_b{i}"), out, datasets[i].d)?;
                    }
                }
                Err(e) => {
                    failed += 1;
                    println!("[{i}] error: {e:#}");
                }
            }
        }
        if let Some(id) = trace_id {
            write_trace_outputs(trace_file, profile_file, id)?;
        }
        if failed > 0 {
            bail!("{failed}/{batch} batch items failed");
        }
        return Ok(());
    }

    let dataset = make_dataset(seed)?;
    if spec.kind == MethodKind::Learned {
        println!("backend: {}", engine.backend_desc(&overrides)?);
    }
    let base_nbr = mean_neighbor_distance(&dataset.rows, dataset.d, g);
    let base_dpq = dpq16(&dataset.rows, dataset.d, g);
    println!("unsorted: nbr={base_nbr:.4} dpq16={base_dpq:.3}");

    let mut root = if tracing { trace::Span::root("sort") } else { trace::Span::off() };
    let outcome = {
        let _cur = root.make_current();
        engine.sort(spec.name, &dataset, g, &overrides)?
    };
    outcome.report.trace_attrs(&mut root);
    let trace_id = root.ctx().map(|c| c.trace_id);
    root.end();

    println!("{}", outcome.report.summary());
    println!("sections: {}", outcome.report.sections.report());
    println!(
        "sorted:   nbr={:.4} dpq16={:.3}",
        mean_neighbor_distance(&outcome.arranged, dataset.d, g),
        outcome.report.final_dpq
    );

    if let Some(dir) = args.opt("out") {
        write_outputs(dir, spec.name, g, "", &outcome, dataset.d)?;
    }
    if let Some(id) = trace_id {
        write_trace_outputs(trace_file, profile_file, id)?;
    }
    Ok(())
}

/// Assemble the finished trace once and write every requested artifact
/// from it: Chrome trace-event JSON (`--trace-file`) and/or a
/// collapsed-stack folded profile (`--profile-file`).
fn write_trace_outputs(
    trace_file: Option<&str>,
    profile_file: Option<&str>,
    trace_id: u64,
) -> Result<()> {
    let t = trace::finish(trace_id).ok_or_else(|| {
        anyhow!("trace {} recorded no spans", trace::format_trace_id(trace_id))
    })?;
    if let Some(path) = trace_file {
        std::fs::write(path, json::to_string_pretty(&trace::chrome_trace_json(&t)))?;
        let dropped = if t.dropped > 0 {
            format!(", {} dropped", t.dropped)
        } else {
            String::new()
        };
        println!(
            "wrote {path} ({} spans{dropped}; open in chrome://tracing or Perfetto)",
            t.spans.len()
        );
    }
    if let Some(path) = profile_file {
        let p = trace::profile::Profile::new();
        p.observe(&t);
        std::fs::write(path, p.folded())?;
        println!("wrote {path} ({} stacks; feed to flamegraph.pl or speedscope)", p.len());
    }
    Ok(())
}

/// Write the viewable grid image (3-d data) and, when recorded, the loss
/// curve for one outcome. `suffix` disambiguates batch items.
fn write_outputs(
    dir: &str,
    method: &str,
    g: GridShape,
    suffix: &str,
    outcome: &SortOutcome,
    d: usize,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    if d == 3 {
        let path =
            std::path::Path::new(dir).join(format!("{method}_{}x{}{suffix}.ppm", g.h, g.w));
        ppm::write_ppm_upscaled(&path, &outcome.arranged, g.h, g.w, 12)?;
        println!("wrote {}", path.display());
    }
    if !outcome.report.curve.is_empty() {
        let curve_path = std::path::Path::new(dir)
            .join(format!("{method}_{}x{}{suffix}_curve.csv", g.h, g.w));
        let mut csv = String::from("phase,iter,tau,loss\n");
        for p in &outcome.report.curve {
            csv.push_str(&format!("{},{},{},{}\n", p.phase, p.iter, p.tau, p.loss));
        }
        std::fs::write(&curve_path, csv)?;
        println!("wrote {}", curve_path.display());
    }
    Ok(())
}

/// `sssort serve` — put the engine on a socket (see `shufflesort::serve`).
/// `--addr/--workers/--cache-mb/--shards/--cache-file/--rate-limit/
/// --auth-token` + bare `k=v` pairs configure the HTTP side;
/// `--backend/--threads/--artifacts` configure the engine hosts.
fn cmd_serve(args: &ParsedArgs) -> Result<()> {
    let mut cfg = ServeConfig::default();
    if let Some(addr) = args.opt("addr") {
        cfg.addr = addr.to_string();
    }
    cfg.workers = args.opt_usize("workers", cfg.workers)?;
    cfg.cache_mb = args.opt_usize("cache-mb", cfg.cache_mb)?;
    cfg.shards = args.opt_usize("shards", cfg.shards)?.max(1);
    if let Some(path) = args.opt("cache-file") {
        cfg.cache_file = (!path.is_empty()).then(|| path.to_string());
    }
    cfg.rate_limit = args.opt_usize("rate-limit", cfg.rate_limit as usize)? as u64;
    if let Some(token) = args.opt("auth-token") {
        cfg.auth_token = (!token.is_empty()).then(|| token.to_string());
    }
    cfg.trace_sample = args.opt_usize("trace-sample", cfg.trace_sample as usize)? as u64;
    cfg.trace_keep = args.opt_usize("trace-keep", cfg.trace_keep)?.max(1);
    cfg.trace_tail_ms = args.opt_usize("trace-tail-ms", cfg.trace_tail_ms as usize)? as u64;
    // Dedicated flags first, bare `k=v` pairs after: overrides win.
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    let backend = match args.opt("backend") {
        Some(b) => BackendChoice::parse(b)?,
        None => BackendChoice::default(),
    };
    let threads = match args.opt("threads") {
        Some(t) => normalize_threads(
            t.parse().map_err(|_| anyhow!("--threads must be an integer"))?,
        ),
        None => None,
    };
    let simd = match args.opt("simd") {
        Some(s) => SimdChoice::parse(s)?,
        None => SimdChoice::default(),
    };
    let spec = EngineSpec {
        artifacts_dir: artifacts_dir(args),
        backend,
        threads,
        simd,
        batch_workers: None,
        registry: MethodRegistry::new(),
    };
    serve::run(cfg, spec)
}

fn cmd_sog(args: &ParsedArgs) -> Result<()> {
    let n = args.opt_usize("n", 4096)?;
    let side = (n as f64).sqrt() as usize;
    anyhow::ensure!(side * side == n, "--n must be a perfect square");
    let (h, w) = match args.opt("grid") {
        Some(s) => parse_grid(s)?,
        None => (side, side),
    };
    let bits: u8 = args.opt("bits").unwrap_or("8").parse()?;
    let scene_seed: u64 = args.opt("scene-seed").unwrap_or("7").parse()?;

    let scene = GaussianScene::generate(&SceneConfig {
        n_splats: n,
        seed: scene_seed,
        ..Default::default()
    });
    let g = GridShape::new(h, w);
    let codec = CodecConfig { bits, ..Default::default() };
    let engine = engine_for(args)?;

    println!("SOG pipeline: N={n} grid={h}x{w} bits={bits}");
    let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec)?;
    println!("{}", shuffled.summary());

    let flas = engine.sorter("flas", &shufflesort::api::overrides(&[("seed", "11")]))?;
    let heuristic = run_pipeline(&scene, g, SorterKind::Sorter(flas.as_ref()), &codec)?;
    println!("{}", heuristic.summary());

    let sss = engine.sorter("shuffle-softsort", &args.overrides)?;
    let learned = run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &codec)?;
    println!("{}", learned.summary());

    println!(
        "gain: learned {:.2}x vs shuffled {:.2}x ({}% smaller)",
        learned.ratio,
        shuffled.ratio,
        (100.0 * (1.0 - learned.compressed_bytes as f64 / shuffled.compressed_bytes as f64))
            as i64
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_inspect(args: &ParsedArgs) -> Result<()> {
    let dir = artifacts_dir(args);
    let engine = Engine::builder(&dir).build();
    let rt = engine
        .runtime()
        .map_err(|e| anyhow!("{e:#} (build with `make artifacts`)"))?;
    let m = rt.manifest();
    println!("manifest v{} (jax {}), {} artifacts in {dir}:", m.version, m.jax_version, m.artifacts.len());
    for a in &m.artifacts {
        println!(
            "  {:<34} method={:<8} N={:<5} d={:<3} grid={}x{} params={}",
            a.name, a.method, a.n, a.d, a.h, a.w, a.param_count
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_inspect(_args: &ParsedArgs) -> Result<()> {
    bail!(
        "`inspect` lists AOT artifacts, but this build has no PJRT support \
         (compiled without the 'pjrt' feature); learned methods run on the \
         native backend instead"
    )
}
