//! `sssort` — the leader binary: CLI over the ShuffleSoftSort coordinator,
//! the baselines and the SOG pipeline. See `cli::USAGE`.

use anyhow::{anyhow, bail, Result};

use shufflesort::cli::{parse_grid, ParsedArgs, USAGE};
use shufflesort::config::{BaselineConfig, ShuffleSoftSortConfig};
use shufflesort::coordinator::baselines::{GumbelSinkhornDriver, KissingDriver, SoftSortDriver};
use shufflesort::coordinator::ShuffleSoftSort;
use shufflesort::data;
use shufflesort::grid::GridShape;
use shufflesort::metrics::{dpq16, mean_neighbor_distance};
use shufflesort::runtime::Runtime;
use shufflesort::sog::codec::CodecConfig;
use shufflesort::sog::scene::{GaussianScene, SceneConfig};
use shufflesort::sog::{run_pipeline, SorterKind};
use shufflesort::util::ppm;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = ParsedArgs::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "sort" => cmd_sort(&args),
        "sog" => cmd_sog(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn artifacts_dir(args: &ParsedArgs) -> String {
    args.opt("artifacts").unwrap_or("artifacts").to_string()
}

fn cmd_sort(args: &ParsedArgs) -> Result<()> {
    let (h, w) = parse_grid(args.opt("grid").unwrap_or("16x16"))?;
    let n = h * w;
    let seed: u64 = args.opt("seed").unwrap_or("42").parse()?;
    let method = args.opt("method").unwrap_or("sss");
    let dataset = match args.opt("dataset").unwrap_or("colors") {
        "colors" => data::random_colors(n, seed),
        "features" => data::clustered_features(n, 50, 16, 0.06, seed),
        other => bail!("unknown dataset '{other}'"),
    };

    let rt = Runtime::from_manifest(artifacts_dir(args))?;
    println!("platform: {}", rt.platform());
    let g = GridShape::new(h, w);
    let base_nbr = mean_neighbor_distance(&dataset.rows, dataset.d, g);
    let base_dpq = dpq16(&dataset.rows, dataset.d, g);
    println!("unsorted: nbr={base_nbr:.4} dpq16={base_dpq:.3}");

    let outcome = match method {
        "sss" | "shufflesoftsort" => {
            let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
            cfg.seed = seed;
            for (k, v) in &args.overrides {
                cfg.set(k, v)?;
            }
            ShuffleSoftSort::new(&rt, cfg)?.sort(&dataset)?
        }
        "softsort" => {
            let mut cfg = BaselineConfig::for_grid(h, w);
            cfg.seed = seed;
            for (k, v) in &args.overrides {
                cfg.set(k, v)?;
            }
            SoftSortDriver::new(&rt, cfg).sort(&dataset)?
        }
        "gs" | "gumbel-sinkhorn" => {
            let mut cfg = BaselineConfig::for_gs(h, w);
            cfg.seed = seed;
            for (k, v) in &args.overrides {
                cfg.set(k, v)?;
            }
            GumbelSinkhornDriver::new(&rt, cfg).sort(&dataset)?
        }
        "kiss" | "kissing" => {
            let mut cfg = BaselineConfig::for_grid(h, w);
            cfg.seed = seed;
            for (k, v) in &args.overrides {
                cfg.set(k, v)?;
            }
            KissingDriver::new(&rt, cfg).sort(&dataset)?
        }
        other => bail!("unknown method '{other}'"),
    };

    println!("{}", outcome.report.summary());
    println!("sections: {}", outcome.report.sections.report());
    println!(
        "sorted:   nbr={:.4} dpq16={:.3}",
        mean_neighbor_distance(&outcome.arranged, dataset.d, g),
        outcome.report.final_dpq
    );

    if let Some(dir) = args.opt("out") {
        std::fs::create_dir_all(dir)?;
        if dataset.d == 3 {
            let path = std::path::Path::new(dir).join(format!("{method}_{h}x{w}.ppm"));
            ppm::write_ppm_upscaled(&path, &outcome.arranged, h, w, 12)?;
            println!("wrote {}", path.display());
        }
        let curve_path = std::path::Path::new(dir).join(format!("{method}_{h}x{w}_curve.csv"));
        let mut csv = String::from("phase,iter,tau,loss\n");
        for p in &outcome.report.curve {
            csv.push_str(&format!("{},{},{},{}\n", p.phase, p.iter, p.tau, p.loss));
        }
        std::fs::write(&curve_path, csv)?;
        println!("wrote {}", curve_path.display());
    }
    Ok(())
}

fn cmd_sog(args: &ParsedArgs) -> Result<()> {
    let n = args.opt_usize("n", 4096)?;
    let side = (n as f64).sqrt() as usize;
    anyhow::ensure!(side * side == n, "--n must be a perfect square");
    let (h, w) = match args.opt("grid") {
        Some(s) => parse_grid(s)?,
        None => (side, side),
    };
    let bits: u8 = args.opt("bits").unwrap_or("8").parse()?;
    let scene_seed: u64 = args.opt("scene-seed").unwrap_or("7").parse()?;

    let scene = GaussianScene::generate(&SceneConfig {
        n_splats: n,
        seed: scene_seed,
        ..Default::default()
    });
    let g = GridShape::new(h, w);
    let codec = CodecConfig { bits, ..Default::default() };

    println!("SOG pipeline: N={n} grid={h}x{w} bits={bits}");
    let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec)?;
    println!("{}", shuffled.summary());
    let heuristic = run_pipeline(&scene, g, SorterKind::Heuristic, &codec)?;
    println!("{}", heuristic.summary());

    let rt = Runtime::from_manifest(artifacts_dir(args))?;
    let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    let learned = run_pipeline(&scene, g, SorterKind::Learned(&rt, cfg), &codec)?;
    println!("{}", learned.summary());

    println!(
        "gain: learned {:.2}x vs shuffled {:.2}x ({}% smaller)",
        learned.ratio,
        shuffled.ratio,
        (100.0 * (1.0 - learned.compressed_bytes as f64 / shuffled.compressed_bytes as f64))
            as i64
    );
    Ok(())
}

fn cmd_inspect(args: &ParsedArgs) -> Result<()> {
    let dir = artifacts_dir(args);
    let rt = Runtime::from_manifest(&dir)
        .map_err(|e| anyhow!("{e} (build with `make artifacts`)"))?;
    let m = rt.manifest();
    println!("manifest v{} (jax {}), {} artifacts in {dir}:", m.version, m.jax_version, m.artifacts.len());
    for a in &m.artifacts {
        println!(
            "  {:<34} method={:<8} N={:<5} d={:<3} grid={}x{} params={}",
            a.name, a.method, a.n, a.d, a.h, a.w, a.param_count
        );
    }
    Ok(())
}
