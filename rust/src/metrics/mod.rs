//! Layout-quality metrics: DPQ (the paper's headline quality number),
//! mean-neighbor-distance (the smoothness objective itself) and spatial
//! autocorrelation (the SOG compressibility proxy).

pub mod corr;
pub mod dpq;
pub mod neighbor;

pub use dpq::{dpq, dpq16};
pub use neighbor::mean_neighbor_distance;
