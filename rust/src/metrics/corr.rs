//! Spatial autocorrelation of attribute planes — the SOG compressibility
//! proxy: codecs exploit exactly the lag-1 correlation that grid sorting
//! creates (paper §IV-B).

use crate::grid::GridShape;

/// Lag-1 spatial autocorrelation of a scalar plane (mean of the horizontal
/// and vertical Pearson correlations between adjacent cells). 1.0 = smooth,
/// ~0 = white noise.
pub fn lag1_autocorr(plane: &[f32], g: GridShape) -> f64 {
    assert_eq!(plane.len(), g.n());
    let n = g.n() as f64;
    let mean = plane.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = plane.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    if var < 1e-18 {
        return 1.0;
    }
    let mut cov = 0.0f64;
    let mut cnt = 0usize;
    for r in 0..g.h {
        for c in 0..g.w {
            let i = g.index(r, c);
            if c + 1 < g.w {
                cov += (plane[i] as f64 - mean) * (plane[i + 1] as f64 - mean);
                cnt += 1;
            }
            if r + 1 < g.h {
                cov += (plane[i] as f64 - mean) * (plane[g.index(r + 1, c)] as f64 - mean);
                cnt += 1;
            }
        }
    }
    (cov / cnt as f64) / var
}

/// Mean lag-1 autocorrelation over the `d` channels of `[n, d]` data
/// arranged on the grid.
pub fn mean_lag1_autocorr(data: &[f32], d: usize, g: GridShape) -> f64 {
    let n = g.n();
    assert_eq!(data.len(), n * d);
    let mut plane = vec![0.0f32; n];
    let mut acc = 0.0f64;
    for ch in 0..d {
        for i in 0..n {
            plane[i] = data[i * d + ch];
        }
        acc += lag1_autocorr(&plane, g);
    }
    acc / d as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn smooth_gradient_high_noise_low() {
        let g = GridShape::new(16, 16);
        let grad: Vec<f32> = (0..g.n()).map(|i| (i / 16) as f32 + (i % 16) as f32).collect();
        assert!(lag1_autocorr(&grad, g) > 0.9);
        let mut rng = Pcg32::new(1);
        let noise: Vec<f32> = (0..g.n()).map(|_| rng.f32()).collect();
        assert!(lag1_autocorr(&noise, g).abs() < 0.2);
    }

    #[test]
    fn constant_plane_is_one() {
        let g = GridShape::new(4, 4);
        assert_eq!(lag1_autocorr(&vec![3.0; 16], g), 1.0);
    }

    #[test]
    fn multichannel_averages() {
        let g = GridShape::new(8, 8);
        let mut data = vec![0.0f32; g.n() * 2];
        for i in 0..g.n() {
            data[i * 2] = (i / 8) as f32; // smooth channel
        }
        let mut rng = Pcg32::new(2);
        for i in 0..g.n() {
            data[i * 2 + 1] = rng.f32(); // noise channel
        }
        let m = mean_lag1_autocorr(&data, 2, g);
        assert!(m > 0.3 && m < 0.8, "m={m}");
    }
}
