//! Distance Preservation Quality — DPQ_p, after Barthel et al.,
//! "Improved evaluation and generation of grid layouts using distance
//! preservation quality and linear assignment sorting" (CGF 2023) — the
//! quality metric of the paper's Table 2 (p = 16).
//!
//! Construction (DESIGN.md §7). For each neighborhood size k ∈ 1..K:
//!
//!   D_grid(k) — mean feature distance from each cell to its k spatially
//!               nearest cells (the layout under evaluation),
//!   D_opt(k)  — the same with the k *feature-space* nearest neighbors
//!               (the unattainable-in-general lower bound),
//!   D_rand    — mean feature distance over all pairs (the expectation of a
//!               random layout).
//!
//!   q(k) = clamp((D_rand − D_grid(k)) / (D_rand − D_opt(k)), 0, 1)
//!
//! and DPQ_p aggregates with a 1/k-weighted power mean,
//!
//!   DPQ_p = ( Σ_k w_k q(k)^p / Σ_k w_k )^(1/p),   w_k = 1/k ,
//!
//! emphasizing small (perceptually dominant) neighborhoods, the role the
//! exponent plays in [3]. DPQ ∈ [0, 1]; identical inputs to every method ⇒
//! cross-method ordering (what the paper's table reports) is preserved.

use crate::grid::GridShape;
use crate::util::stats::l2;

/// Default maximum neighborhood size: √N keeps O(N·K) accumulation cheap
/// while covering the perceptually relevant range.
fn default_k_max(n: usize) -> usize {
    ((n as f64).sqrt() as usize).clamp(1, n - 1)
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

/// Anchor cells beyond this count are subsampled with a deterministic
/// stride: the exact metric is O(N²(d + log N)) — fine to N ≈ 4096, not at
/// the sizes the tiled phase executor now reaches (16k–100k). Runs with
/// n ≤ `DPQ_MAX_ANCHORS` are **bit-identical** to the exact computation
/// (stride 1); above it DPQ becomes a strided estimate over ⌈n/stride⌉
/// anchor cells, with every cell still participating as a neighbor.
pub const DPQ_MAX_ANCHORS: usize = 4096;

/// DPQ_16 — the paper's reported variant.
pub fn dpq16(data: &[f32], d: usize, g: GridShape) -> f64 {
    dpq(data, d, g, 16.0, default_k_max(g.n()))
}

/// General DPQ_p with explicit neighborhood cap.
///
/// `data` is row-major `[n, d]`, already arranged on the grid (cell i holds
/// the vector at rows `i*d..`). Exact up to [`DPQ_MAX_ANCHORS`] cells,
/// anchor-strided above.
pub fn dpq(data: &[f32], d: usize, g: GridShape, p: f64, k_max: usize) -> f64 {
    dpq_with_anchor_cap(data, d, g, p, k_max, DPQ_MAX_ANCHORS)
}

fn dpq_with_anchor_cap(
    data: &[f32],
    d: usize,
    g: GridShape,
    p: f64,
    k_max: usize,
    max_anchors: usize,
) -> f64 {
    let n = g.n();
    assert_eq!(data.len(), n * d);
    assert!(n >= 2);
    let k_max = k_max.clamp(1, n - 1);
    // Deterministic anchor stride, bumped to be coprime with the grid
    // width: a stride sharing a factor with `w` would sample anchors from
    // a fixed subset of columns (stride 4 on a 128-wide grid hits only
    // every 4th column), biasing the estimate on layouts whose quality
    // varies by column. Coprime strides cycle through all columns.
    // n ≤ max_anchors keeps stride = 1 — the exact, bit-identical path.
    let mut stride = n.div_ceil(max_anchors.max(1)).max(1);
    while stride > 1 && gcd(stride, g.w) != 1 {
        stride += 1;
    }
    let mut anchors = 0usize;

    // Per anchor cell: feature distances to everyone, ranked once by grid
    // distance and once by feature distance.
    let mut d_grid_acc = vec![0.0f64; k_max]; // Σ over anchors of mean-to-k-grid-nearest
    let mut d_opt_acc = vec![0.0f64; k_max];
    let mut d_rand_sum = 0.0f64;

    let mut feat = vec![0.0f32; n];
    let mut order_grid: Vec<u32> = Vec::with_capacity(n);
    let mut order_feat: Vec<u32> = Vec::with_capacity(n);

    for i in (0..n).step_by(stride) {
        anchors += 1;
        let xi = &data[i * d..(i + 1) * d];
        for j in 0..n {
            feat[j] = l2(xi, &data[j * d..(j + 1) * d]);
        }
        order_grid.clear();
        order_feat.clear();
        order_grid.extend((0..n as u32).filter(|&j| j as usize != i));
        order_feat.extend_from_slice(&order_grid);
        // Rank by grid distance (ties by index → deterministic).
        order_grid.sort_by(|&a, &b| {
            g.cell_dist_sq(i, a as usize)
                .partial_cmp(&g.cell_dist_sq(i, b as usize))
                .unwrap()
                .then(a.cmp(&b))
        });
        order_feat.sort_by(|&a, &b| {
            feat[a as usize].partial_cmp(&feat[b as usize]).unwrap().then(a.cmp(&b))
        });

        let mut grid_run = 0.0f64;
        let mut opt_run = 0.0f64;
        for k in 0..k_max {
            grid_run += feat[order_grid[k] as usize] as f64;
            opt_run += feat[order_feat[k] as usize] as f64;
            d_grid_acc[k] += grid_run / (k + 1) as f64;
            d_opt_acc[k] += opt_run / (k + 1) as f64;
        }
        d_rand_sum += feat.iter().map(|&v| v as f64).sum::<f64>() / (n - 1) as f64;
    }

    let d_rand = d_rand_sum / anchors as f64;
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for k in 0..k_max {
        let d_grid = d_grid_acc[k] / anchors as f64;
        let d_opt = d_opt_acc[k] / anchors as f64;
        let gap = d_rand - d_opt;
        let q = if gap <= 1e-12 {
            1.0 // degenerate data: every layout is optimal
        } else {
            ((d_rand - d_grid) / gap).clamp(0.0, 1.0)
        };
        let w = 1.0 / (k + 1) as f64;
        num += w * q.powf(p);
        den += w;
    }
    (num / den).powf(1.0 / p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// 1-D ramp on a line grid is the optimal layout → DPQ ≈ 1.
    #[test]
    fn perfect_line_is_one() {
        let g = GridShape::new(1, 32);
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let q = dpq16(&data, 1, g);
        assert!(q > 0.97, "q={q}");
    }

    #[test]
    fn random_layout_scores_low() {
        let mut rng = Pcg32::new(9);
        let g = GridShape::new(16, 16);
        let data: Vec<f32> = (0..g.n() * 3).map(|_| rng.f32()).collect();
        let q = dpq16(&data, 3, g);
        assert!(q < 0.45, "random layout q={q}");
    }

    #[test]
    fn sorted_beats_shuffled() {
        // Smooth 2-D gradient arranged correctly vs the same set shuffled.
        let g = GridShape::new(8, 8);
        let mut sorted = Vec::with_capacity(g.n() * 2);
        for r in 0..8 {
            for c in 0..8 {
                sorted.push(r as f32 / 8.0);
                sorted.push(c as f32 / 8.0);
            }
        }
        let mut rng = Pcg32::new(10);
        let perm = rng.permutation(g.n());
        let mut shuffled = vec![0.0f32; sorted.len()];
        for (i, &s) in perm.iter().enumerate() {
            shuffled[i * 2..i * 2 + 2].copy_from_slice(&sorted[s as usize * 2..s as usize * 2 + 2]);
        }
        let qs = dpq16(&sorted, 2, g);
        let qr = dpq16(&shuffled, 2, g);
        assert!(qs > qr + 0.3, "sorted {qs} vs shuffled {qr}");
        assert!(qs > 0.9, "gradient layout should be near-optimal, got {qs}");
    }

    #[test]
    fn bounded_in_unit_interval() {
        let mut rng = Pcg32::new(11);
        for seed in 0..3 {
            let g = GridShape::new(6, 6);
            let mut r = Pcg32::new(seed);
            let data: Vec<f32> = (0..g.n() * 4).map(|_| r.f32() + rng.f32() * 0.0).collect();
            let q = dpq(&data, 4, g, 16.0, 12);
            assert!((0.0..=1.0).contains(&q));
        }
    }

    #[test]
    fn degenerate_constant_data_is_one() {
        let g = GridShape::new(4, 4);
        let data = vec![0.7f32; 16 * 2];
        assert_eq!(dpq16(&data, 2, g), 1.0);
    }

    #[test]
    fn anchor_stride_estimates_the_exact_metric() {
        // Strided anchors (the large-N path) must stay close to the exact
        // value and keep the sorted-vs-shuffled ordering.
        let mut rng = Pcg32::new(13);
        let g = GridShape::new(16, 16);
        let mut sorted = Vec::with_capacity(g.n() * 2);
        for r in 0..16 {
            for c in 0..16 {
                sorted.push(r as f32 / 16.0);
                sorted.push(c as f32 / 16.0);
            }
        }
        let random: Vec<f32> = (0..g.n() * 2).map(|_| rng.f32()).collect();
        for data in [&sorted, &random] {
            let exact = dpq_with_anchor_cap(data, 2, g, 16.0, 16, usize::MAX);
            let strided = dpq_with_anchor_cap(data, 2, g, 16.0, 16, 128);
            assert!((exact - strided).abs() < 0.1, "exact {exact} vs strided {strided}");
        }
        let qs = dpq_with_anchor_cap(&sorted, 2, g, 16.0, 16, 128);
        let qr = dpq_with_anchor_cap(&random, 2, g, 16.0, 16, 128);
        assert!(qs > qr + 0.3, "sorted {qs} vs random {qr}");
        // At or below the cap the strided path IS the exact path.
        assert_eq!(
            dpq16(&sorted, 2, g).to_bits(),
            dpq_with_anchor_cap(&sorted, 2, g, 16.0, default_k_max(g.n()), g.n()).to_bits()
        );
    }

    #[test]
    fn higher_p_is_stricter() {
        let mut rng = Pcg32::new(12);
        let g = GridShape::new(8, 8);
        let data: Vec<f32> = (0..g.n() * 3).map(|_| rng.f32()).collect();
        let q2 = dpq(&data, 3, g, 2.0, 16);
        let q16 = dpq(&data, 3, g, 16.0, 16);
        // power-mean inequality: higher exponent ≥ for same q(k) profile
        assert!(q16 >= q2 - 1e-9, "q16={q16} q2={q2}");
    }
}
