//! Mean neighbor distance — the quantity L_nbr optimizes, reported raw
//! (unnormalized) and normalized by the dataset's mean pairwise distance.

use crate::grid::GridShape;
use crate::util::stats::l2;

/// Mean L2 feature distance over horizontally+vertically adjacent cells of
/// `data` (row-major `[n, d]`, already arranged on the grid).
pub fn mean_neighbor_distance(data: &[f32], d: usize, g: GridShape) -> f64 {
    assert_eq!(data.len(), g.n() * d);
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for r in 0..g.h {
        for c in 0..g.w {
            let i = g.index(r, c);
            if c + 1 < g.w {
                sum += l2(&data[i * d..(i + 1) * d], &data[(i + 1) * d..(i + 2) * d]) as f64;
                count += 1;
            }
            if r + 1 < g.h {
                let j = g.index(r + 1, c);
                sum += l2(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]) as f64;
                count += 1;
            }
        }
    }
    sum / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_computed_2x2() {
        // scalar grid [[0,1],[2,4]] → pairs |0-1|,|2-4|,|0-2|,|1-4| = 1,2,2,3
        let g = GridShape::new(2, 2);
        let data = vec![0.0, 1.0, 2.0, 4.0];
        assert!((mean_neighbor_distance(&data, 1, g) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn constant_grid_zero() {
        let g = GridShape::new(3, 3);
        let data = vec![0.5f32; 9 * 4];
        assert_eq!(mean_neighbor_distance(&data, 4, g), 0.0);
    }

    #[test]
    fn sorted_line_beats_shuffled_line() {
        use crate::util::rng::Pcg32;
        let g = GridShape::new(1, 64);
        let sorted: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
        let mut shuffled = sorted.clone();
        Pcg32::new(3).shuffle(&mut shuffled);
        assert!(
            mean_neighbor_distance(&sorted, 1, g) < mean_neighbor_distance(&shuffled, 1, g)
        );
    }
}
